"""Launch planning for the bitonic network — **jax-free on purpose**.

A *plan* is the sequence of launches (``pallas_call``s) a variant executes
for a given row length — the Python mirror of
``rust/src/sort/network.rs::Network::launches`` / ``merge_launches``. It
lives apart from the jax model (``compile.model`` re-exports everything
here) so the rust/python parity guard (``tests/test_launch_parity.py`` vs
``rust/tests/launch_parity.rs``, both pinned to the checked-in golden
table) runs even where jax is not installed — CI installs only
numpy+pytest, and a planner drift must fail there, not skip.

Variants (paper Table 1 columns):

* ``basic``      — §3.3: one launch per compare-exchange step.
* ``semi``       — §4.1 (optimization 1): in-VMEM fused stages.
* ``optimized``  — §4.1 + §4.2 (optimizations 1 and 2): fused stages plus
                   register-paired double steps for the global stage.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator

VARIANTS = ("basic", "semi", "optimized")

#: Default VMEM tile width (keys per row per tile) for the fused stages.
#: §Perf L1 iteration 1: 256 → 4096 cut interpret-mode launches ~2× and
#: measured 2.3–3.6× faster at n=2^16 (EXPERIMENTS.md §Perf); 4096 u32
#: keys/row × batch 8 × in+out = 256 KiB — 1.6% of a TPU core's 16 MiB
#: VMEM (analysis.py), and exactly the K10's 48 KiB/2/4B shared-memory
#: tile from the paper's own configuration. The rust native executor uses
#: the same value (``runtime::DEFAULT_PLAN_BLOCK``).
DEFAULT_BLOCK = 4096


@dataclass(frozen=True)
class GlobalStep:
    """One global compare-exchange pass (paper §3.3)."""

    phase_len: int
    stride: int


@dataclass(frozen=True)
class GlobalDoubleStep:
    """Two register-paired global steps in one pass (paper §4.2)."""

    phase_len: int
    stride_hi: int


@dataclass(frozen=True)
class BlockFused:
    """In-VMEM fused stage covering phases [phase_lo..phase_hi] (§4.1)."""

    phase_lo: int
    phase_hi: int
    stride_max: int
    paired: bool


Launch = GlobalStep | GlobalDoubleStep | BlockFused


def _phase_tail(k: int, block: int, paired: bool) -> Iterator[Launch]:
    """Launches of one post-presort phase ``k``: paired global doubles
    while both strides stay >= block (opt 2), single global steps down to
    ``block``, then the in-block fused tail (opt 1). Shared by ``plan``
    (every phase k > block) and ``merge_plan`` (exactly this at k = n) so
    the two cannot drift — mirrors ``phase_tail_launches`` in
    ``rust/src/sort/network.rs``."""
    j = k // 2
    if paired:
        while j >= 2 * block:
            yield GlobalDoubleStep(k, j)
            j //= 4
    while j >= block:
        yield GlobalStep(k, j)
        j //= 2
    yield BlockFused(k, k, block // 2, paired)


def plan(n: int, variant: str, block: int = DEFAULT_BLOCK) -> Iterator[Launch]:
    """The launch schedule for sorting rows of length ``n``.

    Mirrors ``rust/src/sort/network.rs::Network::launches`` exactly.
    """
    if n < 2 or n & (n - 1):
        raise ValueError(f"n must be a power of two >= 2, got {n}")
    if variant not in VARIANTS:
        raise ValueError(f"unknown variant {variant!r}")
    if block < 2 or block & (block - 1):
        raise ValueError(f"block must be a power of two >= 2, got {block}")
    block = min(block, n)

    if variant == "basic":
        k = 2
        while k <= n:
            j = k // 2
            while j >= 1:
                yield GlobalStep(k, j)
                j //= 2
            k *= 2
        return

    paired = variant == "optimized"
    # Presort: every phase up to `block` runs inside the tile.
    yield BlockFused(2, block, block // 2, paired)
    k = 2 * block
    while k <= n:
        yield from _phase_tail(k, block, paired)
        k *= 2


def merge_plan(n: int, variant: str, block: int = DEFAULT_BLOCK):
    """Launches of the *final phase only* (k = n): merging one bitonic
    row of length n into sorted order. log2(n) steps instead of the full
    network's k(k+1)/2 — this is what makes merge trees cheap. The fused
    grouping is structurally ``_phase_tail`` at k = n, the same helper
    ``plan`` folds over every post-presort phase."""
    if n < 2 or n & (n - 1):
        raise ValueError(f"n must be a power of two >= 2, got {n}")
    if variant not in VARIANTS:
        raise ValueError(f"unknown variant {variant!r}")
    if block < 2 or block & (block - 1):
        raise ValueError(f"block must be a power of two >= 2, got {block}")
    block = min(block, n)
    if variant == "basic":
        j = n // 2
        while j >= 1:
            yield GlobalStep(n, j)
            j //= 2
        return
    yield from _phase_tail(n, block, variant == "optimized")


def launch_counts(n: int, variant: str, block: int = DEFAULT_BLOCK):
    """(launches, global_passes) — the two quantities the paper optimizes.

    Every launch is exactly one read+write pass over the array, so the two
    numbers coincide; they are reported separately because the simulator
    charges them differently (latency vs bandwidth).
    """
    launches = list(plan(n, variant, block))
    return len(launches), len(launches)
