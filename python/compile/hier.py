"""Hierarchical mega-sort reference — the jax-free mirror of
``rust/src/sort/kmerge.rs`` (loser-tree k-way merge) and the tiling
logic of ``rust/src/sort/hybrid.rs::HierarchicalSorter``, plus the
autotune fallback-distance rule from ``rust/src/runtime/autotune.rs``.

Pure standard library. Keys are plain ints in u32 range; the rust side
carries the same algorithms over its ``SortKey`` trait (the f32 total
order is exercised by the rust tests). These functions are the oracle
``python/tests/test_hier.py`` checks the structure against, 1:1 with the
rust unit tests so a divergence shows up in whichever side drifted.
"""

from __future__ import annotations

from bisect import bisect_left, bisect_right

MAX_KEY = 0xFFFF_FFFF

#: Default upper bound on the device tile (mirror of
#: ``sort::hybrid::DEFAULT_TILE_CAP``): the largest fixture class, i.e.
#: a tile the executor is known to sort entirely in cache-resident
#: batches.
DEFAULT_TILE_CAP = 1 << 16


class LoserTree:
    """Tournament (loser) tree over ``k`` sorted runs: Knuth §5.4.1.

    Layout mirror of the rust struct: conceptual leaves at ``k..2k``
    (leaf ``k + j`` is run ``j``), internal nodes ``1..k`` each holding
    the *loser* of the match below, the overall winner cached at
    ``tree[0]``. Exhaustion is positional, so runs whose keys *are*
    ``MAX_KEY`` still merge correctly.
    """

    def __init__(self, runs: list[list[int]]):
        self.runs = runs
        self.pos = [0] * len(runs)
        self.k = max(len(runs), 1)
        self.tree = [0] * self.k
        winners = [0] * (2 * self.k)
        for j in range(len(runs)):
            winners[self.k + j] = j
        for node in range(self.k - 1, 0, -1):
            a, b = winners[2 * node], winners[2 * node + 1]
            if self._leads(a, b):
                winners[node], self.tree[node] = a, b
            else:
                winners[node], self.tree[node] = b, a
        self.tree[0] = winners[1]

    def _head(self, run: int):
        if run < len(self.runs) and self.pos[run] < len(self.runs[run]):
            return self.runs[run][self.pos[run]]
        return None

    def _leads(self, a: int, b: int) -> bool:
        """Exhausted runs lose; ties break on run index (stable)."""
        x, y = self._head(a), self._head(b)
        if x is None:
            return False
        if y is None:
            return True
        if x != y:
            return x < y
        return a <= b

    def pop(self):
        winner = self.tree[0]
        val = self._head(winner)
        if val is None:
            return None
        self.pos[winner] += 1
        cur = winner
        node = (self.k + winner) // 2
        while node >= 1:
            if self._leads(self.tree[node], cur):
                self.tree[node], cur = cur, self.tree[node]
            node //= 2
        self.tree[0] = cur
        return val


def kway_merge(runs: list[list[int]]) -> list[int]:
    """Merge ``k`` sorted runs in one streaming pass (mirror of rust
    ``kway_merge``: ``O(total * log k)`` comparisons)."""
    if not runs:
        return []
    if len(runs) == 1:
        return list(runs[0])
    tree = LoserTree(runs)
    out = []
    while (v := tree.pop()) is not None:
        out.append(v)
    return out


def pick_tile(class_ns: list[int], cap: int | None = None) -> int | None:
    """Mirror of ``HierarchicalSorter::pick_tile``: the largest size
    class ``<= cap`` (default :data:`DEFAULT_TILE_CAP`), else the
    smallest class; ``None`` on an empty menu."""
    cap = DEFAULT_TILE_CAP if cap is None else cap
    under = [n for n in class_ns if n <= cap]
    if under:
        return max(under)
    return min(class_ns) if class_ns else None


def hierarchical_sort(keys: list[int], tile: int, batch: int = 1,
                      device_sort=sorted) -> tuple[list[int], dict]:
    """Mirror of ``HierarchicalSorter::sort``: MAX-pad to a tile
    multiple, device-sort ``batch`` tiles per dispatch, one k-way merge,
    truncate to the real length.

    ``device_sort`` stands in for the executor (a whole dispatch group
    is sorted per-tile through it). Returns ``(sorted, stats)`` with
    ``stats`` mirroring ``HierarchicalStats``.
    """
    real_len = len(keys)
    stats = {"tile": tile, "tiles": 0, "device_dispatches": 0}
    if real_len <= 1:
        return list(keys), stats
    padded_len = -(-real_len // tile) * tile
    padded = list(keys) + [MAX_KEY] * (padded_len - real_len)
    group = batch * tile
    sorted_tiles: list[int] = []
    for start in range(0, padded_len, group):
        chunk = padded[start:start + group]
        chunk += [MAX_KEY] * (group - len(chunk))
        for t in range(0, group, tile):
            sorted_tiles.extend(device_sort(chunk[t:t + tile]))
        stats["device_dispatches"] += 1
    sorted_tiles = sorted_tiles[:padded_len]
    stats["tiles"] = padded_len // tile
    if stats["tiles"] == 1:
        return sorted_tiles[:real_len], stats
    runs = [sorted_tiles[i:i + tile] for i in range(0, padded_len, tile)]
    return kway_merge(runs)[:real_len], stats


# ----------------------------------------------------------------------
# Splitter-partitioned parallel merge (mirror of rust/src/sort/pmerge.rs)
# ----------------------------------------------------------------------
#
# The geometry functions below are 1:1 with the rust module: the same
# regular sampling, the same ``(key, run, index)`` rank tie-break, the
# same binary-search cuts. ``pmerge`` executes the bucket merges
# serially (the parallel dispatch itself is the rust ThreadPool's job);
# what this mirror proves is that the *partition* is identical, which is
# the part the static checker and the balance bound reason about.


def _rank_key(key: int, q: int, i: int) -> tuple[int, int, int]:
    """The ``(key, run, index)`` total rank order of ``rank_cmp``."""
    return (key, q, i)


def _cut_at(run: list[int], q: int, splitter: int, rs: int, is_: int) -> int:
    """Keys of run ``q`` ranked at or below the splitter (key at index
    ``is_`` of run ``rs``) — mirror of ``pmerge::cut_at``."""
    lo = bisect_left(run, splitter)
    hi = bisect_right(run, splitter)
    if q < rs:
        return hi
    if q > rs:
        return lo
    return max(lo, min(is_ + 1, hi))


def _select_splitters(runs: list[list[int]], parts: int) -> list[tuple[int, int]]:
    """PSRS-style regular sampling — mirror of ``select_splitters``:
    up to ``parts - 1`` evenly spaced positions per run, pooled, rank
    sorted, then evenly spaced ranks picked as splitters."""
    samples: list[tuple[int, int]] = []
    for q, run in enumerate(runs):
        last = None
        for j in range(1, parts):
            idx = j * len(run) // parts
            if idx < len(run) and idx != last:
                samples.append((q, idx))
                last = idx
    samples.sort(key=lambda s: _rank_key(runs[s[0]][s[1]], s[0], s[1]))
    splitters: list[tuple[int, int]] = []
    last_pick = None
    for i in range(1, parts):
        pick = i * len(samples) // parts
        if pick < len(samples) and pick != last_pick:
            splitters.append(samples[pick])
            last_pick = pick
    return splitters


def plan_partition(runs: list[list[int]], parts: int) -> list[list[int]]:
    """Mirror of ``pmerge::plan_partition``: the cut matrix with
    ``parts + 1`` rows of ``len(runs)`` columns. Row 0 is zeros, the last
    row is the run lengths, rows are elementwise non-decreasing, and
    bucket ``b`` consumes ``runs[q][cuts[b][q]:cuts[b+1][q]]``."""
    parts = max(parts, 1)
    lens = [len(r) for r in runs]
    cuts = [[0] * len(runs)]
    for rs, is_ in _select_splitters(runs, parts):
        splitter = runs[rs][is_]
        row = [_cut_at(run, q, splitter, rs, is_) for q, run in enumerate(runs)]
        assert all(a <= b for a, b in zip(cuts[-1], row)), \
            "splitter cuts must be monotone"
        cuts.append(row)
    cuts.append(lens)
    return cuts


def bucket_sizes(cuts: list[list[int]]) -> list[int]:
    """Keys per bucket (mirror of ``MergePlan::bucket_sizes``)."""
    return [
        sum(hi - lo for lo, hi in zip(cuts[b], cuts[b + 1]))
        for b in range(len(cuts) - 1)
    ]


def balance_bound(lens: list[int], parts: int) -> int:
    """Mirror of ``pmerge::balance_bound``: a provable, key-value-free
    upper bound on the largest bucket ``plan_partition`` can produce."""
    parts = max(parts, 1)
    nonempty = sum(1 for m in lens if m > 0)
    gap_max = max((-(-m // parts) + 1 for m in lens), default=1)
    samples = 0
    for m in lens:
        last = None
        for j in range(1, parts):
            idx = j * m // parts
            if idx < m and idx != last:
                samples += 1
                last = idx
    return gap_max * (-(-samples // parts) + nonempty + 1)


def pmerge(runs: list[list[int]], parts: int) -> list[int]:
    """Mirror of ``pmerge::pmerge`` with the bucket merges run serially:
    plan the partition, loser-tree merge each bucket's slices, and
    concatenate in bucket order. Must be bit-exact with
    :func:`kway_merge` — the tests assert exactly that."""
    cuts = plan_partition(runs, parts)
    out: list[int] = []
    for b in range(len(cuts) - 1):
        srcs = [
            runs[q][cuts[b][q]:cuts[b + 1][q]]
            for q in range(len(runs))
            if cuts[b][q] < cuts[b + 1][q]
        ]
        out.extend(kway_merge(srcs))
    return out


def fallback_shortfall(entry_n: int, n: int) -> int | None:
    """Mirror of ``autotune::fallback_shortfall``: when the nearest
    tuned class is more than 4x smaller than the requested ``n``, return
    the distance factor ``n // entry_n`` (the WARN the CLI logs);
    ``None`` when the fallback is close enough."""
    if entry_n * 4 < n:
        return n // entry_n
    return None
