"""Hierarchical mega-sort reference — the jax-free mirror of
``rust/src/sort/kmerge.rs`` (loser-tree k-way merge) and the tiling
logic of ``rust/src/sort/hybrid.rs::HierarchicalSorter``, plus the
autotune fallback-distance rule from ``rust/src/runtime/autotune.rs``.

Pure standard library. Keys are plain ints in u32 range; the rust side
carries the same algorithms over its ``SortKey`` trait (the f32 total
order is exercised by the rust tests). These functions are the oracle
``python/tests/test_hier.py`` checks the structure against, 1:1 with the
rust unit tests so a divergence shows up in whichever side drifted.
"""

from __future__ import annotations

MAX_KEY = 0xFFFF_FFFF

#: Default upper bound on the device tile (mirror of
#: ``sort::hybrid::DEFAULT_TILE_CAP``): the largest fixture class, i.e.
#: a tile the executor is known to sort entirely in cache-resident
#: batches.
DEFAULT_TILE_CAP = 1 << 16


class LoserTree:
    """Tournament (loser) tree over ``k`` sorted runs: Knuth §5.4.1.

    Layout mirror of the rust struct: conceptual leaves at ``k..2k``
    (leaf ``k + j`` is run ``j``), internal nodes ``1..k`` each holding
    the *loser* of the match below, the overall winner cached at
    ``tree[0]``. Exhaustion is positional, so runs whose keys *are*
    ``MAX_KEY`` still merge correctly.
    """

    def __init__(self, runs: list[list[int]]):
        self.runs = runs
        self.pos = [0] * len(runs)
        self.k = max(len(runs), 1)
        self.tree = [0] * self.k
        winners = [0] * (2 * self.k)
        for j in range(len(runs)):
            winners[self.k + j] = j
        for node in range(self.k - 1, 0, -1):
            a, b = winners[2 * node], winners[2 * node + 1]
            if self._leads(a, b):
                winners[node], self.tree[node] = a, b
            else:
                winners[node], self.tree[node] = b, a
        self.tree[0] = winners[1]

    def _head(self, run: int):
        if run < len(self.runs) and self.pos[run] < len(self.runs[run]):
            return self.runs[run][self.pos[run]]
        return None

    def _leads(self, a: int, b: int) -> bool:
        """Exhausted runs lose; ties break on run index (stable)."""
        x, y = self._head(a), self._head(b)
        if x is None:
            return False
        if y is None:
            return True
        if x != y:
            return x < y
        return a <= b

    def pop(self):
        winner = self.tree[0]
        val = self._head(winner)
        if val is None:
            return None
        self.pos[winner] += 1
        cur = winner
        node = (self.k + winner) // 2
        while node >= 1:
            if self._leads(self.tree[node], cur):
                self.tree[node], cur = cur, self.tree[node]
            node //= 2
        self.tree[0] = cur
        return val


def kway_merge(runs: list[list[int]]) -> list[int]:
    """Merge ``k`` sorted runs in one streaming pass (mirror of rust
    ``kway_merge``: ``O(total * log k)`` comparisons)."""
    if not runs:
        return []
    if len(runs) == 1:
        return list(runs[0])
    tree = LoserTree(runs)
    out = []
    while (v := tree.pop()) is not None:
        out.append(v)
    return out


def pick_tile(class_ns: list[int], cap: int | None = None) -> int | None:
    """Mirror of ``HierarchicalSorter::pick_tile``: the largest size
    class ``<= cap`` (default :data:`DEFAULT_TILE_CAP`), else the
    smallest class; ``None`` on an empty menu."""
    cap = DEFAULT_TILE_CAP if cap is None else cap
    under = [n for n in class_ns if n <= cap]
    if under:
        return max(under)
    return min(class_ns) if class_ns else None


def hierarchical_sort(keys: list[int], tile: int, batch: int = 1,
                      device_sort=sorted) -> tuple[list[int], dict]:
    """Mirror of ``HierarchicalSorter::sort``: MAX-pad to a tile
    multiple, device-sort ``batch`` tiles per dispatch, one k-way merge,
    truncate to the real length.

    ``device_sort`` stands in for the executor (a whole dispatch group
    is sorted per-tile through it). Returns ``(sorted, stats)`` with
    ``stats`` mirroring ``HierarchicalStats``.
    """
    real_len = len(keys)
    stats = {"tile": tile, "tiles": 0, "device_dispatches": 0}
    if real_len <= 1:
        return list(keys), stats
    padded_len = -(-real_len // tile) * tile
    padded = list(keys) + [MAX_KEY] * (padded_len - real_len)
    group = batch * tile
    sorted_tiles: list[int] = []
    for start in range(0, padded_len, group):
        chunk = padded[start:start + group]
        chunk += [MAX_KEY] * (group - len(chunk))
        for t in range(0, group, tile):
            sorted_tiles.extend(device_sort(chunk[t:t + tile]))
        stats["device_dispatches"] += 1
    sorted_tiles = sorted_tiles[:padded_len]
    stats["tiles"] = padded_len // tile
    if stats["tiles"] == 1:
        return sorted_tiles[:real_len], stats
    runs = [sorted_tiles[i:i + tile] for i in range(0, padded_len, tile)]
    return kway_merge(runs)[:real_len], stats


def fallback_shortfall(entry_n: int, n: int) -> int | None:
    """Mirror of ``autotune::fallback_shortfall``: when the nearest
    tuned class is more than 4x smaller than the requested ``n``, return
    the distance factor ``n // entry_n`` (the WARN the CLI logs);
    ``None`` when the fallback is close enough."""
    if entry_n * 4 < n:
        return n // entry_n
    return None
