"""Layer-2 JAX model: the full bitonic sorting network composed from the
Layer-1 Pallas kernels.

A *plan* is the sequence of launches (pallas_calls) a variant executes for
a given row length — the Python mirror of ``rust/src/sort/network.rs``
``Network::launches`` (the two enumerations are asserted equal in tests on
both sides via the closed forms). ``sort()`` folds the plan over the input.

Variants (paper Table 1 columns):

* ``basic``      — §3.3: one launch per compare-exchange step.
* ``semi``       — §4.1 (optimization 1): in-VMEM fused stages.
* ``optimized``  — §4.1 + §4.2 (optimizations 1 and 2): fused stages plus
                   register-paired double steps for the global stage.

The compute graph is deliberately *unrolled* (a Python loop over launches,
not ``lax.fori_loop``): every step has different static strides/shapes, and
unrolling lets XLA see and fuse the whole network. See EXPERIMENTS.md §Perf
for the measured effect.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Iterator

import jax
import jax.numpy as jnp

from .kernels import bitonic as kb

VARIANTS = ("basic", "semi", "optimized")

#: Default VMEM tile width (keys per row per tile) for the fused stages.
#: §Perf L1 iteration 1: 256 → 4096 cut interpret-mode launches ~2× and
#: measured 2.3–3.6× faster at n=2^16 (EXPERIMENTS.md §Perf); 4096 u32
#: keys/row × batch 8 × in+out = 256 KiB — 1.6% of a TPU core's 16 MiB
#: VMEM (analysis.py), and exactly the K10's 48 KiB/2/4B shared-memory
#: tile from the paper's own configuration.
DEFAULT_BLOCK = 4096


# ----------------------------------------------------------------------
# Launch plan
# ----------------------------------------------------------------------


@dataclass(frozen=True)
class GlobalStep:
    """One global compare-exchange pass (paper §3.3)."""

    phase_len: int
    stride: int


@dataclass(frozen=True)
class GlobalDoubleStep:
    """Two register-paired global steps in one pass (paper §4.2)."""

    phase_len: int
    stride_hi: int


@dataclass(frozen=True)
class BlockFused:
    """In-VMEM fused stage covering phases [phase_lo..phase_hi] (§4.1)."""

    phase_lo: int
    phase_hi: int
    stride_max: int
    paired: bool


Launch = GlobalStep | GlobalDoubleStep | BlockFused


def plan(n: int, variant: str, block: int = DEFAULT_BLOCK) -> Iterator[Launch]:
    """The launch schedule for sorting rows of length ``n``.

    Mirrors ``rust/src/sort/network.rs::Network::launches`` exactly.
    """
    if n < 2 or n & (n - 1):
        raise ValueError(f"n must be a power of two >= 2, got {n}")
    if variant not in VARIANTS:
        raise ValueError(f"unknown variant {variant!r}")
    block = min(block, n)

    if variant == "basic":
        k = 2
        while k <= n:
            j = k // 2
            while j >= 1:
                yield GlobalStep(k, j)
                j //= 2
            k *= 2
        return

    paired = variant == "optimized"
    # Presort: every phase up to `block` runs inside the tile.
    yield BlockFused(2, block, block // 2, paired)
    k = 2 * block
    while k <= n:
        j = k // 2
        if paired:
            while j >= 2 * block:
                yield GlobalDoubleStep(k, j)
                j //= 4
        while j >= block:
            yield GlobalStep(k, j)
            j //= 2
        yield BlockFused(k, k, block // 2, paired)
        k *= 2


def launch_counts(n: int, variant: str, block: int = DEFAULT_BLOCK):
    """(launches, global_passes) — the two quantities the paper optimizes.

    Every launch is exactly one read+write pass over the array, so the two
    numbers coincide; they are reported separately because the simulator
    charges them differently (latency vs bandwidth).
    """
    launches = list(plan(n, variant, block))
    return len(launches), len(launches)


# ----------------------------------------------------------------------
# Sort
# ----------------------------------------------------------------------


def sort(x, variant: str = "optimized", *, block: int = DEFAULT_BLOCK,
         descending: bool = False, grid_cells: int = kb.DEFAULT_GRID_CELLS):
    """Sort each row of ``(B, N)`` ascending (or descending).

    N must be a power of two; the rust coordinator pads requests with
    ``MAX_KEY`` before dispatch, so the compiled artifact only ever sees
    power-of-two rows.
    """
    b, n = x.shape
    del b
    flip_phase = n if descending else 0
    for launch in plan(n, variant, block):
        if isinstance(launch, GlobalStep):
            x = kb.step(x, launch.phase_len, launch.stride,
                        flip=descending and launch.phase_len == n,
                        grid_cells=grid_cells)
        elif isinstance(launch, GlobalDoubleStep):
            x = kb.double_step(x, launch.phase_len, launch.stride_hi,
                               flip=descending and launch.phase_len == n,
                               grid_cells=grid_cells)
        else:
            x = kb.fused_block(x, launch.stride_max * 2, launch.phase_lo,
                               launch.phase_hi, paired=launch.paired,
                               flip_phase=flip_phase,
                               grid_cells=grid_cells)
    return x


def make_sort_fn(variant: str, *, block: int = DEFAULT_BLOCK,
                 descending: bool = False,
                 grid_cells: int = kb.DEFAULT_GRID_CELLS):
    """A jit-able ``x -> (sorted,)`` closure for AOT export.

    Returns a 1-tuple because the HLO interchange uses ``return_tuple=True``
    (the rust side unwraps with ``to_tuple1``).
    """

    def fn(x):
        return (sort(x, variant, block=block, descending=descending,
                     grid_cells=grid_cells),)

    fn.__name__ = f"bitonic_sort_{variant}"
    return fn


# ----------------------------------------------------------------------
# Bitonic merge (the paper §3's core primitive, exported standalone)
# ----------------------------------------------------------------------


def merge_plan(n: int, variant: str, block: int = DEFAULT_BLOCK):
    """Launches of the *final phase only* (k = n): merging one bitonic
    row of length n into sorted order. log2(n) steps instead of the full
    network's k(k+1)/2 — this is what makes merge trees cheap."""
    if n < 2 or n & (n - 1):
        raise ValueError(f"n must be a power of two >= 2, got {n}")
    block = min(block, n)
    k = n
    j = k // 2
    paired = variant == "optimized"
    if variant == "basic":
        while j >= 1:
            yield GlobalStep(k, j)
            j //= 2
        return
    if paired:
        while j >= 2 * block:
            yield GlobalDoubleStep(k, j)
            j //= 4
    while j >= block:
        yield GlobalStep(k, j)
        j //= 2
    yield BlockFused(k, k, block // 2, paired)


def merge_sorted_halves(x, variant: str = "optimized", *,
                        block: int = DEFAULT_BLOCK, descending: bool = False,
                        grid_cells: int = kb.DEFAULT_GRID_CELLS):
    """Merge rows whose two halves are each sorted ascending.

    Reverses the second half (making each row bitonic by construction —
    the paper §3.1's definition) and runs the final-phase merge. This is
    the primitive behind the rust `sort::hybrid` out-of-core sorter:
    device-sorted chunks are merged pairwise in log-depth instead of
    re-sorting, at log2(n) steps per level instead of k(k+1)/2.
    """
    b, n = x.shape
    half = n // 2
    x = jnp.concatenate([x[:, :half], x[:, half:][:, ::-1]], axis=1)
    flip_phase = n if descending else 0
    for launch in merge_plan(n, variant, block):
        if isinstance(launch, GlobalStep):
            x = kb.step(x, launch.phase_len, launch.stride, flip=descending,
                        grid_cells=grid_cells)
        elif isinstance(launch, GlobalDoubleStep):
            x = kb.double_step(x, launch.phase_len, launch.stride_hi,
                               flip=descending, grid_cells=grid_cells)
        else:
            x = kb.fused_block(x, launch.stride_max * 2, launch.phase_lo,
                               launch.phase_hi, paired=launch.paired,
                               flip_phase=flip_phase, grid_cells=grid_cells)
    return x


def make_merge_fn(variant: str, *, block: int = DEFAULT_BLOCK,
                  descending: bool = False,
                  grid_cells: int = kb.DEFAULT_GRID_CELLS):
    """Jit-able ``x -> (merged,)`` closure for AOT export (1-tuple, like
    make_sort_fn)."""

    def fn(x):
        return (merge_sorted_halves(x, variant, block=block,
                                    descending=descending,
                                    grid_cells=grid_cells),)

    fn.__name__ = f"bitonic_merge_{variant}"
    return fn


@functools.lru_cache(maxsize=None)
def jitted(variant: str, batch: int, n: int, dtype: str = "uint32", *,
           block: int = DEFAULT_BLOCK, descending: bool = False):
    """Compiled sort for a concrete (variant, batch, n, dtype) — used by
    the python test-suite; the rust runtime uses the AOT artifacts instead."""
    fn = make_sort_fn(variant, block=block, descending=descending)
    spec = jax.ShapeDtypeStruct((batch, n), jnp.dtype(dtype))
    return jax.jit(fn).lower(spec).compile()
