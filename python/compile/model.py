"""Layer-2 JAX model: the full bitonic sorting network composed from the
Layer-1 Pallas kernels.

A *plan* is the sequence of launches (pallas_calls) a variant executes for
a given row length — the Python mirror of ``rust/src/sort/network.rs``
``Network::launches`` (the two enumerations are asserted equal in tests on
both sides via the closed forms and a checked-in golden table). Planning
itself lives in the jax-free ``compile.planner`` (re-exported here), so
the parity guard runs without jax; ``sort()`` folds the plan over the
input.

Variants (paper Table 1 columns):

* ``basic``      — §3.3: one launch per compare-exchange step.
* ``semi``       — §4.1 (optimization 1): in-VMEM fused stages.
* ``optimized``  — §4.1 + §4.2 (optimizations 1 and 2): fused stages plus
                   register-paired double steps for the global stage.

The compute graph is deliberately *unrolled* (a Python loop over launches,
not ``lax.fori_loop``): every step has different static strides/shapes, and
unrolling lets XLA see and fuse the whole network. See EXPERIMENTS.md §Perf
for the measured effect.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernels import bitonic as kb
from .planner import (  # noqa: F401  (re-exported public surface)
    DEFAULT_BLOCK,
    VARIANTS,
    BlockFused,
    GlobalDoubleStep,
    GlobalStep,
    Launch,
    launch_counts,
    merge_plan,
    plan,
)


# ----------------------------------------------------------------------
# Sort
# ----------------------------------------------------------------------


def sort(x, variant: str = "optimized", *, block: int = DEFAULT_BLOCK,
         descending: bool = False, grid_cells: int = kb.DEFAULT_GRID_CELLS):
    """Sort each row of ``(B, N)`` ascending (or descending).

    N must be a power of two; the rust coordinator pads requests with
    ``MAX_KEY`` before dispatch, so the compiled artifact only ever sees
    power-of-two rows.
    """
    b, n = x.shape
    del b
    flip_phase = n if descending else 0
    for launch in plan(n, variant, block):
        if isinstance(launch, GlobalStep):
            x = kb.step(x, launch.phase_len, launch.stride,
                        flip=descending and launch.phase_len == n,
                        grid_cells=grid_cells)
        elif isinstance(launch, GlobalDoubleStep):
            x = kb.double_step(x, launch.phase_len, launch.stride_hi,
                               flip=descending and launch.phase_len == n,
                               grid_cells=grid_cells)
        else:
            x = kb.fused_block(x, launch.stride_max * 2, launch.phase_lo,
                               launch.phase_hi, paired=launch.paired,
                               flip_phase=flip_phase,
                               grid_cells=grid_cells)
    return x


def make_sort_fn(variant: str, *, block: int = DEFAULT_BLOCK,
                 descending: bool = False,
                 grid_cells: int = kb.DEFAULT_GRID_CELLS):
    """A jit-able ``x -> (sorted,)`` closure for AOT export.

    Returns a 1-tuple because the HLO interchange uses ``return_tuple=True``
    (the rust side unwraps with ``to_tuple1``).
    """

    def fn(x):
        return (sort(x, variant, block=block, descending=descending,
                     grid_cells=grid_cells),)

    fn.__name__ = f"bitonic_sort_{variant}"
    return fn


# ----------------------------------------------------------------------
# Bitonic merge (the paper §3's core primitive, exported standalone)
# ----------------------------------------------------------------------


def merge_sorted_halves(x, variant: str = "optimized", *,
                        block: int = DEFAULT_BLOCK, descending: bool = False,
                        grid_cells: int = kb.DEFAULT_GRID_CELLS):
    """Merge rows whose two halves are each sorted ascending.

    Reverses the second half (making each row bitonic by construction —
    the paper §3.1's definition) and runs the final-phase merge. This is
    the primitive behind the rust `sort::hybrid` out-of-core sorter:
    device-sorted chunks are merged pairwise in log-depth instead of
    re-sorting, at log2(n) steps per level instead of k(k+1)/2.
    """
    b, n = x.shape
    half = n // 2
    x = jnp.concatenate([x[:, :half], x[:, half:][:, ::-1]], axis=1)
    flip_phase = n if descending else 0
    for launch in merge_plan(n, variant, block):
        if isinstance(launch, GlobalStep):
            x = kb.step(x, launch.phase_len, launch.stride, flip=descending,
                        grid_cells=grid_cells)
        elif isinstance(launch, GlobalDoubleStep):
            x = kb.double_step(x, launch.phase_len, launch.stride_hi,
                               flip=descending, grid_cells=grid_cells)
        else:
            x = kb.fused_block(x, launch.stride_max * 2, launch.phase_lo,
                               launch.phase_hi, paired=launch.paired,
                               flip_phase=flip_phase, grid_cells=grid_cells)
    return x


def make_merge_fn(variant: str, *, block: int = DEFAULT_BLOCK,
                  descending: bool = False,
                  grid_cells: int = kb.DEFAULT_GRID_CELLS):
    """Jit-able ``x -> (merged,)`` closure for AOT export (1-tuple, like
    make_sort_fn)."""

    def fn(x):
        return (merge_sorted_halves(x, variant, block=block,
                                    descending=descending,
                                    grid_cells=grid_cells),)

    fn.__name__ = f"bitonic_merge_{variant}"
    return fn


@functools.lru_cache(maxsize=None)
def jitted(variant: str, batch: int, n: int, dtype: str = "uint32", *,
           block: int = DEFAULT_BLOCK, descending: bool = False):
    """Compiled sort for a concrete (variant, batch, n, dtype) — used by
    the python test-suite; the rust runtime uses the AOT artifacts instead."""
    fn = make_sort_fn(variant, block=block, descending=descending)
    spec = jax.ShapeDtypeStruct((batch, n), jnp.dtype(dtype))
    return jax.jit(fn).lower(spec).compile()
