"""Native artifact synthesis — the jax-free mirror of
``rust/src/runtime/genart.rs`` (``bitonic-tpu gen-artifacts``).

The AOT pipeline in :mod:`compile.aot` needs JAX + XLA to lower real HLO,
which tops the checked-in fixture out at n=64K. The rust executor only
ever consumes the small HLO *text* subset below, so this module renders
that exact format directly — byte-compatible with the fixture files —
for any (op, batch, n, dtype, order) grid. It needs nothing beyond the
standard library and is the oracle the rust implementation is tested
against (``python/tests/test_genart.py`` asserts the rendered text
equals the checked-in fixture bytes).

Usage::

    python -m compile.genart --out-dir ../rust/artifacts/generated [--smoke]
"""

from __future__ import annotations

import argparse
import os
from dataclasses import dataclass

MANIFEST_HEADER = "name\tkind\tvariant\tbatch\tn\tdtype\tdescending\tblock\tgrid_cells\tfile"

#: Block-size hint recorded in generated manifest rows (same value the
#: fixture rows carry; the plan policy decides real execution geometry).
GEN_BLOCK = 256

#: manifest dtype name -> HLO shape token
DTYPE_TOKENS = {"uint32": "u32", "int32": "s32", "float32": "f32"}

_HLO_TEMPLATE = """HloModule jit_{name}, entry_computation_layout={{({tok}[{b},{n}]{{1,0}})->(({tok}[{b},{n}]{{1,0}}))}}

%compare.1 (lhs.2: {tok}[], rhs.3: {tok}[]) -> pred[] {{
  %lhs.2 = {tok}[] parameter(0)
  %rhs.3 = {tok}[] parameter(1)
  ROOT %compare.4 = pred[] compare({tok}[] %lhs.2, {tok}[] %rhs.3), direction={direction}
}}

ENTRY %main.8 (Arg_0.1: {tok}[{b},{n}]) -> ({tok}[{b},{n}]) {{
  %Arg_0.1 = {tok}[{b},{n}]{{1,0}} parameter(0)
  %sort.5 = {tok}[{b},{n}]{{1,0}} sort({tok}[{b},{n}]{{1,0}} %Arg_0.1), dimensions={{1}}, to_apply=%compare.1
  ROOT %tuple.7 = ({tok}[{b},{n}]{{1,0}}) tuple({tok}[{b},{n}]{{1,0}} %sort.5)
}}
"""


@dataclass(frozen=True)
class GenSpec:
    """One artifact class to synthesize (mirror of rust ``GenSpec``)."""

    kind: str  # "sort" | "merge"
    variant: str  # "basic" | "semi" | "optimized"
    batch: int
    n: int
    dtype: str  # "uint32" | "int32" | "float32"
    descending: bool

    @staticmethod
    def sort(n: int, batch: int = 1, dtype: str = "uint32",
             descending: bool = False) -> "GenSpec":
        return GenSpec("sort", "optimized", batch, n, dtype, descending)

    @staticmethod
    def merge(n: int, batch: int = 1) -> "GenSpec":
        return GenSpec("merge", "optimized", batch, n, "uint32", False)

    @property
    def name(self) -> str:
        order = "desc" if self.descending else "asc"
        return f"{self.kind}_{self.variant}_b{self.batch}_n{self.n}_{self.dtype}_{order}"

    @property
    def file(self) -> str:
        return f"{self.name}.hlo.txt"

    def validate(self) -> None:
        if self.n < 2 or self.n & (self.n - 1):
            raise ValueError(f"gen-artifacts: n={self.n} is not a power of two >= 2")
        if self.batch < 1:
            raise ValueError("gen-artifacts: batch must be >= 1")
        if self.dtype not in DTYPE_TOKENS:
            raise ValueError(f"gen-artifacts: unknown dtype {self.dtype!r}")

    @property
    def block(self) -> int:
        return min(GEN_BLOCK, self.n)

    @property
    def grid_cells(self) -> int:
        return max(self.n // self.block, 1)

    def hlo_text(self) -> str:
        return _HLO_TEMPLATE.format(
            name=self.name,
            tok=DTYPE_TOKENS[self.dtype],
            b=self.batch,
            n=self.n,
            direction="GT" if self.descending else "LT",
        )

    def manifest_row(self) -> str:
        return "\t".join(
            str(x)
            for x in (
                self.name, self.kind, self.variant, self.batch, self.n,
                self.dtype, int(self.descending), self.block,
                self.grid_cells, self.file,
            )
        )


def default_grid() -> list[GenSpec]:
    """The full offline grid (mirror of rust ``default_grid``)."""
    specs = [GenSpec.sort(1 << k) for k in range(17, 25)]
    specs += [
        GenSpec.sort(1 << 20, descending=True),
        GenSpec.sort(1 << 20, dtype="int32"),
        GenSpec.sort(1 << 20, dtype="float32"),
        GenSpec.sort(1 << 16, batch=4),
        GenSpec.sort(1 << 17, batch=2),
    ]
    specs += [GenSpec.merge(1 << k) for k in range(18, 22)]
    return specs


def smoke_grid() -> list[GenSpec]:
    """CI-sized grid (mirror of rust ``smoke_grid``)."""
    return [
        GenSpec.sort(1 << 18),
        GenSpec.sort(1 << 18, descending=True),
        GenSpec.sort(1 << 18, dtype="int32"),
        GenSpec.sort(1 << 18, dtype="float32"),
        GenSpec.sort(1 << 20),  # the n >= 1M acceptance class
        GenSpec.merge(1 << 19),
    ]


def generate(out_dir: str, specs: list[GenSpec]) -> dict:
    """Write HLO texts + a manifest referencing exactly those files.

    Returns a report dict mirroring rust ``GenReport``:
    ``{"dir", "written", "rows", "max_sort_n"}``.
    """
    if not specs:
        raise ValueError("gen-artifacts: empty grid")
    os.makedirs(out_dir, exist_ok=True)
    seen: set[str] = set()
    rows = [MANIFEST_HEADER]
    written = 0
    max_sort_n = 0
    for spec in specs:
        spec.validate()
        if spec.name in seen:
            continue
        seen.add(spec.name)
        with open(os.path.join(out_dir, spec.file), "w") as f:
            f.write(spec.hlo_text())
        written += 1
        if spec.kind == "sort":
            max_sort_n = max(max_sort_n, spec.n)
        rows.append(spec.manifest_row())
    with open(os.path.join(out_dir, "manifest.tsv"), "w") as f:
        f.write("\n".join(rows) + "\n")
    return {"dir": out_dir, "written": written, "rows": len(rows) - 1,
            "max_sort_n": max_sort_n}


def main(argv=None) -> None:
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("--out-dir", default="../rust/artifacts/generated")
    p.add_argument("--smoke", action="store_true",
                   help="CI-sized grid instead of the full 16M ladder")
    args = p.parse_args(argv)
    report = generate(args.out_dir, smoke_grid() if args.smoke else default_grid())
    print(
        f"wrote {report['written']} HLO artifact(s) / {report['rows']} manifest "
        f"row(s) to {report['dir']} — menu now reaches n={report['max_sort_n']}"
    )


if __name__ == "__main__":
    main()
