"""L1 performance model: VMEM footprint and HBM-pass analysis.

``interpret=True`` wallclock is CPU-numpy time, *not* a TPU proxy, so the
kernel is optimized structurally: minimize passes over HBM, keep every
fused tile inside the VMEM budget, keep lane dimensions multiples of the
(8, 128) vreg tile. This module computes those quantities for a given
configuration; DESIGN.md §Perf and EXPERIMENTS.md §Perf cite its output.

Bitonic sort is min/max + select over integers — VPU work, no MXU use, so
the roofline is the HBM bandwidth line: a variant's TPU time estimate is

    T ≈ passes(variant) · 2 · bytes(row) · rows / BW_hbm + launches · t_dispatch

which is the same two-term model the GPU simulator uses (rust/src/sim),
with TPU constants.
"""

from __future__ import annotations

from dataclasses import dataclass

from . import model

#: TPU-v4-ish constants used for the structural estimate (per core).
VMEM_BYTES = 16 * 2 ** 20
HBM_GBPS = 1200.0
DISPATCH_US = 3.0
VREG_LANES = 128
VREG_SUBLANES = 8


@dataclass(frozen=True)
class KernelEstimate:
    """Structural cost estimate for one (variant, n, batch, dtype, block)."""

    variant: str
    n: int
    batch: int
    dtype_bytes: int
    block: int
    launches: int
    hbm_passes: int
    vmem_peak_bytes: int
    est_tpu_ms: float

    @property
    def vmem_ok(self) -> bool:
        return self.vmem_peak_bytes <= VMEM_BYTES

    @property
    def lane_aligned(self) -> bool:
        # The innermost lane dim of every kernel is >= one vreg row when
        # the smallest fused reshape still has >= 128 contiguous lanes.
        return self.block >= VREG_LANES


def estimate(variant: str, n: int, batch: int = 8, dtype_bytes: int = 4,
             block: int = 1 << 13) -> KernelEstimate:
    """Estimate TPU cost for one configuration (see module docstring)."""
    launches = list(model.plan(n, variant, block))
    num = len(launches)
    # Every launch streams the full (batch, n) array HBM->VMEM->HBM once.
    bytes_per_pass = 2 * batch * n * dtype_bytes
    # Peak VMEM: the widest tile any launch holds resident. Global steps
    # hold (batch, groups*2*j) = one grid cell's block; fused stages hold
    # (batch, width). Both are `batch * tile_width * dtype_bytes` with
    # tile_width <= 2*block for double-steps, block*tiles_per_cell for
    # fused; we size one tile per cell here (grid == tiles).
    tile_width = 2 * block
    vmem_peak = batch * tile_width * dtype_bytes * 2  # in + out copies
    time_s = (num * bytes_per_pass / (HBM_GBPS * 1e9)
              + num * DISPATCH_US * 1e-6)
    return KernelEstimate(variant, n, batch, dtype_bytes, block, num, num,
                          vmem_peak, time_s * 1e3)


def report(n: int = 1 << 24, batch: int = 8, block: int = 1 << 13) -> str:
    """Side-by-side structural comparison of the three variants."""
    lines = [
        f"n={n} batch={batch} block={block} (u32 keys)",
        f"{'variant':<10} {'launches':>8} {'hbm passes':>10} "
        f"{'vmem peak':>10} {'est ms':>8} {'vs basic':>8}",
    ]
    base = None
    for v in model.VARIANTS:
        e = estimate(v, n, batch, 4, block)
        base = base or e.est_tpu_ms
        lines.append(
            f"{v:<10} {e.launches:>8} {e.hbm_passes:>10} "
            f"{e.vmem_peak_bytes / 2**20:>9.2f}M {e.est_tpu_ms:>8.2f} "
            f"{base / e.est_tpu_ms:>7.2f}x"
        )
    return "\n".join(lines)


if __name__ == "__main__":
    for n in (1 << 18, 1 << 21, 1 << 24, 1 << 28):
        print(report(n))
        print()
