"""Cross-language mirror of the rust static plan verifier — **jax-free**.

``rust/src/analysis`` proves, before anything executes, that (1) every
launch program sorts (0-1 principle: brute force for tiny n, a per-phase
induction up to the exhaustive cap, seeded sampling above it), and
(2) the chunked parallel schedule and the interleaved tile dispatch are
write-disjoint. This module is a line-for-line port of those proof
engines — same bit-vector encoding (bit ``i`` = value at index ``i``),
same structured sampling family, same PCG32 streams and seeds — so
``tests/test_static_check.py`` can re-derive the rust suite's pinned
verdicts (which mutants are refuted, which schedules race) in a second
implementation. A disagreement between the two is a bug in one of them;
like the launch-planner parity guard, this runs on CI's numpy+pytest
floor with no jax.

The port adds one thing the rust side states but cannot cheaply show:
:func:`simulate_intervals` *executes* the barrier-interval write
semantics on concrete integer rows, grounding the symbolic write sets
the disjointness checker marks in an actual sort.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import lru_cache

FULL_ENUM_MAX_N = 16  # rust: network_check::FULL_ENUM_MAX_N
DEFAULT_EXHAUSTIVE_CAP = 1024  # rust: analysis::DEFAULT_EXHAUSTIVE_CAP
DEFAULT_SAMPLES = 96  # rust: VerifyOptions::default().samples

MASK64 = (1 << 64) - 1
MASK32 = (1 << 32) - 1


class Pcg32:
    """PCG32 (XSH-RR) — exact port of ``rust/src/workload/rng.rs``."""

    MULT = 6364136223846793005

    def __init__(self, seed: int, stream: int) -> None:
        self.state = 0
        self.inc = ((stream << 1) | 1) & MASK64
        self.next_u32()
        self.state = (self.state + seed) & MASK64
        self.next_u32()

    def next_u32(self) -> int:
        old = self.state
        self.state = (old * self.MULT + self.inc) & MASK64
        xorshifted = (((old >> 18) ^ old) >> 27) & MASK32
        rot = old >> 59
        return ((xorshifted >> rot) | (xorshifted << (32 - rot) & MASK32)) & MASK32

    def next_u64(self) -> int:
        hi = self.next_u32()
        return (hi << 32) | self.next_u32()

    def next_below(self, bound: int) -> int:
        """Lemire 32-bit multiply-shift rejection (unbiased)."""
        assert bound > 0
        while True:
            x = self.next_u32()
            m = x * bound
            lo = m & MASK32
            if lo >= bound or lo >= (-bound) % (1 << 32) % bound:
                return m >> 32


# ----------------------------------------------------------------------
# Canonical schedules (rust: sort/network.rs).
# ----------------------------------------------------------------------


def step_schedule(n: int) -> list[tuple[int, int]]:
    """``Network::step_schedule`` as ``(phase_len, stride)`` tuples."""
    out = []
    k = 2
    while k <= n:
        j = k // 2
        while j >= 1:
            out.append((k, j))
            j //= 2
        k *= 2
    return out


def merge_steps(n: int) -> list[tuple[int, int]]:
    """``Phase { len: n }.steps()`` — the final phase only."""
    out = []
    j = n // 2
    while j >= 1:
        out.append((n, j))
        j //= 2
    return out


# ----------------------------------------------------------------------
# 0-1 vectors as python ints: bit i = value at index i. The rust side
# uses u64 word arrays; a python int *is* that array, so the word-
# parallel kernels port to whole-vector mask arithmetic.
# ----------------------------------------------------------------------


@lru_cache(maxsize=None)
def stride_mask(nbits: int, j: int) -> int:
    """Bits ``b`` in ``[0, nbits)`` with ``b & j == 0`` (power-of-two j)."""
    m = (1 << j) - 1
    span = 2 * j
    while span < nbits:
        m |= m << span
        span *= 2
    return m & ((1 << nbits) - 1)


def ones_block(nbits: int, lo: int, hi: int) -> int:
    return ((1 << (hi - lo)) - 1) << lo if hi > lo else 0


def sorted_vec(nbits: int, ones: int, ascending: bool) -> int:
    if ascending:
        return ones_block(nbits, nbits - ones, nbits)
    return ones_block(nbits, 0, ones)


def first_diff(a: int, b: int) -> int | None:
    x = a ^ b
    if x == 0:
        return None
    return (x & -x).bit_length() - 1


def zo_step_uniform(v: int, nbits: int, j: int, ascending: bool) -> int:
    """One step with a uniform direction (the phase lemma's view)."""
    mj = stride_mask(nbits, j)
    a = v & mj
    b = (v >> j) & mj
    mn, mx = a & b, a | b
    return (mn | (mx << j)) if ascending else (mx | (mn << j))


def zo_step(v: int, nbits: int, k: int, j: int) -> int:
    """One canonical step: pair ``(i, i^j)`` ascending iff ``i & k == 0``.

    Mask-parallel fast path for power-of-two geometry, per-pair generic
    fallback for anything else (mutants) — mirroring rust ``zo_step`` /
    ``zo_step_generic``.
    """
    pow2 = lambda x: x > 0 and (x & (x - 1)) == 0
    if not (pow2(j) and pow2(k) and j < k and j < nbits):
        return zo_step_generic(v, nbits, k, j)
    mj = stride_mask(nbits, j)
    a = v & mj
    b = (v >> j) & mj
    mn, mx = a & b, a | b
    if k >= nbits:
        return mn | (mx << j)  # i & k == 0 everywhere: all ascending
    mk = stride_mask(nbits, k)
    amask, dmask = mj & mk, mj & ~mk
    low = (mn & amask) | (mx & dmask)
    high = (mx & amask) | (mn & dmask)
    return low | (high << j)


def zo_step_generic(v: int, nbits: int, k: int, j: int) -> int:
    """Per-pair reference, valid for arbitrary ``(k, j)`` incl. mutants."""
    if j == 0:
        return v
    for i in range(nbits):
        p = i ^ j
        if p > i and p < nbits:
            a = (v >> i) & 1
            b = (v >> p) & 1
            if a != b:
                ascending = (i & k) == 0
                if ascending == bool(a):  # out of order: swap the pair
                    v ^= (1 << i) | (1 << p)
    return v


def sim_steps(v: int, nbits: int, steps: list[tuple[int, int]]) -> int:
    for k, j in steps:
        v = zo_step(v, nbits, k, j)
    return v


# ----------------------------------------------------------------------
# Proof engines (rust: analysis/network_check.rs).
# ----------------------------------------------------------------------


def brute_force_sort(n: int, steps: list[tuple[int, int]]) -> int:
    """All ``2^n`` 0-1 inputs at once, transposed: ``pos[e]`` is a bitset
    over candidate inputs holding input ``t``'s value at index ``e``.
    Returns the vector count; raises ``AssertionError``-free ``ValueError``
    with the counterexample on refutation (rust returns ``Err``)."""
    assert 1 <= n <= FULL_ENUM_MAX_N
    vectors = 1 << n
    full = (1 << vectors) - 1
    # Input t's vector is the binary encoding of t itself.
    pos = [full ^ stride_mask(vectors, 1 << e) for e in range(n)]
    for k, j in steps:
        if j == 0:
            continue
        for i in range(n):
            p = i ^ j
            if p > i and p < n:
                a, b = pos[i], pos[p]
                mn, mx = a & b, a | b
                if (i & k) == 0:
                    pos[i], pos[p] = mn, mx
                else:
                    pos[i], pos[p] = mx, mn
    for e in range(n - 1):
        viol = pos[e] & ~pos[e + 1] & full
        if viol:
            t = (viol & -viol).bit_length() - 1
            bits = "".join("1" if (t >> e2) & 1 else "0" for e2 in range(n))
            raise ValueError(
                f"0-1 input [{bits}] (lsb-first) leaves index {e} > index {e + 1}"
            )
    return vectors


def phase_lemma(k: int) -> int:
    """The per-phase induction lemma: every ``asc-half ++ desc-half`` 0-1
    state entering phase ``k`` must leave its strides fully sorted, both
    directions. Returns the state count; raises ``ValueError`` on a
    violation."""
    assert k >= 2 and (k & (k - 1)) == 0
    h = k // 2
    vectors = 0
    for ascending in (True, False):
        for x in range(h + 1):
            for y in range(h + 1):
                # First half 0^(h-x) 1^x; second half 1^y 0^(h-y).
                v = ones_block(k, h - x, h) | ones_block(k, h, h + y)
                j = h
                while j >= 1:
                    v = zo_step_uniform(v, k, j, ascending)
                    j //= 2
                if v != sorted_vec(k, x + y, ascending):
                    d = "asc" if ascending else "desc"
                    raise ValueError(
                        f"phase k={k} lemma violated ({d} block, x={x}, y={y})"
                    )
                vectors += 1
    return vectors


def sampled_sort(
    n: int, steps: list[tuple[int, int]], samples: int = DEFAULT_SAMPLES
) -> tuple[int, str | None]:
    """Structured + seeded-random sampling — the exact family (and PCG32
    stream) the rust fallback path simulates, so a mutant refuted here is
    refuted there and vice versa."""
    boundaries: list[int] = []
    t = 1
    while t <= n:
        for p in (max(t - 1, 0), t, t + 1):
            if p < n:
                boundaries.append(p)
        t *= 2
    boundaries = sorted(set(boundaries))

    family: list[tuple[int, str]] = [(0, "all-zeros"), (ones_block(n, 0, n), "all-ones")]
    for p in boundaries:
        family.append((1 << p, f"single-one@{p}"))
        family.append((ones_block(n, 0, n) ^ (1 << p), f"single-zero@{p}"))
        family.append((ones_block(n, 0, p), f"prefix-ones@{p}"))
    rng = Pcg32(0x0501C4EC, n)
    words = (n + 63) // 64
    for s in range(samples):
        v = 0
        for w in range(words):
            v |= rng.next_u64() << (64 * w)
        v &= (1 << n) - 1
        family.append((v, f"random#{s}"))

    tried = 0
    for v, label in family:
        tried += 1
        ones = bin(v).count("1")
        out = sim_steps(v, n, steps)
        bad = first_diff(out, sorted_vec(n, ones, True))
        if bad is not None:
            return tried, f"sampled 0-1 vector ({label}, {ones} ones) unsorted at index {bad}"
    return tried, None


def merge_enum(
    n: int,
    steps: list[tuple[int, int]],
    reverse_tail: bool,
    samples: int = DEFAULT_SAMPLES,
    full_grid: bool | None = None,
) -> tuple[int, bool, str | None]:
    """Enumerate/sample a merge's valid inputs: both halves asc-sorted,
    the plan's ``reverse_tail`` wiring applied (or not), then the steps."""
    h = n // 2
    if full_grid is None:
        full_grid = (h + 1) ** 2 <= 4096
    grid: list[tuple[int, int]] = []
    if full_grid:
        grid = [(x, y) for x in range(h + 1) for y in range(h + 1)]
    else:
        spread = sorted({v for v in (0, 1, 2, h // 2, max(h - 2, 0), max(h - 1, 0), h) if v <= h})
        grid = [(x, y) for x in spread for y in spread]
        rng = Pcg32(0x3E26E001, n)
        for _ in range(samples):
            x = rng.next_below(h + 1)
            y = rng.next_below(h + 1)
            grid.append((x, y))
    tried = 0
    for x, y in grid:
        tried += 1
        v = ones_block(n, h - x, h)
        v |= ones_block(n, h, h + y) if reverse_tail else ones_block(n, n - y, n)
        out = sim_steps(v, n, steps)
        bad = first_diff(out, sorted_vec(n, x + y, True))
        if bad is not None:
            return tried, full_grid, (
                f"merge input (asc half {x} ones, asc tail {y} ones) unsorted at index {bad}"
            )
    return tried, full_grid, None


def check_sort_steps(
    n: int,
    steps: list[tuple[int, int]],
    exhaustive_cap: int = DEFAULT_EXHAUSTIVE_CAP,
    samples: int = DEFAULT_SAMPLES,
) -> tuple[str, str]:
    """Port of rust ``check_sort_steps``: returns ``(status, detail)``
    with status in {"proven", "not-proven", "refuted"}."""
    if n <= FULL_ENUM_MAX_N:
        try:
            brute_force_sort(n, steps)
        except ValueError as e:
            return "refuted", str(e)
        return "proven", "brute-force enumeration"
    if steps == step_schedule(n):
        if n <= exhaustive_cap:
            k = 2
            try:
                while k <= n:
                    phase_lemma(k)
                    k *= 2
            except ValueError as e:
                return "refuted", str(e)
            return "proven", "per-phase 0-1 induction"
        _, cex = sampled_sort(n, steps, samples)
        if cex:
            return "refuted", cex
        return "not-proven", f"n={n} exceeds exhaustive cap {exhaustive_cap}"
    _, cex = sampled_sort(n, steps, samples)
    if cex:
        return "refuted", cex
    return "not-proven", "schedule deviates from the canonical step order"


def check_merge_steps(
    n: int,
    steps: list[tuple[int, int]],
    reverse_tail: bool,
    exhaustive_cap: int = DEFAULT_EXHAUSTIVE_CAP,
    samples: int = DEFAULT_SAMPLES,
) -> tuple[str, str]:
    """Port of rust ``check_merge_steps``."""
    canonical = steps == merge_steps(n)
    if canonical and reverse_tail and n <= exhaustive_cap:
        try:
            phase_lemma(n)
        except ValueError as e:
            return "refuted", str(e)
        return "proven", "phase-n 0-1 lemma"
    _, exhaustive, cex = merge_enum(n, steps, reverse_tail, samples)
    if cex:
        return "refuted", cex
    if exhaustive:
        return "proven", "exhaustive merge-input grid"
    return "not-proven", "sampled merge-input grid"


# ----------------------------------------------------------------------
# Disjointness (rust: sort/bitonic_parallel.rs + analysis/disjoint.rs).
# IntervalOp is a tuple: ("local", k, stride_hi) | ("paired", k,
# stride_hi) | ("lows", k, stride).
# ----------------------------------------------------------------------


def barrier_intervals(n: int, chunk: int) -> list[tuple[str, int, int]]:
    """Port of ``barrier_intervals``: assign each canonical step to a
    local-tail / paired-global / single-global interval by the same
    ``j`` vs ``chunk`` comparisons."""
    assert chunk >= 2 and chunk <= n and (n & (n - 1)) == 0 and (chunk & (chunk - 1)) == 0
    steps = step_schedule(n)
    out = []
    i = 0
    while i < len(steps):
        k, j = steps[i]
        if j < chunk:
            out.append(("local", k, j))
            i += j.bit_length()  # trailing_zeros(j) + 1 for power-of-two j
        elif j // 2 >= chunk:
            out.append(("paired", k, j))
            i += 2
        else:
            out.append(("lows", k, j))
            i += 1
    return out


def interval_steps(op: tuple[str, int, int]) -> list[tuple[int, int]]:
    tag, k, j = op
    if tag == "local":
        # Phase-k steps with stride <= j: exactly j, j/2, ..., 1.
        return [(k, s) for s in _strides_down(j)]
    if tag == "paired":
        return [(k, j), (k, j // 2)]
    return [(k, j)]


def _strides_down(j_hi: int) -> list[int]:
    out = []
    j = j_hi
    while j >= 1:
        out.append(j)
        j //= 2
    return out


def effective_workers(n: int, threads: int) -> int:
    """Port of ``effective_workers``: clamp to n/2, serial below the
    cutover, round down to a power of two."""
    if n < 2:
        return 1
    threads = max(1, min(threads, n // 2))
    if threads == 1 or n < 4096:
        return 1
    if threads & (threads - 1) == 0:
        return threads
    return 1 << (threads.bit_length() - 1)


def check_intervals(
    n: int, workers: int, intervals: list[list[tuple[str, int, int]]]
) -> dict:
    """Port of ``disjoint::check_intervals``: generation-stamped single-
    ownership per barrier interval + coverage. Raises ``ValueError`` with
    the rust-identical message on the first violation."""
    if n < 4 or (n & (n - 1)) != 0:
        raise ValueError(f"row length {n} is not a power of two >= 4")
    if workers < 2 or (workers & (workers - 1)) != 0 or n // workers < 2:
        raise ValueError(f"worker count {workers} invalid for n={n}")
    chunk = n // workers
    owner_gen = [0] * n
    owner = [0] * n
    stats = {"intervals": 0, "writes": 0, "quads": 0}
    for iv, ops in enumerate(intervals):
        stats["intervals"] += 1
        gen = stats["intervals"]

        def mark(i: int, t: int) -> None:
            if owner_gen[i] == gen and owner[i] != t:
                raise ValueError(
                    f"interval #{iv}: index {i} written by workers {owner[i]} and {t}"
                )
            owner_gen[i] = gen
            owner[i] = t

        for tag, k, j in ops:
            for t in range(workers):
                lo, hi = t * chunk, (t + 1) * chunk
                if tag == "local":
                    if j >= chunk:
                        raise ValueError(
                            f"interval #{iv}: local tail stride {j} escapes chunk {chunk}"
                        )
                    for a in range(lo, hi):
                        mark(a, t)
                        stats["writes"] += 1
                elif tag == "lows":
                    if j == 0 or (j & (j - 1)) != 0:
                        raise ValueError(
                            f"interval #{iv}: global stride {j} is not a power of two"
                        )
                    for a in range(lo, hi):
                        if a & j == 0:
                            p = a ^ j
                            if p >= n:
                                raise ValueError(
                                    f"interval #{iv}: pair ({a}, {p}) escapes the row"
                                )
                            mark(a, t)
                            mark(p, t)
                            stats["writes"] += 2
                elif tag == "paired":
                    if j < 2 or (j & (j - 1)) != 0:
                        raise ValueError(
                            f"interval #{iv}: paired stride {j} is not a power of two >= 2"
                        )
                    j_lo = j // 2
                    quad_bits = j | j_lo
                    for a in range(lo, hi):
                        if a & quad_bits == 0:
                            d = a + j + j_lo
                            if d >= n:
                                raise ValueError(
                                    f"interval #{iv}: quad at {a} escapes the row (max index {d})"
                                )
                            if d & k != a & k:
                                raise ValueError(
                                    f"interval #{iv}: quad at {a} spans a direction boundary (phase {k})"
                                )
                            for i in (a, a + j_lo, a + j, d):
                                mark(i, t)
                            stats["writes"] += 4
                            stats["quads"] += 1
                else:
                    raise ValueError(f"unknown interval op {tag!r}")
        for i in range(n):
            if owner_gen[i] != gen:
                raise ValueError(f"interval #{iv}: index {i} written by no worker")
    return stats


def check_parallel_schedule(n: int, workers: int) -> dict:
    """Port of ``check_parallel_schedule``: the canonical interval list
    must expand to ``step_schedule`` and partition the index space."""
    if n < 4 or (n & (n - 1)) != 0:
        raise ValueError(f"row length {n} is not a power of two >= 4")
    chunk = n // workers
    if workers < 2 or (workers & (workers - 1)) != 0 or chunk < 2:
        raise ValueError(f"worker count {workers} invalid for n={n}")
    intervals = barrier_intervals(n, chunk)
    flat = [s for op in intervals for s in interval_steps(op)]
    if flat != step_schedule(n):
        raise ValueError("interval expansion deviates from step_schedule()")
    return check_intervals(n, workers, [[op] for op in intervals])


def simulate_intervals(
    xs: list[int], workers: int, intervals: list[tuple[str, int, int]]
) -> list[int]:
    """Concretely *execute* the barrier-interval write semantics the
    disjointness checker marks symbolically — each op writes exactly the
    indices ``check_intervals`` stamps, so a correct sort here grounds
    the emulation. Not a port; a semantic cross-check."""
    n = len(xs)
    xs = list(xs)
    chunk = n // workers

    def cex(i: int, p: int, k: int) -> None:
        asc = (i & k) == 0
        if (xs[i] > xs[p]) == asc:
            xs[i], xs[p] = xs[p], xs[i]

    for tag, k, j in intervals:
        for t in range(workers):
            lo, hi = t * chunk, (t + 1) * chunk
            if tag == "local":
                s = j
                while s >= 1:
                    for a in range(lo, hi):
                        if a & s == 0:
                            cex(a, a | s, k)
                    s //= 2
            elif tag == "lows":
                for a in range(lo, hi):
                    if a & j == 0:
                        cex(a, a ^ j, k)
            elif tag == "paired":
                j_lo = j // 2
                for a in range(lo, hi):
                    if a & (j | j_lo) == 0:
                        cex(a, a + j, k)
                        cex(a + j_lo, a + j + j_lo, k)
                        cex(a, a + j_lo, k)
                        cex(a + j, a + j + j_lo, k)
    return xs


# ----------------------------------------------------------------------
# Tile dispatch (rust: runtime/executor.rs + analysis/disjoint.rs).
# ----------------------------------------------------------------------


@dataclass
class DispatchGeometry:
    r: int
    tile_len: int
    pooled: bool
    job_len: int


def effective_interleave(want: int, b: int, threads: int) -> int:
    cap = b // threads if threads > 1 else b
    return min(max(want, 1), max(cap, 1), max(b, 1))


def dispatch_geometry(want: int, n: int, b: int, threads: int) -> DispatchGeometry:
    r = effective_interleave(want, b, threads)
    n = max(n, 1)
    tile_len = r * n
    pooled = threads > 1 and b > r and n >= 64
    if pooled:
        tiles = -(-b // r)
        jobs = min(threads * 2, tiles)
        job_len = -(-tiles // jobs) * tile_len
    else:
        job_len = max(b * n, tile_len)
    return DispatchGeometry(r, tile_len, pooled, job_len)


def check_tile_dispatch(b: int, n: int, want: int, threads: int) -> dict:
    """Port of ``disjoint::check_tile_dispatch``: replay the job/tile
    partition and verify row alignment, exact coverage, tile width and
    pool feeding. Raises ``ValueError`` on the first violation."""
    geo = dispatch_geometry(want, n, b, threads)
    n = max(n, 1)
    if geo.r < 1 or geo.r > max(b, 1):
        raise ValueError(f"effective interleave {geo.r} outside [1, {b}]")
    if geo.tile_len != geo.r * n:
        raise ValueError(f"tile_len {geo.tile_len} != r*n = {geo.r * n}")
    # Interior job boundaries must be row-aligned; the pooled partition
    # additionally hands whole tiles to each job (the unpooled path is a
    # single job spanning the buffer).
    if geo.job_len == 0 or geo.job_len % n != 0:
        raise ValueError(
            f"job_len {geo.job_len} is not a positive multiple of the row length {n}"
        )
    if geo.pooled and geo.job_len % geo.tile_len != 0:
        raise ValueError(
            f"pooled job_len {geo.job_len} is not a multiple of tile_len {geo.tile_len}"
        )
    total = b * n
    stats = {"jobs": 0, "tiles": 0, "r": geo.r, "pooled": geo.pooled}
    covered = 0
    start = 0
    while start < total:
        end = min(start + geo.job_len, total)
        stats["jobs"] += 1
        if start % n != 0:
            raise ValueError(f"job boundary {start} splits a row (n={n})")
        ts = start
        while ts < end:
            te = min(ts + geo.tile_len, end)
            stats["tiles"] += 1
            length = te - ts
            if length % n != 0:
                raise ValueError(f"tile [{ts}, {te}) splits a row (n={n})")
            rows = length // n
            if rows == 0 or rows > geo.r:
                raise ValueError(f"tile [{ts}, {te}) holds {rows} rows, want 1..={geo.r}")
            covered += length
            ts = te
        start = end
    if covered != total:
        raise ValueError(f"tiles cover {covered} of {total} elements")
    if geo.pooled and stats["tiles"] < min(threads, b):
        raise ValueError(f"pooled dispatch yields {stats['tiles']} tiles for {threads} workers")
    return stats
