"""AOT export: lower the L2 sort functions to HLO *text* artifacts.

Run once at build time (``make artifacts``); the rust runtime loads the
text with ``HloModuleProto::from_text_file`` and compiles it on the PJRT
CPU client. Python never runs on the request path.

HLO **text** — not ``lowered.compile()`` serialisation, not
``proto.SerializeToString()`` — is the interchange format: jax >= 0.5
emits HloModuleProtos with 64-bit instruction ids which the crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Artifacts are described by ``artifacts/manifest.tsv`` with columns::

    name  variant  batch  n  dtype  descending  block  grid_cells  file

The rust ``runtime::Registry`` is driven entirely by this manifest.

Usage::

    python -m compile.aot --out-dir ../artifacts [--quick]
"""

from __future__ import annotations

import argparse
import os
import sys
import time

try:
    import jax
    import jax.numpy as jnp
    from jax._src.lib import xla_client as xc

    _JAX_IMPORT_ERROR = None
except ImportError as _e:  # offline environment without JAX
    jax = jnp = xc = model = None
    _JAX_IMPORT_ERROR = _e
else:
    # Imported outside the guard so a genuine bug in compile.model (or
    # its dependencies) surfaces as itself, not as "JAX is missing".
    from . import model


def _require_jax() -> None:
    """Exit with a clear one-line message (not a traceback) without JAX."""
    if jax is None:
        sys.exit(
            "error: compile.aot needs JAX (+ a working XLA client) to lower "
            "artifacts; it is not installed in this environment. Install jax "
            "or use the pre-exported artifacts/ fixture consumed by the rust "
            f"runtime. (import error: {_JAX_IMPORT_ERROR})"
        )

# The artifact matrix. Kept moderate: lowering one full sort takes a few
# seconds of trace time, and the rust side compiles each artifact once at
# startup. Sizes beyond 2^16 work fine but bloat `make artifacts`; the
# table-1 bench extrapolates from the simulator for the paper's huge sizes.
SIZES = (1 << 10, 1 << 12, 1 << 14, 1 << 16)
BATCHES = (1, 8)
DTYPES = ("uint32",)
QUICK_SIZES = (1 << 10,)

# Extra artifacts for the paper's §6 future-work experiment (E8): other key
# types at one representative size, plus a descending variant used by the
# coordinator tests.
EXTRA = (
    ("optimized", 8, 1 << 12, "int32", False),
    ("optimized", 8, 1 << 12, "float32", False),
    ("optimized", 8, 1 << 12, "uint32", True),
)

# Standalone bitonic-merge artifacts (paper §3's primitive): input rows of
# length n whose two halves are each sorted; log2(n) steps. Used by the
# rust out-of-core hybrid sorter (sort::hybrid) to merge device-sorted
# chunks in log depth. (n, batch) pairs; variant fixed to optimized.
MERGES = (
    (1 << 11, 4),
    (1 << 12, 2),
    (1 << 13, 2),
    (1 << 17, 1),
)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def artifact_name(variant: str, batch: int, n: int, dtype: str,
                  descending: bool, kind: str = "sort") -> str:
    d = "desc" if descending else "asc"
    return f"{kind}_{variant}_b{batch}_n{n}_{dtype}_{d}"


def export_one(out_dir: str, variant: str, batch: int, n: int, dtype: str,
               descending: bool, *, block: int | None = None,
               grid_cells: int = 4, kind: str = "sort") -> dict:
    """Lower one configuration and write its .hlo.txt. Returns the
    manifest row as a dict."""
    _require_jax()
    if block is None:
        block = model.DEFAULT_BLOCK
    name = artifact_name(variant, batch, n, dtype, descending, kind)
    maker = model.make_sort_fn if kind == "sort" else model.make_merge_fn
    fn = maker(variant, block=block, descending=descending,
               grid_cells=grid_cells)
    spec = jax.ShapeDtypeStruct((batch, n), jnp.dtype(dtype))
    t0 = time.time()
    lowered = jax.jit(fn).lower(spec)
    text = to_hlo_text(lowered)
    path = os.path.join(out_dir, name + ".hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    print(f"  {name}: {len(text) / 1e6:.2f} MB in {time.time() - t0:.1f}s",
          flush=True)
    return {
        "name": name,
        "kind": kind,
        "variant": variant,
        "batch": batch,
        "n": n,
        "dtype": dtype,
        "descending": int(descending),
        "block": min(block, n),
        "grid_cells": grid_cells,
        "file": name + ".hlo.txt",
    }


MANIFEST_COLUMNS = ("name", "kind", "variant", "batch", "n", "dtype",
                    "descending", "block", "grid_cells", "file")


def write_manifest(out_dir: str, rows: list[dict]) -> None:
    path = os.path.join(out_dir, "manifest.tsv")
    with open(path, "w") as f:
        f.write("\t".join(MANIFEST_COLUMNS) + "\n")
        for row in rows:
            f.write("\t".join(str(row[c]) for c in MANIFEST_COLUMNS) + "\n")
    print(f"wrote {path} ({len(rows)} artifacts)", flush=True)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--quick", action="store_true",
                    help="only the smallest size (CI smoke)")
    ap.add_argument("--grid-cells", type=int, default=4,
                    help="interpret-mode grid split per pallas_call")
    args = ap.parse_args(argv)

    _require_jax()
    os.makedirs(args.out_dir, exist_ok=True)
    sizes = QUICK_SIZES if args.quick else SIZES
    rows = []
    for variant in model.VARIANTS:
        for batch in BATCHES:
            for n in sizes:
                for dtype in DTYPES:
                    rows.append(export_one(args.out_dir, variant, batch, n,
                                           dtype, False,
                                           grid_cells=args.grid_cells))
    if not args.quick:
        for variant, batch, n, dtype, desc in EXTRA:
            rows.append(export_one(args.out_dir, variant, batch, n, dtype,
                                   desc, grid_cells=args.grid_cells))
        for n, batch in MERGES:
            rows.append(export_one(args.out_dir, "optimized", batch, n,
                                   "uint32", False,
                                   grid_cells=args.grid_cells, kind="merge"))
    write_manifest(args.out_dir, rows)


if __name__ == "__main__":
    sys.exit(main())
