"""Pure-jnp correctness oracle for the Pallas bitonic kernels.

Two independent references:

* :func:`ref_step` / :func:`ref_sort` — the textbook ``i ^ j`` bitonic
  network written with plain ``jnp`` ops (no Pallas). Every kernel variant
  must match it step-for-step, which localises a failure to a single
  (phase, stride) pair.
* ``jnp.sort`` — the end-to-end oracle; also what the hypothesis sweeps in
  ``python/tests`` compare against.
"""

from __future__ import annotations

import jax.numpy as jnp


def ref_step(x, k: int, j: int, *, flip: bool = False):
    """One compare-exchange step of the bitonic network on ``(B, N)`` rows.

    Pairs are ``(i, i ^ j)``; element ``i`` ascends iff ``i & k == 0``
    (xor ``flip``). Matches ``kernels.bitonic.step`` bit-for-bit.
    """
    b, n = x.shape
    xr = x.reshape(b, n // (2 * j), 2, j)
    lo = xr[:, :, 0, :]
    hi = xr[:, :, 1, :]
    base = jnp.arange(n // (2 * j)) * (2 * j)
    up = (((base & k) == 0) ^ flip)[None, :, None]
    mn = jnp.minimum(lo, hi)
    mx = jnp.maximum(lo, hi)
    out = jnp.stack([jnp.where(up, mn, mx), jnp.where(up, mx, mn)], axis=2)
    return out.reshape(b, n)


def ref_sort(x, *, descending: bool = False):
    """Full bitonic sort of each row of ``(B, N)``, N a power of two."""
    b, n = x.shape
    del b
    if n & (n - 1):
        raise ValueError(f"row length must be a power of two, got {n}")
    k = 2
    while k <= n:
        flip = descending and k == n
        j = k // 2
        while j >= 1:
            x = ref_step(x, k, j, flip=flip)
            j //= 2
        k *= 2
    return x
