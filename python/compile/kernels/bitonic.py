"""Layer-1 Pallas kernels: the bitonic compare-exchange hot-spot.

Three kernel families mirror the paper's three GPU implementations
(DESIGN.md §Hardware-Adaptation maps each CUDA concept to its TPU/Pallas
equivalent):

``step`` (paper §3.3, "Basic")
    One ``pallas_call`` per compare-exchange step — the analog of one CUDA
    kernel launch per step with host synchronisation between launches.
    Every call is a full read+write pass over the array.

``fused_block`` (paper §4.1, optimization 1, "Semi")
    Once the stride fits inside a VMEM tile (the TPU analog of CUDA shared
    memory), a single ``pallas_call`` executes *all* remaining steps of the
    phase — or, for the presort, all early phases — against the tile,
    replacing per-step launches and global-memory round-trips.

``double_step`` / register pairing (paper §4.2, optimization 2, "Optimized")
    Two consecutive global strides are fused into one pass: each lane keeps
    the 4 partner elements ``{i, i^j/2, i^j, i^(j|j/2)}`` live (the CUDA
    version keeps them in registers) and applies both compare-exchanges
    before writing back, halving the number of passes over HBM. Inside the
    fused block kernel the same pairing halves VMEM round-trips.

All kernels are *batched*: arrays have shape ``(B, N)`` and each row is
sorted independently — this is what the rust coordinator's dynamic batcher
exploits to pack concurrent requests into one device execution.

Everything here must be lowered with ``interpret=True``: the CPU PJRT
client used by the rust runtime cannot execute Mosaic custom-calls (see
/opt/xla-example/README.md). ``grid_cells`` trades interpret-mode loop
overhead against per-call working-set size; on a real TPU it would instead
be fixed by the VMEM budget (see ``analysis.py``).

Direction convention (standard ``i ^ j`` bitonic network): element ``i``
belongs to an ascending region iff ``i & k == 0`` where ``k`` is the phase
length. ``flip_phase`` statically flips the direction of one phase (the
last), which turns the final ascending merge into a descending one.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default number of grid cells a step kernel is split into. Interpret mode
# executes grid cells as iterations of an XLA while-loop, so this is the
# main interpret-overhead knob; on real hardware the equivalent knob is
# "how many elements fit in VMEM" (see analysis.py).
# §Perf L2 iteration 2: 16 → 4 measured 1.6–2.3× faster end-to-end at
# n=2^16 with identical outputs (EXPERIMENTS.md §Perf).
DEFAULT_GRID_CELLS = 4


def _check_pow2(name: str, v: int) -> None:
    if v < 1 or v & (v - 1):
        raise ValueError(f"{name} must be a positive power of two, got {v}")


def _groups_per_cell(num_groups: int, grid_cells: int) -> int:
    """Split `num_groups` pair-groups into at most `grid_cells` cells."""
    return max(1, num_groups // max(1, grid_cells))


# ----------------------------------------------------------------------
# Basic: one pallas_call per step (paper §3.3)
# ----------------------------------------------------------------------


def _step_body(x_ref, o_ref, *, k: int, two_j: int, groups: int, flip: bool):
    """Compare-exchange `groups` pair-groups of stride j = two_j/2.

    Block layout: ``(B, groups, 2, j)`` where axis 2 separates the low and
    high partners of each pair-group. The direction of group ``g`` is
    derived from its global base index ``g * two_j`` exactly as the CUDA
    kernel derives it from the thread id.
    """
    cell = pl.program_id(0)
    base = (cell * groups + jnp.arange(groups)) * two_j
    up = ((base & k) == 0) ^ flip  # (groups,)
    up = up[None, :, None]
    lo = x_ref[:, :, 0, :]
    hi = x_ref[:, :, 1, :]
    mn = jnp.minimum(lo, hi)
    mx = jnp.maximum(lo, hi)
    o_ref[:, :, 0, :] = jnp.where(up, mn, mx)
    o_ref[:, :, 1, :] = jnp.where(up, mx, mn)


def step(x, k: int, j: int, *, flip: bool = False,
         grid_cells: int = DEFAULT_GRID_CELLS):
    """One global compare-exchange step with stride ``j``, phase ``k``.

    The "Basic" building block: every invocation is one launch and one full
    pass over the ``(B, N)`` array.
    """
    b, n = x.shape
    _check_pow2("n", n)
    _check_pow2("j", j)
    _check_pow2("k", k)
    if not (1 <= j < n) or j * 2 > k:
        raise ValueError(f"invalid step: n={n} k={k} j={j}")
    num_groups = n // (2 * j)
    groups = _groups_per_cell(num_groups, grid_cells)
    xr = x.reshape(b, num_groups, 2, j)
    fn = pl.pallas_call(
        functools.partial(_step_body, k=k, two_j=2 * j, groups=groups,
                          flip=flip),
        grid=(num_groups // groups,),
        in_specs=[pl.BlockSpec((b, groups, 2, j), lambda g: (0, g, 0, 0))],
        out_specs=pl.BlockSpec((b, groups, 2, j), lambda g: (0, g, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(xr.shape, xr.dtype),
        interpret=True,
    )
    return fn(xr).reshape(b, n)


# ----------------------------------------------------------------------
# Optimization 2: two global steps in one pass (paper §4.2)
# ----------------------------------------------------------------------


def _double_step_body(x_ref, o_ref, *, k: int, four_j: int, groups: int,
                      flip: bool):
    """Strides ``2j`` then ``j`` fused; the four partners of each lane are
    live at once (the register quartet of the paper's optimization 2).

    Block layout ``(B, groups, 2, 2, j)``: axis 2 = stride-2j partner
    selector, axis 3 = stride-j partner selector. Because ``4j <= k``
    divides the group base, the direction is uniform within a group.
    """
    cell = pl.program_id(0)
    base = (cell * groups + jnp.arange(groups)) * four_j
    up = ((base & k) == 0) ^ flip  # (groups,)
    up4 = up[None, :, None]

    def cx(lo, hi):
        mn = jnp.minimum(lo, hi)
        mx = jnp.maximum(lo, hi)
        return jnp.where(up4, mn, mx), jnp.where(up4, mx, mn)

    # First compare over the 2j-stride axis (axis 2); shapes (B, groups, j).
    lo0 = x_ref[:, :, 0, 0, :]
    lo1 = x_ref[:, :, 0, 1, :]
    hi0 = x_ref[:, :, 1, 0, :]
    hi1 = x_ref[:, :, 1, 1, :]
    n00, n10 = cx(lo0, hi0)  # stride-2j compare of sub-lane 0
    n01, n11 = cx(lo1, hi1)  # stride-2j compare of sub-lane 1
    # …then over the j-stride axis within each half.
    m00, m01 = cx(n00, n01)
    m10, m11 = cx(n10, n11)
    o_ref[:, :, 0, 0, :] = m00
    o_ref[:, :, 0, 1, :] = m01
    o_ref[:, :, 1, 0, :] = m10
    o_ref[:, :, 1, 1, :] = m11


def double_step(x, k: int, j_hi: int, *, flip: bool = False,
                grid_cells: int = DEFAULT_GRID_CELLS):
    """Fused strides ``j_hi`` and ``j_hi // 2`` in a single pass."""
    b, n = x.shape
    _check_pow2("n", n)
    _check_pow2("j_hi", j_hi)
    j = j_hi // 2
    if j < 1 or j_hi * 2 > k:
        raise ValueError(f"invalid double step: n={n} k={k} j_hi={j_hi}")
    num_groups = n // (4 * j)
    groups = _groups_per_cell(num_groups, grid_cells)
    xr = x.reshape(b, num_groups, 2, 2, j)
    fn = pl.pallas_call(
        functools.partial(_double_step_body, k=k, four_j=4 * j,
                          groups=groups, flip=flip),
        grid=(num_groups // groups,),
        in_specs=[
            pl.BlockSpec((b, groups, 2, 2, j), lambda g: (0, g, 0, 0, 0))
        ],
        out_specs=pl.BlockSpec((b, groups, 2, 2, j),
                               lambda g: (0, g, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(xr.shape, xr.dtype),
        interpret=True,
    )
    return fn(xr).reshape(b, n)


# ----------------------------------------------------------------------
# Optimization 1: fused in-block (VMEM) stage (paper §4.1)
# ----------------------------------------------------------------------


def _fused_body(x_ref, o_ref, *, width: int, phase_lo: int, phase_hi: int,
                jmax: int, paired: bool, flip_phase: int):
    """Run all steps with stride <= jmax of phases [phase_lo..phase_hi]
    against a VMEM-resident tile of `width` contiguous keys per row.

    The static Python loops unroll at trace time — the analog of the CUDA
    kernel's unrolled shared-memory loop with `__syncthreads()` between
    iterations (here: SSA data dependencies).
    """
    cell = pl.program_id(0)
    b = x_ref.shape[0]
    off = cell * width
    y = x_ref[:, 0, :]

    def cx_pass(y, k, j):
        rows = y.reshape(b, width // (2 * j), 2, j)
        lo = rows[:, :, 0, :]
        hi = rows[:, :, 1, :]
        base = off + jnp.arange(width // (2 * j)) * (2 * j)
        up = (((base & k) == 0) ^ (k == flip_phase))[None, :, None]
        mn = jnp.minimum(lo, hi)
        mx = jnp.maximum(lo, hi)
        z = jnp.stack([jnp.where(up, mn, mx), jnp.where(up, mx, mn)], axis=2)
        return z.reshape(b, width)

    def cx_pass2(y, k, j_hi):
        # Register-paired double step inside the tile (optimization 2
        # applied to the shared-memory stage).
        j = j_hi // 2
        rows = y.reshape(b, width // (4 * j), 2, 2, j)
        base = off + jnp.arange(width // (4 * j)) * (4 * j)
        up = (((base & k) == 0) ^ (k == flip_phase))[None, :, None]

        def cx(lo, hi):
            mn = jnp.minimum(lo, hi)
            mx = jnp.maximum(lo, hi)
            return jnp.where(up, mn, mx), jnp.where(up, mx, mn)

        n00, n10 = cx(rows[:, :, 0, 0, :], rows[:, :, 1, 0, :])
        n01, n11 = cx(rows[:, :, 0, 1, :], rows[:, :, 1, 1, :])
        m00, m01 = cx(n00, n01)
        m10, m11 = cx(n10, n11)
        z = jnp.stack(
            [jnp.stack([m00, m01], axis=2), jnp.stack([m10, m11], axis=2)],
            axis=2,
        )
        return z.reshape(b, width)

    k = phase_lo
    while k <= phase_hi:
        j = min(k // 2, jmax)
        if paired:
            while j >= 2:
                y = cx_pass2(y, k, j)
                j //= 4
            if j == 1:
                y = cx_pass(y, k, 1)
        else:
            while j >= 1:
                y = cx_pass(y, k, j)
                j //= 2
        k *= 2
    o_ref[:, 0, :] = y


def fused_block(x, block: int, phase_lo: int, phase_hi: int, *,
                paired: bool = False, flip_phase: int = 0,
                grid_cells: int = DEFAULT_GRID_CELLS):
    """Fused in-tile stage (optimization 1; ``paired=True`` adds opt 2).

    Runs, for each phase ``k`` in ``[phase_lo .. phase_hi]`` (powers of
    two), every step with stride ``<= block // 2`` out of a VMEM tile.
    ``phase_lo == 2`` with ``phase_hi == block`` is the presort that fully
    sorts each tile; ``phase_lo == phase_hi == k`` is the in-tile tail of a
    later phase.
    """
    b, n = x.shape
    _check_pow2("n", n)
    _check_pow2("block", block)
    if block > n:
        raise ValueError(f"block {block} larger than row {n}")
    # A grid cell may cover several contiguous tiles; strides stay within
    # tiles, directions are derived from global indices, so fusing tiles
    # into one cell is semantics-preserving.
    tiles_per_cell = _groups_per_cell(n // block, grid_cells)
    width = tiles_per_cell * block
    xr = x.reshape(b, n // width, width)
    fn = pl.pallas_call(
        functools.partial(_fused_body, width=width, phase_lo=phase_lo,
                          phase_hi=phase_hi, jmax=block // 2, paired=paired,
                          flip_phase=flip_phase),
        grid=(n // width,),
        in_specs=[pl.BlockSpec((b, 1, width), lambda g: (0, g, 0))],
        out_specs=pl.BlockSpec((b, 1, width), lambda g: (0, g, 0)),
        out_shape=jax.ShapeDtypeStruct(xr.shape, xr.dtype),
        interpret=True,
    )
    return fn(xr).reshape(b, n)
