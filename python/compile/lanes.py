"""Lane-model mirror of ``rust/src/sort/simd.rs`` — jax-free.

The Rust side makes the batch-interleaved lane model *literal*: explicit
SIMD kernels sweep an element-major tile (``xs[e * lanes + l]`` is
element ``e`` of row ``l``) with pointwise min/max lanes, mapping f32
keys through an order-preserving bit trick so NaN/±inf/±0 behave exactly
like the scalar total-order comparator. This module mirrors those
semantics in numpy so the pytest suite can pin them without a Rust
toolchain (and without jax): same layout, same direction rule (global
element index ``& k``), same f32 bit mapping, same chunked sweep
decomposition, same fused double-step operation order.

Everything here is an oracle, not a fast path.
"""

from __future__ import annotations

import numpy as np

# Mirror of ``simd::CHUNK`` — the portable kernels' sweep width. The
# decomposition is observationally identity (pointwise compare-exchange
# commutes with chunking); it is mirrored anyway so this suite pins the
# loop structure the Rust portable kernels actually run.
CHUNK = 8


def f32_ord_key(x):
    """Order-preserving ``int32`` view of f32 bit patterns.

    ``m(b) = b ^ (0x7FFF_FFFF if sign bit else 0)``, compared as signed —
    the AVX2 kernel's ``xor(v, srli(srai(v, 31), 1))``. Monotone with
    respect to IEEE total order (-NaN < -inf < ... < -0.0 < +0.0 < ... <
    +inf < NaN) and involutive on bits (the sign bit is untouched).
    """
    b = np.asarray(x, dtype=np.float32).view(np.uint32)
    neg = (b & np.uint32(0x8000_0000)) != 0
    mask = np.where(neg, 0x7FFF_FFFF, 0).astype(np.uint32)
    return (b ^ mask).view(np.int32)


def order_key(x):
    """Comparison key under the crate's total order: identity for the
    integer dtypes, the order-preserving bit map for f32."""
    x = np.asarray(x)
    if x.dtype == np.float32:
        return f32_ord_key(x)
    return x


def interleave(rows):
    """``(lanes, n)`` row-major rows → element-major 1-D tile
    (``tile[e * lanes + l] == rows[l, e]``)."""
    return np.ascontiguousarray(np.asarray(rows).T).reshape(-1)


def deinterleave(tile, lanes):
    """Inverse of :func:`interleave`: 1-D tile → ``(lanes, n)`` rows."""
    return np.ascontiguousarray(tile.reshape(-1, lanes).T)


def _sweep(lows, highs, *, descending):
    """Pointwise compare-exchange of two equal-length blocks, in
    CHUNK-sized pieces plus a tail. Swaps whole bit patterns (never
    arithmetic min/max on floats), exactly like the Rust kernels."""
    for s in range(0, lows.shape[0], CHUNK):
        a = lows[s : s + CHUNK].copy()
        b = highs[s : s + CHUNK].copy()
        ka, kb = order_key(a), order_key(b)
        swap = (ka < kb) if descending else (kb < ka)
        lows[s : s + CHUNK] = np.where(swap, b, a)
        highs[s : s + CHUNK] = np.where(swap, a, b)


def step_interleaved(xs, k, j, lanes, lo=0, hi=None, *, flip=False):
    """One compare-exchange step (stride ``j``, direction bit ``k``) over
    an element-major interleaved tile: within each ``2j``-aligned run the
    low partners are one contiguous block of ``j * lanes`` keys and the
    high partners the next, so the step is a single pointwise sweep —
    the layout fact the explicit SIMD kernels are built on."""
    n = xs.shape[0] // lanes
    if hi is None:
        hi = n
    i = lo
    while i < hi:
        lows = xs[i * lanes : (i + j) * lanes]
        highs = xs[(i + j) * lanes : (i + 2 * j) * lanes]
        _sweep(lows, highs, descending=((i & k) != 0) ^ flip)
        i += 2 * j


def double_step_interleaved(xs, k, j_hi, lanes, lo=0, hi=None, *, flip=False):
    """The fused stride pair ``(j_hi, j_hi // 2)`` in one pass: each
    ``2 * j_hi``-aligned run is four adjacent blocks A B C D of
    ``j_lo * lanes`` keys, swept (A,C), (B,D) then (A,B), (C,D) — the
    register-paired Rust kernel's operation order."""
    n = xs.shape[0] // lanes
    if hi is None:
        hi = n
    j_lo = j_hi // 2
    blk = j_lo * lanes
    i = lo
    while i < hi:
        desc = ((i & k) != 0) ^ flip
        base = i * lanes
        a = xs[base : base + blk]
        b = xs[base + blk : base + 2 * blk]
        c = xs[base + 2 * blk : base + 3 * blk]
        d = xs[base + 3 * blk : base + 4 * blk]
        _sweep(a, c, descending=desc)
        _sweep(b, d, descending=desc)
        _sweep(a, b, descending=desc)
        _sweep(c, d, descending=desc)
        i += 2 * j_hi


def sort_interleaved(xs, lanes, *, descending=False, paired=False):
    """Full bitonic sort of every lane of an element-major tile, in
    place. ``paired=True`` walks the double-step schedule (strides two
    at a time plus the stride-1 leftover), mirroring the fused plans;
    both walks must be bit-identical at every lane width."""
    n = xs.shape[0] // lanes
    k = 2
    while k <= n:
        flip = descending and k == n
        j = k // 2
        if paired:
            while j >= 2:
                double_step_interleaved(xs, k, j, lanes, flip=flip)
                j //= 4
            if j == 1:
                step_interleaved(xs, k, 1, lanes, flip=flip)
        else:
            while j >= 1:
                step_interleaved(xs, k, j, lanes, flip=flip)
                j //= 2
        k *= 2
    return xs
