"""1:1 python mirror of the rust wire codec (``coordinator::net::wire``).

Byte-for-byte: every frame type, every offset, every validation rule and
its error *kind* tag match the rust implementation — the golden byte
vectors in ``python/tests/test_net.py`` and ``rust/tests/net_props.rs``
pin the two against each other. This file is also the reference for
writing clients in other languages.

Frame layout (all integers little-endian)::

    u32 length prefix        (length of the body that follows)
    body:
      0..4   magic  b"BTSP"
      4      version (1)
      5      op      1=Sort 2=Sorted 3=Error 4=Ping 5=Pong 6=Shutdown

    Sort   : dtype@6 (0=u32)  order@7 (0/1)  id@8 u64  slo_us@16 u32
             n@20 u32  keys@24 (4n bytes)
    Sorted : path@6 (0=dev,1=cpu)  rsvd@7 (=0)  id@8 u64  latency_us@16
             occupancy@20  n@24  keys@28
    Error  : code@6 (1..5)  rsvd@7 (=0)  id@8 u64  message@16 (UTF-8)
    Ping/Pong/Shutdown : token@6 u64
"""

from __future__ import annotations

import struct
from dataclasses import dataclass, field
from typing import List, Tuple, Union

MAGIC = b"BTSP"
VERSION = 1
DEFAULT_MAX_KEYS = 1 << 20
MAX_ERROR_MSG = 1024

OP_SORT = 1
OP_SORTED = 2
OP_ERROR = 3
OP_PING = 4
OP_PONG = 5
OP_SHUTDOWN = 6

_HDR = 6
_SORT_FIXED = 24
_SORTED_FIXED = 28
_ERROR_FIXED = 16
_TOKEN_BODY = 14

# Error-frame codes (mirror of rust ``ErrorCode``).
CODE_MALFORMED = 1
CODE_UNSUPPORTED = 2
CODE_OVERSIZE = 3
CODE_SHED = 4
CODE_INTERNAL = 5

CODE_NAMES = {
    CODE_MALFORMED: "malformed",
    CODE_UNSUPPORTED: "unsupported",
    CODE_OVERSIZE: "oversize",
    CODE_SHED: "shed",
    CODE_INTERNAL: "internal",
}


def frame_cap(max_keys: int) -> int:
    """Largest legal body length for a given key cap (rust ``frame_cap``)."""
    return max(_SORTED_FIXED + 4 * max_keys, _ERROR_FIXED + MAX_ERROR_MSG)


class NetProtocolError(ValueError):
    """Decode failure; ``kind`` matches rust ``WireError::kind()`` verbatim."""

    def __init__(self, kind: str, detail: str = ""):
        super().__init__(f"{kind}: {detail}" if detail else kind)
        self.kind = kind

    @property
    def code(self) -> int:
        """The error-frame code a server answers this defect with."""
        if self.kind == "oversize":
            return CODE_OVERSIZE
        if self.kind in ("bad-version", "bad-op", "bad-dtype"):
            return CODE_UNSUPPORTED
        return CODE_MALFORMED


@dataclass
class Sort:
    id: int
    descending: bool = False
    slo_us: int = 0
    keys: List[int] = field(default_factory=list)


@dataclass
class Sorted:
    id: int
    cpu_path: bool = False
    latency_us: int = 0
    occupancy: int = 0
    keys: List[int] = field(default_factory=list)


@dataclass
class Error:
    code: int
    id: int
    message: str = ""


@dataclass
class Ping:
    token: int


@dataclass
class Pong:
    token: int


@dataclass
class Shutdown:
    token: int


Frame = Union[Sort, Sorted, Error, Ping, Pong, Shutdown]


def _header(op: int) -> bytes:
    return MAGIC + bytes([VERSION, op])


def encode_body(frame: Frame) -> bytes:
    """Mirror of rust ``Frame::encode_body``."""
    if isinstance(frame, Sort):
        return (
            _header(OP_SORT)
            + bytes([0, 1 if frame.descending else 0])
            + struct.pack("<QII", frame.id, frame.slo_us, len(frame.keys))
            + struct.pack(f"<{len(frame.keys)}I", *frame.keys)
        )
    if isinstance(frame, Sorted):
        return (
            _header(OP_SORTED)
            + bytes([1 if frame.cpu_path else 0, 0])
            + struct.pack(
                "<QIII", frame.id, frame.latency_us, frame.occupancy, len(frame.keys)
            )
            + struct.pack(f"<{len(frame.keys)}I", *frame.keys)
        )
    if isinstance(frame, Error):
        # Clamp to the cap on a char boundary, like the rust encoder: the
        # clamped frame must still pass its own strict UTF-8 decode.
        msg = frame.message.encode("utf-8")
        if len(msg) > MAX_ERROR_MSG:
            cut = MAX_ERROR_MSG
            while cut > 0 and (msg[cut] & 0xC0) == 0x80:  # inside a code point
                cut -= 1
            msg = msg[:cut]
        return _header(OP_ERROR) + bytes([frame.code, 0]) + struct.pack("<Q", frame.id) + msg
    if isinstance(frame, Ping):
        return _header(OP_PING) + struct.pack("<Q", frame.token)
    if isinstance(frame, Pong):
        return _header(OP_PONG) + struct.pack("<Q", frame.token)
    if isinstance(frame, Shutdown):
        return _header(OP_SHUTDOWN) + struct.pack("<Q", frame.token)
    raise TypeError(f"not a frame: {frame!r}")


def encode_frame(frame: Frame) -> bytes:
    """Full frame: ``u32`` length prefix + body (rust ``Frame::encode``)."""
    body = encode_body(frame)
    return struct.pack("<I", len(body)) + body


def _check_len(got: int, want: int) -> None:
    if got < want:
        raise NetProtocolError("truncated", f"need {want}, got {got}")
    if got > want:
        raise NetProtocolError("trailing", f"{got - want} trailing byte(s)")


def _keys(b: bytes) -> List[int]:
    return list(struct.unpack(f"<{len(b) // 4}I", b[: len(b) // 4 * 4]))


def decode_body(body: bytes, max_keys: int = DEFAULT_MAX_KEYS) -> Frame:
    """Mirror of rust ``Frame::decode_body`` — strict, same error kinds."""
    if len(body) < _HDR:
        raise NetProtocolError("truncated", f"need {_HDR}, got {len(body)}")
    if body[:4] != MAGIC:
        raise NetProtocolError("bad-magic", body[:4].hex())
    if body[4] != VERSION:
        raise NetProtocolError("bad-version", str(body[4]))
    op = body[5]
    if op == OP_SORT:
        if len(body) < _SORT_FIXED:
            raise NetProtocolError("truncated", f"need {_SORT_FIXED}, got {len(body)}")
        if body[6] != 0:
            raise NetProtocolError("bad-dtype", str(body[6]))
        if body[7] > 1:
            raise NetProtocolError("bad-order", str(body[7]))
        (rid, slo_us, n) = struct.unpack_from("<QII", body, 8)
        if n > max_keys:
            raise NetProtocolError("oversize", f"{n} exceeds cap {max_keys}")
        _check_len(len(body), _SORT_FIXED + 4 * n)
        return Sort(
            id=rid, descending=body[7] == 1, slo_us=slo_us, keys=_keys(body[_SORT_FIXED:])
        )
    if op == OP_SORTED:
        if len(body) < _SORTED_FIXED:
            raise NetProtocolError("truncated", f"need {_SORTED_FIXED}, got {len(body)}")
        if body[6] > 1:
            raise NetProtocolError("bad-path", str(body[6]))
        if body[7] != 0:
            raise NetProtocolError("bad-reserved", str(body[7]))
        (rid, latency_us, occupancy, n) = struct.unpack_from("<QIII", body, 8)
        if n > max_keys:
            raise NetProtocolError("oversize", f"{n} exceeds cap {max_keys}")
        _check_len(len(body), _SORTED_FIXED + 4 * n)
        return Sorted(
            id=rid,
            cpu_path=body[6] == 1,
            latency_us=latency_us,
            occupancy=occupancy,
            keys=_keys(body[_SORTED_FIXED:]),
        )
    if op == OP_ERROR:
        if len(body) < _ERROR_FIXED:
            raise NetProtocolError("truncated", f"need {_ERROR_FIXED}, got {len(body)}")
        if body[6] not in CODE_NAMES:
            raise NetProtocolError("bad-code", str(body[6]))
        if body[7] != 0:
            raise NetProtocolError("bad-reserved", str(body[7]))
        msg = body[_ERROR_FIXED:]
        if len(msg) > MAX_ERROR_MSG:
            raise NetProtocolError("oversize", f"{len(msg)} exceeds cap {MAX_ERROR_MSG}")
        try:
            text = msg.decode("utf-8")
        except UnicodeDecodeError:
            raise NetProtocolError("bad-utf8") from None
        (rid,) = struct.unpack_from("<Q", body, 8)
        return Error(code=body[6], id=rid, message=text)
    if op in (OP_PING, OP_PONG, OP_SHUTDOWN):
        _check_len(len(body), _TOKEN_BODY)
        (token,) = struct.unpack_from("<Q", body, 6)
        return {OP_PING: Ping, OP_PONG: Pong, OP_SHUTDOWN: Shutdown}[op](token)
    raise NetProtocolError("bad-op", str(op))


def decode_frame(data: bytes, max_keys: int = DEFAULT_MAX_KEYS) -> Tuple[Frame, int]:
    """Decode one length-prefixed frame from the start of ``data``.

    Returns ``(frame, bytes_consumed)``. Raises ``NetProtocolError`` with
    kind ``truncated`` when fewer bytes than one whole frame are present,
    and ``oversize`` when the length prefix exceeds ``frame_cap``.
    """
    if len(data) < 4:
        raise NetProtocolError("truncated", f"need 4, got {len(data)}")
    (length,) = struct.unpack_from("<I", data, 0)
    cap = frame_cap(max_keys)
    if length > cap:
        raise NetProtocolError("oversize", f"{length} exceeds cap {cap}")
    if length < _HDR:
        raise NetProtocolError("truncated", f"need {_HDR}, got {length}")
    if len(data) < 4 + length:
        raise NetProtocolError("truncated", f"need {4 + length}, got {len(data)}")
    return decode_body(data[4 : 4 + length], max_keys), 4 + length
