"""L1 kernel correctness: every Pallas kernel vs the pure-jnp oracle.

This is the core correctness signal of the compile path: a failure here
localises to a single (kernel, phase, stride) triple.
"""

import numpy as np
import pytest

jnp = pytest.importorskip("jax.numpy", reason="JAX is not installed (offline env)")

from compile.kernels import bitonic as kb
from compile.kernels import ref

from conftest import random_rows


def all_steps(n):
    """(k, j) pairs of the full network on n keys."""
    k = 2
    while k <= n:
        j = k // 2
        while j >= 1:
            yield k, j
            j //= 2
        k *= 2


class TestStepKernel:
    @pytest.mark.parametrize("n", [2, 8, 64, 512])
    @pytest.mark.parametrize("b", [1, 3])
    def test_matches_ref_on_every_step(self, rng, n, b):
        x = random_rows(rng, b, n, np.uint32)
        for k, j in all_steps(n):
            got = kb.step(jnp.asarray(x), k, j)
            want = ref.ref_step(jnp.asarray(x), k, j)
            np.testing.assert_array_equal(np.asarray(got), np.asarray(want),
                                          err_msg=f"k={k} j={j}")

    def test_flip_inverts_direction(self, rng):
        x = random_rows(rng, 2, 64, np.uint32)
        for k, j in all_steps(64):
            got = kb.step(jnp.asarray(x), k, j, flip=True)
            want = ref.ref_step(jnp.asarray(x), k, j, flip=True)
            np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @pytest.mark.parametrize("grid_cells", [1, 4, 64])
    def test_grid_split_is_semantics_preserving(self, rng, grid_cells):
        x = random_rows(rng, 2, 1024, np.uint32)
        for k, j in [(1024, 512), (256, 32), (8, 4)]:
            got = kb.step(jnp.asarray(x), k, j, grid_cells=grid_cells)
            want = ref.ref_step(jnp.asarray(x), k, j)
            np.testing.assert_array_equal(np.asarray(got), np.asarray(want),
                                          err_msg=f"cells={grid_cells} k={k} j={j}")

    def test_rejects_bad_shapes(self):
        x = jnp.zeros((1, 96), jnp.uint32)  # not a power of two
        with pytest.raises(ValueError):
            kb.step(x, 4, 2)
        x = jnp.zeros((1, 64), jnp.uint32)
        with pytest.raises(ValueError):
            kb.step(x, 4, 4)  # j*2 > k


class TestDoubleStepKernel:
    @pytest.mark.parametrize("n", [8, 128, 1024])
    def test_equals_two_single_steps(self, rng, n):
        x = random_rows(rng, 2, n, np.uint32)
        for k, j in all_steps(n):
            if j < 2 or 2 * j > k:
                continue
            got = kb.double_step(jnp.asarray(x), k, j)
            want = ref.ref_step(ref.ref_step(jnp.asarray(x), k, j), k, j // 2)
            np.testing.assert_array_equal(np.asarray(got), np.asarray(want),
                                          err_msg=f"k={k} j_hi={j}")

    def test_flip(self, rng):
        x = random_rows(rng, 1, 256, np.uint32)
        got = kb.double_step(jnp.asarray(x), 256, 128, flip=True)
        want = ref.ref_step(ref.ref_step(jnp.asarray(x), 256, 128, flip=True),
                            256, 64, flip=True)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_rejects_j1(self):
        with pytest.raises(ValueError):
            kb.double_step(jnp.zeros((1, 8), jnp.uint32), 8, 1)


class TestFusedBlockKernel:
    @pytest.mark.parametrize("block", [4, 16, 64])
    def test_presort_equals_ref_prefix(self, rng, block):
        """Presort = all phases 2..block of the reference network."""
        n, b = 256, 2
        x = random_rows(rng, b, n, np.uint32)
        got = np.asarray(kb.fused_block(jnp.asarray(x), block, 2, block))
        want = jnp.asarray(x)
        k = 2
        while k <= block:
            j = k // 2
            while j >= 1:
                want = ref.ref_step(want, k, j)
                j //= 2
            k *= 2
        np.testing.assert_array_equal(got, np.asarray(want))

    @pytest.mark.parametrize("paired", [False, True])
    def test_phase_tail_equals_ref(self, rng, paired):
        """BlockFused(k, k) = steps j=block/2..1 of phase k."""
        n, block, k = 512, 32, 512
        x = random_rows(rng, 1, n, np.uint32)
        got = np.asarray(kb.fused_block(jnp.asarray(x), block, k, k,
                                        paired=paired))
        want = jnp.asarray(x)
        j = block // 2
        while j >= 1:
            want = ref.ref_step(want, k, j)
            j //= 2
        np.testing.assert_array_equal(got, np.asarray(want),
                                      err_msg=f"paired={paired}")

    def test_paired_presort_equals_unpaired(self, rng):
        x = random_rows(rng, 2, 512, np.uint32)
        a = np.asarray(kb.fused_block(jnp.asarray(x), 64, 2, 64, paired=False))
        b = np.asarray(kb.fused_block(jnp.asarray(x), 64, 2, 64, paired=True))
        np.testing.assert_array_equal(a, b)

    def test_rejects_oversized_block(self):
        with pytest.raises(ValueError):
            kb.fused_block(jnp.zeros((1, 8), jnp.uint32), 16, 2, 16)
