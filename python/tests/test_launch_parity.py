"""Parity guard (python side): ``compile.planner.plan`` / ``merge_plan``
(re-exported by ``compile.model`` for the jax layer) must agree with the
checked-in golden launch-count table that ``rust/tests/launch_parity.rs``
pins ``Network::launches`` / ``merge_launches`` against — so the Pallas
planner, the simulator, and the native executor cannot drift apart
silently. The planner is deliberately jax-free, so this guard runs in
the numpy+pytest-only CI environment too (no skips)."""

import os

import pytest

from compile import planner

GOLDEN = os.path.join(
    os.path.dirname(__file__), "..", "..", "rust", "tests", "data",
    "launch_counts_golden.tsv",
)


def golden_rows():
    with open(GOLDEN) as f:
        lines = [l.rstrip("\n") for l in f if l.strip()]
    assert lines[0] == "kind\tvariant\tn\tblock\tlaunches"
    for line in lines[1:]:
        kind, variant, n, block, launches = line.split("\t")
        yield kind, variant, int(n), int(block), int(launches)


def test_golden_table_is_complete():
    assert sum(1 for _ in golden_rows()) == 48  # 8 shapes x 3 variants x 2 blocks


@pytest.mark.parametrize("kind,variant,n,block,want", list(golden_rows()))
def test_plan_launch_counts_match_golden(kind, variant, n, block, want):
    if kind == "sort":
        got = len(list(planner.plan(n, variant, block)))
    else:
        got = len(list(planner.merge_plan(n, variant, block)))
    assert got == want, (
        f"{kind} {variant} n={n} block={block}: python plans {got} launches, "
        f"golden (and rust) say {want}"
    )


def test_model_reexports_planner():
    """The jax model must serve the exact same planner objects, so the
    parity pinned here covers what ``sort()``/``merge_sorted_halves()``
    actually fold over."""
    try:
        from compile import model
    except ImportError:  # works on every pytest version, unlike
        pytest.skip("jax not installed")  # importorskip(exc_type=...)
    assert model.plan is planner.plan
    assert model.merge_plan is planner.merge_plan
    assert model.DEFAULT_BLOCK == planner.DEFAULT_BLOCK
