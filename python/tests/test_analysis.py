"""Structural perf-model tests (analysis.py) — the L1 optimization
targets the §Perf pass verifies (DESIGN.md §7)."""

import pytest

pytest.importorskip("jax", reason="JAX is not installed (offline env)")

from compile import analysis, model


def test_launch_counts_match_plan():
    for variant in model.VARIANTS:
        e = analysis.estimate(variant, 1 << 20, batch=8, block=1 << 13)
        assert e.launches == len(list(model.plan(1 << 20, variant, 1 << 13)))


def test_variant_ordering():
    for n in (1 << 18, 1 << 24, 1 << 28):
        basic = analysis.estimate("basic", n)
        semi = analysis.estimate("semi", n)
        opt = analysis.estimate("optimized", n)
        assert basic.hbm_passes > semi.hbm_passes > opt.hbm_passes
        assert basic.est_tpu_ms > semi.est_tpu_ms > opt.est_tpu_ms


def test_basic_pass_closed_form():
    # k(k+1)/2 passes for Basic.
    for k in range(10, 26, 4):
        e = analysis.estimate("basic", 1 << k)
        assert e.hbm_passes == k * (k + 1) // 2


def test_optimized_pass_count_near_linear_in_logn():
    # With block 2^13 the optimized schedule should be O(log n) passes for
    # the sizes of interest — far below k(k+1)/2.
    k = 24
    e = analysis.estimate("optimized", 1 << k, block=1 << 13)
    assert e.hbm_passes < 3 * k


def test_vmem_budget_respected_at_default_block():
    for variant in model.VARIANTS:
        e = analysis.estimate(variant, 1 << 24, batch=8, block=1 << 13)
        assert e.vmem_ok, f"{variant}: {e.vmem_peak_bytes} bytes"
        assert e.lane_aligned


def test_vmem_violation_detected():
    e = analysis.estimate("optimized", 1 << 24, batch=64, block=1 << 20)
    assert not e.vmem_ok


def test_report_renders():
    out = analysis.report(1 << 20)
    assert "basic" in out and "optimized" in out
    assert "vs basic" in out
