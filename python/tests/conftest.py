"""Shared pytest fixtures/helpers for the kernel test-suite."""

import os
import sys

import numpy as np
import pytest

# Make `compile` importable when pytest is launched from python/ or repo root.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0xB170)


def random_rows(rng, b, n, dtype):
    """(b, n) random array of the given dtype, full key range."""
    if dtype == np.uint32:
        return rng.integers(0, 2 ** 32, size=(b, n), dtype=np.uint32)
    if dtype == np.int32:
        return rng.integers(-(2 ** 31), 2 ** 31, size=(b, n), dtype=np.int32)
    if dtype == np.float32:
        return (rng.standard_normal(size=(b, n)) * 1e6).astype(np.float32)
    raise ValueError(dtype)
