"""Hypothesis sweeps: shapes × dtypes × data against numpy's sort oracle.

The deadline is disabled because pallas interpret mode pays a trace+compile
cost per fresh shape that dwarfs hypothesis's default budget.
"""

import numpy as np
import pytest

jnp = pytest.importorskip("jax.numpy", reason="JAX is not installed (offline env)")
hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis is not installed (offline env)"
)
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

SLOW = settings(deadline=None, max_examples=12)


def log2_sizes(lo=1, hi=10):
    return st.integers(lo, hi).map(lambda e: 1 << e)


@st.composite
def rows(draw, dtype=np.uint32, max_log2=9):
    b = draw(st.integers(1, 3))
    n = draw(log2_sizes(1, max_log2))
    if dtype == np.uint32:
        elems = st.integers(0, 2 ** 32 - 1)
    elif dtype == np.int32:
        elems = st.integers(-(2 ** 31), 2 ** 31 - 1)
    else:
        # allow_subnormal=False: XLA CPU flushes subnormals to zero inside
        # min/max (FTZ), which would spuriously fail the exact-equality
        # oracle. Finite normal floats only — documented in DESIGN.md §6.
        bound = float(np.finfo(np.float32).max)
        elems = st.floats(-bound, bound, allow_nan=False, width=32,
                          allow_subnormal=False)
    data = draw(
        st.lists(st.lists(elems, min_size=n, max_size=n), min_size=b, max_size=b)
    )
    return np.asarray(data, dtype=dtype)


@SLOW
@given(x=rows())
def test_ref_sort_is_a_sort(x):
    got = np.asarray(ref.ref_sort(jnp.asarray(x)))
    np.testing.assert_array_equal(got, np.sort(x, axis=1))


@SLOW
@given(x=rows(), variant=st.sampled_from(model.VARIANTS),
       block=st.sampled_from([4, 32, 256]))
def test_variants_sort_u32(x, variant, block):
    got = np.asarray(model.sort(jnp.asarray(x), variant,
                                block=min(block, x.shape[1])))
    np.testing.assert_array_equal(got, np.sort(x, axis=1))


@SLOW
@given(x=rows(dtype=np.int32, max_log2=8))
def test_optimized_sorts_i32(x):
    got = np.asarray(model.sort(jnp.asarray(x), "optimized",
                                block=min(32, x.shape[1])))
    np.testing.assert_array_equal(got, np.sort(x, axis=1))


@SLOW
@given(x=rows(dtype=np.float32, max_log2=8))
def test_optimized_sorts_f32(x):
    got = np.asarray(model.sort(jnp.asarray(x), "optimized",
                                block=min(32, x.shape[1])))
    np.testing.assert_array_equal(got, np.sort(x, axis=1))


@SLOW
@given(x=rows(max_log2=8), variant=st.sampled_from(model.VARIANTS))
def test_descending_is_reversed_ascending(x, variant):
    block = min(32, x.shape[1])
    asc = np.asarray(model.sort(jnp.asarray(x), variant, block=block))
    desc = np.asarray(model.sort(jnp.asarray(x), variant, block=block,
                                 descending=True))
    np.testing.assert_array_equal(desc, asc[:, ::-1])


@SLOW
@given(x=rows(max_log2=7))
def test_idempotent(x):
    once = model.sort(jnp.asarray(x), "optimized", block=32)
    twice = model.sort(once, "optimized", block=32)
    np.testing.assert_array_equal(np.asarray(once), np.asarray(twice))


@SLOW
@given(bits=st.integers(0, 2 ** 16 - 1))
def test_zero_one_principle_n16(bits):
    """Knuth's 0-1 principle on the optimized variant at n=16."""
    x = np.asarray([[(bits >> i) & 1 for i in range(16)]], dtype=np.uint32)
    got = np.asarray(model.sort(jnp.asarray(x), "optimized", block=8))
    np.testing.assert_array_equal(got, np.sort(x, axis=1))
