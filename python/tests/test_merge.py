"""Bitonic-merge primitive (paper §3's core; used by rust sort::hybrid)."""

import numpy as np
import pytest

jnp = pytest.importorskip("jax.numpy", reason="JAX is not installed (offline env)")

from compile import model

from conftest import random_rows


def sorted_halves(rng, b, n, dtype=np.uint32):
    a = np.sort(random_rows(rng, b, n // 2, dtype), axis=1)
    c = np.sort(random_rows(rng, b, n // 2, dtype), axis=1)
    return np.concatenate([a, c], axis=1)


class TestMergePlan:
    def test_log_depth(self):
        # The whole point: log2(n) steps for basic, not k(k+1)/2.
        for logn in range(1, 20):
            assert len(list(model.merge_plan(1 << logn, "basic"))) == logn

    def test_fewer_launches_than_full_sort(self):
        n = 1 << 16
        merge = len(list(model.merge_plan(n, "optimized")))
        sort = len(list(model.plan(n, "optimized")))
        assert merge < sort / 3

    def test_rejects_bad_n(self):
        with pytest.raises(ValueError):
            list(model.merge_plan(100, "basic"))


class TestMerge:
    @pytest.mark.parametrize("variant", model.VARIANTS)
    @pytest.mark.parametrize("b,n", [(1, 2), (1, 64), (3, 512), (1, 4096)])
    def test_merges_sorted_halves(self, rng, variant, b, n):
        x = sorted_halves(rng, b, n)
        got = np.asarray(model.merge_sorted_halves(
            jnp.asarray(x), variant, block=min(256, n)))
        np.testing.assert_array_equal(got, np.sort(x, axis=1))

    def test_descending(self, rng):
        x = sorted_halves(rng, 2, 256)
        got = np.asarray(model.merge_sorted_halves(
            jnp.asarray(x), "optimized", block=64, descending=True))
        np.testing.assert_array_equal(got, np.sort(x, axis=1)[:, ::-1])

    def test_unequal_content_halves(self, rng):
        # One half all-small, one all-large (merge-tree worst case for
        # naive split points; trivial for a bitonic merge).
        b, n = 2, 512
        lo = np.sort(random_rows(rng, b, n // 2, np.uint32) % 1000, axis=1)
        hi = np.sort(random_rows(rng, b, n // 2, np.uint32) % 1000 + 10_000,
                     axis=1)
        x = np.concatenate([hi.astype(np.uint32), lo.astype(np.uint32)],
                           axis=1)
        got = np.asarray(model.merge_sorted_halves(jnp.asarray(x),
                                                   "optimized", block=64))
        np.testing.assert_array_equal(got, np.sort(x, axis=1))

    def test_padding_with_max_preserved(self, rng):
        # Hybrid sorter pads the tail chunk with MAX before merging.
        n = 256
        x = sorted_halves(rng, 1, n)
        x[:, n - 32:] = np.uint32(0xFFFFFFFF)  # still sorted halves
        got = np.asarray(model.merge_sorted_halves(jnp.asarray(x),
                                                   "optimized", block=64))
        np.testing.assert_array_equal(got, np.sort(x, axis=1))
        assert (got[:, -32:] == 0xFFFFFFFF).all()

    def test_merge_of_device_sorted_chunks_roundtrip(self, rng):
        # Full hybrid pipeline in miniature: sort two chunks, merge them.
        b, chunk = 1, 128
        raw = random_rows(rng, b, 2 * chunk, np.uint32)
        s1 = model.sort(jnp.asarray(raw[:, :chunk]), "optimized", block=64)
        s2 = model.sort(jnp.asarray(raw[:, chunk:]), "optimized", block=64)
        x = jnp.concatenate([s1, s2], axis=1)
        got = np.asarray(model.merge_sorted_halves(x, "optimized", block=64))
        np.testing.assert_array_equal(got, np.sort(raw, axis=1))
