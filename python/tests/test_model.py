"""L2 model correctness: full sorts per variant vs jnp.sort / numpy."""

import numpy as np
import pytest

jnp = pytest.importorskip("jax.numpy", reason="JAX is not installed (offline env)")

from compile import model
from compile.kernels import ref

from conftest import random_rows


class TestPlan:
    def test_basic_launch_count_closed_form(self):
        # Paper §3.2: k(k+1)/2 rounds = launches for Basic.
        for logn in range(1, 16):
            n = 1 << logn
            launches = list(model.plan(n, "basic"))
            assert len(launches) == logn * (logn + 1) // 2

    def test_ordering_basic_ge_semi_ge_optimized(self):
        for n in [1 << 10, 1 << 14, 1 << 18]:
            counts = {v: len(list(model.plan(n, v))) for v in model.VARIANTS}
            assert counts["basic"] > counts["semi"] >= counts["optimized"]

    def test_plans_cover_every_step_exactly_once(self):
        # Mirror of the rust test: the multiset of (k, j) covered must
        # equal the full network for every variant.
        n, block = 1 << 12, 64
        want = []
        k = 2
        while k <= n:
            j = k // 2
            while j >= 1:
                want.append((k, j))
                j //= 2
            k *= 2
        for variant in model.VARIANTS:
            covered = []
            for l in model.plan(n, variant, block):
                if isinstance(l, model.GlobalStep):
                    covered.append((l.phase_len, l.stride))
                elif isinstance(l, model.GlobalDoubleStep):
                    covered.append((l.phase_len, l.stride_hi))
                    covered.append((l.phase_len, l.stride_hi // 2))
                else:
                    k = l.phase_lo
                    while k <= l.phase_hi:
                        j = min(k // 2, l.stride_max)
                        while j >= 1:
                            covered.append((k, j))
                            j //= 2
                        k *= 2
            assert sorted(covered) == sorted(want), variant

    def test_rejects_bad_input(self):
        with pytest.raises(ValueError):
            list(model.plan(100, "basic"))
        with pytest.raises(ValueError):
            list(model.plan(64, "wat"))


class TestSort:
    @pytest.mark.parametrize("variant", model.VARIANTS)
    @pytest.mark.parametrize("b,n", [(1, 2), (1, 8), (2, 256), (3, 1024)])
    def test_sorts_uniform_u32(self, rng, variant, b, n):
        x = random_rows(rng, b, n, np.uint32)
        got = np.asarray(model.sort(jnp.asarray(x), variant,
                                    block=min(64, n)))
        np.testing.assert_array_equal(got, np.sort(x, axis=1))

    @pytest.mark.parametrize("variant", model.VARIANTS)
    def test_descending(self, rng, variant):
        x = random_rows(rng, 2, 512, np.uint32)
        got = np.asarray(model.sort(jnp.asarray(x), variant, block=64,
                                    descending=True))
        np.testing.assert_array_equal(got, np.sort(x, axis=1)[:, ::-1])

    @pytest.mark.parametrize("dtype", [np.int32, np.float32])
    def test_other_dtypes(self, rng, dtype):
        x = random_rows(rng, 2, 256, dtype)
        got = np.asarray(model.sort(jnp.asarray(x), "optimized", block=64))
        np.testing.assert_array_equal(got, np.sort(x, axis=1))

    def test_rows_sorted_independently(self, rng):
        """Batch independence: sorting (B,N) == sorting each row alone."""
        x = random_rows(rng, 4, 256, np.uint32)
        batched = np.asarray(model.sort(jnp.asarray(x), "optimized", block=64))
        for i in range(4):
            alone = np.asarray(model.sort(jnp.asarray(x[i:i + 1]),
                                          "optimized", block=64))
            np.testing.assert_array_equal(batched[i:i + 1], alone)

    @pytest.mark.parametrize("variant", model.VARIANTS)
    def test_matches_ref_network_exactly(self, rng, variant):
        """Stronger than sortedness: identical to the reference network
        (same comparator set ⇒ identical output for any input)."""
        x = random_rows(rng, 2, 512, np.uint32)
        got = np.asarray(model.sort(jnp.asarray(x), variant, block=32))
        want = np.asarray(ref.ref_sort(jnp.asarray(x)))
        np.testing.assert_array_equal(got, want)

    def test_block_size_invariance(self, rng):
        x = random_rows(rng, 1, 1024, np.uint32)
        outs = [
            np.asarray(model.sort(jnp.asarray(x), "optimized", block=blk))
            for blk in (4, 32, 256, 1024)
        ]
        for o in outs[1:]:
            np.testing.assert_array_equal(outs[0], o)

    def test_duplicate_heavy_input(self, rng):
        x = (rng.integers(0, 4, size=(2, 512)) * 1000).astype(np.uint32)
        got = np.asarray(model.sort(jnp.asarray(x), "semi", block=64))
        np.testing.assert_array_equal(got, np.sort(x, axis=1))

    def test_already_sorted_and_reverse(self):
        x = np.arange(512, dtype=np.uint32)[None, :]
        got = np.asarray(model.sort(jnp.asarray(x), "optimized", block=64))
        np.testing.assert_array_equal(got, x)
        got = np.asarray(model.sort(jnp.asarray(x[:, ::-1]), "optimized",
                                    block=64))
        np.testing.assert_array_equal(got, x)

    def test_padding_semantics(self, rng):
        """MAX-padding then truncation = sorting the prefix (what the rust
        router relies on)."""
        x = random_rows(rng, 1, 100, np.uint32)
        padded = np.full((1, 128), np.uint32(0xFFFFFFFF))
        padded[:, :100] = x
        got = np.asarray(model.sort(jnp.asarray(padded), "optimized",
                                    block=32))
        np.testing.assert_array_equal(got[:, :100], np.sort(x, axis=1))
        assert (got[:, 100:] == 0xFFFFFFFF).all()
