"""AOT pipeline tests: HLO text round-trip and manifest integrity.

The full matrix is exercised by `make artifacts`; here we export one tiny
artifact into a temp dir and re-execute the HLO through XLA to prove the
interchange format is self-contained (exactly what the rust runtime does,
minus the FFI).
"""

import os

import numpy as np
import pytest

jax = pytest.importorskip("jax", reason="JAX is not installed (offline env)")
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import aot, model


def test_artifact_name_stable():
    assert (aot.artifact_name("semi", 8, 1024, "uint32", False)
            == "sort_semi_b8_n1024_uint32_asc")
    assert (aot.artifact_name("optimized", 1, 64, "float32", True)
            == "sort_optimized_b1_n64_float32_desc")


def test_export_one_and_manifest(tmp_path):
    row = aot.export_one(str(tmp_path), "optimized", 2, 64, "uint32", False,
                         grid_cells=4)
    assert row["name"] == "sort_optimized_b2_n64_uint32_asc"
    path = tmp_path / row["file"]
    assert path.exists() and path.stat().st_size > 1000
    text = path.read_text()
    assert text.lstrip().startswith("HloModule")
    aot.write_manifest(str(tmp_path), [row])
    manifest = (tmp_path / "manifest.tsv").read_text().splitlines()
    assert manifest[0].split("\t") == list(aot.MANIFEST_COLUMNS)
    assert manifest[1].split("\t")[0] == row["name"]


def test_hlo_text_parses_back(tmp_path):
    """The emitted HLO text must parse back into an HloModule with the
    right entry computation shape — the contract the rust loader
    (HloModuleProto::from_text_file) relies on. Full re-execution of the
    text is covered by rust/tests/runtime_integration.rs over the real
    artifacts."""
    row = aot.export_one(str(tmp_path), "semi", 2, 128, "uint32", False,
                         grid_cells=4)
    text = (tmp_path / row["file"]).read_text()
    module = xc._xla.hlo_module_from_text(text)
    rendered = module.to_string()
    assert "u32[2,128]" in rendered, "entry shape lost in round-trip"
    # The module must be tuple-returning (rust unwraps with to_tuple1).
    assert "(u32[2,128])" in rendered


def test_quick_mode_covers_all_variants(tmp_path, monkeypatch):
    aot.main(["--out-dir", str(tmp_path), "--quick", "--grid-cells", "4"])
    manifest = (tmp_path / "manifest.tsv").read_text().splitlines()
    body = [l.split("\t") for l in manifest[1:]]
    cols = manifest[0].split("\t")
    variants = {row[cols.index("variant")] for row in body}
    assert variants == set(model.VARIANTS)
    for row in body:
        assert (tmp_path / row[-1]).exists()


def test_descending_artifact_content(tmp_path):
    row = aot.export_one(str(tmp_path), "basic", 1, 32, "uint32", True,
                         grid_cells=2)
    assert row["descending"] == 1
    assert row["name"].endswith("_desc")
