"""Bit-exactness pins for the lane-model mirror (``compile.lanes``).

The mirror is deliberately jax-free, so this suite runs in the
numpy+pytest-only CI environment too (no skips). It pins the same
properties the Rust suite (``rust/tests/simd_props.rs``) proves about
the explicit SIMD kernels: the f32 order-preserving bit map, the
interleaved-layout step semantics, the fused double-step operation
order, and full-network agreement with an independent total-order
oracle — everything compared as bits, never with float ``==``.
"""

import numpy as np

from compile import lanes
from conftest import random_rows

LANE_WIDTHS = [1, 3, 4, 8, 16]
DTYPES = [np.uint32, np.int32, np.float32]

# Total-order ladder as bit patterns: -NaN < -inf < -1 < -0 < +0 < 1 <
# +inf < +NaN. Strictly increasing under the order key, and every rung
# has a distinct bit pattern the sorts must preserve verbatim.
F32_LADDER_BITS = np.array(
    [
        0xFFC0_0000,  # -NaN
        0xFF80_0000,  # -inf
        0xBF80_0000,  # -1.0
        0x8000_0000,  # -0.0
        0x0000_0000,  # +0.0
        0x3F80_0000,  # +1.0
        0x7F80_0000,  # +inf
        0x7FC0_0000,  # +NaN
    ],
    dtype=np.uint32,
)


def bits(a):
    """uint32 view of any 32-bit row — the only equality we trust."""
    return np.asarray(a).view(np.uint32)


def salted(rows):
    """Plant the full special-value ladder in every f32 row."""
    rows = rows.copy()
    if rows.dtype == np.float32:
        rows[:, : F32_LADDER_BITS.size] = F32_LADDER_BITS.view(np.float32)
    return rows


def oracle_sorted(row, descending=False):
    """Total-order sort of one row, preserving bit patterns."""
    out = row[np.argsort(lanes.order_key(row), kind="stable")]
    return out[::-1] if descending else out


def scalar_step(rows, k, j, flip=False):
    """Per-row (lane-oblivious) reference step in ref.py's conventions:
    partners (i, i ^ j), ascending iff ``i & k == 0``, xor ``flip``."""
    n = rows.shape[1]
    for i in range(0, n, 2 * j):
        lo = rows[:, i : i + j].copy()
        hi = rows[:, i + j : i + 2 * j].copy()
        ka, kb = lanes.order_key(lo), lanes.order_key(hi)
        if ((i & k) != 0) ^ flip:
            swap = ka < kb
        else:
            swap = kb < ka
        rows[:, i : i + j] = np.where(swap, hi, lo)
        rows[:, i + j : i + 2 * j] = np.where(swap, lo, hi)


def test_f32_ord_key_is_total_order_monotone():
    vals = F32_LADDER_BITS.view(np.float32)
    key = lanes.f32_ord_key(vals).astype(np.int64)
    assert (np.diff(key) > 0).all(), key


def test_f32_ord_key_is_an_involution(rng):
    b = rng.integers(0, 2 ** 32, size=4096, dtype=np.uint32)
    once = lanes.f32_ord_key(b.view(np.float32)).view(np.uint32)
    twice = lanes.f32_ord_key(once.view(np.float32)).view(np.uint32)
    assert (twice == b).all()


def test_interleave_roundtrip(rng):
    for width in LANE_WIDTHS:
        rows = random_rows(rng, width, 32, np.uint32)
        tile = lanes.interleave(rows)
        # tile[e * lanes + l] == rows[l, e] — the layout contract.
        assert tile[5 * width + (width - 1)] == rows[width - 1, 5]
        assert (lanes.deinterleave(tile, width) == rows).all()


def test_interleaved_steps_match_per_lane_scalar_steps(rng):
    """Lanes must be invisible: every step of the interleaved walk is
    bit-identical to the same step applied to each lane separately."""
    n = 64
    for dtype in DTYPES:
        for width in LANE_WIDTHS:
            rows = salted(random_rows(rng, width, n, dtype))
            tile = lanes.interleave(rows)
            ref = rows.copy()
            k = 2
            while k <= n:
                j = k // 2
                while j >= 1:
                    lanes.step_interleaved(tile, k, j, width)
                    scalar_step(ref, k, j)
                    got = lanes.deinterleave(tile, width)
                    label = f"{np.dtype(dtype)} lanes={width} k={k} j={j}"
                    assert (bits(got) == bits(ref)).all(), label
                    j //= 2
                k *= 2


def test_double_step_equals_two_single_steps(rng):
    for dtype in DTYPES:
        for width in [1, 3, 8]:
            for n, k, j_hi in [(64, 64, 32), (64, 16, 8), (256, 256, 4)]:
                rows = salted(random_rows(rng, width, n, dtype))
                fused = lanes.interleave(rows)
                split = fused.copy()
                lanes.double_step_interleaved(fused, k, j_hi, width)
                lanes.step_interleaved(split, k, j_hi, width)
                lanes.step_interleaved(split, k, j_hi // 2, width)
                label = f"{np.dtype(dtype)} lanes={width} n={n} k={k} j_hi={j_hi}"
                assert (bits(fused) == bits(split)).all(), label


def test_full_network_sorts_every_lane(rng):
    """Both walks (single-step and the paired double-step schedule) of
    the full network must equal the total-order oracle per lane, as
    bits, ascending and descending."""
    n = 128
    for dtype in DTYPES:
        for width in LANE_WIDTHS:
            for descending in [False, True]:
                rows = salted(random_rows(rng, width, n, dtype))
                want = np.stack([oracle_sorted(r, descending) for r in rows])
                for paired in [False, True]:
                    tile = lanes.interleave(rows)
                    lanes.sort_interleaved(
                        tile, width, descending=descending, paired=paired
                    )
                    got = lanes.deinterleave(tile, width)
                    label = (
                        f"{np.dtype(dtype)} lanes={width} "
                        f"desc={descending} paired={paired}"
                    )
                    assert (bits(got) == bits(want)).all(), label


def test_chunked_sweep_is_observationally_identity(rng):
    """CHUNK only decomposes the sweep loop; results must not depend on
    it. Pin by re-running a full sort with a pathological chunk width."""
    rows = salted(random_rows(rng, 3, 64, np.float32))
    a = lanes.interleave(rows)
    b = a.copy()
    lanes.sort_interleaved(a, 3)
    original = lanes.CHUNK
    try:
        lanes.CHUNK = 1
        lanes.sort_interleaved(b, 3)
    finally:
        lanes.CHUNK = original
    assert (bits(a) == bits(b)).all()
