"""Cross-language regression net for the hierarchical mega-sort.

``compile.hier`` mirrors ``rust/src/sort/kmerge.rs`` (loser-tree k-way
merge), the tiling loop of ``HierarchicalSorter::sort``, and the
autotune fallback-distance rule — the same cases the rust unit tests
pin, so a divergence fails on CI's numpy+pytest floor without cargo.
"""

import random

import pytest

from compile.hier import (
    DEFAULT_TILE_CAP,
    MAX_KEY,
    LoserTree,
    balance_bound,
    bucket_sizes,
    fallback_shortfall,
    hierarchical_sort,
    kway_merge,
    pick_tile,
    plan_partition,
    pmerge,
)


# ----------------------------------------------------------------------
# Loser-tree k-way merge (mirror of the rust kmerge tests)
# ----------------------------------------------------------------------


def test_merges_edge_shapes():
    assert kway_merge([]) == []
    assert kway_merge([[3, 7, 9]]) == [3, 7, 9]
    assert kway_merge([[], [1], []]) == [1]
    assert kway_merge([[1, 3], [2, 4]]) == [1, 2, 3, 4]


def test_max_key_runs_merge_positionally():
    # Pads equal to MAX_KEY must not be confused with exhaustion.
    out = kway_merge([[5, MAX_KEY, MAX_KEY], [1, MAX_KEY]])
    assert out == [1, 5, MAX_KEY, MAX_KEY, MAX_KEY]


@pytest.mark.parametrize("k", [2, 3, 5, 8, 16, 33, 64])
def test_random_runs_match_oracle_for_many_fanins(k):
    rng = random.Random(0xFEED_F00D ^ k)
    runs = [
        sorted(rng.randrange(1000) for _ in range(rng.randrange(200)))
        for _ in range(k)
    ]
    assert kway_merge(runs) == sorted(x for r in runs for x in r)


def test_merge_is_stable_in_run_order():
    # Equal keys must come out in ascending run order: pop one key per
    # tie and check the tree always prefers the lower-indexed run.
    tree = LoserTree([[7, 7], [7], [7, 7]])
    order = []
    while (v := tree.pop()) is not None:
        order.append(v)
    assert order == [7] * 5


# ----------------------------------------------------------------------
# Hierarchical tiling (mirror of HierarchicalSorter::sort)
# ----------------------------------------------------------------------


def test_hierarchical_matches_oracle_on_ragged_mega_rows():
    rng = random.Random(0x64_000)
    for n in [0, 1, 2, 1023, 1024, 1025, 3 * 1024 + 917]:
        keys = [rng.randrange(2 ** 32) for _ in range(n)]
        # Salt real MAX keys: they must survive the MAX padding.
        for i in range(0, n, 131):
            keys[i] = MAX_KEY
        got, stats = hierarchical_sort(keys, tile=1024)
        assert got == sorted(keys), f"n={n}"
        if n > 1:
            assert stats["tiles"] == -(-n // 1024)
            assert stats["device_dispatches"] >= 1


def test_hierarchical_batched_dispatch_groups():
    rng = random.Random(7)
    keys = [rng.randrange(2 ** 32) for _ in range(10 * 256 + 13)]
    got, stats = hierarchical_sort(keys, tile=256, batch=4)
    assert got == sorted(keys)
    assert stats["tiles"] == 11
    # 11 tiles in groups of 4 -> 3 dispatches (mirror of chunks(b*n)).
    assert stats["device_dispatches"] == 3


def test_single_tile_passthrough_shortcut():
    keys = [5, 3, 1]
    got, stats = hierarchical_sort(keys, tile=1024)
    assert got == [1, 3, 5]
    assert stats["tiles"] == 1


def test_pick_tile_ladder():
    menu = [1024, 4096, 65536, 1 << 20]
    assert pick_tile(menu) == 65536  # largest class under the cap
    assert pick_tile(menu, cap=4096) == 4096
    assert pick_tile([1 << 20, 1 << 22]) == 1 << 20  # only mega: smallest
    assert pick_tile([]) is None
    assert DEFAULT_TILE_CAP == 1 << 16


# ----------------------------------------------------------------------
# Splitter-partitioned parallel merge (mirror of sort::pmerge)
# ----------------------------------------------------------------------


def _random_runs(rng, k, max_len, modulo):
    return [
        sorted(rng.randrange(modulo) for _ in range(rng.randrange(max_len + 1)))
        for _ in range(k)
    ]


@pytest.mark.parametrize("k,parts", [(2, 4), (3, 8), (16, 8), (5, 2)])
def test_partition_covers_monotonically(k, parts):
    rng = random.Random(0x5A_11 ^ (k << 8) ^ parts)
    runs = _random_runs(rng, k, 300, 1000)
    cuts = plan_partition(runs, parts)
    lens = [len(r) for r in runs]
    assert cuts[0] == [0] * k
    assert cuts[-1] == lens
    assert 2 <= len(cuts) <= parts + 1
    for prev, nxt in zip(cuts, cuts[1:]):
        assert all(a <= b for a, b in zip(prev, nxt))
    assert sum(bucket_sizes(cuts)) == sum(lens)


@pytest.mark.parametrize("parts", [2, 4, 8])
def test_dup_heavy_partition_stays_under_the_balance_bound(parts):
    # All keys equal: only the (key, run, index) rank tie-break keeps
    # the buckets from collapsing into one.
    runs = [[42] * 512 for _ in range(8)]
    cuts = plan_partition(runs, parts)
    assert len(cuts) - 1 > 1, "all-equal keys collapsed the partition"
    lens = [len(r) for r in runs]
    assert max(bucket_sizes(cuts)) <= balance_bound(lens, parts)


@pytest.mark.parametrize("k", [2, 3, 16])
@pytest.mark.parametrize("parts", [2, 4, 16])
def test_pmerge_is_bit_exact_with_the_loser_tree(k, parts):
    rng = random.Random(0xB17_E ^ (k << 4) ^ parts)
    for modulo in (7, 10_000, 2 ** 32):
        runs = _random_runs(rng, k, 400, modulo)
        assert pmerge(runs, parts) == kway_merge(runs)


def test_pmerge_handles_max_pads_and_empty_runs():
    runs = [
        [5, MAX_KEY, MAX_KEY],
        [],
        [1, MAX_KEY],
        [MAX_KEY] * 4,
    ]
    got = pmerge(runs, 4)
    assert got == kway_merge(runs)
    assert got.count(MAX_KEY) == 7


def test_pmerge_degenerate_shapes():
    assert pmerge([], 4) == []
    assert pmerge([[1, 2, 3]], 4) == [1, 2, 3]
    assert pmerge([[], []], 4) == []
    assert pmerge([[2], [1]], 1) == [1, 2]


# ----------------------------------------------------------------------
# Autotune fallback distance (mirror of autotune::fallback_shortfall)
# ----------------------------------------------------------------------


def test_fallback_shortfall_warns_only_beyond_4x():
    assert fallback_shortfall(1024, 1 << 20) == 1024
    assert fallback_shortfall(1024, 4096) is None  # exactly 4x: fine
    assert fallback_shortfall(1024, 8192) == 8
    assert fallback_shortfall(65536, 65536) is None
    assert fallback_shortfall(1 << 20, 65536) is None  # upward is never far
