"""Wire-codec mirror grid: python ``compile.net`` vs rust ``net::wire``.

The golden byte vectors here are pinned in ``rust/src/coordinator/net/
wire.rs`` (unit tests) and ``rust/tests/net_props.rs`` — all three must
agree or an interop break slipped in. The malformed table keys on the
rust ``WireError::kind()`` strings verbatim.
"""

import random

import pytest

from compile import net


# ---------------------------------------------------------------------
# Round-trips.
# ---------------------------------------------------------------------

FRAMES = [
    net.Sort(id=7, descending=False, slo_us=0, keys=[1, 2]),
    net.Sort(id=2**64 - 1, descending=True, slo_us=2**32 - 1, keys=[]),
    net.Sorted(id=3, cpu_path=True, latency_us=123, occupancy=4, keys=[9, 9, 9]),
    net.Error(code=net.CODE_SHED, id=9, message="shed"),
    net.Error(code=net.CODE_INTERNAL, id=0, message=""),
    net.Ping(token=0x0102030405060708),
    net.Pong(token=0),
    net.Shutdown(token=2**64 - 1),
]


@pytest.mark.parametrize("frame", FRAMES, ids=lambda f: type(f).__name__)
def test_round_trip(frame):
    body = net.encode_body(frame)
    assert net.decode_body(body) == frame
    decoded, used = net.decode_frame(net.encode_frame(frame))
    assert decoded == frame
    assert used == 4 + len(body)


def test_randomized_round_trips():
    rng = random.Random(0xB170)
    for _ in range(300):
        kind = rng.randrange(6)
        rid = rng.getrandbits(64)
        keys = [rng.getrandbits(32) for _ in range(rng.randrange(32))]
        if kind == 0:
            frame = net.Sort(
                id=rid,
                descending=bool(rng.getrandbits(1)),
                slo_us=rng.getrandbits(32),
                keys=keys,
            )
        elif kind == 1:
            frame = net.Sorted(
                id=rid,
                cpu_path=bool(rng.getrandbits(1)),
                latency_us=rng.getrandbits(32),
                occupancy=rng.getrandbits(32),
                keys=keys,
            )
        elif kind == 2:
            frame = net.Error(
                code=rng.randrange(1, 6),
                id=rid,
                message="".join(chr(rng.randrange(97, 123)) for _ in range(rng.randrange(48))),
            )
        elif kind == 3:
            frame = net.Ping(token=rid)
        elif kind == 4:
            frame = net.Pong(token=rid)
        else:
            frame = net.Shutdown(token=rid)
        assert net.decode_body(net.encode_body(frame)) == frame


# ---------------------------------------------------------------------
# Golden byte vectors — identical in wire.rs and net_props.rs.
# ---------------------------------------------------------------------

def test_golden_ping_bytes():
    assert net.encode_frame(net.Ping(token=0x0102030405060708)) == bytes(
        [0x0E, 0x00, 0x00, 0x00]
        + list(b"BTSP")
        + [0x01, 0x04]
        + [0x08, 0x07, 0x06, 0x05, 0x04, 0x03, 0x02, 0x01]
    )


def test_golden_sort_bytes():
    assert net.encode_frame(net.Sort(id=7, keys=[1, 2])) == bytes(
        [0x20, 0x00, 0x00, 0x00]
        + list(b"BTSP")
        + [0x01, 0x01]
        + [0x00, 0x00]
        + [0x07, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00]
        + [0x00, 0x00, 0x00, 0x00]
        + [0x02, 0x00, 0x00, 0x00]
        + [0x01, 0x00, 0x00, 0x00, 0x02, 0x00, 0x00, 0x00]
    )


def test_golden_error_bytes():
    assert net.encode_frame(net.Error(code=net.CODE_SHED, id=9, message="shed")) == bytes(
        [0x14, 0x00, 0x00, 0x00]
        + list(b"BTSP")
        + [0x01, 0x03]
        + [0x04, 0x00]
        + [0x09, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00]
        + list(b"shed")
    )


# ---------------------------------------------------------------------
# Malformed table, keyed by rust WireError::kind().
# ---------------------------------------------------------------------

def _mutate(body, index, value):
    out = bytearray(body)
    out[index] = value
    return bytes(out)


SORT = net.encode_body(net.Sort(id=1, keys=[5]))
SORTED = net.encode_body(net.Sorted(id=1, latency_us=1, occupancy=1, keys=[]))
ERROR = net.encode_body(net.Error(code=net.CODE_INTERNAL, id=1, message="x"))

MALFORMED = [
    (_mutate(SORT, 0, ord("X")), "bad-magic"),
    (_mutate(SORT, 4, 99), "bad-version"),
    (_mutate(SORT, 5, 42), "bad-op"),
    (_mutate(SORT, 6, 7), "bad-dtype"),
    (_mutate(SORT, 7, 2), "bad-order"),
    (SORT[:-1], "truncated"),
    (SORT + b"\0", "trailing"),
    (_mutate(SORT, 20, 2), "truncated"),  # n claims 2 keys, payload has 1
    (_mutate(SORTED, 6, 3), "bad-path"),
    (_mutate(SORTED, 7, 1), "bad-reserved"),
    (_mutate(ERROR, 6, 0), "bad-code"),
    (_mutate(ERROR, 16, 0xFF), "bad-utf8"),
    (b"", "truncated"),
    (b"BTSP\x01", "truncated"),
]


@pytest.mark.parametrize("body,kind", MALFORMED, ids=[k for _, k in MALFORMED])
def test_malformed_kind(body, kind):
    with pytest.raises(net.NetProtocolError) as exc:
        net.decode_body(body)
    assert exc.value.kind == kind


def test_oversize_n_against_small_cap():
    body = net.encode_body(net.Sort(id=1, keys=[0] * 9))
    with pytest.raises(net.NetProtocolError) as exc:
        net.decode_body(body, max_keys=8)
    assert exc.value.kind == "oversize"
    assert exc.value.code == net.CODE_OVERSIZE


def test_error_codes_follow_the_rust_mapping():
    cases = {
        "bad-magic": net.CODE_MALFORMED,
        "bad-version": net.CODE_UNSUPPORTED,
        "bad-op": net.CODE_UNSUPPORTED,
        "bad-dtype": net.CODE_UNSUPPORTED,
        "bad-order": net.CODE_MALFORMED,
        "truncated": net.CODE_MALFORMED,
        "oversize": net.CODE_OVERSIZE,
    }
    for kind, code in cases.items():
        assert net.NetProtocolError(kind).code == code


# ---------------------------------------------------------------------
# Truncation sweep + fuzz.
# ---------------------------------------------------------------------

@pytest.mark.parametrize("frame", FRAMES, ids=lambda f: type(f).__name__)
def test_every_truncation_is_rejected(frame):
    # Error frames are the one variable-tail op with no length field of
    # its own: a truncated *body* is a valid frame with a shorter
    # message, so only cuts into the fixed part must fail. (The outer
    # length prefix is what delimits the message on the wire.)
    body = net.encode_body(frame)
    end = net._ERROR_FIXED if isinstance(frame, net.Error) else len(body)
    for cut in range(end):
        with pytest.raises(net.NetProtocolError):
            net.decode_body(body[:cut])


def test_outer_frame_truncations_are_rejected():
    data = net.encode_frame(net.Sort(id=1, keys=[1, 2, 3]))
    for cut in range(len(data)):
        with pytest.raises(net.NetProtocolError) as exc:
            net.decode_frame(data[:cut])
        assert exc.value.kind == "truncated"


def test_oversize_length_prefix_is_rejected_before_decoding():
    import struct

    huge = struct.pack("<I", net.frame_cap(net.DEFAULT_MAX_KEYS) + 1)
    with pytest.raises(net.NetProtocolError) as exc:
        net.decode_frame(huge + b"\0" * 16)
    assert exc.value.kind == "oversize"


def test_garbage_never_crashes():
    rng = random.Random(0xB170F422)
    for round_no in range(2000):
        body = bytearray(rng.getrandbits(8) for _ in range(rng.randrange(256)))
        # Half the rounds get a valid header so the fuzz reaches the
        # per-op validation (mirrors the rust fuzz loop).
        if round_no % 2 == 0 and len(body) >= 6:
            body[:4] = net.MAGIC
            body[4] = net.VERSION
            body[5] = 1 + rng.randrange(6)
        try:
            net.decode_body(bytes(body))
        except net.NetProtocolError:
            pass


def test_long_error_messages_clamp_on_a_char_boundary():
    frame = net.Error(code=net.CODE_INTERNAL, id=1, message="é" * net.MAX_ERROR_MSG)
    decoded = net.decode_body(net.encode_body(frame))
    assert len(decoded.message.encode("utf-8")) <= net.MAX_ERROR_MSG
    assert decoded.message and set(decoded.message) == {"é"}
