"""Cross-language pin for native artifact synthesis.

``compile.genart`` is a 1:1 mirror of ``rust/src/runtime/genart.rs``
(``bitonic-tpu gen-artifacts``). The strongest check here renders a
class that also exists in the checked-in fixture and asserts **byte
equality** with the fixture file — proving both generators (and the JAX
AOT pipeline that produced the fixture) emit the same HLO text format.
"""

import os

import pytest

from compile import genart

FIXTURE_DIR = os.path.join(
    os.path.dirname(__file__), "..", "..", "rust", "artifacts"
)


# ----------------------------------------------------------------------
# Template fidelity
# ----------------------------------------------------------------------


def test_rendered_hlo_is_byte_identical_to_the_fixture():
    spec = genart.GenSpec.sort(65536)
    path = os.path.join(FIXTURE_DIR, spec.file)
    assert os.path.exists(path), f"fixture missing: {path}"
    with open(path) as f:
        assert spec.hlo_text() == f.read()


def test_rendered_manifest_row_matches_the_fixture_row():
    spec = genart.GenSpec.sort(65536)
    with open(os.path.join(FIXTURE_DIR, "manifest.tsv")) as f:
        lines = f.read().splitlines()
    assert lines[0] == genart.MANIFEST_HEADER
    fixture_row = next(l for l in lines if l.startswith(spec.name + "\t"))
    # grid_cells is a hint column: the fixture derived it from its own
    # lowering geometry, so compare every other field exactly.
    ours = spec.manifest_row().split("\t")
    theirs = fixture_row.split("\t")
    assert len(ours) == len(theirs) == 10
    for i, (a, b) in enumerate(zip(ours, theirs)):
        if i not in (7, 8):  # block / grid_cells hints
            assert a == b, f"column {i}: {a!r} != {b!r}"


@pytest.mark.parametrize(
    "dtype,tok", [("uint32", "u32"), ("int32", "s32"), ("float32", "f32")]
)
def test_dtype_tokens_and_order_direction(dtype, tok):
    asc = genart.GenSpec.sort(1024, batch=2, dtype=dtype)
    text = asc.hlo_text()
    assert f"{tok}[2,1024]" in text
    assert "direction=LT" in text and "direction=GT" not in text
    desc = genart.GenSpec.sort(1024, batch=2, dtype=dtype, descending=True)
    assert "direction=GT" in desc.hlo_text()


def test_names_match_the_fixture_convention():
    s = genart.GenSpec.sort(1 << 20)
    assert s.name == "sort_optimized_b1_n1048576_uint32_asc"
    assert s.file == "sort_optimized_b1_n1048576_uint32_asc.hlo.txt"
    assert genart.GenSpec.sort(1 << 10, batch=8, dtype="int32",
                               descending=True).name == \
        "sort_optimized_b8_n1024_int32_desc"
    assert genart.GenSpec.merge(1 << 12).name == \
        "merge_optimized_b1_n4096_uint32_asc"


# ----------------------------------------------------------------------
# Generation
# ----------------------------------------------------------------------


def test_generate_writes_manifest_referencing_exactly_the_files(tmp_path):
    specs = [
        genart.GenSpec.sort(1 << 17),
        genart.GenSpec.sort(1 << 10, batch=4, dtype="float32",
                            descending=True),
        genart.GenSpec.merge(1 << 12, batch=2),
    ]
    report = genart.generate(str(tmp_path), specs)
    assert report["written"] == 3
    assert report["rows"] == 3
    assert report["max_sort_n"] == 1 << 17

    with open(tmp_path / "manifest.tsv") as f:
        lines = f.read().splitlines()
    assert lines[0] == genart.MANIFEST_HEADER
    files_on_disk = {p.name for p in tmp_path.iterdir()} - {"manifest.tsv"}
    files_in_rows = {line.split("\t")[-1] for line in lines[1:]}
    assert files_on_disk == files_in_rows  # no dangling texts either way


def test_duplicate_specs_collapse(tmp_path):
    s = genart.GenSpec.sort(1 << 10)
    report = genart.generate(str(tmp_path), [s, s])
    assert report["rows"] == 1 and report["written"] == 1


def test_invalid_specs_rejected():
    with pytest.raises(ValueError):
        genart.GenSpec.sort(1000).validate()  # not a power of two
    with pytest.raises(ValueError):
        genart.GenSpec.sort(1024, batch=0).validate()
    with pytest.raises(ValueError):
        genart.GenSpec.sort(1024, dtype="uint64").validate()
    with pytest.raises(ValueError):
        genart.generate("/nonexistent-never-created", [])


# ----------------------------------------------------------------------
# Grids (mirror the rust grid pins)
# ----------------------------------------------------------------------


def test_default_grid_reaches_16m_duplicate_free():
    grid = genart.default_grid()
    assert len({s.name for s in grid}) == len(grid)
    for s in grid:
        s.validate()
    assert max(s.n for s in grid if s.kind == "sort") == 1 << 24
    assert any(s.kind == "merge" for s in grid)


def test_smoke_grid_crosses_the_old_ceiling_and_the_1m_line():
    grid = genart.smoke_grid()
    assert all(s.n > 1 << 16 for s in grid if s.kind == "sort")
    assert any(s.kind == "sort" and s.n >= 1 << 20 for s in grid)
    dtypes = {s.dtype for s in grid}
    assert {"uint32", "int32", "float32"} <= dtypes
    assert any(s.descending for s in grid)
    assert any(s.kind == "merge" for s in grid)
