"""Cross-language regression net for the rust static plan verifier.

``compile.static_check`` is a 1:1 port of ``rust/src/analysis``'s proof
engines (same bit encodings, sampling family and PCG32 streams). These
tests re-derive the verdicts the rust suite pins — canonical schedules
prove, the seeded mutants refute, racy interval schedules are caught —
so a divergence between the implementations fails here on CI's
numpy+pytest floor, no cargo or jax required.
"""

import pytest

from compile import static_check as sc


# ----------------------------------------------------------------------
# RNG fidelity: both sides must generate the same sampled 0-1 vectors.
# ----------------------------------------------------------------------


def test_pcg32_matches_published_reference():
    # O'Neill's pcg32 demo: seed 42, stream 54 — first outputs of the
    # reference implementation. The rust Pcg32 uses the same init, so
    # this pins both ports to the published generator.
    rng = sc.Pcg32(42, 54)
    assert [rng.next_u32() for _ in range(6)] == [
        0xA15C02B7,
        0x7B47F409,
        0xBA1D3330,
        0x83D2F293,
        0xBFA4784B,
        0xCBED606E,
    ]


def test_next_below_is_in_range_and_deterministic():
    rng = sc.Pcg32(0x3E26E001, 64)
    draws = [rng.next_below(33) for _ in range(64)]
    assert all(0 <= d < 33 for d in draws)
    rng2 = sc.Pcg32(0x3E26E001, 64)
    assert draws == [rng2.next_below(33) for _ in range(64)]


# ----------------------------------------------------------------------
# Kernel fidelity: the mask-parallel step equals the per-pair reference.
# ----------------------------------------------------------------------


def test_zo_step_matches_generic_reference():
    n = 256
    rng = sc.Pcg32(7, 7)
    for k, j in sc.step_schedule(n):
        v = 0
        for w in range(n // 64):
            v |= rng.next_u64() << (64 * w)
        assert sc.zo_step(v, n, k, j) == sc.zo_step_generic(v, n, k, j), (k, j)


# ----------------------------------------------------------------------
# Proof engines on canonical schedules.
# ----------------------------------------------------------------------


@pytest.mark.parametrize("n", [2, 4, 8, 16])
def test_brute_force_proves_small_canonical_networks(n):
    assert sc.brute_force_sort(n, sc.step_schedule(n)) == 1 << n


@pytest.mark.parametrize("n", [32, 128, 256])
def test_induction_proves_midsize_canonical_networks(n):
    status, detail = sc.check_sort_steps(n, sc.step_schedule(n))
    assert status == "proven" and detail == "per-phase 0-1 induction"


def test_induction_agrees_with_brute_force_on_overlap():
    # At n=16 both engines run; they must agree the schedule sorts.
    sc.brute_force_sort(16, sc.step_schedule(16))
    k = 2
    while k <= 16:
        sc.phase_lemma(k)
        k *= 2


def test_above_cap_is_sampled_not_proven():
    status, detail = sc.check_sort_steps(2048, sc.step_schedule(2048), exhaustive_cap=512)
    assert status == "not-proven" and "exceeds exhaustive cap" in detail


@pytest.mark.parametrize("n", [4, 64, 256])
def test_merge_lemma_proves_canonical_merge(n):
    status, _ = sc.check_merge_steps(n, sc.merge_steps(n), reverse_tail=True)
    assert status == "proven"


# ----------------------------------------------------------------------
# Mutants — these verdicts are pinned by rust/tests/analysis_mutations.rs;
# the port must agree on every one.
# ----------------------------------------------------------------------


def test_mutant_dropped_final_step_small_is_refuted():
    steps = sc.step_schedule(16)[:-1]
    status, detail = sc.check_sort_steps(16, steps)
    assert status == "refuted", detail


def test_mutant_dropped_final_step_large_is_refuted_by_sampling():
    # n=1024 deviates from canonical -> the sampled family must find a
    # counterexample (the rust mutation suite asserts the same).
    steps = sc.step_schedule(1024)[:-1]
    status, detail = sc.check_sort_steps(1024, steps)
    assert status == "refuted", detail


def test_mutant_flipped_direction_is_refuted():
    # Corrupt an *earlier* phase's phase_len: (4,2) -> (8,2) flips the
    # direction bit for half the pairs of phase 4.
    steps = sc.step_schedule(16)
    i = steps.index((4, 2))
    steps[i] = (8, 2)
    status, detail = sc.check_sort_steps(16, steps)
    assert status == "refuted", detail


def test_mutant_off_by_one_stride_is_refuted():
    # (8,4) -> (8,3): non-power-of-two stride, generic kernel path.
    steps = sc.step_schedule(16)
    i = steps.index((8, 4))
    steps[i] = (8, 3)
    status, detail = sc.check_sort_steps(16, steps)
    assert status == "refuted", detail


def test_mutant_merge_without_reverse_tail_is_refuted():
    status, detail = sc.check_merge_steps(64, sc.merge_steps(64), reverse_tail=False)
    assert status == "refuted", detail


def test_mutant_merge_dropped_step_is_refuted():
    status, detail = sc.check_merge_steps(64, sc.merge_steps(64)[:-1], reverse_tail=True)
    assert status == "refuted", detail


# ----------------------------------------------------------------------
# Disjointness checker.
# ----------------------------------------------------------------------


@pytest.mark.parametrize("n,workers", [(4096, 2), (4096, 8), (1024, 4), (16, 4), (64, 2)])
def test_canonical_parallel_schedule_is_disjoint(n, workers):
    stats = sc.check_parallel_schedule(n, workers)
    assert stats["intervals"] > 0 and stats["writes"] >= n


def test_interval_expansion_equals_step_schedule():
    for n, workers in [(1024, 4), (4096, 8), (64, 2)]:
        ops = sc.barrier_intervals(n, n // workers)
        flat = [s for op in ops for s in sc.interval_steps(op)]
        assert flat == sc.step_schedule(n), (n, workers)


def test_mutant_racy_interval_is_detected():
    # Two unpaired global strides in ONE barrier interval — the race the
    # quad pairing exists to prevent. Pinned by the rust mutation suite.
    racy = [[("lows", 16, 8), ("lows", 16, 4)]]
    with pytest.raises(ValueError, match="workers"):
        sc.check_intervals(16, 4, racy)


def test_mutant_escaping_local_tail_is_detected():
    with pytest.raises(ValueError, match="escapes"):
        sc.check_intervals(32, 4, [[("local", 8, 8)]])


def test_mutant_out_of_range_quad_is_detected():
    with pytest.raises(ValueError, match="escapes"):
        sc.check_intervals(16, 4, [[("paired", 32, 16)]])


def test_mutant_direction_splitting_quad_is_detected():
    with pytest.raises(ValueError, match="direction"):
        sc.check_intervals(16, 2, [[("paired", 4, 4)]])


def test_effective_workers_matches_runtime_cutover():
    assert sc.effective_workers(1024, 8) == 1  # below the n cutover
    assert sc.effective_workers(4096, 1) == 1
    assert sc.effective_workers(4096, 8) == 8
    assert sc.effective_workers(4096, 6) == 4  # rounds down to a power of two
    assert sc.effective_workers(8, 64) == 1  # clamp to n/2=4, then n cutover


@pytest.mark.parametrize("n,workers", [(64, 2), (256, 4), (1024, 8)])
def test_interval_semantics_actually_sort(n, workers):
    # Ground the symbolic write sets: executing the interval ops on
    # concrete rows must be a correct sort.
    ops = sc.barrier_intervals(n, n // workers)
    rng = sc.Pcg32(0xB170, n)
    xs = [rng.next_u32() for _ in range(n)]
    assert sc.simulate_intervals(xs, workers, ops) == sorted(xs)


# ----------------------------------------------------------------------
# Tile dispatch.
# ----------------------------------------------------------------------


def test_tile_dispatch_grid_is_disjoint():
    ragged = 0
    for b in range(1, 65):
        for want in (1, 3, 4, 8, 16):
            for threads in (1, 2, 4, 8):
                for n in (32, 256):
                    stats = sc.check_tile_dispatch(b, n, want, threads)
                    if b % stats["r"] != 0:
                        ragged += 1
    assert ragged > 0  # ragged tails were actually exercised


def test_tile_dispatch_spot_check():
    stats = sc.check_tile_dispatch(13, 256, 4, 4)
    assert stats["pooled"]
    assert stats["r"] == 3  # capped at b/threads
    assert stats["tiles"] == 5  # ceil(13/3)
