#!/usr/bin/env python3
"""Render speedup-vs-n curves from BENCH_trajectory.json.

The paper's headline claim is a speedup ratio over CPU quicksort that
grows with array size: "nearly 20 times" on average, "up to 30" around
the peak. This script draws our measured analogue — one curve per
non-quicksort substrate (the flat executor, the hierarchical mega-sort
with its parallel merge, the CPU baselines) against those two reference
lines — from the same trajectory file `bitonic-tpu report` consumes.

matplotlib is optional: without it (or with --ascii) the curves render
as an aligned text table, so CI and headless boxes still get the
numbers. numpy is not required at all.

Usage:
    python3 scripts/plot_speedup.py                  # auto-locate, PNG or ASCII
    python3 scripts/plot_speedup.py -t path.json -o speedup.png
    python3 scripts/plot_speedup.py --ascii          # force the text table
"""

from __future__ import annotations

import argparse
import json
import os
import sys

# The paper's claims (abstract + Table 1), drawn as reference lines.
PAPER_AVG = 20.0
PAPER_PEAK = 30.0
# Substrate whose records carry the merge ablation annotation.
HIER = "hierarchical"


def default_trajectory() -> str:
    """Mirror Trajectory::default_path: env var, then repo root."""
    env = os.environ.get("BENCH_TRAJECTORY_JSON")
    if env:
        return env
    here = os.path.dirname(os.path.abspath(__file__))
    return os.path.join(os.path.dirname(here), "BENCH_trajectory.json")


def load_records(path: str) -> list[dict]:
    with open(path, encoding="utf-8") as fh:
        doc = json.load(fh)
    records = doc.get("records")
    if not isinstance(records, list):
        raise SystemExit(f"{path}: no 'records' array — not a trajectory file")
    return [r for r in records if isinstance(r, dict)]


def ms_per_row(rec: dict) -> float:
    batch = max(int(rec.get("batch", 1) or 1), 1)
    return float(rec.get("ms", 0.0)) / batch


def speedup_curves(records: list[dict]) -> tuple[dict[str, dict[int, float]], dict[int, float]]:
    """Per-substrate {n: speedup} curves (uniform u32 matrix cells, the
    paper's workload), plus the hierarchical cells' parallel-merge
    annotation {n: merge_speedup_vs_serial} as its own curve source.

    Latest record wins a cell, matching the report's convention.
    """
    quick: dict[int, float] = {}
    for r in records:
        if (
            r.get("bench") == "matrix"
            and r.get("substrate") == "quicksort"
            and r.get("dist") == "uniform"
            and r.get("dtype") == "u32"
            and float(r.get("ms", 0.0)) > 0.0
        ):
            quick[int(r["n"])] = ms_per_row(r)

    curves: dict[str, dict[int, float]] = {}
    merge: dict[int, float] = {}
    for r in records:
        if r.get("bench") != "matrix" or r.get("dist") != "uniform" or r.get("dtype") != "u32":
            continue
        sub = str(r.get("substrate", ""))
        n = int(r.get("n", 0))
        if sub == "quicksort" or n not in quick or ms_per_row(r) <= 0.0:
            continue
        curves.setdefault(sub, {})[n] = quick[n] / ms_per_row(r)
        if sub == HIER and "merge_speedup_vs_serial" in r:
            merge[n] = float(r["merge_speedup_vs_serial"])
    return curves, merge


def fmt_n(n: int) -> str:
    for shift, suffix in ((20, "M"), (10, "K")):
        if n >= (1 << shift) and n % (1 << shift) == 0:
            return f"{n >> shift}{suffix}"
    return str(n)


def render_ascii(curves: dict[str, dict[int, float]], merge: dict[int, float]) -> str:
    sizes = sorted({n for c in curves.values() for n in c})
    subs = sorted(curves, key=lambda s: (-max(curves[s].values()), s))
    header = ["n"] + subs + ["hier merge vs serial"]
    rows = []
    for n in sizes:
        row = [fmt_n(n)]
        for s in subs:
            v = curves[s].get(n)
            row.append(f"{v:.2f}x" if v is not None else "-")
        m = merge.get(n)
        row.append(f"{m:.2f}x" if m is not None else "-")
        rows.append(row)
    widths = [max(len(r[i]) for r in [header] + rows) for i in range(len(header))]
    lines = ["  ".join(h.ljust(w) for h, w in zip(header, widths))]
    lines.append("  ".join("-" * w for w in widths))
    for row in rows:
        lines.append("  ".join(c.rjust(w) if i else c.ljust(w) for i, (c, w) in enumerate(zip(row, widths))))
    lines.append("")
    lines.append(f"paper reference: ~{PAPER_AVG:.0f}x average, up to ~{PAPER_PEAK:.0f}x (GPU vs CPU quicksort)")
    return "\n".join(lines)


def render_png(curves: dict[str, dict[int, float]], merge: dict[int, float], out: str) -> None:
    import matplotlib

    matplotlib.use("Agg")
    import matplotlib.pyplot as plt

    fig, ax = plt.subplots(figsize=(8, 5))
    for sub in sorted(curves):
        pts = sorted(curves[sub].items())
        ax.plot(
            [n for n, _ in pts],
            [s for _, s in pts],
            marker="o",
            label=sub,
            linewidth=1.6,
        )
    if merge:
        pts = sorted(merge.items())
        ax.plot(
            [n for n, _ in pts],
            [s for _, s in pts],
            marker="s",
            linestyle=":",
            label="hier merge vs serial",
            linewidth=1.4,
        )
    ax.axhline(PAPER_AVG, color="gray", linestyle="--", linewidth=1)
    ax.axhline(PAPER_PEAK, color="gray", linestyle=":", linewidth=1)
    ax.text(0.99, PAPER_AVG, "paper ~20x avg", ha="right", va="bottom", transform=ax.get_yaxis_transform(), fontsize=8, color="gray")
    ax.text(0.99, PAPER_PEAK, "paper ~30x peak", ha="right", va="bottom", transform=ax.get_yaxis_transform(), fontsize=8, color="gray")
    ax.set_xscale("log", base=2)
    ax.set_xlabel("array size n")
    ax.set_ylabel("speedup vs CPU quicksort (x)")
    ax.set_title("Measured speedup vs quicksort (uniform u32)")
    ax.grid(True, which="both", alpha=0.3)
    ax.legend(fontsize=8)
    fig.tight_layout()
    fig.savefig(out, dpi=140)
    print(f"wrote {out}")


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__, formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("-t", "--trajectory", default=default_trajectory(), help="trajectory JSON path (default: $BENCH_TRAJECTORY_JSON or repo root)")
    ap.add_argument("-o", "--out", default="speedup.png", help="output image path (default: speedup.png)")
    ap.add_argument("--ascii", action="store_true", help="print the text table even if matplotlib is available")
    args = ap.parse_args(argv)

    if not os.path.exists(args.trajectory):
        print(f"no trajectory at {args.trajectory} — run `bitonic-tpu bench` first", file=sys.stderr)
        return 1
    curves, merge = speedup_curves(load_records(args.trajectory))
    if not curves:
        print("trajectory has no (quicksort, substrate) uniform-u32 pairs to compare", file=sys.stderr)
        return 1

    if not args.ascii:
        try:
            render_png(curves, merge, args.out)
            return 0
        except ImportError:
            print("matplotlib not available — falling back to the text table\n", file=sys.stderr)
    print(render_ascii(curves, merge))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
