#!/usr/bin/env bash
# Tier-1 verification: build + tests, plus a format check when rustfmt
# is available (it is optional in the offline toolchain image).
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

if cargo fmt --version >/dev/null 2>&1; then
    echo "== cargo fmt --check =="
    cargo fmt --all -- --check
else
    echo "== cargo fmt unavailable; skipping format check =="
fi

# Python suite (skips itself per-module when JAX/hypothesis are absent,
# but needs numpy + pytest to collect at all).
if python3 -c "import numpy, pytest" >/dev/null 2>&1; then
    echo "== pytest python/tests =="
    (cd python && python3 -m pytest tests -q)
else
    echo "== numpy/pytest unavailable; skipping python tests =="
fi

echo "verify: OK"
