#!/usr/bin/env bash
# Tier-1 verification: build + tests, plus a format check when rustfmt
# is available (it is optional in the offline toolchain image).
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== cargo build --release (incl. all bench binaries) =="
# --benches: every bench appends to the trajectory now, so a bench that
# stops compiling is a broken producer even when CI only *runs* two of
# them — build them all.
cargo build --release --all-targets

# Lint gate: warnings are defects. Gated on availability like rustfmt
# below (the offline toolchain image may lack the component); CI always
# has it, so a finding cannot land through the gap.
if cargo clippy --version >/dev/null 2>&1; then
    echo "== cargo clippy --all-targets (deny warnings) =="
    cargo clippy --all-targets -- -D warnings
else
    echo "== cargo clippy unavailable; skipping lint =="
fi

echo "== cargo test -q =="
cargo test -q

# Feature matrix: the `simd` feature must build and pass the whole
# suite too (on a non-AVX2 host its explicit kernels compile out /
# dispatch away, so this is cheap insurance either way). The
# bit-exactness properties in tests/simd_props.rs only cover the AVX2
# kernels when this build runs on hardware that has them.
echo "== cargo build/test --features simd (feature matrix) =="
cargo build --release --all-targets --features simd
cargo test -q --features simd

# Static plan verifier: prove every registry-producible launch program
# sorts (0-1 principle) and every parallel schedule is write-disjoint,
# then gate on the report. The subcommand exits non-zero on any failing
# finding; the grep is belt and braces on top of that (the report
# renders failing verdicts as the bare token FAIL and nothing else).
echo "== static plan verifier (verify-plans) =="
rm -f ANALYSIS.md ANALYSIS.json
cargo run --release --bin bitonic-tpu -- verify-plans --exhaustive-cap 2048
for f in ANALYSIS.md ANALYSIS.json; do
    if [ ! -f "$f" ]; then
        echo "ERROR: verify-plans did not write $f" >&2
        exit 1
    fi
done
if grep -q "FAIL" ANALYSIS.md; then
    echo "ERROR: ANALYSIS.md contains a failing verdict" >&2
    exit 1
fi
echo "== ANALYSIS.md + ANALYSIS.json written, no failing verdicts =="

# Native artifact synthesis, time-bounded: generate the CI-sized grid
# (crossing both the old 64K fixture ceiling and the 1M line) and run
# the full static verifier over the generated directory before anything
# serves it. Every class in the smoke grid sits above the exhaustive
# cap, so the report MUST contain sampled-proof WARNs — their absence
# means the above-cap path silently didn't run — and must contain no
# failing verdict.
echo "== gen-artifacts smoke + verify-plans over the generated grid =="
GEN_DIR="rust/artifacts/generated-smoke"
rm -rf "$GEN_DIR" ANALYSIS_generated.md ANALYSIS_generated.json
if command -v timeout >/dev/null 2>&1; then
    timeout --signal=KILL 300 cargo run --release --bin bitonic-tpu -- gen-artifacts --smoke
    timeout --signal=KILL 600 cargo run --release --bin bitonic-tpu -- verify-plans \
        --artifacts "$GEN_DIR" --analysis-out ANALYSIS_generated.md
else
    cargo run --release --bin bitonic-tpu -- gen-artifacts --smoke
    cargo run --release --bin bitonic-tpu -- verify-plans \
        --artifacts "$GEN_DIR" --analysis-out ANALYSIS_generated.md
fi
if [ ! -f "$GEN_DIR/manifest.tsv" ]; then
    echo "ERROR: gen-artifacts did not write $GEN_DIR/manifest.tsv" >&2
    exit 1
fi
if grep -q "FAIL" ANALYSIS_generated.md; then
    echo "ERROR: ANALYSIS_generated.md contains a failing verdict" >&2
    exit 1
fi
if ! grep -q "exceeds exhaustive cap" ANALYSIS_generated.md; then
    echo "ERROR: generated grid produced no above-cap sampled-proof WARN" >&2
    exit 1
fi
echo "== generated grid verified: FAIL-free, sampled-proof WARNs present =="

# The static proofs must be ISA-independent in fact, not just by
# argument: re-run the plan verifier with the simd feature enabled and
# gate on the same FAIL token.
echo "== verify-plans with --features simd =="
rm -f ANALYSIS_simd.md ANALYSIS_simd.json
cargo run --release --features simd --bin bitonic-tpu -- verify-plans \
    --exhaustive-cap 1024 --analysis-out ANALYSIS_simd.md
if grep -q "FAIL" ANALYSIS_simd.md; then
    echo "ERROR: ANALYSIS_simd.md contains a failing verdict" >&2
    exit 1
fi
rm -f ANALYSIS_simd.md ANALYSIS_simd.json
echo "== simd-feature plan proofs clean =="

# Comparator-ISA equality smoke: the device path must produce the same
# bytes whatever --kernel selects. The sorts share (seed, dist, n), so
# the sorted-output digest cmd_sort prints must agree across scalar,
# explicitly portable, auto, and auto under the simd feature (= avx2 on
# hosts that have it).
echo "== kernel ISA equality smoke (--kernel scalar vs auto) =="
sort_digest() {
    # $1: extra cargo flags (word-split on purpose), $2: --kernel value.
    # shellcheck disable=SC2086
    cargo run --release $1 --bin bitonic-tpu -- \
        sort --algo device --n 4096 --kernel "$2" 2>/dev/null \
        | grep -o 'digest [0-9a-f]*' || true
}
d_scalar=$(sort_digest "" scalar)
d_portable=$(sort_digest "" portable)
d_auto=$(sort_digest "" auto)
d_simd=$(sort_digest "--features simd" auto)
if [ -z "$d_scalar" ]; then
    echo "ERROR: --kernel scalar sort printed no digest" >&2
    exit 1
fi
for d in "$d_portable" "$d_auto" "$d_simd"; do
    if [ "$d" != "$d_scalar" ]; then
        echo "ERROR: kernel ISA digests diverge: scalar=$d_scalar got=$d" >&2
        exit 1
    fi
done
echo "== ISA digests agree: $d_scalar =="

# Hierarchical merge-parallelism equality smoke: the splitter-
# partitioned parallel merge must produce the same bytes as the serial
# loser tree, proven at the CLI-digest level on a size big enough
# (2^18 > PMERGE_MIN_TOTAL) that the parallel path actually engages.
# --no-profile keeps the tile pick deterministic across hosts.
echo "== hier merge digest smoke (--merge-threads 1 vs 4) =="
hier_digest() {
    # $1: --merge-threads value.
    cargo run --release --bin bitonic-tpu -- \
        sort --algo hier --n 262144 --no-profile --merge-threads "$1" 2>/dev/null \
        | grep -o 'digest [0-9a-f]*' || true
}
d_serial_merge=$(hier_digest 1)
d_parallel_merge=$(hier_digest 4)
if [ -z "$d_serial_merge" ]; then
    echo "ERROR: hier sort with --merge-threads 1 printed no digest" >&2
    exit 1
fi
if [ "$d_parallel_merge" != "$d_serial_merge" ]; then
    echo "ERROR: hier merge digests diverge: serial=$d_serial_merge parallel=$d_parallel_merge" >&2
    exit 1
fi
echo "== hier merge digests agree: $d_serial_merge =="

# Bench smoke, time-bounded: the coordinator bench drives the real
# work-stealing scheduler and the row-parallel executor end to end, so a
# scheduler regression (deadlock, starvation, lost wakeup) fails here
# with a kill instead of hanging CI silently; the ablation bench drives
# the fused launch programs (Basic/Semi/Optimized) on the real executor,
# so a fusion regression (wrong pass count, hung interpreter) fails the
# same way. CI runs these as their own steps and sets SKIP_BENCH_SMOKE=1
# here to avoid the double run.
if [ "${SKIP_BENCH_SMOKE:-0}" != "1" ]; then
    # Pin both trajectory paths to the repo root explicitly. The unified
    # trajectory already defaults to the workspace root at compile time,
    # but `cargo bench` runs binaries with cwd = the *package* root
    # (rust/) while `cargo run` keeps this script's cwd — BENCH_ablation
    # defaults to cwd, and pinning both keeps every producer and the
    # existence checks below on exactly the files this script asserts.
    export BENCH_ABLATION_JSON="$PWD/BENCH_ablation.json"
    export BENCH_TRAJECTORY_JSON="$PWD/BENCH_trajectory.json"
    # Remove any stale trajectories first: the existence checks below
    # must prove THIS run wrote them, not a previous one (the files are
    # gitignored and linger in the working tree).
    rm -f BENCH_ablation.json BENCH_trajectory.json RESULTS.md rust/BENCH_ablation.json rust/BENCH_trajectory.json
    for smoke in coordinator ablation; do
        echo "== bench smoke: ${smoke} (timeout-bounded) =="
        if command -v timeout >/dev/null 2>&1; then
            timeout --signal=KILL 300 cargo bench --bench "${smoke}"
        else
            cargo bench --bench "${smoke}"
        fi
    done
    # The ablation bench must leave the machine-readable trajectory
    # behind (rows/sec, passes, interleaved speedup, autotuned config) —
    # future PRs compare against it instead of re-deriving baselines.
    if [ ! -f BENCH_ablation.json ]; then
        echo "ERROR: ablation bench did not write BENCH_ablation.json" >&2
        exit 1
    fi
    echo "== BENCH_ablation.json written =="

    # Survey matrix smoke + report generation: the bench subcommand must
    # append a schema-valid unified trajectory (the ablation bench above
    # already appended its records to it) and the report subcommand must
    # regenerate RESULTS.md from it.
    echo "== bench smoke: survey matrix (timeout-bounded) =="
    if command -v timeout >/dev/null 2>&1; then
        timeout --signal=KILL 300 cargo run --release --bin bitonic-tpu -- bench --smoke
    else
        cargo run --release --bin bitonic-tpu -- bench --smoke
    fi
    echo "== report generation =="
    cargo run --release --bin bitonic-tpu -- report
    for f in BENCH_trajectory.json RESULTS.md; do
        if [ ! -f "$f" ]; then
            echo "ERROR: bench/report smoke did not produce $f" >&2
            exit 1
        fi
    done
    echo "== BENCH_trajectory.json + RESULTS.md written =="

    # Regression gate plumbing: diff the trajectory against itself —
    # every cell compares at ratio 1.0, so the gate must pass — proving
    # the --diff/--gate path end to end (env stamp match, cell keying,
    # exit code). Real use diffs against a baseline from an earlier run.
    echo "== report --diff --gate (self-diff must be clean) =="
    cp BENCH_trajectory.json BENCH_trajectory.baseline.json
    cargo run --release --bin bitonic-tpu -- report \
        --diff BENCH_trajectory.baseline.json --gate
    rm -f BENCH_trajectory.baseline.json
    echo "== trajectory diff gate clean =="

    # Serving smoke 1: the one-command loopback E2E — loadgen self-hosts
    # a serve-tcp server, drives the smoke mix closed-loop, gates itself
    # on zero protocol errors, and appends records to the trajectory.
    echo "== serving smoke: loadgen --smoke (self-hosted loopback) =="
    if command -v timeout >/dev/null 2>&1; then
        timeout --signal=KILL 300 cargo run --release --bin bitonic-tpu -- loadgen --smoke
    else
        cargo run --release --bin bitonic-tpu -- loadgen --smoke
    fi

    # Serving smoke 2: a real out-of-process round trip — background
    # serve-tcp on an ephemeral port, parse the bound address off its
    # stdout, drive it open-loop, then stop it with a Shutdown frame and
    # check it drained cleanly.
    echo "== serving smoke: serve-tcp + loadgen over the wire =="
    SERVE_LOG=$(mktemp)
    if command -v timeout >/dev/null 2>&1; then
        timeout --signal=KILL 300 cargo run --release --bin bitonic-tpu -- \
            serve-tcp --addr 127.0.0.1:0 > "$SERVE_LOG" 2>&1 &
    else
        cargo run --release --bin bitonic-tpu -- \
            serve-tcp --addr 127.0.0.1:0 > "$SERVE_LOG" 2>&1 &
    fi
    SERVE_PID=$!
    ADDR=""
    for _ in $(seq 1 120); do
        ADDR=$(grep -o 'listening on [0-9.:]*' "$SERVE_LOG" | head -1 | awk '{print $3}' || true)
        [ -n "$ADDR" ] && break
        if ! kill -0 "$SERVE_PID" 2>/dev/null; then
            echo "ERROR: serve-tcp exited before binding:" >&2
            cat "$SERVE_LOG" >&2
            exit 1
        fi
        sleep 0.5
    done
    if [ -z "$ADDR" ]; then
        echo "ERROR: serve-tcp never printed its listening address" >&2
        cat "$SERVE_LOG" >&2
        kill "$SERVE_PID" 2>/dev/null || true
        exit 1
    fi
    if command -v timeout >/dev/null 2>&1; then
        timeout --signal=KILL 120 cargo run --release --bin bitonic-tpu -- \
            loadgen --smoke --addr "$ADDR" --qps 200 --stop-server
    else
        cargo run --release --bin bitonic-tpu -- \
            loadgen --smoke --addr "$ADDR" --qps 200 --stop-server
    fi
    wait "$SERVE_PID"
    if ! grep -q "shutdown frame received" "$SERVE_LOG"; then
        echo "ERROR: serve-tcp did not drain on the Shutdown frame:" >&2
        cat "$SERVE_LOG" >&2
        exit 1
    fi
    rm -f "$SERVE_LOG"

    # The loadgen records must have landed in the trajectory with the
    # serving extras, and the report must render the serving section.
    python3 - <<'EOF'
import json
t = json.load(open("BENCH_trajectory.json"))
recs = [r for r in t["records"] if r["bench"] == "loadgen"]
assert recs, "no loadgen records in the trajectory"
# Extras are flattened onto the record object; per-class records carry a
# "class" key, the aggregate does not.
agg = [r for r in recs if "class" not in r]
assert agg, "no aggregate loadgen record"
for r in agg:
    for key in ("p50_ms", "p99_ms", "p999_ms", "shed_rate", "slo_miss_rate", "qps_achieved"):
        assert key in r, f"aggregate loadgen record lacks {key}: {sorted(r)}"
modes = {r.get("mode") for r in agg}
assert {"closed", "open"} <= modes, f"expected both pacing modes, got {modes}"
print(f"serving smoke: {len(recs)} loadgen record(s), modes={sorted(modes)}")
EOF
    cargo run --release --bin bitonic-tpu -- report
    if ! grep -q "Serving over the wire" RESULTS.md; then
        echo "ERROR: RESULTS.md lacks the serving section" >&2
        exit 1
    fi
    echo "== serving smoke clean: loopback E2E + wire round trip =="
else
    echo "== bench smoke skipped (SKIP_BENCH_SMOKE=1; CI runs it as its own step) =="
fi

if cargo fmt --version >/dev/null 2>&1; then
    echo "== cargo fmt --check =="
    cargo fmt --all -- --check
else
    echo "== cargo fmt unavailable; skipping format check =="
fi

# Python suite (skips itself per-module when JAX/hypothesis are absent,
# but needs numpy + pytest to collect at all).
if python3 -c "import numpy, pytest" >/dev/null 2>&1; then
    echo "== pytest python/tests =="
    (cd python && python3 -m pytest tests -q)
else
    echo "== numpy/pytest unavailable; skipping python tests =="
fi

echo "verify: OK"
