#!/usr/bin/env bash
# Tier-1 verification: build + tests, plus a format check when rustfmt
# is available (it is optional in the offline toolchain image).
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

# Bench smoke, time-bounded: the coordinator bench drives the real
# work-stealing scheduler and the row-parallel executor end to end, so a
# scheduler regression (deadlock, starvation, lost wakeup) fails here
# with a kill instead of hanging CI silently; the ablation bench drives
# the fused launch programs (Basic/Semi/Optimized) on the real executor,
# so a fusion regression (wrong pass count, hung interpreter) fails the
# same way. CI runs these as their own steps and sets SKIP_BENCH_SMOKE=1
# here to avoid the double run.
if [ "${SKIP_BENCH_SMOKE:-0}" != "1" ]; then
    # Remove any stale trajectory first: the existence check below must
    # prove THIS run wrote it, not a previous one (the file is gitignored
    # and lingers in the working tree).
    rm -f BENCH_ablation.json
    for smoke in coordinator ablation; do
        echo "== bench smoke: ${smoke} (timeout-bounded) =="
        if command -v timeout >/dev/null 2>&1; then
            timeout --signal=KILL 300 cargo bench --bench "${smoke}"
        else
            cargo bench --bench "${smoke}"
        fi
    done
    # The ablation bench must leave the machine-readable trajectory
    # behind (rows/sec, passes, interleaved speedup, autotuned config) —
    # future PRs compare against it instead of re-deriving baselines.
    if [ ! -f BENCH_ablation.json ]; then
        echo "ERROR: ablation bench did not write BENCH_ablation.json" >&2
        exit 1
    fi
    echo "== BENCH_ablation.json written =="
else
    echo "== bench smoke skipped (SKIP_BENCH_SMOKE=1; CI runs it as its own step) =="
fi

if cargo fmt --version >/dev/null 2>&1; then
    echo "== cargo fmt --check =="
    cargo fmt --all -- --check
else
    echo "== cargo fmt unavailable; skipping format check =="
fi

# Python suite (skips itself per-module when JAX/hypothesis are absent,
# but needs numpy + pytest to collect at all).
if python3 -c "import numpy, pytest" >/dev/null 2>&1; then
    echo "== pytest python/tests =="
    (cd python && python3 -m pytest tests -q)
else
    echo "== numpy/pytest unavailable; skipping python tests =="
fi

echo "verify: OK"
