//! Quickstart: the 60-second tour of the public API.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use bitonic_tpu::runtime::{spawn_device_host, Key};
use bitonic_tpu::sort::network::{Network, Variant};
use bitonic_tpu::sort::{bitonic_sort, is_sorted, quicksort};
use bitonic_tpu::workload::{Distribution, Generator};

fn main() -> bitonic_tpu::Result<()> {
    // 1. Generate a workload (the paper's: uniform 32-bit integers).
    let mut gen = Generator::new(42);
    let keys = gen.u32s(10_000, Distribution::Uniform);

    // 2. CPU baselines — the paper's two CPU columns.
    let mut a = keys.clone();
    quicksort(&mut a);
    let mut b = keys.clone();
    b.resize(keys.len().next_power_of_two(), u32::MAX);
    bitonic_sort(&mut b);
    b.truncate(keys.len());
    assert_eq!(a, b, "quicksort and bitonic sort must agree");
    println!("CPU: quicksort and bitonic sort agree on {} keys", a.len());

    // 3. The bitonic network itself (paper Fig. 2 / §3.2 closed forms).
    let net = Network::new(1 << 20);
    println!(
        "n=2^20 network: {} steps, {} compare-exchanges",
        net.step_count(),
        net.compare_exchange_count()
    );
    for v in Variant::ALL {
        println!(
            "  {:>9}: {} kernel launches at block=4096",
            v.name(),
            net.launches(v, 4096).len()
        );
    }

    // 4. The device path: AOT-compiled Pallas kernels via PJRT.
    let (handle, manifest) = spawn_device_host(bitonic_tpu::runtime::default_artifacts_dir())?;
    let metas = manifest.size_classes(Variant::Optimized);
    let meta = metas.first().expect("no artifacts — run `python -m compile.aot`");
    println!(
        "device: sorting a ({}, {}) batch with the '{}' artifact…",
        meta.batch, meta.n, meta.name
    );
    let rows = gen.u32s(meta.batch * meta.n, Distribution::Uniform);
    let sorted = handle.sort_u32(Key::of(meta), rows)?;
    for r in 0..meta.batch {
        assert!(is_sorted(&sorted[r * meta.n..(r + 1) * meta.n]));
    }
    println!("device: all {} rows sorted — quickstart OK", meta.batch);
    Ok(())
}
