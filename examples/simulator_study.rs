//! Simulator deep-dive (DESIGN.md E7 companion): where does the time go,
//! per variant, and what does the transaction-level trace say about
//! coalescing and bank conflicts — the quantitative story behind the
//! paper's two optimizations.
//!
//! ```bash
//! cargo run --release --offline --example simulator_study
//! ```

use bitonic_tpu::sim::trace::{trace_global_step, trace_shared_step};
use bitonic_tpu::sim::{calibrate_from_table1, simulate};
use bitonic_tpu::sort::network::{Network, Step, Variant};
use bitonic_tpu::util::table::{fmt_size, Table};

fn main() {
    let cal = calibrate_from_table1();
    let dev = cal.device;

    // --- 1. cost breakdown per variant ---------------------------------
    println!("== cost breakdown (calibrated K10 model), n = 16M u32 ==");
    let mut t = Table::new(vec![
        "variant", "launches", "launch ms", "gmem ms", "shmem ms", "alu ms", "total ms",
    ]);
    for v in Variant::ALL {
        let r = simulate(&dev, v, 16 << 20, 4);
        t.row(vec![
            v.name().to_string(),
            r.launches.to_string(),
            format!("{:.2}", r.t_launch * 1e3),
            format!("{:.2}", r.t_gmem * 1e3),
            format!("{:.2}", r.t_shmem * 1e3),
            format!("{:.2}", r.t_alu * 1e3),
            format!("{:.2}", r.total_ms()),
        ]);
    }
    println!("{}", t.render());
    println!("→ optimization 1 & 2 attack the gmem+launch terms; the ALU term is invariant.\n");

    // --- 2. why pass count, not coalescing, is the lever ----------------
    println!("== transaction trace: global step coalescing, n = 1M ==");
    let n = 1 << 20;
    let mut t = Table::new(vec!["stride", "gmem transactions", "ideal", "divergent warps"]);
    let ideal = 2 * 2 * (n / 2) / 32;
    for log_j in [0u32, 2, 5, 10, 16, 19] {
        let stride = 1usize << log_j;
        let c = trace_global_step(&dev, n, Step { phase_len: 2 * stride, stride }, 4);
        t.row(vec![
            format!("2^{log_j}"),
            c.gmem_transactions.to_string(),
            ideal.to_string(),
            c.divergent.to_string(),
        ]);
    }
    println!("{}", t.render());
    println!("→ every stride is within 2× of ideal streaming transactions: coalescing was never the problem; the number of *passes* was.\n");

    // --- 3. shared-memory bank behaviour --------------------------------
    println!("== shared-memory bank conflicts per warp-step (block = 4096 keys) ==");
    let mut t = Table::new(vec!["stride", "u32 conflicts", "u64 conflicts"]);
    for log_j in [0u32, 1, 3, 4, 5, 8, 11] {
        let stride = 1usize << log_j;
        let s = Step { phase_len: 2 * stride, stride };
        t.row(vec![
            format!("2^{log_j}"),
            trace_shared_step(&dev, 4096, s, 4).bank_conflicts.to_string(),
            trace_shared_step(&dev, 4096, s, 8).bank_conflicts.to_string(),
        ]);
    }
    println!("{}", t.render());
    println!("→ strides < warp hit 2-way conflicts; 64-bit keys (paper §6 future work) double them.\n");

    // --- 4. block-size ablation ------------------------------------------
    println!("== block-size ablation: launches at n = 16M ==");
    let net = Network::new(16 << 20);
    let mut t = Table::new(vec!["block (keys)", "semi launches", "optimized launches"]);
    for log_b in [8u32, 10, 12, 13, 14] {
        let block = 1usize << log_b;
        t.row(vec![
            fmt_size(block),
            net.launches(Variant::Semi, block).len().to_string(),
            net.launches(Variant::Optimized, block).len().to_string(),
        ]);
    }
    println!("{}", t.render());
    println!("→ bigger shared tiles monotonically cut launches — until the 48 KiB shared-memory budget caps block at 4096 u32 keys (K10).");
}
