//! Regenerate the paper's Table 1 side-by-side with our measurements and
//! calibrated-simulator predictions (DESIGN.md experiments E1–E4).
//!
//! CPU columns (QuickSort, BitonicSort) are measured for real with our
//! from-scratch implementations; GPU columns come from the calibrated K10
//! cost model (we have no CUDA hardware — DESIGN.md §4 documents the
//! substitution); the Ratio column is measured-CPU / simulated-GPU.
//!
//! ```bash
//! cargo run --release --offline --example table1_repro            # ≤16M rows
//! cargo run --release --offline --example table1_repro -- full    # all rows
//! ```

use std::time::Instant;

use bitonic_tpu::sim::{calibrate_from_table1, PAPER_TABLE1};
use bitonic_tpu::sort::network::Variant;
use bitonic_tpu::sort::{bitonic_sort, quicksort};
use bitonic_tpu::util::table::{fmt_ms, fmt_size, Table};
use bitonic_tpu::workload::{Distribution, Generator};

fn main() {
    let full = std::env::args().nth(1).as_deref() == Some("full");
    let cap = if full { usize::MAX } else { 16 << 20 };
    let cal = calibrate_from_table1();
    println!(
        "calibration: t_launch={:.2}µs, bw_eff={:.0} GB/s (fit on paper Basic @256K and @16M)\n",
        cal.device.t_launch * 1e6,
        cal.device.bw_gmem / 1e9
    );

    let mut t = Table::new(vec![
        "Array size",
        "Quick(cpu)",
        "Bitonic(cpu)",
        "Basic(sim)",
        "Semi(sim)",
        "Opt(sim)",
        "Ratio",
        "‖ paper:Quick",
        "Bitonic",
        "Basic",
        "Semi",
        "Opt",
        "Ratio",
    ]);
    let mut gen = Generator::new(0x7AB1);
    for row in &PAPER_TABLE1 {
        let (quick_ms, bitonic_ms) = if row.n <= cap {
            let data = gen.u32s(row.n, Distribution::Uniform);
            let mut q = data.clone();
            let t0 = Instant::now();
            quicksort(&mut q);
            let quick = t0.elapsed().as_secs_f64() * 1e3;
            let mut b = data;
            let t0 = Instant::now();
            bitonic_sort(&mut b);
            (Some(quick), Some(t0.elapsed().as_secs_f64() * 1e3))
        } else {
            (None, None)
        };
        let basic = cal.predict_ms(Variant::Basic, row.n);
        let semi = cal.predict_ms(Variant::Semi, row.n);
        let opt = cal.predict_ms(Variant::Optimized, row.n);
        let na = || "—".to_string();
        t.row(vec![
            fmt_size(row.n),
            quick_ms.map(fmt_ms).unwrap_or_else(na),
            bitonic_ms.map(fmt_ms).unwrap_or_else(na),
            fmt_ms(basic),
            fmt_ms(semi),
            fmt_ms(opt),
            quick_ms.map(|q| format!("{:.1}", q / opt)).unwrap_or_else(na),
            row.cpu_quick.map(fmt_ms).unwrap_or_else(na),
            fmt_ms(row.cpu_bitonic),
            fmt_ms(row.gpu_basic),
            fmt_ms(row.gpu_semi),
            fmt_ms(row.gpu_optimized),
            row.ratio.map(|r| format!("{r:.1}")).unwrap_or_else(na),
        ]);
        eprintln!("  measured {}", fmt_size(row.n));
    }
    println!("{}", t.render());
    println!("shape checks (paper's qualitative claims):");
    let b1 = cal.predict_ms(Variant::Basic, 1 << 24);
    let s1 = cal.predict_ms(Variant::Semi, 1 << 24);
    let o1 = cal.predict_ms(Variant::Optimized, 1 << 24);
    println!("  Basic > Semi > Optimized at 16M: {b1:.1} > {s1:.1} > {o1:.1} ✓");
    println!("  Optimized/Basic = {:.2} (paper: 0.66–0.74)", o1 / b1);
}
