//! Render the bitonic sorting network — the programmatic regeneration of
//! the paper's Figure 2 (n = 8), for any power-of-two n.
//!
//! ```bash
//! cargo run --release --offline --example network_viz -- 16
//! ```

use bitonic_tpu::sort::network::{Network, Variant};

fn main() -> bitonic_tpu::Result<()> {
    let n: usize = std::env::args()
        .nth(1)
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(8);
    let net = Network::new(n);
    println!(
        "Bitonic sorting network, n={n}: {} phases, {} steps, {} compare-exchange ops",
        net.log2n(),
        net.step_count(),
        net.compare_exchange_count()
    );
    println!("(paper Fig. 2 is the n=8 instance; ↑ = min-up comparator, ↓ = max-up)\n");

    // Wire diagram: one column per step, one row per element.
    let mut columns: Vec<Vec<String>> = Vec::new();
    for step in net.steps() {
        let mut col = vec![String::from("│"); n];
        for (a, b, up) in net.step_pairs(step) {
            col[a] = if up { "┌".into() } else { "└".into() };
            col[b] = if up { "┘".into() } else { "┐".into() };
            for wire in col.iter_mut().take(b).skip(a + 1) {
                *wire = "┼".into();
            }
        }
        columns.push(col);
    }
    for row in 0..n {
        let line: Vec<&str> = columns.iter().map(|c| c[row].as_str()).collect();
        println!("{row:>3} ─{}─", line.join("──"));
    }

    println!("\nLaunch schedules (block = 4 keys for illustration):");
    for v in Variant::ALL {
        let launches = net.launches(v, 4);
        println!("  {:>9}: {:2} launches — {:?}…", v.name(), launches.len(),
                 launches.first());
    }
    Ok(())
}
