//! Quick solo-run perf probe used by the §Perf pass (EXPERIMENTS.md):
//! measures the CPU baselines back-to-back without the bench harness so
//! regressions are visible in seconds on a noisy box.
//!
//! ```bash
//! cargo run --release --offline --example perf_probe
//! ```

use std::time::Instant;

use bitonic_tpu::workload::{Distribution, Generator};

fn main() {
    let mut gen = Generator::new(1);
    let n = 1 << 20;
    println!("n = 2^20 u32 uniform; three runs each (ms):");
    for run in 0..3 {
        let data = gen.u32s(n, Distribution::Uniform);

        let mut a = data.clone();
        let t0 = Instant::now();
        bitonic_tpu::sort::quicksort(&mut a);
        let ours = t0.elapsed().as_secs_f64() * 1e3;

        let mut b = data.clone();
        let t0 = Instant::now();
        b.sort_unstable();
        let std_ms = t0.elapsed().as_secs_f64() * 1e3;

        let mut c = data.clone();
        let t0 = Instant::now();
        bitonic_tpu::sort::bitonic_sort(&mut c);
        let bit = t0.elapsed().as_secs_f64() * 1e3;

        let mut d = data.clone();
        let t0 = Instant::now();
        bitonic_tpu::sort::bitonic_sort_parallel(&mut d, 8);
        let bitp = t0.elapsed().as_secs_f64() * 1e3;

        assert_eq!(a, b);
        assert_eq!(a, c);
        assert_eq!(a, d);
        println!(
            "  run {run}: quicksort(ours) {ours:7.1}  std {std_ms:7.1}  bitonic {bit:7.1}  bitonic-par8 {bitp:7.1}"
        );
    }
    println!(
        "cores visible: {}",
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(0)
    );
}
