//! End-to-end serving driver (DESIGN.md experiment E10) — the full stack
//! on a real workload: open-loop clients with mixed request sizes →
//! admission gate → size-class router → dynamic batcher → PJRT-compiled
//! Pallas artifacts → responses, with latency/throughput reported the way
//! a serving paper would.
//!
//! ```bash
//! cargo run --release --example sort_service
//! ```
//!
//! The run is recorded in EXPERIMENTS.md §E10.

use std::sync::Arc;
use std::time::{Duration, Instant};

use bitonic_tpu::coordinator::{
    BatchSorter, RegistrySorter, Service, ServiceConfig, SortRequest,
};
use bitonic_tpu::runtime::spawn_device_host;
use bitonic_tpu::sort::network::Variant;
use bitonic_tpu::sort::is_sorted;
use bitonic_tpu::util::metrics::Histogram;
use bitonic_tpu::workload::{Distribution, Generator};

fn main() -> bitonic_tpu::Result<()> {
    let requests: usize = std::env::args()
        .nth(1)
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(400);
    let clients = 8usize;

    // --- bring the stack up -------------------------------------------
    let t0 = Instant::now();
    let (handle, manifest) = spawn_device_host(bitonic_tpu::runtime::default_artifacts_dir())?;
    let classes = manifest.size_classes(Variant::Optimized);
    println!(
        "loaded manifest: {} artifacts, {} optimized size classes",
        manifest.entries.len(),
        classes.len()
    );
    handle.warm_up(Variant::Optimized)?;
    println!(
        "compiled {} executables in {:.1}s",
        handle.compiled_count()?,
        t0.elapsed().as_secs_f64()
    );
    let sorters: Vec<Arc<dyn BatchSorter>> = classes
        .iter()
        .map(|m| Arc::new(RegistrySorter::new(handle.clone(), m)) as Arc<dyn BatchSorter>)
        .collect();
    let svc = Service::new(sorters, ServiceConfig::default());

    // --- drive it ------------------------------------------------------
    // Mixed sizes: 60% small (≤1K), 30% medium (≤16K), 10% large (≤64K) —
    // a plausible service mix; all sorted correctness-checked.
    let per_client = requests / clients;
    let wall = Instant::now();
    let device_lat = Arc::new(Histogram::new());
    let cpu_lat = Arc::new(Histogram::new());
    std::thread::scope(|scope| {
        for c in 0..clients {
            let svc = &svc;
            let device_lat = Arc::clone(&device_lat);
            let cpu_lat = Arc::clone(&cpu_lat);
            scope.spawn(move || {
                let mut gen = Generator::new(0x5EED + c as u64);
                for i in 0..per_client {
                    let roll = gen.u32s(1, Distribution::Uniform)[0] % 10;
                    let max = if roll < 6 {
                        1 << 10
                    } else if roll < 9 {
                        1 << 14
                    } else {
                        1 << 16
                    };
                    let len = 1 + gen.u32s(1, Distribution::Uniform)[0] as usize % max;
                    let keys = gen.u32s(len, Distribution::Uniform);
                    let t = Instant::now();
                    match svc.sort_blocking(SortRequest::new((c * per_client + i) as u64, keys)) {
                        Ok(resp) => {
                            assert!(is_sorted(&resp.keys), "response unsorted!");
                            match resp.path {
                                bitonic_tpu::coordinator::request::ExecPath::Device => {
                                    device_lat.record(t.elapsed())
                                }
                                bitonic_tpu::coordinator::request::ExecPath::Cpu => {
                                    cpu_lat.record(t.elapsed())
                                }
                            }
                        }
                        Err(_) => { /* shed under burst — counted below */ }
                    }
                }
            });
        }
    });
    let elapsed = wall.elapsed();

    // --- report --------------------------------------------------------
    let st = svc.stats();
    let served = st.admitted.get() - (st.shed.get().min(st.admitted.get()));
    println!("\n== sort_service end-to-end report ==");
    println!("requests      : {requests} over {clients} closed-loop clients");
    println!(
        "wall time     : {:.2}s  ({:.0} req/s)",
        elapsed.as_secs_f64(),
        served as f64 / elapsed.as_secs_f64()
    );
    println!("device path   : {}", device_lat.summary());
    println!("cpu fallback  : {}", cpu_lat.summary());
    println!(
        "device batches: {} (mean occupancy {:.2} rows)",
        st.device_batches.get(),
        st.device_rows.get() as f64 / st.device_batches.get().max(1) as f64
    );
    println!("shed          : {}", st.shed.get());
    assert!(st.device_batches.get() > 0, "device path never exercised!");
    println!("\nall responses verified sorted — E2E OK");
    Ok(())
}

fn _unused(_: Duration) {}
