//! `bitonic-tpu` CLI: the leader entrypoint.
//!
//! Subcommands map onto DESIGN.md's experiments:
//!
//! * `sort`      — sort one generated workload through a chosen path
//! * `serve`     — run the sort service on a synthetic request stream
//! * `serve-tcp` — expose the sort service over TCP (length-prefixed frames)
//! * `loadgen`   — drive a `serve-tcp` endpoint with mixed serving traffic
//! * `table1`    — regenerate the paper's Table 1 (also in benches)
//! * `simulate`  — print calibrated GPU-model predictions
//! * `network`   — print the bitonic network (paper Fig. 2)
//! * `analyze`   — launch/pass counts per variant (structural perf model)
//! * `bench`     — the survey benchmark matrix → `BENCH_trajectory.json`
//! * `report`    — regenerate `RESULTS.md` from the trajectory
//! * `verify-plans` — static plan verifier + disjointness checker → `ANALYSIS.md`
//! * `gen-artifacts` — synthesize HLO artifact grids beyond the 64K fixture

use std::sync::Arc;
use std::time::{Duration, Instant};

use bitonic_tpu::bench::{
    matrix::{run_matrix, run_mega_cells, run_pass_ablation, DeviceCtx},
    render_results, run_loadgen, LoadMode, LoadgenConfig, MatrixConfig, Substrate, Trajectory,
};
use bitonic_tpu::coordinator::{
    NetClient, NetServer, NetServerConfig, RegistrySorter, Service, ServiceConfig, SortRequest,
};
use bitonic_tpu::runtime::{
    genart, spawn_device_host_discovered, tune, tune_tiles, ArtifactKind, DeviceHandle,
    HostConfig, Key, Manifest, PlanConfig, PlanPolicy, TileProfile, TuneRequest, TuningProfile,
};
use bitonic_tpu::sim::{calibrate_from_table1, PAPER_TABLE1};
use bitonic_tpu::sort::network::{Network, Variant};
use bitonic_tpu::sort::{bitonic_sort_padded, bitonic_sort_parallel_padded, quicksort, KernelChoice};
use bitonic_tpu::util::cli::Parser;
use bitonic_tpu::util::table::{fmt_ms, fmt_size, Table};
use bitonic_tpu::workload::{Distribution, Generator, TrafficMix};

fn main() -> bitonic_tpu::Result<()> {
    let parser = Parser::new("bitonic-tpu", "bitonic sort on the rust+JAX+Pallas stack")
        .command("sort", "sort one generated workload")
        .command("serve", "run the sort service on a synthetic stream")
        .command(
            "serve-tcp",
            "serve the sort service over TCP (length-prefixed binary protocol)",
        )
        .command(
            "loadgen",
            "drive a serve-tcp endpoint with mixed traffic; append latency/shed records",
        )
        .command("table1", "regenerate the paper's Table 1")
        .command("simulate", "GPU cost-model predictions")
        .command("network", "print the bitonic network (Fig. 2)")
        .command("analyze", "launch/pass counts per variant")
        .command("tune", "sweep plan configs on this host; write a tuning profile")
        .command("bench", "survey matrix: substrates × dists × dtypes × sizes → trajectory JSON")
        .command("report", "regenerate RESULTS.md from the bench trajectory")
        .command(
            "verify-plans",
            "statically prove plans sort + schedules are race-free; write ANALYSIS.md/.json",
        )
        .command("gen-data", "write a workload dataset file (.btsd)")
        .command(
            "gen-artifacts",
            "synthesize HLO artifact grids beyond the 64K fixture ceiling",
        )
        .opt("n", "array size (elements)", Some("65536"))
        .opt(
            "algo",
            "algorithm: quick|bitonic|bitonic-par|device|hybrid|hier",
            Some("device"),
        )
        .opt("variant", "device variant: basic|semi|optimized", Some("optimized"))
        .opt("dist", "workload distribution", Some("uniform"))
        .opt("artifacts", "artifacts directory (default: auto-discover)", None)
        .opt("requests", "serve: number of requests", Some("200"))
        .opt(
            "threads",
            "worker threads: bitonic-par chunks, device-host row pool, serve workers \
             (default: tuned profile, else 8)",
            None,
        )
        .opt(
            "merge-threads",
            "hier: merge-phase workers for the splitter-partitioned parallel merge \
             (default: tile profile, else 1 = serial loser-tree merge)",
            None,
        )
        .opt(
            "plan-variant",
            "executor launch fusion: basic|semi|optimized (default optimized)",
            None,
        )
        .opt(
            "plan-block",
            "executor fused-tile block in keys, power of two >= 2 (default 4096; \
             explicit value pins it over the tuning profile)",
            None,
        )
        .opt(
            "plan-interleave",
            "batch-interleave width R, rows per interleaved tile (default 8, 1 = scalar; \
             explicit value pins it over the tuning profile)",
            None,
        )
        .opt(
            "kernel",
            "comparator ISA: auto|scalar|portable|avx2 (default auto = explicit SIMD when \
             built+detected; explicit value pins it over the tuning profile)",
            None,
        )
        .opt(
            "profile",
            "tuning profile TSV (default: <artifacts>/autotune.tsv when present)",
            None,
        )
        .opt("tune-rows", "tune: rows per measured batch", None)
        .opt(
            "trajectory",
            "bench/report: trajectory JSON path (default: $BENCH_TRAJECTORY_JSON \
             or BENCH_trajectory.json at the workspace root)",
            None,
        )
        .opt("out", "report: output markdown path", Some("RESULTS.md"))
        .opt(
            "diff",
            "report: older trajectory JSON to diff against instead of rendering \
             (per-cell tolerance compare at equal env stamps)",
            None,
        )
        .opt(
            "exhaustive-cap",
            "verify-plans: largest n proven exhaustively by the 0-1 induction \
             (default 1024; larger targets get sampled checks + WARN)",
            None,
        )
        .opt(
            "analysis-out",
            "verify-plans: markdown report path (default: $ANALYSIS_MD or \
             ANALYSIS.md at the workspace root; JSON lands beside it)",
            None,
        )
        .opt(
            "gen-dir",
            "gen-artifacts: output directory (default <artifacts>/generated; \
             smoke: <artifacts>/generated-smoke)",
            None,
        )
        .opt("seed", "workload seed", Some("42"))
        .opt(
            "addr",
            "serve-tcp: listen address (default 127.0.0.1:7071); \
             loadgen: target endpoint (default: self-host a loopback server)",
            None,
        )
        .opt(
            "qps",
            "loadgen: open-loop target rate across all connections \
             (0 = closed loop, one request in flight per connection)",
            Some("0"),
        )
        .opt("duration-secs", "loadgen: wall-clock run length", Some("10"))
        .opt("conns", "loadgen: concurrent client connections", Some("4"))
        .opt("mix", "loadgen: traffic mix (serving|smoke)", Some("serving"))
        .opt(
            "max-in-flight",
            "serve-tcp/loadgen self-host: service admission bound",
            None,
        )
        .opt(
            "max-keys",
            "serve-tcp: largest key count accepted per request frame",
            None,
        )
        .opt(
            "read-timeout-ms",
            "serve-tcp: close connections idle longer than this",
            Some("30000"),
        )
        .opt(
            "write-timeout-ms",
            "serve-tcp: socket write timeout for stalled readers",
            Some("10000"),
        )
        .flag(
            "stop-server",
            "loadgen: send a Shutdown frame to the target when done",
        )
        .flag("no-profile", "ignore any tuning profile")
        .flag("gate", "report --diff: exit non-zero when any cell slowed down more than 2x")
        .flag(
            "smoke",
            "tune/bench/gen-artifacts/loadgen: tiny CI-sized sweep",
        )
        .flag(
            "hier",
            "tune: sweep the hierarchical tile axis instead (writes autotune_hier.tsv)",
        )
        .flag("verbose", "more output");
    let args = parser.parse_env()?;

    match args.command.as_deref() {
        Some("sort") => cmd_sort(&args),
        Some("serve") => cmd_serve(&args),
        Some("serve-tcp") => cmd_serve_tcp(&args),
        Some("loadgen") => cmd_loadgen(&args),
        Some("table1") => cmd_table1(&args),
        Some("simulate") => cmd_simulate(),
        Some("network") => cmd_network(&args),
        Some("analyze") => cmd_analyze(&args),
        Some("tune") => cmd_tune(&args),
        Some("bench") => cmd_bench(&args),
        Some("report") => cmd_report(&args),
        Some("verify-plans") => cmd_verify_plans(&args),
        Some("gen-data") => cmd_gen_data(&args),
        Some("gen-artifacts") => cmd_gen_artifacts(&args),
        _ => {
            println!("{}", parser.usage());
            Ok(())
        }
    }
}

/// `--artifacts DIR` if given, else auto-discovery (env var, ./artifacts,
/// the checked-in fixture).
fn artifacts_dir(args: &bitonic_tpu::util::cli::Args) -> std::path::PathBuf {
    args.get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(bitonic_tpu::runtime::default_artifacts_dir)
}

/// `--plan-variant`/`--plan-block`/`--plan-interleave`/`--kernel`: the
/// base launch program + execution geometry configuration (which of the
/// paper's §4 optimizations run, how wide the batch-interleaved tiles
/// are, and which comparator ISA executes the sweeps). Fields not given
/// fall back to the defaults.
fn plan_base(args: &bitonic_tpu::util::cli::Args) -> bitonic_tpu::Result<PlanConfig> {
    let defaults = PlanConfig::default();
    let variant = match args.get("plan-variant") {
        Some(s) => Variant::parse(s)
            .ok_or_else(|| bitonic_tpu::err!("bad --plan-variant (basic|semi|optimized)"))?,
        None => defaults.variant,
    };
    let block: usize = args.parsed_or("plan-block", defaults.block)?;
    bitonic_tpu::ensure!(
        block.is_power_of_two() && block >= 2,
        "--plan-block must be a power of two >= 2, got {block}"
    );
    let interleave: usize = args.parsed_or("plan-interleave", defaults.interleave)?;
    bitonic_tpu::ensure!(
        interleave >= 1,
        "--plan-interleave must be >= 1 (1 = scalar execution)"
    );
    let kernel = match args.get("kernel") {
        Some(s) => {
            let choice = KernelChoice::parse(s)
                .ok_or_else(|| bitonic_tpu::err!("bad --kernel (auto|scalar|portable|avx2)"))?;
            // Reject an unavailable fixed ISA here, with the flag named,
            // instead of deep inside executor compilation.
            choice.validate()?;
            choice
        }
        None => defaults.kernel,
    };
    Ok(PlanConfig { variant, block, interleave, kernel })
}

/// The full plan policy the device host runs: the base config, refined
/// per size class by a tuning profile when one is available (`--profile`
/// path, else `<artifacts>/autotune.tsv`, suppressed by `--no-profile`).
/// Fields the operator set explicitly are pinned — the profile never
/// overrides a flag.
fn plan_policy(
    args: &bitonic_tpu::util::cli::Args,
    artifacts: &std::path::Path,
) -> bitonic_tpu::Result<PlanPolicy> {
    let base = plan_base(args)?;
    let profile = if args.flag("no-profile") {
        None
    } else if let Some(path) = args.get("profile") {
        Some(TuningProfile::load(path)?)
    } else {
        let path = TuningProfile::default_path(artifacts);
        if path.exists() {
            eprintln!("using tuning profile {path:?} (suppress with --no-profile)");
            Some(TuningProfile::load(&path)?)
        } else {
            None
        }
    };
    Ok(PlanPolicy {
        base,
        profile,
        pin_block: args.get("plan-block").is_some(),
        pin_interleave: args.get("plan-interleave").is_some(),
        pin_kernel: args.get("kernel").is_some(),
    })
}

/// `--threads`, falling back to the tuning profile's recommendation and
/// finally to 8.
fn pick_threads(
    args: &bitonic_tpu::util::cli::Args,
    policy: &PlanPolicy,
) -> bitonic_tpu::Result<usize> {
    Ok(match args.get_parsed::<usize>("threads")? {
        Some(t) => t,
        None => policy.tuned_threads().unwrap_or(8),
    })
}

fn cmd_sort(args: &bitonic_tpu::util::cli::Args) -> bitonic_tpu::Result<()> {
    let n: usize = args.parsed_or("n", 65536)?;
    let seed: u64 = args.parsed_or("seed", 42)?;
    let dist = Distribution::parse(&args.get_or("dist", "uniform"))
        .ok_or_else(|| bitonic_tpu::err!("unknown distribution"))?;
    let algo = args.get_or("algo", "device");
    let mut keys = Generator::new(seed).u32s(n, dist);
    let t0 = Instant::now();
    match algo.as_str() {
        "quick" => quicksort(&mut keys),
        "bitonic" => bitonic_sort_padded(&mut keys),
        "bitonic-par" => {
            let threads: usize = args.parsed_or("threads", 8)?;
            bitonic_sort_parallel_padded(&mut keys, threads);
        }
        "hybrid" => {
            let variant = Variant::parse(&args.get_or("variant", "optimized"))
                .ok_or_else(|| bitonic_tpu::err!("bad variant"))?;
            let dir = artifacts_dir(args);
            let plan = plan_policy(args, &dir)?;
            let threads = pick_threads(args, &plan)?;
            let (handle, manifest) =
                spawn_device_host_discovered(&dir, HostConfig { threads, plan })?;
            // A merged menu can reach 16M-row classes; cap the chunk at
            // the input's padded size so a small sort never round-trips
            // through a mega artifact.
            let chunk = manifest
                .size_classes(variant)
                .into_iter()
                .map(|m| m.n)
                .filter(|&c| c <= n.next_power_of_two().max(2))
                .max();
            let sorter = match chunk {
                Some(c) => bitonic_tpu::sort::HybridSorter::with_chunk(
                    handle, &manifest, variant, c,
                )?,
                None => bitonic_tpu::sort::HybridSorter::new(handle, &manifest, variant)?,
            };
            let stats = sorter.sort(&mut keys)?;
            eprintln!(
                "hybrid: chunk={} device_sorts={} device_merges={} cpu_merges={}",
                stats.chunk, stats.device_sorts, stats.device_merges, stats.cpu_merges
            );
        }
        "hier" => {
            let variant = Variant::parse(&args.get_or("variant", "optimized"))
                .ok_or_else(|| bitonic_tpu::err!("bad variant"))?;
            let dir = artifacts_dir(args);
            let plan = plan_policy(args, &dir)?;
            let threads = pick_threads(args, &plan)?;
            let (handle, manifest) =
                spawn_device_host_discovered(&dir, HostConfig { threads, plan })?;
            // Tile + merge parallelism: the tuned tile profile when one
            // exists (same --no-profile suppression as the plan profile),
            // else the cache-sized default pick. An explicit
            // --merge-threads pins the merge axis over the profile.
            let tile_path = TileProfile::default_path(&dir);
            let tuned = if !args.flag("no-profile") && tile_path.exists() {
                eprintln!("using tile profile {tile_path:?} (suppress with --no-profile)");
                TileProfile::load(&tile_path)?
                    .lookup_entry(n)
                    .map(|e| (e.tile, e.merge_threads))
            } else {
                None
            };
            let merge_threads = match args.get("merge-threads") {
                Some(raw) => {
                    let mt: usize = raw
                        .parse()
                        .map_err(|_| bitonic_tpu::err!("bad --merge-threads {raw}"))?;
                    bitonic_tpu::ensure!(mt >= 1, "--merge-threads must be >= 1");
                    mt
                }
                None => tuned.map_or(1, |(_, mt)| mt),
            };
            let sorter = match tuned {
                Some((tile, _)) => bitonic_tpu::sort::HierarchicalSorter::with_tile(
                    handle, &manifest, variant, tile,
                )?,
                None => bitonic_tpu::sort::HierarchicalSorter::new(handle, &manifest, variant)?,
            }
            .with_merge_threads(merge_threads);
            let stats = sorter.sort(&mut keys)?;
            eprintln!(
                "hier: tile={} tiles={} device_dispatches={} merge_threads={} merge_parts={} \
                 phases tile_sort={} partition={} merge={}",
                stats.tile,
                stats.tiles,
                stats.device_dispatches,
                stats.merge_threads,
                stats.merge_parts,
                fmt_ms(stats.tile_sort_ms),
                fmt_ms(stats.partition_ms),
                fmt_ms(stats.merge_ms)
            );
        }
        "device" => {
            let variant = Variant::parse(&args.get_or("variant", "optimized"))
                .ok_or_else(|| bitonic_tpu::err!("bad variant"))?;
            let dir = artifacts_dir(args);
            let plan = plan_policy(args, &dir)?;
            let threads = pick_threads(args, &plan)?;
            let (handle, manifest) =
                spawn_device_host_discovered(&dir, HostConfig { threads, plan })?;
            let padded = n.next_power_of_two();
            let meta = manifest
                .size_classes(variant)
                .into_iter()
                .find(|m| m.n >= padded)
                .ok_or_else(|| bitonic_tpu::err!("no artifact fits n={n}"))?
                .clone();
            let mut rows = keys.clone();
            rows.resize(meta.batch * meta.n, u32::MAX);
            let sorted = handle.sort_u32(Key::of(&meta), rows)?;
            keys = sorted[..n].to_vec();
        }
        other => bitonic_tpu::bail!("unknown algo {other}"),
    }
    let ms = t0.elapsed().as_secs_f64() * 1e3;
    bitonic_tpu::ensure!(
        bitonic_tpu::sort::is_sorted(&keys),
        "output not sorted — bug"
    );
    // FNV-1a over the sorted keys: two runs over the same (seed, dist,
    // n) must print the same digest whatever --kernel/--algo produced
    // them — the ISA equality smoke in scripts/verify.sh greps this.
    let mut digest = 0xcbf2_9ce4_8422_2325u64;
    for k in &keys {
        digest = (digest ^ u64::from(*k)).wrapping_mul(0x100_0000_01b3);
    }
    println!(
        "sorted {} keys ({}) via {algo} in {} ms [digest {digest:016x}]",
        n,
        dist.name(),
        fmt_ms(ms)
    );
    Ok(())
}

fn cmd_serve(args: &bitonic_tpu::util::cli::Args) -> bitonic_tpu::Result<()> {
    let requests: usize = args.parsed_or("requests", 200)?;
    let seed: u64 = args.parsed_or("seed", 42)?;
    let variant = Variant::parse(&args.get_or("variant", "optimized"))
        .ok_or_else(|| bitonic_tpu::err!("bad variant"))?;
    let dir = artifacts_dir(args);
    let plan = plan_policy(args, &dir)?;
    // The tuning profile's threads recommendation applies to the device
    // host's executor pool only — that is what the sweep measured. The
    // service's work-stealing worker count shares the explicit --threads
    // knob but never follows the profile: the tune does not benchmark
    // service-level concurrency, and one tuned `threads=1` entry must not
    // collapse the whole request plane to a single worker.
    let host_threads = pick_threads(args, &plan)?;
    let service_threads: usize = args.parsed_or("threads", 8)?;
    let (handle, manifest) =
        spawn_device_host_discovered(&dir, HostConfig { threads: host_threads, plan })?;
    println!(
        "warming {} artifacts… ({host_threads} executor / {service_threads} service threads)",
        manifest.size_classes(variant).len()
    );
    handle.warm_up(variant)?;
    let sorters: Vec<Arc<dyn bitonic_tpu::coordinator::BatchSorter>> = manifest
        .size_classes(variant)
        .into_iter()
        .map(|m| {
            Arc::new(RegistrySorter::new(handle.clone(), m))
                as Arc<dyn bitonic_tpu::coordinator::BatchSorter>
        })
        .collect();
    let svc = Service::new(
        sorters,
        ServiceConfig {
            threads: service_threads,
            ..ServiceConfig::default()
        },
    );

    let mut gen = Generator::new(seed);
    let t0 = Instant::now();
    let rxs: Vec<_> = (0..requests)
        .map(|i| {
            let len = 1 + gen.u32s(1, Distribution::Uniform)[0] as usize % 4096;
            let keys = gen.u32s(len, Distribution::Uniform);
            svc.submit(SortRequest::new(i as u64, keys)).ok()
        })
        .collect();
    let mut ok = 0;
    for rx in rxs.into_iter().flatten() {
        let resp = rx.recv()?;
        bitonic_tpu::ensure!(
            bitonic_tpu::sort::is_sorted(&resp.keys),
            "unsorted response"
        );
        ok += 1;
    }
    let wall = t0.elapsed().as_secs_f64();
    let st = svc.stats();
    println!(
        "served {ok}/{requests} in {:.2}s ({:.0} req/s) — latency {} — device batches {} (occupancy {:.2}) shed {} cpu-fallback {}",
        wall,
        ok as f64 / wall,
        st.latency.summary(),
        st.device_batches.get(),
        st.device_rows.get() as f64 / st.device_batches.get().max(1) as f64,
        st.shed.get(),
        st.cpu_fallbacks.get(),
    );
    Ok(())
}

/// Spawn the device host + warmed [`Service`] the way `serve` does —
/// shared by `serve-tcp` and the self-hosting `loadgen` path so both
/// front-ends sit on identical plumbing (same plan policy, same thread
/// split, same admission bound).
fn spawn_sort_service(
    args: &bitonic_tpu::util::cli::Args,
) -> bitonic_tpu::Result<(DeviceHandle, Arc<Service>)> {
    let variant = Variant::parse(&args.get_or("variant", "optimized"))
        .ok_or_else(|| bitonic_tpu::err!("bad variant"))?;
    let dir = artifacts_dir(args);
    let plan = plan_policy(args, &dir)?;
    // Same split as `serve`: the profile tunes the executor pool only,
    // never the service's work-stealing worker count.
    let host_threads = pick_threads(args, &plan)?;
    let service_threads: usize = args.parsed_or("threads", 8)?;
    let (handle, manifest) =
        spawn_device_host_discovered(&dir, HostConfig { threads: host_threads, plan })?;
    println!(
        "warming {} artifacts… ({host_threads} executor / {service_threads} service threads)",
        manifest.size_classes(variant).len()
    );
    handle.warm_up(variant)?;
    let sorters: Vec<Arc<dyn bitonic_tpu::coordinator::BatchSorter>> = manifest
        .size_classes(variant)
        .into_iter()
        .map(|m| {
            Arc::new(RegistrySorter::new(handle.clone(), m))
                as Arc<dyn bitonic_tpu::coordinator::BatchSorter>
        })
        .collect();
    let defaults = ServiceConfig::default();
    let max_in_flight: usize = args.parsed_or("max-in-flight", defaults.max_in_flight)?;
    let svc = Service::new(
        sorters,
        ServiceConfig {
            threads: service_threads,
            max_in_flight,
            ..defaults
        },
    );
    Ok((handle, svc))
}

/// Render the per-class half of a [`bitonic_tpu::coordinator::ServiceStats`]
/// snapshot as a table — printed by `serve-tcp` at drain time and by the
/// self-hosting `loadgen` path at teardown.
fn print_class_stats(svc: &Service) {
    let st = svc.stats();
    let mut table = Table::new(vec![
        "class n", "batch", "admitted", "shed", "batches", "rows", "slo miss", "latency",
    ]);
    for c in &st.classes {
        table.row(vec![
            c.n.to_string(),
            c.batch.to_string(),
            c.admitted.get().to_string(),
            c.shed.get().to_string(),
            c.batches.get().to_string(),
            c.rows.get().to_string(),
            c.slo_misses.get().to_string(),
            c.latency.summary(),
        ]);
    }
    println!("{}", table.render());
    println!(
        "aggregate: admitted {} shed {} cpu-fallback {} slo-miss {} — latency {}",
        st.admitted.get(),
        st.shed.get(),
        st.cpu_fallbacks.get(),
        st.slo_misses.get(),
        st.latency.summary(),
    );
}

/// `serve-tcp`: bind the length-prefixed binary protocol on `--addr`,
/// serve until a Shutdown frame arrives, then drain connections and
/// print transport + per-class service statistics.
fn cmd_serve_tcp(args: &bitonic_tpu::util::cli::Args) -> bitonic_tpu::Result<()> {
    let addr = args.get_or("addr", "127.0.0.1:7071");
    let config = NetServerConfig {
        max_keys: args
            .parsed_or("max-keys", bitonic_tpu::coordinator::net::DEFAULT_MAX_KEYS)?,
        read_timeout: Duration::from_millis(args.parsed_or("read-timeout-ms", 30_000)?),
        write_timeout: Duration::from_millis(args.parsed_or("write-timeout-ms", 10_000)?),
    };
    let (handle, svc) = spawn_sort_service(args)?;
    let mut server = NetServer::start(Arc::clone(&svc), &addr, config)?;
    // Greppable by scripts/verify.sh and CI, which parse the resolved
    // ephemeral port out of this line.
    println!(
        "listening on {} — stop with a Shutdown frame (loadgen --stop-server)",
        server.local_addr()
    );
    server.wait_shutdown();
    println!("shutdown frame received; draining connections…");
    server.shutdown();
    println!("transport: {}", server.stats().summary());
    print_class_stats(&svc);
    svc.shutdown();
    handle.shutdown();
    Ok(())
}

/// `loadgen`: drive a `serve-tcp` endpoint with the seeded traffic mix.
/// Without `--addr` it self-hosts a loopback server first, so
/// `bitonic-tpu loadgen --smoke` is a one-command E2E check. Appends
/// schema-valid `loadgen` records to the bench trajectory.
fn cmd_loadgen(args: &bitonic_tpu::util::cli::Args) -> bitonic_tpu::Result<()> {
    let seed: u64 = args.parsed_or("seed", 42)?;
    let smoke = args.flag("smoke");
    let mut cfg = if smoke {
        LoadgenConfig::smoke(seed)
    } else {
        let qps: f64 = args.parsed_or("qps", 0.0)?;
        LoadgenConfig {
            mode: if qps > 0.0 { LoadMode::Open { qps } } else { LoadMode::Closed },
            conns: args.parsed_or("conns", 4)?,
            duration: Duration::from_secs(args.parsed_or("duration-secs", 10)?),
            seed,
            mix: TrafficMix::parse(&args.get_or("mix", "serving"))
                .ok_or_else(|| bitonic_tpu::err!("bad --mix (serving|smoke)"))?,
            timeout: Duration::from_secs(30),
        }
    };
    // `--smoke --qps N` upgrades the smoke run to open-loop pacing so CI
    // exercises both modes without paying for a full-length run.
    if smoke {
        let qps: f64 = args.parsed_or("qps", 0.0)?;
        if qps > 0.0 {
            cfg.mode = LoadMode::Open { qps };
        }
    }

    // Self-host a loopback server when no target was given.
    let hosted = match args.get("addr") {
        Some(_) => None,
        None => {
            let (handle, svc) = spawn_sort_service(args)?;
            let server = NetServer::start(
                Arc::clone(&svc),
                "127.0.0.1:0",
                NetServerConfig::default(),
            )?;
            println!("self-hosting loopback server on {}", server.local_addr());
            Some((handle, svc, server))
        }
    };
    let addr = match &hosted {
        Some((_, _, server)) => server.local_addr().to_string(),
        None => args.get("addr").unwrap().to_string(),
    };

    let report = run_loadgen(&addr, &cfg)?;
    println!("{}", report.render());

    if args.flag("stop-server") && hosted.is_none() {
        let mut client = NetClient::connect(addr.as_str())?;
        client.shutdown_server(seed)?;
        println!("sent shutdown frame to {addr}");
    }
    if let Some((handle, svc, mut server)) = hosted {
        server.shutdown();
        print_class_stats(&svc);
        svc.shutdown();
        handle.shutdown();
    }

    bitonic_tpu::ensure!(
        report.protocol_errors() == 0,
        "loadgen saw {} protocol errors/rejections — the wire path is broken",
        report.protocol_errors()
    );
    let path = trajectory_path(args);
    let records = report.to_records();
    let added = records.len();
    let total = Trajectory::append_to(&path, records)?;
    println!("appended {added} loadgen record(s) to {path:?} ({total} total)");
    Ok(())
}

fn cmd_table1(args: &bitonic_tpu::util::cli::Args) -> bitonic_tpu::Result<()> {
    let verbose = args.flag("verbose");
    let cal = calibrate_from_table1();
    let mut table = Table::new(vec![
        "Array size",
        "QuickSort(cpu)",
        "BitonicSort(cpu)",
        "Basic(sim)",
        "Semi(sim)",
        "Optimized(sim)",
        "Ratio",
        "paper:Basic",
        "paper:Opt",
        "paper:Ratio",
    ]);
    let mut gen = Generator::new(7);
    for row in &PAPER_TABLE1 {
        // CPU columns measured for real up to 16M to keep runtime sane;
        // larger sizes are skipped here (benches/table1.rs measures all).
        let measure_cap = 16 << 20;
        let (quick_ms, bitonic_ms) = if row.n <= measure_cap {
            let data = gen.u32s(row.n, Distribution::Uniform);
            let mut q = data.clone();
            let t0 = Instant::now();
            quicksort(&mut q);
            let quick = t0.elapsed().as_secs_f64() * 1e3;
            let mut b = data;
            let t0 = Instant::now();
            bitonic_sort_padded(&mut b);
            (quick, t0.elapsed().as_secs_f64() * 1e3)
        } else {
            (f64::NAN, f64::NAN)
        };
        let basic = cal.predict_ms(Variant::Basic, row.n);
        let semi = cal.predict_ms(Variant::Semi, row.n);
        let opt = cal.predict_ms(Variant::Optimized, row.n);
        table.row(vec![
            fmt_size(row.n),
            if quick_ms.is_nan() { "—".into() } else { fmt_ms(quick_ms) },
            if bitonic_ms.is_nan() { "—".into() } else { fmt_ms(bitonic_ms) },
            fmt_ms(basic),
            fmt_ms(semi),
            fmt_ms(opt),
            if quick_ms.is_nan() { "—".into() } else { format!("{:.1}", quick_ms / opt) },
            fmt_ms(row.gpu_basic),
            fmt_ms(row.gpu_optimized),
            row.ratio.map(|r| format!("{r:.1}")).unwrap_or("—".into()),
        ]);
        if verbose {
            eprintln!("row {} done", fmt_size(row.n));
        }
    }
    println!("{}", table.render());
    println!("(sim columns: calibrated K10 cost model — DESIGN.md §4; CPU columns measured here)");
    Ok(())
}

fn cmd_simulate() -> bitonic_tpu::Result<()> {
    let cal = calibrate_from_table1();
    println!(
        "calibrated: t_launch={:.2}µs bw_eff={:.0} GB/s (fit on Basic @256K,16M)",
        cal.device.t_launch * 1e6,
        cal.device.bw_gmem / 1e9
    );
    let mut t = Table::new(vec![
        "n", "Basic", "Semi", "Optimized", "paper:Basic", "paper:Semi", "paper:Opt",
    ]);
    for row in &PAPER_TABLE1 {
        t.row(vec![
            fmt_size(row.n),
            fmt_ms(cal.predict_ms(Variant::Basic, row.n)),
            fmt_ms(cal.predict_ms(Variant::Semi, row.n)),
            fmt_ms(cal.predict_ms(Variant::Optimized, row.n)),
            fmt_ms(row.gpu_basic),
            fmt_ms(row.gpu_semi),
            fmt_ms(row.gpu_optimized),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

fn cmd_network(args: &bitonic_tpu::util::cli::Args) -> bitonic_tpu::Result<()> {
    let n: usize = args.parsed_or("n", 8)?;
    let net = Network::new(n);
    println!(
        "bitonic network, n={n}: {} phases, {} steps, {} compare-exchanges",
        net.log2n(),
        net.step_count(),
        net.compare_exchange_count()
    );
    for (p, phase) in net.phases().enumerate() {
        for step in phase.steps() {
            let pairs = net.step_pairs(step);
            let rendering: Vec<String> = pairs
                .iter()
                .map(|(a, b, up)| format!("{a}{}{b}", if *up { "↑" } else { "↓" }))
                .collect();
            println!(
                "phase {} (k={:>3}) stride {:>3}: {}",
                p + 1,
                step.phase_len,
                step.stride,
                rendering.join(" ")
            );
        }
    }
    Ok(())
}

/// `bitonic-tpu tune`: sweep `block × interleave × threads × isa` on the
/// real executor over the manifest's `(n, dtype)` size classes, print
/// every measurement, and persist the fastest config per class as the
/// tuning profile `sort`/`serve` consult on start-up.
fn cmd_tune(args: &bitonic_tpu::util::cli::Args) -> bitonic_tpu::Result<()> {
    let dir = artifacts_dir(args);
    if args.flag("hier") {
        return cmd_tune_hier(args, &dir);
    }
    let manifest = Manifest::load(&dir)?;
    let smoke = args.flag("smoke");

    // Distinct (n, dtype) classes over the sort artifacts — merge
    // artifacts share their class's tuned config via the same lookup.
    let mut classes: Vec<(usize, bitonic_tpu::runtime::Dtype)> = manifest
        .entries
        .iter()
        .filter(|m| m.kind == ArtifactKind::Sort)
        .map(|m| (m.n, m.dtype))
        .collect();
    classes.sort_by_key(|&(n, d)| (n, d.name()));
    classes.dedup();
    if smoke {
        classes.truncate(2); // smallest two classes: seconds, not minutes
    }
    bitonic_tpu::ensure!(!classes.is_empty(), "no sort artifacts to tune for");

    let mut request = if smoke {
        TuneRequest::smoke(classes)
    } else {
        let mut r = TuneRequest::full(classes);
        // Measure at the geometry serving actually dispatches: the
        // largest batch the artifact menu ships (fixture batches are
        // 1..8 rows, not the generic default) — so the interleave
        // narrowing during measurement matches the narrowing at serve
        // time. --tune-rows overrides for what-if sweeps.
        let max_batch = manifest
            .entries
            .iter()
            .filter(|m| m.kind == ArtifactKind::Sort)
            .map(|m| m.batch)
            .max()
            .unwrap_or(r.rows);
        r.rows = max_batch.max(1);
        r
    };
    if let Some(rows) = args.get_parsed::<usize>("tune-rows")? {
        bitonic_tpu::ensure!(rows >= 1, "--tune-rows must be >= 1");
        request.rows = rows;
    }
    request.seed = args.parsed_or("seed", request.seed)?;
    // An explicit --kernel narrows the sweep to that ISA (`auto` keeps
    // the full axis — the point of tuning is to measure all of them).
    if let Some(s) = args.get("kernel") {
        match KernelChoice::parse(s) {
            Some(KernelChoice::Fixed(isa)) => {
                bitonic_tpu::ensure!(
                    isa.available(),
                    "--kernel {s} is not available on this host/build"
                );
                request.isas = vec![isa];
            }
            Some(KernelChoice::Auto) => {}
            None => bitonic_tpu::bail!("bad --kernel (auto|scalar|portable|avx2)"),
        }
    }
    let isa_names: Vec<&str> = request.isas.iter().map(|i| i.name()).collect();
    println!(
        "tuning {} class(es) × blocks {:?} × interleave {:?} × threads {:?} × isa {:?} \
         ({} rows/batch{})…",
        request.classes.len(),
        request.blocks,
        request.interleaves,
        request.threads,
        isa_names,
        request.rows,
        if smoke { ", smoke grid" } else { "" },
    );

    let t0 = Instant::now();
    let outcome = tune(&request);

    let mut measured = Table::new(vec![
        "n", "dtype", "block", "interleave", "threads", "isa", "rows/sec",
    ]);
    for e in &outcome.measured {
        measured.row(vec![
            fmt_size(e.n),
            e.dtype.name().to_string(),
            e.block.to_string(),
            e.interleave.to_string(),
            e.threads.to_string(),
            e.isa.name().to_string(),
            format!("{:.0}", e.rows_per_sec),
        ]);
    }
    println!("{}", measured.render());

    let mut chosen = Table::new(vec![
        "class", "chosen block", "interleave", "threads", "isa", "rows/sec",
    ]);
    for e in &outcome.profile.entries {
        chosen.row(vec![
            format!("n={} {}", fmt_size(e.n), e.dtype.name()),
            e.block.to_string(),
            e.interleave.to_string(),
            e.threads.to_string(),
            e.isa.name().to_string(),
            format!("{:.0}", e.rows_per_sec),
        ]);
    }
    println!("{}", chosen.render());

    // A smoke sweep (tiny grid, truncated classes, threads=[1]) is a
    // pipeline exercise, not a real tuning — persist it to a side path
    // that sort/serve do NOT auto-consult, so a CI smoke can never
    // silently downgrade production runs to its miniature config.
    let path = match args.get("profile") {
        Some(p) => std::path::PathBuf::from(p),
        None if smoke => dir.join("autotune.smoke.tsv"),
        None => TuningProfile::default_path(&dir),
    };
    outcome.profile.save(&path)?;
    if smoke {
        // A smoke grid is never a real tuning, wherever it was written —
        // including an explicit `--profile` pointing at the auto-consulted
        // path. Say so instead of advertising automatic pickup.
        println!(
            "wrote {} smoke-tuned class(es) to {path:?} in {:.1}s — smoke grids are for \
             pipeline checks; run a full `tune` before relying on this profile",
            outcome.profile.entries.len(),
            t0.elapsed().as_secs_f64()
        );
    } else {
        println!(
            "wrote {} tuned class(es) to {path:?} in {:.1}s — sort/serve pick it up automatically",
            outcome.profile.entries.len(),
            t0.elapsed().as_secs_f64()
        );
    }
    Ok(())
}

/// `bitonic-tpu tune --hier`: sweep the hierarchical sorter's tile ×
/// merge-parallelism grid over every mega size class the (merged) menu
/// reaches, persisting the fastest (tile, merge_threads) per n as
/// `autotune_hier.tsv` — the profile `sort --algo hier` consults.
fn cmd_tune_hier(
    args: &bitonic_tpu::util::cli::Args,
    dir: &std::path::Path,
) -> bitonic_tpu::Result<()> {
    let smoke = args.flag("smoke");
    let plan = plan_policy(args, dir)?;
    let threads = pick_threads(args, &plan)?;
    let (handle, manifest) = spawn_device_host_discovered(dir, HostConfig { threads, plan })?;

    // Target sizes: every u32-asc class above the default tile cap —
    // below it the flat device path wins by construction; smoke keeps
    // the two smallest mega targets so CI stays in seconds.
    let mut targets: Vec<usize> = manifest
        .size_classes(Variant::Optimized)
        .into_iter()
        .map(|m| m.n)
        .filter(|&n| n > bitonic_tpu::sort::hybrid::DEFAULT_TILE_CAP)
        .collect();
    targets.sort_unstable();
    targets.dedup();
    if smoke {
        targets.truncate(2);
    }
    bitonic_tpu::ensure!(
        !targets.is_empty(),
        "no size class above the {} tile cap — run `bitonic-tpu gen-artifacts` first",
        fmt_size(bitonic_tpu::sort::hybrid::DEFAULT_TILE_CAP)
    );

    let bench = if smoke {
        bitonic_tpu::bench::Bench {
            warmup: 1,
            min_iters: 2,
            max_iters: 5,
            target: std::time::Duration::from_millis(400),
        }
    } else {
        bitonic_tpu::bench::Bench::quick()
    };
    let seed: u64 = args.parsed_or("seed", 42)?;

    // Merge-parallelism axis: an explicit --merge-threads pins a single
    // candidate; otherwise sweep a small power-of-two grid capped by the
    // host's parallelism (smoke keeps two points so CI stays in seconds).
    // tune_tiles always re-adds 1, so the serial merge is never untested.
    let merge_grid: Vec<usize> = match args.get_parsed::<usize>("merge-threads")? {
        Some(mt) => {
            bitonic_tpu::ensure!(mt >= 1, "--merge-threads must be >= 1");
            vec![mt]
        }
        None if smoke => vec![1, 2],
        None => {
            let cap = std::thread::available_parallelism().map_or(4, |p| p.get());
            [1usize, 2, 4, 8].iter().copied().filter(|&t| t <= cap.max(2)).collect()
        }
    };
    println!(
        "tuning hierarchical tiles for {} target size(s) {:?} × merge grid {:?}{}…",
        targets.len(),
        targets,
        merge_grid,
        if smoke { " (smoke grid)" } else { "" }
    );
    let t0 = Instant::now();
    let profile = tune_tiles(&handle, &manifest, &targets, &merge_grid, &bench, seed)?;
    handle.shutdown();

    let mut t = Table::new(vec!["n", "chosen tile", "merge", "keys/sec"]);
    for e in &profile.entries {
        t.row(vec![
            fmt_size(e.n),
            fmt_size(e.tile),
            format!("{}", e.merge_threads),
            format!("{:.0}", e.keys_per_sec),
        ]);
    }
    println!("{}", t.render());

    let path = match args.get("profile") {
        Some(p) => std::path::PathBuf::from(p),
        None => TileProfile::default_path(dir),
    };
    profile.save(&path)?;
    println!(
        "wrote {} tiled class(es) to {path:?} in {:.1}s — `sort --algo hier` picks it up automatically",
        profile.entries.len(),
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}

/// `--trajectory PATH` if given, else the library default
/// (`$BENCH_TRAJECTORY_JSON`, or `BENCH_trajectory.json` at the
/// workspace root — producers run with different cwds, see
/// [`Trajectory::default_path`]).
fn trajectory_path(args: &bitonic_tpu::util::cli::Args) -> std::path::PathBuf {
    args.get("trajectory")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(Trajectory::default_path)
}

/// `bitonic-tpu bench [--smoke]`: run the survey matrix (substrates ×
/// distributions × dtypes × sizes) plus the launch-fusion pass ablation,
/// print the per-size speedup-vs-quicksort headline, and append every
/// record to the bench trajectory. The device substrate routes through
/// the real registry with the same autotune plan policy `sort`/`serve`
/// resolve (`--profile`/`--no-profile`/`--plan-*` all apply).
fn cmd_bench(args: &bitonic_tpu::util::cli::Args) -> bitonic_tpu::Result<()> {
    let smoke = args.flag("smoke");
    let mut cfg = if smoke { MatrixConfig::smoke() } else { MatrixConfig::full() };
    cfg.seed = args.parsed_or("seed", cfg.seed)?;
    if let Some(threads) = args.get_parsed::<usize>("threads")? {
        bitonic_tpu::ensure!(threads >= 1, "--threads must be >= 1");
        cfg.threads = threads;
    }

    // Device substrate: a real device host — registry, executor pool,
    // autotune plan policy — not an inlined plan walk. Missing artifacts
    // degrade to a CPU-only matrix rather than failing the sweep.
    let dir = artifacts_dir(args);
    let device = (|| -> bitonic_tpu::Result<DeviceCtx> {
        let plan = plan_policy(args, &dir)?;
        let threads = pick_threads(args, &plan)?;
        let (handle, manifest) =
            spawn_device_host_discovered(&dir, HostConfig { threads, plan })?;
        Ok(DeviceCtx { handle, manifest, threads })
    })();
    let device = match device {
        Ok(ctx) => Some(ctx),
        Err(e) => {
            eprintln!("device path unavailable ({e:#}); running CPU substrates only");
            None
        }
    };

    println!(
        "bench matrix: {} substrate(s) × {} dist(s) × {} dtype(s) × sizes {:?}{}{}",
        cfg.substrates.len(),
        cfg.dists.len(),
        cfg.dtypes.len(),
        cfg.sizes,
        if smoke { " (smoke grid)" } else { "" },
        if device.is_some() { "" } else { " [no device]" },
    );
    let t0 = Instant::now();
    let mut records = run_matrix(&cfg, device.as_ref())?;
    records.extend(run_pass_ablation(&cfg.sizes, &cfg.bench, cfg.seed));
    // Mega cells: the hierarchical substrate above the flat-artifact
    // ceiling, each paired with a quicksort baseline (and, when the
    // merged menu reaches, a flat-device crossover point).
    if let Some(ctx) = &device {
        let mega_sizes: &[usize] = if smoke {
            &[1 << 18]
        } else {
            &[1 << 17, 1 << 18, 1 << 20]
        };
        records.extend(run_mega_cells(ctx, mega_sizes, &cfg.bench, cfg.seed)?);
    }
    if let Some(ctx) = device {
        ctx.handle.shutdown();
    }

    // The paper's headline, per size class, on stdout.
    let mut t = Table::new(vec!["n", "quick ms/row", "executor ms/row", "speedup vs quick"]);
    for &n in &cfg.sizes {
        let find = |sub: &str| {
            records
                .iter()
                .find(|r| r.substrate == sub && r.dtype == "u32" && r.dist == "uniform" && r.n == n)
        };
        let quick = find(Substrate::Quicksort.name());
        let exec = find("bitonic-executor");
        t.row(vec![
            fmt_size(n),
            quick.map(|r| fmt_ms(r.ms_per_row())).unwrap_or("—".into()),
            exec.map(|r| fmt_ms(r.ms_per_row())).unwrap_or("—".into()),
            exec.and_then(|r| r.extra_f64("speedup_vs_quicksort"))
                .map(|s| format!("{s:.2}x"))
                .unwrap_or("—".into()),
        ]);
    }
    println!("{}", t.render());

    // The mega-sort headline: hierarchical substrate vs quicksort.
    let hier: Vec<_> = records
        .iter()
        .filter(|r| r.substrate == "hierarchical")
        .collect();
    if !hier.is_empty() {
        let mut t = Table::new(vec!["n", "hier ms/row", "tile", "speedup vs quick"]);
        for r in hier {
            t.row(vec![
                fmt_size(r.n),
                fmt_ms(r.ms_per_row()),
                r.extra_f64("tile")
                    .map(|v| fmt_size(v as usize))
                    .unwrap_or("—".into()),
                r.extra_f64("speedup_vs_quicksort")
                    .map(|s| format!("{s:.2}x"))
                    .unwrap_or("—".into()),
            ]);
        }
        println!("{}", t.render());
    }

    let path = trajectory_path(args);
    let appended = records.len();
    let total = Trajectory::append_to(&path, records)?;
    println!(
        "appended {appended} records to {path:?} ({total} total) in {:.1}s — render with `bitonic-tpu report`",
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}

/// `bitonic-tpu report`: regenerate `RESULTS.md` from the trajectory.
/// Pure function of the JSON — same trajectory, byte-identical output.
///
/// With `--diff OLD`, render a per-cell tolerance comparison against an
/// older trajectory instead (keyed on bench/substrate/dist/dtype/n/batch,
/// only at equal env stamps); `--gate` additionally exits non-zero when
/// any cell slowed down past the regression threshold — the CI slice of
/// ROADMAP's trajectory-regression item.
fn cmd_report(args: &bitonic_tpu::util::cli::Args) -> bitonic_tpu::Result<()> {
    let path = trajectory_path(args);
    let trajectory = Trajectory::load(&path)?;
    if let Some(old_path) = args.get("diff") {
        let old = Trajectory::load(old_path)?;
        let diff = bitonic_tpu::bench::diff_trajectories(&old, &trajectory);
        print!("{}", diff.render());
        if args.flag("gate") {
            let bad = diff.regressions();
            bitonic_tpu::ensure!(
                bad.is_empty(),
                "report --diff --gate: {} cell(s) slowed down more than {:.1}x vs {old_path} \
                 (worst: {})",
                bad.len(),
                bitonic_tpu::bench::DIFF_SLOWDOWN_GATE,
                bad[0].label()
            );
            println!(
                "gate clean: {} comparable cell(s), none slower than {:.1}x",
                diff.compared.len(),
                bitonic_tpu::bench::DIFF_SLOWDOWN_GATE
            );
        }
        return Ok(());
    }
    let out = args.get_or("out", "RESULTS.md");
    let text = render_results(&trajectory);
    std::fs::write(&out, &text)
        .map_err(|e| bitonic_tpu::err!("writing {out}: {e}"))?;
    println!(
        "wrote {out} from {path:?} ({} records, {} bytes)",
        trajectory.records.len(),
        text.len()
    );
    Ok(())
}

/// `bitonic-tpu verify-plans`: run the static plan verifier, the
/// concurrency-disjointness checker and the artifact auditor over the
/// artifacts directory; write `ANALYSIS.md` + `ANALYSIS.json`; exit
/// non-zero on any failing finding (the CI gate).
fn cmd_verify_plans(args: &bitonic_tpu::util::cli::Args) -> bitonic_tpu::Result<()> {
    use bitonic_tpu::analysis::{verify_plans, Report, Verdict, VerifyOptions};

    let dir = artifacts_dir(args);
    let mut opts = VerifyOptions::default();
    if let Some(cap) = args.get_parsed::<usize>("exhaustive-cap")? {
        bitonic_tpu::ensure!(cap >= 2, "--exhaustive-cap must be >= 2");
        opts.exhaustive_cap = cap;
    }
    println!(
        "verify-plans: {dir:?} (exhaustive 0-1 proofs up to n={}, sampled above)…",
        opts.exhaustive_cap
    );
    let t0 = Instant::now();
    let report = verify_plans(&dir, &opts)?;
    let (pass, warn, fail) = report.counts();

    let md_path = args
        .get("analysis-out")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(Report::default_md_path);
    std::fs::write(&md_path, report.render_markdown())
        .map_err(|e| bitonic_tpu::err!("writing {md_path:?}: {e}"))?;
    let json_path = md_path.with_extension("json");
    std::fs::write(&json_path, format!("{}\n", report.to_json().render()))
        .map_err(|e| bitonic_tpu::err!("writing {json_path:?}: {e}"))?;

    let mut t = Table::new(vec!["check", "targets", "worst"]);
    let mut checks: Vec<&str> = report.findings.iter().map(|f| f.check.as_str()).collect();
    checks.sort_unstable();
    checks.dedup();
    for check in checks {
        let of_check: Vec<_> = report.findings.iter().filter(|f| f.check == check).collect();
        let worst = of_check.iter().map(|f| f.verdict).max().unwrap_or(Verdict::Pass);
        t.row(vec![
            check.to_string(),
            of_check.len().to_string(),
            worst.name().to_string(),
        ]);
    }
    println!("{}", t.render());
    println!(
        "verdict {} — {} findings ({pass} passed, {warn} warned, {fail} failed) in {:.1}s — report at {md_path:?} (+ json)",
        report.worst().name(),
        report.findings.len(),
        t0.elapsed().as_secs_f64()
    );
    if report.has_fail() {
        for f in report.findings.iter().filter(|f| f.verdict == Verdict::Fail) {
            eprintln!("  {}: {} — {}", f.check, f.target, f.detail);
        }
        bitonic_tpu::bail!("static analysis found {fail} failing finding(s); see {md_path:?}");
    }
    Ok(())
}

fn cmd_gen_data(args: &bitonic_tpu::util::cli::Args) -> bitonic_tpu::Result<()> {
    let n: usize = args.parsed_or("n", 65536)?;
    let seed: u64 = args.parsed_or("seed", 42)?;
    let dist = Distribution::parse(&args.get_or("dist", "uniform"))
        .ok_or_else(|| bitonic_tpu::err!("unknown distribution"))?;
    let path = args
        .positionals()
        .first()
        .cloned()
        .unwrap_or_else(|| format!("workload_{}_{}.btsd", dist.name(), n));
    let keys = Generator::new(seed).u32s(n, dist);
    bitonic_tpu::workload::datasets::save_u32(&path, &keys)?;
    println!("wrote {n} {} u32 keys to {path}", dist.name());
    Ok(())
}

/// `bitonic-tpu gen-artifacts [--smoke]`: synthesize the default (or
/// smoke) grid of HLO sort/merge artifacts natively — no Python, no jax
/// — into `<artifacts>/generated` (smoke: `generated-smoke`), where the
/// drivers' merged discovery picks them up. Validate the result with
/// `verify-plans --artifacts <gen dir>`.
fn cmd_gen_artifacts(args: &bitonic_tpu::util::cli::Args) -> bitonic_tpu::Result<()> {
    let dir = artifacts_dir(args);
    let smoke = args.flag("smoke");
    let out = match args.get("gen-dir") {
        Some(p) => std::path::PathBuf::from(p),
        None => dir.join(if smoke { "generated-smoke" } else { "generated" }),
    };
    let specs = if smoke {
        genart::smoke_grid()
    } else {
        genart::default_grid()
    };
    let t0 = Instant::now();
    let report = bitonic_tpu::runtime::generate_artifacts(&out, &specs)?;
    println!(
        "wrote {} HLO artifact(s) / {} manifest row(s) to {:?} in {:.1}s — menu now reaches n={}{}",
        report.written,
        report.rows,
        report.dir,
        t0.elapsed().as_secs_f64(),
        fmt_size(report.max_sort_n),
        if smoke { " (smoke grid)" } else { "" },
    );
    if out == dir.join("generated") {
        println!("sort/serve/bench auto-merge this dir into the fixture menu");
    } else {
        println!(
            "serve it via --artifacts {:?} or BITONIC_GEN_ARTIFACTS={:?}",
            report.dir, report.dir
        );
    }
    Ok(())
}

fn cmd_analyze(args: &bitonic_tpu::util::cli::Args) -> bitonic_tpu::Result<()> {
    let n: usize = args.parsed_or("n", 65536)?;
    let net = Network::new(n.next_power_of_two());
    // Same knob the executor compiles its plans at, so the structural
    // numbers printed here are the ones the native path actually pays.
    let block = plan_base(args)?.block;
    let mut t = Table::new(vec!["variant", "launches", "hbm passes", "vs basic"]);
    let basic_launches = net.launches(Variant::Basic, block).len() as f64;
    for v in Variant::ALL {
        let launches = net.launches(v, block);
        t.row(vec![
            v.name().to_string(),
            launches.len().to_string(),
            launches
                .iter()
                .map(|l| l.global_passes())
                .sum::<usize>()
                .to_string(),
            format!("{:.2}x", basic_launches / launches.len() as f64),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}
