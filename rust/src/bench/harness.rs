//! Measurement harness (criterion is unavailable offline): warmup,
//! adaptive repetition, and robust statistics (median, p10/p90, MAD) so
//! bench numbers are stable enough to compare variants.
//!
//! A [`Measurement`] is pure timing; converting one into a trajectory
//! entry is [`super::record::BenchRecord::with_timing`]'s job.

use std::time::{Duration, Instant};

/// Result of one benchmark: robust statistics over per-iteration times.
#[derive(Clone, Debug)]
pub struct Measurement {
    /// Benchmark label.
    pub name: String,
    /// Per-iteration wall times, sorted ascending.
    pub samples_ns: Vec<u64>,
}

impl Measurement {
    /// Median iteration time in nanoseconds.
    pub fn median_ns(&self) -> u64 {
        percentile(&self.samples_ns, 0.5)
    }

    /// Median in milliseconds (Table 1's unit).
    pub fn median_ms(&self) -> f64 {
        self.median_ns() as f64 / 1e6
    }

    /// p10 in nanoseconds.
    pub fn p10_ns(&self) -> u64 {
        percentile(&self.samples_ns, 0.10)
    }

    /// p90 in nanoseconds.
    pub fn p90_ns(&self) -> u64 {
        percentile(&self.samples_ns, 0.90)
    }

    /// Median absolute deviation (spread indicator).
    pub fn mad_ns(&self) -> u64 {
        let med = self.median_ns();
        let mut dev: Vec<u64> = self.samples_ns.iter().map(|&s| s.abs_diff(med)).collect();
        dev.sort_unstable();
        percentile(&dev, 0.5)
    }

    /// One-line report.
    pub fn summary(&self) -> String {
        format!(
            "{:<32} median {:>10.4} ms  (p10 {:>9.4}, p90 {:>9.4}, n={})",
            self.name,
            self.median_ms(),
            self.p10_ns() as f64 / 1e6,
            self.p90_ns() as f64 / 1e6,
            self.samples_ns.len()
        )
    }
}

fn percentile(sorted: &[u64], q: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let pos = ((sorted.len() - 1) as f64 * q).round() as usize;
    sorted[pos]
}

/// Harness configuration.
#[derive(Clone, Copy, Debug)]
pub struct Bench {
    /// Warmup iterations (not recorded).
    pub warmup: u32,
    /// Minimum recorded iterations.
    pub min_iters: u32,
    /// Maximum recorded iterations.
    pub max_iters: u32,
    /// Target total measuring time; iteration stops after this once
    /// `min_iters` is reached.
    pub target: Duration,
}

impl Default for Bench {
    fn default() -> Self {
        Self {
            warmup: 2,
            min_iters: 5,
            max_iters: 200,
            target: Duration::from_secs(2),
        }
    }
}

impl Bench {
    /// Quick preset for slow end-to-end benches.
    pub fn quick() -> Self {
        Self {
            warmup: 1,
            min_iters: 3,
            max_iters: 20,
            target: Duration::from_millis(1500),
        }
    }

    /// Measure `f`, which must regenerate its own input (use
    /// [`Bench::run_with_setup`] when setup must be excluded).
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> Measurement {
        self.run_with_setup(name, || (), |()| f())
    }

    /// Measure `work(setup())` excluding `setup` time from samples.
    pub fn run_with_setup<S, T, F>(&self, name: &str, mut setup: S, mut work: F) -> Measurement
    where
        S: FnMut() -> T,
        F: FnMut(T),
    {
        for _ in 0..self.warmup {
            let input = setup();
            work(input);
        }
        let mut samples = Vec::new();
        let started = Instant::now();
        for i in 0..self.max_iters {
            let input = setup();
            let t0 = Instant::now();
            work(input);
            samples.push(t0.elapsed().as_nanos() as u64);
            if i + 1 >= self.min_iters && started.elapsed() >= self.target {
                break;
            }
        }
        samples.sort_unstable();
        Measurement {
            name: name.to_string(),
            samples_ns: samples,
        }
    }
}

/// Prevent the optimizer from discarding a computed value
/// (`std::hint::black_box` wrapper kept for call-site clarity).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let b = Bench {
            warmup: 1,
            min_iters: 3,
            max_iters: 10,
            target: Duration::from_millis(50),
        };
        let m = b.run("spin", || {
            let mut acc = 0u64;
            for i in 0..10_000 {
                acc = acc.wrapping_add(black_box(i));
            }
            black_box(acc);
        });
        assert!(m.median_ns() > 0);
        assert!(m.samples_ns.len() >= 3);
    }

    #[test]
    fn setup_excluded_from_samples() {
        let b = Bench {
            warmup: 0,
            min_iters: 3,
            max_iters: 3,
            target: Duration::from_millis(1),
        };
        let m = b.run_with_setup(
            "setup-heavy",
            || std::thread::sleep(Duration::from_millis(20)),
            |()| {},
        );
        // Work is ~nothing; if setup leaked into timing, median would be ≥20ms.
        assert!(m.median_ns() < 5_000_000, "median {}", m.median_ns());
    }

    #[test]
    fn respects_max_iters() {
        let b = Bench {
            warmup: 0,
            min_iters: 1,
            max_iters: 4,
            target: Duration::from_secs(999),
        };
        let m = b.run("fast", || {});
        assert!(m.samples_ns.len() <= 4);
    }

    #[test]
    fn percentile_edges() {
        assert_eq!(percentile(&[], 0.5), 0);
        assert_eq!(percentile(&[7], 0.5), 7);
        assert_eq!(percentile(&[1, 2, 3, 4, 5], 0.0), 1);
        assert_eq!(percentile(&[1, 2, 3, 4, 5], 1.0), 5);
    }

    #[test]
    fn summary_contains_name() {
        let m = Measurement {
            name: "abc".into(),
            samples_ns: vec![1000, 2000, 3000],
        };
        assert!(m.summary().contains("abc"));
        assert_eq!(m.median_ns(), 2000);
    }
}
