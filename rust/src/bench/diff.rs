//! Trajectory regression diffing: compare two bench trajectories
//! cell-by-cell and flag slowdowns — the CI slice of ROADMAP's
//! "trajectory-aware regression gate" item.
//!
//! A **cell** is the scenario key `(bench, substrate, dist, dtype, n,
//! batch)`. Both files may contain a key several times (trajectories are
//! append-only across runs); the diff takes the *last* record per key —
//! the most recent measurement on each side.
//!
//! Comparability first: timings from different hosts or build modes are
//! noise, so [`diff_trajectories`] only compares cells when the two env
//! stamps agree on everything that shapes throughput (`os`, `arch`,
//! `cpus`, `crate_version`, `debug_assertions` — **not** `unix_secs`,
//! which merely dates the file). On a stamp mismatch the diff carries
//! zero compared cells and says why; the `--gate` exit stays clean
//! because there is nothing sound to gate on.
//!
//! Thresholds: a cell is **reported** when its ratio leaves the
//! [`DIFF_TOLERANCE`] band (bench timings on shared CI hosts jitter; a
//! few percent is not signal) and **gated** when it slows past
//! [`DIFF_SLOWDOWN_GATE`] — deliberately loose, catching "the kernel
//! fell off a cliff", not "the machine was busy".
//!
//! Driven by `bitonic-tpu report --diff <old> [--gate]`; wired into
//! verify.sh against the smoke bench run.

use super::record::Trajectory;
use crate::util::table::Table;

/// Ratios inside `[1/DIFF_TOLERANCE, DIFF_TOLERANCE]` are considered
/// noise and left out of the rendered cell table.
pub const DIFF_TOLERANCE: f64 = 1.25;

/// `new_ms / old_ms` above this fails `report --diff --gate`.
pub const DIFF_SLOWDOWN_GATE: f64 = 2.0;

/// Benches whose cells are never compared. `loadgen` records carry
/// *client-measured serving latency* — a function of the traffic mix,
/// connection count, and whatever else shared CI hardware was doing —
/// not kernel throughput; across runs they jitter far past any sane
/// gate and would make the perf gate cry wolf. They still land in the
/// trajectory and RESULTS.md serving section; they just don't gate.
pub const DIFF_EXCLUDED_BENCHES: &[&str] = &["loadgen"];

/// One scenario measured in both trajectories.
#[derive(Clone, Debug, PartialEq)]
pub struct DiffCell {
    /// Producer bench name.
    pub bench: String,
    /// Sorting substrate.
    pub substrate: String,
    /// Input distribution.
    pub dist: String,
    /// Key dtype.
    pub dtype: String,
    /// Keys per row.
    pub n: usize,
    /// Rows per batch.
    pub batch: usize,
    /// Median ms in the old trajectory (last record for the key).
    pub old_ms: f64,
    /// Median ms in the new trajectory (last record for the key).
    pub new_ms: f64,
}

impl DiffCell {
    /// Slowdown factor: `new_ms / old_ms` (> 1 ⇒ the new run is slower).
    pub fn ratio(&self) -> f64 {
        self.new_ms / self.old_ms
    }

    /// True when the cell fails the regression gate.
    pub fn regressed(&self) -> bool {
        self.ratio() > DIFF_SLOWDOWN_GATE
    }

    /// Human key, e.g. `matrix/bitonic-executor uniform u32 n=65536 b=16`.
    pub fn label(&self) -> String {
        format!(
            "{}/{} {} {} n={} b={}",
            self.bench, self.substrate, self.dist, self.dtype, self.n, self.batch
        )
    }
}

/// The outcome of comparing two trajectories.
#[derive(Clone, Debug)]
pub struct TrajectoryDiff {
    /// Env stamps agreed on every throughput-shaping field.
    pub env_comparable: bool,
    /// One-line explanation of the env verdict.
    pub env_note: String,
    /// Cells present (with `ms > 0`) in both files, old-file order.
    pub compared: Vec<DiffCell>,
    /// Scenario keys only the old trajectory has.
    pub only_old: usize,
    /// Scenario keys only the new trajectory has.
    pub only_new: usize,
}

impl TrajectoryDiff {
    /// The cells that fail the gate, worst first.
    pub fn regressions(&self) -> Vec<&DiffCell> {
        let mut bad: Vec<&DiffCell> = self.compared.iter().filter(|c| c.regressed()).collect();
        bad.sort_by(|a, b| b.ratio().total_cmp(&a.ratio()));
        bad
    }

    /// Render the diff as text: env verdict, a table of the cells
    /// outside the tolerance band (worst first), and a summary line.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("env: {}\n", self.env_note));
        if !self.env_comparable {
            out.push_str("no cells compared — timings across different environments are noise\n");
            return out;
        }
        let mut outliers: Vec<&DiffCell> = self
            .compared
            .iter()
            .filter(|c| {
                let r = c.ratio();
                !(1.0 / DIFF_TOLERANCE..=DIFF_TOLERANCE).contains(&r)
            })
            .collect();
        outliers.sort_by(|a, b| b.ratio().total_cmp(&a.ratio()));
        if outliers.is_empty() {
            out.push_str(&format!(
                "all {} comparable cell(s) within {DIFF_TOLERANCE}x tolerance\n",
                self.compared.len()
            ));
        } else {
            let mut t = Table::new(vec!["cell", "old ms", "new ms", "ratio", "verdict"]);
            for c in &outliers {
                t.row(vec![
                    c.label(),
                    format!("{:.3}", c.old_ms),
                    format!("{:.3}", c.new_ms),
                    format!("{:.2}x", c.ratio()),
                    if c.regressed() {
                        "REGRESSED".to_string()
                    } else if c.ratio() > 1.0 {
                        "slower".to_string()
                    } else {
                        "faster".to_string()
                    },
                ]);
            }
            out.push_str(&t.render());
            out.push('\n');
        }
        out.push_str(&format!(
            "{} compared, {} outside {DIFF_TOLERANCE}x tolerance, {} regressed \
             (> {DIFF_SLOWDOWN_GATE}x), {} only-old, {} only-new\n",
            self.compared.len(),
            outliers.len(),
            self.regressions().len(),
            self.only_old,
            self.only_new,
        ));
        out
    }
}

/// Compare `old` against `new` per scenario cell (see module docs for
/// keying, dedup, and the env-stamp precondition).
pub fn diff_trajectories(old: &Trajectory, new: &Trajectory) -> TrajectoryDiff {
    let (oe, ne) = (&old.env, &new.env);
    let env_comparable = oe.os == ne.os
        && oe.arch == ne.arch
        && oe.cpus == ne.cpus
        && oe.crate_version == ne.crate_version
        && oe.debug_assertions == ne.debug_assertions;
    let env_note = if env_comparable {
        format!("comparable ({})", ne.summary())
    } else {
        format!("NOT comparable — old [{}] vs new [{}]", oe.summary(), ne.summary())
    };
    if !env_comparable {
        return TrajectoryDiff {
            env_comparable,
            env_note,
            compared: Vec::new(),
            only_old: 0,
            only_new: 0,
        };
    }

    // Last record per key wins on each side; unmeasured (ms <= 0) cells
    // can't produce a meaningful ratio and are dropped.
    type Key = (String, String, String, String, usize, usize);
    let index = |t: &Trajectory| -> Vec<(Key, f64)> {
        let mut keys: Vec<(Key, f64)> = Vec::new();
        for r in &t.records {
            if r.ms <= 0.0 || DIFF_EXCLUDED_BENCHES.contains(&r.bench.as_str()) {
                continue;
            }
            let key: Key = (
                r.bench.clone(),
                r.substrate.clone(),
                r.dist.clone(),
                r.dtype.clone(),
                r.n,
                r.batch,
            );
            match keys.iter_mut().find(|(k, _)| *k == key) {
                Some((_, ms)) => *ms = r.ms,
                None => keys.push((key, r.ms)),
            }
        }
        keys
    };
    let old_cells = index(old);
    let new_cells = index(new);

    let mut compared = Vec::new();
    let mut only_old = 0usize;
    for (key, old_ms) in &old_cells {
        match new_cells.iter().find(|(k, _)| k == key) {
            Some((_, new_ms)) => compared.push(DiffCell {
                bench: key.0.clone(),
                substrate: key.1.clone(),
                dist: key.2.clone(),
                dtype: key.3.clone(),
                n: key.4,
                batch: key.5,
                old_ms: *old_ms,
                new_ms: *new_ms,
            }),
            None => only_old += 1,
        }
    }
    let only_new = new_cells
        .iter()
        .filter(|(k, _)| old_cells.iter().all(|(ok, _)| ok != k))
        .count();

    TrajectoryDiff {
        env_comparable,
        env_note,
        compared,
        only_old,
        only_new,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::record::BenchRecord;

    fn rec(substrate: &str, n: usize, ms: f64) -> BenchRecord {
        BenchRecord::new("matrix", substrate, "uniform", "u32", n)
            .with_batch(4)
            .with_ms(ms)
    }

    fn trajectory(records: Vec<BenchRecord>) -> Trajectory {
        let mut t = Trajectory::new();
        for r in records {
            t.push(r);
        }
        t
    }

    #[test]
    fn matches_cells_and_flags_regressions() {
        let old = trajectory(vec![
            rec("quicksort", 1024, 10.0),
            rec("bitonic-executor", 1024, 4.0),
            rec("only-old", 64, 1.0),
        ]);
        let new = trajectory(vec![
            rec("quicksort", 1024, 10.5),       // within tolerance
            rec("bitonic-executor", 1024, 9.0), // 2.25x — regressed
            rec("only-new", 64, 1.0),
        ]);
        let d = diff_trajectories(&old, &new);
        assert!(d.env_comparable, "{}", d.env_note);
        assert_eq!(d.compared.len(), 2);
        assert_eq!((d.only_old, d.only_new), (1, 1));
        let bad = d.regressions();
        assert_eq!(bad.len(), 1);
        assert_eq!(bad[0].substrate, "bitonic-executor");
        assert!(bad[0].ratio() > DIFF_SLOWDOWN_GATE);
        let rendered = d.render();
        assert!(rendered.contains("REGRESSED"), "{rendered}");
        assert!(rendered.contains("1 regressed"), "{rendered}");
    }

    #[test]
    fn improvements_and_noise_are_not_regressions() {
        let old = trajectory(vec![rec("a", 64, 10.0), rec("b", 64, 10.0)]);
        let new = trajectory(vec![rec("a", 64, 2.0), rec("b", 64, 11.0)]);
        let d = diff_trajectories(&old, &new);
        assert!(d.regressions().is_empty());
        // The 5x speedup is an outlier worth showing; the 1.1x is noise.
        let rendered = d.render();
        assert!(rendered.contains("faster"), "{rendered}");
        assert!(rendered.contains("1 outside"), "{rendered}");
    }

    #[test]
    fn last_record_per_key_wins() {
        // The same cell re-measured later in the same file: only the
        // most recent measurement counts on each side.
        let old = trajectory(vec![rec("a", 64, 50.0), rec("a", 64, 10.0)]);
        let new = trajectory(vec![rec("a", 64, 300.0), rec("a", 64, 11.0)]);
        let d = diff_trajectories(&old, &new);
        assert_eq!(d.compared.len(), 1);
        assert!((d.compared[0].ratio() - 1.1).abs() < 1e-9);
        assert!(d.regressions().is_empty());
    }

    #[test]
    fn different_env_stamps_compare_nothing() {
        let old = trajectory(vec![rec("a", 64, 1.0)]);
        let mut new = trajectory(vec![rec("a", 64, 100.0)]);
        new.env.cpus = old.env.cpus + 8;
        let d = diff_trajectories(&old, &new);
        assert!(!d.env_comparable);
        assert!(d.compared.is_empty());
        assert!(d.regressions().is_empty(), "nothing sound to gate on");
        assert!(d.render().contains("NOT comparable"));
        // unix_secs differing alone must NOT break comparability.
        let mut new2 = trajectory(vec![rec("a", 64, 1.0)]);
        new2.env = old.env.clone();
        new2.env.unix_secs += 3600;
        assert!(diff_trajectories(&old, &new2).env_comparable);
    }

    #[test]
    fn serving_latency_cells_are_excluded_from_the_gate() {
        // A 100× "slowdown" in client-measured serving latency must not
        // trip the kernel perf gate (see DIFF_EXCLUDED_BENCHES).
        let serving = |ms: f64| {
            BenchRecord::new("loadgen", "sort-service-tcp", "mixed", "u32", 2048).with_ms(ms)
        };
        let old = trajectory(vec![rec("a", 64, 1.0), serving(1.0)]);
        let new = trajectory(vec![rec("a", 64, 1.0), serving(100.0)]);
        let d = diff_trajectories(&old, &new);
        assert_eq!(d.compared.len(), 1, "loadgen cell leaked into the diff");
        assert!(d.regressions().is_empty());
        assert_eq!((d.only_old, d.only_new), (0, 0));
    }

    #[test]
    fn unmeasured_cells_are_skipped() {
        let old = trajectory(vec![rec("a", 64, 0.0), rec("b", 64, 1.0)]);
        let new = trajectory(vec![rec("a", 64, 5.0), rec("b", 64, 1.0)]);
        let d = diff_trajectories(&old, &new);
        assert_eq!(d.compared.len(), 1);
        assert_eq!(d.compared[0].substrate, "b");
    }
}
