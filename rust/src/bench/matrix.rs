//! The survey-style benchmark matrix: **substrates × distributions ×
//! dtypes × sizes**, in the shape of Božidar & Dobravec's parallel-sort
//! comparison and the Arkhipov et al. GPU-sorting survey (PAPERS.md).
//!
//! Every cell is measured by the shared [`Bench`] harness and emitted as
//! one [`BenchRecord`], so a single `bitonic-tpu bench` run leaves a
//! machine-readable trajectory a future PR can diff. The CPU substrates
//! run in-process; the **device substrate routes through the real
//! serving stack** — [`crate::runtime::Registry`] via a
//! [`crate::runtime::DeviceHandle`], plan resolved per size class by the
//! autotune [`crate::runtime::PlanPolicy`] — so its numbers are the
//! numbers `serve` would see, not an idealised inner loop.
//!
//! The sweep also computes the paper's headline per size class:
//! `speedup_vs_quicksort` is attached to every non-quicksort record that
//! has a same-`(n, dtype, dist)` quicksort baseline (normalised per row,
//! so batch-B device records compare fairly with batch-1 CPU records).
//!
//! [`run_pass_ablation`] contributes the Basic → Semi → Optimized
//! launch-fusion ablation (measured ms + static full-row pass counts) to
//! the same trajectory; the report renders it as the paper's §4 table.

use crate::runtime::{
    ArtifactKind, DeviceHandle, Dtype, ExecutionPlan, Manifest, PlanConfig, DEFAULT_PLAN_BLOCK,
};
use crate::sort::network::Variant;
use crate::sort::{
    bitonic_sort_padded, bitonic_sort_parallel_padded, heapsort, mergesort, oddeven_sort,
    quicksort, radix_sort_u32, SortKey,
};
use crate::workload::{Distribution, Generator};

use super::harness::{black_box, Bench, Measurement};
use super::record::BenchRecord;

/// Key dtypes the matrix sweeps (the trio the artifact menu ships).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MatrixDtype {
    /// 32-bit unsigned (the paper's workload).
    U32,
    /// 32-bit signed.
    I32,
    /// 32-bit float.
    F32,
}

impl MatrixDtype {
    /// All matrix dtypes.
    pub const ALL: [MatrixDtype; 3] = [MatrixDtype::U32, MatrixDtype::I32, MatrixDtype::F32];

    /// Record/report name.
    pub fn name(self) -> &'static str {
        match self {
            MatrixDtype::U32 => "u32",
            MatrixDtype::I32 => "i32",
            MatrixDtype::F32 => "f32",
        }
    }

    /// The runtime's artifact dtype for the device substrate.
    pub fn runtime_dtype(self) -> Dtype {
        match self {
            MatrixDtype::U32 => Dtype::U32,
            MatrixDtype::I32 => Dtype::I32,
            MatrixDtype::F32 => Dtype::F32,
        }
    }
}

/// The substrate menu: the paper's two CPU baselines, the multicore
/// bitonic it lists as future work, the device path, and the classical
/// auxiliary baselines.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Substrate {
    /// CPU quicksort — the paper's baseline every speedup is against.
    Quicksort,
    /// Sequential bitonic sort (the paper's second CPU column).
    BitonicScalar,
    /// Multicore bitonic ([`crate::sort::bitonic_parallel`]).
    BitonicParallel,
    /// The device path: batch-interleaved executor behind the registry,
    /// plan resolved by the autotune policy.
    BitonicExecutor,
    /// LSD radix sort (u32 keys only).
    Radix,
    /// Top-down mergesort.
    Merge,
    /// Heapsort.
    Heap,
    /// Odd-even transposition network (O(n²) comparators — size-capped).
    OddEven,
    /// The hierarchical mega-sort: device-sorted cache-sized tiles +
    /// one loser-tree k-way merge ([`crate::sort::HierarchicalSorter`])
    /// — the large-n path past the fixture ceiling.
    Hierarchical,
}

impl Substrate {
    /// Canonical sweep/report order.
    pub const ALL: [Substrate; 9] = [
        Substrate::Quicksort,
        Substrate::BitonicScalar,
        Substrate::BitonicParallel,
        Substrate::BitonicExecutor,
        Substrate::Hierarchical,
        Substrate::Radix,
        Substrate::Merge,
        Substrate::Heap,
        Substrate::OddEven,
    ];

    /// Record/report name.
    pub fn name(self) -> &'static str {
        match self {
            Substrate::Quicksort => "quicksort",
            Substrate::BitonicScalar => "bitonic-scalar",
            Substrate::BitonicParallel => "bitonic-parallel",
            Substrate::BitonicExecutor => "bitonic-executor",
            Substrate::Hierarchical => "hierarchical",
            Substrate::Radix => "radix",
            Substrate::Merge => "merge",
            Substrate::Heap => "heap",
            Substrate::OddEven => "odd-even",
        }
    }

    /// Whether the substrate can sort this key type (LSD radix digits
    /// are u32-only here, as is the hierarchical driver).
    pub fn supports(self, dtype: MatrixDtype) -> bool {
        match self {
            Substrate::Radix | Substrate::Hierarchical => dtype == MatrixDtype::U32,
            _ => true,
        }
    }

    /// Largest n the matrix will ask of this substrate (odd-even's n
    /// rounds × n/2 comparators make 64K cells minutes-long; everything
    /// else is uncapped).
    pub fn size_cap(self) -> usize {
        match self {
            Substrate::OddEven => 1 << 14,
            _ => usize::MAX,
        }
    }

    /// True for the substrates that need a device host.
    pub fn is_device(self) -> bool {
        matches!(self, Substrate::BitonicExecutor | Substrate::Hierarchical)
    }
}

/// The device-host context the matrix routes [`Substrate::BitonicExecutor`]
/// through: the handle's registry applies the autotune plan policy the
/// caller configured at spawn time.
pub struct DeviceCtx {
    /// Handle to the device-host thread (registry + executor pool).
    pub handle: DeviceHandle,
    /// The artifact menu the registry serves.
    pub manifest: Manifest,
    /// Executor pool threads the host was spawned with (recorded into
    /// the trajectory; the handle itself does not expose it).
    pub threads: usize,
}

/// One matrix sweep: which cells to measure and how hard.
#[derive(Clone, Debug)]
pub struct MatrixConfig {
    /// Substrates to sweep, in [`Substrate::ALL`] order for reports.
    pub substrates: Vec<Substrate>,
    /// Input distributions.
    pub dists: Vec<Distribution>,
    /// Key dtypes.
    pub dtypes: Vec<MatrixDtype>,
    /// Array sizes (powers of two — the bitonic substrates and the
    /// artifact menu are power-of-two shaped).
    pub sizes: Vec<usize>,
    /// Threads for [`Substrate::BitonicParallel`].
    pub threads: usize,
    /// Measurement harness preset.
    pub bench: Bench,
    /// Workload seed.
    pub seed: u64,
}

impl MatrixConfig {
    /// The survey grid: every substrate × the four survey distributions
    /// × all three dtypes × sizes up to the fixture ceiling (64K rows).
    pub fn full() -> Self {
        Self {
            substrates: Substrate::ALL.to_vec(),
            dists: Distribution::SURVEY.to_vec(),
            dtypes: MatrixDtype::ALL.to_vec(),
            sizes: vec![1 << 10, 1 << 12, 1 << 14, 1 << 16],
            threads: 4,
            bench: Bench::quick(),
            seed: 0x5EED_17,
        }
    }

    /// CI-sized grid: same dimensional coverage (all substrates, the
    /// four survey distributions, all dtypes) at the two smallest sizes
    /// with a millisecond-budget harness — seconds, not minutes.
    pub fn smoke() -> Self {
        Self {
            sizes: vec![1 << 10, 1 << 12],
            bench: Bench {
                warmup: 1,
                min_iters: 2,
                max_iters: 8,
                target: std::time::Duration::from_millis(60),
            },
            ..Self::full()
        }
    }
}

/// Run the matrix. `device` is the host for the executor substrate;
/// `None` (no artifacts) skips those cells. Cells whose substrate does
/// not support the dtype, exceeds its size cap, or has no matching
/// artifact are skipped, not errors — the matrix is the union of what
/// this host can measure. Returns the records with
/// `speedup_vs_quicksort` annotations already applied.
pub fn run_matrix(
    cfg: &MatrixConfig,
    device: Option<&DeviceCtx>,
) -> crate::Result<Vec<BenchRecord>> {
    crate::ensure!(!cfg.sizes.is_empty(), "matrix: no sizes configured");
    for &n in &cfg.sizes {
        crate::ensure!(
            n.is_power_of_two() && n >= 2,
            "matrix: size {n} is not a power of two >= 2"
        );
    }
    let mut records = Vec::new();
    let mut seed = cfg.seed;
    for &dtype in &cfg.dtypes {
        for &dist in &cfg.dists {
            for &n in &cfg.sizes {
                for &sub in &cfg.substrates {
                    // Distinct seed per cell, deterministic in the config.
                    seed = seed.wrapping_mul(0x9E37_79B9_7F4A_7C15).wrapping_add(1);
                    if !sub.supports(dtype) || n > sub.size_cap() {
                        continue;
                    }
                    let record = if sub.is_device() {
                        let Some(ctx) = device else { continue };
                        let cell = if sub == Substrate::Hierarchical {
                            measure_hierarchical(ctx, dist, n, &cfg.bench, seed, cfg.threads)?
                        } else {
                            measure_device(ctx, dtype, dist, n, &cfg.bench, seed)?
                        };
                        match cell {
                            Some(r) => r,
                            None => continue, // no artifact for (n, dtype)
                        }
                    } else {
                        let m = measure_cpu(sub, dtype, dist, n, cfg.threads, &cfg.bench, seed);
                        let mut r = BenchRecord::new("matrix", sub.name(), dist.name(), dtype.name(), n)
                            .with_timing(&m);
                        if sub == Substrate::BitonicParallel {
                            r = r.with_extra("threads", cfg.threads);
                        }
                        r
                    };
                    records.push(record);
                }
            }
        }
    }
    annotate_speedups(&mut records);
    Ok(records)
}

/// Attach `speedup_vs_quicksort` (per-row time ratio, > 1 = faster than
/// quicksort) to every record that has a same-`(n, dtype, dist)`
/// quicksort baseline in the slice.
pub fn annotate_speedups(records: &mut [BenchRecord]) {
    let baselines: Vec<(String, String, usize, f64)> = records
        .iter()
        .filter(|r| r.substrate == Substrate::Quicksort.name() && r.ms > 0.0)
        .map(|r| (r.dtype.clone(), r.dist.clone(), r.n, r.ms_per_row()))
        .collect();
    for r in records.iter_mut() {
        if r.substrate == Substrate::Quicksort.name() || r.ms <= 0.0 {
            continue;
        }
        if let Some((_, _, _, quick)) = baselines
            .iter()
            .find(|(dtype, dist, n, _)| *dtype == r.dtype && *dist == r.dist && *n == r.n)
        {
            let speedup = quick / r.ms_per_row();
            r.extra.set("speedup_vs_quicksort", speedup);
        }
    }
}

/// i.i.d.-cast helper: map u32 keys to i32 preserving order (flip the
/// sign bit), so "sorted"/"reverse" distributions stay sorted/reverse in
/// the signed domain.
fn monotone_i32(keys: Vec<u32>) -> Vec<i32> {
    keys.into_iter().map(|x| (x ^ 0x8000_0000) as i32).collect()
}

/// Measure one CPU cell.
fn measure_cpu(
    sub: Substrate,
    dtype: MatrixDtype,
    dist: Distribution,
    n: usize,
    threads: usize,
    bench: &Bench,
    seed: u64,
) -> Measurement {
    fn go<T: SortKey>(
        sub: Substrate,
        threads: usize,
        bench: &Bench,
        mut make: impl FnMut() -> Vec<T>,
        radix: Option<Box<dyn FnMut(&mut Vec<T>)>>,
    ) -> Measurement {
        let mut f: Box<dyn FnMut(&mut Vec<T>)> = match sub {
            Substrate::Quicksort => Box::new(|v| quicksort(v)),
            Substrate::BitonicScalar => Box::new(bitonic_sort_padded),
            Substrate::BitonicParallel => Box::new(move |v| bitonic_sort_parallel_padded(v, threads)),
            Substrate::Merge => Box::new(|v| mergesort(v)),
            Substrate::Heap => Box::new(|v| heapsort(v)),
            Substrate::OddEven => Box::new(|v| oddeven_sort(v)),
            Substrate::Radix => radix.expect("radix gated to u32 by Substrate::supports"),
            Substrate::BitonicExecutor | Substrate::Hierarchical => {
                unreachable!("device cells use measure_device / measure_hierarchical")
            }
        };
        bench.run_with_setup(sub.name(), &mut make, move |mut v| {
            f(&mut v);
            black_box(&v);
        })
    }
    match dtype {
        MatrixDtype::U32 => {
            let mut gen = Generator::new(seed);
            go(
                sub,
                threads,
                bench,
                move || gen.u32s(n, dist),
                Some(Box::new(radix_sort_u32)),
            )
        }
        MatrixDtype::I32 => {
            let mut gen = Generator::new(seed);
            go(sub, threads, bench, move || monotone_i32(gen.u32s(n, dist)), None)
        }
        MatrixDtype::F32 => {
            let mut gen = Generator::new(seed);
            go(sub, threads, bench, move || gen.f32s(n, dist), None)
        }
    }
}

/// Measure one device cell: the `(batch, n)` Optimized-variant artifact
/// for this dtype, executed through the registry (autotune plan policy
/// applied at compile time). Returns `None` when the menu has no such
/// artifact; a failing execution is a real error.
fn measure_device(
    ctx: &DeviceCtx,
    dtype: MatrixDtype,
    dist: Distribution,
    n: usize,
    bench: &Bench,
    seed: u64,
) -> crate::Result<Option<BenchRecord>> {
    let Some(meta) = ctx
        .manifest
        .entries
        .iter()
        .find(|m| {
            m.kind == ArtifactKind::Sort
                && m.variant == Variant::Optimized
                && !m.descending
                && m.dtype == dtype.runtime_dtype()
                && m.n == n
        })
        .cloned()
    else {
        return Ok(None);
    };
    let key = crate::runtime::Key::of(&meta);
    let (b, n) = (meta.batch, meta.n);
    let mut gen = Generator::new(seed);
    // One checked execution first: compile errors and artifact drift
    // surface as Err here instead of panicking mid-measurement.
    let m = match dtype {
        MatrixDtype::U32 => {
            ctx.handle
                .sort_u32(key, gen.u32s(b * n, dist))
                .map_err(|e| e.context(format!("device probe for {}", meta.name)))?;
            bench.run_with_setup(
                meta.name.as_str(),
                || gen.u32s(b * n, dist),
                |rows| {
                    let _ = black_box(ctx.handle.sort_u32(key, rows).expect("probed artifact"));
                },
            )
        }
        MatrixDtype::I32 => {
            ctx.handle
                .sort_i32(key, monotone_i32(gen.u32s(b * n, dist)))
                .map_err(|e| e.context(format!("device probe for {}", meta.name)))?;
            bench.run_with_setup(
                meta.name.as_str(),
                || monotone_i32(gen.u32s(b * n, dist)),
                |rows| {
                    let _ = black_box(ctx.handle.sort_i32(key, rows).expect("probed artifact"));
                },
            )
        }
        MatrixDtype::F32 => {
            ctx.handle
                .sort_f32(key, gen.f32s(b * n, dist))
                .map_err(|e| e.context(format!("device probe for {}", meta.name)))?;
            bench.run_with_setup(
                meta.name.as_str(),
                || gen.f32s(b * n, dist),
                |rows| {
                    let _ = black_box(ctx.handle.sort_f32(key, rows).expect("probed artifact"));
                },
            )
        }
    };
    Ok(Some(
        BenchRecord::new(
            "matrix",
            Substrate::BitonicExecutor.name(),
            dist.name(),
            dtype.name(),
            n,
        )
        .with_batch(b)
        .with_timing(&m)
        .with_extra("artifact", meta.name.as_str())
        .with_extra("variant", meta.variant.name())
        .with_extra("threads", ctx.threads),
    ))
}

/// Measure one hierarchical cell: cache-sized device-sorted tiles + a
/// k-way merge (serial loser tree when `merge_threads == 1`, the
/// splitter-partitioned parallel merge otherwise), through the same
/// device host the executor substrate uses. Returns `None` when no sort
/// class fits inside `n` (the hierarchical path needs at least one whole
/// tile). The probe run's per-phase timings (tile sort / partition /
/// merge) land as extras so the report can show where the time goes.
fn measure_hierarchical(
    ctx: &DeviceCtx,
    dist: Distribution,
    n: usize,
    bench: &Bench,
    seed: u64,
    merge_threads: usize,
) -> crate::Result<Option<BenchRecord>> {
    use crate::sort::hybrid::{HierarchicalSorter, DEFAULT_TILE_CAP};
    let variant = Variant::Optimized;
    // Tile never exceeds n: padding a 64K tile to sort 1K keys would
    // measure the padding, not the algorithm.
    let Some(tile) = HierarchicalSorter::pick_tile(
        &ctx.manifest,
        variant,
        Some(n.min(DEFAULT_TILE_CAP)),
    )
    .filter(|&t| t <= n) else {
        return Ok(None);
    };
    let sorter =
        HierarchicalSorter::with_tile(ctx.handle.clone(), &ctx.manifest, variant, tile)?
            .with_merge_threads(merge_threads);
    let mut gen = Generator::new(seed);
    // One checked execution first, mirroring measure_device's probe.
    let mut probe = gen.u32s(n, dist);
    let stats = sorter
        .sort(&mut probe)
        .map_err(|e| e.context(format!("hierarchical probe at n={n} tile={tile}")))?;
    let m = bench.run_with_setup(
        Substrate::Hierarchical.name(),
        || gen.u32s(n, dist),
        |mut keys| {
            sorter.sort(&mut keys).expect("probed hierarchical path");
            black_box(&keys);
        },
    );
    Ok(Some(
        BenchRecord::new(
            "matrix",
            Substrate::Hierarchical.name(),
            dist.name(),
            MatrixDtype::U32.name(),
            n,
        )
        .with_timing(&m)
        .with_extra("tile", tile)
        .with_extra("tiles", stats.tiles)
        .with_extra("threads", ctx.threads)
        .with_extra("merge_threads", stats.merge_threads)
        .with_extra("merge_parts", stats.merge_parts)
        .with_extra("tile_sort_ms", stats.tile_sort_ms)
        .with_extra("partition_ms", stats.partition_ms)
        .with_extra("merge_ms", stats.merge_ms),
    ))
}

/// Merge workers the mega cells' parallel-merge ablation runs with —
/// the ≥4-thread configuration the paper-claim gate in the report
/// ([`super::report`]) judges `merge_speedup_vs_serial` under.
pub const MEGA_MERGE_THREADS: usize = 4;

/// The above-ceiling cells the paper's peak-speedup claim needs: for
/// each size (2^17–2^20, through the paper's 2^18 peak), a quicksort
/// baseline, the hierarchical substrate — measured **twice**, serial
/// loser-tree merge then the splitter-partitioned parallel merge with
/// [`MEGA_MERGE_THREADS`] workers, the parallel record annotated with
/// `merge_speedup_vs_serial` — and, when the generated menu has a
/// matching mega-artifact, the flat executor, so the
/// bitonic-vs-hierarchical crossover is measured, not extrapolated.
/// The serial record lands first so latest-wins cell lookups resolve to
/// the parallel one. All records are `speedup_vs_quicksort`-annotated
/// and land in the same trajectory as the matrix.
pub fn run_mega_cells(
    device: &DeviceCtx,
    sizes: &[usize],
    bench: &Bench,
    seed: u64,
) -> crate::Result<Vec<BenchRecord>> {
    let mut records = Vec::new();
    let mut seed = seed;
    for &n in sizes {
        crate::ensure!(
            n.is_power_of_two() && n >= 2,
            "mega cells: size {n} is not a power of two >= 2"
        );
        seed = seed.wrapping_mul(0x9E37_79B9_7F4A_7C15).wrapping_add(1);
        let dist = Distribution::Uniform;
        let m = measure_cpu(
            Substrate::Quicksort,
            MatrixDtype::U32,
            dist,
            n,
            1,
            bench,
            seed,
        );
        records.push(
            BenchRecord::new("matrix", Substrate::Quicksort.name(), dist.name(), "u32", n)
                .with_timing(&m),
        );
        let serial = measure_hierarchical(device, dist, n, bench, seed, 1)?;
        if let Some(r) = &serial {
            records.push(r.clone());
        }
        if let Some(mut r) =
            measure_hierarchical(device, dist, n, bench, seed, MEGA_MERGE_THREADS)?
        {
            if let Some(s) = &serial {
                if r.ms > 0.0 && s.ms > 0.0 {
                    r.extra
                        .set("merge_speedup_vs_serial", s.ms_per_row() / r.ms_per_row());
                }
            }
            records.push(r);
        }
        // The flat device path only exists where the (generated) menu
        // reaches; its absence is the menu's message, not an error.
        if let Some(r) = measure_device(device, MatrixDtype::U32, dist, n, bench, seed)? {
            records.push(r);
        }
    }
    annotate_speedups(&mut records);
    Ok(records)
}

/// The paper's §4 ablation as trajectory records: for each size, compile
/// the Basic / Semi / Optimized launch programs and record the measured
/// per-row time plus the **static full-row memory-pass count** — the
/// quantity the two optimizations exist to shrink.
pub fn run_pass_ablation(sizes: &[usize], bench: &Bench, seed: u64) -> Vec<BenchRecord> {
    let mut records = Vec::new();
    let mut gen = Generator::new(seed);
    for &n in sizes {
        if !n.is_power_of_two() || n < 2 {
            continue;
        }
        for variant in Variant::ALL {
            let plan = ExecutionPlan::with_config(
                ArtifactKind::Sort,
                n,
                false,
                PlanConfig {
                    variant,
                    block: DEFAULT_PLAN_BLOCK.min(n),
                    interleave: 1,
                    ..Default::default()
                },
            );
            let m = bench.run_with_setup(
                variant.name(),
                || gen.u32s(n, Distribution::Uniform),
                |mut row| {
                    plan.run_row(&mut row);
                    black_box(&row);
                },
            );
            records.push(
                BenchRecord::new("matrix", "bitonic-plan", "uniform", "u32", n)
                    .with_timing(&m)
                    .with_extra("variant", variant.name())
                    .with_extra("hbm_passes", plan.global_passes()),
            );
        }
    }
    records
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn tiny_bench() -> Bench {
        Bench {
            warmup: 0,
            min_iters: 1,
            max_iters: 1,
            target: Duration::from_millis(1),
        }
    }

    #[test]
    fn substrate_names_unique_and_gates_sane() {
        let names: Vec<&str> = Substrate::ALL.iter().map(|s| s.name()).collect();
        let mut dedup = names.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), names.len());
        assert!(Substrate::Radix.supports(MatrixDtype::U32));
        assert!(!Substrate::Radix.supports(MatrixDtype::I32));
        assert!(Substrate::BitonicExecutor.supports(MatrixDtype::F32));
        assert!(Substrate::OddEven.size_cap() < usize::MAX);
        assert!(Substrate::BitonicExecutor.is_device());
        assert!(!Substrate::Quicksort.is_device());
        // The hierarchical substrate is device-gated and u32-only — both
        // gates keep the CPU-only matrix (and its cell count) unchanged.
        assert!(Substrate::Hierarchical.is_device());
        assert!(Substrate::Hierarchical.supports(MatrixDtype::U32));
        assert!(!Substrate::Hierarchical.supports(MatrixDtype::F32));
    }

    #[test]
    fn cpu_matrix_covers_dimensions_and_annotates_speedups() {
        let cfg = MatrixConfig {
            substrates: Substrate::ALL.to_vec(),
            dists: vec![Distribution::Uniform, Distribution::Sorted],
            dtypes: vec![MatrixDtype::U32, MatrixDtype::F32],
            sizes: vec![64, 128],
            threads: 2,
            bench: tiny_bench(),
            seed: 1,
        };
        let records = run_matrix(&cfg, None).unwrap();
        // Per (dtype, dist, n): 7 CPU substrates for u32, 6 for f32
        // (radix gated), executor skipped without a device.
        assert_eq!(records.len(), 2 * 2 * 7 + 2 * 2 * 6);
        for r in &records {
            assert_eq!(r.bench, "matrix");
            assert_eq!(r.batch, 1);
            assert!(r.ms >= 0.0);
            assert!(r.p10_ms.is_some() && r.p90_ms.is_some());
        }
        // Every non-quicksort record with a positive-ms quicksort
        // baseline in the same (dtype, dist, n) cell carries the speedup.
        let baselines: Vec<(&str, &str, usize)> = records
            .iter()
            .filter(|r| r.substrate == "quicksort" && r.ms > 0.0)
            .map(|r| (r.dtype.as_str(), r.dist.as_str(), r.n))
            .collect();
        for r in &records {
            if r.substrate != "quicksort"
                && r.ms > 0.0
                && baselines.contains(&(r.dtype.as_str(), r.dist.as_str(), r.n))
            {
                assert!(
                    r.extra_f64("speedup_vs_quicksort").is_some(),
                    "missing speedup on {} {} {} {}",
                    r.substrate,
                    r.dtype,
                    r.dist,
                    r.n
                );
            }
        }
        // Sorted output sanity is the substrates' own tests' job; here we
        // check the sweep's bookkeeping: every expected cell exists.
        for dtype in ["u32", "f32"] {
            for dist in ["uniform", "sorted"] {
                for n in [64usize, 128] {
                    assert!(records
                        .iter()
                        .any(|r| r.substrate == "heap" && r.dtype == dtype && r.dist == dist && r.n == n));
                }
            }
        }
        assert!(!records.iter().any(|r| r.substrate == "bitonic-executor"));
        assert!(!records.iter().any(|r| r.substrate == "hierarchical"));
        assert!(!records
            .iter()
            .any(|r| r.substrate == "radix" && r.dtype == "f32"));
    }

    #[test]
    fn non_power_of_two_size_rejected() {
        let cfg = MatrixConfig {
            sizes: vec![100],
            bench: tiny_bench(),
            ..MatrixConfig::smoke()
        };
        assert!(run_matrix(&cfg, None).is_err());
    }

    #[test]
    fn pass_ablation_tracks_the_paper_ordering() {
        let records = run_pass_ablation(&[1 << 14], &tiny_bench(), 3);
        assert_eq!(records.len(), 3);
        let passes: Vec<f64> = Variant::ALL
            .iter()
            .map(|v| {
                records
                    .iter()
                    .find(|r| r.extra_str("variant") == Some(v.name()))
                    .unwrap()
                    .extra_f64("hbm_passes")
                    .unwrap()
            })
            .collect();
        // Basic > Semi >= Optimized, the §4 claim the executor reproduces.
        assert!(passes[0] > passes[1], "{passes:?}");
        assert!(passes[1] >= passes[2], "{passes:?}");
    }

    #[test]
    fn monotone_i32_preserves_order() {
        let a = vec![0u32, 1, u32::MAX / 2, u32::MAX];
        let b = monotone_i32(a);
        assert!(b.windows(2).all(|w| w[0] < w[1]));
        assert_eq!(b[0], i32::MIN);
        assert_eq!(b[3], i32::MAX);
    }

    #[test]
    fn presets_cover_acceptance_dimensions() {
        for cfg in [MatrixConfig::full(), MatrixConfig::smoke()] {
            assert!(cfg.substrates.len() >= 4);
            assert!(cfg.dists.len() >= 3);
            assert!(cfg.dtypes.len() >= 2);
            assert!(!cfg.sizes.is_empty());
        }
        assert!(MatrixConfig::full().sizes.contains(&(1 << 16)));
    }
}
