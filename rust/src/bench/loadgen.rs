//! The serving load generator: drive a live `serve-tcp` endpoint with a
//! deterministic [`TrafficMix`] and measure what a *client* sees.
//!
//! Two drive modes:
//!
//! * **Closed loop** — each connection sends, waits for the reply, and
//!   immediately sends again: measures the service's sustainable
//!   throughput and in-service latency.
//! * **Open loop** (`--qps`) — requests are issued on a fixed schedule
//!   regardless of how the previous ones fared, and latency is measured
//!   from the *scheduled* send time, not the actual one. That is the
//!   coordinated-omission correction: a server that stalls makes the
//!   scheduled requests behind the stall look as slow as clients truly
//!   experienced them, instead of silently thinning the load.
//!
//! Every worker connection draws from its own seeded [`TrafficGen`]
//! stream ([`worker_seed`]), so a whole run is reproducible from
//! `(mix, seed, conns)` — the determinism test in
//! `rust/tests/service_load.rs` pins this.
//!
//! Results surface three ways: a stdout table ([`LoadgenReport::render`]),
//! schema-valid [`BenchRecord`]s appended to the unified trajectory
//! ([`LoadgenReport::to_records`], `bench = "loadgen"`), and from there
//! the RESULTS.md serving section. Loadgen cells are excluded from the
//! cross-run diff gate — see `bench::diff::DIFF_EXCLUDED_BENCHES`.

use std::time::{Duration, Instant};

use super::record::BenchRecord;
use crate::coordinator::net::{NetClient, SortReply, DEFAULT_MAX_KEYS};
use crate::util::metrics::{Counter, Histogram};
use crate::util::table::Table;
use crate::workload::{SplitMix64, TrafficGen, TrafficMix};

/// How the generator paces requests.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum LoadMode {
    /// Send → await → send again, per connection.
    Closed,
    /// Fixed aggregate schedule at `qps`, split evenly across
    /// connections; latency measured from the scheduled send time.
    Open {
        /// Aggregate target request rate.
        qps: f64,
    },
}

impl LoadMode {
    /// Stable name recorded in bench extras ("closed" / "open").
    pub fn name(&self) -> &'static str {
        match self {
            Self::Closed => "closed",
            Self::Open { .. } => "open",
        }
    }

    /// The target rate (0 for closed loop).
    pub fn qps_target(&self) -> f64 {
        match self {
            Self::Closed => 0.0,
            Self::Open { qps } => *qps,
        }
    }
}

/// Loadgen run configuration.
#[derive(Clone, Debug)]
pub struct LoadgenConfig {
    /// Pacing mode.
    pub mode: LoadMode,
    /// Concurrent client connections (each on its own thread).
    pub conns: usize,
    /// Wall-clock run length.
    pub duration: Duration,
    /// Root seed; each connection derives its own via [`worker_seed`].
    pub seed: u64,
    /// The traffic mix to draw.
    pub mix: TrafficMix,
    /// Per-connection socket I/O timeout.
    pub timeout: Duration,
}

impl LoadgenConfig {
    /// The CI smoke shape: 2 closed-loop connections, 2 seconds, the
    /// small fixture-friendly mix.
    pub fn smoke(seed: u64) -> Self {
        Self {
            mode: LoadMode::Closed,
            conns: 2,
            duration: Duration::from_secs(2),
            seed,
            mix: TrafficMix::smoke(),
            timeout: Duration::from_secs(30),
        }
    }
}

/// The per-connection seed: decorrelated from neighbours by a
/// SplitMix64 scramble of `seed ⊕ worker·φ64` (pub so the determinism
/// test can reproduce a worker's exact stream).
pub fn worker_seed(seed: u64, worker: usize) -> u64 {
    SplitMix64::new(seed ^ (worker as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)).next_u64()
}

/// Shared tallies for one traffic class (client-side view).
#[derive(Default)]
struct ClassTally {
    sent: Counter,
    ok: Counter,
    shed: Counter,
    slo_tracked: Counter,
    slo_missed: Counter,
    latency: Histogram,
}

/// Per-class slice of a finished run.
#[derive(Clone, Debug)]
pub struct ClassReport {
    /// Class label from the mix.
    pub name: &'static str,
    /// The class's input distribution name.
    pub dist: String,
    /// The class's largest request length.
    pub max_len: usize,
    /// Requests sent.
    pub sent: u64,
    /// Requests answered with sorted keys.
    pub ok: u64,
    /// Requests answered with a shed rejection.
    pub shed: u64,
    /// Answered requests that carried an SLO.
    pub slo_tracked: u64,
    /// Of those, how many blew their budget (client-measured).
    pub slo_missed: u64,
    /// Client-side latency percentiles, milliseconds.
    pub p50_ms: f64,
    /// 99th percentile latency.
    pub p99_ms: f64,
    /// 99.9th percentile latency.
    pub p999_ms: f64,
    /// Mean latency.
    pub mean_ms: f64,
}

impl ClassReport {
    /// Fraction of sent requests that were shed.
    pub fn shed_rate(&self) -> f64 {
        self.shed as f64 / (self.sent.max(1)) as f64
    }

    /// Fraction of SLO-tracked answers that missed their budget.
    pub fn slo_miss_rate(&self) -> f64 {
        self.slo_missed as f64 / (self.slo_tracked.max(1)) as f64
    }
}

/// Aggregate view of a finished loadgen run.
#[derive(Clone, Debug)]
pub struct LoadgenReport {
    /// Pacing mode name ("closed" / "open").
    pub mode: &'static str,
    /// Target QPS (0 for closed loop).
    pub qps_target: f64,
    /// Connections driven.
    pub conns: usize,
    /// Wall clock actually spent.
    pub wall: Duration,
    /// Requests sent across all classes.
    pub sent: u64,
    /// Requests answered with sorted keys.
    pub ok: u64,
    /// Requests shed by admission control.
    pub shed: u64,
    /// Answered requests that carried an SLO.
    pub slo_tracked: u64,
    /// Of those, how many missed (client-measured).
    pub slo_missed: u64,
    /// Transport failures + invalid payloads (a healthy run has none).
    pub errors: u64,
    /// Non-shed rejection frames (a healthy run has none).
    pub rejected: u64,
    /// Achieved request rate (sent / wall).
    pub qps_achieved: f64,
    /// Client-side latency percentiles over every OK answer, ms.
    pub p50_ms: f64,
    /// 99th percentile latency.
    pub p99_ms: f64,
    /// 99.9th percentile latency.
    pub p999_ms: f64,
    /// Mean latency.
    pub mean_ms: f64,
    /// Largest request length the mix can draw (the aggregate record's n).
    pub max_len: usize,
    /// Per-class breakdown, mix order.
    pub classes: Vec<ClassReport>,
}

impl LoadgenReport {
    /// Fraction of sent requests that were shed.
    pub fn shed_rate(&self) -> f64 {
        self.shed as f64 / (self.sent.max(1)) as f64
    }

    /// Fraction of SLO-tracked answers that missed their budget.
    pub fn slo_miss_rate(&self) -> f64 {
        self.slo_missed as f64 / (self.slo_tracked.max(1)) as f64
    }

    /// Protocol-level failures: transport errors, invalid payloads, and
    /// non-shed rejections. The smoke gates on this being zero.
    pub fn protocol_errors(&self) -> u64 {
        self.errors + self.rejected
    }

    /// Per-class slice by name.
    pub fn class(&self, name: &str) -> Option<&ClassReport> {
        self.classes.iter().find(|c| c.name == name)
    }

    /// Render the stdout summary: one headline plus a per-class table.
    pub fn render(&self) -> String {
        let mut out = format!(
            "loadgen: mode {} (target {:.0} qps) conns {} wall {:.2}s — \
             sent {} ok {} shed {} ({:.2}%) errors {} achieved {:.1} qps\n\
             latency ms: p50 {:.3} p99 {:.3} p999 {:.3} mean {:.3} — \
             SLO tracked {} missed {} ({:.2}%)\n",
            self.mode,
            self.qps_target,
            self.conns,
            self.wall.as_secs_f64(),
            self.sent,
            self.ok,
            self.shed,
            self.shed_rate() * 100.0,
            self.protocol_errors(),
            self.qps_achieved,
            self.p50_ms,
            self.p99_ms,
            self.p999_ms,
            self.mean_ms,
            self.slo_tracked,
            self.slo_missed,
            self.slo_miss_rate() * 100.0,
        );
        let mut t = Table::new(vec![
            "class", "dist", "sent", "ok", "shed %", "SLO miss %", "p50 ms", "p99 ms",
            "p999 ms",
        ]);
        for c in &self.classes {
            t.row(vec![
                c.name.to_string(),
                c.dist.clone(),
                c.sent.to_string(),
                c.ok.to_string(),
                format!("{:.2}", c.shed_rate() * 100.0),
                format!("{:.2}", c.slo_miss_rate() * 100.0),
                format!("{:.3}", c.p50_ms),
                format!("{:.3}", c.p99_ms),
                format!("{:.3}", c.p999_ms),
            ]);
        }
        out.push_str(&t.render());
        out
    }

    /// Map the run onto trajectory records: one aggregate cell
    /// (`dist = "mixed"`) plus one per class, all `bench = "loadgen"`,
    /// `substrate = "sort-service-tcp"`, with the serving metrics as
    /// extras. `ms` is the mean client latency so the record validates
    /// even though a serving cell has no single kernel time.
    pub fn to_records(&self) -> Vec<BenchRecord> {
        let stamp = |r: BenchRecord, p50: f64, p99: f64, p999: f64, shed: f64, miss: f64| {
            r.with_extra("mode", self.mode)
                .with_extra("qps_target", self.qps_target)
                .with_extra("p50_ms", p50)
                .with_extra("p99_ms", p99)
                .with_extra("p999_ms", p999)
                .with_extra("shed_rate", shed)
                .with_extra("slo_miss_rate", miss)
        };
        let mut records = Vec::with_capacity(1 + self.classes.len());
        records.push(
            stamp(
                BenchRecord::new("loadgen", "sort-service-tcp", "mixed", "u32", self.max_len)
                    .with_ms(self.mean_ms),
                self.p50_ms,
                self.p99_ms,
                self.p999_ms,
                self.shed_rate(),
                self.slo_miss_rate(),
            )
            .with_extra("qps_achieved", self.qps_achieved)
            .with_extra("conns", self.conns)
            .with_extra("duration_s", self.wall.as_secs_f64())
            .with_extra("requests_sent", self.sent)
            .with_extra("requests_ok", self.ok)
            .with_extra("protocol_errors", self.protocol_errors()),
        );
        for c in &self.classes {
            records.push(
                stamp(
                    BenchRecord::new("loadgen", "sort-service-tcp", &c.dist, "u32", c.max_len)
                        .with_ms(c.mean_ms),
                    c.p50_ms,
                    c.p99_ms,
                    c.p999_ms,
                    c.shed_rate(),
                    c.slo_miss_rate(),
                )
                .with_extra("class", c.name)
                .with_extra("requests_sent", c.sent),
            );
        }
        records
    }
}

fn ms(ns: u64) -> f64 {
    ns as f64 / 1e6
}

/// Drive `addr` per `cfg` and gather the client-side report. Fails on
/// an unreachable server or an invalid config; per-request transport
/// errors after connect are counted (and end that worker) rather than
/// failing the run.
pub fn run_loadgen(addr: &str, cfg: &LoadgenConfig) -> crate::Result<LoadgenReport> {
    cfg.mix.validate()?;
    crate::ensure!(cfg.conns >= 1, "loadgen needs at least one connection");
    crate::ensure!(
        cfg.duration > Duration::ZERO,
        "loadgen duration must be positive"
    );
    if let LoadMode::Open { qps } = cfg.mode {
        crate::ensure!(qps > 0.0, "open-loop qps must be positive");
    }

    let tallies: Vec<ClassTally> = cfg.mix.classes.iter().map(|_| ClassTally::default()).collect();
    let aggregate = Histogram::new();
    let errors = Counter::new();
    let rejected = Counter::new();
    let t0 = Instant::now();
    let deadline = t0 + cfg.duration;

    std::thread::scope(|scope| -> crate::Result<()> {
        let mut handles = Vec::with_capacity(cfg.conns);
        for w in 0..cfg.conns {
            let (tallies, aggregate, errors, rejected) =
                (&tallies, &aggregate, &errors, &rejected);
            handles.push(scope.spawn(move || {
                worker_loop(
                    addr, cfg, w, t0, deadline, tallies, aggregate, errors, rejected,
                )
            }));
        }
        for h in handles {
            h.join().map_err(|_| crate::err!("loadgen worker panicked"))??;
        }
        Ok(())
    })?;

    let wall = t0.elapsed();
    let classes: Vec<ClassReport> = cfg
        .mix
        .classes
        .iter()
        .zip(&tallies)
        .map(|(c, t)| ClassReport {
            name: c.name,
            dist: c.dist.name().to_string(),
            max_len: c.max_len,
            sent: t.sent.get(),
            ok: t.ok.get(),
            shed: t.shed.get(),
            slo_tracked: t.slo_tracked.get(),
            slo_missed: t.slo_missed.get(),
            p50_ms: ms(t.latency.quantile_ns(0.5)),
            p99_ms: ms(t.latency.quantile_ns(0.99)),
            p999_ms: ms(t.latency.quantile_ns(0.999)),
            mean_ms: t.latency.mean_ns() / 1e6,
        })
        .collect();
    let sent: u64 = classes.iter().map(|c| c.sent).sum();
    Ok(LoadgenReport {
        mode: cfg.mode.name(),
        qps_target: cfg.mode.qps_target(),
        conns: cfg.conns,
        wall,
        sent,
        ok: classes.iter().map(|c| c.ok).sum(),
        shed: classes.iter().map(|c| c.shed).sum(),
        slo_tracked: classes.iter().map(|c| c.slo_tracked).sum(),
        slo_missed: classes.iter().map(|c| c.slo_missed).sum(),
        errors: errors.get(),
        rejected: rejected.get(),
        qps_achieved: sent as f64 / wall.as_secs_f64().max(1e-9),
        p50_ms: ms(aggregate.quantile_ns(0.5)),
        p99_ms: ms(aggregate.quantile_ns(0.99)),
        p999_ms: ms(aggregate.quantile_ns(0.999)),
        mean_ms: aggregate.mean_ns() / 1e6,
        max_len: cfg.mix.max_len(),
        classes,
    })
}

#[allow(clippy::too_many_arguments)]
fn worker_loop(
    addr: &str,
    cfg: &LoadgenConfig,
    worker: usize,
    t0: Instant,
    deadline: Instant,
    tallies: &[ClassTally],
    aggregate: &Histogram,
    errors: &Counter,
    rejected: &Counter,
) -> crate::Result<()> {
    let mut client = NetClient::connect_with(addr, cfg.timeout, DEFAULT_MAX_KEYS)
        .map_err(|e| crate::err!("loadgen worker {worker}: {e}"))?;
    let mut gen = TrafficGen::new(cfg.mix.clone(), worker_seed(cfg.seed, worker));
    let per_conn_interval = match cfg.mode {
        LoadMode::Closed => None,
        LoadMode::Open { qps } => Some(Duration::from_secs_f64(
            cfg.conns as f64 / qps.max(f64::MIN_POSITIVE),
        )),
    };
    let mut k: u32 = 0;
    loop {
        // Pacing: closed loop issues now; open loop issues on the k-th
        // scheduled tick and measures from it (coordinated omission).
        let issue_at = match per_conn_interval {
            None => {
                let now = Instant::now();
                if now >= deadline {
                    return Ok(());
                }
                now
            }
            Some(interval) => {
                let sched = t0 + interval * k;
                if sched >= deadline {
                    return Ok(());
                }
                let now = Instant::now();
                if sched > now {
                    std::thread::sleep(sched - now);
                }
                sched
            }
        };
        k += 1;
        let req = gen.next_request();
        let tally = &tallies[req.class];
        let slo = req.slo;
        let want_len = req.keys.len();
        tally.sent.inc();
        match client.sort(req.id, req.keys, req.descending, slo) {
            Ok(SortReply::Sorted { keys, .. }) => {
                let elapsed = issue_at.elapsed();
                let well_formed = keys.len() == want_len
                    && if req.descending {
                        keys.windows(2).all(|w| w[0] >= w[1])
                    } else {
                        keys.windows(2).all(|w| w[0] <= w[1])
                    };
                if !well_formed {
                    errors.inc();
                    continue;
                }
                tally.ok.inc();
                tally.latency.record(elapsed);
                aggregate.record(elapsed);
                if let Some(budget) = slo {
                    tally.slo_tracked.inc();
                    if elapsed > budget {
                        tally.slo_missed.inc();
                    }
                }
            }
            Ok(SortReply::Shed { .. }) => {
                tally.shed.inc();
            }
            Ok(SortReply::Rejected { .. }) => {
                rejected.inc();
            }
            Err(_) => {
                // Transport broke: count it and retire this worker; the
                // run-level gate on protocol_errors() surfaces it.
                errors.inc();
                return Ok(());
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn worker_seeds_are_deterministic_and_distinct() {
        assert_eq!(worker_seed(42, 0), worker_seed(42, 0));
        let seeds: Vec<u64> = (0..16).map(|w| worker_seed(42, w)).collect();
        let mut dedup = seeds.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), seeds.len(), "worker seeds collide: {seeds:?}");
        assert_ne!(worker_seed(1, 0), worker_seed(2, 0));
    }

    #[test]
    fn report_maps_onto_schema_valid_records() {
        let report = LoadgenReport {
            mode: "open",
            qps_target: 500.0,
            conns: 4,
            wall: Duration::from_secs(2),
            sent: 1000,
            ok: 950,
            shed: 50,
            slo_tracked: 900,
            slo_missed: 9,
            errors: 0,
            rejected: 0,
            qps_achieved: 500.0,
            p50_ms: 1.0,
            p99_ms: 5.0,
            p999_ms: 9.0,
            mean_ms: 1.5,
            max_len: 2048,
            classes: vec![ClassReport {
                name: "interactive",
                dist: "uniform".into(),
                max_len: 512,
                sent: 800,
                ok: 790,
                shed: 10,
                slo_tracked: 790,
                slo_missed: 8,
                p50_ms: 0.9,
                p99_ms: 4.0,
                p999_ms: 8.0,
                mean_ms: 1.2,
            }],
        };
        assert!((report.shed_rate() - 0.05).abs() < 1e-12);
        assert!((report.slo_miss_rate() - 0.01).abs() < 1e-12);
        assert_eq!(report.protocol_errors(), 0);
        assert!(report.class("interactive").is_some());

        let records = report.to_records();
        assert_eq!(records.len(), 2);
        let agg = &records[0];
        assert_eq!(agg.bench, "loadgen");
        assert_eq!(agg.substrate, "sort-service-tcp");
        assert_eq!(agg.dist, "mixed");
        assert_eq!(agg.n, 2048);
        for key in [
            "mode",
            "qps_target",
            "qps_achieved",
            "p50_ms",
            "p99_ms",
            "p999_ms",
            "shed_rate",
            "slo_miss_rate",
            "protocol_errors",
        ] {
            assert!(
                agg.extra_f64(key).is_some() || agg.extra_str(key).is_some(),
                "aggregate record lacks extra {key}"
            );
        }
        assert_eq!(agg.extra_str("mode"), Some("open"));
        assert!((agg.extra_f64("shed_rate").unwrap() - 0.05).abs() < 1e-12);
        let class = &records[1];
        assert_eq!(class.extra_str("class"), Some("interactive"));
        assert_eq!(class.dist, "uniform");
        assert_eq!(class.n, 512);
        // Round-trip through the strict trajectory schema.
        let mut t = super::super::record::Trajectory::new();
        for r in report.to_records() {
            t.push(r);
        }
        let json = t.to_json().render();
        let doc = crate::util::json::Json::parse(&json).unwrap();
        super::super::record::Trajectory::from_json(&doc)
            .expect("loadgen records violate schema");
    }

    #[test]
    fn empty_report_rates_do_not_divide_by_zero() {
        let report = LoadgenReport {
            mode: "closed",
            qps_target: 0.0,
            conns: 1,
            wall: Duration::from_millis(1),
            sent: 0,
            ok: 0,
            shed: 0,
            slo_tracked: 0,
            slo_missed: 0,
            errors: 0,
            rejected: 0,
            qps_achieved: 0.0,
            p50_ms: 0.0,
            p99_ms: 0.0,
            p999_ms: 0.0,
            mean_ms: 0.0,
            max_len: 16,
            classes: vec![],
        };
        assert_eq!(report.shed_rate(), 0.0);
        assert_eq!(report.slo_miss_rate(), 0.0);
        assert!(report.render().contains("loadgen:"));
    }
}
