//! The bench-trajectory JSON schema: [`BenchRecord`] (one measured
//! scenario) and [`Trajectory`] (the single `BENCH_trajectory.json` file
//! every bench appends to).
//!
//! Design rules:
//!
//! * **One file, many writers — run sequentially.** Every bench binary
//!   and the `bitonic-tpu bench` subcommand append records to the same
//!   trajectory ([`Trajectory::append_to`]): load-if-present, extend,
//!   rewrite via write-then-rename (a killed producer never leaves a
//!   torn file). There is deliberately **no cross-process lock**: two
//!   producers appending concurrently race load-vs-rename and the last
//!   rename wins, dropping the other's records — verify.sh and CI run
//!   the benches one at a time, and so should you. The environment
//!   stamp is captured when the file is first created.
//! * **Flat records.** A record is a flat JSON object — fixed, typed
//!   core fields (`bench`, `substrate`, `dist`, `dtype`, `n`, `batch`,
//!   `ms`, optional `p10_ms`/`p90_ms`) plus arbitrary extra scalar
//!   fields kept verbatim — so external tooling (`jq`, pandas) needs no
//!   schema knowledge beyond "array of flat objects".
//! * **Validated on load.** [`Trajectory::load`] re-validates everything
//!   ([`BenchRecord::from_json`]): a malformed or hand-edited trajectory
//!   fails with the record index and field named, instead of feeding a
//!   quietly wrong table into `RESULTS.md`.
//! * **Derived fields are never trusted.** `keys_per_sec` is written for
//!   the convenience of external consumers but recomputed from
//!   `batch·n/ms` on load.
//!
//! Producers: `benches/{cpu_sorts,dtypes,scaling,table1,hybrid,ablation}`
//! and the `bench` subcommand ([`super::matrix`]). Consumer:
//! [`super::report`] / the `report` subcommand.

use std::path::{Path, PathBuf};

use crate::util::error::Context;
use crate::util::json::Json;

use super::env::EnvStamp;
use super::harness::Measurement;

/// Top-level `schema` tag of the trajectory file.
pub const SCHEMA_NAME: &str = "bitonic-tpu-bench-trajectory";
/// Schema version understood by this crate.
pub const SCHEMA_VERSION: u64 = 1;

/// Core record fields; every other key on a record object is an extra
/// and round-trips verbatim. `keys_per_sec` is derived (rewritten on
/// save, ignored on load).
const CORE_FIELDS: [&str; 10] = [
    "bench", "substrate", "dist", "dtype", "n", "batch", "ms", "p10_ms", "p90_ms", "keys_per_sec",
];

/// One measured scenario: which code sorted what, and how fast.
#[derive(Clone, Debug, PartialEq)]
pub struct BenchRecord {
    /// Producer (bench binary or subcommand): `"matrix"`, `"cpu_sorts"`…
    pub bench: String,
    /// Sorting substrate (see [`super::matrix::Substrate::name`] for the
    /// canonical menu; free-form for bench-specific entries).
    pub substrate: String,
    /// Input distribution name ([`crate::workload::Distribution::name`]).
    pub dist: String,
    /// Key dtype name: `"u32"`, `"i32"`, `"f32"`, `"u64"`, `"f64"`.
    pub dtype: String,
    /// Keys per row (CPU substrates: the whole array).
    pub n: usize,
    /// Rows per measured batch (1 for CPU substrates).
    pub batch: usize,
    /// Median wall milliseconds per batch.
    pub ms: f64,
    /// 10th-percentile milliseconds, when the harness measured spread.
    pub p10_ms: Option<f64>,
    /// 90th-percentile milliseconds, when the harness measured spread.
    pub p90_ms: Option<f64>,
    /// Substrate-specific extra fields (always a [`Json::Obj`]): e.g.
    /// `variant`, `threads`, `hbm_passes`, `speedup_vs_quicksort`.
    pub extra: Json,
}

impl BenchRecord {
    /// New record with `batch = 1` and no timing yet.
    pub fn new(
        bench: impl Into<String>,
        substrate: impl Into<String>,
        dist: impl Into<String>,
        dtype: impl Into<String>,
        n: usize,
    ) -> Self {
        Self {
            bench: bench.into(),
            substrate: substrate.into(),
            dist: dist.into(),
            dtype: dtype.into(),
            n,
            batch: 1,
            ms: 0.0,
            p10_ms: None,
            p90_ms: None,
            extra: Json::obj(),
        }
    }

    /// Set the rows-per-batch of the measured execution.
    pub fn with_batch(mut self, batch: usize) -> Self {
        self.batch = batch;
        self
    }

    /// Set the median milliseconds directly (single-shot measurements).
    pub fn with_ms(mut self, ms: f64) -> Self {
        self.ms = ms;
        self
    }

    /// Take median/p10/p90 from a harness [`Measurement`].
    pub fn with_timing(mut self, m: &Measurement) -> Self {
        self.ms = m.median_ms();
        self.p10_ms = Some(m.p10_ns() as f64 / 1e6);
        self.p90_ms = Some(m.p90_ns() as f64 / 1e6);
        self
    }

    /// Attach an extra field (kept verbatim in the JSON).
    pub fn with_extra(mut self, key: &str, value: impl Into<Json>) -> Self {
        self.extra.set(key, value);
        self
    }

    /// Milliseconds per row — the unit the report compares CPU
    /// (batch = 1) and device (batch = B) substrates in.
    pub fn ms_per_row(&self) -> f64 {
        self.ms / self.batch.max(1) as f64
    }

    /// Sorted keys per second over the whole batch.
    pub fn keys_per_sec(&self) -> f64 {
        if self.ms > 0.0 {
            (self.batch * self.n) as f64 / (self.ms / 1e3)
        } else {
            0.0
        }
    }

    /// An extra field as a number.
    pub fn extra_f64(&self, key: &str) -> Option<f64> {
        self.extra.get(key).and_then(Json::as_f64)
    }

    /// An extra field as a string.
    pub fn extra_str(&self, key: &str) -> Option<&str> {
        self.extra.get(key).and_then(Json::as_str)
    }

    /// Serialise as a flat JSON object (core fields first, extras after,
    /// insertion order preserved).
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("bench", self.bench.as_str())
            .set("substrate", self.substrate.as_str())
            .set("dist", self.dist.as_str())
            .set("dtype", self.dtype.as_str())
            .set("n", self.n)
            .set("batch", self.batch)
            .set("ms", self.ms);
        if let Some(p10) = self.p10_ms {
            o.set("p10_ms", p10);
        }
        if let Some(p90) = self.p90_ms {
            o.set("p90_ms", p90);
        }
        o.set("keys_per_sec", self.keys_per_sec());
        if let Some(fields) = self.extra.fields() {
            for (k, v) in fields {
                o.set(k, v.clone());
            }
        }
        o
    }

    /// Parse and validate one record object. Core fields are required
    /// with the right types; unknown fields become extras; the derived
    /// `keys_per_sec` is ignored (recomputed on save).
    pub fn from_json(v: &Json) -> crate::Result<Self> {
        v.fields()
            .ok_or_else(|| crate::err!("record is not an object"))?;
        let str_field = |key: &str| -> crate::Result<String> {
            let s = v
                .get(key)
                .and_then(Json::as_str)
                .ok_or_else(|| crate::err!("record: missing/invalid string field {key:?}"))?;
            crate::ensure!(!s.is_empty(), "record: field {key:?} is empty");
            Ok(s.to_string())
        };
        let usize_field = |key: &str| -> crate::Result<usize> {
            let x = v
                .get(key)
                .and_then(Json::as_usize)
                .ok_or_else(|| crate::err!("record: missing/invalid integer field {key:?}"))?;
            crate::ensure!(x >= 1, "record: field {key:?} must be >= 1");
            Ok(x)
        };
        let ms = v
            .get("ms")
            .and_then(Json::as_f64)
            .ok_or_else(|| crate::err!("record: missing/invalid number field \"ms\""))?;
        crate::ensure!(ms >= 0.0, "record: \"ms\" must be >= 0, got {ms}");
        let opt_ms = |key: &str| -> crate::Result<Option<f64>> {
            match v.get(key) {
                None => Ok(None),
                Some(x) => {
                    let x = x
                        .as_f64()
                        .ok_or_else(|| crate::err!("record: field {key:?} must be a number"))?;
                    crate::ensure!(x >= 0.0, "record: field {key:?} must be >= 0");
                    Ok(Some(x))
                }
            }
        };
        let mut extra = Json::obj();
        for (k, val) in v.fields().unwrap() {
            if !CORE_FIELDS.contains(&k.as_str()) {
                extra.set(k, val.clone());
            }
        }
        Ok(Self {
            bench: str_field("bench")?,
            substrate: str_field("substrate")?,
            dist: str_field("dist")?,
            dtype: str_field("dtype")?,
            n: usize_field("n")?,
            batch: usize_field("batch")?,
            ms,
            p10_ms: opt_ms("p10_ms")?,
            p90_ms: opt_ms("p90_ms")?,
            extra,
        })
    }
}

/// The whole trajectory file: env stamp + every appended record.
#[derive(Clone, Debug, PartialEq)]
pub struct Trajectory {
    /// Host/build environment captured when the file was first created.
    pub env: EnvStamp,
    /// All records, append order.
    pub records: Vec<BenchRecord>,
}

impl Default for Trajectory {
    fn default() -> Self {
        Self::new()
    }
}

impl Trajectory {
    /// Fresh empty trajectory stamped with the current environment.
    pub fn new() -> Self {
        Self {
            env: EnvStamp::capture(),
            records: Vec::new(),
        }
    }

    /// Canonical trajectory location: `$BENCH_TRAJECTORY_JSON` if set,
    /// else `BENCH_trajectory.json` at the **workspace root** (the
    /// parent of this crate's manifest dir, resolved at compile time
    /// like [`crate::runtime::default_artifacts_dir`]). Anchoring
    /// matters because the producers run with different cwds — `cargo
    /// run` keeps the shell's, `cargo bench` sets the *package* root
    /// `rust/` — and "one file, many writers" only works if they all
    /// resolve the same file without per-caller env plumbing.
    pub fn default_path() -> PathBuf {
        if let Ok(path) = std::env::var("BENCH_TRAJECTORY_JSON") {
            return PathBuf::from(path);
        }
        let manifest = Path::new(env!("CARGO_MANIFEST_DIR"));
        manifest.parent().unwrap_or(manifest).join("BENCH_trajectory.json")
    }

    /// Append a record.
    pub fn push(&mut self, record: BenchRecord) {
        self.records.push(record);
    }

    /// Serialise the whole trajectory document.
    pub fn to_json(&self) -> Json {
        let mut doc = Json::obj();
        doc.set("schema", SCHEMA_NAME)
            .set("version", SCHEMA_VERSION)
            .set("env", self.env.to_json());
        let mut records = Json::arr();
        for r in &self.records {
            records.push(r.to_json());
        }
        doc.set("records", records);
        doc
    }

    /// Parse and validate a trajectory document.
    pub fn from_json(doc: &Json) -> crate::Result<Self> {
        let schema = doc
            .get("schema")
            .and_then(Json::as_str)
            .ok_or_else(|| crate::err!("trajectory: missing \"schema\" tag"))?;
        crate::ensure!(
            schema == SCHEMA_NAME,
            "trajectory: schema is {schema:?}, want {SCHEMA_NAME:?}"
        );
        let version = doc
            .get("version")
            .and_then(Json::as_usize)
            .ok_or_else(|| crate::err!("trajectory: missing \"version\""))?;
        crate::ensure!(
            version as u64 == SCHEMA_VERSION,
            "trajectory: version {version} not understood (this crate reads {SCHEMA_VERSION})"
        );
        let env = EnvStamp::from_json(
            doc.get("env")
                .ok_or_else(|| crate::err!("trajectory: missing \"env\""))?,
        )?;
        let items = doc
            .get("records")
            .and_then(Json::items)
            .ok_or_else(|| crate::err!("trajectory: missing \"records\" array"))?;
        let mut records = Vec::with_capacity(items.len());
        for (i, item) in items.iter().enumerate() {
            records.push(
                BenchRecord::from_json(item)
                    .map_err(|e| e.context(format!("trajectory record [{i}]")))?,
            );
        }
        Ok(Self { env, records })
    }

    /// Load and validate a trajectory file.
    pub fn load(path: impl AsRef<Path>) -> crate::Result<Self> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path).with_context(|| {
            format!("reading bench trajectory {path:?} — generate one with `bitonic-tpu bench`")
        })?;
        let doc = Json::parse(&text)
            .map_err(|e| e.context(format!("parsing bench trajectory {path:?}")))?;
        Self::from_json(&doc)
            .map_err(|e| e.context(format!("validating bench trajectory {path:?}")))
    }

    /// Load if the file exists, else start a fresh trajectory. A file
    /// that exists but fails validation is an error — appending to a
    /// corrupt trajectory would launder it.
    pub fn load_or_new(path: impl AsRef<Path>) -> crate::Result<Self> {
        if path.as_ref().exists() {
            Self::load(path)
        } else {
            Ok(Self::new())
        }
    }

    /// Write the trajectory file (pretty-printed, trailing newline).
    /// Write-then-rename, so a producer killed mid-write (the CI smokes
    /// run under `timeout --signal=KILL`) can never leave a torn
    /// half-document that fails every later bench run at load.
    pub fn save(&self, path: impl AsRef<Path>) -> crate::Result<()> {
        let path = path.as_ref();
        let tmp = path.with_extension("json.tmp");
        std::fs::write(&tmp, self.to_json().render())
            .with_context(|| format!("writing bench trajectory {tmp:?}"))?;
        std::fs::rename(&tmp, path)
            .with_context(|| format!("moving bench trajectory into place at {path:?}"))
    }

    /// The append protocol every bench uses: load-or-create `path`, add
    /// `records`, rewrite. Returns the total record count afterwards.
    pub fn append_to(path: impl AsRef<Path>, records: Vec<BenchRecord>) -> crate::Result<usize> {
        let mut t = Self::load_or_new(&path)?;
        t.records.extend(records);
        t.save(&path)?;
        Ok(t.records.len())
    }

    /// Bench-binary epilogue: append `records` to [`Self::default_path`],
    /// report the running total on stdout, and **exit the process** with
    /// a failure code when the existing file is malformed — a corrupt
    /// trajectory must fail the bench run loudly, never be clobbered.
    /// One definition so the six bench binaries cannot drift; library
    /// code should use [`Self::append_to`] and handle the error.
    pub fn append_default_or_exit(records: Vec<BenchRecord>) -> usize {
        let path = Self::default_path();
        match Self::append_to(&path, records) {
            Ok(total) => {
                println!("trajectory: {path:?} now holds {total} records");
                total
            }
            Err(e) => {
                eprintln!("ERROR: could not append bench trajectory: {e:#}");
                std::process::exit(1);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("bitonic-tpu-record-tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    fn sample_record() -> BenchRecord {
        BenchRecord::new("matrix", "quicksort", "uniform", "u32", 65536)
            .with_batch(4)
            .with_ms(2.5)
            .with_extra("threads", 4usize)
            .with_extra("variant", "optimized")
    }

    #[test]
    fn record_json_roundtrip_preserves_everything() {
        let mut r = sample_record();
        r.p10_ms = Some(2.25);
        r.p90_ms = Some(3.5);
        let back = BenchRecord::from_json(&r.to_json()).unwrap();
        assert_eq!(back, r);
        // Through text too (the on-disk path).
        let back = BenchRecord::from_json(&Json::parse(&r.to_json().render()).unwrap()).unwrap();
        assert_eq!(back, r);
        assert_eq!(back.extra_str("variant"), Some("optimized"));
        assert_eq!(back.extra_f64("threads"), Some(4.0));
    }

    #[test]
    fn derived_fields_computed_not_trusted() {
        let r = sample_record();
        // 4 rows × 65536 keys in 2.5 ms.
        let expect = (4.0 * 65536.0) / (2.5 / 1e3);
        assert!((r.keys_per_sec() - expect).abs() < 1e-6);
        assert!((r.ms_per_row() - 0.625).abs() < 1e-12);
        // A lying keys_per_sec in the JSON is ignored on load.
        let mut j = r.to_json();
        j.set("keys_per_sec", 1.0);
        let back = BenchRecord::from_json(&j).unwrap();
        assert!((back.keys_per_sec() - expect).abs() < 1e-6);
        // Zero-ms records report zero throughput instead of inf.
        let z = BenchRecord::new("b", "s", "d", "u32", 8);
        assert_eq!(z.keys_per_sec(), 0.0);
    }

    #[test]
    fn record_rejects_missing_and_invalid_fields() {
        let good = sample_record().to_json();
        for field in ["bench", "substrate", "dist", "dtype", "n", "batch", "ms"] {
            let mut j = Json::obj();
            for (k, v) in good.fields().unwrap() {
                if k != field {
                    j.set(k, v.clone());
                }
            }
            assert!(BenchRecord::from_json(&j).is_err(), "accepted without {field}");
        }
        for (field, bad) in [
            ("n", Json::Num(0.0)),
            ("n", Json::Str("64".into())),
            ("batch", Json::Num(2.5)),
            ("ms", Json::Num(-1.0)),
            ("ms", Json::Str("fast".into())),
            ("p10_ms", Json::Str("slow".into())),
            ("bench", Json::Str(String::new())),
        ] {
            let mut j = good.clone();
            j.set(field, bad);
            assert!(BenchRecord::from_json(&j).is_err(), "accepted bad {field}");
        }
        assert!(BenchRecord::from_json(&Json::arr()).is_err());
    }

    #[test]
    fn trajectory_file_roundtrip_and_append() {
        let path = tmp("roundtrip.json");
        let _ = std::fs::remove_file(&path);
        // First append creates the file.
        let count = Trajectory::append_to(&path, vec![sample_record()]).unwrap();
        assert_eq!(count, 1);
        // Second append extends it, same env stamp.
        let first = Trajectory::load(&path).unwrap();
        let count =
            Trajectory::append_to(&path, vec![sample_record().with_ms(9.0)]).unwrap();
        assert_eq!(count, 2);
        let second = Trajectory::load(&path).unwrap();
        assert_eq!(second.env, first.env);
        assert_eq!(second.records.len(), 2);
        assert_eq!(second.records[0], first.records[0]);
        assert!((second.records[1].ms - 9.0).abs() < 1e-12);
    }

    #[test]
    fn load_rejects_malformed_trajectories() {
        let path = tmp("malformed.json");
        // Not JSON at all.
        std::fs::write(&path, "not json {").unwrap();
        assert!(Trajectory::load(&path).is_err());
        // Wrong schema tag.
        std::fs::write(&path, r#"{"schema": "other", "version": 1}"#).unwrap();
        assert!(Trajectory::load(&path).is_err());
        // Future version.
        let mut t = Trajectory::new();
        t.push(sample_record());
        let mut doc = t.to_json();
        doc.set("version", 999usize);
        std::fs::write(&path, doc.render()).unwrap();
        assert!(Trajectory::load(&path).is_err());
        // A record with a broken field, index named in the error.
        let mut doc = t.to_json();
        match doc.get("records").unwrap().clone() {
            Json::Arr(mut items) => {
                items[0].set("ms", "not a number");
                doc.set("records", Json::Arr(items));
            }
            _ => unreachable!(),
        }
        std::fs::write(&path, doc.render()).unwrap();
        let err = format!("{:#}", Trajectory::load(&path).unwrap_err());
        assert!(err.contains("record [0]"), "{err}");
        // load_or_new refuses corrupt files rather than clobbering them…
        assert!(Trajectory::load_or_new(&path).is_err());
        // …but starts fresh when the file simply does not exist.
        let missing = tmp("missing.json");
        let _ = std::fs::remove_file(&missing);
        assert!(Trajectory::load_or_new(&missing).unwrap().records.is_empty());
        // Missing file on load names the generating command.
        let err = format!("{:#}", Trajectory::load(&missing).unwrap_err());
        assert!(err.contains("bitonic-tpu bench"), "{err}");
    }

    #[test]
    fn default_path_is_workspace_anchored() {
        // `cargo run` (shell cwd) and `cargo bench` (cwd = rust/) must
        // agree on ONE trajectory file, so the default cannot be
        // cwd-relative.
        let p = Trajectory::default_path();
        assert!(p.ends_with("BENCH_trajectory.json"), "{p:?}");
        if std::env::var("BENCH_TRAJECTORY_JSON").is_err() {
            assert!(p.is_absolute(), "{p:?}");
        }
    }

    #[test]
    fn empty_trajectory_is_valid() {
        let path = tmp("empty.json");
        Trajectory::new().save(&path).unwrap();
        let t = Trajectory::load(&path).unwrap();
        assert!(t.records.is_empty());
    }
}
