//! Benchmark subsystem: measurement harness, the machine-readable
//! **bench trajectory**, the survey-style scenario matrix, and the
//! `RESULTS.md` report generator.
//!
//! The paper's headline claim is empirical (~20× GPU-bitonic over CPU
//! quicksort, peaking around 30×), so this crate treats benchmark output
//! as a first-class artifact rather than scattered stdout tables:
//!
//! * [`harness`] — warmup + adaptive repetition + robust statistics
//!   ([`Bench`], [`Measurement`]); the criterion stand-in every bench
//!   binary uses.
//! * [`record`] — the JSON schema: one [`BenchRecord`] per measured
//!   scenario, appended by every bench run to a single
//!   [`Trajectory`] file (`BENCH_trajectory.json`), schema-validated on
//!   load so future PRs diff baselines instead of re-deriving them.
//! * [`env`] — the [`EnvStamp`] recorded into each trajectory: numbers
//!   without host/thread/build context are not comparable.
//! * [`matrix`] — the survey-grade scenario sweep (substrates ×
//!   distributions × dtypes × sizes, after Božidar & Dobravec's
//!   parallel-sort comparison and the Arkhipov et al. GPU-sorting
//!   survey): CPU substrates run directly, device-path substrates route
//!   through the real [`crate::runtime::Registry`] + autotune plan
//!   policy. Drives the `bitonic-tpu bench` subcommand.
//! * [`report`] — renders a trajectory into the paper-style `RESULTS.md`
//!   (Table-1 matrix, pass-count ablation, speedup-vs-quicksort
//!   headline). Pure function of the JSON: regeneration is
//!   deterministic. Drives the `bitonic-tpu report` subcommand.
//! * [`diff`] — per-cell tolerance comparison of two trajectories at
//!   equal env stamps, with a >2× slowdown gate. Drives
//!   `bitonic-tpu report --diff <old> [--gate]`.
//! * [`loadgen`] — the closed-/open-loop serving load generator:
//!   drives a live `serve-tcp` endpoint with a seeded
//!   [`crate::workload::TrafficMix`] and records client-side
//!   p50/p99/p999, throughput, SLO-miss and shed rates as `loadgen`
//!   trajectory records. Drives the `bitonic-tpu loadgen` subcommand.
//!
//! ```text
//! benches/* ─┐
//! bitonic-tpu bench ──> Trajectory::append ──> BENCH_trajectory.json
//!                                                   │ Trajectory::load
//!                              bitonic-tpu report ──┴──> RESULTS.md
//! ```

pub mod diff;
pub mod env;
pub mod harness;
pub mod loadgen;
pub mod matrix;
pub mod record;
pub mod report;

pub use diff::{diff_trajectories, TrajectoryDiff, DIFF_SLOWDOWN_GATE, DIFF_TOLERANCE};
pub use loadgen::{run_loadgen, LoadMode, LoadgenConfig, LoadgenReport};
pub use env::EnvStamp;
pub use harness::{black_box, Bench, Measurement};
pub use matrix::{MatrixConfig, MatrixDtype, Substrate};
pub use matrix::{run_matrix, run_mega_cells, run_pass_ablation, DeviceCtx};
pub use record::{BenchRecord, Trajectory, SCHEMA_NAME, SCHEMA_VERSION};
pub use report::render_results;
