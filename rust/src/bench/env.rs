//! Environment stamping for bench trajectories.
//!
//! A throughput number is meaningless without the host it was measured
//! on: the trajectory JSON therefore opens with an [`EnvStamp`] captured
//! when the file is first created. The stamp is informational — the
//! report prints it, nothing branches on it — but it is what lets a
//! future reader decide whether two trajectories are comparable at all.

use crate::util::json::Json;

/// Where and how a trajectory's numbers were measured.
#[derive(Clone, Debug, PartialEq)]
pub struct EnvStamp {
    /// Operating system (`std::env::consts::OS`).
    pub os: String,
    /// CPU architecture (`std::env::consts::ARCH`).
    pub arch: String,
    /// `available_parallelism()` at capture time (0 = unknown).
    pub cpus: usize,
    /// `bitonic_tpu` crate version that ran the benches.
    pub crate_version: String,
    /// True when the binary was built with debug assertions — a loud
    /// marker that absolute numbers are not release-grade.
    pub debug_assertions: bool,
    /// Unix timestamp (seconds) of the first record batch (0 = unknown).
    pub unix_secs: u64,
}

impl EnvStamp {
    /// Capture the current process environment.
    pub fn capture() -> Self {
        Self {
            os: std::env::consts::OS.to_string(),
            arch: std::env::consts::ARCH.to_string(),
            cpus: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(0),
            crate_version: env!("CARGO_PKG_VERSION").to_string(),
            debug_assertions: cfg!(debug_assertions),
            unix_secs: std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .map(|d| d.as_secs())
                .unwrap_or(0),
        }
    }

    /// Serialise into the trajectory's `env` object.
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("os", self.os.as_str())
            .set("arch", self.arch.as_str())
            .set("cpus", self.cpus)
            .set("crate_version", self.crate_version.as_str())
            .set("debug_assertions", self.debug_assertions)
            .set("unix_secs", self.unix_secs);
        o
    }

    /// Parse a trajectory's `env` object (every field required — the
    /// stamp is written by [`EnvStamp::to_json`] only).
    pub fn from_json(v: &Json) -> crate::Result<Self> {
        let str_field = |key: &str| -> crate::Result<String> {
            v.get(key)
                .and_then(Json::as_str)
                .map(str::to_string)
                .ok_or_else(|| crate::err!("env stamp: missing/invalid string field {key:?}"))
        };
        Ok(Self {
            os: str_field("os")?,
            arch: str_field("arch")?,
            cpus: v
                .get("cpus")
                .and_then(Json::as_usize)
                .ok_or_else(|| crate::err!("env stamp: missing/invalid field \"cpus\""))?,
            crate_version: str_field("crate_version")?,
            debug_assertions: v
                .get("debug_assertions")
                .and_then(Json::as_bool)
                .ok_or_else(|| crate::err!("env stamp: missing/invalid field \"debug_assertions\""))?,
            unix_secs: v
                .get("unix_secs")
                .and_then(Json::as_usize)
                .ok_or_else(|| crate::err!("env stamp: missing/invalid field \"unix_secs\""))?
                as u64,
        })
    }

    /// One-line human summary for report headers.
    pub fn summary(&self) -> String {
        format!(
            "{}/{} · {} cpu(s) · bitonic-tpu v{}{}",
            self.os,
            self.arch,
            self.cpus,
            self.crate_version,
            if self.debug_assertions { " · DEBUG BUILD" } else { "" }
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn capture_roundtrips_through_json() {
        let e = EnvStamp::capture();
        assert!(!e.os.is_empty());
        assert!(!e.crate_version.is_empty());
        let back = EnvStamp::from_json(&e.to_json()).unwrap();
        assert_eq!(back, e);
        // Render → parse → from_json too (the on-disk path).
        let back = EnvStamp::from_json(&Json::parse(&e.to_json().render()).unwrap()).unwrap();
        assert_eq!(back, e);
    }

    #[test]
    fn from_json_rejects_missing_fields() {
        let mut o = EnvStamp::capture().to_json();
        o.set("cpus", "four"); // wrong type
        assert!(EnvStamp::from_json(&o).is_err());
        assert!(EnvStamp::from_json(&Json::obj()).is_err());
        assert!(EnvStamp::from_json(&Json::Null).is_err());
    }

    #[test]
    fn summary_flags_debug_builds() {
        let mut e = EnvStamp::capture();
        e.debug_assertions = true;
        assert!(e.summary().contains("DEBUG BUILD"));
        e.debug_assertions = false;
        assert!(!e.summary().contains("DEBUG BUILD"));
    }
}
