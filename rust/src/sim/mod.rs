//! GPU cost-model simulator — the substitution for the paper's testbed
//! (Intel Xeon E5-2620 + NVIDIA Kepler K10), which we do not have.
//!
//! What the paper used → what we built → why the substitution preserves
//! the relevant behaviour (DESIGN.md §4): Table 1's GPU columns are
//! dominated by exactly two quantities the paper itself identifies as the
//! optimization targets — the number of kernel launches and the number of
//! passes over global memory. Both are *schedule* properties, computed
//! exactly from [`crate::sort::network::Network::launches`], not silicon
//! properties. The simulator charges:
//!
//! ```text
//! T(variant, n) =   launches · t_launch                      (latency term)
//!                 + Σ_global passes · 2·4·n / BW_gmem_eff     (bandwidth term)
//!                 + Σ_fused  tile traffic  / BW_shmem         (in-block term)
//!                 + compare_exchanges / throughput_cx          (ALU term)
//! ```
//!
//! Two calibration constants (`t_launch`, `BW_gmem_eff`) are fit against
//! two cells of the paper's Table 1 ([`calibrate`]); everything else is
//! *predicted* and compared against the remaining ten rows × three
//! columns in EXPERIMENTS.md.
//!
//! [`trace`] additionally provides a transaction-level mode that walks the
//! compare-exchange index stream of a step and counts 128-byte coalesced
//! transactions and shared-memory bank conflicts — used for the ablation
//! study (why stride-1 steps from global memory are not the bottleneck the
//! naive coalescing argument suggests: partners at stride ≥ 32 always
//! coalesce perfectly; it is the *pass count* that matters, which is the
//! paper's own conclusion).

pub mod analytic;
pub mod calibrate;
pub mod device;
pub mod trace;

pub use analytic::{simulate, SimResult};
pub use calibrate::{calibrate_from_table1, Calibration, PAPER_TABLE1};
pub use device::Device;
