//! Analytic cost model: walk the exact launch schedule of a variant and
//! charge each launch's latency, bandwidth and ALU terms.

use super::device::Device;
use crate::sort::network::{Launch, Network, Variant};

/// Cost breakdown for one simulated sort.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SimResult {
    /// Keys sorted.
    pub n: usize,
    /// Variant simulated.
    pub variant: Variant,
    /// Number of kernel launches.
    pub launches: usize,
    /// Launch-overhead seconds.
    pub t_launch: f64,
    /// Global-memory seconds.
    pub t_gmem: f64,
    /// Shared-memory seconds.
    pub t_shmem: f64,
    /// Compare-exchange ALU seconds.
    pub t_alu: f64,
}

impl SimResult {
    /// Total simulated milliseconds. Bandwidth/ALU overlap latency on a
    /// GPU, but the paper's per-step kernels are serialised by host sync,
    /// so terms add; within one launch the max of gmem/alu dominates.
    pub fn total_ms(&self) -> f64 {
        (self.t_launch + self.t_gmem + self.t_shmem + self.t_alu) * 1e3
    }
}

/// Simulate sorting `n` 32-bit keys with `variant` on `device`.
///
/// `key_bytes` is 4 for the paper's workload; the future-work experiment
/// (E8) passes 8 for 64-bit keys.
pub fn simulate(device: &Device, variant: Variant, n: usize, key_bytes: usize) -> SimResult {
    let net = Network::new(n);
    let block = device.block_keys(key_bytes).min(n);
    let launches = net.launches(variant, block);

    let pass_bytes = 2.0 * (n * key_bytes) as f64; // read + write whole array
    let mut t_launch = 0.0;
    let mut t_gmem = 0.0;
    let mut t_shmem = 0.0;
    let mut t_alu = 0.0;

    for l in &launches {
        t_launch += device.t_launch;
        // Every launch streams the array through global memory once.
        t_gmem += pass_bytes / device.bw_gmem;
        let steps = l.step_count() as f64;
        // Each step performs n/2 compare-exchanges.
        t_alu += steps * (n as f64 / 2.0) / device.cx_throughput;
        if let Launch::BlockFused { .. } = l {
            // In-block steps re-read/re-write the tile from shared memory
            // once per step (minus the one global pass already charged).
            let shmem_bytes = (steps - 1.0).max(0.0) * pass_bytes;
            t_shmem += shmem_bytes / device.bw_shmem;
        }
    }

    SimResult {
        n,
        variant,
        launches: launches.len(),
        t_launch,
        t_gmem,
        t_shmem,
        t_alu,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dev() -> Device {
        Device::k10_gk104()
    }

    #[test]
    fn variant_ordering_matches_paper() {
        // Table 1: Basic > Semi > Optimized at every size.
        for logn in [17usize, 20, 24, 28] {
            let n = 1 << logn;
            let basic = simulate(&dev(), Variant::Basic, n, 4).total_ms();
            let semi = simulate(&dev(), Variant::Semi, n, 4).total_ms();
            let opt = simulate(&dev(), Variant::Optimized, n, 4).total_ms();
            assert!(basic > semi, "n=2^{logn}: basic {basic} !> semi {semi}");
            assert!(semi > opt, "n=2^{logn}: semi {semi} !> opt {opt}");
        }
    }

    #[test]
    fn scaling_superlinear_in_n() {
        // O(n log^2 n): doubling n should a bit more than double time.
        let a = simulate(&dev(), Variant::Optimized, 1 << 20, 4).total_ms();
        let b = simulate(&dev(), Variant::Optimized, 1 << 21, 4).total_ms();
        assert!(b > 2.0 * a && b < 3.0 * a, "a={a} b={b}");
    }

    #[test]
    fn launch_counts_match_network() {
        let n = 1 << 20;
        let d = dev();
        for v in Variant::ALL {
            let r = simulate(&d, v, n, 4);
            assert_eq!(
                r.launches,
                Network::new(n).launches(v, d.block_keys(4)).len()
            );
        }
    }

    #[test]
    fn alu_term_charges_all_steps() {
        // Total ALU work is variant-independent (same network).
        let n = 1 << 18;
        let d = dev();
        let alus: Vec<f64> = Variant::ALL
            .iter()
            .map(|&v| simulate(&d, v, n, 4).t_alu)
            .collect();
        for w in alus.windows(2) {
            assert!((w[0] - w[1]).abs() < 1e-12);
        }
    }

    #[test]
    fn semi_improvement_band_plausible() {
        // Paper Table 1: Semi/Basic ≈ 0.88–0.95 at large n.
        let n = 1 << 24;
        let basic = simulate(&dev(), Variant::Basic, n, 4).total_ms();
        let semi = simulate(&dev(), Variant::Semi, n, 4).total_ms();
        let ratio = semi / basic;
        assert!(
            (0.3..0.97).contains(&ratio),
            "semi/basic ratio {ratio} wildly off"
        );
    }

    #[test]
    fn bigger_keys_cost_more() {
        let a = simulate(&dev(), Variant::Optimized, 1 << 20, 4).total_ms();
        let b = simulate(&dev(), Variant::Optimized, 1 << 20, 8).total_ms();
        assert!(b > a);
    }
}
