//! Calibration of the cost model against the paper's Table 1.
//!
//! Exactly two constants are fit — kernel-launch overhead `t_launch` and
//! effective global-memory bandwidth `bw_gmem` — using two anchor cells of
//! the *Basic* column (256K and 16M). Everything else (the other ten Basic
//! cells, and the entire Semi and Optimized columns) is then a genuine
//! prediction of the model; EXPERIMENTS.md reports predicted-vs-paper for
//! all of them.
//!
//! Note (recorded in EXPERIMENTS.md): the bandwidth the paper's numbers
//! imply (~500 GB/s for 300 full passes over 64 MiB in 80 ms) exceeds a
//! single GK104's 160 GB/s datasheet peak — the authors likely used both
//! K10 dies and/or measured without transfer setup. Calibration absorbs
//! this into `bw_gmem`; the *shape* conclusions are unaffected because all
//! three variants share the constant.

use super::analytic::simulate;
use super::device::Device;
use crate::sort::network::Variant;

/// One row of the paper's Table 1 (times in milliseconds; `None` = the
/// paper prints "—").
#[derive(Clone, Copy, Debug)]
pub struct PaperRow {
    /// Array size label (elements).
    pub n: usize,
    /// CPU quick sort ms.
    pub cpu_quick: Option<f64>,
    /// CPU bitonic sort ms.
    pub cpu_bitonic: f64,
    /// GPU basic ms.
    pub gpu_basic: f64,
    /// GPU semi (optimization 1) ms.
    pub gpu_semi: f64,
    /// GPU optimized (optimizations 1+2) ms.
    pub gpu_optimized: f64,
    /// Speedup ratio the paper reports (quick / optimized).
    pub ratio: Option<f64>,
}

/// The paper's Table 1, transcribed. The "521K" row is the paper's typo
/// for 512K.
pub const PAPER_TABLE1: [PaperRow; 12] = [
    PaperRow { n: 128 << 10, cpu_quick: None,           cpu_bitonic: 30.0,     gpu_basic: 0.76,    gpu_semi: 0.46,    gpu_optimized: 0.36,    ratio: None },
    PaperRow { n: 256 << 10, cpu_quick: Some(20.0),     cpu_bitonic: 60.0,     gpu_basic: 1.21,    gpu_semi: 0.87,    gpu_optimized: 0.66,    ratio: Some(30.2) },
    PaperRow { n: 512 << 10, cpu_quick: Some(30.0),     cpu_bitonic: 110.0,    gpu_basic: 2.22,    gpu_semi: 1.78,    gpu_optimized: 1.31,    ratio: Some(22.7) },
    PaperRow { n: 1 << 20,   cpu_quick: Some(80.0),     cpu_bitonic: 250.0,    gpu_basic: 4.58,    gpu_semi: 3.89,    gpu_optimized: 2.80,    ratio: Some(28.5) },
    PaperRow { n: 2 << 20,   cpu_quick: Some(150.0),    cpu_bitonic: 550.0,    gpu_basic: 8.90,    gpu_semi: 7.95,    gpu_optimized: 5.87,    ratio: Some(25.5) },
    PaperRow { n: 4 << 20,   cpu_quick: Some(280.0),    cpu_bitonic: 1230.0,   gpu_basic: 18.14,   gpu_semi: 16.59,   gpu_optimized: 12.30,   ratio: Some(22.7) },
    PaperRow { n: 8 << 20,   cpu_quick: Some(590.0),    cpu_bitonic: 2670.0,   gpu_basic: 38.13,   gpu_semi: 35.29,   gpu_optimized: 26.36,   ratio: Some(22.3) },
    PaperRow { n: 16 << 20,  cpu_quick: Some(1230.0),   cpu_bitonic: 5880.0,   gpu_basic: 80.09,   gpu_semi: 75.52,   gpu_optimized: 56.27,   ratio: Some(21.8) },
    PaperRow { n: 32 << 20,  cpu_quick: Some(2570.0),   cpu_bitonic: 12900.0,  gpu_basic: 173.77,  gpu_semi: 162.56,  gpu_optimized: 120.93,  ratio: Some(21.3) },
    PaperRow { n: 64 << 20,  cpu_quick: Some(5360.0),   cpu_bitonic: 27780.0,  gpu_basic: 373.52,  gpu_semi: 350.87,  gpu_optimized: 258.61,  ratio: Some(20.7) },
    PaperRow { n: 128 << 20, cpu_quick: Some(11180.0),  cpu_bitonic: 59860.0,  gpu_basic: 803.16,  gpu_semi: 756.94,  gpu_optimized: 553.49,  ratio: Some(20.1) },
    PaperRow { n: 256 << 20, cpu_quick: Some(23260.0),  cpu_bitonic: 128660.0, gpu_basic: 1727.23, gpu_semi: 1631.92, gpu_optimized: 1185.02, ratio: Some(19.6) },
];

/// Fitted constants plus the device they apply to.
#[derive(Clone, Copy, Debug)]
pub struct Calibration {
    /// The calibrated device.
    pub device: Device,
    /// Anchor sizes used for the fit.
    pub anchors: [usize; 2],
}

/// Fit `t_launch` and `bw_gmem` so the Basic column matches the paper at
/// the two anchor sizes (256K and 16M), holding the nominal ALU and
/// shared-memory terms fixed.
pub fn calibrate_from_table1() -> Calibration {
    let nominal = Device::k10_gk104();
    let anchors = [256 << 10, 16 << 20];
    let cells: Vec<(usize, f64)> = anchors
        .iter()
        .map(|&n| {
            let row = PAPER_TABLE1.iter().find(|r| r.n == n).unwrap();
            (n, row.gpu_basic / 1e3) // seconds
        })
        .collect();

    // For Basic: T = L·a + L·8n·b + fixed(alu), with a = t_launch,
    // b = 1/bw. Two cells → 2×2 linear system.
    let term = |n: usize| -> (f64, f64, f64) {
        let r = simulate(&nominal, Variant::Basic, n, 4);
        let launches = r.launches as f64;
        (launches, launches * 8.0 * n as f64, r.t_alu)
    };
    let (l1, g1, f1) = term(cells[0].0);
    let (l2, g2, f2) = term(cells[1].0);
    let (y1, y2) = (cells[0].1 - f1, cells[1].1 - f2);
    let det = l1 * g2 - l2 * g1;
    let (mut a, mut b) = if det.abs() > 1e-30 {
        ((y1 * g2 - y2 * g1) / det, (l1 * y2 - l2 * y1) / det)
    } else {
        (nominal.t_launch, 1.0 / nominal.bw_gmem)
    };
    // Physically implausible fits (e.g. negative launch overhead because
    // the ALU estimate overshoots) degrade gracefully: clamp and refit the
    // single remaining unknown on the large anchor.
    if a <= 0.0 || !a.is_finite() {
        a = 1.0e-6;
        b = (y2 - l2 * a) / g2;
    }
    if b <= 0.0 || !b.is_finite() {
        b = 1.0 / nominal.bw_gmem;
    }

    let device = Device {
        t_launch: a,
        bw_gmem: 1.0 / b,
        ..nominal
    };
    Calibration { device, anchors }
}

impl Calibration {
    /// Predicted milliseconds for (variant, n) under the calibrated model.
    pub fn predict_ms(&self, variant: Variant, n: usize) -> f64 {
        simulate(&self.device, variant, n, 4).total_ms()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn anchors_reproduced_exactly() {
        let cal = calibrate_from_table1();
        for &n in &cal.anchors {
            let paper = PAPER_TABLE1.iter().find(|r| r.n == n).unwrap().gpu_basic;
            let pred = cal.predict_ms(Variant::Basic, n);
            assert!(
                (pred - paper).abs() / paper < 0.02,
                "anchor n={n}: pred {pred} vs paper {paper}"
            );
        }
    }

    #[test]
    fn non_anchor_basic_cells_within_2x() {
        // The model is two-parameter; the other ten Basic cells are
        // predictions and must land in the right ballpark (shape).
        let cal = calibrate_from_table1();
        for row in &PAPER_TABLE1 {
            let pred = cal.predict_ms(Variant::Basic, row.n);
            let ratio = pred / row.gpu_basic;
            assert!(
                (0.5..2.0).contains(&ratio),
                "n={}: pred {pred:.2} vs paper {:.2} (×{ratio:.2})",
                row.n,
                row.gpu_basic
            );
        }
    }

    #[test]
    fn predicted_variant_ordering_everywhere() {
        let cal = calibrate_from_table1();
        for row in &PAPER_TABLE1 {
            let b = cal.predict_ms(Variant::Basic, row.n);
            let s = cal.predict_ms(Variant::Semi, row.n);
            let o = cal.predict_ms(Variant::Optimized, row.n);
            assert!(b > s && s > o, "n={}: {b:.2} {s:.2} {o:.2}", row.n);
        }
    }

    #[test]
    fn optimized_speedup_factor_in_paper_band() {
        // Paper: Optimized/Basic ∈ [0.60, 0.75] across sizes ≥ 1M.
        let cal = calibrate_from_table1();
        for row in PAPER_TABLE1.iter().filter(|r| r.n >= 1 << 20) {
            let frac = cal.predict_ms(Variant::Optimized, row.n)
                / cal.predict_ms(Variant::Basic, row.n);
            assert!(
                (0.4..0.9).contains(&frac),
                "n={}: optimized/basic {frac:.2}",
                row.n
            );
        }
    }

    #[test]
    fn table_constants_transcribed() {
        assert_eq!(PAPER_TABLE1.len(), 12);
        assert_eq!(PAPER_TABLE1[0].n, 128 << 10);
        assert_eq!(PAPER_TABLE1[11].n, 256 << 20);
        assert_eq!(PAPER_TABLE1[11].gpu_optimized, 1185.02);
        // Ratio column consistency: quick / optimized ≈ printed ratio.
        for row in &PAPER_TABLE1 {
            if let (Some(q), Some(r)) = (row.cpu_quick, row.ratio) {
                let computed = q / row.gpu_optimized;
                assert!(
                    (computed - r).abs() / r < 0.02,
                    "n={}: {computed:.1} vs printed {r}",
                    row.n
                );
            }
        }
    }
}
