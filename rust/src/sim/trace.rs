//! Transaction-level trace mode: walk the real compare-exchange index
//! stream of a step and count 128-byte coalesced global-memory
//! transactions and shared-memory bank conflicts, per the CUDA coalescing
//! rules the paper's §2.2 describes (half-warp segment coalescing).
//!
//! This is the evidence behind the paper's (implicit) claim that the
//! optimizations work by reducing *pass counts*, not by improving
//! per-access coalescing: bitonic's partner accesses are already perfectly
//! coalesced for strides ≥ warp size, and for small strides the accesses
//! still fall in few segments. The ablation bench (E7) prints these
//! counts.

use super::device::Device;
use crate::sort::network::Step;

/// Tiny set of segment ids touched by one warp (≤ 64 entries, so a linear
/// scan beats hashing).
#[derive(Default)]
struct SegSet(Vec<usize>);

impl SegSet {
    fn insert(&mut self, seg: usize) {
        if !self.0.contains(&seg) {
            self.0.push(seg);
        }
    }
    fn len(&self) -> usize {
        self.0.len()
    }
}

/// Transaction counts for one kernel launch over `n` keys.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TraceCounts {
    /// 128-byte global-memory transactions issued (loads + stores).
    pub gmem_transactions: usize,
    /// Perfectly coalesced half-warp accesses.
    pub coalesced: usize,
    /// Divergent (multi-segment) half-warp accesses.
    pub divergent: usize,
    /// Shared-memory bank conflicts (extra cycles).
    pub bank_conflicts: usize,
}

/// Count global-memory transactions for one *global* compare-exchange
/// step: every thread `t` of every (half-)warp loads `a[t]` and
/// `a[t ^ stride]` and stores both back.
///
/// Transaction rule (cc 2.0 simplification of the paper's §2.2): a warp's
/// 32 4-byte accesses are serviced by one 128-byte transaction per
/// distinct 128-byte segment touched.
pub fn trace_global_step(device: &Device, n: usize, step: Step, key_bytes: usize) -> TraceCounts {
    let warp = device.warp;
    let seg_keys = 128 / key_bytes; // keys per 128-byte segment
    let mut counts = TraceCounts::default();

    // Threads are assigned one per *pair*: thread t handles pair
    // (i, i ^ j) where i is the t-th index with bit j clear.
    // We walk warps analytically: within a warp, the 32 consecutive pair
    // indices map to base addresses; count distinct segments.
    let pairs = n / 2;
    let stride = step.stride;
    let mut warp_start = 0usize;
    while warp_start < pairs {
        let lanes = warp.min(pairs - warp_start);
        // Low-side and high-side addresses of this warp's lanes.
        let mut segs_lo = SegSet::default();
        let mut segs_hi = SegSet::default();
        for lane in 0..lanes {
            let t = warp_start + lane;
            // The t-th index with bit `stride` clear: insert a 0 at bit
            // position log2(stride).
            let low_bits = t & (stride - 1);
            let high_bits = (t & !(stride - 1)) << 1;
            let i = high_bits | low_bits;
            let partner = i | stride;
            segs_lo.insert(i / seg_keys);
            segs_hi.insert(partner / seg_keys);
        }
        // Loads and stores each: 2 accesses per side.
        let tx = 2 * (segs_lo.len() + segs_hi.len());
        counts.gmem_transactions += tx;
        let ideal = 2 * 2 * lanes.div_ceil(seg_keys).max(1);
        if tx <= ideal {
            counts.coalesced += 1;
        } else {
            counts.divergent += 1;
        }
        warp_start += lanes;
    }
    counts
}

/// Count shared-memory bank conflicts for one in-block step: Kepler has 32
/// banks, 4-byte wide; thread `t` of a warp accesses `a[i]`/`a[i^j]` in
/// the tile. Conflict degree = max threads hitting the same bank with
/// different addresses.
pub fn trace_shared_step(device: &Device, block: usize, step: Step, key_bytes: usize) -> TraceCounts {
    let warp = device.warp;
    let banks = 32;
    let words_per_key = key_bytes / 4;
    let mut counts = TraceCounts::default();
    let pairs = block / 2;
    let stride = step.stride;
    let mut warp_start = 0usize;
    while warp_start < pairs {
        let lanes = warp.min(pairs - warp_start);
        // Bank histogram of the low-side accesses (high side is the same
        // pattern shifted by `stride` keys → identical conflict degree).
        let mut bank_addr: Vec<Option<usize>> = vec![None; banks];
        let mut conflicts = 0usize;
        for lane in 0..lanes {
            let t = warp_start + lane;
            let low_bits = t & (stride - 1);
            let high_bits = (t & !(stride - 1)) << 1;
            let i = high_bits | low_bits;
            let word = i * words_per_key;
            let bank = word % banks;
            match bank_addr[bank] {
                None => bank_addr[bank] = Some(word),
                Some(w) if w == word => {} // broadcast, no conflict
                Some(_) => conflicts += 1,
            }
        }
        counts.bank_conflicts += conflicts * 2; // both sides
        warp_start += lanes;
    }
    counts
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sort::network::Network;

    fn dev() -> Device {
        Device::k10_gk104()
    }

    #[test]
    fn large_strides_perfectly_coalesced() {
        // stride >= 32 keys: lane addresses are consecutive on both sides.
        let n = 1 << 16;
        for stride in [32usize, 256, 1 << 12] {
            let c = trace_global_step(&dev(), n, Step { phase_len: 2 * stride, stride }, 4);
            assert_eq!(c.divergent, 0, "stride {stride} diverged");
            assert!(c.coalesced > 0);
        }
    }

    #[test]
    fn transaction_count_lower_bound() {
        // At minimum, every key must be loaded and stored once:
        // 2 * n / seg_keys transactions.
        let n = 1 << 14;
        let net = Network::new(n);
        for step in net.steps() {
            let c = trace_global_step(&dev(), n, step, 4);
            assert!(
                c.gmem_transactions >= 2 * n / 32,
                "step {step:?}: {} transactions",
                c.gmem_transactions
            );
        }
    }

    #[test]
    fn small_strides_cost_no_extra_segments() {
        // stride < 32: low and high lanes interleave inside the same
        // segments, so total segments ≈ the ideal streaming count — the
        // quantitative version of "coalescing is not the bottleneck".
        let n = 1 << 14;
        let ideal = 2 * 2 * (n / 2) / 32; // loads+stores, both sides
        for stride in [1usize, 2, 8, 16] {
            let c = trace_global_step(&dev(), n, Step { phase_len: 2 * stride, stride }, 4);
            assert!(
                c.gmem_transactions <= 2 * ideal,
                "stride {stride}: {} vs ideal {ideal}",
                c.gmem_transactions
            );
        }
    }

    #[test]
    fn shared_step_u32_conflict_free_at_warp_strides() {
        // 4-byte keys at strides >= warp size: the 32 low-side addresses
        // of a warp are consecutive words → 32 distinct banks.
        let d = dev();
        for stride in [32usize, 64, 512, 2048] {
            let c = trace_shared_step(&d, 4096, Step { phase_len: 2 * stride, stride }, 4);
            assert_eq!(c.bank_conflicts, 0, "stride {stride}");
        }
    }

    #[test]
    fn shared_step_u32_small_strides_conflict() {
        // Strides < 32 interleave the low-side addresses with gaps, so a
        // warp's accesses revisit banks (2-way for stride 16, 2-way for
        // stride 1 where lanes hit words 2t) — the known shared-memory
        // bitonic penalty the literature pads around.
        let d = dev();
        for stride in [1usize, 2, 8, 16] {
            let c = trace_shared_step(&d, 4096, Step { phase_len: 2 * stride, stride }, 4);
            assert!(c.bank_conflicts > 0, "stride {stride} unexpectedly clean");
        }
    }

    #[test]
    fn shared_step_u64_has_two_way_conflicts() {
        // 8-byte keys stride the banks 2× faster → 2-way conflicts appear
        // (the known penalty for 64-bit keys the paper's §6 future work
        // would hit).
        let d = dev();
        let c = trace_shared_step(&d, 4096, Step { phase_len: 32, stride: 16 }, 8);
        assert!(c.bank_conflicts > 0);
    }
}
