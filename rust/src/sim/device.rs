//! Device parameter sets for the cost model.

/// GPU device parameters. Defaults model one GK104 die of the paper's
/// Kepler K10 (the paper uses a single-GPU implementation; K10 carries two
/// GK104s but bitonic sort as described runs on one).
#[derive(Clone, Copy, Debug)]
pub struct Device {
    /// Human-readable name.
    pub name: &'static str,
    /// Kernel-launch (host-synchronisation) overhead, seconds.
    pub t_launch: f64,
    /// Effective global-memory bandwidth for streaming access, bytes/s.
    /// (K10 peak per GK104 is 160 GB/s; effective streaming ≈ 75–85%.)
    pub bw_gmem: f64,
    /// Aggregate shared-memory bandwidth, bytes/s (per-SMX 32 banks × 4 B
    /// × core clock × 8 SMX ≈ 1 TB/s class).
    pub bw_shmem: f64,
    /// Compare-exchange throughput, operations/s (bound by integer
    /// min/max + select on 1536 cores/SMX-issue; ~1e11/s class).
    pub cx_throughput: f64,
    /// Shared memory per block, bytes (48 KiB on Kepler).
    pub shmem_bytes: usize,
    /// Threads per block the paper-style kernels use.
    pub threads_per_block: usize,
    /// Warp size (32 on all CUDA GPUs the paper considers).
    pub warp: usize,
}

impl Device {
    /// One GK104 of the paper's K10 — *pre-calibration* nominal values;
    /// `calibrate::calibrate_from_table1` refines `t_launch`/`bw_gmem`.
    pub fn k10_gk104() -> Self {
        Self {
            name: "K10 (GK104)",
            t_launch: 5.0e-6,
            bw_gmem: 0.80 * 160.0e9,
            bw_shmem: 1.0e12,
            cx_throughput: 1.2e11,
            shmem_bytes: 48 << 10,
            threads_per_block: 512,
            warp: 32,
        }
    }

    /// Keys per shared-memory tile for `key_bytes`-sized keys: the paper's
    /// optimization 1 copies a subsequence into shared memory; with
    /// double-buffering headroom the usable tile is half the 48 KiB.
    pub fn block_keys(&self, key_bytes: usize) -> usize {
        let usable = self.shmem_bytes / 2;
        (usable / key_bytes).next_power_of_two() >> 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn k10_defaults_sane() {
        let d = Device::k10_gk104();
        assert!(d.t_launch > 0.0 && d.t_launch < 1e-3);
        assert!(d.bw_gmem > 1e10 && d.bw_gmem < 1e12);
        assert_eq!(d.warp, 32);
    }

    #[test]
    fn block_keys_power_of_two_and_fits() {
        let d = Device::k10_gk104();
        let keys = d.block_keys(4);
        assert!(keys.is_power_of_two());
        assert!(keys * 4 <= d.shmem_bytes);
        // 48 KiB / 2 / 4 B = 6144 → 4096 keys.
        assert_eq!(keys, 4096);
    }
}
