//! Out-of-core hybrid sort: how a fixed-shape sorting accelerator is
//! actually deployed.
//!
//! The compiled artifacts sort fixed `(B, N)` shapes, so inputs larger
//! than the biggest artifact row are handled in three stages:
//!
//! 1. **Chunk sort** — split the input into `N`-key chunks (the largest
//!    sort artifact row), pad the tail with `MAX`, and sort chunks on the
//!    device, packing up to `B` chunks per execution (the artifact's
//!    batch dimension gives chunk-level parallelism for free).
//! 2. **Device merge tree** — merge sorted runs pairwise with the
//!    standalone bitonic-*merge* artifacts (`kind=merge`): a merge of two
//!    `m`-key runs costs `log2(2m)` compare-exchange steps instead of the
//!    `k(k+1)/2` a full re-sort would — the paper §3's own primitive used
//!    at the next level up.
//! 3. **CPU merge tail** — once runs outgrow the largest merge artifact,
//!    finish with a classic two-way merge on the CPU (bandwidth-bound
//!    streaming; the device has no artifact that large by construction).
//!
//! The result is exact (`quicksort` oracle in tests) for any input
//! length, not just powers of two.

use crate::util::error::Context;

use crate::runtime::registry::Key;
use crate::runtime::{ArtifactMeta, DeviceHandle, Manifest};
use crate::sort::network::Variant;

/// Statistics of one hybrid sort (for benches and the example driver).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct HybridStats {
    /// Device sort executions (each sorts up to B chunks).
    pub device_sorts: usize,
    /// Device merge executions.
    pub device_merges: usize,
    /// CPU two-way merges.
    pub cpu_merges: usize,
    /// Chunk size used.
    pub chunk: usize,
}

/// Hybrid device/CPU sorter over the artifact menu.
pub struct HybridSorter {
    handle: DeviceHandle,
    /// Largest (batch, n) ascending-u32 sort artifact.
    sort_meta: ArtifactMeta,
    /// Merge artifacts by input row length, ascending.
    merges: Vec<ArtifactMeta>,
}

impl HybridSorter {
    /// Build from a device handle + manifest snapshot (see
    /// `runtime::spawn_device_host`). Uses `variant` sort artifacts.
    pub fn new(
        handle: DeviceHandle,
        manifest: &Manifest,
        variant: Variant,
    ) -> crate::Result<Self> {
        let chunk = manifest
            .size_classes(variant)
            .into_iter()
            .map(|m| m.n)
            .max()
            .context("no sort artifacts in manifest")?;
        Self::with_chunk(handle, manifest, variant, chunk)
    }

    /// [`HybridSorter::new`] with an explicit chunk size (must match a
    /// sort artifact's row length). Smaller chunks push more levels of the
    /// merge tree onto the device — used by the ablation tests/benches.
    pub fn with_chunk(
        handle: DeviceHandle,
        manifest: &Manifest,
        variant: Variant,
        chunk: usize,
    ) -> crate::Result<Self> {
        let sort_meta = manifest
            .size_classes(variant)
            .into_iter()
            .filter(|m| m.n == chunk)
            .max_by_key(|m| m.batch)
            .with_context(|| format!("no sort artifact with rows of {chunk}"))?
            .clone();
        let merges: Vec<ArtifactMeta> =
            manifest.merge_classes().into_iter().cloned().collect();
        Ok(Self {
            handle,
            sort_meta,
            merges,
        })
    }

    /// Chunk size (keys per device-sorted run).
    pub fn chunk(&self) -> usize {
        self.sort_meta.n
    }

    /// Sort `keys` ascending, any length. Returns execution statistics.
    pub fn sort(&self, keys: &mut Vec<u32>) -> crate::Result<HybridStats> {
        let real_len = keys.len();
        let mut stats = HybridStats {
            chunk: self.chunk(),
            ..Default::default()
        };
        if real_len <= 1 {
            return Ok(stats);
        }
        let chunk = self.chunk();

        // ---- stage 1: device-sort chunks, B at a time ------------------
        let padded_len = real_len.div_ceil(chunk) * chunk;
        keys.resize(padded_len, u32::MAX);
        let (b, n) = (self.sort_meta.batch, self.sort_meta.n);
        let sort_key = Key::of(&self.sort_meta);
        let mut sorted = Vec::with_capacity(padded_len);
        for group in keys.chunks(b * n) {
            let mut buf = group.to_vec();
            buf.resize(b * n, u32::MAX);
            let out = self.handle.sort_u32(sort_key, buf)?;
            stats.device_sorts += 1;
            sorted.extend_from_slice(&out[..group.len()]);
        }
        debug_assert_eq!(sorted.len(), padded_len);

        // ---- stage 2: device merge tree ---------------------------------
        // Runs of length `run` merge pairwise into 2*run while a merge
        // artifact with rows of 2*run exists. A final *partial* pair (full
        // run + shorter tail) is merged by MAX-padding the tail half — the
        // merged prefix of the original length has the right multiset even
        // when real keys equal MAX (pads are indistinguishable by value).
        let mut run = chunk;
        while run < padded_len {
            let Some(meta) = self.merges.iter().find(|m| m.n == 2 * run) else {
                break;
            };
            let key = Key::of(meta);
            let (mb, mn) = (meta.batch, meta.n);
            debug_assert_eq!(mn, 2 * run);
            let mut next = Vec::with_capacity(padded_len);
            let mut i = 0;
            while i < padded_len {
                let full_pairs = ((padded_len - i) / (2 * run)).min(mb);
                if full_pairs >= 1 {
                    // Pack up to `mb` full pairs into one execution.
                    let take = full_pairs * 2 * run;
                    let mut buf = sorted[i..i + take].to_vec();
                    buf.resize(mb * mn, u32::MAX);
                    let out = self.handle.sort_u32(key, buf)?;
                    stats.device_merges += 1;
                    next.extend_from_slice(&out[..take]);
                    i += take;
                } else {
                    let remaining = padded_len - i;
                    if remaining > run {
                        // Partial pair: full run + shorter sorted tail.
                        let mut buf = sorted[i..].to_vec();
                        buf.resize(mb * mn, u32::MAX);
                        let out = self.handle.sort_u32(key, buf)?;
                        stats.device_merges += 1;
                        next.extend_from_slice(&out[..remaining]);
                    } else {
                        // Lone run: passes through to the next level.
                        next.extend_from_slice(&sorted[i..]);
                    }
                    i = padded_len;
                }
            }
            sorted = next;
            run *= 2;
        }

        // ---- stage 3: CPU merge tail ------------------------------------
        while run < padded_len {
            let mut next = Vec::with_capacity(padded_len);
            let mut i = 0;
            while i < padded_len {
                let mid = (i + run).min(padded_len);
                let end = (i + 2 * run).min(padded_len);
                if mid < end {
                    merge_two(&sorted[i..mid], &sorted[mid..end], &mut next);
                    stats.cpu_merges += 1;
                } else {
                    next.extend_from_slice(&sorted[i..end]);
                }
                i = end;
            }
            sorted = next;
            run *= 2;
        }

        sorted.truncate(real_len);
        *keys = sorted;
        Ok(stats)
    }
}

/// Largest tile the hierarchical sorter picks by default: the fixture's
/// top sort class, which is also roughly L2-sized for 4-byte keys —
/// tiles above this stop fitting cache and the k-way merge's streaming
/// advantage evaporates.
pub const DEFAULT_TILE_CAP: usize = 1 << 16;

/// Statistics of one hierarchical sort.
///
/// (`PartialEq` only: the phase timings are `f64`.)
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct HierarchicalStats {
    /// Tile size used (keys per device-sorted run).
    pub tile: usize,
    /// Number of tiles the input split into (= fan-in of the k-way merge).
    pub tiles: usize,
    /// Device sort executions (each sorts up to B tiles).
    pub device_dispatches: usize,
    /// Configured merge workers (1 = the serial loser-tree path).
    pub merge_threads: usize,
    /// Buckets the splitter partition produced; 0 when the merge ran
    /// serially (one thread, a single tile, or a sub-threshold input).
    pub merge_parts: usize,
    /// Phase timing: device tile sorts (ms).
    pub tile_sort_ms: f64,
    /// Phase timing: splitter selection + binary-search partitioning
    /// (ms; 0 on the serial path, which has no partition phase).
    pub partition_ms: f64,
    /// Phase timing: the merge itself — scoped bucket merges on the
    /// parallel path, the single loser-tree pass on the serial one (ms).
    pub merge_ms: f64,
}

/// Hierarchical mega-sort: the large-n path past the merge-artifact
/// ladder (GPU Sample Sort's shape — Leischner et al., PAPERS.md).
///
/// Where [`HybridSorter`] climbs a pairwise device merge *tree*
/// (re-touching every key per level), this sorter does exactly two
/// passes over the data:
///
/// 1. **Tile sort** — split the mega-row into cache-sized tiles and
///    device-sort them with the fused launch programs, up to `B` tiles
///    per dispatch (batch-interleaved across tiles by the executor).
/// 2. **k-way merge** — one streaming [`crate::sort::kmerge`] pass over
///    all tiles (`O(n log k)` comparisons, each key read/written once),
///    or — with [`HierarchicalSorter::with_merge_threads`] — the
///    splitter-partitioned parallel merge of [`crate::sort::pmerge`]:
///    buckets of disjoint key ranges merged concurrently into disjoint
///    output slices. The serial merge stays as the 1-thread/small-n
///    fallback and the bit-exactness oracle.
///
/// Exact for any input length: the tail tile is MAX-padded, and the
/// loser tree tracks run exhaustion positionally, so real `MAX` keys
/// survive.
pub struct HierarchicalSorter {
    handle: DeviceHandle,
    /// Tile-sized ascending-u32 sort artifact.
    tile_meta: ArtifactMeta,
    /// Merge workers; > 1 engages the parallel bucket merge.
    merge_threads: usize,
    /// Owned pool for the bucket merges (None when `merge_threads` = 1).
    merge_pool: Option<crate::util::threadpool::ThreadPool>,
}

impl HierarchicalSorter {
    /// Build with the default tile class: the largest ascending-u32 sort
    /// artifact no bigger than [`DEFAULT_TILE_CAP`] (falling back to the
    /// smallest class if the menu only has mega-artifacts).
    pub fn new(
        handle: DeviceHandle,
        manifest: &Manifest,
        variant: Variant,
    ) -> crate::Result<Self> {
        let tile = Self::pick_tile(manifest, variant, None)
            .context("no sort artifacts in manifest")?;
        Self::with_tile(handle, manifest, variant, tile)
    }

    /// [`HierarchicalSorter::new`] with an explicit tile size (must match
    /// a sort artifact's row length) — the autotuner's tile axis and the
    /// ablation benches use this.
    pub fn with_tile(
        handle: DeviceHandle,
        manifest: &Manifest,
        variant: Variant,
        tile: usize,
    ) -> crate::Result<Self> {
        let tile_meta = manifest
            .size_classes(variant)
            .into_iter()
            .filter(|m| m.n == tile)
            .max_by_key(|m| m.batch)
            .with_context(|| format!("no sort artifact with rows of {tile}"))?
            .clone();
        Ok(Self {
            handle,
            tile_meta,
            merge_threads: 1,
            merge_pool: None,
        })
    }

    /// Configure the merge phase to run on `threads` workers (builder
    /// style). `threads <= 1` keeps the serial loser-tree merge; more
    /// spawn an owned pool and engage [`crate::sort::pmerge`] for
    /// multi-tile inputs at or above
    /// [`crate::sort::pmerge::PMERGE_MIN_TOTAL`] keys.
    pub fn with_merge_threads(mut self, threads: usize) -> Self {
        let threads = threads.max(1);
        self.merge_threads = threads;
        self.merge_pool = (threads > 1)
            .then(|| crate::util::threadpool::ThreadPool::new(threads, 2 * threads));
        self
    }

    /// Configured merge workers (1 = serial merge).
    pub fn merge_threads(&self) -> usize {
        self.merge_threads
    }

    /// Choose a tile size from the menu: the largest class `<= cap`
    /// (default [`DEFAULT_TILE_CAP`]), else the smallest class. `None`
    /// when the menu has no sort artifacts at all.
    pub fn pick_tile(
        manifest: &Manifest,
        variant: Variant,
        cap: Option<usize>,
    ) -> Option<usize> {
        let cap = cap.unwrap_or(DEFAULT_TILE_CAP);
        let ns: Vec<usize> = manifest
            .size_classes(variant)
            .into_iter()
            .map(|m| m.n)
            .collect();
        ns.iter()
            .filter(|&&n| n <= cap)
            .max()
            .or_else(|| ns.iter().min())
            .copied()
    }

    /// Tile size (keys per device-sorted run).
    pub fn tile(&self) -> usize {
        self.tile_meta.n
    }

    /// Sort `keys` ascending, any length. Returns execution statistics.
    pub fn sort(&self, keys: &mut Vec<u32>) -> crate::Result<HierarchicalStats> {
        let real_len = keys.len();
        let tile = self.tile();
        let mut stats = HierarchicalStats {
            tile,
            merge_threads: self.merge_threads,
            ..Default::default()
        };
        if real_len <= 1 {
            return Ok(stats);
        }

        // ---- pass 1: device-sort tiles, B at a time --------------------
        let t_tiles = std::time::Instant::now();
        let padded_len = real_len.div_ceil(tile) * tile;
        keys.resize(padded_len, u32::MAX);
        let (b, n) = (self.tile_meta.batch, self.tile_meta.n);
        let sort_key = Key::of(&self.tile_meta);
        let mut sorted = Vec::with_capacity(padded_len);
        for group in keys.chunks(b * n) {
            let mut buf = group.to_vec();
            buf.resize(b * n, u32::MAX);
            let out = self.handle.sort_u32(sort_key, buf)?;
            stats.device_dispatches += 1;
            sorted.extend_from_slice(&out[..group.len()]);
        }
        debug_assert_eq!(sorted.len(), padded_len);
        stats.tiles = padded_len / tile;
        stats.tile_sort_ms = t_tiles.elapsed().as_secs_f64() * 1e3;

        // ---- pass 2: merge the tiles -----------------------------------
        if stats.tiles == 1 {
            sorted.truncate(real_len);
            *keys = sorted;
            return Ok(stats);
        }
        let runs: Vec<&[u32]> = sorted.chunks(tile).collect();
        let mut merged = Vec::new();
        match &self.merge_pool {
            // Splitter-partitioned parallel merge: disjoint key-range
            // buckets into disjoint output slices (sort::pmerge).
            Some(pool) if padded_len >= crate::sort::pmerge::PMERGE_MIN_TOTAL => {
                let parts =
                    self.merge_threads * crate::sort::pmerge::BUCKETS_PER_THREAD;
                let ps =
                    crate::sort::pmerge::pmerge(&runs, pool, parts, &mut merged)?;
                stats.merge_parts = ps.parts;
                stats.partition_ms = ps.partition_ms;
                stats.merge_ms = ps.merge_ms;
            }
            // Serial fallback: one streaming loser-tree pass — also the
            // bit-exactness oracle the parallel path is tested against.
            _ => {
                let t_merge = std::time::Instant::now();
                crate::sort::kmerge::kway_merge(&runs, &mut merged);
                stats.merge_ms = t_merge.elapsed().as_secs_f64() * 1e3;
            }
        }
        merged.truncate(real_len);
        *keys = merged;
        Ok(stats)
    }
}

/// Streaming two-way merge of sorted `a` and `b` onto the end of `out`.
fn merge_two(a: &[u32], b: &[u32], out: &mut Vec<u32>) {
    let (mut i, mut j) = (0, 0);
    while i < a.len() && j < b.len() {
        if a[i] <= b[j] {
            out.push(a[i]);
            i += 1;
        } else {
            out.push(b[j]);
            j += 1;
        }
    }
    out.extend_from_slice(&a[i..]);
    out.extend_from_slice(&b[j..]);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merge_two_basics() {
        let mut out = Vec::new();
        merge_two(&[1, 3, 5], &[2, 4, 6], &mut out);
        assert_eq!(out, vec![1, 2, 3, 4, 5, 6]);
        out.clear();
        merge_two(&[], &[1], &mut out);
        assert_eq!(out, vec![1]);
        out.clear();
        merge_two(&[2, 2], &[2], &mut out);
        assert_eq!(out, vec![2, 2, 2]);
    }

    // Device-dependent tests live in rust/tests/hybrid_integration.rs.
}
