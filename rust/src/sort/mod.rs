//! CPU sorting substrates, implemented from scratch.
//!
//! The paper's evaluation (Table 1) has two CPU columns — quick sort and
//! sequential bitonic sort — both implemented here. The paper's §6 lists
//! "multicore bitonic" as future work; [`bitonic_parallel`] implements it.
//! The introduction name-checks the classical sorts; [`heapsort`],
//! [`mergesort`], [`radix`] and [`oddeven`] provide them as additional
//! baselines for the extended benchmarks (DESIGN.md E6–E9).
//!
//! [`network`] generates the bitonic network *schedule* (phases, steps,
//! compare-exchange pairs). It is the single source of truth shared by the
//! CPU bitonic sorts, the GPU simulator's cost model, and (structurally —
//! the Python side mirrors the same enumeration) the Pallas kernels.

pub mod bitonic;
pub mod bitonic_parallel;
pub mod heapsort;
pub mod hybrid;
pub mod kmerge;
pub mod mergesort;
pub mod network;
pub mod oddeven;
pub mod pmerge;
pub mod quicksort;
pub mod radix;
pub mod simd;
pub mod verify;

pub use bitonic::{bitonic_sort, bitonic_sort_desc, bitonic_sort_padded};
pub use bitonic_parallel::{bitonic_sort_parallel, bitonic_sort_parallel_padded};
pub use heapsort::heapsort;
pub use hybrid::{HierarchicalSorter, HierarchicalStats, HybridSorter, HybridStats};
pub use kmerge::{kway_merge, LoserTree};
pub use mergesort::mergesort;
pub use network::{Network, Phase, Step, Variant};
pub use oddeven::oddeven_sort;
pub use pmerge::{plan_partition, pmerge, MergePlan, PmergeStats};
pub use quicksort::quicksort;
pub use radix::radix_sort_u32;
pub use simd::{KernelChoice, KernelIsa, LaneKind};
pub use verify::{is_sorted, is_sorted_desc, same_multiset};

/// Keys sortable by every substrate in this module.
///
/// `Ord` would exclude floats; instead we require a total order via
/// [`SortKey::total_lt`]. For floats this is the IEEE-754 `totalOrder`
/// predicate restricted to finite values plus ±inf/NaN ordering consistent
/// with `f32::total_cmp`, matching what the JAX layer produces for float
/// keys.
pub trait SortKey: Copy + Send + Sync + 'static {
    /// Strict total-order less-than.
    fn total_lt(&self, other: &Self) -> bool;
    /// Maximum value (used for padding partial blocks to powers of two).
    const MAX_KEY: Self;
    /// Minimum value (used for descending padding).
    const MIN_KEY: Self;
    /// Explicit-SIMD lane classification (see [`simd`]). A non-`Other`
    /// value declares that `Self` is bit-identical to the named
    /// primitive and that [`Self::total_lt`] matches its total order —
    /// the SIMD dispatcher reinterprets key slices based on it.
    const LANE_KIND: simd::LaneKind = simd::LaneKind::Other;
    /// Total-order minimum of two keys.
    #[inline]
    fn key_min(a: Self, b: Self) -> Self {
        if b.total_lt(&a) {
            b
        } else {
            a
        }
    }
    /// Total-order maximum of two keys.
    #[inline]
    fn key_max(a: Self, b: Self) -> Self {
        if b.total_lt(&a) {
            a
        } else {
            b
        }
    }
}

macro_rules! int_key {
    ($($t:ty => $kind:ident),* $(,)?) => {$(
        impl SortKey for $t {
            #[inline]
            fn total_lt(&self, other: &Self) -> bool { self < other }
            const MAX_KEY: Self = <$t>::MAX;
            const MIN_KEY: Self = <$t>::MIN;
            const LANE_KIND: simd::LaneKind = simd::LaneKind::$kind;
        }
    )*};
}
int_key!(
    u8 => Other,
    u16 => Other,
    u32 => U32,
    u64 => Other,
    i8 => Other,
    i16 => Other,
    i32 => I32,
    i64 => Other,
    usize => Other,
);

impl SortKey for f32 {
    #[inline]
    fn total_lt(&self, other: &Self) -> bool {
        self.total_cmp(other) == std::cmp::Ordering::Less
    }
    const MAX_KEY: Self = f32::INFINITY;
    const MIN_KEY: Self = f32::NEG_INFINITY;
    const LANE_KIND: simd::LaneKind = simd::LaneKind::F32;
}

impl SortKey for f64 {
    #[inline]
    fn total_lt(&self, other: &Self) -> bool {
        self.total_cmp(other) == std::cmp::Ordering::Less
    }
    const MAX_KEY: Self = f64::INFINITY;
    const MIN_KEY: Self = f64::NEG_INFINITY;
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Regression tests for the classic edge cases across every substrate:
    /// empty input, a single element, all-equal keys, and non-power-of-two
    /// lengths (via the padded entry points for the bitonic sorts, which
    /// require power-of-two shapes directly).
    #[test]
    fn edge_cases_every_substrate() {
        type SortFn = fn(&mut Vec<u32>);
        let sorts: Vec<(&str, SortFn)> = vec![
            ("quicksort", |v| quicksort(v)),
            ("heapsort", |v| heapsort(v)),
            ("mergesort", |v| mergesort(v)),
            ("oddeven", |v| oddeven_sort(v)),
            ("radix", |v| radix_sort_u32(v)),
            ("bitonic_padded", |v| bitonic_sort_padded(v)),
            ("bitonic_parallel_padded", |v| bitonic_sort_parallel_padded(v, 4)),
        ];
        let cases: Vec<(&str, Vec<u32>)> = vec![
            ("empty", vec![]),
            ("single", vec![7]),
            ("two", vec![9, 3]),
            ("all-equal", vec![5; 37]),
            ("non-pow2", vec![3, 1, 4, 1, 5, 9, 2, 6, 5, 3, 5]),
            (
                "non-pow2-with-max",
                vec![u32::MAX, 0, u32::MAX, 42, 7, u32::MAX, 1],
            ),
        ];
        for (sname, sort) in &sorts {
            for (cname, case) in &cases {
                let mut v = case.clone();
                sort(&mut v);
                let mut want = case.clone();
                want.sort_unstable();
                assert_eq!(v, want, "{sname} on {cname}");
            }
        }
    }

    /// The padded parallel entry must also survive degenerate thread
    /// counts (0 and more threads than elements).
    #[test]
    fn parallel_padded_degenerate_threads() {
        for threads in [0usize, 1, 64] {
            let mut v = vec![5u32, 2, 8, 1, 9];
            bitonic_sort_parallel_padded(&mut v, threads);
            assert_eq!(v, vec![1, 2, 5, 8, 9], "threads={threads}");
        }
    }

    #[test]
    fn key_min_max_ints() {
        assert_eq!(u32::key_min(3, 5), 3);
        assert_eq!(u32::key_max(3, 5), 5);
        assert_eq!(i32::key_min(-3, 5), -3);
    }

    #[test]
    fn float_total_order_handles_nan() {
        assert!(1.0f32.total_lt(&f32::NAN));
        assert!(f32::NEG_INFINITY.total_lt(&-1.0f32));
        assert!(!f32::NAN.total_lt(&f32::NAN));
    }

    #[test]
    fn max_key_is_maximal() {
        assert!(!u32::MAX_KEY.total_lt(&u32::MAX));
        assert!(0u32.total_lt(&u32::MAX_KEY));
        assert!(1.0e30f32.total_lt(&f32::MAX_KEY));
    }
}
