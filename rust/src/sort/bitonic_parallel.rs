//! Multicore CPU bitonic sort — the paper's §6 future-work item
//! ("further explore and compare the performance of a multicore … bitonic
//! sort implementation"), DESIGN.md experiment E9.
//!
//! Parallelisation mirrors the GPU structure: within one compare-exchange
//! step every pair is independent, so the index space is split across
//! threads; steps are separated by a barrier (the CPU analog of the
//! paper's host synchronization). Like the GPU "semi" optimisation, small
//! strides are handled by giving each thread a contiguous chunk and
//! running the whole tail of the phase locally without any barrier —
//! the shared-memory optimisation translated to cache locality.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Barrier};

use super::network::{run_fused_tail_range, Network};
use super::SortKey;

/// Sort `xs` ascending in place using `threads` OS threads.
/// `xs.len()` must be a power of two.
pub fn bitonic_sort_parallel<T: SortKey>(xs: &mut [T], threads: usize) {
    let n = xs.len();
    if n < 2 {
        return;
    }
    assert!(n.is_power_of_two(), "bitonic_sort_parallel requires n = 2^k, got {n}");
    let threads = threads.clamp(1, n / 2);
    if threads == 1 || n < 4096 {
        // Thread overhead dominates below this; fall back to sequential.
        super::bitonic::bitonic_sort(xs);
        return;
    }

    // Each thread owns a contiguous chunk of size n/threads (power of two
    // by construction when threads is a power of two; round down to one).
    let threads = threads.next_power_of_two() >> usize::from(!threads.is_power_of_two());
    let chunk = n / threads;

    let barrier = Arc::new(Barrier::new(threads));
    let ptr = SharedSlice(xs.as_mut_ptr(), n);

    // The schedule every thread walks in lockstep.
    let net = Network::new(n);
    let steps: Vec<(usize, usize)> = net.steps().map(|s| (s.phase_len, s.stride)).collect();
    let panics = Arc::new(AtomicUsize::new(0));

    std::thread::scope(|scope| {
        for t in 0..threads {
            let barrier = Arc::clone(&barrier);
            let steps = &steps;
            let panics = Arc::clone(&panics);
            let ptr = ptr;
            scope.spawn(move || {
                let guard = PanicCounter(&panics);
                // SAFETY: each thread writes only indices whose pair (a, a^j)
                // both fall in [t*chunk, (t+1)*chunk) when j < chunk, or
                // disjoint index sets split by pair-group when j >= chunk;
                // barriers separate steps.
                let xs: &mut [T] = unsafe { ptr.slice() };
                let lo = t * chunk;
                let hi = lo + chunk;
                let mut i = 0;
                while i < steps.len() {
                    let (k, j) = steps[i];
                    if j < chunk {
                        // Local tail: all remaining steps of this phase
                        // touch only in-chunk pairs; run them through the
                        // shared fused-tile kernel — the same kernel the
                        // runtime's BlockFused launches execute — with no
                        // barriers while the chunk stays cache-resident.
                        run_fused_tail_range(xs, k, j, lo, hi, true);
                        i += j.trailing_zeros() as usize + 1;
                        barrier.wait();
                    } else {
                        // Global step: split by pair-group. Thread t takes
                        // lows in [t*chunk, (t+1)*chunk) — every low index
                        // a has partner a^j outside every chunk, but lows
                        // are disjoint across threads, and each (a, a^j)
                        // pair is written by exactly the thread owning the
                        // *low* index a (a < a^j since a & j == 0).
                        step_lows_in(xs, k, j, lo, hi);
                        i += 1;
                        barrier.wait();
                    }
                }
                drop(guard);
            });
        }
    });
    assert_eq!(panics.load(Ordering::SeqCst), 0, "worker thread panicked");
}

/// Sort any-length input in parallel by padding to the next power of two
/// with `T::MAX_KEY`, sorting, and truncating — the parallel analogue of
/// [`crate::sort::bitonic_sort_padded`], and the safe entry point for
/// non-power-of-two lengths (the unpadded function asserts on them).
pub fn bitonic_sort_parallel_padded<T: SortKey>(xs: &mut Vec<T>, threads: usize) {
    let n = xs.len();
    if n < 2 {
        return;
    }
    xs.resize(n.next_power_of_two(), T::MAX_KEY);
    bitonic_sort_parallel(xs, threads);
    xs.truncate(n);
}

/// Compare-exchange pairs whose *low* index lies in [lo, hi) for a stride
/// `j >= hi - lo` (the partner is out of range; ownership is by low index).
fn step_lows_in<T: SortKey>(xs: &mut [T], k: usize, j: usize, lo: usize, hi: usize) {
    for a in lo..hi {
        if a & j == 0 {
            cx(xs, a, a ^ j, a & k == 0);
        }
    }
}

#[inline]
fn cx<T: SortKey>(xs: &mut [T], a: usize, b: usize, ascending: bool) {
    let (va, vb) = (xs[a], xs[b]);
    let swap = if ascending {
        vb.total_lt(&va)
    } else {
        va.total_lt(&vb)
    };
    if swap {
        xs.swap(a, b);
    }
}

/// Raw shared-slice smuggler for scoped threads. The disjoint-write
/// argument is documented at the use site.
#[derive(Clone, Copy)]
struct SharedSlice<T>(*mut T, usize);
unsafe impl<T: Send> Send for SharedSlice<T> {}
unsafe impl<T: Send> Sync for SharedSlice<T> {}
impl<T> SharedSlice<T> {
    unsafe fn slice<'a>(&self) -> &'a mut [T] {
        std::slice::from_raw_parts_mut(self.0, self.1)
    }
}

/// Counts panics that unwind out of a worker body.
struct PanicCounter<'a>(&'a AtomicUsize);
impl Drop for PanicCounter<'_> {
    fn drop(&mut self) {
        if std::thread::panicking() {
            self.0.fetch_add(1, Ordering::SeqCst);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sort::verify::{is_sorted, same_multiset};
    use crate::workload::{Distribution, Generator};

    #[test]
    fn matches_sequential_across_sizes_and_threads() {
        let mut gen = Generator::new(0xFA57);
        for logn in [12usize, 13, 15] {
            for threads in [1usize, 2, 4, 8] {
                let orig = gen.u32s(1 << logn, Distribution::Uniform);
                let mut par = orig.clone();
                bitonic_sort_parallel(&mut par, threads);
                assert!(is_sorted(&par), "n=2^{logn} t={threads}");
                assert!(same_multiset(&orig, &par));
            }
        }
    }

    #[test]
    fn all_distributions() {
        let mut gen = Generator::new(0xAB);
        for d in Distribution::ALL {
            let orig = gen.u32s(1 << 13, d);
            let mut v = orig.clone();
            bitonic_sort_parallel(&mut v, 4);
            assert!(is_sorted(&v), "{}", d.name());
            assert!(same_multiset(&orig, &v));
        }
    }

    #[test]
    fn small_input_falls_back() {
        let mut v = vec![3u32, 1, 4, 1, 5, 9, 2, 6];
        bitonic_sort_parallel(&mut v, 8);
        assert_eq!(v, vec![1, 1, 2, 3, 4, 5, 6, 9]);
    }

    #[test]
    fn non_power_of_two_thread_count() {
        let mut gen = Generator::new(0x77);
        let orig = gen.u32s(1 << 13, Distribution::Uniform);
        let mut v = orig.clone();
        bitonic_sort_parallel(&mut v, 3); // rounds to a power of two
        assert!(is_sorted(&v));
        assert!(same_multiset(&orig, &v));
    }

    #[test]
    fn u64_keys() {
        let mut gen = Generator::new(0x99);
        let orig = gen.u64s(1 << 13, Distribution::Uniform);
        let mut v = orig.clone();
        bitonic_sort_parallel(&mut v, 4);
        assert!(is_sorted(&v));
        assert!(same_multiset(&orig, &v));
    }
}
