//! Multicore CPU bitonic sort — the paper's §6 future-work item
//! ("further explore and compare the performance of a multicore … bitonic
//! sort implementation"), DESIGN.md experiment E9.
//!
//! Parallelisation mirrors the GPU structure: within one compare-exchange
//! step every pair is independent, so the index space is split across
//! threads; steps are separated by a barrier (the CPU analog of the
//! paper's host synchronization). Like the GPU "semi" optimisation, small
//! strides are handled by giving each thread a contiguous chunk and
//! running the whole tail of the phase locally without any barrier —
//! the shared-memory optimisation translated to cache locality. And like
//! the GPU "optimized" variant, *global* steps are paired two-at-a-time
//! (the paper's §4.2 register fusion): whenever both strides of the pair
//! stay at or above the chunk size, each thread executes whole register
//! quads across chunk boundaries in one barrier interval, halving the
//! barrier count of the global portion — see [`double_step_lows_in`] for
//! the two-stride ownership argument.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Barrier};

use super::network::{run_fused_tail_range, Network, Phase, Step};
use super::SortKey;

/// One barrier interval of the chunked parallel schedule: the operation
/// **every** worker executes (on its own index range) between two
/// barriers. This is the single source of truth the worker loop in
/// [`bitonic_sort_parallel`] walks and the static disjointness checker
/// ([`crate::analysis::disjoint`]) verifies — the two can never drift.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum IntervalOp {
    /// The whole `stride < chunk` tail of phase `phase_len`, run on the
    /// worker's own chunk via [`run_fused_tail_range`] (no cross-chunk
    /// pairs; the §4.1 shared-memory stage as cache locality).
    LocalTail {
        /// Phase length `k`.
        phase_len: usize,
        /// Largest stride of the fused tail (`< chunk`).
        stride_hi: usize,
    },
    /// Strides `(stride_hi, stride_hi/2)` of phase `phase_len` executed
    /// as register quads owned by their minimum index (the §4.2 pairing
    /// across chunk boundaries) — see [`double_step_lows_in`].
    PairedGlobal {
        /// Phase length `k`.
        phase_len: usize,
        /// The larger stride of the fused pair (`stride_hi/2 >= chunk`).
        stride_hi: usize,
    },
    /// One global step, pairs owned by their low index — see
    /// [`step_lows_in`].
    GlobalLows {
        /// Phase length `k`.
        phase_len: usize,
        /// Compare-exchange stride (`>= chunk`).
        stride: usize,
    },
}

impl IntervalOp {
    /// The network steps this interval covers, in execution order —
    /// concatenating over [`barrier_intervals`] reproduces
    /// [`Network::step_schedule`] exactly (checked statically by
    /// `analysis::disjoint` and dynamically by the bit-exactness test
    /// below).
    pub fn steps(self) -> Vec<Step> {
        match self {
            IntervalOp::LocalTail { phase_len, stride_hi } => Phase { len: phase_len }
                .steps()
                .filter(|s| s.stride <= stride_hi)
                .collect(),
            IntervalOp::PairedGlobal { phase_len, stride_hi } => vec![
                Step { phase_len, stride: stride_hi },
                Step { phase_len, stride: stride_hi / 2 },
            ],
            IntervalOp::GlobalLows { phase_len, stride } => {
                vec![Step { phase_len, stride }]
            }
        }
    }
}

/// The chunked barrier schedule for row length `n` and per-worker chunk
/// size `chunk` (both powers of two, `chunk >= 2`): each step of the
/// network is assigned to a local-tail, paired-global or single-global
/// interval by the same `j` vs `chunk` comparisons the workers used to
/// make inline. One [`IntervalOp`] per barrier.
pub fn barrier_intervals(n: usize, chunk: usize) -> Vec<IntervalOp> {
    assert!(n.is_power_of_two() && chunk.is_power_of_two() && 2 <= chunk && chunk <= n);
    let steps: Vec<Step> = Network::new(n).step_schedule();
    let mut out = Vec::new();
    let mut i = 0;
    while i < steps.len() {
        let Step { phase_len: k, stride: j } = steps[i];
        if j < chunk {
            out.push(IntervalOp::LocalTail { phase_len: k, stride_hi: j });
            i += j.trailing_zeros() as usize + 1;
        } else if j / 2 >= chunk {
            out.push(IntervalOp::PairedGlobal { phase_len: k, stride_hi: j });
            i += 2;
        } else {
            out.push(IntervalOp::GlobalLows { phase_len: k, stride: j });
            i += 1;
        }
    }
    out
}

/// The worker count [`bitonic_sort_parallel`] actually uses for a given
/// request: clamped to `n/2`, rounded **down** to a power of two, and 1
/// when the serial fallback engages (`threads == 1 || n < 4096`). Shared
/// with the static checker so it emulates the real geometry.
pub fn effective_workers(n: usize, threads: usize) -> usize {
    if n < 2 {
        return 1;
    }
    let threads = threads.clamp(1, n / 2);
    if threads == 1 || n < 4096 {
        return 1;
    }
    threads.next_power_of_two() >> usize::from(!threads.is_power_of_two())
}

/// Statically verify this module's schedule for `(n, threads)` — step
/// completeness and write-disjointness per barrier interval — without
/// sorting anything. See [`crate::analysis::disjoint`].
pub fn analyze(n: usize, threads: usize) -> crate::analysis::Report {
    crate::analysis::disjoint::analyze_parallel_schedule(n, threads)
}

/// Sort `xs` ascending in place using `threads` OS threads.
/// `xs.len()` must be a power of two.
pub fn bitonic_sort_parallel<T: SortKey>(xs: &mut [T], threads: usize) {
    let n = xs.len();
    if n < 2 {
        return;
    }
    assert!(n.is_power_of_two(), "bitonic_sort_parallel requires n = 2^k, got {n}");
    let threads = effective_workers(n, threads);
    if threads == 1 {
        // Thread overhead dominates below the cutover; fall back.
        super::bitonic::bitonic_sort(xs);
        return;
    }

    // Each thread owns a contiguous chunk of size n/threads (power of two
    // because effective_workers rounds down to one).
    let chunk = n / threads;

    let barrier = Arc::new(Barrier::new(threads));
    let ptr = SharedSlice(xs.as_mut_ptr(), n);

    // The schedule every thread walks in lockstep — the same interval
    // list the static checker proves disjoint.
    let intervals = barrier_intervals(n, chunk);
    let panics = Arc::new(AtomicUsize::new(0));

    std::thread::scope(|scope| {
        for t in 0..threads {
            let barrier = Arc::clone(&barrier);
            let intervals = &intervals;
            let panics = Arc::clone(&panics);
            let ptr = ptr;
            scope.spawn(move || {
                let guard = PanicCounter(&panics);
                // SAFETY: within one barrier interval each element is
                // written by at most one thread, by one of three
                // disjointness arguments: (1) local tails (j < chunk) —
                // every pair (a, a^j) falls inside the owning thread's
                // [t*chunk, (t+1)*chunk); (2) paired global steps
                // (j/2 >= chunk) — the index space partitions into
                // register quads closed under both strides, and only the
                // thread owning the quad's MINIMUM index touches its four
                // elements (three of which live in other threads'
                // chunks — see double_step_lows_in); (3) single global
                // steps — pairs are owned by their low index, and lows
                // are disjoint across threads. Barriers separate
                // intervals, and every thread executes the same shared
                // interval list. These three arguments are PROVEN, not
                // assumed: `analysis::disjoint::check_parallel_schedule`
                // emulates this exact interval list symbolically (it is
                // built by the same `barrier_intervals` call) and
                // verifies every index is written by exactly one worker
                // per interval — run by `bitonic-tpu verify-plans`, the
                // in-module tests of `analysis::disjoint`, and the
                // mutation suite in `rust/tests/analysis_mutations.rs`
                // (which proves the checker rejects racy schedules). The
                // debug asserts below restate the per-branch invariant.
                let xs: &mut [T] = unsafe { ptr.slice() };
                let lo = t * chunk;
                let hi = lo + chunk;
                for op in intervals {
                    match *op {
                        IntervalOp::LocalTail { phase_len, stride_hi } => {
                            // All pairs in-chunk: proven disjoint per
                            // worker by analysis::disjoint (case 1).
                            debug_assert!(stride_hi < chunk);
                            run_fused_tail_range(xs, phase_len, stride_hi, lo, hi, true);
                        }
                        IntervalOp::PairedGlobal { phase_len, stride_hi } => {
                            // Quad ownership by minimum index: proven
                            // disjoint by analysis::disjoint (case 2).
                            debug_assert!(stride_hi / 2 >= chunk);
                            double_step_lows_in(xs, phase_len, stride_hi, lo, hi);
                        }
                        IntervalOp::GlobalLows { phase_len, stride } => {
                            // Pair ownership by low index: proven
                            // disjoint by analysis::disjoint (case 3).
                            debug_assert!(stride >= chunk);
                            step_lows_in(xs, phase_len, stride, lo, hi);
                        }
                    }
                    barrier.wait();
                }
                drop(guard);
            });
        }
    });
    assert_eq!(panics.load(Ordering::SeqCst), 0, "worker thread panicked");
}

/// Sort any-length input in parallel by padding to the next power of two
/// with `T::MAX_KEY`, sorting, and truncating — the parallel analogue of
/// [`crate::sort::bitonic_sort_padded`], and the safe entry point for
/// non-power-of-two lengths (the unpadded function asserts on them).
pub fn bitonic_sort_parallel_padded<T: SortKey>(xs: &mut Vec<T>, threads: usize) {
    let n = xs.len();
    if n < 2 {
        return;
    }
    xs.resize(n.next_power_of_two(), T::MAX_KEY);
    bitonic_sort_parallel(xs, threads);
    xs.truncate(n);
}

/// Compare-exchange pairs whose *low* index lies in [lo, hi) for a stride
/// `j >= hi - lo` (the partner is out of range; ownership is by low index).
fn step_lows_in<T: SortKey>(xs: &mut [T], k: usize, j: usize, lo: usize, hi: usize) {
    for a in lo..hi {
        if a & j == 0 {
            // Low-index ownership (a < a^j, in range): the invariant
            // analysis::disjoint proves for GlobalLows intervals.
            debug_assert!(a ^ j > a && a ^ j < xs.len());
            cx(xs, a, a ^ j, a & k == 0);
        }
    }
}

/// Both steps of the stride pair `(j_hi, j_hi/2)` of phase `k`, restricted
/// to register quads whose *minimum* index lies in `[lo, hi)` — the
/// two-stride ownership argument that lets the pairing cross chunk
/// boundaries safely:
///
/// * The quads `{a, a+j_lo, a+j_hi, a+j_hi+j_lo}` (over all `a` with
///   `a & (j_hi | j_lo) == 0`) partition the index space, and a quad is
///   closed under both `^j_hi` and `^j_lo` — so executing both steps
///   quad-by-quad is bit-identical to the two serial sweeps (the same
///   argument as [`crate::sort::bitonic::compare_exchange_double_step`]).
/// * Exactly one thread owns each quad (the owner of its minimum index),
///   so within the single barrier interval no element is touched by two
///   threads, even though three of the four indices live in other
///   threads' chunks (`j_lo >= chunk` here).
/// * All four compare-exchanges share one direction: the quad spans
///   offsets `< 2*j_hi <= k`, never flipping bit `k` (the minimum has
///   `a & j_hi == a & j_lo == 0`, so the additions carry nothing into
///   bit `k`).
fn double_step_lows_in<T: SortKey>(xs: &mut [T], k: usize, j_hi: usize, lo: usize, hi: usize) {
    debug_assert!(j_hi >= 2 && 2 * j_hi <= k);
    let j_lo = j_hi / 2;
    let quad_bits = j_hi | j_lo;
    for a in lo..hi {
        if a & quad_bits == 0 {
            let (b, c) = (a + j_lo, a + j_hi);
            let d = c + j_lo;
            // The quad invariants analysis::disjoint proves for
            // PairedGlobal intervals: all four indices in range, and the
            // direction bit never flips inside the quad (no carry into
            // bit k, since a has zeros at both stride bits).
            debug_assert!(d < xs.len());
            debug_assert_eq!(d & k, a & k, "quad spans a direction boundary");
            let ascending = a & k == 0;
            cx(xs, a, c, ascending); // stride j_hi: (a, c)
            cx(xs, b, d, ascending); //              (b, d)
            cx(xs, a, b, ascending); // stride j_lo: (a, b)
            cx(xs, c, d, ascending); //              (c, d)
        }
    }
}

#[inline]
fn cx<T: SortKey>(xs: &mut [T], a: usize, b: usize, ascending: bool) {
    let (va, vb) = (xs[a], xs[b]);
    let swap = if ascending {
        vb.total_lt(&va)
    } else {
        va.total_lt(&vb)
    };
    if swap {
        xs.swap(a, b);
    }
}

/// Raw shared-slice smuggler for scoped threads. The disjoint-write
/// argument is documented at the use site.
#[derive(Clone, Copy)]
struct SharedSlice<T>(*mut T, usize);
unsafe impl<T: Send> Send for SharedSlice<T> {}
unsafe impl<T: Send> Sync for SharedSlice<T> {}
impl<T> SharedSlice<T> {
    unsafe fn slice<'a>(&self) -> &'a mut [T] {
        // SAFETY: pointer and length come from the caller's exclusive
        // `&mut [T]`, which outlives the thread scope; non-overlapping
        // use across threads is the barrier-interval disjointness
        // invariant proven by `analysis::disjoint` (see the use site).
        unsafe { std::slice::from_raw_parts_mut(self.0, self.1) }
    }
}

/// Counts panics that unwind out of a worker body.
struct PanicCounter<'a>(&'a AtomicUsize);
impl Drop for PanicCounter<'_> {
    fn drop(&mut self) {
        if std::thread::panicking() {
            self.0.fetch_add(1, Ordering::SeqCst);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sort::verify::{is_sorted, same_multiset};
    use crate::workload::{Distribution, Generator};

    #[test]
    fn matches_sequential_across_sizes_and_threads() {
        let mut gen = Generator::new(0xFA57);
        for logn in [12usize, 13, 15] {
            for threads in [1usize, 2, 4, 8] {
                let orig = gen.u32s(1 << logn, Distribution::Uniform);
                let mut par = orig.clone();
                bitonic_sort_parallel(&mut par, threads);
                assert!(is_sorted(&par), "n=2^{logn} t={threads}");
                assert!(same_multiset(&orig, &par));
            }
        }
    }

    #[test]
    fn all_distributions() {
        let mut gen = Generator::new(0xAB);
        for d in Distribution::ALL {
            let orig = gen.u32s(1 << 13, d);
            let mut v = orig.clone();
            bitonic_sort_parallel(&mut v, 4);
            assert!(is_sorted(&v), "{}", d.name());
            assert!(same_multiset(&orig, &v));
        }
    }

    /// Satellite: the chunked schedule — fused local tails, paired global
    /// double-steps, leftover single global steps — must be bit-exact
    /// with the serial network walk after every barrier interval. The
    /// worker loop is emulated deterministically on one thread (running
    /// every chunk's slice of the interval before the "barrier"), which
    /// pins exactly the step grouping the real workers execute.
    #[test]
    fn chunked_schedule_bit_exact_with_serial_network_walk() {
        use crate::sort::bitonic::compare_exchange_step;
        let mut gen = Generator::new(0xBA121E2);
        for logn in [10usize, 12, 13] {
            let n = 1 << logn;
            for threads in [2usize, 4, 8] {
                let chunk = n / threads;
                let data = gen.u32s(n, Distribution::DupHeavy);
                let mut chunked = data.clone();
                let mut serial = data;
                let mut paired_intervals = 0usize;
                for op in barrier_intervals(n, chunk) {
                    match op {
                        IntervalOp::LocalTail { phase_len, stride_hi } => {
                            for t in 0..threads {
                                run_fused_tail_range(
                                    &mut chunked,
                                    phase_len,
                                    stride_hi,
                                    t * chunk,
                                    (t + 1) * chunk,
                                    true,
                                );
                            }
                        }
                        IntervalOp::PairedGlobal { phase_len, stride_hi } => {
                            for t in 0..threads {
                                double_step_lows_in(
                                    &mut chunked,
                                    phase_len,
                                    stride_hi,
                                    t * chunk,
                                    (t + 1) * chunk,
                                );
                            }
                            paired_intervals += 1;
                        }
                        IntervalOp::GlobalLows { phase_len, stride } => {
                            for t in 0..threads {
                                step_lows_in(&mut chunked, phase_len, stride, t * chunk, (t + 1) * chunk);
                            }
                        }
                    }
                    for s in op.steps() {
                        compare_exchange_step(&mut serial, s.phase_len, s.stride);
                    }
                    assert_eq!(chunked, serial, "diverged at n=2^{logn} threads={threads} {op:?}");
                }
                assert!(is_sorted(&chunked));
                // The pairing must actually engage whenever at least two
                // global strides exist (n >= 4 * chunk).
                if n >= 4 * chunk {
                    assert!(paired_intervals > 0, "pairing never engaged at n=2^{logn} t={threads}");
                }
            }
        }
    }

    /// The paired schedule halves the barrier count of the global
    /// portion: count barrier intervals structurally.
    #[test]
    fn pairing_halves_global_barrier_count() {
        let n = 1 << 16;
        let chunk = n / 8; // 8 threads
        let (mut paired_intervals, mut unpaired_intervals) = (0usize, 0usize);
        for op in barrier_intervals(n, chunk) {
            match op {
                IntervalOp::PairedGlobal { .. } => paired_intervals += 1,
                _ => unpaired_intervals += 1,
            }
        }
        // Without pairing every global step is its own interval; with it,
        // paired intervals cover two steps each.
        let with_pairing = paired_intervals + unpaired_intervals;
        let without_pairing = 2 * paired_intervals + unpaired_intervals;
        assert!(paired_intervals > 0);
        assert!(
            with_pairing < without_pairing,
            "pairing saved no barriers: {with_pairing} vs {without_pairing}"
        );
    }

    /// End to end across real threads: the parallel sort (with paired
    /// global steps) must produce byte-identical output to the serial
    /// network walk — sorted u32 output is unique per multiset, so this
    /// is full bit-exactness, across sizes, thread counts and
    /// distributions.
    #[test]
    fn parallel_output_identical_to_serial_walk() {
        let mut gen = Generator::new(0xB17DB1);
        for logn in [12usize, 14] {
            for threads in [2usize, 3, 4, 8] {
                for dist in [Distribution::Uniform, Distribution::DupHeavy] {
                    let data = gen.u32s(1 << logn, dist);
                    let mut par = data.clone();
                    bitonic_sort_parallel(&mut par, threads);
                    let mut ser = data;
                    crate::sort::bitonic::bitonic_sort(&mut ser);
                    assert_eq!(par, ser, "n=2^{logn} t={threads} {}", dist.name());
                }
            }
        }
    }

    /// The shared-schedule refactor invariant: concatenating every
    /// interval's steps reproduces the flat network schedule exactly —
    /// the same property the static checker re-verifies symbolically.
    #[test]
    fn barrier_intervals_cover_schedule_exactly() {
        for logn in [12usize, 13, 16] {
            let n = 1 << logn;
            for threads in [2usize, 4, 8, 32] {
                let chunk = n / threads;
                let flat: Vec<Step> = barrier_intervals(n, chunk)
                    .into_iter()
                    .flat_map(IntervalOp::steps)
                    .collect();
                assert_eq!(flat, Network::new(n).step_schedule(), "n={n} chunk={chunk}");
            }
        }
    }

    #[test]
    fn effective_workers_geometry() {
        assert_eq!(effective_workers(1 << 13, 1), 1); // explicit serial
        assert_eq!(effective_workers(2048, 8), 1); // below the cutover
        assert_eq!(effective_workers(1 << 13, 3), 2); // rounds down to 2^k
        assert_eq!(effective_workers(1 << 13, 8), 8);
        assert_eq!(effective_workers(1 << 12, 1 << 13), 1 << 11); // clamp n/2
    }

    #[test]
    fn small_input_falls_back() {
        let mut v = vec![3u32, 1, 4, 1, 5, 9, 2, 6];
        bitonic_sort_parallel(&mut v, 8);
        assert_eq!(v, vec![1, 1, 2, 3, 4, 5, 6, 9]);
    }

    #[test]
    fn non_power_of_two_thread_count() {
        let mut gen = Generator::new(0x77);
        let orig = gen.u32s(1 << 13, Distribution::Uniform);
        let mut v = orig.clone();
        bitonic_sort_parallel(&mut v, 3); // rounds to a power of two
        assert!(is_sorted(&v));
        assert!(same_multiset(&orig, &v));
    }

    #[test]
    fn u64_keys() {
        let mut gen = Generator::new(0x99);
        let orig = gen.u64s(1 << 13, Distribution::Uniform);
        let mut v = orig.clone();
        bitonic_sort_parallel(&mut v, 4);
        assert!(is_sorted(&v));
        assert!(same_multiset(&orig, &v));
    }
}
