//! Multicore CPU bitonic sort — the paper's §6 future-work item
//! ("further explore and compare the performance of a multicore … bitonic
//! sort implementation"), DESIGN.md experiment E9.
//!
//! Parallelisation mirrors the GPU structure: within one compare-exchange
//! step every pair is independent, so the index space is split across
//! threads; steps are separated by a barrier (the CPU analog of the
//! paper's host synchronization). Like the GPU "semi" optimisation, small
//! strides are handled by giving each thread a contiguous chunk and
//! running the whole tail of the phase locally without any barrier —
//! the shared-memory optimisation translated to cache locality. And like
//! the GPU "optimized" variant, *global* steps are paired two-at-a-time
//! (the paper's §4.2 register fusion): whenever both strides of the pair
//! stay at or above the chunk size, each thread executes whole register
//! quads across chunk boundaries in one barrier interval, halving the
//! barrier count of the global portion — see [`double_step_lows_in`] for
//! the two-stride ownership argument.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Barrier};

use super::network::{run_fused_tail_range, Network};
use super::SortKey;

/// Sort `xs` ascending in place using `threads` OS threads.
/// `xs.len()` must be a power of two.
pub fn bitonic_sort_parallel<T: SortKey>(xs: &mut [T], threads: usize) {
    let n = xs.len();
    if n < 2 {
        return;
    }
    assert!(n.is_power_of_two(), "bitonic_sort_parallel requires n = 2^k, got {n}");
    let threads = threads.clamp(1, n / 2);
    if threads == 1 || n < 4096 {
        // Thread overhead dominates below this; fall back to sequential.
        super::bitonic::bitonic_sort(xs);
        return;
    }

    // Each thread owns a contiguous chunk of size n/threads (power of two
    // by construction when threads is a power of two; round down to one).
    let threads = threads.next_power_of_two() >> usize::from(!threads.is_power_of_two());
    let chunk = n / threads;

    let barrier = Arc::new(Barrier::new(threads));
    let ptr = SharedSlice(xs.as_mut_ptr(), n);

    // The schedule every thread walks in lockstep.
    let net = Network::new(n);
    let steps: Vec<(usize, usize)> = net.steps().map(|s| (s.phase_len, s.stride)).collect();
    let panics = Arc::new(AtomicUsize::new(0));

    std::thread::scope(|scope| {
        for t in 0..threads {
            let barrier = Arc::clone(&barrier);
            let steps = &steps;
            let panics = Arc::clone(&panics);
            let ptr = ptr;
            scope.spawn(move || {
                let guard = PanicCounter(&panics);
                // SAFETY: within one barrier interval each element is
                // written by at most one thread, by one of three
                // disjointness arguments: (1) local tails (j < chunk) —
                // every pair (a, a^j) falls inside the owning thread's
                // [t*chunk, (t+1)*chunk); (2) paired global steps
                // (j/2 >= chunk) — the index space partitions into
                // register quads closed under both strides, and only the
                // thread owning the quad's MINIMUM index touches its four
                // elements (three of which live in other threads'
                // chunks — see double_step_lows_in); (3) single global
                // steps — pairs are owned by their low index, and lows
                // are disjoint across threads. Barriers separate
                // intervals, and every thread takes the same branch
                // (conditions depend only on the shared j and chunk).
                let xs: &mut [T] = unsafe { ptr.slice() };
                let lo = t * chunk;
                let hi = lo + chunk;
                let mut i = 0;
                while i < steps.len() {
                    let (k, j) = steps[i];
                    if j < chunk {
                        // Local tail: all remaining steps of this phase
                        // touch only in-chunk pairs; run them through the
                        // shared fused-tile kernel — the same kernel the
                        // runtime's BlockFused launches execute — with no
                        // barriers while the chunk stays cache-resident.
                        run_fused_tail_range(xs, k, j, lo, hi, true);
                        i += j.trailing_zeros() as usize + 1;
                        barrier.wait();
                    } else if j / 2 >= chunk {
                        // Paired global steps (paper §4.2 applied across
                        // chunk boundaries): the next stride j/2 is still
                        // global, so run both through register quads in
                        // ONE barrier interval — every thread takes this
                        // branch in lockstep (the test depends only on
                        // the shared j and chunk), halving the global
                        // barrier count.
                        double_step_lows_in(xs, k, j, lo, hi);
                        i += 2;
                        barrier.wait();
                    } else {
                        // Global step: split by pair-group. Thread t takes
                        // lows in [t*chunk, (t+1)*chunk) — every low index
                        // a has partner a^j outside every chunk, but lows
                        // are disjoint across threads, and each (a, a^j)
                        // pair is written by exactly the thread owning the
                        // *low* index a (a < a^j since a & j == 0).
                        step_lows_in(xs, k, j, lo, hi);
                        i += 1;
                        barrier.wait();
                    }
                }
                drop(guard);
            });
        }
    });
    assert_eq!(panics.load(Ordering::SeqCst), 0, "worker thread panicked");
}

/// Sort any-length input in parallel by padding to the next power of two
/// with `T::MAX_KEY`, sorting, and truncating — the parallel analogue of
/// [`crate::sort::bitonic_sort_padded`], and the safe entry point for
/// non-power-of-two lengths (the unpadded function asserts on them).
pub fn bitonic_sort_parallel_padded<T: SortKey>(xs: &mut Vec<T>, threads: usize) {
    let n = xs.len();
    if n < 2 {
        return;
    }
    xs.resize(n.next_power_of_two(), T::MAX_KEY);
    bitonic_sort_parallel(xs, threads);
    xs.truncate(n);
}

/// Compare-exchange pairs whose *low* index lies in [lo, hi) for a stride
/// `j >= hi - lo` (the partner is out of range; ownership is by low index).
fn step_lows_in<T: SortKey>(xs: &mut [T], k: usize, j: usize, lo: usize, hi: usize) {
    for a in lo..hi {
        if a & j == 0 {
            cx(xs, a, a ^ j, a & k == 0);
        }
    }
}

/// Both steps of the stride pair `(j_hi, j_hi/2)` of phase `k`, restricted
/// to register quads whose *minimum* index lies in `[lo, hi)` — the
/// two-stride ownership argument that lets the pairing cross chunk
/// boundaries safely:
///
/// * The quads `{a, a+j_lo, a+j_hi, a+j_hi+j_lo}` (over all `a` with
///   `a & (j_hi | j_lo) == 0`) partition the index space, and a quad is
///   closed under both `^j_hi` and `^j_lo` — so executing both steps
///   quad-by-quad is bit-identical to the two serial sweeps (the same
///   argument as [`crate::sort::bitonic::compare_exchange_double_step`]).
/// * Exactly one thread owns each quad (the owner of its minimum index),
///   so within the single barrier interval no element is touched by two
///   threads, even though three of the four indices live in other
///   threads' chunks (`j_lo >= chunk` here).
/// * All four compare-exchanges share one direction: the quad spans
///   offsets `< 2*j_hi <= k`, never flipping bit `k` (the minimum has
///   `a & j_hi == a & j_lo == 0`, so the additions carry nothing into
///   bit `k`).
fn double_step_lows_in<T: SortKey>(xs: &mut [T], k: usize, j_hi: usize, lo: usize, hi: usize) {
    debug_assert!(j_hi >= 2 && 2 * j_hi <= k);
    let j_lo = j_hi / 2;
    let quad_bits = j_hi | j_lo;
    for a in lo..hi {
        if a & quad_bits == 0 {
            let (b, c) = (a + j_lo, a + j_hi);
            let d = c + j_lo;
            let ascending = a & k == 0;
            cx(xs, a, c, ascending); // stride j_hi: (a, c)
            cx(xs, b, d, ascending); //              (b, d)
            cx(xs, a, b, ascending); // stride j_lo: (a, b)
            cx(xs, c, d, ascending); //              (c, d)
        }
    }
}

#[inline]
fn cx<T: SortKey>(xs: &mut [T], a: usize, b: usize, ascending: bool) {
    let (va, vb) = (xs[a], xs[b]);
    let swap = if ascending {
        vb.total_lt(&va)
    } else {
        va.total_lt(&vb)
    };
    if swap {
        xs.swap(a, b);
    }
}

/// Raw shared-slice smuggler for scoped threads. The disjoint-write
/// argument is documented at the use site.
#[derive(Clone, Copy)]
struct SharedSlice<T>(*mut T, usize);
unsafe impl<T: Send> Send for SharedSlice<T> {}
unsafe impl<T: Send> Sync for SharedSlice<T> {}
impl<T> SharedSlice<T> {
    unsafe fn slice<'a>(&self) -> &'a mut [T] {
        std::slice::from_raw_parts_mut(self.0, self.1)
    }
}

/// Counts panics that unwind out of a worker body.
struct PanicCounter<'a>(&'a AtomicUsize);
impl Drop for PanicCounter<'_> {
    fn drop(&mut self) {
        if std::thread::panicking() {
            self.0.fetch_add(1, Ordering::SeqCst);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sort::verify::{is_sorted, same_multiset};
    use crate::workload::{Distribution, Generator};

    #[test]
    fn matches_sequential_across_sizes_and_threads() {
        let mut gen = Generator::new(0xFA57);
        for logn in [12usize, 13, 15] {
            for threads in [1usize, 2, 4, 8] {
                let orig = gen.u32s(1 << logn, Distribution::Uniform);
                let mut par = orig.clone();
                bitonic_sort_parallel(&mut par, threads);
                assert!(is_sorted(&par), "n=2^{logn} t={threads}");
                assert!(same_multiset(&orig, &par));
            }
        }
    }

    #[test]
    fn all_distributions() {
        let mut gen = Generator::new(0xAB);
        for d in Distribution::ALL {
            let orig = gen.u32s(1 << 13, d);
            let mut v = orig.clone();
            bitonic_sort_parallel(&mut v, 4);
            assert!(is_sorted(&v), "{}", d.name());
            assert!(same_multiset(&orig, &v));
        }
    }

    /// Satellite: the chunked schedule — fused local tails, paired global
    /// double-steps, leftover single global steps — must be bit-exact
    /// with the serial network walk after every barrier interval. The
    /// worker loop is emulated deterministically on one thread (running
    /// every chunk's slice of the interval before the "barrier"), which
    /// pins exactly the step grouping the real workers execute.
    #[test]
    fn chunked_schedule_bit_exact_with_serial_network_walk() {
        use crate::sort::bitonic::compare_exchange_step;
        let mut gen = Generator::new(0xBA121E2);
        for logn in [10usize, 12, 13] {
            let n = 1 << logn;
            for threads in [2usize, 4, 8] {
                let chunk = n / threads;
                let data = gen.u32s(n, Distribution::DupHeavy);
                let mut chunked = data.clone();
                let mut serial = data;
                let steps: Vec<(usize, usize)> =
                    Network::new(n).steps().map(|s| (s.phase_len, s.stride)).collect();
                let mut paired_intervals = 0usize;
                let mut i = 0;
                while i < steps.len() {
                    let (k, j) = steps[i];
                    if j < chunk {
                        for t in 0..threads {
                            run_fused_tail_range(&mut chunked, k, j, t * chunk, (t + 1) * chunk, true);
                        }
                        for jj in
                            std::iter::successors(Some(j), |&x| (x > 1).then_some(x / 2))
                        {
                            compare_exchange_step(&mut serial, k, jj);
                        }
                        i += j.trailing_zeros() as usize + 1;
                    } else if j / 2 >= chunk {
                        for t in 0..threads {
                            double_step_lows_in(&mut chunked, k, j, t * chunk, (t + 1) * chunk);
                        }
                        compare_exchange_step(&mut serial, k, j);
                        compare_exchange_step(&mut serial, k, j / 2);
                        i += 2;
                        paired_intervals += 1;
                    } else {
                        for t in 0..threads {
                            step_lows_in(&mut chunked, k, j, t * chunk, (t + 1) * chunk);
                        }
                        compare_exchange_step(&mut serial, k, j);
                        i += 1;
                    }
                    assert_eq!(
                        chunked, serial,
                        "diverged at n=2^{logn} threads={threads} step {i} (k={k}, j={j})"
                    );
                }
                assert!(is_sorted(&chunked));
                // The pairing must actually engage whenever at least two
                // global strides exist (n >= 4 * chunk).
                if n >= 4 * chunk {
                    assert!(paired_intervals > 0, "pairing never engaged at n=2^{logn} t={threads}");
                }
            }
        }
    }

    /// The paired schedule halves the barrier count of the global
    /// portion: count barrier intervals structurally.
    #[test]
    fn pairing_halves_global_barrier_count() {
        let n = 1 << 16;
        let chunk = n / 8; // 8 threads
        let steps: Vec<(usize, usize)> =
            Network::new(n).steps().map(|s| (s.phase_len, s.stride)).collect();
        let (mut paired_intervals, mut unpaired_intervals) = (0usize, 0usize);
        let mut i = 0;
        while i < steps.len() {
            let (_, j) = steps[i];
            if j < chunk {
                i += j.trailing_zeros() as usize + 1;
                unpaired_intervals += 1; // local tail: one barrier either way
            } else if j / 2 >= chunk {
                i += 2;
                paired_intervals += 1;
            } else {
                i += 1;
                unpaired_intervals += 1;
            }
        }
        // Without pairing every global step is its own interval; with it,
        // paired intervals cover two steps each.
        let with_pairing = paired_intervals + unpaired_intervals;
        let without_pairing = 2 * paired_intervals + unpaired_intervals;
        assert!(paired_intervals > 0);
        assert!(
            with_pairing < without_pairing,
            "pairing saved no barriers: {with_pairing} vs {without_pairing}"
        );
    }

    /// End to end across real threads: the parallel sort (with paired
    /// global steps) must produce byte-identical output to the serial
    /// network walk — sorted u32 output is unique per multiset, so this
    /// is full bit-exactness, across sizes, thread counts and
    /// distributions.
    #[test]
    fn parallel_output_identical_to_serial_walk() {
        let mut gen = Generator::new(0xB17DB1);
        for logn in [12usize, 14] {
            for threads in [2usize, 3, 4, 8] {
                for dist in [Distribution::Uniform, Distribution::DupHeavy] {
                    let data = gen.u32s(1 << logn, dist);
                    let mut par = data.clone();
                    bitonic_sort_parallel(&mut par, threads);
                    let mut ser = data;
                    crate::sort::bitonic::bitonic_sort(&mut ser);
                    assert_eq!(par, ser, "n=2^{logn} t={threads} {}", dist.name());
                }
            }
        }
    }

    #[test]
    fn small_input_falls_back() {
        let mut v = vec![3u32, 1, 4, 1, 5, 9, 2, 6];
        bitonic_sort_parallel(&mut v, 8);
        assert_eq!(v, vec![1, 1, 2, 3, 4, 5, 6, 9]);
    }

    #[test]
    fn non_power_of_two_thread_count() {
        let mut gen = Generator::new(0x77);
        let orig = gen.u32s(1 << 13, Distribution::Uniform);
        let mut v = orig.clone();
        bitonic_sort_parallel(&mut v, 3); // rounds to a power of two
        assert!(is_sorted(&v));
        assert!(same_multiset(&orig, &v));
    }

    #[test]
    fn u64_keys() {
        let mut gen = Generator::new(0x99);
        let orig = gen.u64s(1 << 13, Distribution::Uniform);
        let mut v = orig.clone();
        bitonic_sort_parallel(&mut v, 4);
        assert!(is_sorted(&v));
        assert!(same_multiset(&orig, &v));
    }
}
