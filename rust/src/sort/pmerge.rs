//! Splitter-partitioned parallel multiway merge — the GPU Sample Sort
//! decomposition (Leischner, Osipov & Sanders, PAPERS.md) applied to the
//! hierarchical mega-sort's CPU merge tail.
//!
//! The serial [`crate::sort::kmerge`] pass is `O(n log k)` on one core;
//! every other core idles through it. This module splits that pass by
//! *keys* instead of by runs: pick `P-1` splitters, binary-search each
//! splitter into every sorted run ([`plan_partition`]), and hand each of
//! the resulting `P` buckets — a write-disjoint slice of the output at a
//! prefix-sum offset — to its own loser-tree merge on the shared
//! [`ThreadPool`]. Buckets touch disjoint key ranges and disjoint output
//! ranges, so the workers need no synchronisation beyond the scoped join.
//!
//! Three hazards carried over from the serial merge, all covered by
//! `rust/tests/pmerge_props.rs`:
//!
//! * **Positional exhaustion** — MAX-padded tails are real keys; the
//!   partition counts them like any other key and the per-bucket loser
//!   trees track exhaustion by position, so pads merge correctly.
//! * **f32 total order** — all comparisons go through
//!   [`SortKey::total_lt`] (NaN sorts high, `-0.0 < +0.0`), matching the
//!   device kernels bit for bit.
//! * **Splitter duplicates** — splitters are ranked by `(key, run,
//!   index)`, a total order even when every key is equal, so dup-heavy
//!   inputs cannot collapse into one bucket: bucket sizes are bounded by
//!   [`balance_bound`], which depends only on run lengths, never on key
//!   values.
//!
//! The bucket geometry lives in [`MergePlan`], produced by
//! [`plan_partition`] — the *same* function the static checker
//! (`analysis::disjoint::check_bucket_plan`) replays to prove the
//! partition covers the output exactly once, which is what licenses the
//! unsafe lifetime extension inside `ThreadPool::run_scoped`.

use std::time::Instant;

use crate::sort::kmerge::LoserTree;
use crate::sort::SortKey;
use crate::util::threadpool::{ScopedJob, ThreadPool};

/// Below this many total keys the serial merge wins: the input is
/// cache-resident and the partition + dispatch overhead exceeds the
/// parallel payoff. [`crate::sort::hybrid::HierarchicalSorter`] falls
/// back to [`crate::sort::kmerge::kway_merge`] under this line.
pub const PMERGE_MIN_TOTAL: usize = 1 << 15;

/// Buckets per merge worker: over-decomposing gives the pool slack to
/// load-balance buckets the sampling left uneven.
pub const BUCKETS_PER_THREAD: usize = 2;

/// Bucket geometry of one planned parallel merge.
///
/// `cuts` has `parts + 1` rows of `runs` columns; `cuts[i][q]` is how
/// many keys of run `q` feed buckets `0..i`. Row 0 is all zeros, the
/// last row is the run lengths, and rows are elementwise non-decreasing
/// — so bucket `b` consumes `runs[q][cuts[b][q]..cuts[b+1][q]]` from
/// every run, each key belongs to exactly one bucket, and the bucket's
/// output offset is the prefix sum of the bucket sizes before it.
///
/// The field is public so the mutation tests in
/// `rust/tests/analysis_mutations.rs` can corrupt a plan and prove the
/// static checker rejects it.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct MergePlan {
    /// `cuts[i][q]`: keys of run `q` assigned to buckets `0..i`.
    pub cuts: Vec<Vec<usize>>,
}

impl MergePlan {
    /// Number of buckets (`P`). At most the `parts` requested from
    /// [`plan_partition`]; fewer when the input is too small to split.
    pub fn parts(&self) -> usize {
        self.cuts.len() - 1
    }

    /// Number of input runs.
    pub fn runs(&self) -> usize {
        self.cuts.first().map(Vec::len).unwrap_or(0)
    }

    /// Total keys across all runs.
    pub fn total(&self) -> usize {
        self.cuts.last().map(|row| row.iter().sum()).unwrap_or(0)
    }

    /// Keys per bucket.
    pub fn bucket_sizes(&self) -> Vec<usize> {
        self.cuts
            .windows(2)
            .map(|w| w[0].iter().zip(&w[1]).map(|(lo, hi)| hi - lo).sum())
            .collect()
    }

    /// Output offsets: `offsets[b]..offsets[b+1]` is bucket `b`'s slice
    /// of the output (`parts + 1` entries, last = total).
    pub fn bucket_offsets(&self) -> Vec<usize> {
        let mut offsets = Vec::with_capacity(self.cuts.len());
        let mut acc = 0usize;
        offsets.push(0);
        for size in self.bucket_sizes() {
            acc += size;
            offsets.push(acc);
        }
        offsets
    }

    /// The non-empty `(run, lo, hi)` input slices feeding bucket `b`.
    pub fn bucket_slices(&self, b: usize) -> Vec<(usize, usize, usize)> {
        (0..self.runs())
            .map(|q| (q, self.cuts[b][q], self.cuts[b + 1][q]))
            .filter(|&(_, lo, hi)| lo < hi)
            .collect()
    }

    /// Size of the largest bucket (the parallel merge's critical path).
    pub fn largest_bucket(&self) -> usize {
        self.bucket_sizes().into_iter().max().unwrap_or(0)
    }
}

/// `(key, run, index)` rank order: key first under `total_lt`, ties by
/// position. Total and strict for any key distribution — every element
/// occupies a distinct rank, which is what keeps dup-heavy partitions
/// balanced.
fn rank_cmp<T: SortKey>(
    a: T,
    qa: usize,
    ia: usize,
    b: T,
    qb: usize,
    ib: usize,
) -> std::cmp::Ordering {
    if a.total_lt(&b) {
        std::cmp::Ordering::Less
    } else if b.total_lt(&a) {
        std::cmp::Ordering::Greater
    } else {
        (qa, ia).cmp(&(qb, ib))
    }
}

/// Keys of `run` (run index `q`) ranked at or below the splitter — the
/// key at index `is` of run `rs`. Binary search finds the splitter
/// key's tie range `[lo, hi)`; the `(run, index)` tie-break resolves how
/// much of the tie range falls below the cut.
fn cut_at<T: SortKey>(run: &[T], q: usize, splitter: T, rs: usize, is: usize) -> usize {
    let lo = run.partition_point(|e| e.total_lt(&splitter));
    let hi = run.partition_point(|e| !splitter.total_lt(e));
    match q.cmp(&rs) {
        std::cmp::Ordering::Less => hi,
        std::cmp::Ordering::Greater => lo,
        // The splitter itself lives at index `is` of this run, so
        // lo <= is < hi; exactly the ties up to and including it count.
        std::cmp::Ordering::Equal => (is + 1).clamp(lo, hi),
    }
}

/// Regular sampling (PSRS-style): each run contributes up to `parts-1`
/// evenly spaced positions; the splitters are evenly spaced ranks of the
/// pooled sample under the `(key, run, index)` order. Returns splitter
/// positions in strictly ascending rank order.
fn select_splitters<T: SortKey>(runs: &[&[T]], parts: usize) -> Vec<(usize, usize)> {
    let mut samples: Vec<(usize, usize)> = Vec::new();
    for (q, run) in runs.iter().enumerate() {
        let mut last = usize::MAX;
        for j in 1..parts {
            let idx = j * run.len() / parts;
            if idx < run.len() && idx != last {
                samples.push((q, idx));
                last = idx;
            }
        }
    }
    samples.sort_by(|&(qa, ia), &(qb, ib)| {
        rank_cmp(runs[qa][ia], qa, ia, runs[qb][ib], qb, ib)
    });
    let mut splitters = Vec::new();
    let mut last_pick = usize::MAX;
    for i in 1..parts {
        let pick = i * samples.len() / parts;
        if pick < samples.len() && pick != last_pick {
            splitters.push(samples[pick]);
            last_pick = pick;
        }
    }
    splitters
}

/// Partition `runs` (each sorted ascending under `total_lt`) into at
/// most `parts` buckets of contiguous `(key, run, index)` rank ranges.
///
/// This is the geometry the static checker replays: the runtime and
/// `analysis::disjoint::check_bucket_plan` both consume the returned
/// [`MergePlan`], so the proof and the dispatch cannot drift apart.
pub fn plan_partition<T: SortKey>(runs: &[&[T]], parts: usize) -> MergePlan {
    let parts = parts.max(1);
    let lens: Vec<usize> = runs.iter().map(|r| r.len()).collect();
    let mut cuts = Vec::with_capacity(parts + 1);
    cuts.push(vec![0usize; runs.len()]);
    for &(rs, is) in &select_splitters(runs, parts) {
        let splitter = runs[rs][is];
        let row: Vec<usize> = runs
            .iter()
            .enumerate()
            .map(|(q, run)| cut_at(run, q, splitter, rs, is))
            .collect();
        debug_assert!(
            cuts.last().is_some_and(|prev: &Vec<usize>| prev
                .iter()
                .zip(&row)
                .all(|(a, b)| a <= b)),
            "splitter cuts must be monotone"
        );
        cuts.push(row);
    }
    cuts.push(lens);
    MergePlan { cuts }
}

/// Provable upper bound on any bucket [`plan_partition`] can produce,
/// independent of key values (ranks are unique). With per-run sample
/// gaps of at most `ceil(len/parts) + 1` keys and `S` pooled samples, a
/// bucket spans at most `ceil(S/parts)` interior samples plus one
/// boundary gap per non-empty run. The checker and the property tests
/// both assert real plans against this.
pub fn balance_bound(lens: &[usize], parts: usize) -> usize {
    let parts = parts.max(1);
    let nonempty = lens.iter().filter(|&&m| m > 0).count();
    let gap_max = lens
        .iter()
        .map(|&m| m.div_ceil(parts) + 1)
        .max()
        .unwrap_or(1);
    let samples: usize = lens
        .iter()
        .map(|&m| {
            let mut count = 0;
            let mut last = usize::MAX;
            for j in 1..parts {
                let idx = j * m / parts;
                if idx < m && idx != last {
                    count += 1;
                    last = idx;
                }
            }
            count
        })
        .sum();
    gap_max * (samples.div_ceil(parts) + nonempty + 1)
}

/// One bucket's worker: loser-tree merge of its input slices into its
/// output slice. `dst.len()` equals the summed slice lengths by
/// construction of the plan.
fn merge_bucket<T: SortKey>(srcs: Vec<&[T]>, dst: &mut [T]) {
    match srcs.len() {
        0 => debug_assert!(dst.is_empty()),
        1 => dst.copy_from_slice(srcs[0]),
        _ => {
            let mut tree = LoserTree::new(srcs);
            for slot in dst.iter_mut() {
                *slot = tree.pop().expect("bucket size matches its plan");
            }
            debug_assert!(tree.pop().is_none(), "bucket left keys unmerged");
        }
    }
}

/// Statistics of one parallel merge.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct PmergeStats {
    /// Buckets actually produced (≤ requested).
    pub parts: usize,
    /// Largest bucket (critical path of the scoped dispatch).
    pub largest_bucket: usize,
    /// Time spent planning the partition (splitters + binary searches).
    pub partition_ms: f64,
    /// Wall time of the scoped bucket merges.
    pub merge_ms: f64,
}

/// Merge `runs` into `out` (replaced, not appended) using at most
/// `parts` bucket workers on `pool`. Bit-exact with
/// [`crate::sort::kmerge::kway_merge`] for any [`SortKey`] type: tied
/// keys are bit-identical under `total_lt` (ints trivially, f32/f64 via
/// `total_cmp`), so bucket boundaries cannot reorder observable bytes.
pub fn pmerge<T: SortKey>(
    runs: &[&[T]],
    pool: &ThreadPool,
    parts: usize,
    out: &mut Vec<T>,
) -> crate::Result<PmergeStats> {
    let total: usize = runs.iter().map(|r| r.len()).sum();
    out.clear();
    let t_plan = Instant::now();
    let plan = plan_partition(runs, parts);
    let partition_ms = t_plan.elapsed().as_secs_f64() * 1e3;

    let t_merge = Instant::now();
    out.resize(total, T::MAX_KEY);
    let sizes = plan.bucket_sizes();
    let largest = sizes.iter().copied().max().unwrap_or(0);
    {
        let mut rest: &mut [T] = out.as_mut_slice();
        let mut tasks: Vec<ScopedJob<'_>> = Vec::with_capacity(plan.parts());
        for (b, &size) in sizes.iter().enumerate() {
            // Carving the output by split_at_mut *is* the disjointness:
            // each bucket owns `out[offsets[b]..offsets[b+1]]` and
            // nothing else, per the checked plan geometry.
            let (dst, tail) = rest.split_at_mut(size);
            rest = tail;
            if size == 0 {
                continue;
            }
            let srcs: Vec<&[T]> = plan
                .bucket_slices(b)
                .into_iter()
                .map(|(q, lo, hi)| &runs[q][lo..hi])
                .collect();
            tasks.push(Box::new(move || merge_bucket(srcs, dst)));
        }
        debug_assert!(rest.is_empty(), "plan did not cover the output");
        if let Err(panics) = pool.run_scoped(tasks) {
            crate::bail!("parallel merge: {panics} bucket task(s) panicked");
        }
    }
    Ok(PmergeStats {
        parts: plan.parts(),
        largest_bucket: largest,
        partition_ms,
        merge_ms: t_merge.elapsed().as_secs_f64() * 1e3,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sort::kmerge::kway_merge;
    use crate::workload::rng::Pcg32;

    fn pool() -> ThreadPool {
        ThreadPool::new(4, 16)
    }

    fn random_runs(k: usize, max_len: usize, modulo: u32, seed: u64) -> Vec<Vec<u32>> {
        let mut rng = Pcg32::new(0xBEEF_CAFE, seed);
        (0..k)
            .map(|_| {
                let len = (rng.next_u32() as usize) % (max_len + 1);
                let mut v: Vec<u32> =
                    (0..len).map(|_| rng.next_u32() % modulo).collect();
                v.sort_unstable();
                v
            })
            .collect()
    }

    #[test]
    fn plan_covers_and_stays_monotone() {
        for (k, parts, modulo) in
            [(2usize, 4usize, 1000u32), (3, 8, 7), (16, 8, 1), (5, 2, u32::MAX)]
        {
            let runs = random_runs(k, 300, modulo, (k + parts) as u64);
            let refs: Vec<&[u32]> = runs.iter().map(|r| r.as_slice()).collect();
            let plan = plan_partition(&refs, parts);
            assert!(plan.parts() >= 1 && plan.parts() <= parts);
            assert_eq!(plan.runs(), k);
            assert_eq!(plan.cuts[0], vec![0; k]);
            let lens: Vec<usize> = refs.iter().map(|r| r.len()).collect();
            assert_eq!(*plan.cuts.last().unwrap(), lens);
            for w in plan.cuts.windows(2) {
                for q in 0..k {
                    assert!(w[0][q] <= w[1][q], "non-monotone cut");
                }
            }
            let total: usize = lens.iter().sum();
            assert_eq!(plan.total(), total);
            assert_eq!(*plan.bucket_offsets().last().unwrap(), total);
        }
    }

    #[test]
    fn dup_heavy_buckets_stay_bounded() {
        // All keys equal: the value space has one point, the rank space
        // has `total` — the tie-break must keep the buckets balanced.
        let runs: Vec<Vec<u32>> = (0..8).map(|_| vec![42u32; 512]).collect();
        let refs: Vec<&[u32]> = runs.iter().map(|r| r.as_slice()).collect();
        let lens: Vec<usize> = refs.iter().map(|r| r.len()).collect();
        for parts in [2usize, 4, 8] {
            let plan = plan_partition(&refs, parts);
            assert!(plan.parts() > 1, "all-equal input collapsed to one bucket");
            assert!(
                plan.largest_bucket() <= balance_bound(&lens, parts),
                "parts={parts}: largest {} > bound {}",
                plan.largest_bucket(),
                balance_bound(&lens, parts)
            );
        }
    }

    #[test]
    fn sorted_boundaries_hold() {
        let runs = random_runs(6, 400, 50, 99);
        let refs: Vec<&[u32]> = runs.iter().map(|r| r.as_slice()).collect();
        let plan = plan_partition(&refs, 4);
        // Every key in bucket b must rank at or below every key in
        // bucket b+1: check the boundary elements around each cut row.
        for w in plan.cuts.windows(2) {
            let hi_of_prev = (0..refs.len())
                .filter(|&q| w[0][q] > 0)
                .map(|q| (refs[q][w[0][q] - 1], q, w[0][q] - 1))
                .max_by(|&(a, qa, ia), &(b, qb, ib)| rank_cmp(a, qa, ia, b, qb, ib));
            let lo_of_next = (0..refs.len())
                .filter(|&q| w[0][q] < refs[q].len())
                .map(|q| (refs[q][w[0][q]], q, w[0][q]))
                .min_by(|&(a, qa, ia), &(b, qb, ib)| rank_cmp(a, qa, ia, b, qb, ib));
            if let (Some((a, qa, ia)), Some((b, qb, ib))) = (hi_of_prev, lo_of_next) {
                assert_eq!(
                    rank_cmp(a, qa, ia, b, qb, ib),
                    std::cmp::Ordering::Less,
                    "cut row is not a rank boundary"
                );
            }
        }
    }

    #[test]
    fn matches_serial_merge_exactly() {
        let pool = pool();
        for seed in 0..6u64 {
            let runs = random_runs(2 + (seed as usize % 7), 500, 10_000, seed);
            let refs: Vec<&[u32]> = runs.iter().map(|r| r.as_slice()).collect();
            let mut want = Vec::new();
            kway_merge(&refs, &mut want);
            let mut got = Vec::new();
            pmerge(&refs, &pool, 8, &mut got).unwrap();
            assert_eq!(got, want, "seed {seed}");
        }
    }

    #[test]
    fn max_pads_and_empty_runs_merge_correctly() {
        let pool = pool();
        let runs: Vec<Vec<u32>> = vec![
            vec![5, u32::MAX, u32::MAX],
            vec![],
            vec![1, u32::MAX],
            vec![u32::MAX; 4],
        ];
        let refs: Vec<&[u32]> = runs.iter().map(|r| r.as_slice()).collect();
        let mut got = Vec::new();
        pmerge(&refs, &pool, 4, &mut got).unwrap();
        let mut want = Vec::new();
        kway_merge(&refs, &mut want);
        assert_eq!(got, want);
        assert_eq!(got.iter().filter(|&&x| x == u32::MAX).count(), 7);
    }

    #[test]
    fn f32_total_order_survives_partitioning() {
        let pool = pool();
        let mut a = vec![-0.0f32, 0.0, 1.5, f32::NAN];
        let mut b = vec![f32::NEG_INFINITY, -1.0, 0.0, f32::INFINITY, f32::NAN];
        a.sort_by(|x, y| x.total_cmp(y));
        b.sort_by(|x, y| x.total_cmp(y));
        let refs: Vec<&[f32]> = vec![&a, &b];
        let mut want = Vec::new();
        kway_merge(&refs, &mut want);
        let mut got = Vec::new();
        pmerge(&refs, &pool, 4, &mut got).unwrap();
        let got_bits: Vec<u32> = got.iter().map(|x| x.to_bits()).collect();
        let want_bits: Vec<u32> = want.iter().map(|x| x.to_bits()).collect();
        assert_eq!(got_bits, want_bits, "f32 merge must be bit-exact");
    }

    #[test]
    fn degenerate_shapes() {
        let pool = pool();
        let mut out = vec![7u32];
        pmerge::<u32>(&[], &pool, 4, &mut out).unwrap();
        assert!(out.is_empty());

        pmerge(&[&[1u32, 2, 3][..]], &pool, 4, &mut out).unwrap();
        assert_eq!(out, vec![1, 2, 3]);

        pmerge(&[&[][..], &[][..]], &pool, 4, &mut out).unwrap();
        assert!(out.is_empty());

        // parts = 1 degenerates to one serial bucket.
        pmerge(&[&[2u32][..], &[1u32][..]], &pool, 1, &mut out).unwrap();
        assert_eq!(out, vec![1, 2]);
    }
}
