//! Loser-tree k-way merge: the CPU half of the hierarchical mega-sort.
//!
//! The hierarchical path (see [`crate::sort::hybrid`]) device-sorts a
//! mega-row as cache-sized tiles and then needs the tiles merged in one
//! streaming pass. A pairwise merge tree re-reads every key `log2(k)`
//! times; a tournament (loser) tree reads each key once and decides the
//! next output in exactly `ceil(log2(k))` comparisons — the classic
//! external-merge kernel (Knuth TAOCP §5.4.1), and the same shape GPU
//! Sample Sort uses for its bucket recombination.
//!
//! Keys compare with [`SortKey::total_lt`], so f32 merges agree with the
//! network kernels' total order (NaN sorts high) and exhausted runs are
//! tracked positionally — a run whose keys *are* `MAX_KEY` still merges
//! correctly, which the MAX-padded ragged-tail tests rely on.

use crate::sort::SortKey;

/// Tournament tree over `k` sorted runs; yields the global minimum on
/// every [`LoserTree::pop`] in `ceil(log2 k)` comparisons.
///
/// Layout: conceptual leaves at `k..2k` (leaf `k + j` is run `j`),
/// internal nodes at `1..k` each holding the *loser* of the match below
/// it, and the overall winner cached at `tree[0]`. Works for any `k >= 1`
/// (the tree just becomes ragged, parent links `node/2` still hold).
pub struct LoserTree<'a, T: SortKey> {
    runs: Vec<&'a [T]>,
    /// Next unconsumed index in each run.
    pos: Vec<usize>,
    /// `tree[0]` = current winner run; `tree[1..k]` = losers.
    tree: Vec<usize>,
    k: usize,
}

impl<'a, T: SortKey> LoserTree<'a, T> {
    /// Build the tournament over `runs` (each individually sorted
    /// ascending under `total_lt`; empty runs are fine).
    pub fn new(runs: Vec<&'a [T]>) -> Self {
        let k = runs.len().max(1);
        let mut t = LoserTree {
            pos: vec![0; runs.len()],
            runs,
            tree: vec![0; k],
            k,
        };
        // Seed every leaf, then play matches bottom-up; each internal
        // node keeps its loser and forwards its winner.
        let mut winners = vec![0usize; 2 * k];
        for j in 0..t.runs.len() {
            winners[k + j] = j;
        }
        for j in t.runs.len()..k {
            winners[k + j] = 0; // k = 0 guard: single virtual leaf
        }
        for node in (1..k).rev() {
            let (a, b) = (winners[2 * node], winners[2 * node + 1]);
            if t.leads(a, b) {
                winners[node] = a;
                t.tree[node] = b;
            } else {
                winners[node] = b;
                t.tree[node] = a;
            }
        }
        t.tree[0] = winners[1];
        t
    }

    fn head(&self, run: usize) -> Option<T> {
        self.runs
            .get(run)
            .and_then(|r| r.get(self.pos[run]))
            .copied()
    }

    /// Does `a`'s head beat `b`'s? Exhausted runs lose to everything;
    /// ties break on run index, making the merge stable in run order.
    fn leads(&self, a: usize, b: usize) -> bool {
        match (self.head(a), self.head(b)) {
            (None, _) => false,
            (Some(_), None) => true,
            (Some(x), Some(y)) => {
                if x.total_lt(&y) {
                    true
                } else if y.total_lt(&x) {
                    false
                } else {
                    a <= b
                }
            }
        }
    }

    /// Remove and return the smallest remaining key, or `None` once all
    /// runs are exhausted.
    pub fn pop(&mut self) -> Option<T> {
        let winner = self.tree[0];
        let val = self.head(winner)?;
        self.pos[winner] += 1;
        // Replay the winner's path: at each ancestor the stored loser
        // challenges the ascending run; the better one keeps climbing.
        let mut cur = winner;
        let mut node = (self.k + winner) / 2;
        while node >= 1 {
            let loser = self.tree[node];
            if self.leads(loser, cur) {
                self.tree[node] = cur;
                cur = loser;
            }
            node /= 2;
        }
        self.tree[0] = cur;
        Some(val)
    }
}

/// Merge `k` sorted runs into `out` (appended) in one streaming pass.
/// Total work is `O(total_keys * log k)` comparisons, one read and one
/// write per key.
pub fn kway_merge<T: SortKey>(runs: &[&[T]], out: &mut Vec<T>) {
    let total: usize = runs.iter().map(|r| r.len()).sum();
    out.reserve(total);
    match runs.len() {
        0 => {}
        1 => out.extend_from_slice(runs[0]),
        _ => {
            let mut tree = LoserTree::new(runs.to_vec());
            while let Some(v) = tree.pop() {
                out.push(v);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::rng::Pcg32;

    fn oracle_u32(runs: &[&[u32]]) -> Vec<u32> {
        let mut all: Vec<u32> = runs.iter().flat_map(|r| r.iter().copied()).collect();
        all.sort_unstable();
        all
    }

    #[test]
    fn merges_edge_shapes() {
        let mut out = Vec::new();
        kway_merge::<u32>(&[], &mut out);
        assert!(out.is_empty());

        kway_merge(&[&[3u32, 7, 9][..]], &mut out);
        assert_eq!(out, vec![3, 7, 9]);

        out.clear();
        kway_merge(&[&[][..], &[1u32][..], &[][..]], &mut out);
        assert_eq!(out, vec![1]);

        out.clear();
        kway_merge(&[&[1u32, 3][..], &[2u32, 4][..]], &mut out);
        assert_eq!(out, vec![1, 2, 3, 4]);
    }

    #[test]
    fn max_key_runs_merge_positionally() {
        // Pads equal to MAX_KEY must not be confused with exhaustion.
        let mut out = Vec::new();
        kway_merge(
            &[&[5u32, u32::MAX, u32::MAX][..], &[1u32, u32::MAX][..]],
            &mut out,
        );
        assert_eq!(out, vec![1, 5, u32::MAX, u32::MAX, u32::MAX]);
    }

    #[test]
    fn random_runs_match_oracle_for_many_fanins() {
        let mut rng = Pcg32::new(0xFEED_F00D, 42);
        for k in [2usize, 3, 5, 8, 16, 33, 64] {
            let runs: Vec<Vec<u32>> = (0..k)
                .map(|_| {
                    let len = (rng.next_u32() % 200) as usize;
                    let mut v: Vec<u32> =
                        (0..len).map(|_| rng.next_u32() % 1000).collect();
                    v.sort_unstable();
                    v
                })
                .collect();
            let refs: Vec<&[u32]> = runs.iter().map(|r| r.as_slice()).collect();
            let mut out = Vec::new();
            kway_merge(&refs, &mut out);
            assert_eq!(out, oracle_u32(&refs), "fan-in {k}");
        }
    }

    #[test]
    fn float_merge_uses_the_total_order() {
        let a = [-1.5f32, 0.0, 2.0, f32::NAN];
        let b = [f32::NEG_INFINITY, -1.0f32, 3.0];
        let mut out = Vec::new();
        kway_merge(&[&a[..], &b[..]], &mut out);
        assert!(out[0] == f32::NEG_INFINITY);
        assert!(out.last().unwrap().is_nan(), "NaN sorts high");
        for w in out.windows(2) {
            assert!(!w[1].total_lt(&w[0]), "out of order: {w:?}");
        }
    }
}
