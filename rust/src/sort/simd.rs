//! Explicit SIMD comparator kernels with runtime ISA dispatch.
//!
//! The batch-interleaved kernels in [`crate::sort::bitonic`] are written
//! so the autovectorizer *can* turn their branchless element-major sweeps
//! into vector code — but nothing in the repo proved that it *does*, per
//! dtype. This module makes the lane model literal: the same
//! compare-exchange sweeps implemented three ways, selectable at runtime
//! per [`crate::runtime::ExecutionPlan`]:
//!
//! * [`KernelIsa::Scalar`] — exactly today's kernels in
//!   [`crate::sort::bitonic`]; the autovec baseline and the universal
//!   fallback.
//! * [`KernelIsa::Portable`] — a chunked-scalar variant (fixed
//!   [`CHUNK`]-wide inner blocks) that compiles on every architecture; it
//!   restructures the sweep the way an explicit vector kernel would,
//!   without intrinsics, so the ablation can separate "shape of the loop"
//!   from "instruction selection".
//! * [`KernelIsa::Avx2`] — `core::arch::x86_64` AVX2 intrinsics for
//!   u32 / i32 / f32 keys, 8 lanes per vector, behind the `simd` cargo
//!   feature and an `is_x86_feature_detected!("avx2")` runtime check.
//!   Other key types fall back to the scalar sweep.
//!
//! Every path is **bit-exact** with the scalar kernels: the sweeps apply
//! `key_min`/`key_max` pointwise over disjoint index pairs, so chunking or
//! vectorizing the traversal cannot change any result. For `f32` the AVX2
//! kernel maps IEEE-754 bit patterns through the order-preserving
//! involution used by `f32::total_cmp` (flip the low 31 bits of negative
//! values, compare as signed i32), takes signed integer min/max, and maps
//! back — NaN and ±inf order exactly as the scalar total-order path, and
//! ties recover identical bit patterns because the map is injective.
//!
//! This dispatch seam (resolve a [`KernelChoice`] once per plan, route
//! every inner sweep through it) is where a future wgpu/ISPC backend
//! plugs in (ROADMAP item 5).

use super::bitonic::{
    compare_exchange_double_step_interleaved, compare_exchange_double_step_range,
    compare_exchange_step_interleaved, compare_exchange_step_range,
};
use super::SortKey;

/// Chunk width (keys) of the [`KernelIsa::Portable`] kernels, and the
/// vector width (32-bit lanes) of the AVX2 kernels.
pub const CHUNK: usize = 8;

/// Which key types have an explicit vector lowering. Declared by
/// [`SortKey::LANE_KIND`]; the dispatcher reinterprets key slices as the
/// named primitive, so a non-[`LaneKind::Other`] value asserts that
/// `Self` has exactly that primitive's size, alignment and bit layout.
/// The dispatcher additionally checks size/align at runtime and falls
/// back to the scalar sweep on mismatch — a lying `LANE_KIND` degrades to
/// scalar, it cannot corrupt memory.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LaneKind {
    /// `u32` keys: unsigned integer min/max lanes.
    U32,
    /// `i32` keys: signed integer min/max lanes.
    I32,
    /// `f32` keys: total-order bit mapping + signed integer min/max.
    F32,
    /// No explicit lowering; the scalar sweep runs instead.
    Other,
}

/// The comparator instruction sets a plan can execute with.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum KernelIsa {
    /// The autovec-reliant scalar kernels (today's default path).
    Scalar,
    /// Chunked-scalar kernels: explicit-SIMD loop shape, no intrinsics,
    /// available on every architecture.
    Portable,
    /// AVX2 intrinsics (x86_64, `simd` feature, runtime-detected).
    Avx2,
}

impl KernelIsa {
    /// Every ISA, dispatch-preference order (later = more specialized).
    pub const ALL: [KernelIsa; 3] = [KernelIsa::Scalar, KernelIsa::Portable, KernelIsa::Avx2];

    /// Stable lowercase name (CLI values, autotune TSV column).
    pub fn name(self) -> &'static str {
        match self {
            KernelIsa::Scalar => "scalar",
            KernelIsa::Portable => "portable",
            KernelIsa::Avx2 => "avx2",
        }
    }

    /// Inverse of [`Self::name`].
    pub fn parse(s: &str) -> Option<Self> {
        Self::ALL.into_iter().find(|isa| isa.name() == s)
    }

    /// Can this ISA execute on the current host *and* build? `Scalar`
    /// and `Portable` always can; `Avx2` needs the `simd` feature, an
    /// x86_64 target, and runtime AVX2 support.
    pub fn available(self) -> bool {
        match self {
            KernelIsa::Scalar | KernelIsa::Portable => true,
            KernelIsa::Avx2 => avx2_available(),
        }
    }

    /// The ISAs available on this host, in [`Self::ALL`] order — the
    /// autotuner's sweep axis.
    pub fn available_isas() -> Vec<KernelIsa> {
        Self::ALL.into_iter().filter(|isa| isa.available()).collect()
    }
}

/// True when the AVX2 kernels are compiled in *and* the host supports
/// them. Always false without the `simd` feature or off x86_64.
pub fn avx2_available() -> bool {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        std::arch::is_x86_feature_detected!("avx2")
    }
    #[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
    {
        false
    }
}

/// What the user / profile *asked for*; resolved to a concrete
/// [`KernelIsa`] once per plan. `Auto` is the default: best available
/// ISA (AVX2 when compiled in and detected, else the scalar kernels —
/// so a feature-disabled or non-AVX2 build behaves byte-identically to
/// the pre-SIMD tree).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum KernelChoice {
    /// Pick the best available ISA at plan-compile time.
    #[default]
    Auto,
    /// Force one ISA (validated against availability on the CLI path).
    Fixed(KernelIsa),
}

impl KernelChoice {
    /// Stable name (CLI `--kernel` values).
    pub fn name(self) -> &'static str {
        match self {
            KernelChoice::Auto => "auto",
            KernelChoice::Fixed(isa) => isa.name(),
        }
    }

    /// Parse a CLI `--kernel` value: `auto` or any [`KernelIsa::name`].
    pub fn parse(s: &str) -> Option<Self> {
        if s == "auto" {
            return Some(KernelChoice::Auto);
        }
        KernelIsa::parse(s).map(KernelChoice::Fixed)
    }

    /// Resolve to a concrete ISA for this host. `Auto` prefers AVX2 when
    /// available, else scalar (Portable is never picked implicitly — it
    /// exists for the ablation and for profiles that measured it faster).
    /// A `Fixed` ISA that is unavailable resolves to `Scalar` so that
    /// infallible plan construction stays infallible; fallible entry
    /// points reject it first via [`Self::validate`].
    pub fn resolve(self) -> KernelIsa {
        match self {
            KernelChoice::Auto => {
                if avx2_available() {
                    KernelIsa::Avx2
                } else {
                    KernelIsa::Scalar
                }
            }
            KernelChoice::Fixed(isa) => {
                if isa.available() {
                    isa
                } else {
                    KernelIsa::Scalar
                }
            }
        }
    }

    /// Error when a fixed ISA cannot run here — the executor's compile
    /// path calls this so `--kernel avx2` on a non-AVX2 host (or a build
    /// without the `simd` feature) fails loudly instead of silently
    /// degrading.
    pub fn validate(self) -> crate::Result<()> {
        if let KernelChoice::Fixed(isa) = self {
            crate::ensure!(
                isa.available(),
                "kernel isa {:?} is not available on this host (built with `simd` feature: {}; \
                 pick `auto`, `scalar` or `portable`)",
                isa.name(),
                cfg!(feature = "simd"),
            );
        }
        Ok(())
    }
}

// ----------------------------------------------------------------------
// Dispatching sweep entry points.
// ----------------------------------------------------------------------

/// [`compare_exchange_step_interleaved`] under `isa`. `lanes == 1`
/// degenerates to the scalar-row range kernel, so this single entry point
/// serves both the per-row and the batch-interleaved interpreters. Same
/// preconditions as the scalar kernel.
#[inline]
pub fn step_interleaved<T: SortKey>(
    isa: KernelIsa,
    xs: &mut [T],
    k: usize,
    j: usize,
    lanes: usize,
    lo: usize,
    hi: usize,
) {
    match isa {
        KernelIsa::Scalar => {
            if lanes == 1 {
                compare_exchange_step_range(xs, k, j, lo, hi);
            } else {
                compare_exchange_step_interleaved(xs, k, j, lanes, lo, hi);
            }
        }
        KernelIsa::Portable => portable_step_interleaved(xs, k, j, lanes, lo, hi),
        KernelIsa::Avx2 => {
            if !avx2_step_interleaved(xs, k, j, lanes, lo, hi) {
                compare_exchange_step_interleaved(xs, k, j, lanes, lo, hi);
            }
        }
    }
}

/// [`compare_exchange_double_step_interleaved`] under `isa` — the
/// register-paired quad sweep. Same dispatch contract as
/// [`step_interleaved`].
#[inline]
pub fn double_step_interleaved<T: SortKey>(
    isa: KernelIsa,
    xs: &mut [T],
    k: usize,
    j_hi: usize,
    lanes: usize,
    lo: usize,
    hi: usize,
) {
    match isa {
        KernelIsa::Scalar => {
            if lanes == 1 {
                compare_exchange_double_step_range(xs, k, j_hi, lo, hi);
            } else {
                compare_exchange_double_step_interleaved(xs, k, j_hi, lanes, lo, hi);
            }
        }
        KernelIsa::Portable => portable_double_step_interleaved(xs, k, j_hi, lanes, lo, hi),
        KernelIsa::Avx2 => {
            if !avx2_double_step_interleaved(xs, k, j_hi, lanes, lo, hi) {
                compare_exchange_double_step_interleaved(xs, k, j_hi, lanes, lo, hi);
            }
        }
    }
}

// ----------------------------------------------------------------------
// Portable chunked-scalar kernels.
// ----------------------------------------------------------------------

/// One low/high block sweep in [`CHUNK`]-wide pieces. `DESC` hoists the
/// direction out of the hot loop at compile time.
#[inline]
fn sweep_chunks<T: SortKey, const DESC: bool>(lows: &mut [T], highs: &mut [T]) {
    let mut lc = lows.chunks_exact_mut(CHUNK);
    let mut hc = highs.chunks_exact_mut(CHUNK);
    for (cl, ch) in lc.by_ref().zip(hc.by_ref()) {
        for (x, y) in cl.iter_mut().zip(ch.iter_mut()) {
            let (a, b) = (*x, *y);
            if DESC {
                *x = T::key_max(a, b);
                *y = T::key_min(a, b);
            } else {
                *x = T::key_min(a, b);
                *y = T::key_max(a, b);
            }
        }
    }
    for (x, y) in lc.into_remainder().iter_mut().zip(hc.into_remainder().iter_mut()) {
        let (a, b) = (*x, *y);
        if DESC {
            *x = T::key_max(a, b);
            *y = T::key_min(a, b);
        } else {
            *x = T::key_min(a, b);
            *y = T::key_max(a, b);
        }
    }
}

fn portable_step_interleaved<T: SortKey>(
    xs: &mut [T],
    k: usize,
    j: usize,
    lanes: usize,
    lo: usize,
    hi: usize,
) {
    debug_assert!(lanes >= 1 && j >= 1);
    debug_assert!(lo % (2 * j) == 0 && (hi - lo) % (2 * j) == 0 && hi * lanes <= xs.len());
    let w = j * lanes;
    let mut i = lo;
    while i < hi {
        let base = i * lanes;
        let (lows, highs) = xs[base..base + 2 * w].split_at_mut(w);
        if i & k == 0 {
            sweep_chunks::<T, false>(lows, highs);
        } else {
            sweep_chunks::<T, true>(lows, highs);
        }
        i += 2 * j;
    }
}

/// One quad sweep (blocks A B C D of `w` keys) in [`CHUNK`]-wide pieces;
/// the compare-exchange order per index is the scalar quad order
/// `(a,c) (b,d) (a,b) (c,d)`.
#[inline]
fn sweep_quad_chunks<T: SortKey, const DESC: bool>(
    blk_a: &mut [T],
    blk_b: &mut [T],
    blk_c: &mut [T],
    blk_d: &mut [T],
) {
    let w = blk_a.len();
    let mut t0 = 0;
    while t0 < w {
        let t1 = (t0 + CHUNK).min(w);
        for t in t0..t1 {
            let (mut va, mut vb, mut vc, mut vd) = (blk_a[t], blk_b[t], blk_c[t], blk_d[t]);
            let cx = |lo: &mut T, hi: &mut T| {
                let (a, b) = (*lo, *hi);
                if DESC {
                    *lo = T::key_max(a, b);
                    *hi = T::key_min(a, b);
                } else {
                    *lo = T::key_min(a, b);
                    *hi = T::key_max(a, b);
                }
            };
            cx(&mut va, &mut vc); // stride j_hi: (a, c)
            cx(&mut vb, &mut vd); //              (b, d)
            cx(&mut va, &mut vb); // stride j_lo: (a, b)
            cx(&mut vc, &mut vd); //              (c, d)
            blk_a[t] = va;
            blk_b[t] = vb;
            blk_c[t] = vc;
            blk_d[t] = vd;
        }
        t0 = t1;
    }
}

fn portable_double_step_interleaved<T: SortKey>(
    xs: &mut [T],
    k: usize,
    j_hi: usize,
    lanes: usize,
    lo: usize,
    hi: usize,
) {
    debug_assert!(j_hi >= 2 && 2 * j_hi <= k, "double step needs j_hi >= 2 and 2*j_hi <= k");
    debug_assert!(lanes >= 1);
    debug_assert!(lo % (2 * j_hi) == 0 && (hi - lo) % (2 * j_hi) == 0 && hi * lanes <= xs.len());
    let j_lo = j_hi / 2;
    let w = j_lo * lanes;
    let mut i = lo;
    while i < hi {
        let base = i * lanes;
        let (ab, cd) = xs[base..base + 4 * w].split_at_mut(2 * w);
        let (blk_a, blk_b) = ab.split_at_mut(w);
        let (blk_c, blk_d) = cd.split_at_mut(w);
        if i & k == 0 {
            sweep_quad_chunks::<T, false>(blk_a, blk_b, blk_c, blk_d);
        } else {
            sweep_quad_chunks::<T, true>(blk_a, blk_b, blk_c, blk_d);
        }
        i += 2 * j_hi;
    }
}

// ----------------------------------------------------------------------
// AVX2 dispatch (generic → concrete lane type).
// ----------------------------------------------------------------------

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
fn lanes_match<T, U>() -> bool {
    std::mem::size_of::<T>() == std::mem::size_of::<U>()
        && std::mem::align_of::<T>() == std::mem::align_of::<U>()
}

/// Reinterpret a key slice as its declared lane primitive. Caller has
/// checked [`lanes_match`]; `LANE_KIND`'s contract makes the bit layouts
/// identical.
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
unsafe fn cast_mut<T, U>(xs: &mut [T]) -> &mut [U] {
    // SAFETY: caller checked size_of::<T>() == size_of::<U>() and equal
    // alignment (lanes_match), so the same region holds xs.len() valid
    // U values; the &mut borrow keeps the region exclusive.
    unsafe { std::slice::from_raw_parts_mut(xs.as_mut_ptr() as *mut U, xs.len()) }
}

/// Route one step sweep to the AVX2 kernel for `T`'s lane kind. Returns
/// false (caller falls back to scalar) when AVX2 is not detected at
/// runtime or `T` has no vector lowering.
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
fn avx2_step_interleaved<T: SortKey>(
    xs: &mut [T],
    k: usize,
    j: usize,
    lanes: usize,
    lo: usize,
    hi: usize,
) -> bool {
    if !std::arch::is_x86_feature_detected!("avx2") {
        return false;
    }
    // SAFETY: AVX2 verified above; slice casts guarded by lanes_match.
    unsafe {
        match T::LANE_KIND {
            LaneKind::U32 if lanes_match::<T, u32>() => {
                avx2::step_u32(cast_mut::<T, u32>(xs), k, j, lanes, lo, hi);
            }
            LaneKind::I32 if lanes_match::<T, i32>() => {
                avx2::step_i32(cast_mut::<T, i32>(xs), k, j, lanes, lo, hi);
            }
            LaneKind::F32 if lanes_match::<T, f32>() => {
                avx2::step_f32(cast_mut::<T, f32>(xs), k, j, lanes, lo, hi);
            }
            _ => return false,
        }
    }
    true
}

#[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
fn avx2_step_interleaved<T: SortKey>(
    _xs: &mut [T],
    _k: usize,
    _j: usize,
    _lanes: usize,
    _lo: usize,
    _hi: usize,
) -> bool {
    false
}

/// Double-step twin of [`avx2_step_interleaved`].
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
fn avx2_double_step_interleaved<T: SortKey>(
    xs: &mut [T],
    k: usize,
    j_hi: usize,
    lanes: usize,
    lo: usize,
    hi: usize,
) -> bool {
    if !std::arch::is_x86_feature_detected!("avx2") {
        return false;
    }
    // SAFETY: AVX2 verified above; slice casts guarded by lanes_match.
    unsafe {
        match T::LANE_KIND {
            LaneKind::U32 if lanes_match::<T, u32>() => {
                avx2::double_step_u32(cast_mut::<T, u32>(xs), k, j_hi, lanes, lo, hi);
            }
            LaneKind::I32 if lanes_match::<T, i32>() => {
                avx2::double_step_i32(cast_mut::<T, i32>(xs), k, j_hi, lanes, lo, hi);
            }
            LaneKind::F32 if lanes_match::<T, f32>() => {
                avx2::double_step_f32(cast_mut::<T, f32>(xs), k, j_hi, lanes, lo, hi);
            }
            _ => return false,
        }
    }
    true
}

#[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
fn avx2_double_step_interleaved<T: SortKey>(
    _xs: &mut [T],
    _k: usize,
    _j_hi: usize,
    _lanes: usize,
    _lo: usize,
    _hi: usize,
) -> bool {
    false
}

// ----------------------------------------------------------------------
// The AVX2 kernels themselves.
// ----------------------------------------------------------------------

/// `core::arch::x86_64` lowerings of the interleaved sweeps, 8 × 32-bit
/// lanes per `__m256i`. Each kernel mirrors its scalar twin exactly: the
/// same aligned-run walk, the same per-run direction bit, `key_min` /
/// `key_max` replaced by one vector min/max per 8 keys, and a scalar tail
/// for the final `w % 8` keys of each block (w is `j * lanes`, which need
/// not be a multiple of 8 when `lanes` is small or odd).
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
mod avx2 {
    use super::super::SortKey;
    use core::arch::x86_64::{
        __m256i, _mm256_loadu_si256, _mm256_max_epi32, _mm256_max_epu32, _mm256_min_epi32,
        _mm256_min_epu32, _mm256_srai_epi32, _mm256_srli_epi32, _mm256_storeu_si256,
        _mm256_xor_si256,
    };

    const W: usize = super::CHUNK; // 8 × 32-bit lanes per __m256i

    /// Identity bit map for integer lanes (already totally ordered by
    /// the matching min/max instruction).
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn ord_id(v: __m256i) -> __m256i {
        v
    }

    /// The `f32::total_cmp` bit map, vectorized: XOR each lane with
    /// `0x7FFF_FFFF` when its sign bit is set (arithmetic shift right 31
    /// gives the all-ones mask, logical shift right 1 clears the sign
    /// bit), then compare as signed i32. The sign bit is preserved, so
    /// the map is its own inverse — applied after min/max it recovers
    /// the original IEEE-754 bit patterns exactly, NaN payloads included.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn ord_f32(v: __m256i) -> __m256i {
        _mm256_xor_si256(v, _mm256_srli_epi32::<1>(_mm256_srai_epi32::<31>(v)))
    }

    macro_rules! avx2_kernels {
        ($step:ident, $dstep:ident, $ty:ty, $map:ident, $vmin:ident, $vmax:ident) => {
            /// AVX2 lowering of `compare_exchange_step_interleaved` for
            /// this lane type (see module docs; scalar preconditions
            /// apply).
            ///
            /// # Safety
            /// Requires AVX2 (caller runtime-checks).
            #[target_feature(enable = "avx2")]
            pub unsafe fn $step(
                xs: &mut [$ty],
                k: usize,
                j: usize,
                lanes: usize,
                lo: usize,
                hi: usize,
            ) {
                debug_assert!(lanes >= 1 && j >= 1);
                debug_assert!(
                    lo % (2 * j) == 0 && (hi - lo) % (2 * j) == 0 && hi * lanes <= xs.len()
                );
                let w = j * lanes;
                let vec_w = w - w % W;
                let ptr = xs.as_mut_ptr();
                let mut i = lo;
                // SAFETY: the 2j-alignment/bounds preconditions asserted
                // above keep every offset below `hi * lanes <= xs.len()`,
                // so all `ptr.add`s and unaligned loads/stores stay inside
                // `xs`; low and high blocks of a run never overlap.
                unsafe {
                    while i < hi {
                        let lows = ptr.add(i * lanes);
                        let highs = lows.add(w);
                        let asc = i & k == 0;
                        let mut t = 0;
                        while t < vec_w {
                            let pa = lows.add(t) as *mut __m256i;
                            let pb = highs.add(t) as *mut __m256i;
                            let a = $map(_mm256_loadu_si256(pa));
                            let b = $map(_mm256_loadu_si256(pb));
                            let mn = $map($vmin(a, b));
                            let mx = $map($vmax(a, b));
                            if asc {
                                _mm256_storeu_si256(pa, mn);
                                _mm256_storeu_si256(pb, mx);
                            } else {
                                _mm256_storeu_si256(pa, mx);
                                _mm256_storeu_si256(pb, mn);
                            }
                            t += W;
                        }
                        while t < w {
                            let (a, b) = (*lows.add(t), *highs.add(t));
                            if asc {
                                *lows.add(t) = <$ty as SortKey>::key_min(a, b);
                                *highs.add(t) = <$ty as SortKey>::key_max(a, b);
                            } else {
                                *lows.add(t) = <$ty as SortKey>::key_max(a, b);
                                *highs.add(t) = <$ty as SortKey>::key_min(a, b);
                            }
                            t += 1;
                        }
                        i += 2 * j;
                    }
                }
            }

            /// AVX2 lowering of `compare_exchange_double_step_interleaved`
            /// for this lane type: the four blocks A B C D of the aligned
            /// run, quad compare-exchange order `(a,c) (b,d) (a,b) (c,d)`
            /// per vector index — the register pairing of the paper §4.2
            /// with 8 quads in flight per iteration.
            ///
            /// # Safety
            /// Requires AVX2 (caller runtime-checks).
            #[target_feature(enable = "avx2")]
            pub unsafe fn $dstep(
                xs: &mut [$ty],
                k: usize,
                j_hi: usize,
                lanes: usize,
                lo: usize,
                hi: usize,
            ) {
                debug_assert!(j_hi >= 2 && 2 * j_hi <= k);
                debug_assert!(lanes >= 1);
                debug_assert!(
                    lo % (2 * j_hi) == 0 && (hi - lo) % (2 * j_hi) == 0 && hi * lanes <= xs.len()
                );
                let j_lo = j_hi / 2;
                let w = j_lo * lanes;
                let vec_w = w - w % W;
                let ptr = xs.as_mut_ptr();
                let mut i = lo;
                // SAFETY: as in the single-step kernel — the asserted
                // run alignment and `hi * lanes <= xs.len()` bound keep
                // every quad-block offset in range, and the four blocks
                // of a run are pairwise disjoint.
                unsafe {
                    while i < hi {
                        let base = ptr.add(i * lanes);
                        let asc = i & k == 0;
                        let mut t = 0;
                        while t < vec_w {
                            let pa = base.add(t) as *mut __m256i;
                            let pb = base.add(w + t) as *mut __m256i;
                            let pc = base.add(2 * w + t) as *mut __m256i;
                            let pd = base.add(3 * w + t) as *mut __m256i;
                            let mut va = $map(_mm256_loadu_si256(pa));
                            let mut vb = $map(_mm256_loadu_si256(pb));
                            let mut vc = $map(_mm256_loadu_si256(pc));
                            let mut vd = $map(_mm256_loadu_si256(pd));
                            if asc {
                                let (na, nc) = ($vmin(va, vc), $vmax(va, vc));
                                let (nb, nd) = ($vmin(vb, vd), $vmax(vb, vd));
                                (va, vc) = (na, nc);
                                (vb, vd) = (nb, nd);
                                let (na, nb) = ($vmin(va, vb), $vmax(va, vb));
                                let (nc, nd) = ($vmin(vc, vd), $vmax(vc, vd));
                                (va, vb) = (na, nb);
                                (vc, vd) = (nc, nd);
                            } else {
                                let (na, nc) = ($vmax(va, vc), $vmin(va, vc));
                                let (nb, nd) = ($vmax(vb, vd), $vmin(vb, vd));
                                (va, vc) = (na, nc);
                                (vb, vd) = (nb, nd);
                                let (na, nb) = ($vmax(va, vb), $vmin(va, vb));
                                let (nc, nd) = ($vmax(vc, vd), $vmin(vc, vd));
                                (va, vb) = (na, nb);
                                (vc, vd) = (nc, nd);
                            }
                            _mm256_storeu_si256(pa, $map(va));
                            _mm256_storeu_si256(pb, $map(vb));
                            _mm256_storeu_si256(pc, $map(vc));
                            _mm256_storeu_si256(pd, $map(vd));
                            t += W;
                        }
                        while t < w {
                            let cx = |lo: &mut $ty, hi: &mut $ty| {
                                let (a, b) = (*lo, *hi);
                                if asc {
                                    *lo = <$ty as SortKey>::key_min(a, b);
                                    *hi = <$ty as SortKey>::key_max(a, b);
                                } else {
                                    *lo = <$ty as SortKey>::key_max(a, b);
                                    *hi = <$ty as SortKey>::key_min(a, b);
                                }
                            };
                            let mut va = *base.add(t);
                            let mut vb = *base.add(w + t);
                            let mut vc = *base.add(2 * w + t);
                            let mut vd = *base.add(3 * w + t);
                            cx(&mut va, &mut vc);
                            cx(&mut vb, &mut vd);
                            cx(&mut va, &mut vb);
                            cx(&mut vc, &mut vd);
                            *base.add(t) = va;
                            *base.add(w + t) = vb;
                            *base.add(2 * w + t) = vc;
                            *base.add(3 * w + t) = vd;
                            t += 1;
                        }
                        i += 2 * j_hi;
                    }
                }
            }
        };
    }

    avx2_kernels!(step_u32, double_step_u32, u32, ord_id, _mm256_min_epu32, _mm256_max_epu32);
    avx2_kernels!(step_i32, double_step_i32, i32, ord_id, _mm256_min_epi32, _mm256_max_epi32);
    avx2_kernels!(step_f32, double_step_f32, f32, ord_f32, _mm256_min_epi32, _mm256_max_epi32);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sort::network::Network;

    fn interleave(rows: &[Vec<u32>]) -> Vec<u32> {
        let lanes = rows.len();
        let n = rows[0].len();
        let mut out = vec![0u32; lanes * n];
        for (l, row) in rows.iter().enumerate() {
            for (e, &x) in row.iter().enumerate() {
                out[e * lanes + l] = x;
            }
        }
        out
    }

    #[test]
    fn isa_names_roundtrip() {
        for isa in KernelIsa::ALL {
            assert_eq!(KernelIsa::parse(isa.name()), Some(isa));
            assert_eq!(KernelChoice::parse(isa.name()), Some(KernelChoice::Fixed(isa)));
        }
        assert_eq!(KernelChoice::parse("auto"), Some(KernelChoice::Auto));
        assert_eq!(KernelChoice::parse("avx512"), None);
        assert_eq!(KernelIsa::parse("auto"), None);
    }

    #[test]
    fn scalar_and_portable_always_available() {
        assert!(KernelIsa::Scalar.available());
        assert!(KernelIsa::Portable.available());
        let avail = KernelIsa::available_isas();
        assert!(avail.contains(&KernelIsa::Scalar) && avail.contains(&KernelIsa::Portable));
        assert_eq!(avail.contains(&KernelIsa::Avx2), avx2_available());
    }

    #[test]
    fn choice_resolution_and_validation() {
        assert_eq!(KernelChoice::Fixed(KernelIsa::Portable).resolve(), KernelIsa::Portable);
        assert_eq!(KernelChoice::default(), KernelChoice::Auto);
        let auto = KernelChoice::Auto.resolve();
        assert!(auto == KernelIsa::Avx2 || auto == KernelIsa::Scalar);
        assert_eq!(auto == KernelIsa::Avx2, avx2_available());
        assert!(KernelChoice::Auto.validate().is_ok());
        assert!(KernelChoice::Fixed(KernelIsa::Scalar).validate().is_ok());
        if !avx2_available() {
            assert_eq!(KernelChoice::Fixed(KernelIsa::Avx2).resolve(), KernelIsa::Scalar);
            assert!(KernelChoice::Fixed(KernelIsa::Avx2).validate().is_err());
        } else {
            assert!(KernelChoice::Fixed(KernelIsa::Avx2).validate().is_ok());
        }
    }

    #[test]
    fn every_available_isa_matches_scalar_step_sweeps() {
        // Kernel-level bit-exactness on u32 across strides, directions
        // and ragged lane counts (the property suite in
        // tests/simd_props.rs extends this to i32/f32/NaN and whole
        // plans).
        let mut gen = crate::workload::Generator::new(0x51D1);
        let n = 256;
        for isa in KernelIsa::available_isas() {
            for lanes in [1usize, 3, 4, 8, 16] {
                for ph in Network::new(n).phases() {
                    let k = ph.len;
                    for step in ph.steps() {
                        let j = step.stride;
                        let rows: Vec<Vec<u32>> = (0..lanes)
                            .map(|_| gen.u32s(n, crate::workload::Distribution::DupHeavy))
                            .collect();
                        let mut tile = interleave(&rows);
                        let mut want = tile.clone();
                        step_interleaved(isa, &mut tile, k, j, lanes, 0, n);
                        step_interleaved(KernelIsa::Scalar, &mut want, k, j, lanes, 0, n);
                        assert_eq!(tile, want, "{} lanes={lanes} k={k} j={j}", isa.name());
                    }
                }
            }
        }
    }

    #[test]
    fn every_available_isa_matches_scalar_double_step_sweeps() {
        let mut gen = crate::workload::Generator::new(0x51D2);
        let n = 256;
        for isa in KernelIsa::available_isas() {
            for lanes in [1usize, 3, 8] {
                for ph in Network::new(n).phases() {
                    let k = ph.len;
                    let mut j = k / 2;
                    while j >= 2 {
                        let rows: Vec<Vec<u32>> = (0..lanes)
                            .map(|_| gen.u32s(n, crate::workload::Distribution::DupHeavy))
                            .collect();
                        let mut tile = interleave(&rows);
                        let mut want = tile.clone();
                        double_step_interleaved(isa, &mut tile, k, j, lanes, 0, n);
                        double_step_interleaved(KernelIsa::Scalar, &mut want, k, j, lanes, 0, n);
                        assert_eq!(tile, want, "{} lanes={lanes} k={k} j_hi={j}", isa.name());
                        j /= 2;
                    }
                }
            }
        }
    }

    #[test]
    fn f32_total_order_bit_map_is_involutive_and_monotone() {
        // The scalar model of the AVX2 f32 map: proves the mapped signed
        // comparison equals total_cmp and the map is its own inverse —
        // the two facts the vector kernel's bit-exactness rests on.
        let map = |x: f32| -> i32 {
            let b = x.to_bits() as i32;
            b ^ (((b >> 31) as u32) >> 1) as i32
        };
        let unmap = |m: i32| -> f32 {
            f32::from_bits((m ^ (((m >> 31) as u32) >> 1) as i32) as u32)
        };
        let specials = [
            0.0f32,
            -0.0,
            1.5,
            -1.5,
            f32::INFINITY,
            f32::NEG_INFINITY,
            f32::NAN,
            -f32::NAN,
            f32::MIN_POSITIVE,
            f32::from_bits(0x7FC0_1234), // NaN with payload
            f32::from_bits(0xFFC0_5678), // negative NaN with payload
        ];
        for &a in &specials {
            assert_eq!(unmap(map(a)).to_bits(), a.to_bits(), "involution on {:#x}", a.to_bits());
            for &b in &specials {
                assert_eq!(
                    map(a) < map(b),
                    a.total_cmp(&b) == std::cmp::Ordering::Less,
                    "order of {:#x} vs {:#x}",
                    a.to_bits(),
                    b.to_bits()
                );
            }
        }
    }
}
