//! Quick sort — the paper's CPU baseline (Table 1, "QuickSort" column).
//!
//! The paper motivates quicksort as "more efficient than other sorting
//! algorithms on CPU to some extent" but unsuitable for GPU
//! parallelisation. We implement a production-grade variant rather than a
//! textbook one so the CPU baseline is *fair*: median-of-three pivot
//! selection, three-way (Dutch-national-flag) partitioning for
//! duplicate-heavy inputs, insertion sort below a cutoff, and a depth
//! limit falling back to heapsort (i.e. introsort) so adversarial inputs
//! cannot go quadratic.

use super::{heapsort, SortKey};

/// Below this length, insertion sort wins on modern CPUs.
const INSERTION_CUTOFF: usize = 24;

/// Sort `xs` ascending in place.
pub fn quicksort<T: SortKey>(xs: &mut [T]) {
    let depth_limit = 2 * (usize::BITS - xs.len().leading_zeros()) as usize;
    sort_rec(xs, depth_limit);
}

fn sort_rec<T: SortKey>(xs: &mut [T], depth: usize) {
    let mut xs = xs;
    let mut depth = depth;
    // Tail-recurse into the smaller side to bound stack depth at O(log n).
    loop {
        let n = xs.len();
        if n <= INSERTION_CUTOFF {
            insertion_sort(xs);
            return;
        }
        if depth == 0 {
            // Quadratic-behaviour guard: fall back to heapsort.
            heapsort::heapsort(xs);
            return;
        }
        depth -= 1;
        // Pivot selection also sniffs duplicate density: if the sampled
        // candidates tie, a three-way (Dutch-flag) partition collapses the
        // equal run in O(n); otherwise Hoare's scheme does ~n/4 swaps
        // where Dutch-flag would do ~n.
        let (pivot, samples_tied) = select_pivot(xs);
        if samples_tied {
            let (lt, gt) = partition3(xs);
            let (lo, rest) = xs.split_at_mut(lt);
            let hi = &mut rest[gt - lt..];
            if lo.len() < hi.len() {
                sort_rec(lo, depth);
                xs = hi;
            } else {
                sort_rec(hi, depth);
                xs = lo;
            }
        } else {
            let split = hoare_partition(xs, pivot);
            let (lo, hi) = xs.split_at_mut(split);
            if lo.len() < hi.len() {
                sort_rec(lo, depth);
                xs = hi;
            } else {
                sort_rec(hi, depth);
                xs = lo;
            }
        }
    }
}

/// Median-of-three pivot by value (ninther for large slices). Returns the
/// pivot and whether the sampled candidates were all equal (a strong hint
/// of duplicate-heavy data).
fn select_pivot<T: SortKey>(xs: &[T]) -> (T, bool) {
    let n = xs.len();
    let med3 = |a: T, b: T, c: T| -> T {
        // Median of three values without branches on equality.
        let (lo, hi) = if b.total_lt(&a) { (b, a) } else { (a, b) };
        if c.total_lt(&lo) {
            lo
        } else if hi.total_lt(&c) {
            hi
        } else {
            c
        }
    };
    let pivot = if n >= 512 {
        // Ninther: median of three medians-of-three.
        let s = n / 8;
        let m1 = med3(xs[0], xs[s], xs[2 * s]);
        let m2 = med3(xs[n / 2 - s], xs[n / 2], xs[n / 2 + s]);
        let m3 = med3(xs[n - 1 - 2 * s], xs[n - 1 - s], xs[n - 1]);
        med3(m1, m2, m3)
    } else {
        med3(xs[0], xs[n / 2], xs[n - 1])
    };
    // Tie sniff on the three primary samples.
    let (a, b, c) = (xs[0], xs[n / 2], xs[n - 1]);
    let tied = !a.total_lt(&b) && !b.total_lt(&a) && !b.total_lt(&c) && !c.total_lt(&b);
    (pivot, tied)
}

/// Hoare partition around the pivot *value* `p` (which is guaranteed to be
/// an element of `xs`): returns `split` in `[1, n-1]` with
/// `xs[..split] <= p <= xs[split..]` element-wise. Equal keys distribute
/// to both sides, which keeps splits balanced on low-entropy data.
fn hoare_partition<T: SortKey>(xs: &mut [T], p: T) -> usize {
    let n = xs.len();
    let mut i: isize = -1;
    let mut j: isize = n as isize;
    loop {
        // Each scan stops at an occurrence of `p` (select_pivot
        // guarantees p is an element and never the unique extremum), so
        // i and j stay inside [0, n). Unchecked indexing was tried here
        // and measured <5% on this box — kept safe (§Perf log).
        loop {
            i += 1;
            if !xs[i as usize].total_lt(&p) {
                break;
            }
        }
        loop {
            j -= 1;
            if !p.total_lt(&xs[j as usize]) {
                break;
            }
        }
        if i >= j {
            return (j + 1) as usize;
        }
        xs.swap(i as usize, j as usize);
    }
}

/// Median-of-three pivot: moves the median of first/middle/last to `xs[0]`.
fn median_of_three_to_front<T: SortKey>(xs: &mut [T]) {
    let n = xs.len();
    let (a, b, c) = (0, n / 2, n - 1);
    // Sort the three sampled positions.
    if xs[b].total_lt(&xs[a]) {
        xs.swap(a, b);
    }
    if xs[c].total_lt(&xs[b]) {
        xs.swap(b, c);
        if xs[b].total_lt(&xs[a]) {
            xs.swap(a, b);
        }
    }
    // Median now at b; use it as the pivot.
    xs.swap(0, b);
}

/// Three-way partition around the pivot at `xs[0]`. Returns `(lt, gt)`
/// such that `xs[..lt] < pivot`, `xs[lt..gt] == pivot`, `xs[gt..] > pivot`.
fn partition3<T: SortKey>(xs: &mut [T]) -> (usize, usize) {
    median_of_three_to_front(xs);
    let pivot = xs[0];
    let n = xs.len();
    let (mut lt, mut i, mut gt) = (0usize, 1usize, n);
    while i < gt {
        if xs[i].total_lt(&pivot) {
            xs.swap(lt, i);
            lt += 1;
            i += 1;
        } else if pivot.total_lt(&xs[i]) {
            gt -= 1;
            xs.swap(i, gt);
        } else {
            i += 1;
        }
    }
    (lt, gt)
}

/// Insertion sort for short runs.
pub(crate) fn insertion_sort<T: SortKey>(xs: &mut [T]) {
    for i in 1..xs.len() {
        let mut j = i;
        let v = xs[i];
        while j > 0 && v.total_lt(&xs[j - 1]) {
            xs[j] = xs[j - 1];
            j -= 1;
        }
        xs[j] = v;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sort::verify::{is_sorted, same_multiset};
    use crate::workload::{Distribution, Generator};

    #[test]
    fn sorts_all_distributions_u32() {
        let mut gen = Generator::new(0xC0FFEE);
        for d in Distribution::ALL {
            for n in [0, 1, 2, 3, 17, 100, 1 << 12] {
                let orig = gen.u32s(n, d);
                let mut v = orig.clone();
                quicksort(&mut v);
                assert!(is_sorted(&v), "{} n={n}", d.name());
                assert!(same_multiset(&orig, &v), "{} n={n}", d.name());
            }
        }
    }

    #[test]
    fn sorts_floats_with_total_order() {
        let mut v = vec![3.5f32, -0.0, 0.0, f32::NAN, -1.0, f32::INFINITY, f32::NEG_INFINITY];
        quicksort(&mut v);
        // total order: -inf < -1 < -0.0 < 0.0 < 3.5 < inf < NaN
        assert_eq!(v[0], f32::NEG_INFINITY);
        assert_eq!(v[1], -1.0);
        assert!(v[2].is_sign_negative() && v[2] == 0.0);
        assert!(v[3].is_sign_positive() && v[3] == 0.0);
        assert_eq!(v[4], 3.5);
        assert_eq!(v[5], f32::INFINITY);
        assert!(v[6].is_nan());
    }

    #[test]
    fn matches_std_sort_u64() {
        let mut gen = Generator::new(7);
        let orig = gen.u64s(10_000, Distribution::Uniform);
        let mut ours = orig.clone();
        let mut std = orig;
        quicksort(&mut ours);
        std.sort_unstable();
        assert_eq!(ours, std);
    }

    #[test]
    fn adversarial_sorted_input_not_quadratic() {
        // With median-of-three + introsort guard this completes instantly;
        // the assertion is correctness, the real check is that the test
        // does not time out.
        let mut v: Vec<u32> = (0..200_000).collect();
        quicksort(&mut v);
        assert!(is_sorted(&v));
        let mut v: Vec<u32> = (0..200_000).rev().collect();
        quicksort(&mut v);
        assert!(is_sorted(&v));
    }

    #[test]
    fn all_equal_is_linear_via_three_way() {
        let mut v = vec![42u32; 100_000];
        quicksort(&mut v);
        assert!(v.iter().all(|&x| x == 42));
    }

    #[test]
    fn insertion_sort_standalone() {
        let mut v = vec![5u32, 2, 9, 1, 7, 7, 0];
        insertion_sort(&mut v);
        assert_eq!(v, vec![0, 1, 2, 5, 7, 7, 9]);
    }

    #[test]
    fn partition3_invariant() {
        let mut gen = Generator::new(3);
        for _ in 0..50 {
            let mut v = gen.u32s(257, Distribution::DupHeavy);
            let (lt, gt) = partition3(&mut v);
            assert!(lt <= gt && gt <= v.len());
            let pivot = v[lt];
            assert!(v[..lt].iter().all(|x| x < &pivot));
            assert!(v[lt..gt].iter().all(|x| x == &pivot));
            assert!(v[gt..].iter().all(|x| x > &pivot));
        }
    }
}
