//! Heap sort — named in the paper's introduction; used here both as a
//! standalone baseline and as the introsort fallback of [`crate::sort::quicksort`].

use super::SortKey;

/// Sort `xs` ascending in place via a binary max-heap. `O(n log n)`
/// worst-case, in-place, not stable.
pub fn heapsort<T: SortKey>(xs: &mut [T]) {
    let n = xs.len();
    if n < 2 {
        return;
    }
    // Build the heap (Floyd): sift down from the last parent.
    for i in (0..n / 2).rev() {
        sift_down(xs, i, n);
    }
    // Pop the maximum to the end, shrink, restore.
    for end in (1..n).rev() {
        xs.swap(0, end);
        sift_down(xs, 0, end);
    }
}

/// Restore the max-heap property for the subtree rooted at `root` within
/// `xs[..len]`.
fn sift_down<T: SortKey>(xs: &mut [T], mut root: usize, len: usize) {
    loop {
        let left = 2 * root + 1;
        if left >= len {
            return;
        }
        let right = left + 1;
        let mut largest = root;
        if xs[largest].total_lt(&xs[left]) {
            largest = left;
        }
        if right < len && xs[largest].total_lt(&xs[right]) {
            largest = right;
        }
        if largest == root {
            return;
        }
        xs.swap(root, largest);
        root = largest;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sort::verify::{is_sorted, same_multiset};
    use crate::workload::{Distribution, Generator};

    #[test]
    fn sorts_all_distributions() {
        let mut gen = Generator::new(0xBEEF);
        for d in Distribution::ALL {
            for n in [0, 1, 2, 5, 63, 64, 65, 4096] {
                let orig = gen.u32s(n, d);
                let mut v = orig.clone();
                heapsort(&mut v);
                assert!(is_sorted(&v), "{} n={n}", d.name());
                assert!(same_multiset(&orig, &v));
            }
        }
    }

    #[test]
    fn matches_std_sort() {
        let mut gen = Generator::new(1);
        let orig = gen.u32s(5000, Distribution::Uniform);
        let mut ours = orig.clone();
        let mut std = orig;
        heapsort(&mut ours);
        std.sort_unstable();
        assert_eq!(ours, std);
    }

    #[test]
    fn floats_total_order() {
        let mut v = vec![2.0f64, f64::NAN, -1.0, 0.5];
        heapsort(&mut v);
        assert_eq!(&v[..3], &[-1.0, 0.5, 2.0]);
        assert!(v[3].is_nan());
    }
}
