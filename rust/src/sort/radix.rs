//! LSD radix sort — named in the paper's introduction ("Radix sorting");
//! the non-comparison baseline that bounds what any comparison sort can
//! achieve on 32-bit integer keys.

/// Sort `xs` ascending in place (8-bit digits, 4 passes, `O(n)` scratch).
pub fn radix_sort_u32(xs: &mut Vec<u32>) {
    let n = xs.len();
    if n < 2 {
        return;
    }
    let mut scratch = vec![0u32; n];
    let mut src_is_xs = true;
    for pass in 0..4 {
        let shift = pass * 8;
        let (src, dst): (&[u32], &mut [u32]) = if src_is_xs {
            (&xs[..], &mut scratch[..])
        } else {
            (&scratch[..], &mut xs[..])
        };
        // Counting pass.
        let mut counts = [0usize; 256];
        for &x in src {
            counts[((x >> shift) & 0xff) as usize] += 1;
        }
        // Skip the scatter entirely if all keys share this digit.
        if counts.iter().any(|&c| c == n) {
            continue;
        }
        // Exclusive prefix sum → bucket offsets.
        let mut offsets = [0usize; 256];
        let mut acc = 0;
        for d in 0..256 {
            offsets[d] = acc;
            acc += counts[d];
        }
        // Stable scatter.
        for &x in src {
            let d = ((x >> shift) & 0xff) as usize;
            dst[offsets[d]] = x;
            offsets[d] += 1;
        }
        src_is_xs = !src_is_xs;
    }
    if !src_is_xs {
        xs.copy_from_slice(&scratch);
    }
}

/// Sort `xs` of `u64` keys ascending in place (8 passes).
pub fn radix_sort_u64(xs: &mut Vec<u64>) {
    let n = xs.len();
    if n < 2 {
        return;
    }
    let mut scratch = vec![0u64; n];
    let mut src_is_xs = true;
    for pass in 0..8 {
        let shift = pass * 8;
        let (src, dst): (&[u64], &mut [u64]) = if src_is_xs {
            (&xs[..], &mut scratch[..])
        } else {
            (&scratch[..], &mut xs[..])
        };
        let mut counts = [0usize; 256];
        for &x in src {
            counts[((x >> shift) & 0xff) as usize] += 1;
        }
        if counts.iter().any(|&c| c == n) {
            continue;
        }
        let mut offsets = [0usize; 256];
        let mut acc = 0;
        for d in 0..256 {
            offsets[d] = acc;
            acc += counts[d];
        }
        for &x in src {
            let d = ((x >> shift) & 0xff) as usize;
            dst[offsets[d]] = x;
            offsets[d] += 1;
        }
        src_is_xs = !src_is_xs;
    }
    if !src_is_xs {
        xs.copy_from_slice(&scratch);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sort::verify::{is_sorted, same_multiset};
    use crate::workload::{Distribution, Generator};

    #[test]
    fn sorts_all_distributions() {
        let mut gen = Generator::new(0x4AD1);
        for d in Distribution::ALL {
            for n in [0, 1, 2, 255, 256, 257, 10_000] {
                let orig = gen.u32s(n, d);
                let mut v = orig.clone();
                radix_sort_u32(&mut v);
                assert!(is_sorted(&v), "{} n={n}", d.name());
                assert!(same_multiset(&orig, &v));
            }
        }
    }

    #[test]
    fn matches_std_sort() {
        let mut gen = Generator::new(12);
        let orig = gen.u32s(50_000, Distribution::Uniform);
        let mut ours = orig.clone();
        let mut std = orig;
        radix_sort_u32(&mut ours);
        std.sort_unstable();
        assert_eq!(ours, std);
    }

    #[test]
    fn digit_skip_path_constant_digits() {
        // Keys identical in three of four digit positions exercise the
        // counts[d]==n skip.
        let mut v: Vec<u32> = (0..1000u32).map(|i| 0xAABB_CC00 | (i % 256)).collect();
        let orig = v.clone();
        radix_sort_u32(&mut v);
        assert!(is_sorted(&v));
        assert!(same_multiset(&orig, &v));
    }

    #[test]
    fn u64_matches_std() {
        let mut gen = Generator::new(13);
        let orig = gen.u64s(20_000, Distribution::Uniform);
        let mut ours = orig.clone();
        let mut std = orig;
        radix_sort_u64(&mut ours);
        std.sort_unstable();
        assert_eq!(ours, std);
    }
}
