//! Merge sort — named in the paper's introduction ("Bitonic sort is a
//! binary merge sort"); the stable `O(n log n)` CPU baseline.

use super::quicksort::insertion_sort;
use super::SortKey;

/// Below this, insertion sort is faster than recursing.
const INSERTION_CUTOFF: usize = 32;

/// Sort `xs` ascending, stable, using `O(n)` scratch.
pub fn mergesort<T: SortKey>(xs: &mut [T]) {
    let n = xs.len();
    if n < 2 {
        return;
    }
    let mut scratch = xs.to_vec();
    sort_into(&mut scratch, xs);
}

/// Merge-sorts `src` writing the result into `dst` (ping-pong buffers;
/// both start as copies of the input).
fn sort_into<T: SortKey>(src: &mut [T], dst: &mut [T]) {
    let n = dst.len();
    if n <= INSERTION_CUTOFF {
        insertion_sort(dst);
        return;
    }
    let mid = n / 2;
    // Sort each half of `src` (using `dst` halves as their scratch)…
    sort_into(&mut dst[..mid], &mut src[..mid]);
    sort_into(&mut dst[mid..], &mut src[mid..]);
    // …then merge the halves of `src` into `dst`.
    merge(&src[..mid], &src[mid..], dst);
}

/// Stable two-way merge of sorted `a` and `b` into `out`.
fn merge<T: SortKey>(a: &[T], b: &[T], out: &mut [T]) {
    debug_assert_eq!(a.len() + b.len(), out.len());
    let (mut i, mut j) = (0, 0);
    for slot in out.iter_mut() {
        // `!b<a` keeps equal keys from `a` first → stability.
        if i < a.len() && (j >= b.len() || !b[j].total_lt(&a[i])) {
            *slot = a[i];
            i += 1;
        } else {
            *slot = b[j];
            j += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sort::verify::{is_sorted, same_multiset};
    use crate::workload::{Distribution, Generator};

    #[test]
    fn sorts_all_distributions() {
        let mut gen = Generator::new(0xFEED);
        for d in Distribution::ALL {
            for n in [0, 1, 2, 31, 32, 33, 1000, 4096] {
                let orig = gen.u32s(n, d);
                let mut v = orig.clone();
                mergesort(&mut v);
                assert!(is_sorted(&v), "{} n={n}", d.name());
                assert!(same_multiset(&orig, &v));
            }
        }
    }

    #[test]
    fn is_stable() {
        // Sort (key, tag) pairs by key only; tags of equal keys must keep
        // input order. Encode key in the high half, tag low, sort by the
        // key half via a wrapper type… simplest: u64 with key<<32|seq and
        // compare full value — equal keys then order by seq automatically,
        // so instead verify stability by sorting u32 keys duplicated with
        // sequence-encoded low bits and checking low bits ascend within
        // equal groups.
        let keys = [5u32, 1, 5, 3, 1, 5, 3, 1];
        let mut v: Vec<u64> = keys
            .iter()
            .enumerate()
            .map(|(i, &k)| ((k as u64) << 32) | i as u64)
            .collect();
        // Stable sort on the packed value equals stable sort on key, and
        // within equal keys the sequence numbers must ascend.
        mergesort(&mut v);
        for w in v.windows(2) {
            if w[0] >> 32 == w[1] >> 32 {
                assert!((w[0] & 0xffff_ffff) < (w[1] & 0xffff_ffff));
            }
        }
    }

    #[test]
    fn matches_std_sort() {
        let mut gen = Generator::new(11);
        let orig = gen.u32s(10_000, Distribution::Uniform);
        let mut ours = orig.clone();
        let mut std = orig;
        mergesort(&mut ours);
        std.sort();
        assert_eq!(ours, std);
    }
}
