//! Output validation shared by tests, benches and the service.

use super::SortKey;

/// Is `xs` ascending under the total order?
pub fn is_sorted<T: SortKey>(xs: &[T]) -> bool {
    xs.windows(2).all(|w| !w[1].total_lt(&w[0]))
}

/// Is `xs` descending under the total order?
pub fn is_sorted_desc<T: SortKey>(xs: &[T]) -> bool {
    xs.windows(2).all(|w| !w[0].total_lt(&w[1]))
}

/// Do `a` and `b` contain the same multiset of keys? Implemented via a
/// content hash that is order-independent but multiplicity-sensitive, so
/// it works for float bit patterns too and stays O(n) with no allocation
/// proportional to the key domain.
pub fn same_multiset<T: SortKey + PartialEq + std::fmt::Debug>(a: &[T], b: &[T]) -> bool {
    if a.len() != b.len() {
        return false;
    }
    fn hash_of<T>(x: &T) -> u64 {
        // FNV over the value's bytes; keys are Copy + 'static plain data.
        let bytes = unsafe {
            std::slice::from_raw_parts((x as *const T).cast::<u8>(), std::mem::size_of::<T>())
        };
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        for &byte in bytes {
            h ^= byte as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01B3);
        }
        // Post-mix so that summing hashes detects multiplicity changes.
        let mut z = h;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
    let sum = |xs: &[T]| -> (u64, u64) {
        let mut add = 0u64;
        let mut xor_rot = 0u64;
        for (i, x) in xs.iter().enumerate() {
            let h = hash_of(x);
            add = add.wrapping_add(h);
            let _ = i;
            xor_rot ^= h.rotate_left((h % 63) as u32);
        }
        (add, xor_rot)
    };
    sum(a) == sum(b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn is_sorted_basic() {
        assert!(is_sorted(&[1u32, 2, 2, 3]));
        assert!(!is_sorted(&[2u32, 1]));
        assert!(is_sorted::<u32>(&[]));
        assert!(is_sorted(&[5u32]));
    }

    #[test]
    fn is_sorted_desc_basic() {
        assert!(is_sorted_desc(&[3u32, 2, 2, 1]));
        assert!(!is_sorted_desc(&[1u32, 2]));
    }

    #[test]
    fn multiset_detects_substitution() {
        assert!(same_multiset(&[1u32, 2, 3], &[3, 1, 2]));
        assert!(!same_multiset(&[1u32, 2, 3], &[1, 2, 4]));
        assert!(!same_multiset(&[1u32, 2], &[1, 2, 2]));
        // Multiplicity change with same element set.
        assert!(!same_multiset(&[1u32, 1, 2], &[1, 2, 2]));
    }

    #[test]
    fn multiset_floats_bitwise() {
        assert!(same_multiset(&[0.0f32, 1.0], &[1.0, 0.0]));
        // -0.0 and 0.0 differ bitwise — by design (matches total order).
        assert!(!same_multiset(&[0.0f32], &[-0.0f32]));
    }
}
