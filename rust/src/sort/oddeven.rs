//! Odd-even transposition sort — named in the paper's introduction; the
//! simplest sorting *network* (O(n²) comparators, depth n). Included as a
//! baseline network to contrast with bitonic's O(n log² n) comparators /
//! O(log² n) depth in the network-ablation benchmarks.

use super::SortKey;

/// Sort `xs` ascending in place via n rounds of alternating odd/even
/// adjacent compare-exchanges.
pub fn oddeven_sort<T: SortKey>(xs: &mut [T]) {
    let n = xs.len();
    if n < 2 {
        return;
    }
    for round in 0..n {
        let start = round % 2;
        let mut swapped = false;
        let mut i = start;
        while i + 1 < n {
            if xs[i + 1].total_lt(&xs[i]) {
                xs.swap(i, i + 1);
                swapped = true;
            }
            i += 2;
        }
        // Early exit: two consecutive clean rounds ⇒ sorted. One clean
        // round is insufficient in general, so track parity.
        if !swapped && round > 0 {
            // Check the other parity once; if also clean we are done.
            let other = (start + 1) % 2;
            let mut clean = true;
            let mut i = other;
            while i + 1 < n {
                if xs[i + 1].total_lt(&xs[i]) {
                    clean = false;
                    break;
                }
                i += 2;
            }
            if clean {
                return;
            }
        }
    }
}

/// Comparator count of the full odd-even network on `n` keys (for the
/// network comparison bench): `n` rounds × ~n/2 comparators.
pub fn comparator_count(n: usize) -> usize {
    if n < 2 {
        return 0;
    }
    // Even rounds have floor(n/2) comparators, odd rounds floor((n-1)/2).
    let even_rounds = n.div_ceil(2);
    let odd_rounds = n / 2;
    even_rounds * (n / 2) + odd_rounds * ((n - 1) / 2)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sort::verify::{is_sorted, same_multiset};
    use crate::workload::{Distribution, Generator};

    #[test]
    fn sorts_all_distributions() {
        let mut gen = Generator::new(0x0DD);
        for d in Distribution::ALL {
            for n in [0, 1, 2, 3, 64, 255, 1024] {
                let orig = gen.u32s(n, d);
                let mut v = orig.clone();
                oddeven_sort(&mut v);
                assert!(is_sorted(&v), "{} n={n}", d.name());
                assert!(same_multiset(&orig, &v));
            }
        }
    }

    #[test]
    fn early_exit_on_sorted() {
        let mut v: Vec<u32> = (0..10_000).collect();
        oddeven_sort(&mut v); // must be fast (early exit), not O(n^2) work
        assert!(is_sorted(&v));
    }

    #[test]
    fn comparator_count_small() {
        assert_eq!(comparator_count(0), 0);
        assert_eq!(comparator_count(1), 0);
        // n=4: rounds 0,2 (even start): 2 comparators each; rounds 1,3: 1 each.
        assert_eq!(comparator_count(4), 2 * 2 + 2 * 1);
    }
}
