//! Bitonic sorting-network schedule generation.
//!
//! This module is the single source of truth for *which* compare-exchange
//! steps each implementation variant executes, and in what grouping. The
//! CPU bitonic sorts iterate it directly; the GPU simulator derives launch
//! counts, global-memory passes and shared-memory traffic from it
//! (DESIGN.md §4); the unit tests check it against the paper's closed
//! forms (§3.2: `k(k+1)/2` rounds, `n·k(k+1)/4` compare-exchanges for
//! `n = 2^k`); and `examples/network_viz.rs` renders the paper's Figure 2
//! from it.
//!
//! Terminology follows the paper: sorting `n = 2^k` keys takes `k`
//! *phases*; phase `p` (1-based) sorts bitonic subsequences of length
//! `2^p` and consists of `p` *steps* with compare-exchange strides
//! `2^(p-1), 2^(p-2), …, 1`.
//!
//! Besides *generating* schedules, this module also *executes* them: the
//! launch interpreter ([`run_launch`], [`run_fused_tail_range`]) runs one
//! [`Launch`] in a single pass over memory — fused tile groups stay
//! cache-resident, double steps pair strides in registers — and is what
//! the runtime's [`crate::runtime::ExecutionPlan`] walks per row.

use super::bitonic::{compare_exchange_double_step_range, compare_exchange_step_range};
use super::simd::{self, KernelIsa};
use super::SortKey;

/// One compare-exchange step: all pairs `(i, i ^ stride)` with direction
/// decided by bit `phase_len` of `i` (ascending iff `i & phase_len == 0`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Step {
    /// Phase length `k = 2^p` this step belongs to.
    pub phase_len: usize,
    /// Compare-exchange stride `j` (power of two, `j < phase_len`).
    pub stride: usize,
}

/// One phase: `log2(phase_len)` steps with descending strides.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Phase {
    /// Sorted-subsequence length after this phase (`2^p`).
    pub len: usize,
}

impl Phase {
    /// Steps of this phase, stride high → low.
    pub fn steps(self) -> impl Iterator<Item = Step> {
        let k = self.len;
        std::iter::successors(Some(k / 2), |&j| (j > 1).then_some(j / 2)).map(move |stride| Step {
            phase_len: k,
            stride,
        })
    }
}

/// How steps are *grouped into kernel launches / passes* — the three GPU
/// implementations the paper evaluates, plus the CPU reference.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Variant {
    /// §3.3: one kernel launch per step; every step is a full
    /// global-memory pass. `k(k+1)/2` launches.
    Basic,
    /// §4.1 (optimization 1, "Semi"): once `stride < block`, the rest of
    /// the phase runs inside shared memory/VMEM in one launch.
    Semi,
    /// §4.2 (optimizations 1+2, "Optimized"): additionally, global steps
    /// are fused two-at-a-time (each thread keeps 4 elements in
    /// registers), halving global passes; the in-block stage pairs steps
    /// the same way.
    Optimized,
}

impl Variant {
    /// Stable name used in CLI flags, artifact filenames and reports.
    pub fn name(self) -> &'static str {
        match self {
            Variant::Basic => "basic",
            Variant::Semi => "semi",
            Variant::Optimized => "optimized",
        }
    }

    /// Parse a CLI/artifact name.
    pub fn parse(s: &str) -> Option<Variant> {
        match s {
            "basic" => Some(Variant::Basic),
            "semi" => Some(Variant::Semi),
            "optimized" | "opt" => Some(Variant::Optimized),
            _ => None,
        }
    }

    /// All variants in paper order.
    pub const ALL: [Variant; 3] = [Variant::Basic, Variant::Semi, Variant::Optimized];
}

/// One *launch* (CUDA kernel launch / Pallas `pallas_call`): a group of
/// consecutive steps executed in a single pass over memory.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Launch {
    /// A single global-memory compare-exchange step.
    GlobalStep(Step),
    /// Two consecutive global steps (strides `hi`, `hi/2`) fused via
    /// registers (optimization 2). One read-modify-write pass.
    GlobalDoubleStep {
        /// Phase length `k`.
        phase_len: usize,
        /// The larger of the two fused strides.
        stride_hi: usize,
    },
    /// All steps of phases `phase_lo..=phase_hi` whose strides fit in one
    /// block, executed out of shared memory/VMEM (optimization 1). For the
    /// presort this covers *every* early phase (`phase_lo = 2`); for later
    /// phases it is the `stride < block` tail of a single phase.
    BlockFused {
        /// First phase length covered (inclusive, power of two).
        phase_lo: usize,
        /// Last phase length covered (inclusive).
        phase_hi: usize,
        /// Maximum stride executed inside the block (`block/2`).
        stride_max: usize,
        /// Whether the fused kernel pairs steps via registers (opt 2).
        register_paired: bool,
    },
}

impl Launch {
    /// The exact `(phase_len, stride)` steps this launch covers, in
    /// execution order.
    ///
    /// **Invariant (the fusion algebra):** concatenating `steps()` over
    /// `Network::launches(variant, block)` reproduces
    /// [`Network::step_schedule`] *exactly* — same steps, same order —
    /// for every variant and block; likewise [`Network::merge_launches`]
    /// reproduces the final phase's steps. Fusion only regroups
    /// consecutive steps into passes, it never reorders them. This is the
    /// single source of truth for step order: the interpreter
    /// ([`run_launch`]), [`Launch::step_count`], and the tests all derive
    /// from this expansion, pinned exhaustively by
    /// `launch_expansion_reproduces_step_schedule_exactly`.
    pub fn steps(&self) -> Vec<Step> {
        match *self {
            Launch::GlobalStep(s) => vec![s],
            Launch::GlobalDoubleStep {
                phase_len,
                stride_hi,
            } => vec![
                Step { phase_len, stride: stride_hi },
                Step { phase_len, stride: stride_hi / 2 },
            ],
            Launch::BlockFused {
                phase_lo,
                phase_hi,
                stride_max,
                ..
            } => {
                // For each covered phase k, the steps with stride <=
                // stride_max, high to low (a phase's in-block tail).
                let mut out = Vec::new();
                let mut k = phase_lo;
                while k <= phase_hi {
                    out.extend(Phase { len: k }.steps().filter(|s| s.stride <= stride_max));
                    k *= 2;
                }
                out
            }
        }
    }

    /// Number of compare-exchange *steps* of the network this launch
    /// covers.
    pub fn step_count(&self) -> usize {
        self.steps().len()
    }

    /// Number of element-passes over *global* memory (HBM) this launch
    /// costs: every launch reads and writes the array exactly once,
    /// regardless of how many steps it fuses — that is the whole point of
    /// the optimizations.
    pub fn global_passes(&self) -> usize {
        1
    }
}

/// The full bitonic network for `n = 2^k` keys.
#[derive(Clone, Copy, Debug)]
pub struct Network {
    /// Number of keys (power of two).
    pub n: usize,
}

impl Network {
    /// Build a network for `n` keys. Panics unless `n` is a power of two
    /// and `n >= 2`.
    pub fn new(n: usize) -> Self {
        assert!(n.is_power_of_two() && n >= 2, "bitonic network needs n = 2^k >= 2, got {n}");
        Self { n }
    }

    /// `k = log2 n`.
    pub fn log2n(self) -> u32 {
        self.n.trailing_zeros()
    }

    /// Phases in execution order (subsequence length 2, 4, …, n).
    pub fn phases(self) -> impl Iterator<Item = Phase> {
        let n = self.n;
        std::iter::successors(Some(2usize), move |&k| (k < n).then_some(k * 2))
            .map(|len| Phase { len })
    }

    /// All steps in execution order.
    pub fn steps(self) -> impl Iterator<Item = Step> {
        self.phases().flat_map(Phase::steps)
    }

    /// The flat `(phase_len, stride)` step schedule as an owned list —
    /// the reference order the launch fusion must preserve: expanding
    /// [`Self::launches`] via [`Launch::steps`] reproduces this exactly.
    /// (The runtime's [`crate::runtime::ExecutionPlan`] compiles the
    /// *launch* form; `Variant::Basic` degenerates to this walk.)
    pub fn step_schedule(self) -> Vec<Step> {
        self.steps().collect()
    }

    /// Total number of steps — the paper's `k(k+1)/2` "rounds".
    pub fn step_count(self) -> usize {
        let k = self.log2n() as usize;
        k * (k + 1) / 2
    }

    /// Total compare-exchange operations — the paper's `n·k(k+1)/4`.
    pub fn compare_exchange_count(self) -> usize {
        self.n / 2 * self.step_count()
    }

    /// The launch schedule a given implementation variant executes, with
    /// block capacity `block` keys (shared-memory/VMEM tile size).
    ///
    /// This is the exact sequence of `pallas_call`s the Python layer emits
    /// (see `python/compile/model.py::plan`, which mirrors this function)
    /// and the sequence of kernel launches the simulator charges for.
    pub fn launches(self, variant: Variant, block: usize) -> Vec<Launch> {
        assert!(
            block.is_power_of_two() && block >= 2,
            "block must be a power of two >= 2, got {block}"
        );
        let n = self.n;
        let block = block.min(n);
        let mut out = Vec::new();
        match variant {
            Variant::Basic => {
                for s in self.steps() {
                    out.push(Launch::GlobalStep(s));
                }
            }
            Variant::Semi | Variant::Optimized => {
                let paired = variant == Variant::Optimized;
                // Presort: every phase up to `block` runs inside the block.
                out.push(Launch::BlockFused {
                    phase_lo: 2,
                    phase_hi: block,
                    stride_max: block / 2,
                    register_paired: paired,
                });
                // Later phases: global steps until the stride fits in a
                // block, then one fused in-block launch for the tail.
                let mut k = 2 * block;
                while k <= n {
                    phase_tail_launches(k, block, paired, &mut out);
                    k *= 2;
                }
            }
        }
        out
    }

    /// The launch schedule of the *final phase only* (`phase_len = n`):
    /// merging one bitonic row into sorted order, `log2(n)` steps instead
    /// of the full network's `k(k+1)/2`. The Python mirror is
    /// `python/compile/model.py::merge_plan`; the runtime compiles Merge
    /// artifacts' [`crate::runtime::ExecutionPlan`]s from this.
    pub fn merge_launches(self, variant: Variant, block: usize) -> Vec<Launch> {
        assert!(
            block.is_power_of_two() && block >= 2,
            "block must be a power of two >= 2, got {block}"
        );
        let n = self.n;
        let block = block.min(n);
        let mut out = Vec::new();
        if variant == Variant::Basic {
            let mut j = n / 2;
            while j >= 1 {
                out.push(Launch::GlobalStep(Step { phase_len: n, stride: j }));
                j /= 2;
            }
            return out;
        }
        phase_tail_launches(n, block, variant == Variant::Optimized, &mut out);
        out
    }

    /// Compare-exchange pairs `(i, i^stride, ascending)` of one step, in
    /// index order — used by the network visualiser (paper Fig. 2) and by
    /// exhaustive small-n tests.
    pub fn step_pairs(self, step: Step) -> Vec<(usize, usize, bool)> {
        let mut pairs = Vec::with_capacity(self.n / 2);
        for i in 0..self.n {
            let partner = i ^ step.stride;
            if partner > i {
                let ascending = i & step.phase_len == 0;
                pairs.push((i, partner, ascending));
            }
        }
        pairs
    }

    /// Statically verify this network: every `(variant, block,
    /// interleave)` launch program the geometry menu produces for `n`
    /// must expand to [`Self::step_schedule`], and the schedule itself
    /// must sort by the 0–1 principle (exhaustive up to the default
    /// cap). See [`crate::analysis::network_check`].
    pub fn analyze(self) -> crate::analysis::Report {
        let mut proofs = crate::analysis::network_check::ProofCache::new();
        crate::analysis::network_check::check_geometry_sweep(
            crate::runtime::ArtifactKind::Sort,
            self.n,
            &crate::analysis::VerifyOptions::default(),
            &mut proofs,
        )
    }
}

/// The launch grouping of one post-presort phase `k` (Semi/Optimized):
/// paired global double-steps while both strides stay `>= block` (opt 2,
/// `paired` only), single global steps down to `block`, then the one
/// in-block fused launch for the `stride < block` tail (opt 1). Shared by
/// [`Network::launches`] (every phase `k > block`) and
/// [`Network::merge_launches`] (exactly this at `k = n`) so the "merge is
/// the final phase only" relationship is structural, not copy-paste —
/// mirrored by `_phase_tail` in `python/compile/planner.py`.
fn phase_tail_launches(k: usize, block: usize, paired: bool, out: &mut Vec<Launch>) {
    let mut j = k / 2;
    if paired {
        // Fuse global steps two-at-a-time while both strides stay
        // >= block (the lower stride of the pair is j/2).
        while j >= 2 * block {
            out.push(Launch::GlobalDoubleStep {
                phase_len: k,
                stride_hi: j,
            });
            j /= 4;
        }
    }
    while j >= block {
        out.push(Launch::GlobalStep(Step { phase_len: k, stride: j }));
        j /= 2;
    }
    out.push(Launch::BlockFused {
        phase_lo: k,
        phase_hi: k,
        stride_max: block / 2,
        register_paired: paired,
    });
}

// ----------------------------------------------------------------------
// Launch interpreter — the native-CPU execution of one launch/pass.
// ----------------------------------------------------------------------

/// Execute one [`Launch`] over a full row, in exactly **one pass over the
/// row's memory** — the property the paper's two optimizations buy:
///
/// * [`Launch::GlobalStep`] — one branchless compare-exchange sweep
///   ([`crate::sort::bitonic::compare_exchange_step`]).
/// * [`Launch::GlobalDoubleStep`] — both strides in registers per quad,
///   one read+write of the row
///   ([`crate::sort::bitonic::compare_exchange_double_step`], the
///   paper §4.2).
/// * [`Launch::BlockFused`] — the row is cut into aligned tiles of
///   `2 * stride_max` keys and *all* fused steps run per tile while it is
///   cache-resident (the paper §4.1 shared-memory stage translated to L1
///   locality): one read+write of the row for the whole step group.
///
/// Bit-exactness with the serial step walk holds because every fused
/// stride is `< tile`, so tiles are independent across all fused steps
/// (pairs never cross a tile boundary) and per-tile execution order
/// equals the flat [`Launch::steps`] order on each tile.
pub fn run_launch<T: SortKey>(xs: &mut [T], launch: &Launch) {
    run_launch_counting(xs, launch);
}

/// [`run_launch`] under an explicit comparator ISA (see
/// [`crate::sort::simd`]): the pass structure is identical for every ISA
/// — only the inner compare-exchange sweeps change instruction selection
/// — so pass counting, disjointness proofs and launch algebra are all
/// ISA-independent.
pub fn run_launch_isa<T: SortKey>(xs: &mut [T], launch: &Launch, isa: KernelIsa) {
    run_launch_counting_isa(xs, launch, isa);
}

/// [`run_launch`], returning the number of row elements this launch
/// streamed from row-level ("global") memory: the whole row for a global
/// launch, and **one tile per outer tile iteration** for `BlockFused` —
/// the fused steps inside a tile re-touch only cache-resident data and
/// are deliberately not re-counted. This makes the pass-count
/// instrumentation real rather than derived from the static launch list:
/// a structural regression that, say, re-walks the row once per fused
/// step (tile loop inside the step loop) inflates the streamed count and
/// fails the `run_row_counting == global_passes` assertions in the
/// runtime tests and the ablation bench.
pub fn run_launch_counting<T: SortKey>(xs: &mut [T], launch: &Launch) -> usize {
    run_launch_counting_isa(xs, launch, KernelIsa::Scalar)
}

/// [`run_launch_counting`] under an explicit comparator ISA. The
/// streamed count is a property of the launch structure alone, so it is
/// identical for every ISA.
pub fn run_launch_counting_isa<T: SortKey>(
    xs: &mut [T],
    launch: &Launch,
    isa: KernelIsa,
) -> usize {
    let n = xs.len();
    match *launch {
        Launch::GlobalStep(s) => {
            simd::step_interleaved(isa, xs, s.phase_len, s.stride, 1, 0, n);
            n
        }
        Launch::GlobalDoubleStep {
            phase_len,
            stride_hi,
        } => {
            simd::double_step_interleaved(isa, xs, phase_len, stride_hi, 1, 0, n);
            n
        }
        Launch::BlockFused {
            phase_lo,
            phase_hi,
            stride_max,
            register_paired,
        } => {
            let tile = 2 * stride_max;
            debug_assert!(tile >= 2 && n % tile == 0, "tile {tile} must divide n {n}");
            let mut streamed = 0;
            let mut off = 0;
            while off < n {
                let end = off + tile;
                streamed += tile;
                let mut k = phase_lo;
                while k <= phase_hi {
                    run_fused_tail_range_isa(
                        xs,
                        k,
                        (k / 2).min(stride_max),
                        off,
                        end,
                        register_paired,
                        isa,
                    );
                    k *= 2;
                }
                off = end;
            }
            streamed
        }
    }
}

/// The shared fused-tile kernel: strides `stride_hi, stride_hi/2, …, 1`
/// of phase `phase_len`, restricted to the aligned tile `xs[lo..hi)`
/// (`lo` multiple of `2 * stride_hi`, tile length a multiple of it too).
/// With `paired`, consecutive strides run through the register-quad
/// kernel, mirroring what the Optimized variant's in-block stage does on
/// the GPU. Used by [`run_launch`] for `BlockFused` launches and by
/// [`crate::sort::bitonic_parallel`] for each worker's intra-row chunk —
/// one kernel, both paths.
pub fn run_fused_tail_range<T: SortKey>(
    xs: &mut [T],
    phase_len: usize,
    stride_hi: usize,
    lo: usize,
    hi: usize,
    paired: bool,
) {
    let mut j = stride_hi;
    if paired {
        // Pair strides (j, j/2) while both exist; 2*j <= phase_len always
        // holds (strides start at phase_len/2), so the quad kernel's
        // uniform-direction precondition is met.
        while j >= 2 {
            compare_exchange_double_step_range(xs, phase_len, j, lo, hi);
            j /= 4;
        }
    }
    while j >= 1 {
        compare_exchange_step_range(xs, phase_len, j, lo, hi);
        j /= 2;
    }
}

/// [`run_fused_tail_range`] under an explicit comparator ISA — same
/// stride pairing, sweeps routed through [`crate::sort::simd`].
pub fn run_fused_tail_range_isa<T: SortKey>(
    xs: &mut [T],
    phase_len: usize,
    stride_hi: usize,
    lo: usize,
    hi: usize,
    paired: bool,
    isa: KernelIsa,
) {
    let mut j = stride_hi;
    if paired {
        while j >= 2 {
            simd::double_step_interleaved(isa, xs, phase_len, j, 1, lo, hi);
            j /= 4;
        }
    }
    while j >= 1 {
        simd::step_interleaved(isa, xs, phase_len, j, 1, lo, hi);
        j /= 2;
    }
}

/// [`run_launch`] over a **lane-interleaved tile** of `lanes` rows —
/// the batch-interleaved execution mode: `xs.len() = n * lanes` holds
/// `lanes` independent rows element-major (`xs[e * lanes + l]`), and one
/// call executes the launch across every row at once through the
/// interleaved kernels in [`crate::sort::bitonic`]. The grouping into
/// passes is unchanged — only the inner sweeps widen by `lanes` — so the
/// per-row pass count is identical to the scalar interpreter:
///
/// * `GlobalStep` / `GlobalDoubleStep` — one pass over the whole
///   `n * lanes` tile, i.e. still one pass per row.
/// * `BlockFused` — the row is cut into the same aligned element tiles of
///   `2 * stride_max` keys; each becomes a `(lanes × tile)`-key cache
///   block that stays resident across all fused steps.
///
/// Bit-exactness with `lanes` independent scalar walks holds because the
/// compare-exchange partner and direction of every key depend only on its
/// element index, never on its lane — pinned by
/// `interleaved_launch_bit_exact_with_per_lane_scalar_walk`.
pub fn run_launch_interleaved<T: SortKey>(xs: &mut [T], launch: &Launch, lanes: usize) {
    run_launch_interleaved_isa(xs, launch, lanes, KernelIsa::Scalar);
}

/// [`run_launch_interleaved`] under an explicit comparator ISA — the
/// batch-interleaved sweeps are where the explicit vector kernels earn
/// their keep (long stride-1 spans of `j * lanes` keys per direction).
pub fn run_launch_interleaved_isa<T: SortKey>(
    xs: &mut [T],
    launch: &Launch,
    lanes: usize,
    isa: KernelIsa,
) {
    debug_assert!(lanes >= 1 && xs.len() % lanes == 0);
    let n = xs.len() / lanes;
    match *launch {
        Launch::GlobalStep(s) => {
            simd::step_interleaved(isa, xs, s.phase_len, s.stride, lanes, 0, n);
        }
        Launch::GlobalDoubleStep {
            phase_len,
            stride_hi,
        } => {
            simd::double_step_interleaved(isa, xs, phase_len, stride_hi, lanes, 0, n);
        }
        Launch::BlockFused {
            phase_lo,
            phase_hi,
            stride_max,
            register_paired,
        } => {
            let tile = 2 * stride_max;
            debug_assert!(tile >= 2 && n % tile == 0, "tile {tile} must divide n {n}");
            let mut off = 0;
            while off < n {
                let end = off + tile;
                let mut k = phase_lo;
                while k <= phase_hi {
                    run_fused_tail_range_interleaved_isa(
                        xs,
                        k,
                        (k / 2).min(stride_max),
                        off,
                        end,
                        register_paired,
                        lanes,
                        isa,
                    );
                    k *= 2;
                }
                off = end;
            }
        }
    }
}

/// [`run_fused_tail_range`] over a lane-interleaved tile: strides
/// `stride_hi, …, 1` of phase `phase_len` restricted to elements
/// `[lo, hi)` of every lane at once — same pairing structure, interleaved
/// kernels. `lo`/`hi` are element indices (the caller's alignment
/// contract is unchanged).
pub fn run_fused_tail_range_interleaved<T: SortKey>(
    xs: &mut [T],
    phase_len: usize,
    stride_hi: usize,
    lo: usize,
    hi: usize,
    paired: bool,
    lanes: usize,
) {
    run_fused_tail_range_interleaved_isa(
        xs,
        phase_len,
        stride_hi,
        lo,
        hi,
        paired,
        lanes,
        KernelIsa::Scalar,
    )
}

/// [`run_fused_tail_range_interleaved`] under an explicit comparator ISA.
#[allow(clippy::too_many_arguments)]
pub fn run_fused_tail_range_interleaved_isa<T: SortKey>(
    xs: &mut [T],
    phase_len: usize,
    stride_hi: usize,
    lo: usize,
    hi: usize,
    paired: bool,
    lanes: usize,
    isa: KernelIsa,
) {
    let mut j = stride_hi;
    if paired {
        while j >= 2 {
            simd::double_step_interleaved(isa, xs, phase_len, j, lanes, lo, hi);
            j /= 4;
        }
    }
    while j >= 1 {
        simd::step_interleaved(isa, xs, phase_len, j, lanes, lo, hi);
        j /= 2;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_closed_form_round_count() {
        // §3.2: sum_{i=1..log n} i = log n (log n + 1) / 2 rounds.
        for k in 1..=20 {
            let net = Network::new(1 << k);
            assert_eq!(net.steps().count(), k * (k + 1) / 2);
            assert_eq!(net.step_count(), k * (k + 1) / 2);
        }
    }

    #[test]
    fn paper_closed_form_compare_exchanges() {
        // §3.2: n·logn·(logn+1)/4 compare-exchange operations.
        for k in 1..=12 {
            let n = 1usize << k;
            let net = Network::new(n);
            let by_pairs: usize = net.steps().map(|s| net.step_pairs(s).len()).sum();
            assert_eq!(by_pairs, n * k * (k + 1) / 4);
            assert_eq!(net.compare_exchange_count(), by_pairs);
        }
    }

    #[test]
    fn figure2_network_n8() {
        // The paper's Figure 2: n=8 → 3 phases, phase p has p steps,
        // every step has n/2 = 4 compare/exchange operations.
        let net = Network::new(8);
        let phases: Vec<_> = net.phases().collect();
        assert_eq!(phases.len(), 3);
        for (idx, ph) in phases.iter().enumerate() {
            assert_eq!(ph.len, 2 << idx);
            assert_eq!(ph.steps().count(), idx + 1);
            for s in ph.steps() {
                assert_eq!(net.step_pairs(s).len(), 4);
            }
        }
    }

    #[test]
    fn step_schedule_matches_iterator() {
        let net = Network::new(1 << 10);
        let owned = net.step_schedule();
        let iterated: Vec<Step> = net.steps().collect();
        assert_eq!(owned, iterated);
        assert_eq!(owned.len(), net.step_count());
    }

    #[test]
    fn strides_descend_within_phase() {
        let net = Network::new(64);
        for ph in net.phases() {
            let strides: Vec<_> = ph.steps().map(|s| s.stride).collect();
            for w in strides.windows(2) {
                assert_eq!(w[0], w[1] * 2);
            }
            assert_eq!(*strides.first().unwrap(), ph.len / 2);
            assert_eq!(*strides.last().unwrap(), 1);
        }
    }

    #[test]
    fn basic_launch_count_is_step_count() {
        for k in 1..=16 {
            let net = Network::new(1 << k);
            assert_eq!(net.launches(Variant::Basic, 1 << 10).len(), net.step_count());
        }
    }

    #[test]
    fn semi_launch_count_closed_form() {
        // Presort (1) + per phase k = 2B..n: log2(k/B) global steps + 1 fused.
        let n = 1 << 16;
        let b = 1 << 8;
        let net = Network::new(n);
        let launches = net.launches(Variant::Semi, b);
        let kb = (n / b).trailing_zeros() as usize; // number of post-presort phases
        let expected = 1 + (1..=kb).map(|i| i + 1).sum::<usize>();
        assert_eq!(launches.len(), expected);
        assert!(launches.len() < net.launches(Variant::Basic, b).len());
    }

    #[test]
    fn optimized_fewer_launches_than_semi() {
        for (n, b) in [(1 << 12, 1 << 6), (1 << 18, 1 << 8), (1 << 20, 1 << 10)] {
            let net = Network::new(n);
            let semi = net.launches(Variant::Semi, b).len();
            let opt = net.launches(Variant::Optimized, b).len();
            let basic = net.launches(Variant::Basic, b).len();
            assert!(opt < semi, "opt {opt} !< semi {semi} at n={n}");
            assert!(semi < basic, "semi {semi} !< basic {basic} at n={n}");
        }
    }

    #[test]
    fn launch_expansion_reproduces_step_schedule_exactly() {
        // The fusion algebra the runtime relies on: expanding each launch
        // back to steps reproduces the flat schedule EXACTLY — same
        // steps, same order, for every n up to 4096, every variant, and
        // a spread of block sizes (smaller, equal, larger than n).
        for logn in 1..=12usize {
            let n = 1 << logn;
            let net = Network::new(n);
            let want = net.step_schedule();
            for variant in Variant::ALL {
                for block in [2usize, 4, 16, 64, 256, 1024, 4096, 1 << 14] {
                    let got: Vec<Step> = net
                        .launches(variant, block)
                        .iter()
                        .flat_map(Launch::steps)
                        .collect();
                    assert_eq!(got, want, "{variant:?} n={n} block={block}");
                }
            }
        }
    }

    #[test]
    fn merge_launch_expansion_is_exactly_the_final_phase() {
        for logn in 1..=12usize {
            let n = 1 << logn;
            let net = Network::new(n);
            let want: Vec<Step> = Phase { len: n }.steps().collect();
            for variant in Variant::ALL {
                for block in [2usize, 16, 256, 4096] {
                    let got: Vec<Step> = net
                        .merge_launches(variant, block)
                        .iter()
                        .flat_map(Launch::steps)
                        .collect();
                    assert_eq!(got, want, "{variant:?} n={n} block={block}");
                    assert_eq!(
                        got.len(),
                        logn,
                        "merge must cost log2(n) steps, not the full network"
                    );
                }
            }
        }
    }

    #[test]
    fn run_launch_bit_exact_with_serial_step_walk() {
        // Execute each launch program twice: fused through the
        // interpreter vs its own step expansion through the plain sweep.
        // Every intermediate state (after each launch) must agree
        // bit-for-bit, and the result must be sorted.
        use crate::sort::bitonic::compare_exchange_step;
        use crate::workload::{Distribution, Generator};
        let mut gen = Generator::new(0xF0);
        for (n, blocks) in [(64usize, vec![4usize, 16, 64]), (1024, vec![4, 64, 256, 4096])] {
            let net = Network::new(n);
            for variant in Variant::ALL {
                for &block in &blocks {
                    let data = gen.u32s(n, Distribution::DupHeavy);
                    let mut fused = data.clone();
                    let mut serial = data;
                    for l in net.launches(variant, block) {
                        run_launch(&mut fused, &l);
                        for s in l.steps() {
                            compare_exchange_step(&mut serial, s.phase_len, s.stride);
                        }
                        assert_eq!(fused, serial, "{variant:?} n={n} block={block} {l:?}");
                    }
                    assert!(fused.windows(2).all(|w| w[0] <= w[1]));
                }
            }
        }
    }

    #[test]
    fn interleaved_launch_bit_exact_with_per_lane_scalar_walk() {
        // The batch-interleaved interpreter must agree bit-for-bit with
        // running the scalar interpreter on each lane's row independently,
        // after every launch of every program — including lanes = 1 and
        // non-power-of-two lane counts.
        use crate::workload::{Distribution, Generator};
        let mut gen = Generator::new(0x1A7E);
        let n = 512;
        let net = Network::new(n);
        for variant in Variant::ALL {
            for block in [16usize, 64, 1024] {
                for lanes in [1usize, 3, 4, 16] {
                    let rows: Vec<Vec<u32>> =
                        (0..lanes).map(|_| gen.u32s(n, Distribution::DupHeavy)).collect();
                    let mut tile = vec![0u32; lanes * n];
                    for (l, row) in rows.iter().enumerate() {
                        for (e, &x) in row.iter().enumerate() {
                            tile[e * lanes + l] = x;
                        }
                    }
                    let mut scalar = rows;
                    for launch in net.launches(variant, block) {
                        run_launch_interleaved(&mut tile, &launch, lanes);
                        for row in scalar.iter_mut() {
                            run_launch(row, &launch);
                        }
                        for (l, row) in scalar.iter().enumerate() {
                            let got: Vec<u32> = (0..n).map(|e| tile[e * lanes + l]).collect();
                            assert_eq!(
                                &got, row,
                                "{variant:?} block={block} lanes={lanes} lane={l} {launch:?}"
                            );
                        }
                    }
                    for (l, row) in scalar.iter().enumerate() {
                        assert!(row.windows(2).all(|w| w[0] <= w[1]), "lane {l} unsorted");
                    }
                }
            }
        }
    }

    #[test]
    fn isa_interpreters_bit_exact_with_scalar_launch_walk() {
        // Every available comparator ISA must produce bit-identical
        // state after every launch of every program, scalar rows and
        // interleaved tiles alike — the interpreter-level half of the
        // SIMD bit-exactness contract (the kernel-level half lives in
        // sort::simd, the plan/executor halves in tests/simd_props.rs).
        use crate::workload::{Distribution, Generator};
        let mut gen = Generator::new(0x15A);
        let n = 256;
        let net = Network::new(n);
        for isa in KernelIsa::available_isas() {
            for variant in Variant::ALL {
                for lanes in [1usize, 5, 8] {
                    let data = gen.u32s(lanes * n, Distribution::DupHeavy);
                    let mut tile = data.clone();
                    let mut want = data;
                    for launch in net.launches(variant, 64) {
                        if lanes == 1 {
                            let streamed = run_launch_counting_isa(&mut tile, &launch, isa);
                            assert_eq!(streamed, run_launch_counting(&mut want, &launch));
                        } else {
                            run_launch_interleaved_isa(&mut tile, &launch, lanes, isa);
                            run_launch_interleaved(&mut want, &launch, lanes);
                        }
                        assert_eq!(
                            tile,
                            want,
                            "{} {variant:?} lanes={lanes} {launch:?}",
                            isa.name()
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn launch_step_count_matches_enumeration() {
        for variant in Variant::ALL {
            let net = Network::new(1 << 14);
            let total: usize = net
                .launches(variant, 1 << 7)
                .iter()
                .map(Launch::step_count)
                .sum();
            assert_eq!(total, net.step_count(), "{variant:?}");
        }
    }

    #[test]
    fn step_pairs_partition_indices() {
        let net = Network::new(32);
        for s in net.steps() {
            let pairs = net.step_pairs(s);
            assert_eq!(pairs.len(), 16);
            let mut seen = vec![false; 32];
            for (a, b, _) in pairs {
                assert_eq!(a ^ b, s.stride);
                assert!(!seen[a] && !seen[b]);
                seen[a] = true;
                seen[b] = true;
            }
            assert!(seen.iter().all(|&x| x));
        }
    }

    #[test]
    fn small_block_degenerates_gracefully() {
        // block >= n: semi/optimized collapse to a single fused launch.
        let net = Network::new(64);
        let launches = net.launches(Variant::Semi, 1 << 10);
        assert_eq!(launches.len(), 1);
        assert_eq!(launches[0].step_count(), net.step_count());
    }

    #[test]
    #[should_panic]
    fn non_power_of_two_rejected() {
        Network::new(48);
    }
}
