//! Sequential CPU bitonic sort — the paper's second CPU baseline
//! (Table 1, "BitonicSort" column, the one that is ~5× slower than
//! quicksort because of its `O(n log² n)` complexity).
//!
//! The implementation iterates the exact [`Network`] schedule, so the CPU
//! baseline, the simulator, and the Pallas kernels all execute the same
//! abstract network.

use super::network::Network;
use super::SortKey;

/// Sort `xs` ascending in place. `xs.len()` must be a power of two (or 0/1);
/// use [`bitonic_sort_padded`] for arbitrary lengths.
pub fn bitonic_sort<T: SortKey>(xs: &mut [T]) {
    let n = xs.len();
    if n < 2 {
        return;
    }
    assert!(
        n.is_power_of_two(),
        "bitonic_sort requires a power-of-two length, got {n}; use bitonic_sort_padded"
    );
    for step in Network::new(n).steps() {
        compare_exchange_step(xs, step.phase_len, step.stride);
    }
}

/// One full compare-exchange step with stride `j`, direction from bit `k`.
///
/// This loop is the CPU analog of the paper §3.3 kernel: for each `i`,
/// partner `ixj = i ^ j`; ascending iff `i & k == 0`.
#[inline]
pub fn compare_exchange_step<T: SortKey>(xs: &mut [T], k: usize, j: usize) {
    let n = xs.len();
    compare_exchange_step_range(xs, k, j, 0, n);
}

/// [`compare_exchange_step`] restricted to `xs[lo..hi)`: only pairs whose
/// indices both lie in the range are touched. `lo` must be aligned to
/// `2j` and `hi - lo` a multiple of `2j` (powers of two throughout), so
/// every pair `(a, a ^ j)` with `a` in range has its partner in range —
/// the contract the fused-tile interpreter and the chunked parallel sort
/// rely on. Direction still comes from the *global* index (`i & k`).
#[inline]
pub fn compare_exchange_step_range<T: SortKey>(
    xs: &mut [T],
    k: usize,
    j: usize,
    lo: usize,
    hi: usize,
) {
    debug_assert!(j >= 1 && lo % (2 * j) == 0 && (hi - lo) % (2 * j) == 0 && hi <= xs.len());
    // Iterate i over the "lower partner" indices only: groups of j
    // consecutive lows alternate with j highs, so skip j after every j.
    let mut i = lo;
    while i < hi {
        // Whole run [i, i+j) shares the same direction when 2j <= k
        // (always true within a phase), so hoist the branch out of the
        // inner loop; the loop body itself is branchless min/max.
        if i & k == 0 {
            for a in i..i + j {
                let b = a ^ j;
                let (x, y) = (xs[a], xs[b]);
                xs[a] = T::key_min(x, y);
                xs[b] = T::key_max(x, y);
            }
        } else {
            for a in i..i + j {
                let b = a ^ j;
                let (x, y) = (xs[a], xs[b]);
                xs[a] = T::key_max(x, y);
                xs[b] = T::key_min(x, y);
            }
        }
        i += 2 * j;
    }
}

/// Two consecutive compare-exchange steps (strides `j_hi`, `j_hi/2`) of
/// phase `k` in **one pass over memory** — the CPU analogue of the
/// paper's §4.2 register pairing: each iteration loads the quad
/// `{a, a+j_lo, a+j_hi, a+j_hi+j_lo}` into locals, performs all four
/// compare-exchanges of both strides in registers, and stores once.
///
/// Exactness: the quad is closed under `^j_hi` and `^j_lo`, so applying
/// both whole-array steps restricted to each quad is bit-identical to the
/// two serial sweeps. All four pair directions agree because `2*j_hi <= k`
/// keeps bit `k` constant across the aligned run `[i, i + 2*j_hi)`.
#[inline]
pub fn compare_exchange_double_step<T: SortKey>(xs: &mut [T], k: usize, j_hi: usize) {
    let n = xs.len();
    compare_exchange_double_step_range(xs, k, j_hi, 0, n);
}

/// [`compare_exchange_double_step`] restricted to `xs[lo..hi)`, same
/// alignment contract as [`compare_exchange_step_range`] (with `2*j_hi`
/// in place of `2j`).
#[inline]
pub fn compare_exchange_double_step_range<T: SortKey>(
    xs: &mut [T],
    k: usize,
    j_hi: usize,
    lo: usize,
    hi: usize,
) {
    debug_assert!(j_hi >= 2 && 2 * j_hi <= k, "double step needs j_hi >= 2 and 2*j_hi <= k");
    debug_assert!(lo % (2 * j_hi) == 0 && (hi - lo) % (2 * j_hi) == 0 && hi <= xs.len());
    let j_lo = j_hi / 2;
    let mut i = lo;
    while i < hi {
        let ascending = i & k == 0;
        for a in i..i + j_lo {
            let (b, c) = (a + j_lo, a + j_hi);
            let d = c + j_lo;
            let (mut va, mut vb, mut vc, mut vd) = (xs[a], xs[b], xs[c], xs[d]);
            if ascending {
                cx_asc(&mut va, &mut vc); // stride j_hi: (a, c)
                cx_asc(&mut vb, &mut vd); //              (b, d)
                cx_asc(&mut va, &mut vb); // stride j_lo: (a, b)
                cx_asc(&mut vc, &mut vd); //              (c, d)
            } else {
                cx_desc(&mut va, &mut vc);
                cx_desc(&mut vb, &mut vd);
                cx_desc(&mut va, &mut vb);
                cx_desc(&mut vc, &mut vd);
            }
            xs[a] = va;
            xs[b] = vb;
            xs[c] = vc;
            xs[d] = vd;
        }
        i += 2 * j_hi;
    }
}

/// [`compare_exchange_step_range`] over a **lane-interleaved tile** — the
/// batch-interleaved (SIMT-style) kernel: `xs` holds `lanes` independent
/// rows in element-major order (`xs[e * lanes + l]` is element `e` of row
/// `l`), and one call runs the step on every row at once. `lo`/`hi` are
/// *element* indices with the same `2j`-alignment contract as the scalar
/// range kernel; `hi * lanes <= xs.len()`.
///
/// Why this layout: within an aligned run `[i, i + 2j)` the low partners
/// `[i, i + j)` are contiguous, and (since `a & j == 0` there) each
/// partner is `a + j`, so in element-major order the run is two adjacent
/// blocks of `j * lanes` keys compared pointwise — one long, branchless,
/// stride-1 min/max sweep the compiler can keep vector-width-saturated.
/// This is the CPU translation of "one warp lane per row": the direction
/// bit depends only on the element index, so all lanes agree, exactly
/// like the paper's threads executing one compare-exchange in lockstep.
/// At `lanes == 1` the kernel degenerates to the scalar sweep bit-for-bit.
#[inline]
pub fn compare_exchange_step_interleaved<T: SortKey>(
    xs: &mut [T],
    k: usize,
    j: usize,
    lanes: usize,
    lo: usize,
    hi: usize,
) {
    debug_assert!(lanes >= 1 && j >= 1);
    debug_assert!(lo % (2 * j) == 0 && (hi - lo) % (2 * j) == 0 && hi * lanes <= xs.len());
    let w = j * lanes;
    let mut i = lo;
    while i < hi {
        let base = i * lanes;
        let (lows, highs) = xs[base..base + 2 * w].split_at_mut(w);
        if i & k == 0 {
            for (x, y) in lows.iter_mut().zip(highs.iter_mut()) {
                let (a, b) = (*x, *y);
                *x = T::key_min(a, b);
                *y = T::key_max(a, b);
            }
        } else {
            for (x, y) in lows.iter_mut().zip(highs.iter_mut()) {
                let (a, b) = (*x, *y);
                *x = T::key_max(a, b);
                *y = T::key_min(a, b);
            }
        }
        i += 2 * j;
    }
}

/// [`compare_exchange_double_step_range`] over a lane-interleaved tile:
/// both strides of the pair `(j_hi, j_hi/2)` across all `lanes` rows in
/// one pass. The aligned run `[i, i + 2*j_hi)` is four adjacent blocks of
/// `j_lo * lanes` keys (`A B C D`), and the scalar register quad
/// `{a, a+j_lo, a+j_hi, a+j_hi+j_lo}` is `(A[t], B[t], C[t], D[t])`
/// pointwise — so the whole run is one branchless four-stream sweep.
/// Same preconditions as the scalar kernel (`j_hi >= 2`, `2*j_hi <= k`,
/// `2*j_hi`-aligned range), plus `hi * lanes <= xs.len()`.
#[inline]
pub fn compare_exchange_double_step_interleaved<T: SortKey>(
    xs: &mut [T],
    k: usize,
    j_hi: usize,
    lanes: usize,
    lo: usize,
    hi: usize,
) {
    debug_assert!(j_hi >= 2 && 2 * j_hi <= k, "double step needs j_hi >= 2 and 2*j_hi <= k");
    debug_assert!(lanes >= 1);
    debug_assert!(lo % (2 * j_hi) == 0 && (hi - lo) % (2 * j_hi) == 0 && hi * lanes <= xs.len());
    let j_lo = j_hi / 2;
    let w = j_lo * lanes;
    let mut i = lo;
    while i < hi {
        let base = i * lanes;
        let (ab, cd) = xs[base..base + 4 * w].split_at_mut(2 * w);
        let (blk_a, blk_b) = ab.split_at_mut(w);
        let (blk_c, blk_d) = cd.split_at_mut(w);
        if i & k == 0 {
            for t in 0..w {
                let (mut va, mut vb, mut vc, mut vd) = (blk_a[t], blk_b[t], blk_c[t], blk_d[t]);
                cx_asc(&mut va, &mut vc); // stride j_hi: (a, c)
                cx_asc(&mut vb, &mut vd); //              (b, d)
                cx_asc(&mut va, &mut vb); // stride j_lo: (a, b)
                cx_asc(&mut vc, &mut vd); //              (c, d)
                blk_a[t] = va;
                blk_b[t] = vb;
                blk_c[t] = vc;
                blk_d[t] = vd;
            }
        } else {
            for t in 0..w {
                let (mut va, mut vb, mut vc, mut vd) = (blk_a[t], blk_b[t], blk_c[t], blk_d[t]);
                cx_desc(&mut va, &mut vc);
                cx_desc(&mut vb, &mut vd);
                cx_desc(&mut va, &mut vb);
                cx_desc(&mut vc, &mut vd);
                blk_a[t] = va;
                blk_b[t] = vb;
                blk_c[t] = vc;
                blk_d[t] = vd;
            }
        }
        i += 2 * j_hi;
    }
}

/// Branchless in-register compare-exchange, ascending (low gets min).
#[inline]
fn cx_asc<T: SortKey>(lo: &mut T, hi: &mut T) {
    let (a, b) = (*lo, *hi);
    *lo = T::key_min(a, b);
    *hi = T::key_max(a, b);
}

/// Branchless in-register compare-exchange, descending (low gets max).
#[inline]
fn cx_desc<T: SortKey>(lo: &mut T, hi: &mut T) {
    let (a, b) = (*lo, *hi);
    *lo = T::key_max(a, b);
    *hi = T::key_min(a, b);
}

/// Sort any-length input by padding to the next power of two with
/// `T::MAX_KEY`, sorting, and truncating. This is exactly what the L3
/// coordinator's size-class router does before dispatching to the GPU
/// artifacts, so the CPU path and the accelerator path agree bit-for-bit.
pub fn bitonic_sort_padded<T: SortKey>(xs: &mut Vec<T>) {
    let n = xs.len();
    if n < 2 {
        return;
    }
    let padded = n.next_power_of_two();
    xs.resize(padded, T::MAX_KEY);
    bitonic_sort(xs);
    xs.truncate(n);
}

/// Sort descending (paper Fig. 2 alternates directions internally; a
/// descending final order is the mirrored network).
pub fn bitonic_sort_desc<T: SortKey>(xs: &mut [T]) {
    bitonic_sort(xs);
    xs.reverse();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sort::verify::{is_sorted, is_sorted_desc, same_multiset};
    use crate::workload::{Distribution, Generator};

    #[test]
    fn sorts_all_pow2_sizes() {
        let mut gen = Generator::new(0xB17);
        for logn in 1..=14 {
            let orig = gen.u32s(1 << logn, Distribution::Uniform);
            let mut v = orig.clone();
            bitonic_sort(&mut v);
            assert!(is_sorted(&v), "n=2^{logn}");
            assert!(same_multiset(&orig, &v));
        }
    }

    #[test]
    fn sorts_all_distributions() {
        let mut gen = Generator::new(0x50F7);
        for d in Distribution::ALL {
            let orig = gen.u32s(1 << 10, d);
            let mut v = orig.clone();
            bitonic_sort(&mut v);
            assert!(is_sorted(&v), "{}", d.name());
            assert!(same_multiset(&orig, &v));
        }
    }

    #[test]
    fn exhaustive_tiny_permutations() {
        // All permutations of 8 distinct keys (the paper's Fig. 2 size) —
        // the 0-1 principle plus this gives very high confidence.
        let mut perm = [0u32, 1, 2, 3, 4, 5, 6, 7];
        let mut count = 0;
        permute(&mut perm, 0, &mut |p| {
            let mut v = p.to_vec();
            bitonic_sort(&mut v);
            assert_eq!(v, vec![0, 1, 2, 3, 4, 5, 6, 7]);
            count += 1;
        });
        assert_eq!(count, 40320);

        fn permute(xs: &mut [u32], k: usize, f: &mut impl FnMut(&[u32])) {
            if k == xs.len() {
                f(xs);
                return;
            }
            for i in k..xs.len() {
                xs.swap(k, i);
                permute(xs, k + 1, f);
                xs.swap(k, i);
            }
        }
    }

    #[test]
    fn zero_one_principle_n16() {
        // Knuth's 0-1 principle: a comparison network sorts all inputs iff
        // it sorts all 0-1 inputs. Exhaust all 2^16 binary inputs at n=16.
        for bits in 0u32..(1 << 16) {
            let mut v: Vec<u32> = (0..16).map(|i| (bits >> i) & 1).collect();
            bitonic_sort(&mut v);
            assert!(is_sorted(&v), "bits={bits:#x}");
        }
    }

    #[test]
    fn padded_handles_arbitrary_lengths() {
        let mut gen = Generator::new(2);
        for n in [0usize, 1, 3, 5, 100, 1000, 1023, 1025] {
            let orig = gen.u32s(n, Distribution::Uniform);
            let mut v = orig.clone();
            bitonic_sort_padded(&mut v);
            assert_eq!(v.len(), n);
            assert!(is_sorted(&v), "n={n}");
            assert!(same_multiset(&orig, &v));
        }
    }

    #[test]
    fn descending_order() {
        let mut gen = Generator::new(3);
        let mut v = gen.u32s(256, Distribution::Uniform);
        bitonic_sort_desc(&mut v);
        assert!(is_sorted_desc(&v));
    }

    #[test]
    fn double_step_bit_exact_with_two_single_steps() {
        // Walk the full network twice: once pairing consecutive strides
        // through the register-quad kernel, once as two serial sweeps.
        // Every intermediate state must agree bit-for-bit.
        let mut gen = Generator::new(0xD0B1E);
        for logn in [3usize, 4, 8, 10] {
            let n = 1 << logn;
            let data = gen.u32s(n, Distribution::DupHeavy);
            let mut paired = data.clone();
            let mut serial = data;
            for ph in Network::new(n).phases() {
                let k = ph.len;
                let mut j = k / 2;
                while j >= 1 {
                    if j >= 2 {
                        compare_exchange_double_step(&mut paired, k, j);
                        compare_exchange_step(&mut serial, k, j);
                        compare_exchange_step(&mut serial, k, j / 2);
                        j /= 4;
                    } else {
                        compare_exchange_step(&mut paired, k, j);
                        compare_exchange_step(&mut serial, k, j);
                        j = 0;
                    }
                    assert_eq!(paired, serial, "n=2^{logn} k={k}");
                }
            }
            assert!(is_sorted(&paired), "n=2^{logn}");
        }
    }

    #[test]
    fn step_range_matches_full_step_on_aligned_tiles() {
        // Running a small-stride step tile-by-tile must equal the full
        // sweep: pairs never cross an aligned tile boundary when j < tile.
        let mut gen = Generator::new(0x7A11);
        let n = 1 << 10;
        let k = 1 << 10;
        for tile in [16usize, 64, 256] {
            for j in [1usize, 2, tile / 2] {
                let data = gen.u32s(n, Distribution::Uniform);
                let mut whole = data.clone();
                let mut tiled = data;
                compare_exchange_step(&mut whole, k, j);
                let mut off = 0;
                while off < n {
                    compare_exchange_step_range(&mut tiled, k, j, off, off + tile);
                    off += tile;
                }
                assert_eq!(whole, tiled, "tile={tile} j={j}");
            }
        }
    }

    /// Element-major interleave of `lanes` equal-length rows.
    fn interleave(rows: &[Vec<u32>]) -> Vec<u32> {
        let lanes = rows.len();
        let n = rows[0].len();
        let mut out = vec![0u32; lanes * n];
        for (l, row) in rows.iter().enumerate() {
            for (e, &x) in row.iter().enumerate() {
                out[e * lanes + l] = x;
            }
        }
        out
    }

    #[test]
    fn interleaved_step_bit_exact_with_per_lane_scalar_sweep() {
        // Running one interleaved step over an R-lane tile must equal
        // running the scalar step on each lane's row independently —
        // including lanes = 1 (degenerate) and non-power-of-two lane
        // counts, full rows and aligned sub-ranges.
        let mut gen = Generator::new(0x1A7E5);
        let n = 256;
        for lanes in [1usize, 2, 3, 5, 8, 16] {
            for ph in Network::new(n).phases() {
                let k = ph.len;
                for step in ph.steps() {
                    let j = step.stride;
                    for (lo, hi) in [(0, n), (0, n / 2), (n / 2, n)] {
                        if lo % (2 * j) != 0 || (hi - lo) % (2 * j) != 0 {
                            continue;
                        }
                        let rows: Vec<Vec<u32>> =
                            (0..lanes).map(|_| gen.u32s(n, Distribution::DupHeavy)).collect();
                        let mut tile = interleave(&rows);
                        compare_exchange_step_interleaved(&mut tile, k, j, lanes, lo, hi);
                        let mut want = rows;
                        for row in want.iter_mut() {
                            compare_exchange_step_range(row, k, j, lo, hi);
                        }
                        assert_eq!(tile, interleave(&want), "lanes={lanes} k={k} j={j} [{lo},{hi})");
                    }
                }
            }
        }
    }

    #[test]
    fn interleaved_double_step_bit_exact_with_per_lane_scalar_quads() {
        let mut gen = Generator::new(0x2B0B);
        let n = 256;
        for lanes in [1usize, 3, 4, 16] {
            for ph in Network::new(n).phases() {
                let k = ph.len;
                let mut j = k / 2;
                while j >= 2 {
                    let rows: Vec<Vec<u32>> =
                        (0..lanes).map(|_| gen.u32s(n, Distribution::DupHeavy)).collect();
                    let mut tile = interleave(&rows);
                    compare_exchange_double_step_interleaved(&mut tile, k, j, lanes, 0, n);
                    let mut want = rows;
                    for row in want.iter_mut() {
                        compare_exchange_double_step(row, k, j);
                    }
                    assert_eq!(tile, interleave(&want), "lanes={lanes} k={k} j_hi={j}");
                    j /= 2;
                }
            }
        }
    }

    #[test]
    fn interleaved_full_network_walk_sorts_every_lane() {
        // Walk the whole network through the interleaved kernels only:
        // every lane must come out sorted, independent of the others.
        let mut gen = Generator::new(0x3C4D);
        let n = 512;
        for lanes in [2usize, 7] {
            let rows: Vec<Vec<u32>> =
                (0..lanes).map(|_| gen.u32s(n, Distribution::Uniform)).collect();
            let mut tile = interleave(&rows);
            for step in Network::new(n).steps() {
                compare_exchange_step_interleaved(&mut tile, step.phase_len, step.stride, lanes, 0, n);
            }
            for (l, row) in rows.iter().enumerate() {
                let got: Vec<u32> = (0..n).map(|e| tile[e * lanes + l]).collect();
                let mut want = row.clone();
                want.sort_unstable();
                assert_eq!(got, want, "lane {l} of {lanes}");
            }
        }
    }

    #[test]
    fn floats_sort_with_total_order() {
        let mut v = vec![0.5f32, -2.0, f32::NAN, 1.5, -0.0, 0.0, f32::INFINITY, -3.25];
        bitonic_sort(&mut v);
        assert_eq!(v[0], -3.25);
        assert!(v[7].is_nan());
        assert!(is_sorted(&v[..7]));
    }

    #[test]
    #[should_panic]
    fn rejects_non_power_of_two() {
        bitonic_sort(&mut [3u32, 1, 2]);
    }
}
