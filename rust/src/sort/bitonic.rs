//! Sequential CPU bitonic sort — the paper's second CPU baseline
//! (Table 1, "BitonicSort" column, the one that is ~5× slower than
//! quicksort because of its `O(n log² n)` complexity).
//!
//! The implementation iterates the exact [`Network`] schedule, so the CPU
//! baseline, the simulator, and the Pallas kernels all execute the same
//! abstract network.

use super::network::Network;
use super::SortKey;

/// Sort `xs` ascending in place. `xs.len()` must be a power of two (or 0/1);
/// use [`bitonic_sort_padded`] for arbitrary lengths.
pub fn bitonic_sort<T: SortKey>(xs: &mut [T]) {
    let n = xs.len();
    if n < 2 {
        return;
    }
    assert!(
        n.is_power_of_two(),
        "bitonic_sort requires a power-of-two length, got {n}; use bitonic_sort_padded"
    );
    for step in Network::new(n).steps() {
        compare_exchange_step(xs, step.phase_len, step.stride);
    }
}

/// One full compare-exchange step with stride `j`, direction from bit `k`.
///
/// This loop is the CPU analog of the paper §3.3 kernel: for each `i`,
/// partner `ixj = i ^ j`; ascending iff `i & k == 0`.
#[inline]
pub fn compare_exchange_step<T: SortKey>(xs: &mut [T], k: usize, j: usize) {
    let n = xs.len();
    let mut i = 0;
    // Iterate i over the "lower partner" indices only: groups of j
    // consecutive lows alternate with j highs, so skip j after every j.
    while i < n {
        let ascending = i & k == 0;
        // Whole run [i, i+j) shares the same direction when 2j <= k
        // (always true within a phase), so hoist the branch.
        for a in i..i + j {
            let b = a ^ j;
            let (lo, hi) = (xs[a], xs[b]);
            let swap = if ascending {
                hi.total_lt(&lo)
            } else {
                lo.total_lt(&hi)
            };
            if swap {
                xs.swap(a, b);
            }
        }
        i += 2 * j;
    }
}

/// Sort any-length input by padding to the next power of two with
/// `T::MAX_KEY`, sorting, and truncating. This is exactly what the L3
/// coordinator's size-class router does before dispatching to the GPU
/// artifacts, so the CPU path and the accelerator path agree bit-for-bit.
pub fn bitonic_sort_padded<T: SortKey>(xs: &mut Vec<T>) {
    let n = xs.len();
    if n < 2 {
        return;
    }
    let padded = n.next_power_of_two();
    xs.resize(padded, T::MAX_KEY);
    bitonic_sort(xs);
    xs.truncate(n);
}

/// Sort descending (paper Fig. 2 alternates directions internally; a
/// descending final order is the mirrored network).
pub fn bitonic_sort_desc<T: SortKey>(xs: &mut [T]) {
    bitonic_sort(xs);
    xs.reverse();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sort::verify::{is_sorted, is_sorted_desc, same_multiset};
    use crate::workload::{Distribution, Generator};

    #[test]
    fn sorts_all_pow2_sizes() {
        let mut gen = Generator::new(0xB17);
        for logn in 1..=14 {
            let orig = gen.u32s(1 << logn, Distribution::Uniform);
            let mut v = orig.clone();
            bitonic_sort(&mut v);
            assert!(is_sorted(&v), "n=2^{logn}");
            assert!(same_multiset(&orig, &v));
        }
    }

    #[test]
    fn sorts_all_distributions() {
        let mut gen = Generator::new(0x50F7);
        for d in Distribution::ALL {
            let orig = gen.u32s(1 << 10, d);
            let mut v = orig.clone();
            bitonic_sort(&mut v);
            assert!(is_sorted(&v), "{}", d.name());
            assert!(same_multiset(&orig, &v));
        }
    }

    #[test]
    fn exhaustive_tiny_permutations() {
        // All permutations of 8 distinct keys (the paper's Fig. 2 size) —
        // the 0-1 principle plus this gives very high confidence.
        let mut perm = [0u32, 1, 2, 3, 4, 5, 6, 7];
        let mut count = 0;
        permute(&mut perm, 0, &mut |p| {
            let mut v = p.to_vec();
            bitonic_sort(&mut v);
            assert_eq!(v, vec![0, 1, 2, 3, 4, 5, 6, 7]);
            count += 1;
        });
        assert_eq!(count, 40320);

        fn permute(xs: &mut [u32], k: usize, f: &mut impl FnMut(&[u32])) {
            if k == xs.len() {
                f(xs);
                return;
            }
            for i in k..xs.len() {
                xs.swap(k, i);
                permute(xs, k + 1, f);
                xs.swap(k, i);
            }
        }
    }

    #[test]
    fn zero_one_principle_n16() {
        // Knuth's 0-1 principle: a comparison network sorts all inputs iff
        // it sorts all 0-1 inputs. Exhaust all 2^16 binary inputs at n=16.
        for bits in 0u32..(1 << 16) {
            let mut v: Vec<u32> = (0..16).map(|i| (bits >> i) & 1).collect();
            bitonic_sort(&mut v);
            assert!(is_sorted(&v), "bits={bits:#x}");
        }
    }

    #[test]
    fn padded_handles_arbitrary_lengths() {
        let mut gen = Generator::new(2);
        for n in [0usize, 1, 3, 5, 100, 1000, 1023, 1025] {
            let orig = gen.u32s(n, Distribution::Uniform);
            let mut v = orig.clone();
            bitonic_sort_padded(&mut v);
            assert_eq!(v.len(), n);
            assert!(is_sorted(&v), "n={n}");
            assert!(same_multiset(&orig, &v));
        }
    }

    #[test]
    fn descending_order() {
        let mut gen = Generator::new(3);
        let mut v = gen.u32s(256, Distribution::Uniform);
        bitonic_sort_desc(&mut v);
        assert!(is_sorted_desc(&v));
    }

    #[test]
    fn floats_sort_with_total_order() {
        let mut v = vec![0.5f32, -2.0, f32::NAN, 1.5, -0.0, 0.0, f32::INFINITY, -3.25];
        bitonic_sort(&mut v);
        assert_eq!(v[0], -3.25);
        assert!(v[7].is_nan());
        assert!(is_sorted(&v[..7]));
    }

    #[test]
    #[should_panic]
    fn rejects_non_power_of_two() {
        bitonic_sort(&mut [3u32, 1, 2]);
    }
}
