//! Pass 3: the artifact auditor — lint `manifest.tsv` and the HLO texts
//! for drift before the registry ever compiles a plan from them.
//!
//! The manifest is the contract between the Python AOT compiler and the
//! native executor; nothing else cross-checks it. The auditor verifies,
//! per entry: the shape is executable (power-of-two `n`, positive batch,
//! power-of-two block), the referenced HLO file exists, and the HLO text
//! actually declares a module with the entry's dtype/shape token (so a
//! regenerated artifact whose dtype or geometry drifted from the
//! manifest row is caught as a hard failure, not a runtime surprise).
//! Softer wrinkles — duplicated size classes, HLO files on disk no row
//! references, names that disagree with their own order flag — are
//! warnings: the executor tolerates them, a human should not.

use std::collections::{HashMap, HashSet};

use super::{Report, Verdict};
use crate::runtime::artifact::{ArtifactMeta, Manifest};
use crate::runtime::Key;

/// Audit one manifest entry's metadata shape (no I/O).
fn audit_shape(meta: &ArtifactMeta) -> Result<(), String> {
    if !meta.n.is_power_of_two() || meta.n < 2 {
        return Err(format!("n={} is not a power of two >= 2", meta.n));
    }
    if meta.batch == 0 {
        return Err("batch is zero".into());
    }
    if !meta.block.is_power_of_two() || meta.block < 2 {
        return Err(format!("block={} is not a power of two >= 2", meta.block));
    }
    if meta.grid_cells == 0 {
        return Err("grid_cells is zero".into());
    }
    Ok(())
}

/// Audit one entry's HLO text against its manifest row.
fn audit_hlo(meta: &ArtifactMeta, text: &str) -> Result<(), String> {
    if !text.contains("HloModule") {
        return Err("file does not declare an HloModule".into());
    }
    let shape = format!("{}[{},{}]", meta.dtype.hlo_token(), meta.batch, meta.n);
    if !text.contains(&shape) {
        return Err(format!(
            "HLO text never mentions the manifest shape {shape} — dtype/shape drift"
        ));
    }
    Ok(())
}

/// Lint the whole manifest: shapes, files, HLO drift, duplicates and
/// dangling files. Pass 3 of [`super::verify_plans`]; also exposed as
/// [`Manifest::analyze`].
pub fn audit_manifest(manifest: &Manifest) -> Report {
    let mut report = Report::new();
    let mut seen: HashMap<Key, String> = HashMap::new();
    let mut referenced: HashSet<std::path::PathBuf> = HashSet::new();
    let mut clean = 0usize;

    for meta in &manifest.entries {
        let mut entry_ok = true;
        if let Err(e) = audit_shape(meta) {
            report.push("artifact.shape", &meta.name, Verdict::Fail, e);
            entry_ok = false;
        }
        let path = manifest.path_of(meta);
        referenced.insert(path.clone());
        match std::fs::read_to_string(&path) {
            Err(e) => {
                report.push(
                    "artifact.file",
                    &meta.name,
                    Verdict::Fail,
                    format!("HLO file {} unreadable: {e}", meta.file.display()),
                );
                entry_ok = false;
            }
            Ok(text) => {
                if let Err(e) = audit_hlo(meta, &text) {
                    report.push("artifact.hlo", &meta.name, Verdict::Fail, e);
                    entry_ok = false;
                }
            }
        }
        // The aot namer encodes the order in the name; a flag that
        // disagrees is almost certainly a hand-edit gone wrong.
        let order = if meta.descending { "desc" } else { "asc" };
        let flipped = if meta.descending { "asc" } else { "desc" };
        if meta.name.ends_with(flipped) && !meta.name.ends_with(order) {
            report.push(
                "artifact.order",
                &meta.name,
                Verdict::Warn,
                format!("name suggests {flipped} but descending={}", meta.descending as u8),
            );
        }
        if let Some(prev) = seen.insert(Key::of(meta), meta.name.clone()) {
            report.push(
                "artifact.duplicate",
                &meta.name,
                Verdict::Warn,
                format!("same size class as {prev}; the registry will only ever use one"),
            );
        }
        if entry_ok {
            clean += 1;
        }
    }

    // Dangling HLO files: on disk, referenced by no row.
    if let Ok(dir) = std::fs::read_dir(&manifest.dir) {
        let mut dangling: Vec<String> = dir
            .flatten()
            .map(|e| e.path())
            .filter(|p| {
                p.file_name()
                    .and_then(|f| f.to_str())
                    .is_some_and(|f| f.ends_with(".hlo.txt"))
                    && !referenced.contains(p)
            })
            .filter_map(|p| p.file_name().map(|f| f.to_string_lossy().into_owned()))
            .collect();
        dangling.sort();
        if !dangling.is_empty() {
            report.push(
                "artifact.dangling",
                manifest.dir.display().to_string(),
                Verdict::Warn,
                format!("{} HLO file(s) referenced by no manifest row: {}", dangling.len(), dangling.join(", ")),
            );
        }
    }

    report.push(
        "artifact.manifest",
        manifest.dir.display().to_string(),
        if clean == manifest.entries.len() { Verdict::Pass } else { Verdict::Warn },
        format!(
            "{clean}/{} entries audit clean (shape, file, HLO dtype/shape token)",
            manifest.entries.len()
        ),
    );
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn temp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "bitonic-artifact-check-{}-{tag}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn write_hlo(dir: &std::path::Path, file: &str, shape: &str) {
        std::fs::write(
            dir.join(file),
            format!("HloModule jit_sort\n\nENTRY main {{\n  p = {shape} parameter(0)\n}}\n"),
        )
        .unwrap();
    }

    const HEADER: &str = "name\tkind\tvariant\tbatch\tn\tdtype\tdescending\tblock\tgrid_cells\tfile\n";

    #[test]
    fn clean_manifest_passes() {
        let dir = temp_dir("clean");
        write_hlo(&dir, "a.hlo.txt", "u32[8,1024]");
        let text = format!(
            "{HEADER}sort_basic_b8_n1024_uint32_asc\tsort\tbasic\t8\t1024\tuint32\t0\t256\t16\ta.hlo.txt\n"
        );
        let m = Manifest::parse(dir, &text).unwrap();
        let report = audit_manifest(&m);
        assert!(!report.has_fail(), "{}", report.render_markdown());
        assert_eq!(report.worst(), Verdict::Pass);
    }

    #[test]
    fn dtype_drift_and_bad_n_fail() {
        let dir = temp_dir("drift");
        // HLO says s32 but the manifest row says uint32.
        write_hlo(&dir, "a.hlo.txt", "s32[8,1024]");
        write_hlo(&dir, "b.hlo.txt", "u32[8,48]");
        let text = format!(
            "{HEADER}sort_basic_b8_n1024_uint32_asc\tsort\tbasic\t8\t1024\tuint32\t0\t256\t16\ta.hlo.txt\n\
             sort_basic_b8_n48_uint32_asc\tsort\tbasic\t8\t48\tuint32\t0\t256\t16\tb.hlo.txt\n"
        );
        let m = Manifest::parse(dir, &text).unwrap();
        let report = audit_manifest(&m);
        assert!(report.has_fail());
        assert!(report.findings.iter().any(|f| f.check == "artifact.hlo"));
        assert!(report.findings.iter().any(|f| f.check == "artifact.shape"));
    }

    #[test]
    fn missing_file_dangling_and_duplicate_flagged() {
        let dir = temp_dir("files");
        write_hlo(&dir, "a.hlo.txt", "u32[8,1024]");
        write_hlo(&dir, "orphan.hlo.txt", "u32[1,16]");
        let text = format!(
            "{HEADER}sort_basic_b8_n1024_uint32_asc\tsort\tbasic\t8\t1024\tuint32\t0\t256\t16\ta.hlo.txt\n\
             sort_basic_b8_n1024_uint32_asc_v2\tsort\tbasic\t8\t1024\tuint32\t0\t256\t16\tmissing.hlo.txt\n"
        );
        let m = Manifest::parse(dir, &text).unwrap();
        let report = audit_manifest(&m);
        assert!(report.findings.iter().any(|f| f.check == "artifact.file" && f.verdict == Verdict::Fail));
        assert!(report.findings.iter().any(|f| f.check == "artifact.dangling" && f.detail.contains("orphan.hlo.txt")));
        assert!(report.findings.iter().any(|f| f.check == "artifact.duplicate"));
    }

    #[test]
    fn order_flag_name_disagreement_warns() {
        let dir = temp_dir("order");
        write_hlo(&dir, "a.hlo.txt", "u32[8,1024]");
        let text = format!(
            "{HEADER}sort_basic_b8_n1024_uint32_desc\tsort\tbasic\t8\t1024\tuint32\t0\t256\t16\ta.hlo.txt\n"
        );
        let m = Manifest::parse(dir, &text).unwrap();
        let report = audit_manifest(&m);
        assert!(!report.has_fail());
        assert!(report.findings.iter().any(|f| f.check == "artifact.order" && f.verdict == Verdict::Warn));
    }
}
