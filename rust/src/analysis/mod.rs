//! Static analysis: prove plans sort and schedules don't race, **before
//! anything executes**.
//!
//! The repo's correctness story so far was dynamic — bit-exactness
//! property tests on sampled inputs. This subsystem adds the static
//! layer the survey literature treats as table stakes for fused or
//! hierarchical sorting kernels (see PAPERS.md): every claim the
//! runtime's `unsafe` blocks and launch programs rest on is checked
//! symbolically, for *all* inputs of the covered sizes, not samples.
//!
//! Three passes, surfaced by `bitonic-tpu verify-plans` and run in CI
//! over the checked-in artifact fixture:
//!
//! 1. **Network verifier** ([`network_check`]): each
//!    [`crate::runtime::ExecutionPlan`]'s launch program is statically
//!    expanded and proven equal to
//!    [`crate::sort::network::Network::step_schedule`] (the fusion
//!    algebra), then the schedule itself is proven to *sort* via the
//!    0–1 principle — exhaustively (full enumeration for tiny rows, a
//!    complete per-phase induction up to
//!    [`VerifyOptions::exhaustive_cap`]), with a monotone-sampling
//!    fallback and an explicit "not exhaustively proven" [`Verdict::Warn`]
//!    above the cap.
//! 2. **Disjointness checker** ([`disjoint`]): the chunked
//!    `bitonic_parallel` barrier schedule (quad ownership included) and
//!    the executor's interleaved tile dispatch are emulated symbolically
//!    and every index is shown to be written by exactly one worker per
//!    barrier interval — the invariant the `unsafe` SAFETY comments in
//!    `sort/bitonic_parallel.rs` and `util/threadpool.rs` cite.
//! 3. **Artifact auditor** ([`artifact_check`]): `manifest.tsv` + HLO
//!    texts are linted for dtype/shape/order drift, dangling files and
//!    malformed shapes; a stale `autotune.tsv` is a warning, never a
//!    panic.
//!
//! Everything lands in a [`Report`]: machine-readable JSON (via
//! [`crate::util::json`]) plus a markdown rendering (`ANALYSIS.md`),
//! written by the CLI and gated in CI (any [`Verdict::Fail`] fails the
//! build). The verifier is deliberately paranoid about *itself* too:
//! `rust/tests/analysis_mutations.rs` feeds it corrupted launch
//! programs, racy schedules and broken manifests and asserts each one
//! is rejected.

pub mod artifact_check;
pub mod disjoint;
pub mod network_check;

use std::path::{Path, PathBuf};

use crate::runtime::{Manifest, Registry, TuningProfile};
use crate::sort::network::Variant;
use crate::util::json::Json;

/// Largest row length / phase length the 0–1 sort proof enumerates
/// exhaustively by default. The per-phase induction costs
/// `O((k/2+1)^2 · log k · k/64)` word operations at phase length `k`,
/// so 1024 keeps `cargo test` (debug profile) comfortable; release
/// drivers (verify.sh, CI) raise it via `--exhaustive-cap` to also
/// prove the smallest merge class.
pub const DEFAULT_EXHAUSTIVE_CAP: usize = 1024;

/// Outcome of one check.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Verdict {
    /// The property holds (proven or audited clean).
    Pass,
    /// Nothing wrong found, but the check is not a proof (e.g. the 0–1
    /// enumeration was sampled because `n` exceeds the exhaustive cap).
    Warn,
    /// The property is violated — a counterexample or a broken artifact.
    Fail,
}

impl Verdict {
    /// Stable token used in the markdown/JSON reports. `FAIL` appears in
    /// report text **only** as a verdict token — verify.sh greps for it.
    pub fn name(self) -> &'static str {
        match self {
            Verdict::Pass => "PASS",
            Verdict::Warn => "WARN",
            Verdict::Fail => "FAIL",
        }
    }
}

/// One check result: which pass ran, on what target, and what it found.
#[derive(Clone, Debug)]
pub struct Finding {
    /// Check identifier, dotted (`network.structural`,
    /// `disjoint.schedule`, `artifact.hlo`, …).
    pub check: String,
    /// What was checked (artifact name, plan geometry, schedule shape).
    pub target: String,
    /// Outcome.
    pub verdict: Verdict,
    /// Human-readable evidence: proof size, counterexample, or drift.
    pub detail: String,
}

/// An ordered collection of [`Finding`]s with renderers — what every
/// `analyze()` hook returns and what `verify-plans` writes to disk.
#[derive(Clone, Debug, Default)]
pub struct Report {
    /// All findings, check order.
    pub findings: Vec<Finding>,
}

impl Report {
    /// Empty report.
    pub fn new() -> Self {
        Self::default()
    }

    /// Append one finding.
    pub fn push(&mut self, check: &str, target: impl Into<String>, verdict: Verdict, detail: impl Into<String>) {
        self.findings.push(Finding {
            check: check.to_string(),
            target: target.into(),
            verdict,
            detail: detail.into(),
        });
    }

    /// Append every finding of `other`.
    pub fn merge(&mut self, other: Report) {
        self.findings.extend(other.findings);
    }

    /// `(pass, warn, fail)` counts.
    pub fn counts(&self) -> (usize, usize, usize) {
        let mut c = (0, 0, 0);
        for f in &self.findings {
            match f.verdict {
                Verdict::Pass => c.0 += 1,
                Verdict::Warn => c.1 += 1,
                Verdict::Fail => c.2 += 1,
            }
        }
        c
    }

    /// True iff any finding failed — the CI gate.
    pub fn has_fail(&self) -> bool {
        self.findings.iter().any(|f| f.verdict == Verdict::Fail)
    }

    /// Worst verdict in the report (`Pass` when empty).
    pub fn worst(&self) -> Verdict {
        self.findings
            .iter()
            .map(|f| f.verdict)
            .max()
            .unwrap_or(Verdict::Pass)
    }

    /// Render the report as markdown (`ANALYSIS.md`).
    pub fn render_markdown(&self) -> String {
        let (pass, warn, fail) = self.counts();
        let mut out = String::new();
        out.push_str("# Static analysis report\n\n");
        out.push_str(
            "Generated by `bitonic-tpu verify-plans` — the static plan verifier,\n\
             concurrency-disjointness checker and artifact auditor (see\n\
             `rust/src/analysis/`). Regenerate with\n\
             `cargo run --release --bin bitonic-tpu -- verify-plans`.\n\n",
        );
        out.push_str(&format!(
            "**Verdict: {}** — {} findings: {pass} passed, {warn} warned, {fail} failed.\n\n",
            self.worst().name(),
            self.findings.len(),
        ));
        out.push_str(
            "A WARN marks a property that was *checked but not exhaustively\n\
             proven* (sampled 0–1 enumeration above the exhaustive cap) or a\n\
             non-breaking audit wrinkle (e.g. a stale autotune class). Any\n\
             failing finding fails CI.\n\n",
        );
        out.push_str("| check | target | verdict | detail |\n|---|---|---|---|\n");
        for f in &self.findings {
            out.push_str(&format!(
                "| `{}` | {} | {} | {} |\n",
                f.check,
                f.target.replace('|', "\\|"),
                f.verdict.name(),
                f.detail.replace('|', "\\|"),
            ));
        }
        out
    }

    /// Serialize the report (`ANALYSIS.json`).
    pub fn to_json(&self) -> Json {
        let (pass, warn, fail) = self.counts();
        let mut summary = Json::obj();
        summary.set("pass", pass).set("warn", warn).set("fail", fail);
        let mut findings = Json::arr();
        for f in &self.findings {
            let mut o = Json::obj();
            o.set("check", f.check.as_str())
                .set("target", f.target.as_str())
                .set("verdict", f.verdict.name())
                .set("detail", f.detail.as_str());
            findings.push(o);
        }
        let mut root = Json::obj();
        root.set("schema", "bitonic-tpu-analysis")
            .set("version", 1usize)
            .set("verdict", self.worst().name())
            .set("summary", summary)
            .set("findings", findings);
        root
    }

    /// Default markdown report path: `$ANALYSIS_MD` if set, else
    /// `ANALYSIS.md` at the workspace root (compile-time anchored, like
    /// the bench trajectory — producers run with different cwds).
    pub fn default_md_path() -> PathBuf {
        if let Ok(path) = std::env::var("ANALYSIS_MD") {
            return PathBuf::from(path);
        }
        let manifest = Path::new(env!("CARGO_MANIFEST_DIR"));
        manifest.parent().unwrap_or(manifest).join("ANALYSIS.md")
    }
}

/// Knobs for [`verify_plans`].
#[derive(Clone, Debug)]
pub struct VerifyOptions {
    /// Largest `n` (row or phase length) proven exhaustively by the 0–1
    /// induction; larger targets get the sampled fallback + `Warn`.
    pub exhaustive_cap: usize,
    /// Random 0–1 vectors per sampled-fallback target (on top of the
    /// deterministic structured family).
    pub samples: usize,
    /// Worker counts the parallel-schedule disjointness check emulates.
    pub threads_menu: Vec<usize>,
}

impl Default for VerifyOptions {
    fn default() -> Self {
        Self {
            exhaustive_cap: DEFAULT_EXHAUSTIVE_CAP,
            samples: 96,
            threads_menu: vec![2, 4, 8],
        }
    }
}

/// Run all three static-analysis passes over an artifacts directory —
/// the engine behind `bitonic-tpu verify-plans`.
///
/// Never panics on bad inputs: a missing manifest, malformed row, or
/// stale autotune profile becomes a `Fail`/`Warn` finding in the report
/// (the regression tests pin the stale-profile case specifically).
pub fn verify_plans(dir: &Path, opts: &VerifyOptions) -> crate::Result<Report> {
    let mut report = Report::new();

    // Pass 3 first: the artifact audit decides whether there is anything
    // coherent to verify plans against.
    let manifest = match Manifest::load(dir) {
        Ok(m) => m,
        Err(e) => {
            report.push(
                "artifact.manifest",
                dir.display().to_string(),
                Verdict::Fail,
                format!("manifest unreadable: {e:#}"),
            );
            return Ok(report);
        }
    };
    report.merge(manifest.analyze());

    // Autotune profile audit: stale classes warn-and-continue; a file
    // that cannot even be parsed is a real failure.
    let profile_path = TuningProfile::default_path(dir);
    if profile_path.exists() {
        match TuningProfile::load(&profile_path) {
            Ok(profile) => report.merge(profile.analyze(&manifest)),
            Err(e) => report.push(
                "artifact.autotune",
                profile_path.display().to_string(),
                Verdict::Fail,
                format!("profile unreadable: {e:#}"),
            ),
        }
    }

    // Pass 1a: every plan the registry actually produces for this menu
    // (real HLO load + policy resolution), structural + 0–1 semantic.
    let mut proofs = network_check::ProofCache::new();
    match Registry::open(dir) {
        Ok(registry) => report.merge(registry.analyze_with(&mut proofs, opts)),
        Err(e) => report.push(
            "network.registry",
            dir.display().to_string(),
            Verdict::Fail,
            format!("registry unopenable: {e:#}"),
        ),
    }

    // Pass 1b: the wider geometry sweep — every variant × block ×
    // interleave the registry *could* be steered to (via profile or
    // --plan-* flags) for each (kind, n) in the menu. Structural checks
    // are per-geometry; the 0–1 proof is shared per (kind, n) through
    // the cache (the expansions are proven identical first).
    let mut shapes: Vec<(crate::runtime::ArtifactKind, usize)> =
        manifest.entries.iter().map(|m| (m.kind, m.n)).collect();
    shapes.sort_by_key(|&(k, n)| (n, k == crate::runtime::ArtifactKind::Merge));
    shapes.dedup();
    for &(kind, n) in &shapes {
        if !n.is_power_of_two() {
            continue; // already a Fail finding from the audit
        }
        report.merge(network_check::check_geometry_sweep(kind, n, opts, &mut proofs));
    }

    // Pass 2a: chunked parallel-schedule disjointness for every sort row
    // length in the menu × the worker menu.
    let mut sort_ns: Vec<usize> = manifest
        .entries
        .iter()
        .filter(|m| m.kind == crate::runtime::ArtifactKind::Sort && m.n.is_power_of_two())
        .map(|m| m.n)
        .collect();
    sort_ns.sort_unstable();
    sort_ns.dedup();
    for &n in &sort_ns {
        for &threads in &opts.threads_menu {
            report.merge(disjoint::analyze_parallel_schedule(n, threads));
        }
    }

    // Pass 2b: interleaved tile dispatch partitions the row space for a
    // dense geometry grid (ragged tails included) plus the exact batch
    // shapes the menu ships.
    let mut batches: Vec<usize> = manifest.entries.iter().map(|m| m.batch).collect();
    batches.extend(1..=64);
    batches.sort_unstable();
    batches.dedup();
    report.merge(disjoint::analyze_tile_dispatch(&batches));

    // Pass 2c: the hierarchical sorter's splitter bucket partition covers
    // its merge output exactly once, rank-ordered and balance-bounded,
    // for a scenario grid that stresses each documented hazard — the
    // proof `sort::pmerge`'s scoped dispatch relies on.
    report.merge(disjoint::analyze_bucket_partition());

    Ok(report)
}

/// Memoized per-`(variant, block)` structural sweep menu used by the
/// orchestrator and the `Network::analyze` hook — a spread of blocks
/// below, at and above typical row lengths.
pub(crate) fn block_menu(n: usize) -> Vec<usize> {
    let mut blocks = vec![64, 256, 1024, 4096];
    blocks.retain(|&b| b <= n.max(2));
    if blocks.is_empty() {
        blocks.push(2);
    }
    blocks.push(2 * n); // clamps to n inside launches(): the degenerate all-fused case
    blocks
}

/// All `(variant, block, interleave)` geometries swept per shape.
pub(crate) fn geometry_menu(n: usize) -> Vec<(Variant, usize, usize)> {
    let mut out = Vec::new();
    for variant in Variant::ALL {
        for &block in &block_menu(n) {
            for interleave in [1usize, 8] {
                out.push((variant, block, interleave));
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn verdict_ordering_and_names() {
        assert!(Verdict::Pass < Verdict::Warn && Verdict::Warn < Verdict::Fail);
        assert_eq!(Verdict::Fail.name(), "FAIL");
    }

    #[test]
    fn report_counts_and_gate() {
        let mut r = Report::new();
        assert_eq!(r.worst(), Verdict::Pass);
        r.push("a.b", "t", Verdict::Pass, "ok");
        r.push("a.c", "t", Verdict::Warn, "sampled only");
        assert!(!r.has_fail());
        assert_eq!(r.worst(), Verdict::Warn);
        r.push("a.d", "t", Verdict::Fail, "counterexample");
        assert!(r.has_fail());
        assert_eq!(r.counts(), (1, 1, 1));
        let md = r.render_markdown();
        assert!(md.contains("FAIL") && md.contains("| `a.c` |"));
        let json = r.to_json();
        assert_eq!(json.get("verdict").and_then(Json::as_str), Some("FAIL"));
        assert_eq!(
            json.get("summary").and_then(|s| s.get("warn")).and_then(Json::as_usize),
            Some(1)
        );
        // Round-trips through the strict parser.
        assert!(Json::parse(&json.render()).is_ok());
    }

    #[test]
    fn fail_token_never_leaks_into_clean_reports() {
        // verify.sh greps ANALYSIS.md for "FAIL"; a clean report must not
        // contain the token anywhere (headers, prose, details).
        let mut r = Report::new();
        r.push("x.y", "target", Verdict::Pass, "proven over 81 vectors");
        r.push("x.z", "target", Verdict::Warn, "sampled; not exhaustively proven");
        assert!(!r.render_markdown().contains("FAIL"));
    }
}
