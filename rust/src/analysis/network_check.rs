//! Pass 1: the network verifier — prove a launch program **sorts**.
//!
//! Two layers, composed:
//!
//! 1. **Structural** — statically expand a plan's launch program via
//!    [`Launch::steps`] and require it to equal the canonical
//!    [`Network::step_schedule`] (sorts) or the final phase's steps
//!    (merges), with the `reverse_tail` wiring matching the kind. This
//!    ties *every* geometry (variant × block × interleave) to one
//!    canonical step schedule.
//! 2. **Semantic** — prove the canonical schedule sorts, via the 0–1
//!    principle (a data-oblivious compare-exchange network sorts all
//!    inputs iff it sorts all 0–1 inputs):
//!
//!    * `n ≤ 16`: brute force, all `2^n` 0–1 vectors at once in a
//!      transposed bit-parallel simulation (one `u64` lane per 64
//!      candidate inputs). Handles *arbitrary* step lists, so it also
//!      refutes mutants with non-power-of-two strides.
//!    * `n ≤ exhaustive_cap`: a complete per-phase induction. After
//!      phase `k`, the aligned `k`-block at base `B` is sorted
//!      ascending iff `B & k == 0`, so every 0–1 state a `2k`-block can
//!      be in when phase `2k` starts is `asc-sorted half ++ desc-sorted
//!      half` — exactly `(k+1)^2` states per direction. The lemma
//!      enumerates them all, runs the phase's strides, and requires a
//!      fully sorted block; composing the lemmas over all phases is an
//!      exhaustive 0–1 proof at a cost quadratic in `n` instead of
//!      `2^n`. Merges are the single phase-`n` lemma: `reverse_tail`
//!      maps "both halves ascending" onto the lemma's precondition.
//!    * above the cap: a structured + seeded-random 0–1 sampling
//!      fallback — counterexamples still refute, but a clean run is
//!      reported as [`Verdict::Warn`] ("not exhaustively proven").
//!
//! Non-canonical step lists (seeded mutants, future generated plans)
//! skip the induction — it is only sound for the canonical grouping —
//! and go straight to exhaustive brute force (small `n`) or sampling.

use std::collections::HashMap;

use super::{Report, Verdict, VerifyOptions};
use crate::runtime::{ArtifactKind, ExecutionPlan};
use crate::sort::network::{Launch, Network, Phase, Step, Variant};
use crate::workload::rng::Pcg32;

/// Row lengths up to this get the full `2^n` brute-force enumeration.
pub const FULL_ENUM_MAX_N: usize = 16;

/// Result of a semantic (0–1) check of one step schedule.
#[derive(Clone, Debug)]
pub enum Outcome {
    /// Sorts **all** inputs — the 0–1 enumeration was complete.
    Proven {
        /// Number of 0–1 vectors simulated.
        vectors: u64,
        /// Which proof produced it (`brute-force` or `induction`).
        method: &'static str,
    },
    /// No counterexample found, but the enumeration was sampled.
    NotProven {
        /// Number of 0–1 vectors simulated.
        vectors: u64,
        /// Why this is not a proof.
        reason: String,
    },
    /// A 0–1 input the schedule fails to sort.
    Refuted {
        /// Counterexample description.
        detail: String,
    },
}

impl Outcome {
    /// Map onto a report verdict.
    pub fn verdict(&self) -> Verdict {
        match self {
            Outcome::Proven { .. } => Verdict::Pass,
            Outcome::NotProven { .. } => Verdict::Warn,
            Outcome::Refuted { .. } => Verdict::Fail,
        }
    }

    /// Human-readable evidence line.
    pub fn detail(&self) -> String {
        match self {
            Outcome::Proven { vectors, method } => {
                format!("proven by {method} over {vectors} 0-1 vectors (0-1 principle)")
            }
            Outcome::NotProven { vectors, reason } => {
                format!("not exhaustively proven: {reason} ({vectors} sampled 0-1 vectors, no counterexample)")
            }
            Outcome::Refuted { detail } => format!("counterexample: {detail}"),
        }
    }
}

// ----------------------------------------------------------------------
// 0–1 vectors as bit vectors (bit i = value at index i), `u64` words.
// ----------------------------------------------------------------------

fn words_for(nbits: usize) -> usize {
    nbits.div_ceil(64)
}

fn set_bit(v: &mut [u64], i: usize) {
    v[i / 64] |= 1u64 << (i % 64);
}

fn get_bit(v: &[u64], i: usize) -> bool {
    v[i / 64] >> (i % 64) & 1 == 1
}

/// Bits `[lo, hi)` set, rest clear — built word-wise, not per-bit.
fn ones_block(nbits: usize, lo: usize, hi: usize) -> Vec<u64> {
    let mut v = vec![0u64; words_for(nbits)];
    if lo >= hi {
        return v;
    }
    let (wl, wh) = (lo / 64, (hi - 1) / 64);
    for (w, word) in v.iter_mut().enumerate().take(wh + 1).skip(wl) {
        let base = w * 64;
        let l = lo.max(base) - base;
        let h = hi.min(base + 64) - base;
        let mask = if h - l == 64 { !0u64 } else { ((1u64 << (h - l)) - 1) << l };
        *word |= mask;
    }
    v
}

/// The fully sorted 0–1 vector of `nbits` bits with `ones` ones.
fn sorted_vec(nbits: usize, ones: usize, ascending: bool) -> Vec<u64> {
    if ascending {
        ones_block(nbits, nbits - ones, nbits)
    } else {
        ones_block(nbits, 0, ones)
    }
}

fn popcount(v: &[u64]) -> usize {
    v.iter().map(|w| w.count_ones() as usize).sum()
}

/// First index at which two equal-length bit vectors differ.
fn first_diff(a: &[u64], b: &[u64]) -> Option<usize> {
    for (w, (x, y)) in a.iter().zip(b).enumerate() {
        if x != y {
            return Some(w * 64 + (x ^ y).trailing_zeros() as usize);
        }
    }
    None
}

/// In-word mask of bit positions `b` (0..64) with `b & j == 0`, for
/// power-of-two `j < 64` (the classic alternating magic masks).
fn in_word_mask(j: usize) -> u64 {
    let mut m = 0u64;
    for b in 0..64 {
        if b & j == 0 {
            m |= 1u64 << b;
        }
    }
    m
}

/// One compare-exchange step with a **uniform** direction over the whole
/// vector (the per-block view used by the phase lemma). `j` must be a
/// power of two `< nbits`.
fn zo_step_uniform(v: &mut [u64], j: usize, ascending: bool) {
    debug_assert!(j.is_power_of_two());
    if j >= 64 {
        let d = j / 64;
        for w in 0..v.len() {
            if w & d == 0 {
                let (a, b) = (v[w], v[w | d]);
                let (mn, mx) = (a & b, a | b);
                if ascending {
                    v[w] = mn;
                    v[w | d] = mx;
                } else {
                    v[w] = mx;
                    v[w | d] = mn;
                }
            }
        }
    } else {
        let mj = in_word_mask(j);
        for word in v.iter_mut() {
            let a = *word & mj;
            let b = (*word >> j) & mj;
            let (mn, mx) = (a & b, a | b);
            *word = if ascending { mn | (mx << j) } else { mx | (mn << j) };
        }
    }
}

/// One canonical step over a full row: direction of pair `(i, i^j)` is
/// ascending iff `i & k == 0`. Fast word-parallel path for power-of-two
/// `j < k`; generic per-pair fallback for anything else (mutants).
fn zo_step(v: &mut [u64], nbits: usize, k: usize, j: usize) {
    let fast = j.is_power_of_two() && k.is_power_of_two() && j < k && j < nbits && j >= 1;
    if !fast {
        zo_step_generic(v, nbits, k, j);
        return;
    }
    if k >= nbits {
        // i & k == 0 for every i < nbits: the whole row is ascending.
        zo_step_uniform(v, j, true);
    } else if j >= 64 {
        // k > j >= 64: direction is constant per word pair.
        let (d, dk) = (j / 64, k / 64);
        for w in 0..v.len() {
            if w & d == 0 {
                let asc = w & dk == 0;
                let (a, b) = (v[w], v[w | d]);
                let (mn, mx) = (a & b, a | b);
                if asc {
                    v[w] = mn;
                    v[w | d] = mx;
                } else {
                    v[w] = mx;
                    v[w | d] = mn;
                }
            }
        }
    } else if k >= 64 {
        // j < 64 <= k: pairs stay in-word, direction constant per word.
        let (mj, dk) = (in_word_mask(j), k / 64);
        for (w, word) in v.iter_mut().enumerate() {
            let a = *word & mj;
            let b = (*word >> j) & mj;
            let (mn, mx) = (a & b, a | b);
            *word = if w & dk == 0 { mn | (mx << j) } else { mx | (mn << j) };
        }
    } else {
        // j < k < 64: both the pairing and the direction pattern repeat
        // within every word.
        let (mj, mk) = (in_word_mask(j), in_word_mask(k));
        let (amask, dmask) = (mj & mk, mj & !mk);
        for word in v.iter_mut() {
            let a = *word & mj;
            let b = (*word >> j) & mj;
            let (mn, mx) = (a & b, a | b);
            let low = (mn & amask) | (mx & dmask);
            let high = (mx & amask) | (mn & dmask);
            *word = low | (high << j);
        }
    }
}

/// Per-pair reference step: correct for arbitrary `(phase_len, stride)`,
/// including the non-power-of-two strides mutants produce. Pairs whose
/// partner falls outside the row are skipped (matching
/// [`Network::step_pairs`]' `partner > i` + in-range enumeration).
fn zo_step_generic(v: &mut [u64], nbits: usize, k: usize, j: usize) {
    if j == 0 {
        return;
    }
    for i in 0..nbits {
        let p = i ^ j;
        if p > i && p < nbits {
            let (a, b) = (get_bit(v, i), get_bit(v, p));
            if a != b {
                let ascending = i & k == 0;
                // Out of order iff (asc and a > b) or (desc and a < b).
                if ascending == a {
                    v[i / 64] ^= 1u64 << (i % 64);
                    v[p / 64] ^= 1u64 << (p % 64);
                }
            }
        }
    }
}

fn sim_steps(v: &mut [u64], nbits: usize, steps: &[Step]) {
    for s in steps {
        zo_step(v, nbits, s.phase_len, s.stride);
    }
}

// ----------------------------------------------------------------------
// Proof engines.
// ----------------------------------------------------------------------

/// Brute force for `n ≤ FULL_ENUM_MAX_N`: simulate **all** `2^n` 0–1
/// inputs simultaneously. State is transposed — `pos[e]` is a bitset
/// over candidate inputs holding input `t`'s value at index `e`, so one
/// compare-exchange pair costs `O(2^n / 64)` word ops and handles
/// arbitrary step lists. Input `t`'s vector is the binary encoding of
/// `t` itself, which makes counterexample extraction exact.
fn brute_force_sort(n: usize, steps: &[Step]) -> Result<u64, String> {
    debug_assert!(n >= 1 && n <= FULL_ENUM_MAX_N);
    let vectors = 1usize << n;
    let words = words_for(vectors);
    let tail_mask = if vectors >= 64 { !0u64 } else { (1u64 << vectors) - 1 };
    let mut pos: Vec<Vec<u64>> = (0..n)
        .map(|e| {
            (0..words)
                .map(|w| {
                    if e < 6 {
                        !in_word_mask(1 << e) // bit t set iff (t >> e) & 1
                    } else if (w >> (e - 6)) & 1 == 1 {
                        !0u64
                    } else {
                        0u64
                    }
                })
                .collect()
        })
        .collect();
    for s in steps {
        if s.stride == 0 {
            continue;
        }
        for i in 0..n {
            let p = i ^ s.stride;
            if p > i && p < n {
                let ascending = i & s.phase_len == 0;
                for w in 0..words {
                    let (a, b) = (pos[i][w], pos[p][w]);
                    let (mn, mx) = (a & b, a | b);
                    if ascending {
                        pos[i][w] = mn;
                        pos[p][w] = mx;
                    } else {
                        pos[i][w] = mx;
                        pos[p][w] = mn;
                    }
                }
            }
        }
    }
    // Sorted ascending for every input: no input may have 1 at e, 0 at e+1.
    for e in 0..n.saturating_sub(1) {
        for w in 0..words {
            let viol = pos[e][w] & !pos[e + 1][w] & tail_mask;
            if viol != 0 {
                let t = w * 64 + viol.trailing_zeros() as usize;
                let bits: String = (0..n).map(|e| if (t >> e) & 1 == 1 { '1' } else { '0' }).collect();
                return Err(format!(
                    "0-1 input [{bits}] (lsb-first) leaves index {e} > index {}",
                    e + 1
                ));
            }
        }
    }
    Ok(vectors as u64)
}

/// The per-phase induction lemma at phase length `k`: for both
/// directions, every reachable 0–1 state of one aligned `k`-block
/// entering phase `k` — ascending-sorted first half (`x` ones) ++
/// descending-sorted second half (`y` ones) — must leave the phase's
/// strides `k/2 … 1` fully sorted in the phase direction.
fn phase_lemma(k: usize) -> Result<u64, String> {
    debug_assert!(k.is_power_of_two() && k >= 2);
    let h = k / 2;
    let mut vectors = 0u64;
    for ascending in [true, false] {
        for x in 0..=h {
            for y in 0..=h {
                // First half 0^(h-x) 1^x; second half 1^y 0^(h-y).
                let mut v = ones_block(k, h - x, h);
                let tail = ones_block(k, h, h + y);
                for (w, t) in v.iter_mut().zip(tail) {
                    *w |= t;
                }
                let mut j = h;
                while j >= 1 {
                    zo_step_uniform(&mut v, j, ascending);
                    j /= 2;
                }
                if v != sorted_vec(k, x + y, ascending) {
                    let dir = if ascending { "asc" } else { "desc" };
                    return Err(format!(
                        "phase k={k} lemma violated ({dir} block, asc half x={x} ones, desc half y={y} ones)"
                    ));
                }
                vectors += 1;
            }
        }
    }
    Ok(vectors)
}

/// Structured + seeded-random 0–1 sampling of a full-row sort schedule.
/// Returns `(vectors tried, first counterexample)`.
fn sampled_sort(n: usize, steps: &[Step], samples: usize) -> (u64, Option<String>) {
    let mut tried = 0u64;
    let mut run = |input: Vec<u64>, label: &str| -> Option<String> {
        let mut v = input;
        let ones = popcount(&v);
        sim_steps(&mut v, n, steps);
        let want = sorted_vec(n, ones, true);
        let bad = first_diff(&v, &want)?;
        Some(format!("sampled 0-1 vector ({label}, {ones} ones) unsorted at index {bad}"))
    };
    let mut boundaries: Vec<usize> = Vec::new();
    let mut t = 1usize;
    while t <= n {
        for p in [t.saturating_sub(1), t, t + 1] {
            if p < n {
                boundaries.push(p);
            }
        }
        t *= 2;
    }
    boundaries.sort_unstable();
    boundaries.dedup();

    let mut family: Vec<(Vec<u64>, String)> = Vec::new();
    family.push((ones_block(n, 0, 0), "all-zeros".into()));
    family.push((ones_block(n, 0, n), "all-ones".into()));
    for &p in &boundaries {
        let mut one = vec![0u64; words_for(n)];
        set_bit(&mut one, p);
        family.push((one, format!("single-one@{p}")));
        let mut zero = ones_block(n, 0, n);
        zero[p / 64] ^= 1u64 << (p % 64);
        family.push((zero, format!("single-zero@{p}")));
        family.push((ones_block(n, 0, p), format!("prefix-ones@{p}")));
    }
    let mut rng = Pcg32::new(0x0501_C4EC, n as u64);
    for s in 0..samples {
        let mut v: Vec<u64> = (0..words_for(n)).map(|_| rng.next_u64()).collect();
        if n % 64 != 0 {
            let last = v.len() - 1;
            v[last] &= (1u64 << (n % 64)) - 1;
        }
        family.push((v, format!("random#{s}")));
    }
    for (input, label) in family {
        tried += 1;
        if let Some(cex) = run(input, &label) {
            return (tried, Some(cex));
        }
    }
    (tried, None)
}

/// Enumerate / sample a merge schedule's **valid** 0–1 inputs: both
/// halves ascending-sorted (`x`, `y` ones), the plan's `reverse_tail`
/// applied (or not — broken wiring should be refutable), then the steps;
/// the output must be fully sorted. When the full `(h+1)^2` grid fits
/// the budget this is exhaustive over the merge's input contract.
fn merge_enum(
    n: usize,
    steps: &[Step],
    reverse_tail: bool,
    samples: usize,
    full_grid: bool,
) -> (u64, bool, Option<String>) {
    let h = n / 2;
    let mut grid: Vec<(usize, usize)> = Vec::new();
    if full_grid {
        for x in 0..=h {
            for y in 0..=h {
                grid.push((x, y));
            }
        }
    } else {
        let mut spread: Vec<usize> = vec![0, 1, 2, h / 2, h.saturating_sub(2), h.saturating_sub(1), h];
        spread.retain(|&v| v <= h);
        spread.sort_unstable();
        spread.dedup();
        for &x in &spread {
            for &y in &spread {
                grid.push((x, y));
            }
        }
        let mut rng = Pcg32::new(0x3E26_E001, n as u64);
        for _ in 0..samples {
            grid.push((rng.next_below(h as u32 + 1) as usize, rng.next_below(h as u32 + 1) as usize));
        }
    }
    let mut tried = 0u64;
    for (x, y) in grid {
        tried += 1;
        // First half asc: ones at [h-x, h). Second half holds y ones,
        // asc before the plan runs; with reverse_tail they land at
        // [h, h+y) (descending layout), without it at [n-y, n).
        let mut v = ones_block(n, h - x, h);
        let tail = if reverse_tail {
            ones_block(n, h, h + y)
        } else {
            ones_block(n, n - y, n)
        };
        for (w, t) in v.iter_mut().zip(tail) {
            *w |= t;
        }
        sim_steps(&mut v, n, steps);
        if let Some(bad) = first_diff(&v, &sorted_vec(n, x + y, true)) {
            return (
                tried,
                full_grid,
                Some(format!(
                    "merge input (asc half {x} ones, asc tail {y} ones) unsorted at index {bad}"
                )),
            );
        }
    }
    (tried, full_grid, None)
}

// ----------------------------------------------------------------------
// Public checks.
// ----------------------------------------------------------------------

/// The canonical step schedule of a shape.
pub fn canonical_steps(kind: ArtifactKind, n: usize) -> Vec<Step> {
    match kind {
        ArtifactKind::Sort => Network::new(n).step_schedule(),
        ArtifactKind::Merge => Phase { len: n }.steps().collect(),
    }
}

/// Semantically check an arbitrary **sort** step schedule over row
/// length `n` (power of two). Canonical schedules get a real proof up
/// to `opts.exhaustive_cap`; deviant schedules are brute-forced
/// (`n ≤ 16`) or sampled for a counterexample.
pub fn check_sort_steps(n: usize, steps: &[Step], opts: &VerifyOptions) -> Outcome {
    if n <= FULL_ENUM_MAX_N {
        return match brute_force_sort(n, steps) {
            Ok(vectors) => Outcome::Proven { vectors, method: "brute-force enumeration" },
            Err(detail) => Outcome::Refuted { detail },
        };
    }
    if steps == canonical_steps(ArtifactKind::Sort, n).as_slice() {
        if n <= opts.exhaustive_cap {
            let mut vectors = 0u64;
            let mut k = 2usize;
            while k <= n {
                match phase_lemma(k) {
                    Ok(v) => vectors += v,
                    Err(detail) => return Outcome::Refuted { detail },
                }
                k *= 2;
            }
            return Outcome::Proven { vectors, method: "per-phase 0-1 induction" };
        }
        let (vectors, cex) = sampled_sort(n, steps, opts.samples);
        return match cex {
            Some(detail) => Outcome::Refuted { detail },
            None => Outcome::NotProven {
                vectors,
                reason: format!("n={n} exceeds exhaustive cap {}", opts.exhaustive_cap),
            },
        };
    }
    let (vectors, cex) = sampled_sort(n, steps, opts.samples);
    match cex {
        Some(detail) => Outcome::Refuted { detail },
        None => Outcome::NotProven {
            vectors,
            reason: "schedule deviates from the canonical step order (sampled refutation only)".into(),
        },
    }
}

/// Semantically check a **merge** step schedule (final phase only) with
/// the plan's `reverse_tail` wiring. Canonical merges are the single
/// phase-`n` lemma (exhaustive up to the cap); deviants are enumerated
/// over the merge input grid, which is itself exhaustive when small.
pub fn check_merge_steps(n: usize, steps: &[Step], reverse_tail: bool, opts: &VerifyOptions) -> Outcome {
    let canonical = steps == canonical_steps(ArtifactKind::Merge, n).as_slice();
    if canonical && reverse_tail && n <= opts.exhaustive_cap {
        return match phase_lemma(n) {
            Ok(vectors) => Outcome::Proven {
                vectors,
                method: "phase-n 0-1 lemma (reverse_tail maps sorted halves onto its precondition)",
            },
            Err(detail) => Outcome::Refuted { detail },
        };
    }
    let h = n / 2;
    let full_grid = (h + 1).pow(2) <= 4096;
    let (vectors, exhaustive, cex) = merge_enum(n, steps, reverse_tail, opts.samples, full_grid);
    match cex {
        Some(detail) => Outcome::Refuted { detail },
        None if exhaustive => Outcome::Proven {
            vectors,
            method: "exhaustive merge-input grid",
        },
        None => Outcome::NotProven {
            vectors,
            reason: if canonical && reverse_tail {
                format!("n={n} exceeds exhaustive cap {}", opts.exhaustive_cap)
            } else {
                "schedule deviates from the canonical merge (sampled refutation only)".into()
            },
        },
    }
}

/// Memoizes the expensive semantic proofs across plans: phase lemmas by
/// `k` and whole-shape verdicts by `(kind, n)` — every geometry of a
/// shape shares one proof once its expansion is proven canonical.
#[derive(Default)]
pub struct ProofCache {
    shapes: HashMap<(ArtifactKind, usize), (Verdict, String)>,
}

impl ProofCache {
    /// Empty cache.
    pub fn new() -> Self {
        Self::default()
    }

    /// Verdict + evidence for the canonical schedule of `(kind, n)`.
    pub fn prove_canonical(&mut self, kind: ArtifactKind, n: usize, opts: &VerifyOptions) -> (Verdict, String) {
        if let Some(hit) = self.shapes.get(&(kind, n)) {
            return hit.clone();
        }
        let steps = canonical_steps(kind, n);
        let outcome = match kind {
            ArtifactKind::Sort => check_sort_steps(n, &steps, opts),
            ArtifactKind::Merge => check_merge_steps(n, &steps, true, opts),
        };
        let entry = (outcome.verdict(), outcome.detail());
        self.shapes.insert((kind, n), entry.clone());
        entry
    }
}

/// Check one compiled [`ExecutionPlan`]: structural expansion equality
/// plus the (cached) semantic proof. `target` labels the findings —
/// artifact name or geometry string.
pub fn check_plan(plan: &ExecutionPlan, target: &str, opts: &VerifyOptions, cache: &mut ProofCache) -> Report {
    let mut report = Report::new();
    let n = plan.n();
    let kind = plan.kind();
    if n < 2 {
        report.push("network.structural", target, Verdict::Pass, "degenerate plan (n < 2), no steps");
        return report;
    }
    let expansion: Vec<Step> = plan.launches().iter().flat_map(Launch::steps).collect();
    let canonical = canonical_steps(kind, n);
    let wiring_ok = plan.reverse_tail() == (kind == ArtifactKind::Merge);
    let steps_ok = expansion == canonical;
    if steps_ok && wiring_ok {
        report.push(
            "network.structural",
            target,
            Verdict::Pass,
            format!(
                "{} launches expand to the canonical {} steps exactly; reverse_tail wired for {}",
                plan.launches().len(),
                canonical.len(),
                kind.name(),
            ),
        );
        let (verdict, detail) = cache.prove_canonical(kind, n, opts);
        report.push("network.zero-one", target, verdict, detail);
    } else {
        let detail = if !wiring_ok {
            format!("reverse_tail={} is wrong for a {} plan", plan.reverse_tail(), kind.name())
        } else {
            let at = expansion
                .iter()
                .zip(&canonical)
                .position(|(a, b)| a != b)
                .unwrap_or_else(|| expansion.len().min(canonical.len()));
            format!(
                "expansion ({} steps) diverges from canonical ({} steps) at step {at}",
                expansion.len(),
                canonical.len(),
            )
        };
        report.push("network.structural", target, Verdict::Fail, detail);
        // Independent semantic teeth: try to refute the actual expansion.
        let outcome = match kind {
            ArtifactKind::Sort => check_sort_steps(n, &expansion, opts),
            ArtifactKind::Merge => check_merge_steps(n, &expansion, plan.reverse_tail(), opts),
        };
        report.push("network.zero-one", target, outcome.verdict(), outcome.detail());
    }
    report
}

/// Sweep every `(variant, block, interleave, descending)` geometry the
/// registry could be steered to for one `(kind, n)` shape: structural
/// equality per geometry (aggregated), then the shared semantic proof.
pub fn check_geometry_sweep(
    kind: ArtifactKind,
    n: usize,
    opts: &VerifyOptions,
    cache: &mut ProofCache,
) -> Report {
    let mut report = Report::new();
    let target = format!("{} n={n} (geometry sweep)", kind.name());
    let canonical = canonical_steps(kind, n);
    let mut checked = 0usize;
    let mut first_bad: Option<String> = None;
    for (variant, block, interleave) in super::geometry_menu(n) {
        for descending in [false, true] {
            // The proofs are ISA-independent: the default `Auto` kernel
            // never changes the expanded schedule, only the comparator
            // instructions each step executes with.
            let cfg = crate::runtime::PlanConfig {
                variant,
                block,
                interleave,
                ..Default::default()
            };
            let plan = ExecutionPlan::with_config(kind, n, descending, cfg);
            let expansion: Vec<Step> = plan.launches().iter().flat_map(Launch::steps).collect();
            let ok = expansion == canonical
                && plan.reverse_tail() == (kind == ArtifactKind::Merge)
                && plan.reverse_output() == descending;
            checked += 1;
            if !ok && first_bad.is_none() {
                first_bad = Some(format!(
                    "{} block={block} r={interleave} desc={descending}",
                    variant.name(),
                ));
            }
        }
    }
    match first_bad {
        None => report.push(
            "network.structural-sweep",
            target.clone(),
            Verdict::Pass,
            format!(
                "{checked} geometries ({} variants x blocks x interleave x order) all expand to the canonical schedule",
                Variant::ALL.len(),
            ),
        ),
        Some(bad) => report.push(
            "network.structural-sweep",
            target.clone(),
            Verdict::Fail,
            format!("{bad} diverges from the canonical schedule"),
        ),
    }
    let (verdict, detail) = cache.prove_canonical(kind, n, opts);
    report.push("network.zero-one", target, verdict, detail);
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    fn opts() -> VerifyOptions {
        VerifyOptions { exhaustive_cap: 1024, samples: 48, threads_menu: vec![2] }
    }

    #[test]
    fn brute_force_proves_small_canonical_networks() {
        for n in [2usize, 4, 8, 16] {
            let steps = canonical_steps(ArtifactKind::Sort, n);
            match check_sort_steps(n, &steps, &opts()) {
                Outcome::Proven { vectors, .. } => assert_eq!(vectors, 1 << n),
                other => panic!("n={n}: {other:?}"),
            }
        }
    }

    #[test]
    fn induction_proves_midsize_canonical_networks() {
        for n in [32usize, 128, 1024] {
            let steps = canonical_steps(ArtifactKind::Sort, n);
            match check_sort_steps(n, &steps, &opts()) {
                Outcome::Proven { method, .. } => assert_eq!(method, "per-phase 0-1 induction"),
                other => panic!("n={n}: {other:?}"),
            }
        }
    }

    #[test]
    fn induction_agrees_with_brute_force_on_overlap() {
        // Sanity for the lemma composition: at n=16 both engines run;
        // they must agree that the canonical schedule sorts.
        let steps = canonical_steps(ArtifactKind::Sort, 16);
        assert!(brute_force_sort(16, &steps).is_ok());
        let mut k = 2;
        while k <= 16 {
            assert!(phase_lemma(k).is_ok(), "k={k}");
            k *= 2;
        }
    }

    #[test]
    fn above_cap_is_warn_not_pass() {
        let o = VerifyOptions { exhaustive_cap: 512, ..opts() };
        let steps = canonical_steps(ArtifactKind::Sort, 2048);
        match check_sort_steps(2048, &steps, &o) {
            Outcome::NotProven { reason, .. } => assert!(reason.contains("exhaustive cap")),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn merge_lemma_proves_canonical_merge() {
        for n in [4usize, 64, 1024] {
            let steps = canonical_steps(ArtifactKind::Merge, n);
            match check_merge_steps(n, &steps, true, &opts()) {
                Outcome::Proven { .. } => {}
                other => panic!("n={n}: {other:?}"),
            }
        }
    }

    #[test]
    fn merge_without_reverse_tail_is_refuted() {
        // Dropping the reverse_tail wiring breaks the bitonic
        // precondition; the grid enumeration must find a witness.
        let steps = canonical_steps(ArtifactKind::Merge, 64);
        match check_merge_steps(64, &steps, false, &opts()) {
            Outcome::Refuted { .. } => {}
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn zo_step_matches_generic_reference() {
        // The word-parallel kernels must agree with the per-pair
        // reference on random vectors for every canonical step.
        let n = 256;
        let mut rng = Pcg32::new(7, 7);
        for s in canonical_steps(ArtifactKind::Sort, n) {
            let mut v: Vec<u64> = (0..words_for(n)).map(|_| rng.next_u64()).collect();
            let mut w = v.clone();
            zo_step(&mut v, n, s.phase_len, s.stride);
            zo_step_generic(&mut w, n, s.phase_len, s.stride);
            assert_eq!(v, w, "step {s:?}");
        }
    }

    #[test]
    fn ones_block_and_sorted_vec_are_wordwise_correct() {
        for (lo, hi) in [(0usize, 0usize), (0, 1), (3, 70), (64, 128), (5, 200), (0, 256)] {
            let v = ones_block(256, lo, hi);
            for i in 0..256 {
                assert_eq!(get_bit(&v, i), i >= lo && i < hi, "bit {i} of [{lo},{hi})");
            }
        }
        assert_eq!(popcount(&sorted_vec(192, 77, true)), 77);
        assert!(get_bit(&sorted_vec(192, 77, false), 0));
    }
}
