//! Pass 2: the concurrency-disjointness checker — prove parallel
//! schedules never write one index from two workers.
//!
//! Two symbolic emulations, both consuming the **same geometry code the
//! runtime dispatches from** (no parallel re-derivation that could
//! drift):
//!
//! * **Chunked barrier schedule** ([`check_parallel_schedule`]): walks
//!   [`barrier_intervals`] — the exact interval list
//!   [`crate::sort::bitonic_parallel::bitonic_sort_parallel`]'s workers
//!   execute — and, per interval, marks every index each worker writes
//!   (local fused tails, low-owned global pairs, minimum-owned register
//!   quads). Every index must be written by **exactly one** worker per
//!   barrier interval, quads must stay in range with a uniform
//!   direction bit, and the concatenated interval steps must equal the
//!   canonical [`Network::step_schedule`]. This is the proof the
//!   `SAFETY` comments in `sort/bitonic_parallel.rs` cite.
//! * **Interleaved tile dispatch** ([`check_tile_dispatch`]): replays
//!   [`dispatch_geometry`] — the partition `execute_batch` cuts a
//!   `(B, N)` buffer into — and verifies jobs and tiles are row-aligned,
//!   cover the buffer exactly once, never exceed the effective
//!   interleave width (ragged tails included), and yield enough tiles
//!   to feed the pool whenever the pooled path engages.
//! * **Splitter bucket partition** ([`check_bucket_plan`]): replays the
//!   [`MergePlan`] [`crate::sort::pmerge::plan_partition`] computes —
//!   the same geometry `pmerge` carves its output and dispatches bucket
//!   merges from — and verifies every run element lands in exactly one
//!   bucket, the bucket ranges tile the output exactly once, adjacent
//!   buckets are rank-ordered (so concatenating their merges is sorted),
//!   and no bucket exceeds the provable
//!   [`crate::sort::pmerge::balance_bound`]. This is the proof the
//!   `SAFETY` comment in `util/threadpool.rs` cites for the merge path.
//!
//! [`check_intervals`] takes an arbitrary interval list, so the mutation
//! suite can feed it *racy* schedules (e.g. two unpaired global strides
//! in one barrier interval) and assert the race is detected; likewise
//! [`check_bucket_plan`] takes an arbitrary plan (checked arithmetic
//! throughout) so corrupted cut matrices are findings, not panics.

use super::{Report, Verdict};
use crate::sort::bitonic_parallel::{barrier_intervals, effective_workers, IntervalOp};
use crate::sort::network::{Network, Step};
use crate::sort::pmerge::{balance_bound, plan_partition, MergePlan};
use crate::runtime::executor::dispatch_geometry;

/// Evidence from a clean schedule check.
#[derive(Clone, Copy, Debug)]
pub struct ScheduleStats {
    /// Barrier intervals emulated.
    pub intervals: usize,
    /// Total index writes marked (each verified singly-owned).
    pub writes: u64,
    /// Register quads verified (range + minimum ownership + uniform
    /// direction).
    pub quads: u64,
}

/// Emulate an arbitrary barrier-interval schedule for `workers` equal
/// chunks of `n` and verify write-disjointness. Each inner `Vec` is one
/// barrier interval (the canonical schedule has one op per interval;
/// mutants may pack several). Returns the first violation as `Err`.
pub fn check_intervals(
    n: usize,
    workers: usize,
    intervals: &[Vec<IntervalOp>],
) -> Result<ScheduleStats, String> {
    if !n.is_power_of_two() || n < 4 {
        return Err(format!("row length {n} is not a power of two >= 4"));
    }
    if !workers.is_power_of_two() || workers < 2 || n / workers < 2 {
        return Err(format!("worker count {workers} invalid for n={n}"));
    }
    let chunk = n / workers;
    // Generation-stamped ownership: owner_gen[i] == current generation
    // means index i was already written this interval, by owner[i].
    let mut owner_gen = vec![0u32; n];
    let mut owner = vec![0u32; n];
    let mut stats = ScheduleStats { intervals: 0, writes: 0, quads: 0 };
    for (iv, ops) in intervals.iter().enumerate() {
        stats.intervals += 1;
        let gen = stats.intervals as u32;
        let mut mark = |i: usize, t: usize| -> Result<(), String> {
            if owner_gen[i] == gen && owner[i] != t as u32 {
                return Err(format!(
                    "interval #{iv}: index {i} written by workers {} and {t}",
                    owner[i]
                ));
            }
            owner_gen[i] = gen;
            owner[i] = t as u32;
            Ok(())
        };
        for op in ops {
            for t in 0..workers {
                let (lo, hi) = (t * chunk, (t + 1) * chunk);
                match *op {
                    IntervalOp::LocalTail { stride_hi, .. } => {
                        // Closure: every pair (a, a^j) with j <= stride_hi
                        // < chunk stays inside the aligned chunk.
                        if stride_hi >= chunk {
                            return Err(format!(
                                "interval #{iv}: local tail stride {stride_hi} escapes chunk {chunk}"
                            ));
                        }
                        for a in lo..hi {
                            mark(a, t)?;
                            stats.writes += 1;
                        }
                    }
                    IntervalOp::GlobalLows { phase_len: _, stride } => {
                        if !stride.is_power_of_two() || stride == 0 {
                            return Err(format!(
                                "interval #{iv}: global stride {stride} is not a power of two"
                            ));
                        }
                        for a in lo..hi {
                            if a & stride == 0 {
                                let p = a ^ stride;
                                if p >= n {
                                    return Err(format!(
                                        "interval #{iv}: pair ({a}, {p}) escapes the row"
                                    ));
                                }
                                mark(a, t)?;
                                mark(p, t)?;
                                stats.writes += 2;
                            }
                        }
                    }
                    IntervalOp::PairedGlobal { phase_len, stride_hi } => {
                        if !stride_hi.is_power_of_two() || stride_hi < 2 {
                            return Err(format!(
                                "interval #{iv}: paired stride {stride_hi} is not a power of two >= 2"
                            ));
                        }
                        let j_lo = stride_hi / 2;
                        let quad_bits = stride_hi | j_lo;
                        for a in lo..hi {
                            if a & quad_bits == 0 {
                                let d = a + stride_hi + j_lo;
                                if d >= n {
                                    return Err(format!(
                                        "interval #{iv}: quad at {a} escapes the row (max index {d})"
                                    ));
                                }
                                if d & phase_len != a & phase_len {
                                    return Err(format!(
                                        "interval #{iv}: quad at {a} spans a direction boundary (phase {phase_len})"
                                    ));
                                }
                                for i in [a, a + j_lo, a + stride_hi, d] {
                                    mark(i, t)?;
                                }
                                stats.writes += 4;
                                stats.quads += 1;
                            }
                        }
                    }
                }
            }
        }
        // Coverage: every canonical op touches the whole index space; a
        // skipped index means the interval did less work than the step
        // semantics require.
        if let Some(i) = owner_gen.iter().position(|&g| g != gen) {
            return Err(format!("interval #{iv}: index {i} written by no worker"));
        }
    }
    Ok(stats)
}

/// Check the **canonical** chunked schedule for `(n, workers)`: interval
/// steps must reproduce [`Network::step_schedule`] exactly, then every
/// interval must partition the index space across workers
/// ([`check_intervals`]).
pub fn check_parallel_schedule(n: usize, workers: usize) -> Result<ScheduleStats, String> {
    if !n.is_power_of_two() || n < 4 {
        return Err(format!("row length {n} is not a power of two >= 4"));
    }
    let chunk = n / workers;
    if !workers.is_power_of_two() || workers < 2 || chunk < 2 {
        return Err(format!("worker count {workers} invalid for n={n}"));
    }
    let intervals = barrier_intervals(n, chunk);
    let flat: Vec<Step> = intervals.iter().flat_map(|op| op.steps()).collect();
    if flat != Network::new(n).step_schedule() {
        return Err("interval expansion deviates from step_schedule()".into());
    }
    let grouped: Vec<Vec<IntervalOp>> = intervals.into_iter().map(|op| vec![op]).collect();
    check_intervals(n, workers, &grouped)
}

/// Report-producing wrapper for one `(n, threads)` request — the
/// `analyze` hook of `sort::bitonic_parallel` and the orchestrator's
/// pass-2a entry.
pub fn analyze_parallel_schedule(n: usize, threads: usize) -> Report {
    let mut report = Report::new();
    let workers = effective_workers(n, threads);
    let target = format!("parallel sort n={n} threads={threads} (workers={workers})");
    if workers <= 1 {
        report.push(
            "disjoint.schedule",
            target,
            Verdict::Pass,
            "serial fallback engages; no shared-slice concurrency",
        );
        return report;
    }
    match check_parallel_schedule(n, workers) {
        Ok(stats) => report.push(
            "disjoint.schedule",
            target,
            Verdict::Pass,
            format!(
                "{} barrier intervals == step_schedule(); {} writes each owned by exactly one worker ({} register quads verified)",
                stats.intervals, stats.writes, stats.quads
            ),
        ),
        Err(e) => report.push("disjoint.schedule", target, Verdict::Fail, e),
    }
    report
}

/// Evidence from a clean tile-dispatch check.
#[derive(Clone, Copy, Debug)]
pub struct TileStats {
    /// Pool jobs the buffer splits into.
    pub jobs: usize,
    /// Tiles across all jobs (last one possibly ragged).
    pub tiles: usize,
    /// Effective interleave width.
    pub r: usize,
    /// Whether the pooled path engages.
    pub pooled: bool,
}

/// Replay the exact job/tile partition [`dispatch_geometry`] hands to
/// `execute_batch` for a `(b, n)` batch at configured interleave `want`
/// on `threads` workers, and verify it partitions the row space:
/// row-aligned boundaries, exact single coverage, tile width `<= r`
/// rows (ragged tail included), and enough tiles to feed the pool when
/// the pooled path engages.
pub fn check_tile_dispatch(b: usize, n: usize, want: usize, threads: usize) -> Result<TileStats, String> {
    let geo = dispatch_geometry(want, n, b, threads);
    let n = n.max(1);
    if geo.r < 1 || geo.r > b.max(1) {
        return Err(format!("effective interleave {} outside [1, {b}]", geo.r));
    }
    if geo.tile_len != geo.r * n {
        return Err(format!("tile_len {} != r*n = {}", geo.tile_len, geo.r * n));
    }
    // Interior job boundaries must be row-aligned; the pooled partition
    // additionally hands whole tiles to each job (the unpooled path is a
    // single job spanning the buffer, so its length is just `b * n`).
    if geo.job_len == 0 || geo.job_len % n != 0 {
        return Err(format!(
            "job_len {} is not a positive multiple of the row length {n}",
            geo.job_len
        ));
    }
    if geo.pooled && geo.job_len % geo.tile_len != 0 {
        return Err(format!(
            "pooled job_len {} is not a multiple of tile_len {}",
            geo.job_len, geo.tile_len
        ));
    }
    let total = b * n;
    let mut stats = TileStats { jobs: 0, tiles: 0, r: geo.r, pooled: geo.pooled };
    let mut covered = 0usize;
    let mut start = 0usize;
    while start < total {
        // `chunks_mut(job_len)`: consecutive, last one ragged.
        let end = (start + geo.job_len).min(total);
        stats.jobs += 1;
        if start % n != 0 {
            return Err(format!("job boundary {start} splits a row (n={n})"));
        }
        let mut ts = start;
        while ts < end {
            let te = (ts + geo.tile_len).min(end);
            stats.tiles += 1;
            let len = te - ts;
            if len % n != 0 {
                return Err(format!("tile [{ts}, {te}) splits a row (n={n})"));
            }
            let rows = len / n;
            if rows == 0 || rows > geo.r {
                return Err(format!("tile [{ts}, {te}) holds {rows} rows, want 1..={}", geo.r));
            }
            covered += len;
            ts = te;
        }
        start = end;
    }
    if covered != total {
        return Err(format!("tiles cover {covered} of {total} elements"));
    }
    if geo.pooled && stats.tiles < threads.min(b) {
        return Err(format!(
            "pooled dispatch yields {} tiles for {threads} workers",
            stats.tiles
        ));
    }
    Ok(stats)
}

/// Sweep the tile-dispatch check over a geometry grid: every batch size
/// in `batches` (the orchestrator passes 1..=64 plus the manifest's own
/// batches) × interleave requests × worker counts × a small/large row
/// split (either side of the pooled cutover). Findings are aggregated
/// per `(want, threads)` so the report stays readable.
pub fn analyze_tile_dispatch(batches: &[usize]) -> Report {
    let mut report = Report::new();
    let ns = [32usize, 256];
    for &want in &[1usize, 3, 4, 8, 16] {
        for &threads in &[1usize, 2, 4, 8] {
            let target = format!("tile dispatch want={want} threads={threads}");
            let mut checked = 0usize;
            let mut ragged = 0usize;
            let mut failure: Option<String> = None;
            'grid: for &b in batches {
                for &n in &ns {
                    match check_tile_dispatch(b, n, want, threads) {
                        Ok(stats) => {
                            checked += 1;
                            if b % stats.r != 0 {
                                ragged += 1;
                            }
                        }
                        Err(e) => {
                            failure = Some(format!("b={b} n={n}: {e}"));
                            break 'grid;
                        }
                    }
                }
            }
            match failure {
                None => report.push(
                    "disjoint.tiles",
                    target,
                    Verdict::Pass,
                    format!(
                        "{checked} geometries partition the row space exactly once ({ragged} with ragged tails)"
                    ),
                ),
                Some(e) => report.push("disjoint.tiles", target, Verdict::Fail, e),
            }
        }
    }
    report
}

/// Evidence from a clean bucket-partition check.
#[derive(Clone, Copy, Debug)]
pub struct BucketStats {
    /// Buckets in the plan.
    pub parts: usize,
    /// Input runs.
    pub runs: usize,
    /// Output elements covered (== the summed run lengths).
    pub total: usize,
    /// Largest bucket (verified `<=` [`balance_bound`]).
    pub largest_bucket: usize,
}

/// Verify an arbitrary [`MergePlan`] against the runs it claims to
/// partition. Everything is checked arithmetic — the mutation suite
/// feeds corrupted cut matrices and expects findings, not panics:
///
/// 1. shape: one cut row per bucket boundary (>= 2), one column per run;
/// 2. frame: row 0 is all zeros, the last row is the run lengths;
/// 3. monotone: cut columns never decrease (and never exceed the run);
/// 4. coverage: marking every `(run, index)` each bucket's slices claim
///    touches every element exactly once, and the bucket sizes prefix-sum
///    to the total — so the output carving in `pmerge` tiles the output;
/// 5. order: all ranks in bucket `b` precede all ranks in bucket `b+1`
///    under the `(key, run, index)` total order — so concatenating the
///    per-bucket merges yields the same sequence one global loser tree
///    would (ties are bit-identical, hence bit-exactness);
/// 6. balance: the largest bucket stays within the distribution-free
///    [`balance_bound`] — dup-heavy keys cannot collapse the partition.
pub fn check_bucket_plan(runs: &[&[u32]], plan: &MergePlan) -> Result<BucketStats, String> {
    let k = runs.len();
    if plan.cuts.len() < 2 {
        return Err(format!("plan has {} cut rows, want >= 2", plan.cuts.len()));
    }
    let parts = plan.cuts.len() - 1;
    for (b, row) in plan.cuts.iter().enumerate() {
        if row.len() != k {
            return Err(format!("cut row {b} has {} columns for {k} runs", row.len()));
        }
    }
    if let Some(r) = plan.cuts[0].iter().position(|&c| c != 0) {
        return Err(format!("cut row 0 is {} at run {r}, want 0", plan.cuts[0][r]));
    }
    for (r, run) in runs.iter().enumerate() {
        let last = plan.cuts[parts][r];
        if last != run.len() {
            return Err(format!(
                "final cut row ends run {r} at {last}, want its length {}",
                run.len()
            ));
        }
    }
    for b in 0..parts {
        for r in 0..k {
            let (lo, hi) = (plan.cuts[b][r], plan.cuts[b + 1][r]);
            if lo > hi {
                return Err(format!("cuts for run {r} decrease across bucket {b}: {lo} > {hi}"));
            }
            if hi > runs[r].len() {
                return Err(format!(
                    "cut {hi} for run {r} exceeds its length {} (bucket {b})",
                    runs[r].len()
                ));
            }
        }
    }
    // Coverage: mark each (run, index) once; checked sums for the
    // output carving.
    let total: usize = runs
        .iter()
        .try_fold(0usize, |acc, r| acc.checked_add(r.len()))
        .ok_or_else(|| "run lengths overflow usize".to_string())?;
    let mut owned: Vec<Vec<bool>> = runs.iter().map(|r| vec![false; r.len()]).collect();
    let mut covered = 0usize;
    let mut largest = 0usize;
    for b in 0..parts {
        let mut size = 0usize;
        for r in 0..k {
            for i in plan.cuts[b][r]..plan.cuts[b + 1][r] {
                if owned[r][i] {
                    return Err(format!("run {r} index {i} claimed by two buckets"));
                }
                owned[r][i] = true;
            }
            size = size
                .checked_add(plan.cuts[b + 1][r] - plan.cuts[b][r])
                .ok_or_else(|| format!("bucket {b} size overflows usize"))?;
        }
        covered = covered
            .checked_add(size)
            .ok_or_else(|| "covered total overflows usize".to_string())?;
        largest = largest.max(size);
    }
    if covered != total {
        return Err(format!("buckets cover {covered} of {total} elements"));
    }
    // Order: the maximum (key, run, index) rank of bucket b must precede
    // the minimum rank of bucket b+1 (ranks are distinct by (run, index)).
    let mut prev_max: Option<(u32, usize, usize)> = None;
    for b in 0..parts {
        let mut lo_rank: Option<(u32, usize, usize)> = None;
        let mut hi_rank: Option<(u32, usize, usize)> = None;
        for r in 0..k {
            let (lo, hi) = (plan.cuts[b][r], plan.cuts[b + 1][r]);
            if lo < hi {
                // Runs are sorted, so per run the extreme ranks sit at
                // the slice ends.
                let first = (runs[r][lo], r, lo);
                let last = (runs[r][hi - 1], r, hi - 1);
                if lo_rank.is_none_or(|m| first < m) {
                    lo_rank = Some(first);
                }
                if hi_rank.is_none_or(|m| last > m) {
                    hi_rank = Some(last);
                }
            }
        }
        if let (Some(pm), Some(lo)) = (prev_max, lo_rank) {
            if pm >= lo {
                return Err(format!(
                    "bucket {b} starts at rank {lo:?} but an earlier bucket reaches {pm:?}"
                ));
            }
        }
        if hi_rank.is_some() {
            prev_max = hi_rank;
        }
    }
    // Balance: the provable distribution-free bound.
    let lens: Vec<usize> = runs.iter().map(|r| r.len()).collect();
    let bound = balance_bound(&lens, parts);
    if largest > bound {
        return Err(format!(
            "largest bucket holds {largest} elements, above the provable bound {bound}"
        ));
    }
    Ok(BucketStats { parts, runs: k, total, largest_bucket: largest })
}

/// Plan-then-check for the **canonical** partition: run
/// [`plan_partition`] (the geometry `pmerge` dispatches from) over the
/// runs and verify the result with [`check_bucket_plan`].
pub fn check_bucket_partition(runs: &[&[u32]], parts: usize) -> Result<BucketStats, String> {
    let plan = plan_partition(runs, parts);
    check_bucket_plan(runs, &plan)
}

/// Sweep the bucket-partition check over a deterministic scenario grid:
/// key shapes that stress each hazard (uniform, dup-heavy, all-equal,
/// MAX-padded tails, an empty run) × fan-ins × bucket counts. Findings
/// are aggregated per scenario so the report stays readable.
pub fn analyze_bucket_partition() -> Report {
    use crate::workload::rng::Pcg32;
    let mut report = Report::new();
    let scenarios: [(&str, fn(usize, usize, u64) -> Vec<Vec<u32>>); 5] = [
        ("uniform", |k, len, seed| {
            let mut rng = Pcg32::new(0x0DD5_EED5, seed);
            (0..k)
                .map(|i| {
                    let mut run: Vec<u32> =
                        (0..len + (i % 3)).map(|_| rng.next_u32()).collect();
                    run.sort_unstable();
                    run
                })
                .collect()
        }),
        ("dup-heavy", |k, len, seed| {
            let mut rng = Pcg32::new(0xD00B_5EED, seed);
            (0..k)
                .map(|_| {
                    let mut run: Vec<u32> =
                        (0..len).map(|_| rng.next_u32() % 4).collect();
                    run.sort_unstable();
                    run
                })
                .collect()
        }),
        ("all-equal", |k, len, _| (0..k).map(|_| vec![42u32; len]).collect()),
        ("max-padded", |k, len, seed| {
            let mut rng = Pcg32::new(0x9AD5_EED5, seed);
            (0..k)
                .map(|_| {
                    let real = len / 2;
                    let mut run: Vec<u32> =
                        (0..real).map(|_| rng.next_u32() >> 1).collect();
                    run.sort_unstable();
                    run.resize(len, u32::MAX);
                    run
                })
                .collect()
        }),
        ("empty-run", |k, len, seed| {
            let mut rng = Pcg32::new(0xE4B7_5EED, seed);
            (0..k)
                .map(|i| {
                    if i == 0 {
                        return Vec::new();
                    }
                    let mut run: Vec<u32> = (0..len).map(|_| rng.next_u32()).collect();
                    run.sort_unstable();
                    run
                })
                .collect()
        }),
    ];
    for (name, make) in scenarios {
        let target = format!("bucket partition dist={name}");
        let mut checked = 0usize;
        let mut worst_fill = 0.0f64;
        let mut failure: Option<String> = None;
        'grid: for &k in &[2usize, 3, 8, 16] {
            for &parts in &[2usize, 4, 8] {
                let runs = make(k, 96, (k * 31 + parts) as u64);
                let views: Vec<&[u32]> = runs.iter().map(|r| r.as_slice()).collect();
                match check_bucket_partition(&views, parts) {
                    Ok(stats) => {
                        checked += 1;
                        if stats.total > 0 {
                            let bound =
                                balance_bound(&views.iter().map(|r| r.len()).collect::<Vec<_>>(), parts);
                            worst_fill =
                                worst_fill.max(stats.largest_bucket as f64 / bound as f64);
                        }
                    }
                    Err(e) => {
                        failure = Some(format!("k={k} parts={parts}: {e}"));
                        break 'grid;
                    }
                }
            }
        }
        match failure {
            None => report.push(
                "disjoint.buckets",
                target,
                Verdict::Pass,
                format!(
                    "{checked} plans cover the output exactly once, rank-ordered, \
                     largest bucket at {:.0}% of the provable bound",
                    worst_fill * 100.0
                ),
            ),
            Some(e) => report.push("disjoint.buckets", target, Verdict::Fail, e),
        }
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn canonical_schedules_are_disjoint() {
        for n in [4096usize, 8192, 65536] {
            for workers in [2usize, 4, 8, 32] {
                let stats = check_parallel_schedule(n, workers)
                    .unwrap_or_else(|e| panic!("n={n} workers={workers}: {e}"));
                assert!(stats.intervals > 0 && stats.writes >= (n as u64));
                // Pairing engages whenever two global strides exist.
                if n >= 4 * (n / workers) {
                    assert!(stats.quads > 0, "n={n} workers={workers}");
                }
            }
        }
    }

    #[test]
    fn small_n_below_cutover_still_checkable() {
        // The checker covers geometries the runtime would refuse (serial
        // fallback) — more coverage, same invariant.
        assert!(check_parallel_schedule(16, 4).is_ok());
        assert!(check_parallel_schedule(64, 2).is_ok());
    }

    #[test]
    fn racy_interval_is_detected() {
        // Two unpaired global strides in ONE barrier interval: worker 0's
        // stride-j partner writes collide with worker owning those lows
        // at stride j/2 — the race quad pairing exists to prevent.
        let (n, workers) = (16usize, 4usize);
        let racy = vec![vec![
            IntervalOp::GlobalLows { phase_len: 16, stride: 8 },
            IntervalOp::GlobalLows { phase_len: 16, stride: 4 },
        ]];
        let err = check_intervals(n, workers, &racy).unwrap_err();
        assert!(err.contains("workers"), "{err}");
    }

    #[test]
    fn escaping_local_tail_is_detected() {
        let bad = vec![vec![IntervalOp::LocalTail { phase_len: 8, stride_hi: 8 }]];
        let err = check_intervals(32, 4, &bad).unwrap_err();
        assert!(err.contains("escapes"), "{err}");
    }

    #[test]
    fn out_of_range_quad_is_detected() {
        // A paired stride too large for the row: the quad's max index
        // escapes.
        let bad = vec![vec![IntervalOp::PairedGlobal { phase_len: 32, stride_hi: 16 }]];
        let err = check_intervals(16, 4, &bad).unwrap_err();
        assert!(err.contains("escapes"), "{err}");
    }

    #[test]
    fn direction_splitting_quad_is_detected() {
        // 2 * stride_hi > phase_len: the quad spans bit `phase_len`.
        let bad = vec![vec![IntervalOp::PairedGlobal { phase_len: 4, stride_hi: 4 }]];
        let err = check_intervals(16, 2, &bad).unwrap_err();
        assert!(err.contains("direction"), "{err}");
    }

    #[test]
    fn tile_dispatch_grid_is_disjoint() {
        let batches: Vec<usize> = (1..=64).collect();
        let report = analyze_tile_dispatch(&batches);
        assert!(!report.has_fail(), "{}", report.render_markdown());
        // Ragged tails were actually exercised.
        assert!(report
            .findings
            .iter()
            .any(|f| f.detail.contains("ragged") && !f.detail.contains("(0 with")));
    }

    #[test]
    fn tile_dispatch_matches_execute_batch_row_count() {
        // Spot-check the emulated tile count against first principles.
        let stats = check_tile_dispatch(13, 256, 4, 4).unwrap();
        assert!(stats.pooled);
        assert_eq!(stats.r, 3); // capped at b/threads = 3
        assert_eq!(stats.tiles, 5); // ceil(13/3)
    }

    fn sorted_runs(k: usize, len: usize, modulo: u32) -> Vec<Vec<u32>> {
        use crate::workload::rng::Pcg32;
        let mut rng = Pcg32::new(0xB0CC_E77E, 7);
        (0..k)
            .map(|_| {
                let mut run: Vec<u32> = (0..len).map(|_| rng.next_u32() % modulo).collect();
                run.sort_unstable();
                run
            })
            .collect()
    }

    #[test]
    fn bucket_partition_grid_is_clean() {
        let report = analyze_bucket_partition();
        assert!(!report.has_fail(), "{}", report.render_markdown());
        assert!(report.findings.iter().any(|f| f.target.contains("dup-heavy")));
    }

    #[test]
    fn honest_bucket_plan_passes() {
        let runs = sorted_runs(4, 64, u32::MAX);
        let views: Vec<&[u32]> = runs.iter().map(|r| r.as_slice()).collect();
        let stats = check_bucket_partition(&views, 4).unwrap();
        assert_eq!(stats.parts, 4);
        assert_eq!(stats.total, 4 * 64);
        assert!(stats.largest_bucket >= 64); // pigeonhole: total / parts
    }

    #[test]
    fn corrupted_bucket_plans_are_findings_not_panics() {
        let runs = sorted_runs(3, 32, 64);
        let views: Vec<&[u32]> = runs.iter().map(|r| r.as_slice()).collect();
        let honest = plan_partition(&views, 4);
        assert!(check_bucket_plan(&views, &honest).is_ok());

        // Non-monotone columns: a row that retreats to zero after a row
        // at the run lengths must be caught before any size arithmetic.
        let mut retreat = honest.clone();
        retreat.cuts[1] = views.iter().map(|r| r.len()).collect();
        retreat.cuts[2] = vec![0; views.len()];
        let e = check_bucket_plan(&views, &retreat).unwrap_err();
        assert!(e.contains("decrease"), "{e}");

        // Wrong final row: the plan stops short of a run's length.
        let mut short = honest.clone();
        let parts = short.cuts.len() - 1;
        short.cuts[parts][0] -= 1;
        let e = check_bucket_plan(&views, &short).unwrap_err();
        assert!(e.contains("final cut row"), "{e}");

        // Out-of-bounds cut.
        let mut oob = honest.clone();
        oob.cuts[1][0] = 33;
        let e = check_bucket_plan(&views, &oob).unwrap_err();
        assert!(e.contains("exceeds") || e.contains("decrease"), "{e}");

        // Non-zero row 0.
        let mut nz = honest.clone();
        nz.cuts[0][2] = 1;
        let e = check_bucket_plan(&views, &nz).unwrap_err();
        assert!(e.contains("row 0"), "{e}");

        // Ragged row shape.
        let mut ragged = honest;
        ragged.cuts[1].pop();
        let e = check_bucket_plan(&views, &ragged).unwrap_err();
        assert!(e.contains("columns"), "{e}");
    }

    #[test]
    fn bucket_rank_order_violation_is_detected() {
        // A monotone, fully-covering plan that still merges wrong:
        // bucket 0 takes all of run 0, bucket 1 all of run 1 — run 1's
        // low keys sort *before* run 0's high keys, so concatenating the
        // bucket merges is not sorted.
        let a: Vec<u32> = vec![0, 1, 2, 3];
        let b: Vec<u32> = vec![0, 1, 2, 3];
        let views: Vec<&[u32]> = vec![&a, &b];
        let plan = MergePlan { cuts: vec![vec![0, 0], vec![4, 0], vec![4, 4]] };
        let e = check_bucket_plan(&views, &plan).unwrap_err();
        assert!(e.contains("earlier bucket reaches"), "{e}");
    }

    #[test]
    fn bucket_balance_violation_is_detected() {
        // Monotone, covering, rank-ordered (one non-empty bucket) — but
        // everything lands in bucket 0, far above the provable bound.
        let a: Vec<u32> = (0..64).collect();
        let b: Vec<u32> = (64..128).collect();
        let views: Vec<&[u32]> = vec![&a, &b];
        let all = vec![64usize, 64];
        let plan = MergePlan {
            cuts: vec![vec![0, 0], all.clone(), all.clone(), all.clone(), all],
        };
        assert!(128 > balance_bound(&[64, 64], 4), "bound should bite here");
        let e = check_bucket_plan(&views, &plan).unwrap_err();
        assert!(e.contains("provable bound"), "{e}");
    }
}
