//! Pass 2: the concurrency-disjointness checker — prove parallel
//! schedules never write one index from two workers.
//!
//! Two symbolic emulations, both consuming the **same geometry code the
//! runtime dispatches from** (no parallel re-derivation that could
//! drift):
//!
//! * **Chunked barrier schedule** ([`check_parallel_schedule`]): walks
//!   [`barrier_intervals`] — the exact interval list
//!   [`crate::sort::bitonic_parallel::bitonic_sort_parallel`]'s workers
//!   execute — and, per interval, marks every index each worker writes
//!   (local fused tails, low-owned global pairs, minimum-owned register
//!   quads). Every index must be written by **exactly one** worker per
//!   barrier interval, quads must stay in range with a uniform
//!   direction bit, and the concatenated interval steps must equal the
//!   canonical [`Network::step_schedule`]. This is the proof the
//!   `SAFETY` comments in `sort/bitonic_parallel.rs` cite.
//! * **Interleaved tile dispatch** ([`check_tile_dispatch`]): replays
//!   [`dispatch_geometry`] — the partition `execute_batch` cuts a
//!   `(B, N)` buffer into — and verifies jobs and tiles are row-aligned,
//!   cover the buffer exactly once, never exceed the effective
//!   interleave width (ragged tails included), and yield enough tiles
//!   to feed the pool whenever the pooled path engages.
//!
//! [`check_intervals`] takes an arbitrary interval list, so the mutation
//! suite can feed it *racy* schedules (e.g. two unpaired global strides
//! in one barrier interval) and assert the race is detected.

use super::{Report, Verdict};
use crate::sort::bitonic_parallel::{barrier_intervals, effective_workers, IntervalOp};
use crate::sort::network::{Network, Step};
use crate::runtime::executor::dispatch_geometry;

/// Evidence from a clean schedule check.
#[derive(Clone, Copy, Debug)]
pub struct ScheduleStats {
    /// Barrier intervals emulated.
    pub intervals: usize,
    /// Total index writes marked (each verified singly-owned).
    pub writes: u64,
    /// Register quads verified (range + minimum ownership + uniform
    /// direction).
    pub quads: u64,
}

/// Emulate an arbitrary barrier-interval schedule for `workers` equal
/// chunks of `n` and verify write-disjointness. Each inner `Vec` is one
/// barrier interval (the canonical schedule has one op per interval;
/// mutants may pack several). Returns the first violation as `Err`.
pub fn check_intervals(
    n: usize,
    workers: usize,
    intervals: &[Vec<IntervalOp>],
) -> Result<ScheduleStats, String> {
    if !n.is_power_of_two() || n < 4 {
        return Err(format!("row length {n} is not a power of two >= 4"));
    }
    if !workers.is_power_of_two() || workers < 2 || n / workers < 2 {
        return Err(format!("worker count {workers} invalid for n={n}"));
    }
    let chunk = n / workers;
    // Generation-stamped ownership: owner_gen[i] == current generation
    // means index i was already written this interval, by owner[i].
    let mut owner_gen = vec![0u32; n];
    let mut owner = vec![0u32; n];
    let mut stats = ScheduleStats { intervals: 0, writes: 0, quads: 0 };
    for (iv, ops) in intervals.iter().enumerate() {
        stats.intervals += 1;
        let gen = stats.intervals as u32;
        let mut mark = |i: usize, t: usize| -> Result<(), String> {
            if owner_gen[i] == gen && owner[i] != t as u32 {
                return Err(format!(
                    "interval #{iv}: index {i} written by workers {} and {t}",
                    owner[i]
                ));
            }
            owner_gen[i] = gen;
            owner[i] = t as u32;
            Ok(())
        };
        for op in ops {
            for t in 0..workers {
                let (lo, hi) = (t * chunk, (t + 1) * chunk);
                match *op {
                    IntervalOp::LocalTail { stride_hi, .. } => {
                        // Closure: every pair (a, a^j) with j <= stride_hi
                        // < chunk stays inside the aligned chunk.
                        if stride_hi >= chunk {
                            return Err(format!(
                                "interval #{iv}: local tail stride {stride_hi} escapes chunk {chunk}"
                            ));
                        }
                        for a in lo..hi {
                            mark(a, t)?;
                            stats.writes += 1;
                        }
                    }
                    IntervalOp::GlobalLows { phase_len: _, stride } => {
                        if !stride.is_power_of_two() || stride == 0 {
                            return Err(format!(
                                "interval #{iv}: global stride {stride} is not a power of two"
                            ));
                        }
                        for a in lo..hi {
                            if a & stride == 0 {
                                let p = a ^ stride;
                                if p >= n {
                                    return Err(format!(
                                        "interval #{iv}: pair ({a}, {p}) escapes the row"
                                    ));
                                }
                                mark(a, t)?;
                                mark(p, t)?;
                                stats.writes += 2;
                            }
                        }
                    }
                    IntervalOp::PairedGlobal { phase_len, stride_hi } => {
                        if !stride_hi.is_power_of_two() || stride_hi < 2 {
                            return Err(format!(
                                "interval #{iv}: paired stride {stride_hi} is not a power of two >= 2"
                            ));
                        }
                        let j_lo = stride_hi / 2;
                        let quad_bits = stride_hi | j_lo;
                        for a in lo..hi {
                            if a & quad_bits == 0 {
                                let d = a + stride_hi + j_lo;
                                if d >= n {
                                    return Err(format!(
                                        "interval #{iv}: quad at {a} escapes the row (max index {d})"
                                    ));
                                }
                                if d & phase_len != a & phase_len {
                                    return Err(format!(
                                        "interval #{iv}: quad at {a} spans a direction boundary (phase {phase_len})"
                                    ));
                                }
                                for i in [a, a + j_lo, a + stride_hi, d] {
                                    mark(i, t)?;
                                }
                                stats.writes += 4;
                                stats.quads += 1;
                            }
                        }
                    }
                }
            }
        }
        // Coverage: every canonical op touches the whole index space; a
        // skipped index means the interval did less work than the step
        // semantics require.
        if let Some(i) = owner_gen.iter().position(|&g| g != gen) {
            return Err(format!("interval #{iv}: index {i} written by no worker"));
        }
    }
    Ok(stats)
}

/// Check the **canonical** chunked schedule for `(n, workers)`: interval
/// steps must reproduce [`Network::step_schedule`] exactly, then every
/// interval must partition the index space across workers
/// ([`check_intervals`]).
pub fn check_parallel_schedule(n: usize, workers: usize) -> Result<ScheduleStats, String> {
    if !n.is_power_of_two() || n < 4 {
        return Err(format!("row length {n} is not a power of two >= 4"));
    }
    let chunk = n / workers;
    if !workers.is_power_of_two() || workers < 2 || chunk < 2 {
        return Err(format!("worker count {workers} invalid for n={n}"));
    }
    let intervals = barrier_intervals(n, chunk);
    let flat: Vec<Step> = intervals.iter().flat_map(|op| op.steps()).collect();
    if flat != Network::new(n).step_schedule() {
        return Err("interval expansion deviates from step_schedule()".into());
    }
    let grouped: Vec<Vec<IntervalOp>> = intervals.into_iter().map(|op| vec![op]).collect();
    check_intervals(n, workers, &grouped)
}

/// Report-producing wrapper for one `(n, threads)` request — the
/// `analyze` hook of `sort::bitonic_parallel` and the orchestrator's
/// pass-2a entry.
pub fn analyze_parallel_schedule(n: usize, threads: usize) -> Report {
    let mut report = Report::new();
    let workers = effective_workers(n, threads);
    let target = format!("parallel sort n={n} threads={threads} (workers={workers})");
    if workers <= 1 {
        report.push(
            "disjoint.schedule",
            target,
            Verdict::Pass,
            "serial fallback engages; no shared-slice concurrency",
        );
        return report;
    }
    match check_parallel_schedule(n, workers) {
        Ok(stats) => report.push(
            "disjoint.schedule",
            target,
            Verdict::Pass,
            format!(
                "{} barrier intervals == step_schedule(); {} writes each owned by exactly one worker ({} register quads verified)",
                stats.intervals, stats.writes, stats.quads
            ),
        ),
        Err(e) => report.push("disjoint.schedule", target, Verdict::Fail, e),
    }
    report
}

/// Evidence from a clean tile-dispatch check.
#[derive(Clone, Copy, Debug)]
pub struct TileStats {
    /// Pool jobs the buffer splits into.
    pub jobs: usize,
    /// Tiles across all jobs (last one possibly ragged).
    pub tiles: usize,
    /// Effective interleave width.
    pub r: usize,
    /// Whether the pooled path engages.
    pub pooled: bool,
}

/// Replay the exact job/tile partition [`dispatch_geometry`] hands to
/// `execute_batch` for a `(b, n)` batch at configured interleave `want`
/// on `threads` workers, and verify it partitions the row space:
/// row-aligned boundaries, exact single coverage, tile width `<= r`
/// rows (ragged tail included), and enough tiles to feed the pool when
/// the pooled path engages.
pub fn check_tile_dispatch(b: usize, n: usize, want: usize, threads: usize) -> Result<TileStats, String> {
    let geo = dispatch_geometry(want, n, b, threads);
    let n = n.max(1);
    if geo.r < 1 || geo.r > b.max(1) {
        return Err(format!("effective interleave {} outside [1, {b}]", geo.r));
    }
    if geo.tile_len != geo.r * n {
        return Err(format!("tile_len {} != r*n = {}", geo.tile_len, geo.r * n));
    }
    // Interior job boundaries must be row-aligned; the pooled partition
    // additionally hands whole tiles to each job (the unpooled path is a
    // single job spanning the buffer, so its length is just `b * n`).
    if geo.job_len == 0 || geo.job_len % n != 0 {
        return Err(format!(
            "job_len {} is not a positive multiple of the row length {n}",
            geo.job_len
        ));
    }
    if geo.pooled && geo.job_len % geo.tile_len != 0 {
        return Err(format!(
            "pooled job_len {} is not a multiple of tile_len {}",
            geo.job_len, geo.tile_len
        ));
    }
    let total = b * n;
    let mut stats = TileStats { jobs: 0, tiles: 0, r: geo.r, pooled: geo.pooled };
    let mut covered = 0usize;
    let mut start = 0usize;
    while start < total {
        // `chunks_mut(job_len)`: consecutive, last one ragged.
        let end = (start + geo.job_len).min(total);
        stats.jobs += 1;
        if start % n != 0 {
            return Err(format!("job boundary {start} splits a row (n={n})"));
        }
        let mut ts = start;
        while ts < end {
            let te = (ts + geo.tile_len).min(end);
            stats.tiles += 1;
            let len = te - ts;
            if len % n != 0 {
                return Err(format!("tile [{ts}, {te}) splits a row (n={n})"));
            }
            let rows = len / n;
            if rows == 0 || rows > geo.r {
                return Err(format!("tile [{ts}, {te}) holds {rows} rows, want 1..={}", geo.r));
            }
            covered += len;
            ts = te;
        }
        start = end;
    }
    if covered != total {
        return Err(format!("tiles cover {covered} of {total} elements"));
    }
    if geo.pooled && stats.tiles < threads.min(b) {
        return Err(format!(
            "pooled dispatch yields {} tiles for {threads} workers",
            stats.tiles
        ));
    }
    Ok(stats)
}

/// Sweep the tile-dispatch check over a geometry grid: every batch size
/// in `batches` (the orchestrator passes 1..=64 plus the manifest's own
/// batches) × interleave requests × worker counts × a small/large row
/// split (either side of the pooled cutover). Findings are aggregated
/// per `(want, threads)` so the report stays readable.
pub fn analyze_tile_dispatch(batches: &[usize]) -> Report {
    let mut report = Report::new();
    let ns = [32usize, 256];
    for &want in &[1usize, 3, 4, 8, 16] {
        for &threads in &[1usize, 2, 4, 8] {
            let target = format!("tile dispatch want={want} threads={threads}");
            let mut checked = 0usize;
            let mut ragged = 0usize;
            let mut failure: Option<String> = None;
            'grid: for &b in batches {
                for &n in &ns {
                    match check_tile_dispatch(b, n, want, threads) {
                        Ok(stats) => {
                            checked += 1;
                            if b % stats.r != 0 {
                                ragged += 1;
                            }
                        }
                        Err(e) => {
                            failure = Some(format!("b={b} n={n}: {e}"));
                            break 'grid;
                        }
                    }
                }
            }
            match failure {
                None => report.push(
                    "disjoint.tiles",
                    target,
                    Verdict::Pass,
                    format!(
                        "{checked} geometries partition the row space exactly once ({ragged} with ragged tails)"
                    ),
                ),
                Some(e) => report.push("disjoint.tiles", target, Verdict::Fail, e),
            }
        }
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn canonical_schedules_are_disjoint() {
        for n in [4096usize, 8192, 65536] {
            for workers in [2usize, 4, 8, 32] {
                let stats = check_parallel_schedule(n, workers)
                    .unwrap_or_else(|e| panic!("n={n} workers={workers}: {e}"));
                assert!(stats.intervals > 0 && stats.writes >= (n as u64));
                // Pairing engages whenever two global strides exist.
                if n >= 4 * (n / workers) {
                    assert!(stats.quads > 0, "n={n} workers={workers}");
                }
            }
        }
    }

    #[test]
    fn small_n_below_cutover_still_checkable() {
        // The checker covers geometries the runtime would refuse (serial
        // fallback) — more coverage, same invariant.
        assert!(check_parallel_schedule(16, 4).is_ok());
        assert!(check_parallel_schedule(64, 2).is_ok());
    }

    #[test]
    fn racy_interval_is_detected() {
        // Two unpaired global strides in ONE barrier interval: worker 0's
        // stride-j partner writes collide with worker owning those lows
        // at stride j/2 — the race quad pairing exists to prevent.
        let (n, workers) = (16usize, 4usize);
        let racy = vec![vec![
            IntervalOp::GlobalLows { phase_len: 16, stride: 8 },
            IntervalOp::GlobalLows { phase_len: 16, stride: 4 },
        ]];
        let err = check_intervals(n, workers, &racy).unwrap_err();
        assert!(err.contains("workers"), "{err}");
    }

    #[test]
    fn escaping_local_tail_is_detected() {
        let bad = vec![vec![IntervalOp::LocalTail { phase_len: 8, stride_hi: 8 }]];
        let err = check_intervals(32, 4, &bad).unwrap_err();
        assert!(err.contains("escapes"), "{err}");
    }

    #[test]
    fn out_of_range_quad_is_detected() {
        // A paired stride too large for the row: the quad's max index
        // escapes.
        let bad = vec![vec![IntervalOp::PairedGlobal { phase_len: 32, stride_hi: 16 }]];
        let err = check_intervals(16, 4, &bad).unwrap_err();
        assert!(err.contains("escapes"), "{err}");
    }

    #[test]
    fn direction_splitting_quad_is_detected() {
        // 2 * stride_hi > phase_len: the quad spans bit `phase_len`.
        let bad = vec![vec![IntervalOp::PairedGlobal { phase_len: 4, stride_hi: 4 }]];
        let err = check_intervals(16, 2, &bad).unwrap_err();
        assert!(err.contains("direction"), "{err}");
    }

    #[test]
    fn tile_dispatch_grid_is_disjoint() {
        let batches: Vec<usize> = (1..=64).collect();
        let report = analyze_tile_dispatch(&batches);
        assert!(!report.has_fail(), "{}", report.render_markdown());
        // Ragged tails were actually exercised.
        assert!(report
            .findings
            .iter()
            .any(|f| f.detail.contains("ragged") && !f.detail.contains("(0 with")));
    }

    #[test]
    fn tile_dispatch_matches_execute_batch_row_count() {
        // Spot-check the emulated tile count against first principles.
        let stats = check_tile_dispatch(13, 256, 4, 4).unwrap();
        assert!(stats.pooled);
        assert_eq!(stats.r, 3); // capped at b/threads = 3
        assert_eq!(stats.tiles, 5); // ceil(13/3)
    }
}
