//! Size-class routing: map a request of arbitrary length onto the
//! power-of-two row size of a compiled artifact.
//!
//! Padding uses `u32::MAX` for ascending (pads sink to the tail) and `0`
//! for descending — exactly mirroring what `bitonic_sort_padded` does on
//! the CPU path, so both paths agree bit-for-bit after truncation.

/// One available (row-size, batch-rows) execution shape.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SizeClass {
    /// Row length N (power of two).
    pub n: usize,
    /// Device batch rows B.
    pub batch: usize,
}

/// Routes requests to size classes.
#[derive(Clone, Debug)]
pub struct Router {
    /// Available classes, ascending by n. For one n, the largest batch is
    /// kept (the batcher decides how full a batch gets dispatched).
    classes: Vec<SizeClass>,
}

impl Router {
    /// Build from the artifact menu. Duplicate `n`s collapse to the
    /// largest batch.
    pub fn new(mut shapes: Vec<SizeClass>) -> Self {
        shapes.sort_by_key(|c| (c.n, c.batch));
        let mut classes: Vec<SizeClass> = Vec::new();
        for s in shapes {
            assert!(s.n.is_power_of_two() && s.batch >= 1, "bad class {s:?}");
            match classes.last_mut() {
                Some(last) if last.n == s.n => last.batch = s.batch,
                _ => classes.push(s),
            }
        }
        Self { classes }
    }

    /// All classes, ascending by `n`.
    pub fn classes(&self) -> &[SizeClass] {
        &self.classes
    }

    /// Index of the smallest class whose row fits `len` keys, or `None`
    /// if the request is larger than every class (CPU fallback).
    pub fn route(&self, len: usize) -> Option<usize> {
        if len == 0 {
            return None; // nothing to sort; answered inline
        }
        self.classes.iter().position(|c| c.n >= len)
    }

    /// Pad `keys` to the class row length. Ascending pads with `MAX`
    /// (sinks to tail), descending with `0`.
    pub fn pad_row(&self, class: usize, keys: &[u32], descending: bool, out: &mut Vec<u32>) {
        let n = self.classes[class].n;
        debug_assert!(keys.len() <= n);
        out.clear();
        out.reserve(n);
        out.extend_from_slice(keys);
        out.resize(n, if descending { 0 } else { u32::MAX });
    }

    /// Internal fragmentation of routing `len` keys: padded/real ratio.
    pub fn overhead(&self, class: usize, len: usize) -> f64 {
        self.classes[class].n as f64 / len.max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn router() -> Router {
        Router::new(vec![
            SizeClass { n: 1024, batch: 8 },
            SizeClass { n: 4096, batch: 8 },
            SizeClass { n: 16384, batch: 4 },
        ])
    }

    #[test]
    fn routes_to_smallest_fitting_class() {
        let r = router();
        assert_eq!(r.route(1), Some(0));
        assert_eq!(r.route(1024), Some(0));
        assert_eq!(r.route(1025), Some(1));
        assert_eq!(r.route(4096), Some(1));
        assert_eq!(r.route(16384), Some(2));
        assert_eq!(r.route(16385), None);
        assert_eq!(r.route(0), None);
    }

    #[test]
    fn duplicate_n_keeps_largest_batch() {
        let r = Router::new(vec![
            SizeClass { n: 1024, batch: 1 },
            SizeClass { n: 1024, batch: 8 },
        ]);
        assert_eq!(r.classes().len(), 1);
        assert_eq!(r.classes()[0].batch, 8);
    }

    #[test]
    fn padding_ascending_sinks() {
        let r = router();
        let mut row = Vec::new();
        r.pad_row(0, &[5, 3], false, &mut row);
        assert_eq!(row.len(), 1024);
        assert_eq!(&row[..2], &[5, 3]);
        assert!(row[2..].iter().all(|&x| x == u32::MAX));
    }

    #[test]
    fn padding_descending_uses_zero() {
        let r = router();
        let mut row = Vec::new();
        r.pad_row(0, &[5, 3], true, &mut row);
        assert!(row[2..].iter().all(|&x| x == 0));
    }

    #[test]
    fn overhead_computation() {
        let r = router();
        assert_eq!(r.overhead(0, 1024), 1.0);
        assert_eq!(r.overhead(0, 512), 2.0);
    }

    #[test]
    #[should_panic]
    fn rejects_non_pow2_class() {
        Router::new(vec![SizeClass { n: 1000, batch: 4 }]);
    }
}
