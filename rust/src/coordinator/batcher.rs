//! Dynamic batching: accumulate same-class requests into a device batch,
//! dispatching when the batch fills, the oldest request's max-wait
//! expires, or a pending request's **SLO budget** is about to run out —
//! the classic throughput/latency trade of serving systems, made
//! deadline-aware.
//!
//! Every pending request has one *flush-trigger instant*:
//! `min(arrived + max_wait, slo − slo_margin)`; the batcher is ready the
//! moment `now` passes the minimum trigger over the queue. That minimum
//! is **cached** — maintained incrementally on push, rescanned only when
//! a batch is taken — so the scheduler's hot queries (`ready`,
//! `next_deadline`) are O(1) instead of O(queue) under the one scheduler
//! mutex that `submit()` also needs (flagged in PR 3 review, fixed in
//! PR 4; regression-tested against a full-scan oracle). Capacity-based
//! readiness (`len >= max_rows`) needs no cache.
//!
//! `max_wait: Duration::MAX` means "never flush on age alone": the
//! trigger arithmetic is `checked_add`, an overflowing wait counts as
//! "no time-based trigger", and `next_deadline` then returns `None`
//! even for a non-empty queue (test emptiness with `is_empty`, never
//! `next_deadline`).

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use super::request::SortRequest;

/// Batching policy.
#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    /// Maximum time the oldest request may wait before a partial batch is
    /// dispatched anyway.
    pub max_wait: Duration,
    /// Dispatch as soon as this many rows are pending (usually the device
    /// batch B).
    pub max_rows: usize,
    /// Dispatch a partial batch early when any pending request's SLO
    /// deadline ([`SortRequest::slo`]) is within this margin — the slack
    /// reserved for queue hand-off plus execution.
    pub slo_margin: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        Self {
            max_wait: Duration::from_millis(2),
            max_rows: 8,
            slo_margin: Duration::from_micros(500),
        }
    }
}

/// A pending request with its arrival time.
#[derive(Debug)]
pub struct Pending {
    /// The request.
    pub request: SortRequest,
    /// When it was admitted.
    pub arrived: Instant,
    /// Response channel.
    pub reply: std::sync::mpsc::Sender<super::request::SortResponse>,
    /// Admission permit, released when the response is sent (dropped).
    pub permit: Option<super::backpressure::Permit>,
}

impl Pending {
    /// Absolute SLO deadline, when the request carries a budget.
    pub fn deadline(&self) -> Option<Instant> {
        self.request.slo.map(|slo| self.arrived + slo)
    }
}

/// A dispatched batch: up to `max_rows` same-class requests.
#[derive(Debug, Default)]
pub struct Batch {
    /// The requests, dispatch order.
    pub items: Vec<Pending>,
}

/// Per-size-class accumulation queue.
///
/// The earliest flush-trigger instant over the queue is **cached**
/// (maintained on [`push`](Self::push), recomputed on
/// [`take_batch`](Self::take_batch)), so [`ready`](Self::ready) and
/// [`next_deadline`](Self::next_deadline) are O(1). That matters because
/// every service-worker wake scans *every* class's batcher under the one
/// scheduler mutex `submit()` also needs — an O(queue) scan there turned
/// the whole scheduler O(classes × queue) per wake under load.
#[derive(Debug)]
pub struct Batcher {
    config: BatcherConfig,
    queue: VecDeque<Pending>,
    /// Earliest flush-trigger instant over all pending requests (`None`
    /// when empty). A request's trigger never changes after push, so the
    /// cached minimum only needs a `min` on push and a rescan when
    /// requests leave in `take_batch`.
    min_trigger: Option<Instant>,
}

impl Batcher {
    /// Empty batcher with the given policy.
    pub fn new(config: BatcherConfig) -> Self {
        Self {
            config,
            queue: VecDeque::new(),
            min_trigger: None,
        }
    }

    /// The instant at which `p` alone would force a flush: its max-wait
    /// expiry, or its SLO deadline minus the dispatch margin, whichever
    /// comes first. Fixed at push time (both terms derive from `arrived`
    /// and the request, neither of which changes in the queue). `None`
    /// means the request never forces a time-based flush — an effectively
    /// infinite `max_wait` (e.g. `Duration::MAX` for "flush on capacity
    /// or SLO only") overflows `Instant` arithmetic, which the old
    /// saturating scan treated as "never"; `checked_add` preserves that
    /// instead of panicking the worker on the first push.
    fn trigger_of(config: &BatcherConfig, p: &Pending) -> Option<Instant> {
        let wait = p.arrived.checked_add(config.max_wait);
        // An SLO tighter than the margin triggers immediately
        // (= at arrival), matching the scan semantics this cache
        // replaced: now + margin >= deadline from the first check.
        let slo = p
            .deadline()
            .map(|d| d.checked_sub(config.slo_margin).unwrap_or(p.arrived));
        match (wait, slo) {
            (Some(w), Some(s)) => Some(w.min(s)),
            (Some(w), None) => Some(w),
            (None, s) => s,
        }
    }

    /// Enqueue a pending request.
    pub fn push(&mut self, p: Pending) {
        if let Some(trigger) = Self::trigger_of(&self.config, &p) {
            self.min_trigger = Some(match self.min_trigger {
                Some(m) => m.min(trigger),
                None => trigger,
            });
        }
        self.queue.push_back(p);
    }

    /// Pending rows.
    pub fn len(&self) -> usize {
        self.queue.len()
    }

    /// True when no requests wait.
    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Should a batch be dispatched now? True when the batch is full, the
    /// oldest request aged past max-wait, or any pending request's SLO
    /// deadline falls within the configured margin — i.e. `now` reached
    /// the cached earliest trigger. O(1).
    pub fn ready(&self, now: Instant) -> bool {
        if self.queue.len() >= self.config.max_rows {
            return true;
        }
        self.min_trigger.is_some_and(|t| now >= t)
    }

    /// Time until the earliest flush trigger (for worker sleep): the
    /// oldest request's max-wait expiry or the tightest SLO deadline
    /// minus the margin, whichever comes first. `None` when no
    /// time-based trigger exists — the queue is empty, **or** every
    /// pending request has an effectively infinite max-wait and no SLO
    /// (so only capacity can flush it); use [`is_empty`](Self::is_empty)
    /// to test for emptiness, never this. O(1).
    pub fn next_deadline(&self, now: Instant) -> Option<Duration> {
        self.min_trigger.map(|t| t.saturating_duration_since(now))
    }

    /// Remove and return up to `max_rows` requests (FIFO). Recomputes the
    /// cached trigger over the survivors — the one place the minimum can
    /// grow, and already O(batch) from the drain itself.
    pub fn take_batch(&mut self) -> Batch {
        let take = self.queue.len().min(self.config.max_rows);
        let items: Vec<Pending> = self.queue.drain(..take).collect();
        let config = &self.config;
        self.min_trigger = self
            .queue
            .iter()
            .filter_map(|p| Self::trigger_of(config, p))
            .min();
        Batch { items }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;

    fn pending(id: u64, arrived: Instant) -> Pending {
        let (tx, _rx) = mpsc::channel();
        Pending {
            request: SortRequest::new(id, vec![1, 2]),
            arrived,
            reply: tx,
            permit: None,
        }
    }

    fn pending_slo(id: u64, arrived: Instant, slo: Duration) -> Pending {
        let (tx, _rx) = mpsc::channel();
        Pending {
            request: SortRequest::new(id, vec![1, 2]).with_slo(slo),
            arrived,
            reply: tx,
            permit: None,
        }
    }

    fn cfg() -> BatcherConfig {
        BatcherConfig {
            max_wait: Duration::from_millis(10),
            max_rows: 4,
            slo_margin: Duration::from_micros(500),
        }
    }

    #[test]
    fn fills_then_dispatches() {
        let mut b = Batcher::new(cfg());
        let now = Instant::now();
        for i in 0..3 {
            b.push(pending(i, now));
            assert!(!b.ready(now), "not full yet at {i}");
        }
        b.push(pending(3, now));
        assert!(b.ready(now), "full batch must be ready");
        let batch = b.take_batch();
        assert_eq!(batch.items.len(), 4);
        assert!(b.is_empty());
    }

    #[test]
    fn deadline_forces_partial_batch() {
        let mut b = Batcher::new(cfg());
        let past = Instant::now() - Duration::from_millis(50);
        b.push(pending(0, past));
        assert!(b.ready(Instant::now()));
        assert_eq!(b.take_batch().items.len(), 1);
    }

    #[test]
    fn fifo_order_preserved() {
        let mut b = Batcher::new(cfg());
        let now = Instant::now();
        for i in 0..4 {
            b.push(pending(i, now));
        }
        let ids: Vec<u64> = b.take_batch().items.iter().map(|p| p.request.id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3]);
    }

    #[test]
    fn take_batch_caps_at_max_rows() {
        let mut b = Batcher::new(cfg());
        let now = Instant::now();
        for i in 0..10 {
            b.push(pending(i, now));
        }
        assert_eq!(b.take_batch().items.len(), 4);
        assert_eq!(b.len(), 6);
    }

    #[test]
    fn next_deadline_counts_down() {
        let mut b = Batcher::new(cfg());
        assert!(b.next_deadline(Instant::now()).is_none());
        let now = Instant::now();
        b.push(pending(0, now));
        let d = b.next_deadline(now + Duration::from_millis(4)).unwrap();
        assert!(d <= Duration::from_millis(6), "{d:?}");
    }

    #[test]
    fn slo_deadline_forces_early_flush() {
        // max_wait is effectively infinite: only the SLO can trigger.
        let mut b = Batcher::new(BatcherConfig {
            max_wait: Duration::from_secs(1000),
            max_rows: 100,
            slo_margin: Duration::from_millis(1),
        });
        let now = Instant::now();
        b.push(pending(0, now));
        assert!(!b.ready(now), "plain request must wait");
        // A 3ms budget: not ready immediately, ready once now + margin
        // crosses the deadline, and definitely ready after expiry.
        b.push(pending_slo(1, now, Duration::from_millis(3)));
        assert!(!b.ready(now));
        assert!(b.ready(now + Duration::from_millis(2)));
        assert!(b.ready(now + Duration::from_millis(10)));
    }

    #[test]
    fn slo_not_limited_to_queue_front() {
        // The SLO carrier arrives *after* a plain request; readiness must
        // still trigger on it (deadlines are not monotonic in arrival).
        let mut b = Batcher::new(BatcherConfig {
            max_wait: Duration::from_secs(1000),
            max_rows: 100,
            slo_margin: Duration::ZERO,
        });
        let now = Instant::now();
        b.push(pending(0, now - Duration::from_millis(50)));
        b.push(pending_slo(1, now, Duration::from_millis(2)));
        assert!(!b.ready(now));
        assert!(b.ready(now + Duration::from_millis(2)));
    }

    #[test]
    fn next_deadline_tracks_tightest_slo() {
        let mut b = Batcher::new(BatcherConfig {
            max_wait: Duration::from_secs(1000),
            max_rows: 100,
            slo_margin: Duration::ZERO,
        });
        let now = Instant::now();
        b.push(pending(0, now));
        b.push(pending_slo(1, now, Duration::from_millis(7)));
        b.push(pending_slo(2, now, Duration::from_millis(3)));
        let d = b.next_deadline(now).unwrap();
        assert!(d <= Duration::from_millis(3), "{d:?}");
        assert!(d > Duration::from_millis(1), "{d:?}");
    }

    #[test]
    fn effectively_infinite_max_wait_never_panics() {
        // `Duration::MAX` is the natural "flush on capacity or SLO only"
        // config; `arrived + max_wait` overflows Instant arithmetic, so
        // the trigger cache must treat it as "never" (like the old
        // saturating scan) instead of panicking on the first push.
        let mut b = Batcher::new(BatcherConfig {
            max_wait: Duration::MAX,
            max_rows: 4,
            slo_margin: Duration::from_micros(500),
        });
        let now = Instant::now();
        b.push(pending(0, now));
        assert!(!b.ready(now + Duration::from_secs(3600)));
        assert_eq!(b.next_deadline(now), None, "no time-based trigger exists");
        // An SLO carrier still triggers on its deadline.
        b.push(pending_slo(1, now, Duration::from_millis(2)));
        assert!(b.ready(now + Duration::from_millis(5)));
        assert!(b.next_deadline(now).unwrap() <= Duration::from_millis(2));
        // And draining recomputes without panicking.
        b.take_batch();
        assert!(b.is_empty());
        assert_eq!(b.next_deadline(now), None);
    }

    /// The O(queue) scan the cached minimum replaced, kept as the test
    /// oracle: readiness and sleep time computed fresh from every pending
    /// request.
    fn oracle_ready(b: &Batcher, now: Instant) -> bool {
        if b.queue.len() >= b.config.max_rows {
            return true;
        }
        if let Some(front) = b.queue.front() {
            if now.duration_since(front.arrived) >= b.config.max_wait {
                return true;
            }
        }
        b.queue
            .iter()
            .any(|p| p.deadline().is_some_and(|d| now + b.config.slo_margin >= d))
    }

    fn oracle_next_deadline(b: &Batcher, now: Instant) -> Option<Duration> {
        b.queue
            .iter()
            .map(|p| {
                let wait = b.config.max_wait.saturating_sub(now.duration_since(p.arrived));
                match p.deadline() {
                    Some(d) => wait.min(
                        d.saturating_duration_since(now)
                            .saturating_sub(b.config.slo_margin),
                    ),
                    None => wait,
                }
            })
            .min()
    }

    /// Regression (PR 3 review): the cached minimum trigger must track
    /// the full-scan oracle exactly across arbitrary push/take
    /// interleavings — mixed SLO and plain requests, out-of-order
    /// deadlines, partial drains that remove the current minimum, and
    /// queues that empty and refill.
    #[test]
    fn cached_deadline_matches_scan_oracle_across_push_take_interleavings() {
        let mut b = Batcher::new(BatcherConfig {
            max_wait: Duration::from_millis(40),
            max_rows: 3,
            slo_margin: Duration::from_micros(500),
        });
        let t0 = Instant::now();
        // Deterministic mixed schedule: (op, arrival offset µs, slo µs).
        // slo = 0 ⇒ plain request; op 'T' ⇒ take_batch. Deadlines are
        // deliberately NOT monotonic in arrival order.
        let script: &[(char, u64, u64)] = &[
            ('P', 0, 0),
            ('P', 10, 9_000),
            ('P', 20, 2_000), // tighter SLO arrives later
            ('T', 0, 0),      // drains 3 incl. the current minimum
            ('P', 30, 0),
            ('P', 40, 50_000),
            ('P', 50, 1_000),
            ('P', 60, 700),
            ('T', 0, 0),
            ('T', 0, 0), // empties the queue
            ('P', 70, 3_000),
            ('P', 80, 0),
        ];
        let mut next_id = 0u64;
        for &(op, arrive_us, slo_us) in script {
            match op {
                'P' => {
                    let arrived = t0 + Duration::from_micros(arrive_us);
                    if slo_us == 0 {
                        b.push(pending(next_id, arrived));
                    } else {
                        b.push(pending_slo(next_id, arrived, Duration::from_micros(slo_us)));
                    }
                    next_id += 1;
                }
                'T' => {
                    let drained = b.take_batch();
                    assert!(drained.items.len() <= 3);
                }
                _ => unreachable!(),
            }
            // After every operation, the cache must agree with the scan
            // at several probe instants around the interesting edges.
            // Probes start at the latest scripted arrival (+80µs): a real
            // worker's `now` is always past every `arrived`, and before
            // an arrival the old scan's saturating `duration_since`
            // deliberately differs from the trigger arithmetic.
            for probe_us in [80u64, 110, 650, 1_500, 2_500, 10_000, 45_000, 100_000] {
                let now = t0 + Duration::from_micros(probe_us);
                assert_eq!(
                    b.ready(now),
                    oracle_ready(&b, now),
                    "ready diverged after op {op} (queue {}) at +{probe_us}µs",
                    b.len()
                );
                assert_eq!(
                    b.next_deadline(now),
                    oracle_next_deadline(&b, now),
                    "next_deadline diverged after op {op} (queue {}) at +{probe_us}µs",
                    b.len()
                );
            }
        }
        assert!(b.len() > 0, "script should leave a non-empty queue");
    }
}
