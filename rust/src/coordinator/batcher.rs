//! Dynamic batching: accumulate same-class requests into a device batch,
//! dispatching when the batch fills, the oldest request's max-wait
//! expires, or a pending request's **SLO budget** is about to run out —
//! the classic throughput/latency trade of serving systems, made
//! deadline-aware.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use super::request::SortRequest;

/// Batching policy.
#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    /// Maximum time the oldest request may wait before a partial batch is
    /// dispatched anyway.
    pub max_wait: Duration,
    /// Dispatch as soon as this many rows are pending (usually the device
    /// batch B).
    pub max_rows: usize,
    /// Dispatch a partial batch early when any pending request's SLO
    /// deadline ([`SortRequest::slo`]) is within this margin — the slack
    /// reserved for queue hand-off plus execution.
    pub slo_margin: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        Self {
            max_wait: Duration::from_millis(2),
            max_rows: 8,
            slo_margin: Duration::from_micros(500),
        }
    }
}

/// A pending request with its arrival time.
#[derive(Debug)]
pub struct Pending {
    /// The request.
    pub request: SortRequest,
    /// When it was admitted.
    pub arrived: Instant,
    /// Response channel.
    pub reply: std::sync::mpsc::Sender<super::request::SortResponse>,
    /// Admission permit, released when the response is sent (dropped).
    pub permit: Option<super::backpressure::Permit>,
}

impl Pending {
    /// Absolute SLO deadline, when the request carries a budget.
    pub fn deadline(&self) -> Option<Instant> {
        self.request.slo.map(|slo| self.arrived + slo)
    }
}

/// A dispatched batch: up to `max_rows` same-class requests.
#[derive(Debug, Default)]
pub struct Batch {
    /// The requests, dispatch order.
    pub items: Vec<Pending>,
}

/// Per-size-class accumulation queue.
#[derive(Debug)]
pub struct Batcher {
    config: BatcherConfig,
    queue: VecDeque<Pending>,
}

impl Batcher {
    /// Empty batcher with the given policy.
    pub fn new(config: BatcherConfig) -> Self {
        Self {
            config,
            queue: VecDeque::new(),
        }
    }

    /// Enqueue a pending request.
    pub fn push(&mut self, p: Pending) {
        self.queue.push_back(p);
    }

    /// Pending rows.
    pub fn len(&self) -> usize {
        self.queue.len()
    }

    /// True when no requests wait.
    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Should a batch be dispatched now? True when the batch is full,
    /// the oldest request aged past max-wait, or any pending request's
    /// SLO deadline falls within the configured margin.
    pub fn ready(&self, now: Instant) -> bool {
        if self.queue.len() >= self.config.max_rows {
            return true;
        }
        // FIFO queue ⇒ the front is oldest, so max-wait only needs the
        // front; SLO deadlines are not monotonic in arrival order, so
        // they need the scan (queue length is bounded by admission).
        if let Some(front) = self.queue.front() {
            if now.duration_since(front.arrived) >= self.config.max_wait {
                return true;
            }
        }
        self.queue
            .iter()
            .any(|p| p.deadline().map_or(false, |d| now + self.config.slo_margin >= d))
    }

    /// Time until the earliest flush trigger (for worker sleep): the
    /// oldest request's max-wait expiry or the tightest SLO deadline
    /// minus the margin, whichever comes first. `None` when empty.
    pub fn next_deadline(&self, now: Instant) -> Option<Duration> {
        self.queue
            .iter()
            .map(|p| {
                let wait = self
                    .config
                    .max_wait
                    .saturating_sub(now.duration_since(p.arrived));
                match p.deadline() {
                    Some(d) => wait.min(
                        d.saturating_duration_since(now)
                            .saturating_sub(self.config.slo_margin),
                    ),
                    None => wait,
                }
            })
            .min()
    }

    /// Remove and return up to `max_rows` requests (FIFO).
    pub fn take_batch(&mut self) -> Batch {
        let take = self.queue.len().min(self.config.max_rows);
        Batch {
            items: self.queue.drain(..take).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;

    fn pending(id: u64, arrived: Instant) -> Pending {
        let (tx, _rx) = mpsc::channel();
        Pending {
            request: SortRequest::new(id, vec![1, 2]),
            arrived,
            reply: tx,
            permit: None,
        }
    }

    fn pending_slo(id: u64, arrived: Instant, slo: Duration) -> Pending {
        let (tx, _rx) = mpsc::channel();
        Pending {
            request: SortRequest::new(id, vec![1, 2]).with_slo(slo),
            arrived,
            reply: tx,
            permit: None,
        }
    }

    fn cfg() -> BatcherConfig {
        BatcherConfig {
            max_wait: Duration::from_millis(10),
            max_rows: 4,
            slo_margin: Duration::from_micros(500),
        }
    }

    #[test]
    fn fills_then_dispatches() {
        let mut b = Batcher::new(cfg());
        let now = Instant::now();
        for i in 0..3 {
            b.push(pending(i, now));
            assert!(!b.ready(now), "not full yet at {i}");
        }
        b.push(pending(3, now));
        assert!(b.ready(now), "full batch must be ready");
        let batch = b.take_batch();
        assert_eq!(batch.items.len(), 4);
        assert!(b.is_empty());
    }

    #[test]
    fn deadline_forces_partial_batch() {
        let mut b = Batcher::new(cfg());
        let past = Instant::now() - Duration::from_millis(50);
        b.push(pending(0, past));
        assert!(b.ready(Instant::now()));
        assert_eq!(b.take_batch().items.len(), 1);
    }

    #[test]
    fn fifo_order_preserved() {
        let mut b = Batcher::new(cfg());
        let now = Instant::now();
        for i in 0..4 {
            b.push(pending(i, now));
        }
        let ids: Vec<u64> = b.take_batch().items.iter().map(|p| p.request.id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3]);
    }

    #[test]
    fn take_batch_caps_at_max_rows() {
        let mut b = Batcher::new(cfg());
        let now = Instant::now();
        for i in 0..10 {
            b.push(pending(i, now));
        }
        assert_eq!(b.take_batch().items.len(), 4);
        assert_eq!(b.len(), 6);
    }

    #[test]
    fn next_deadline_counts_down() {
        let mut b = Batcher::new(cfg());
        assert!(b.next_deadline(Instant::now()).is_none());
        let now = Instant::now();
        b.push(pending(0, now));
        let d = b.next_deadline(now + Duration::from_millis(4)).unwrap();
        assert!(d <= Duration::from_millis(6), "{d:?}");
    }

    #[test]
    fn slo_deadline_forces_early_flush() {
        // max_wait is effectively infinite: only the SLO can trigger.
        let mut b = Batcher::new(BatcherConfig {
            max_wait: Duration::from_secs(1000),
            max_rows: 100,
            slo_margin: Duration::from_millis(1),
        });
        let now = Instant::now();
        b.push(pending(0, now));
        assert!(!b.ready(now), "plain request must wait");
        // A 3ms budget: not ready immediately, ready once now + margin
        // crosses the deadline, and definitely ready after expiry.
        b.push(pending_slo(1, now, Duration::from_millis(3)));
        assert!(!b.ready(now));
        assert!(b.ready(now + Duration::from_millis(2)));
        assert!(b.ready(now + Duration::from_millis(10)));
    }

    #[test]
    fn slo_not_limited_to_queue_front() {
        // The SLO carrier arrives *after* a plain request; readiness must
        // still trigger on it (deadlines are not monotonic in arrival).
        let mut b = Batcher::new(BatcherConfig {
            max_wait: Duration::from_secs(1000),
            max_rows: 100,
            slo_margin: Duration::ZERO,
        });
        let now = Instant::now();
        b.push(pending(0, now - Duration::from_millis(50)));
        b.push(pending_slo(1, now, Duration::from_millis(2)));
        assert!(!b.ready(now));
        assert!(b.ready(now + Duration::from_millis(2)));
    }

    #[test]
    fn next_deadline_tracks_tightest_slo() {
        let mut b = Batcher::new(BatcherConfig {
            max_wait: Duration::from_secs(1000),
            max_rows: 100,
            slo_margin: Duration::ZERO,
        });
        let now = Instant::now();
        b.push(pending(0, now));
        b.push(pending_slo(1, now, Duration::from_millis(7)));
        b.push(pending_slo(2, now, Duration::from_millis(3)));
        let d = b.next_deadline(now).unwrap();
        assert!(d <= Duration::from_millis(3), "{d:?}");
        assert!(d > Duration::from_millis(1), "{d:?}");
    }
}
