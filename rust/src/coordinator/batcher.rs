//! Dynamic batching: accumulate same-class requests into a device batch,
//! dispatching when the batch fills or the oldest request's deadline
//! expires — the classic throughput/latency trade of serving systems.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use super::request::SortRequest;

/// Batching policy.
#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    /// Maximum time the oldest request may wait before a partial batch is
    /// dispatched anyway.
    pub max_wait: Duration,
    /// Dispatch as soon as this many rows are pending (usually the device
    /// batch B).
    pub max_rows: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        Self {
            max_wait: Duration::from_millis(2),
            max_rows: 8,
        }
    }
}

/// A pending request with its arrival time.
#[derive(Debug)]
pub struct Pending {
    /// The request.
    pub request: SortRequest,
    /// When it was admitted.
    pub arrived: Instant,
    /// Response channel.
    pub reply: std::sync::mpsc::Sender<super::request::SortResponse>,
    /// Admission permit, released when the response is sent (dropped).
    pub permit: Option<super::backpressure::Permit>,
}

/// A dispatched batch: up to `max_rows` same-class requests.
#[derive(Debug, Default)]
pub struct Batch {
    /// The requests, dispatch order.
    pub items: Vec<Pending>,
}

/// Per-size-class accumulation queue.
#[derive(Debug)]
pub struct Batcher {
    config: BatcherConfig,
    queue: VecDeque<Pending>,
}

impl Batcher {
    /// Empty batcher with the given policy.
    pub fn new(config: BatcherConfig) -> Self {
        Self {
            config,
            queue: VecDeque::new(),
        }
    }

    /// Enqueue a pending request.
    pub fn push(&mut self, p: Pending) {
        self.queue.push_back(p);
    }

    /// Pending rows.
    pub fn len(&self) -> usize {
        self.queue.len()
    }

    /// True when no requests wait.
    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Should a batch be dispatched now?
    pub fn ready(&self, now: Instant) -> bool {
        if self.queue.len() >= self.config.max_rows {
            return true;
        }
        match self.queue.front() {
            Some(front) => now.duration_since(front.arrived) >= self.config.max_wait,
            None => false,
        }
    }

    /// Time until the oldest request's deadline (for worker sleep), or
    /// `None` when empty.
    pub fn next_deadline(&self, now: Instant) -> Option<Duration> {
        self.queue.front().map(|front| {
            let age = now.duration_since(front.arrived);
            self.config.max_wait.saturating_sub(age)
        })
    }

    /// Remove and return up to `max_rows` requests (FIFO).
    pub fn take_batch(&mut self) -> Batch {
        let take = self.queue.len().min(self.config.max_rows);
        Batch {
            items: self.queue.drain(..take).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;

    fn pending(id: u64, arrived: Instant) -> Pending {
        let (tx, _rx) = mpsc::channel();
        Pending {
            request: SortRequest::new(id, vec![1, 2]),
            arrived,
            reply: tx,
            permit: None,
        }
    }

    fn cfg() -> BatcherConfig {
        BatcherConfig {
            max_wait: Duration::from_millis(10),
            max_rows: 4,
        }
    }

    #[test]
    fn fills_then_dispatches() {
        let mut b = Batcher::new(cfg());
        let now = Instant::now();
        for i in 0..3 {
            b.push(pending(i, now));
            assert!(!b.ready(now), "not full yet at {i}");
        }
        b.push(pending(3, now));
        assert!(b.ready(now), "full batch must be ready");
        let batch = b.take_batch();
        assert_eq!(batch.items.len(), 4);
        assert!(b.is_empty());
    }

    #[test]
    fn deadline_forces_partial_batch() {
        let mut b = Batcher::new(cfg());
        let past = Instant::now() - Duration::from_millis(50);
        b.push(pending(0, past));
        assert!(b.ready(Instant::now()));
        assert_eq!(b.take_batch().items.len(), 1);
    }

    #[test]
    fn fifo_order_preserved() {
        let mut b = Batcher::new(cfg());
        let now = Instant::now();
        for i in 0..4 {
            b.push(pending(i, now));
        }
        let ids: Vec<u64> = b.take_batch().items.iter().map(|p| p.request.id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3]);
    }

    #[test]
    fn take_batch_caps_at_max_rows() {
        let mut b = Batcher::new(cfg());
        let now = Instant::now();
        for i in 0..10 {
            b.push(pending(i, now));
        }
        assert_eq!(b.take_batch().items.len(), 4);
        assert_eq!(b.len(), 6);
    }

    #[test]
    fn next_deadline_counts_down() {
        let mut b = Batcher::new(cfg());
        assert!(b.next_deadline(Instant::now()).is_none());
        let now = Instant::now();
        b.push(pending(0, now));
        let d = b.next_deadline(now + Duration::from_millis(4)).unwrap();
        assert!(d <= Duration::from_millis(6), "{d:?}");
    }
}
