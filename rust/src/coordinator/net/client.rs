//! Blocking client for the TCP sort service — used by `bitonic-tpu
//! loadgen`, the integration tests, and anyone scripting the wire.

use std::io::Write;
use std::net::{TcpStream, ToSocketAddrs};
use std::time::Duration;

use super::wire::{read_event_blocking, ErrorCode, Frame, ReadEvent, DEFAULT_MAX_KEYS};

/// The outcome of one [`NetClient::sort`] round trip.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SortReply {
    /// The request was served.
    Sorted {
        /// The sorted keys.
        keys: Vec<u32>,
        /// True when the CPU fallback served it.
        cpu_path: bool,
        /// Server-measured latency in µs.
        latency_us: u32,
        /// Device-batch occupancy the request rode in.
        occupancy: u32,
    },
    /// Rejected by admission control — retry later.
    Shed {
        /// Server-provided detail.
        message: String,
    },
    /// Rejected for any non-shed reason (malformed, oversize, internal).
    Rejected {
        /// Machine-readable reason.
        code: ErrorCode,
        /// Server-provided detail.
        message: String,
    },
}

/// A blocking connection to a [`NetServer`].
///
/// [`NetServer`]: super::server::NetServer
pub struct NetClient {
    stream: TcpStream,
    max_keys: usize,
}

impl NetClient {
    /// Connect with 30s I/O timeouts and the default key cap.
    pub fn connect(addr: impl ToSocketAddrs) -> crate::Result<Self> {
        Self::connect_with(addr, Duration::from_secs(30), DEFAULT_MAX_KEYS)
    }

    /// Connect with explicit I/O timeouts and decode cap.
    pub fn connect_with(
        addr: impl ToSocketAddrs,
        timeout: Duration,
        max_keys: usize,
    ) -> crate::Result<Self> {
        let stream = TcpStream::connect(addr).map_err(|e| crate::err!("connecting: {e}"))?;
        let _ = stream.set_nodelay(true);
        stream
            .set_read_timeout(Some(timeout))
            .map_err(|e| crate::err!("set_read_timeout: {e}"))?;
        stream
            .set_write_timeout(Some(timeout))
            .map_err(|e| crate::err!("set_write_timeout: {e}"))?;
        Ok(Self { stream, max_keys })
    }

    /// Write one frame.
    pub fn send(&mut self, frame: &Frame) -> crate::Result<()> {
        self.stream
            .write_all(&frame.encode())
            .map_err(|e| crate::err!("sending {:?} frame: {e}", frame.op()))
    }

    /// Read one frame (errors on timeout, close, or protocol defect).
    pub fn recv(&mut self) -> crate::Result<Frame> {
        match read_event_blocking(&mut self.stream, self.max_keys)
            .map_err(|e| crate::err!("receiving: {e}"))?
        {
            ReadEvent::Frame(f) => Ok(f),
            ReadEvent::Eof | ReadEvent::Disconnected => {
                crate::bail!("server closed the connection")
            }
            ReadEvent::Protocol(e) => crate::bail!("protocol error from server: {e}"),
        }
    }

    /// One request/response round trip. Shed and rejection frames are
    /// `Ok` values (the transport worked); `Err` means the transport or
    /// protocol itself failed.
    pub fn sort(
        &mut self,
        id: u64,
        keys: Vec<u32>,
        descending: bool,
        slo: Option<Duration>,
    ) -> crate::Result<SortReply> {
        let slo_us = slo
            .map(|d| d.as_micros().clamp(1, u128::from(u32::MAX)) as u32)
            .unwrap_or(0);
        self.send(&Frame::Sort {
            id,
            descending,
            slo_us,
            keys,
        })?;
        match self.recv()? {
            Frame::Sorted {
                id: rid,
                cpu_path,
                latency_us,
                occupancy,
                keys,
            } => {
                crate::ensure!(rid == id, "response id {rid} != request id {id}");
                Ok(SortReply::Sorted {
                    keys,
                    cpu_path,
                    latency_us,
                    occupancy,
                })
            }
            Frame::Error {
                code: ErrorCode::Shed,
                message,
                ..
            } => Ok(SortReply::Shed { message }),
            Frame::Error { code, message, .. } => Ok(SortReply::Rejected { code, message }),
            other => crate::bail!("unexpected reply op {}", other.op()),
        }
    }

    /// Liveness probe: Ping, expect the matching Pong.
    pub fn ping(&mut self, token: u64) -> crate::Result<()> {
        self.send(&Frame::Ping { token })?;
        match self.recv()? {
            Frame::Pong { token: t } if t == token => Ok(()),
            other => crate::bail!("unexpected ping reply {other:?}"),
        }
    }

    /// Ask the server to drain and exit; waits for the Pong ack.
    pub fn shutdown_server(&mut self, token: u64) -> crate::Result<()> {
        self.send(&Frame::Shutdown { token })?;
        match self.recv()? {
            Frame::Pong { token: t } if t == token => Ok(()),
            other => crate::bail!("unexpected shutdown ack {other:?}"),
        }
    }
}
