//! The wire: a TCP front-end for the sort service (ROADMAP item 3).
//!
//! Zero-dependency `std::net` stack in three layers:
//!
//! * [`wire`] — the length-prefixed binary frame codec (magic +
//!   version + op + payload, strict little-endian layout, mirrored
//!   byte-for-byte by `python/compile/net.py`), plus the incremental
//!   [`FrameReader`] that survives socket read-timeout ticks.
//! * [`server`] — [`NetServer`]: accept loop + per-connection pumps
//!   over an [`Arc<Service>`](super::Service), with per-connection
//!   read/write timeouts, explicit error frames for malformed input
//!   and shed rejections, and graceful drain on shutdown.
//! * [`client`] — [`NetClient`]: the blocking client the loadgen
//!   harness and the integration tests drive.
//!
//! `bitonic-tpu serve-tcp` owns a server over the discovered registry;
//! `bitonic-tpu loadgen` measures one from the outside.

pub mod client;
pub mod server;
pub mod wire;

pub use client::{NetClient, SortReply};
pub use server::{NetServer, NetServerConfig, NetStats};
pub use wire::{
    frame_cap, is_timeout, read_event_blocking, ErrorCode, Frame, FrameReader, ReadEvent,
    WireError, DEFAULT_MAX_KEYS, MAGIC, MAX_ERROR_MSG, VERSION,
};
