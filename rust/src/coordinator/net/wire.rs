//! The length-prefixed binary wire format for the TCP sort service.
//!
//! Every frame is `len: u32 LE` followed by `len` body bytes. A body
//! starts with a fixed six-byte header — magic `b"BTSP"`, a protocol
//! version byte, and an op byte — then op-specific fields, all
//! little-endian:
//!
//! ```text
//! Sort     (op 1, client→server): dtype u8 | order u8 | id u64 |
//!          slo_us u32 | n u32 | keys n×u32            (body 24 + 4n)
//! Sorted   (op 2, server→client): path u8 | rsvd u8 | id u64 |
//!          latency_us u32 | occupancy u32 | n u32 | keys (body 28 + 4n)
//! Error    (op 3, server→client): code u8 | rsvd u8 | id u64 |
//!          message UTF-8 (rest of body)               (body 16 + len)
//! Ping     (op 4) / Pong (op 5) / Shutdown (op 6): token u64 (body 14)
//! ```
//!
//! The codec is strict by design — reserved bytes must be zero, the key
//! count must match the body length exactly, error messages must be
//! UTF-8 — so the python mirror (`python/compile/net.py`) and this file
//! pin the same bytes from both sides. Decoding never panics on
//! arbitrary input: every malformed stream maps to a [`WireError`],
//! which the server answers with an [`ErrorCode`] frame.
//!
//! An oversize length prefix is special: the stream cannot be resynced
//! without reading (and allocating) the claimed bytes, so the reader
//! surfaces [`WireError::Oversize`] and the connection must close after
//! answering.

use std::io::{ErrorKind, Read};

/// Frame magic: every body starts with these four bytes.
pub const MAGIC: [u8; 4] = *b"BTSP";

/// Protocol version this build speaks (single version so far).
pub const VERSION: u8 = 1;

/// Default cap on keys per request frame (4 MiB of key payload).
pub const DEFAULT_MAX_KEYS: usize = 1 << 20;

/// Longest error message carried in an [`Frame::Error`] body.
pub const MAX_ERROR_MSG: usize = 1024;

const OP_SORT: u8 = 1;
const OP_SORTED: u8 = 2;
const OP_ERROR: u8 = 3;
const OP_PING: u8 = 4;
const OP_PONG: u8 = 5;
const OP_SHUTDOWN: u8 = 6;

/// Common header: magic (4) + version (1) + op (1).
const HDR: usize = 6;
/// Sort body length before the key payload.
const SORT_FIXED: usize = 24;
/// Sorted body length before the key payload.
const SORTED_FIXED: usize = 28;
/// Error body length before the message bytes.
const ERROR_FIXED: usize = 16;
/// Exact body length of Ping / Pong / Shutdown.
const TOKEN_BODY: usize = 14;

/// Largest body the reader accepts for a given key cap. The error body
/// bound is folded in so a max-length error frame always fits.
pub fn frame_cap(max_keys: usize) -> usize {
    (SORTED_FIXED + 4 * max_keys).max(ERROR_FIXED + MAX_ERROR_MSG)
}

/// Error codes carried by [`Frame::Error`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ErrorCode {
    /// The frame failed to decode (bad magic, truncation, garbage…).
    Malformed = 1,
    /// Decodable but not something this build serves (version, op, dtype).
    Unsupported = 2,
    /// The request (or the claimed frame length) exceeds the key cap.
    Oversize = 3,
    /// Rejected by admission control — retry later.
    Shed = 4,
    /// The service failed internally after admission.
    Internal = 5,
}

impl ErrorCode {
    /// Decode a wire byte.
    pub fn from_u8(v: u8) -> Option<Self> {
        match v {
            1 => Some(Self::Malformed),
            2 => Some(Self::Unsupported),
            3 => Some(Self::Oversize),
            4 => Some(Self::Shed),
            5 => Some(Self::Internal),
            _ => None,
        }
    }

    /// Stable lower-case name (matches the python mirror).
    pub fn name(self) -> &'static str {
        match self {
            Self::Malformed => "malformed",
            Self::Unsupported => "unsupported",
            Self::Oversize => "oversize",
            Self::Shed => "shed",
            Self::Internal => "internal",
        }
    }
}

/// One decoded protocol frame.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Frame {
    /// Client request: sort `keys` (ascending unless `descending`),
    /// with an optional SLO in microseconds (`0` = none).
    Sort {
        /// Caller-chosen request id, echoed in the reply.
        id: u64,
        /// Sort order.
        descending: bool,
        /// SLO budget in µs; `0` means no SLO.
        slo_us: u32,
        /// The keys to sort.
        keys: Vec<u32>,
    },
    /// Server reply carrying the sorted keys.
    Sorted {
        /// Echo of the request id.
        id: u64,
        /// True when the CPU fallback served the request.
        cpu_path: bool,
        /// Server-measured latency in µs (saturating).
        latency_us: u32,
        /// Rows occupied in the device batch that served this request.
        occupancy: u32,
        /// The sorted keys.
        keys: Vec<u32>,
    },
    /// Server rejection or failure notice.
    Error {
        /// Machine-readable reason.
        code: ErrorCode,
        /// Echo of the request id (`0` when no request decoded).
        id: u64,
        /// Human-readable detail, at most [`MAX_ERROR_MSG`] bytes.
        message: String,
    },
    /// Liveness probe; the server echoes the token in a [`Frame::Pong`].
    Ping {
        /// Opaque token echoed back.
        token: u64,
    },
    /// Reply to [`Frame::Ping`] and ack of [`Frame::Shutdown`].
    Pong {
        /// Echo of the probe token.
        token: u64,
    },
    /// Ask the server to drain and exit (acked with a Pong).
    Shutdown {
        /// Opaque token echoed in the ack.
        token: u64,
    },
}

/// Why a byte stream failed to decode. [`WireError::kind`] names are
/// shared verbatim with the python mirror's test grid.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum WireError {
    /// Body shorter than its op requires.
    Truncated {
        /// Bytes the op needed.
        need: usize,
        /// Bytes actually present.
        got: usize,
    },
    /// Body longer than its op allows.
    TrailingBytes {
        /// Surplus byte count.
        extra: usize,
    },
    /// First four body bytes are not [`MAGIC`].
    BadMagic([u8; 4]),
    /// Unknown protocol version.
    BadVersion(u8),
    /// Unknown op byte.
    BadOp(u8),
    /// Unknown dtype byte (only u32 = 0 exists today).
    BadDtype(u8),
    /// Order byte outside {0, 1}.
    BadOrder(u8),
    /// Path byte outside {0, 1}.
    BadPath(u8),
    /// Unknown error-code byte.
    BadCode(u8),
    /// A reserved byte was not zero.
    BadReserved(u8),
    /// Error message is not UTF-8.
    BadUtf8,
    /// Claimed size exceeds the configured cap.
    Oversize {
        /// Claimed size (body bytes or key count, per context).
        got: usize,
        /// The cap it exceeded.
        cap: usize,
    },
}

impl WireError {
    /// Stable kebab-case kind tag (pinned by the python test grid).
    pub fn kind(&self) -> &'static str {
        match self {
            Self::Truncated { .. } => "truncated",
            Self::TrailingBytes { .. } => "trailing",
            Self::BadMagic(_) => "bad-magic",
            Self::BadVersion(_) => "bad-version",
            Self::BadOp(_) => "bad-op",
            Self::BadDtype(_) => "bad-dtype",
            Self::BadOrder(_) => "bad-order",
            Self::BadPath(_) => "bad-path",
            Self::BadCode(_) => "bad-code",
            Self::BadReserved(_) => "bad-reserved",
            Self::BadUtf8 => "bad-utf8",
            Self::Oversize { .. } => "oversize",
        }
    }

    /// The error-frame code a server answers this defect with.
    pub fn code(&self) -> ErrorCode {
        match self {
            Self::Oversize { .. } => ErrorCode::Oversize,
            Self::BadVersion(_) | Self::BadOp(_) | Self::BadDtype(_) => ErrorCode::Unsupported,
            _ => ErrorCode::Malformed,
        }
    }
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::Truncated { need, got } => write!(f, "truncated frame: need {need}, got {got}"),
            Self::TrailingBytes { extra } => write!(f, "{extra} trailing byte(s) after frame"),
            Self::BadMagic(m) => write!(f, "bad magic {m:02x?}"),
            Self::BadVersion(v) => write!(f, "unsupported protocol version {v}"),
            Self::BadOp(o) => write!(f, "unknown op {o}"),
            Self::BadDtype(d) => write!(f, "unsupported dtype {d}"),
            Self::BadOrder(o) => write!(f, "bad order byte {o}"),
            Self::BadPath(p) => write!(f, "bad path byte {p}"),
            Self::BadCode(c) => write!(f, "unknown error code {c}"),
            Self::BadReserved(b) => write!(f, "reserved byte not zero ({b})"),
            Self::BadUtf8 => write!(f, "error message is not UTF-8"),
            Self::Oversize { got, cap } => write!(f, "oversize: {got} exceeds cap {cap}"),
        }
    }
}

fn header(op: u8, extra: usize) -> Vec<u8> {
    let mut b = Vec::with_capacity(HDR + extra);
    b.extend_from_slice(&MAGIC);
    b.push(VERSION);
    b.push(op);
    b
}

fn u64_at(b: &[u8], off: usize) -> u64 {
    u64::from_le_bytes(b[off..off + 8].try_into().unwrap())
}

fn u32_at(b: &[u8], off: usize) -> u32 {
    u32::from_le_bytes(b[off..off + 4].try_into().unwrap())
}

impl Frame {
    /// The op byte this frame encodes to.
    pub fn op(&self) -> u8 {
        match self {
            Self::Sort { .. } => OP_SORT,
            Self::Sorted { .. } => OP_SORTED,
            Self::Error { .. } => OP_ERROR,
            Self::Ping { .. } => OP_PING,
            Self::Pong { .. } => OP_PONG,
            Self::Shutdown { .. } => OP_SHUTDOWN,
        }
    }

    /// Encode the body (no length prefix).
    pub fn encode_body(&self) -> Vec<u8> {
        match self {
            Self::Sort {
                id,
                descending,
                slo_us,
                keys,
            } => {
                let mut b = header(OP_SORT, SORT_FIXED - HDR + 4 * keys.len());
                b.push(0); // dtype: u32
                b.push(u8::from(*descending));
                b.extend_from_slice(&id.to_le_bytes());
                b.extend_from_slice(&slo_us.to_le_bytes());
                b.extend_from_slice(&(keys.len() as u32).to_le_bytes());
                for k in keys {
                    b.extend_from_slice(&k.to_le_bytes());
                }
                b
            }
            Self::Sorted {
                id,
                cpu_path,
                latency_us,
                occupancy,
                keys,
            } => {
                let mut b = header(OP_SORTED, SORTED_FIXED - HDR + 4 * keys.len());
                b.push(u8::from(*cpu_path));
                b.push(0); // reserved
                b.extend_from_slice(&id.to_le_bytes());
                b.extend_from_slice(&latency_us.to_le_bytes());
                b.extend_from_slice(&occupancy.to_le_bytes());
                b.extend_from_slice(&(keys.len() as u32).to_le_bytes());
                for k in keys {
                    b.extend_from_slice(&k.to_le_bytes());
                }
                b
            }
            Self::Error { code, id, message } => {
                // Clamp to the cap on a char boundary: the clamped frame
                // must still pass the strict UTF-8 decode.
                let mut cut = message.len().min(MAX_ERROR_MSG);
                while cut > 0 && !message.is_char_boundary(cut) {
                    cut -= 1;
                }
                let msg = &message.as_bytes()[..cut];
                let mut b = header(OP_ERROR, ERROR_FIXED - HDR + msg.len());
                b.push(*code as u8);
                b.push(0); // reserved
                b.extend_from_slice(&id.to_le_bytes());
                b.extend_from_slice(msg);
                b
            }
            Self::Ping { token } | Self::Pong { token } | Self::Shutdown { token } => {
                let mut b = header(self.op(), TOKEN_BODY - HDR);
                b.extend_from_slice(&token.to_le_bytes());
                b
            }
        }
    }

    /// Encode the full frame: `len: u32 LE` + body.
    pub fn encode(&self) -> Vec<u8> {
        let body = self.encode_body();
        let mut out = Vec::with_capacity(4 + body.len());
        out.extend_from_slice(&(body.len() as u32).to_le_bytes());
        out.extend_from_slice(&body);
        out
    }

    /// Decode one body (the bytes after the length prefix). Strict: the
    /// body must be exactly as long as its op demands.
    pub fn decode_body(body: &[u8], max_keys: usize) -> Result<Frame, WireError> {
        if body.len() < HDR {
            return Err(WireError::Truncated {
                need: HDR,
                got: body.len(),
            });
        }
        if body[..4] != MAGIC {
            return Err(WireError::BadMagic(body[..4].try_into().unwrap()));
        }
        if body[4] != VERSION {
            return Err(WireError::BadVersion(body[4]));
        }
        let op = body[5];
        match op {
            OP_SORT => {
                if body.len() < SORT_FIXED {
                    return Err(WireError::Truncated {
                        need: SORT_FIXED,
                        got: body.len(),
                    });
                }
                if body[6] != 0 {
                    return Err(WireError::BadDtype(body[6]));
                }
                if body[7] > 1 {
                    return Err(WireError::BadOrder(body[7]));
                }
                let n = u32_at(body, 20) as usize;
                if n > max_keys {
                    return Err(WireError::Oversize {
                        got: n,
                        cap: max_keys,
                    });
                }
                let want = SORT_FIXED + 4 * n;
                check_len(body.len(), want)?;
                Ok(Frame::Sort {
                    id: u64_at(body, 8),
                    descending: body[7] == 1,
                    slo_us: u32_at(body, 16),
                    keys: decode_keys(&body[SORT_FIXED..]),
                })
            }
            OP_SORTED => {
                if body.len() < SORTED_FIXED {
                    return Err(WireError::Truncated {
                        need: SORTED_FIXED,
                        got: body.len(),
                    });
                }
                if body[6] > 1 {
                    return Err(WireError::BadPath(body[6]));
                }
                if body[7] != 0 {
                    return Err(WireError::BadReserved(body[7]));
                }
                let n = u32_at(body, 24) as usize;
                if n > max_keys {
                    return Err(WireError::Oversize {
                        got: n,
                        cap: max_keys,
                    });
                }
                let want = SORTED_FIXED + 4 * n;
                check_len(body.len(), want)?;
                Ok(Frame::Sorted {
                    id: u64_at(body, 8),
                    cpu_path: body[6] == 1,
                    latency_us: u32_at(body, 16),
                    occupancy: u32_at(body, 20),
                    keys: decode_keys(&body[SORTED_FIXED..]),
                })
            }
            OP_ERROR => {
                if body.len() < ERROR_FIXED {
                    return Err(WireError::Truncated {
                        need: ERROR_FIXED,
                        got: body.len(),
                    });
                }
                let code = ErrorCode::from_u8(body[6]).ok_or(WireError::BadCode(body[6]))?;
                if body[7] != 0 {
                    return Err(WireError::BadReserved(body[7]));
                }
                let msg = &body[ERROR_FIXED..];
                if msg.len() > MAX_ERROR_MSG {
                    return Err(WireError::Oversize {
                        got: msg.len(),
                        cap: MAX_ERROR_MSG,
                    });
                }
                Ok(Frame::Error {
                    code,
                    id: u64_at(body, 8),
                    message: std::str::from_utf8(msg)
                        .map_err(|_| WireError::BadUtf8)?
                        .to_string(),
                })
            }
            OP_PING | OP_PONG | OP_SHUTDOWN => {
                check_len(body.len(), TOKEN_BODY)?;
                let token = u64_at(body, 6);
                Ok(match op {
                    OP_PING => Frame::Ping { token },
                    OP_PONG => Frame::Pong { token },
                    _ => Frame::Shutdown { token },
                })
            }
            other => Err(WireError::BadOp(other)),
        }
    }
}

fn check_len(got: usize, want: usize) -> Result<(), WireError> {
    if got < want {
        Err(WireError::Truncated { need: want, got })
    } else if got > want {
        Err(WireError::TrailingBytes { extra: got - want })
    } else {
        Ok(())
    }
}

fn decode_keys(b: &[u8]) -> Vec<u32> {
    b.chunks_exact(4)
        .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
        .collect()
}

/// True for the error kinds a socket read timeout produces.
pub fn is_timeout(e: &std::io::Error) -> bool {
    matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut)
}

/// What one successful [`FrameReader::poll`] produced.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ReadEvent {
    /// A complete, well-formed frame.
    Frame(Frame),
    /// Clean close: EOF on a frame boundary.
    Eof,
    /// Dirty close: EOF in the middle of a frame.
    Disconnected,
    /// The stream produced undecodable bytes. The connection should be
    /// answered (best effort) and closed — the stream may be desynced.
    Protocol(WireError),
}

/// Incremental frame reader that survives socket read timeouts.
///
/// `std::io::Read::read_exact` loses its position when a timeout fires
/// mid-frame, so the server reads through this stateful accumulator
/// instead: [`FrameReader::poll`] returns `Ok(None)` on a timeout tick
/// and keeps the partial frame buffered for the next call.
#[derive(Debug, Default)]
pub struct FrameReader {
    head: [u8; 4],
    head_got: usize,
    body: Vec<u8>,
    body_need: usize,
}

impl FrameReader {
    /// Fresh reader at a frame boundary.
    pub fn new() -> Self {
        Self::default()
    }

    /// True when a frame is partially buffered (an EOF now would be a
    /// dirty disconnect, and a drain point has not been reached).
    pub fn has_partial(&self) -> bool {
        self.head_got > 0 || self.body_need > 0
    }

    fn reset(&mut self) {
        self.head_got = 0;
        self.body.clear();
        self.body_need = 0;
    }

    /// Pump the stream: returns `Ok(None)` on a read-timeout tick (call
    /// again), `Ok(Some(event))` when a frame / close / protocol defect
    /// surfaces, and `Err` for genuine I/O failures.
    pub fn poll(
        &mut self,
        r: &mut impl Read,
        max_keys: usize,
    ) -> std::io::Result<Option<ReadEvent>> {
        loop {
            if self.body_need == 0 {
                // Length prefix.
                match r.read(&mut self.head[self.head_got..]) {
                    Ok(0) => {
                        let ev = if self.has_partial() {
                            ReadEvent::Disconnected
                        } else {
                            ReadEvent::Eof
                        };
                        self.reset();
                        return Ok(Some(ev));
                    }
                    Ok(k) => {
                        self.head_got += k;
                        if self.head_got < 4 {
                            continue;
                        }
                        let len = u32::from_le_bytes(self.head) as usize;
                        let cap = frame_cap(max_keys);
                        if len > cap {
                            self.reset();
                            return Ok(Some(ReadEvent::Protocol(WireError::Oversize {
                                got: len,
                                cap,
                            })));
                        }
                        if len < HDR {
                            self.reset();
                            return Ok(Some(ReadEvent::Protocol(WireError::Truncated {
                                need: HDR,
                                got: len,
                            })));
                        }
                        self.body_need = len;
                        self.body.clear();
                        self.body.reserve(len);
                    }
                    Err(e) if is_timeout(&e) => return Ok(None),
                    Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                    Err(e) => return Err(e),
                }
            } else {
                let mut chunk = [0u8; 8192];
                let want = (self.body_need - self.body.len()).min(chunk.len());
                match r.read(&mut chunk[..want]) {
                    Ok(0) => {
                        self.reset();
                        return Ok(Some(ReadEvent::Disconnected));
                    }
                    Ok(k) => {
                        self.body.extend_from_slice(&chunk[..k]);
                        if self.body.len() == self.body_need {
                            let ev = match Frame::decode_body(&self.body, max_keys) {
                                Ok(f) => ReadEvent::Frame(f),
                                Err(e) => ReadEvent::Protocol(e),
                            };
                            self.reset();
                            return Ok(Some(ev));
                        }
                    }
                    Err(e) if is_timeout(&e) => return Ok(None),
                    Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                    Err(e) => return Err(e),
                }
            }
        }
    }
}

/// Blocking read of one event; a socket-timeout tick maps to a
/// `TimedOut` error (clients set one long timeout, not a poll loop).
pub fn read_event_blocking(r: &mut impl Read, max_keys: usize) -> std::io::Result<ReadEvent> {
    match FrameReader::new().poll(r, max_keys)? {
        Some(ev) => Ok(ev),
        None => Err(std::io::Error::new(
            ErrorKind::TimedOut,
            "timed out waiting for a frame",
        )),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(f: Frame) {
        let enc = f.encode();
        let len = u32::from_le_bytes(enc[..4].try_into().unwrap()) as usize;
        assert_eq!(len, enc.len() - 4, "length prefix wrong for {f:?}");
        let dec = Frame::decode_body(&enc[4..], DEFAULT_MAX_KEYS).unwrap();
        assert_eq!(dec, f);
    }

    #[test]
    fn round_trips_every_frame_type() {
        roundtrip(Frame::Sort {
            id: 7,
            descending: false,
            slo_us: 0,
            keys: vec![1, 2],
        });
        roundtrip(Frame::Sort {
            id: u64::MAX,
            descending: true,
            slo_us: 123_456,
            keys: vec![],
        });
        roundtrip(Frame::Sorted {
            id: 9,
            cpu_path: true,
            latency_us: 42,
            occupancy: 8,
            keys: vec![0, u32::MAX],
        });
        roundtrip(Frame::Error {
            code: ErrorCode::Shed,
            id: 3,
            message: "shed".into(),
        });
        roundtrip(Frame::Ping { token: 1 });
        roundtrip(Frame::Pong { token: 2 });
        roundtrip(Frame::Shutdown { token: 3 });
    }

    #[test]
    fn golden_bytes_ping() {
        // Pinned in python/tests/test_net.py too — do not change.
        let enc = Frame::Ping {
            token: 0x0102_0304_0506_0708,
        }
        .encode();
        assert_eq!(
            enc,
            [
                0x0e, 0x00, 0x00, 0x00, // len = 14
                0x42, 0x54, 0x53, 0x50, // "BTSP"
                0x01, 0x04, // version, op
                0x08, 0x07, 0x06, 0x05, 0x04, 0x03, 0x02, 0x01, // token LE
            ]
        );
    }

    #[test]
    fn golden_bytes_sort() {
        // Pinned in python/tests/test_net.py too — do not change.
        let enc = Frame::Sort {
            id: 7,
            descending: false,
            slo_us: 0,
            keys: vec![1, 2],
        }
        .encode();
        let want: Vec<u8> = [
            &[0x20, 0x00, 0x00, 0x00][..],             // len = 32
            b"BTSP",                                   // magic
            &[0x01, 0x01],                             // version, op
            &[0x00, 0x00],                             // dtype, order
            &7u64.to_le_bytes(),                       // id
            &[0x00, 0x00, 0x00, 0x00],                 // slo_us
            &[0x02, 0x00, 0x00, 0x00],                 // n
            &[0x01, 0x00, 0x00, 0x00, 0x02, 0, 0, 0],  // keys
        ]
        .concat();
        assert_eq!(enc, want);
    }

    #[test]
    fn golden_bytes_error() {
        // Pinned in python/tests/test_net.py too — do not change.
        let enc = Frame::Error {
            code: ErrorCode::Shed,
            id: 9,
            message: "shed".into(),
        }
        .encode();
        let want: Vec<u8> = [
            &[0x14, 0x00, 0x00, 0x00][..], // len = 20
            b"BTSP",
            &[0x01, 0x03],       // version, op
            &[0x04, 0x00],       // code = Shed, reserved
            &9u64.to_le_bytes(), // id
            b"shed",
        ]
        .concat();
        assert_eq!(enc, want);
    }

    /// Decode of a mutated body must yield exactly the expected kind.
    fn expect_kind(body: &[u8], kind: &str) {
        match Frame::decode_body(body, DEFAULT_MAX_KEYS) {
            Err(e) => assert_eq!(e.kind(), kind, "body {body:02x?} gave {e:?}"),
            Ok(f) => panic!("body {body:02x?} decoded to {f:?}, wanted {kind}"),
        }
    }

    #[test]
    fn malformed_bodies_map_to_precise_kinds() {
        let sort = Frame::Sort {
            id: 1,
            descending: false,
            slo_us: 0,
            keys: vec![5],
        }
        .encode_body();

        expect_kind(&[], "truncated");
        expect_kind(b"XTSP\x01\x01", "bad-magic");
        let mut b = sort.clone();
        b[4] = 9;
        expect_kind(&b, "bad-version");
        let mut b = sort.clone();
        b[5] = 0x77;
        expect_kind(&b, "bad-op");
        let mut b = sort.clone();
        b[6] = 1;
        expect_kind(&b, "bad-dtype");
        let mut b = sort.clone();
        b[7] = 2;
        expect_kind(&b, "bad-order");
        // n says 2 but only 1 key present → truncated.
        let mut b = sort.clone();
        b[20] = 2;
        expect_kind(&b, "truncated");
        // n says 0 with 1 key present → trailing.
        let mut b = sort.clone();
        b[20] = 0;
        expect_kind(&b, "trailing");
        // n beyond the cap → oversize.
        let mut b = sort.clone();
        b[20..24].copy_from_slice(&u32::MAX.to_le_bytes());
        expect_kind(&b, "oversize");

        let sorted = Frame::Sorted {
            id: 1,
            cpu_path: false,
            latency_us: 1,
            occupancy: 1,
            keys: vec![5],
        }
        .encode_body();
        let mut b = sorted.clone();
        b[6] = 3;
        expect_kind(&b, "bad-path");
        let mut b = sorted;
        b[7] = 1;
        expect_kind(&b, "bad-reserved");

        let err = Frame::Error {
            code: ErrorCode::Internal,
            id: 1,
            message: "x".into(),
        }
        .encode_body();
        let mut b = err.clone();
        b[6] = 0;
        expect_kind(&b, "bad-code");
        let mut b = err;
        b[16] = 0xff; // lone continuation byte
        expect_kind(&b, "bad-utf8");

        let ping = Frame::Ping { token: 1 }.encode_body();
        let mut b = ping;
        b.push(0);
        expect_kind(&b, "trailing");
    }

    #[test]
    fn every_truncation_of_a_valid_body_errors_not_panics() {
        for f in [
            Frame::Sort {
                id: 2,
                descending: true,
                slo_us: 9,
                keys: vec![3, 1, 2],
            },
            Frame::Sorted {
                id: 2,
                cpu_path: false,
                latency_us: 5,
                occupancy: 2,
                keys: vec![1, 2, 3],
            },
            Frame::Shutdown { token: 77 },
        ] {
            let body = f.encode_body();
            for cut in 0..body.len() {
                assert!(
                    Frame::decode_body(&body[..cut], DEFAULT_MAX_KEYS).is_err(),
                    "{f:?} truncated to {cut} bytes decoded"
                );
            }
        }
        // Error is the one variable-tail op with no length field of its
        // own (the outer prefix delimits the message), so only cuts into
        // the fixed part are malformed — a shorter tail is just a
        // shorter message.
        let body = Frame::Error {
            code: ErrorCode::Malformed,
            id: 0,
            message: "bad".into(),
        }
        .encode_body();
        for cut in 0..ERROR_FIXED {
            assert!(
                Frame::decode_body(&body[..cut], DEFAULT_MAX_KEYS).is_err(),
                "Error truncated to {cut} bytes decoded"
            );
        }
        for cut in ERROR_FIXED..=body.len() {
            assert!(
                matches!(
                    Frame::decode_body(&body[..cut], DEFAULT_MAX_KEYS),
                    Ok(Frame::Error { .. })
                ),
                "Error with a {cut}-byte body failed"
            );
        }
    }

    #[test]
    fn random_garbage_never_panics() {
        let mut rng = crate::workload::SplitMix64::new(0xB170);
        for _ in 0..1000 {
            let len = rng.next_below(64) as usize;
            let mut body = vec![0u8; len];
            for b in &mut body {
                *b = rng.next_u32() as u8;
            }
            let _ = Frame::decode_body(&body, DEFAULT_MAX_KEYS);
            // Sometimes keep a valid prefix so deeper branches run too.
            if len >= 6 {
                body[..4].copy_from_slice(&MAGIC);
                body[4] = VERSION;
                body[5] = 1 + (body[5] % 6);
                let _ = Frame::decode_body(&body, DEFAULT_MAX_KEYS);
            }
        }
    }

    #[test]
    fn frame_reader_survives_one_byte_dribble() {
        // A reader fed one byte at a time (WouldBlock between bytes) must
        // still assemble the frame — this is the mid-frame-timeout path.
        struct Dribble {
            bytes: Vec<u8>,
            at: usize,
            parity: bool,
        }
        impl Read for Dribble {
            fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
                self.parity = !self.parity;
                if self.parity {
                    return Err(std::io::Error::new(ErrorKind::WouldBlock, "tick"));
                }
                if self.at == self.bytes.len() {
                    return Ok(0);
                }
                buf[0] = self.bytes[self.at];
                self.at += 1;
                Ok(1)
            }
        }
        let f = Frame::Sort {
            id: 4,
            descending: false,
            slo_us: 7,
            keys: vec![9, 8, 7],
        };
        let mut r = Dribble {
            bytes: f.encode(),
            at: 0,
            parity: false,
        };
        let mut reader = FrameReader::new();
        let mut ticks = 0;
        loop {
            match reader.poll(&mut r, DEFAULT_MAX_KEYS).unwrap() {
                Some(ReadEvent::Frame(got)) => {
                    assert_eq!(got, f);
                    break;
                }
                Some(other) => panic!("unexpected event {other:?}"),
                None => {
                    ticks += 1;
                    assert!(ticks < 10_000, "reader never completed");
                }
            }
        }
        assert!(!reader.has_partial());
        // And the EOF after it is clean (frame boundary).
        assert_eq!(
            loop {
                if let Some(ev) = reader.poll(&mut r, DEFAULT_MAX_KEYS).unwrap() {
                    break ev;
                }
            },
            ReadEvent::Eof
        );
    }

    #[test]
    fn frame_reader_reports_oversize_prefix_and_mid_frame_eof() {
        let mut cursor = std::io::Cursor::new(u32::MAX.to_le_bytes().to_vec());
        let mut reader = FrameReader::new();
        match reader.poll(&mut cursor, 16).unwrap() {
            Some(ReadEvent::Protocol(WireError::Oversize { .. })) => {}
            other => panic!("wanted oversize, got {other:?}"),
        }

        // Length prefix promising 20 bytes, stream ends after 3.
        let mut bytes = 20u32.to_le_bytes().to_vec();
        bytes.extend_from_slice(b"BTS");
        let mut cursor = std::io::Cursor::new(bytes);
        let mut reader = FrameReader::new();
        assert_eq!(
            reader.poll(&mut cursor, 16).unwrap(),
            Some(ReadEvent::Disconnected)
        );
    }

    #[test]
    fn error_message_is_clamped_on_encode() {
        let f = Frame::Error {
            code: ErrorCode::Internal,
            id: 1,
            message: "x".repeat(MAX_ERROR_MSG * 2),
        };
        let body = f.encode_body();
        assert_eq!(body.len(), ERROR_FIXED + MAX_ERROR_MSG);
        assert!(Frame::decode_body(&body, DEFAULT_MAX_KEYS).is_ok());
    }
}
