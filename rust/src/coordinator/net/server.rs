//! The TCP front-end: accept loop + per-connection frame pumps over a
//! shared [`Service`].
//!
//! Threading model: one accept thread (non-blocking listener polled
//! every few ms so shutdown is prompt) plus one thread per live
//! connection. Connection sockets are blocking with a short read
//! timeout ([`TICK`]) so each pump loop regains control often enough to
//! observe the shutdown flag and its idle budget; partial frames
//! survive those ticks via [`FrameReader`].
//!
//! Lifecycle guarantees:
//!
//! * **Graceful drain** — a [`Frame::Shutdown`] (or
//!   [`NetServer::request_shutdown`]) flips one flag; connections
//!   finish the frame (and in-flight sort) they are on, then close at
//!   the next frame boundary, and [`NetServer::shutdown`] joins the
//!   accept thread which joins every connection.
//! * **No wedged workers** — a client that vanishes mid-request is a
//!   [`ReadEvent::Disconnected`]; one that stops reading its responses
//!   trips the socket write timeout. Both just close the connection:
//!   the admission permit was already released when the service
//!   replied, so capacity cannot leak.
//! * **Malformed input answers, never panics** — every decoder defect
//!   maps to an error frame (see [`WireError::code`]) written best
//!   effort before the close.

use std::io::Write;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use super::super::request::{ExecPath, SortRequest};
use super::super::service::Service;
use super::wire::{is_timeout, ErrorCode, Frame, FrameReader, ReadEvent, DEFAULT_MAX_KEYS};
use crate::util::metrics::Counter;

/// Socket read timeout per poll tick: how often a connection pump
/// re-checks the shutdown flag and its idle budget.
const TICK: Duration = Duration::from_millis(100);

/// Accept-loop poll interval (the listener is non-blocking).
const ACCEPT_POLL: Duration = Duration::from_millis(5);

/// TCP front-end configuration.
#[derive(Clone, Copy, Debug)]
pub struct NetServerConfig {
    /// Largest key count accepted per request frame.
    pub max_keys: usize,
    /// Idle budget: a connection that sends nothing for this long is
    /// closed (counted in [`NetStats::read_timeouts`]).
    pub read_timeout: Duration,
    /// Socket write timeout: a stalled reader trips this and the
    /// connection closes (counted in [`NetStats::write_timeouts`]).
    pub write_timeout: Duration,
}

impl Default for NetServerConfig {
    fn default() -> Self {
        Self {
            max_keys: DEFAULT_MAX_KEYS,
            read_timeout: Duration::from_secs(30),
            write_timeout: Duration::from_secs(10),
        }
    }
}

/// Wire-level counters (the service keeps its own [`ServiceStats`];
/// these count what happened on the sockets).
///
/// [`ServiceStats`]: super::super::service::ServiceStats
#[derive(Debug, Default)]
pub struct NetStats {
    /// Connections accepted.
    pub connections: Counter,
    /// Well-formed frames read.
    pub frames_in: Counter,
    /// Frames written (responses, pongs, error frames).
    pub frames_out: Counter,
    /// Sort requests answered with [`ErrorCode::Shed`].
    pub sheds: Counter,
    /// Undecodable streams answered with an error frame and closed.
    pub protocol_errors: Counter,
    /// Dirty closes: EOF mid-frame, or a write failing outright.
    pub disconnects: Counter,
    /// Connections closed for exceeding the idle read budget.
    pub read_timeouts: Counter,
    /// Writes abandoned because the client stopped reading.
    pub write_timeouts: Counter,
}

impl NetStats {
    /// One-line render for logs.
    pub fn summary(&self) -> String {
        format!(
            "conns {} in {} out {} sheds {} proto-errs {} disconnects {} read-to {} write-to {}",
            self.connections.get(),
            self.frames_in.get(),
            self.frames_out.get(),
            self.sheds.get(),
            self.protocol_errors.get(),
            self.disconnects.get(),
            self.read_timeouts.get(),
            self.write_timeouts.get(),
        )
    }
}

/// State shared by the accept loop and every connection pump.
struct Shared {
    service: Arc<Service>,
    config: NetServerConfig,
    stats: NetStats,
    shutdown: AtomicBool,
}

/// A running TCP front-end. Dropping it shuts it down (drain + join).
pub struct NetServer {
    shared: Arc<Shared>,
    local_addr: SocketAddr,
    accept: Option<std::thread::JoinHandle<()>>,
}

impl NetServer {
    /// Bind `addr` (port 0 picks an ephemeral port — read it back via
    /// [`NetServer::local_addr`]) and start serving `service`.
    pub fn start(
        service: Arc<Service>,
        addr: &str,
        config: NetServerConfig,
    ) -> crate::Result<NetServer> {
        let listener =
            TcpListener::bind(addr).map_err(|e| crate::err!("binding {addr}: {e}"))?;
        let local_addr = listener
            .local_addr()
            .map_err(|e| crate::err!("local_addr: {e}"))?;
        listener
            .set_nonblocking(true)
            .map_err(|e| crate::err!("set_nonblocking: {e}"))?;
        let shared = Arc::new(Shared {
            service,
            config,
            stats: NetStats::default(),
            shutdown: AtomicBool::new(false),
        });
        let accept_shared = Arc::clone(&shared);
        let accept = std::thread::Builder::new()
            .name("net-accept".into())
            .spawn(move || accept_loop(listener, accept_shared))
            .map_err(|e| crate::err!("spawning accept thread: {e}"))?;
        Ok(NetServer {
            shared,
            local_addr,
            accept: Some(accept),
        })
    }

    /// The bound address (resolves `:0` to the actual port).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Wire-level counters.
    pub fn stats(&self) -> &NetStats {
        &self.shared.stats
    }

    /// True once a shutdown was requested (flag or Shutdown frame).
    pub fn shutdown_requested(&self) -> bool {
        self.shared.shutdown.load(Ordering::Acquire)
    }

    /// Ask the server to drain and stop (non-blocking).
    pub fn request_shutdown(&self) {
        self.shared.shutdown.store(true, Ordering::Release);
    }

    /// Block until a shutdown is requested (e.g. by a Shutdown frame).
    pub fn wait_shutdown(&self) {
        while !self.shutdown_requested() {
            std::thread::sleep(TICK);
        }
    }

    /// Drain and stop: request shutdown, then join the accept thread
    /// (which joins every connection pump). Idempotent.
    pub fn shutdown(&mut self) {
        self.request_shutdown();
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn accept_loop(listener: TcpListener, shared: Arc<Shared>) {
    let mut conns: Vec<std::thread::JoinHandle<()>> = Vec::new();
    while !shared.shutdown.load(Ordering::Acquire) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                shared.stats.connections.inc();
                conns.retain(|h| !h.is_finished());
                let sh = Arc::clone(&shared);
                match std::thread::Builder::new()
                    .name("net-conn".into())
                    .spawn(move || handle_conn(sh, stream))
                {
                    Ok(h) => conns.push(h),
                    Err(e) => eprintln!("net: spawning connection thread failed: {e}"),
                }
            }
            Err(e) if is_timeout(&e) => std::thread::sleep(ACCEPT_POLL),
            Err(_) => std::thread::sleep(ACCEPT_POLL),
        }
    }
    // Drain: every pump notices the flag at its next frame boundary.
    for h in conns {
        let _ = h.join();
    }
}

fn handle_conn(sh: Arc<Shared>, mut stream: TcpStream) {
    let _ = stream.set_nodelay(true);
    if stream.set_read_timeout(Some(TICK)).is_err()
        || stream
            .set_write_timeout(Some(sh.config.write_timeout))
            .is_err()
    {
        return;
    }
    let mut reader = FrameReader::new();
    let mut idle = Duration::ZERO;
    loop {
        if sh.shutdown.load(Ordering::Acquire) && !reader.has_partial() {
            return; // drain point: only ever between frames
        }
        let event = match reader.poll(&mut stream, sh.config.max_keys) {
            Ok(Some(ev)) => {
                idle = Duration::ZERO;
                ev
            }
            Ok(None) => {
                idle += TICK;
                if idle >= sh.config.read_timeout {
                    sh.stats.read_timeouts.inc();
                    return;
                }
                continue;
            }
            Err(_) => {
                sh.stats.disconnects.inc();
                return;
            }
        };
        match event {
            ReadEvent::Eof => return,
            ReadEvent::Disconnected => {
                sh.stats.disconnects.inc();
                return;
            }
            ReadEvent::Protocol(err) => {
                sh.stats.protocol_errors.inc();
                // The stream may be desynced past this point (notably
                // after an oversize length prefix): answer and close.
                let f = Frame::Error {
                    code: err.code(),
                    id: 0,
                    message: err.to_string(),
                };
                let _ = write_frame(&sh, &mut stream, &f);
                return;
            }
            ReadEvent::Frame(frame) => {
                sh.stats.frames_in.inc();
                if !handle_frame(&sh, &mut stream, frame) {
                    return;
                }
            }
        }
    }
}

/// Serve one decoded frame. Returns false when the connection should
/// close (write failure or shutdown ack).
fn handle_frame(sh: &Shared, stream: &mut TcpStream, frame: Frame) -> bool {
    match frame {
        Frame::Ping { token } => write_frame(sh, stream, &Frame::Pong { token }),
        Frame::Shutdown { token } => {
            // Ack first (the flag would close us before the write), then
            // flip the flag every pump and the accept loop watch.
            let _ = write_frame(sh, stream, &Frame::Pong { token });
            sh.shutdown.store(true, Ordering::Release);
            false
        }
        Frame::Sort {
            id,
            descending,
            slo_us,
            keys,
        } => {
            let request = SortRequest {
                id,
                keys,
                descending,
                slo: (slo_us > 0).then(|| Duration::from_micros(u64::from(slo_us))),
            };
            match sh.service.submit(request) {
                Err(_rejected) => {
                    sh.stats.sheds.inc();
                    write_frame(
                        sh,
                        stream,
                        &Frame::Error {
                            code: ErrorCode::Shed,
                            id,
                            message: "admission gate full; retry later".into(),
                        },
                    )
                }
                Ok(rx) => match rx.recv() {
                    Ok(resp) => write_frame(
                        sh,
                        stream,
                        &Frame::Sorted {
                            id: resp.id,
                            cpu_path: resp.path == ExecPath::Cpu,
                            latency_us: resp.latency.as_micros().min(u128::from(u32::MAX))
                                as u32,
                            occupancy: resp.batch_occupancy.min(u32::MAX as usize) as u32,
                            keys: resp.keys,
                        },
                    ),
                    Err(_) => write_frame(
                        sh,
                        stream,
                        &Frame::Error {
                            code: ErrorCode::Internal,
                            id,
                            message: "service dropped the response channel".into(),
                        },
                    ),
                },
            }
        }
        // Server-to-client ops arriving at the server: the frame decoded
        // (stream still in sync), so answer and keep the connection.
        Frame::Sorted { id, .. } | Frame::Error { id, .. } => {
            sh.stats.protocol_errors.inc();
            write_frame(
                sh,
                stream,
                &Frame::Error {
                    code: ErrorCode::Malformed,
                    id,
                    message: "unexpected server-to-client op".into(),
                },
            )
        }
        Frame::Pong { .. } => {
            sh.stats.protocol_errors.inc();
            write_frame(
                sh,
                stream,
                &Frame::Error {
                    code: ErrorCode::Malformed,
                    id: 0,
                    message: "unexpected server-to-client op".into(),
                },
            )
        }
    }
}

/// Write one frame; false means the connection must close. A timeout
/// here is the stalled-reader case — the response is dropped but its
/// admission permit was already released, so nothing leaks.
fn write_frame(sh: &Shared, stream: &mut TcpStream, f: &Frame) -> bool {
    match stream.write_all(&f.encode()) {
        Ok(()) => {
            sh.stats.frames_out.inc();
            true
        }
        Err(e) if is_timeout(&e) => {
            sh.stats.write_timeouts.inc();
            false
        }
        Err(_) => {
            sh.stats.disconnects.inc();
            false
        }
    }
}
