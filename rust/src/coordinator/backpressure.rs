//! Admission control: a counting gate bounding total in-flight requests.
//!
//! The service sheds (rejects) new work when the bound is reached instead
//! of queueing without limit — the response-time-preserving policy for a
//! latency-sensitive service.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// Shared admission gate. Clone-able handle.
#[derive(Clone, Debug)]
pub struct AdmissionGate {
    inner: Arc<Inner>,
}

#[derive(Debug)]
struct Inner {
    in_flight: AtomicUsize,
    capacity: usize,
    shed_total: AtomicUsize,
    admitted_total: AtomicUsize,
}

/// RAII permit; releasing happens on drop.
#[derive(Debug)]
pub struct Permit {
    inner: Arc<Inner>,
}

impl AdmissionGate {
    /// Gate admitting at most `capacity` concurrent requests.
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0);
        Self {
            inner: Arc::new(Inner {
                in_flight: AtomicUsize::new(0),
                capacity,
                shed_total: AtomicUsize::new(0),
                admitted_total: AtomicUsize::new(0),
            }),
        }
    }

    /// Try to admit one request. `None` ⇒ shed.
    pub fn try_acquire(&self) -> Option<Permit> {
        let mut cur = self.inner.in_flight.load(Ordering::Relaxed);
        loop {
            if cur >= self.inner.capacity {
                self.inner.shed_total.fetch_add(1, Ordering::Relaxed);
                return None;
            }
            match self.inner.in_flight.compare_exchange_weak(
                cur,
                cur + 1,
                Ordering::AcqRel,
                Ordering::Relaxed,
            ) {
                Ok(_) => {
                    self.inner.admitted_total.fetch_add(1, Ordering::Relaxed);
                    return Some(Permit {
                        inner: Arc::clone(&self.inner),
                    });
                }
                Err(actual) => cur = actual,
            }
        }
    }

    /// Currently admitted requests.
    pub fn in_flight(&self) -> usize {
        self.inner.in_flight.load(Ordering::Acquire)
    }

    /// Total requests shed since start.
    pub fn shed_total(&self) -> usize {
        self.inner.shed_total.load(Ordering::Relaxed)
    }

    /// Total requests admitted since start.
    pub fn admitted_total(&self) -> usize {
        self.inner.admitted_total.load(Ordering::Relaxed)
    }

    /// Configured capacity.
    pub fn capacity(&self) -> usize {
        self.inner.capacity
    }
}

impl Drop for Permit {
    fn drop(&mut self) {
        self.inner.in_flight.fetch_sub(1, Ordering::Release);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn admits_up_to_capacity() {
        let g = AdmissionGate::new(2);
        let p1 = g.try_acquire().unwrap();
        let _p2 = g.try_acquire().unwrap();
        assert!(g.try_acquire().is_none());
        assert_eq!(g.in_flight(), 2);
        drop(p1);
        assert_eq!(g.in_flight(), 1);
        assert!(g.try_acquire().is_some());
    }

    #[test]
    fn counters_track() {
        let g = AdmissionGate::new(1);
        let p = g.try_acquire().unwrap();
        let _ = g.try_acquire();
        let _ = g.try_acquire();
        assert_eq!(g.admitted_total(), 1);
        assert_eq!(g.shed_total(), 2);
        drop(p);
        let _ = g.try_acquire().unwrap();
        assert_eq!(g.admitted_total(), 2);
    }

    #[test]
    fn concurrent_never_exceeds_capacity() {
        let g = AdmissionGate::new(8);
        let peak = Arc::new(AtomicUsize::new(0));
        std::thread::scope(|s| {
            for _ in 0..16 {
                let g = g.clone();
                let peak = Arc::clone(&peak);
                s.spawn(move || {
                    for _ in 0..1000 {
                        if let Some(_p) = g.try_acquire() {
                            peak.fetch_max(g.in_flight(), Ordering::Relaxed);
                        }
                    }
                });
            }
        });
        assert!(peak.load(Ordering::Relaxed) <= 8);
        assert_eq!(g.in_flight(), 0);
    }
}
