//! The service leader: ties router, batchers, admission gate and a
//! shared worker pool together around a [`BatchSorter`] backend per size
//! class.
//!
//! Scheduling is **multi-queue with work stealing**: there is one
//! [`Batcher`] queue per size class, but workers are not bound to
//! classes. Each worker has a *home* class (scanned first, for steady
//! traffic affinity) and steals ready batches from any other class's
//! queue when its home is idle — so no worker sits idle while another
//! class has dispatchable work, and hot classes drain with every thread
//! in the house. Flushes are deadline-aware: see
//! [`BatcherConfig::slo_margin`].
//!
//! Scheduling invariants worth knowing when reading this module:
//!
//! * All batcher queues sit behind **one scheduler mutex**, shared by
//!   `submit()` and the workers; the per-queue readiness checks it makes
//!   under that lock are O(1) (the batcher caches its earliest
//!   flush-trigger instant — see [`super::batcher`]).
//! * Workers sleep on a condvar with a timeout equal to the earliest
//!   `next_deadline` across queues, and shutdown cycles the lock before
//!   `notify_all` so the flag cannot slip between a worker's check and
//!   its wait (the classic lost-wakeup).
//! * A device failure degrades the affected batch to the per-item CPU
//!   path ([`ExecPath::Cpu`] in the response) — requests are
//!   never dropped by the execution layer; only admission
//!   ([`super::backpressure`]) sheds, and that is counted in
//!   [`ServiceStats::shed`].
//! * Work stealing is unweighted today: a hot class can still starve a
//!   cold class's SLOs under sustained overload (per-class admission
//!   budgets and priority stealing are ROADMAP items).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use super::backpressure::AdmissionGate;
use super::batcher::{Batch, Batcher, BatcherConfig, Pending};
use super::request::{ExecPath, SortRequest, SortResponse};
use super::router::{Router, SizeClass};
use crate::util::metrics::{Counter, Histogram};

/// A backend that sorts a full `(batch, n)` row-major buffer ascending.
///
/// Implemented by [`RegistrySorter`] (PJRT artifacts) and by CPU mocks in
/// the test-suite; the service logic is backend-agnostic.
pub trait BatchSorter: Send + Sync {
    /// `(batch_rows, row_len)` of this backend.
    fn shape(&self) -> (usize, usize);
    /// Sort each of the `batch` rows of `rows` ascending. Takes the
    /// buffer by value: the device path ships it across the host-thread
    /// channel anyway, and by-value avoids a defensive copy per batch
    /// (§Perf L3 iteration 1).
    fn sort_rows(&self, rows: Vec<u32>) -> crate::Result<Vec<u32>>;
}

/// [`BatchSorter`] backed by a compiled PJRT artifact, executed via the
/// device-host thread (PJRT objects are `!Send`; see `runtime::host`).
pub struct RegistrySorter {
    handle: crate::runtime::DeviceHandle,
    key: crate::runtime::Key,
    batch: usize,
    n: usize,
}

impl RegistrySorter {
    /// Wrap an (ascending, u32) artifact behind the device handle.
    pub fn new(
        handle: crate::runtime::DeviceHandle,
        meta: &crate::runtime::ArtifactMeta,
    ) -> Self {
        Self {
            handle,
            key: crate::runtime::Key::of(meta),
            batch: meta.batch,
            n: meta.n,
        }
    }
}

impl BatchSorter for RegistrySorter {
    fn shape(&self) -> (usize, usize) {
        (self.batch, self.n)
    }
    fn sort_rows(&self, rows: Vec<u32>) -> crate::Result<Vec<u32>> {
        self.handle.sort_u32(self.key, rows)
    }
}

/// CPU fallback for requests larger than every artifact (or when no
/// artifacts are available): our from-scratch quicksort.
pub struct CpuFallbackSorter;

impl CpuFallbackSorter {
    /// Sort one request's keys on the CPU.
    pub fn sort(&self, keys: &mut [u32], descending: bool) {
        crate::sort::quicksort(keys);
        if descending {
            keys.reverse();
        }
    }
}

/// Service configuration.
#[derive(Clone, Copy, Debug)]
pub struct ServiceConfig {
    /// Batching policy applied to every size class.
    pub batcher: BatcherConfig,
    /// Admission bound (in-flight requests).
    pub max_in_flight: usize,
    /// Worker threads shared across ALL size classes (work stealing);
    /// `0` ⇒ one worker per class, the pre-stealing default shape.
    pub threads: usize,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self {
            batcher: BatcherConfig::default(),
            max_in_flight: 1024,
            threads: 0,
        }
    }
}

/// Per-size-class serving statistics (indexed like `Router::classes`).
/// The wire front-end and the starvation regression tests read these:
/// the aggregate numbers cannot show one class starving another.
#[derive(Debug, Default)]
pub struct ClassStats {
    /// Keys per row of this class.
    pub n: usize,
    /// Rows per device batch of this class.
    pub batch: usize,
    /// Requests routed here and admitted.
    pub admitted: Counter,
    /// Requests routed here but shed by the admission gate.
    pub shed: Counter,
    /// Device batches dispatched for this class.
    pub batches: Counter,
    /// Rows occupied across those batches.
    pub rows: Counter,
    /// Answered requests whose latency exceeded their SLO.
    pub slo_misses: Counter,
    /// End-to-end latency distribution for this class.
    pub latency: Histogram,
}

/// Aggregate service statistics.
#[derive(Debug, Default)]
pub struct ServiceStats {
    /// Requests accepted.
    pub admitted: Counter,
    /// Requests rejected by the admission gate.
    pub shed: Counter,
    /// Device batches dispatched.
    pub device_batches: Counter,
    /// Rows occupied across device batches (occupancy = rows/batches·B).
    pub device_rows: Counter,
    /// Requests served by the CPU fallback.
    pub cpu_fallbacks: Counter,
    /// Batches executed by a worker whose home class differs (work
    /// stealing across size classes).
    pub stolen_batches: Counter,
    /// Answered requests whose latency exceeded their SLO.
    pub slo_misses: Counter,
    /// End-to-end latency distribution.
    pub latency: Histogram,
    /// Per-size-class breakdown (empty when built via `Default`).
    pub classes: Vec<ClassStats>,
}

impl ServiceStats {
    /// Stats with one [`ClassStats`] slot per size class.
    fn for_classes(classes: &[SizeClass]) -> Self {
        Self {
            classes: classes
                .iter()
                .map(|c| ClassStats {
                    n: c.n,
                    batch: c.batch,
                    ..ClassStats::default()
                })
                .collect(),
            ..Self::default()
        }
    }

    /// Record one answered request: aggregate + per-class latency, and
    /// the SLO-miss counters when a budget was attached and blown.
    fn note_latency(&self, class: Option<usize>, slo: Option<Duration>, latency: Duration) {
        self.latency.record(latency);
        let missed = slo.is_some_and(|s| latency > s);
        if missed {
            self.slo_misses.inc();
        }
        if let Some(cs) = class.and_then(|c| self.classes.get(c)) {
            cs.latency.record(latency);
            if missed {
                cs.slo_misses.inc();
            }
        }
    }
}

/// The multi-queue scheduler: one batcher per size class behind a single
/// lock, one condvar shared by every worker. Workers scan home-first and
/// steal from peers; the lock covers only queue scans/takes, never batch
/// execution.
struct Scheduler {
    /// One batcher per size class, index-aligned with `Service::sorters`.
    batchers: Mutex<Vec<Batcher>>,
    /// Wakes workers when requests arrive or shutdown begins.
    wake: Condvar,
}

/// The sort service. `submit` never blocks on sorting; responses arrive on
/// per-request channels.
pub struct Service {
    router: Router,
    sched: Scheduler,
    sorters: Vec<Arc<dyn BatchSorter>>,
    fallback: CpuFallbackSorter,
    gate: AdmissionGate,
    stats: Arc<ServiceStats>,
    shutdown: Arc<AtomicBool>,
    workers: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl Service {
    /// Build a service over one backend per size class. Shapes are taken
    /// from the backends; classes with duplicate `n` must not occur.
    pub fn new(sorters: Vec<Arc<dyn BatchSorter>>, config: ServiceConfig) -> Arc<Self> {
        let mut shaped: Vec<(SizeClass, Arc<dyn BatchSorter>)> = sorters
            .into_iter()
            .map(|s| {
                let (batch, n) = s.shape();
                (SizeClass { n, batch }, s)
            })
            .collect();
        // Duplicate row sizes (e.g. batch-1 and batch-8 artifacts for the
        // same n) collapse to the largest batch — matching Router::new.
        // Sort batch-descending within n so dedup keeps the big batch.
        shaped.sort_by_key(|(c, _)| (c.n, std::cmp::Reverse(c.batch)));
        shaped.dedup_by_key(|(c, _)| c.n);
        let router = Router::new(shaped.iter().map(|(c, _)| *c).collect());
        assert_eq!(
            router.classes().len(),
            shaped.len(),
            "router/class mismatch"
        );
        let batchers: Vec<Batcher> = shaped
            .iter()
            .map(|(c, _)| {
                Batcher::new(BatcherConfig {
                    max_rows: c.batch,
                    ..config.batcher
                })
            })
            .collect();
        let stats = Arc::new(ServiceStats::for_classes(router.classes()));
        let service = Arc::new(Self {
            router,
            sched: Scheduler {
                batchers: Mutex::new(batchers),
                wake: Condvar::new(),
            },
            sorters: shaped.into_iter().map(|(_, s)| s).collect(),
            fallback: CpuFallbackSorter,
            gate: AdmissionGate::new(config.max_in_flight),
            stats,
            shutdown: Arc::new(AtomicBool::new(false)),
            workers: Mutex::new(Vec::new()),
        });
        // A shared worker pool: `threads` workers serve every class via
        // work stealing (0 ⇒ one per class, matching the old silo count
        // while still allowing steals).
        let classes = service.sorters.len();
        let worker_count = if classes == 0 {
            0
        } else if config.threads == 0 {
            classes
        } else {
            config.threads.max(1)
        };
        let mut workers = service.workers.lock().unwrap();
        for idx in 0..worker_count {
            let svc = Arc::clone(&service);
            workers.push(
                std::thread::Builder::new()
                    .name(format!("sort-worker-{idx}"))
                    .spawn(move || svc.worker_loop(idx))
                    .expect("spawn service worker"),
            );
        }
        drop(workers);
        service
    }

    /// Service statistics handle.
    pub fn stats(&self) -> &ServiceStats {
        &self.stats
    }

    /// The router (for introspection / tests).
    pub fn router(&self) -> &Router {
        &self.router
    }

    /// Submit a request. Returns the response channel, or `Err` when shed
    /// by admission control.
    pub fn submit(&self, request: SortRequest) -> Result<Receiver<SortResponse>, SortRequest> {
        // Route before the gate so a shed is attributed to its class —
        // the starvation diagnostics need to see WHICH traffic is shed.
        let class = self.router.route(request.keys.len());
        let Some(permit) = self.gate.try_acquire() else {
            self.stats.shed.inc();
            if let Some(cs) = class.and_then(|c| self.stats.classes.get(c)) {
                cs.shed.inc();
            }
            return Err(request);
        };
        self.stats.admitted.inc();
        if let Some(cs) = class.and_then(|c| self.stats.classes.get(c)) {
            cs.admitted.inc();
        }
        let (tx, rx) = std::sync::mpsc::channel();
        let arrived = Instant::now();
        match class {
            Some(class) => {
                let mut batchers = self.sched.batchers.lock().unwrap();
                batchers[class].push(Pending {
                    request,
                    arrived,
                    reply: tx,
                    permit: Some(permit),
                });
                drop(batchers);
                // Any worker may serve any class; wake one.
                self.sched.wake.notify_one();
            }
            None => {
                // Oversized (or empty) request: CPU fallback, run inline —
                // submit() is documented to be cheap for routed requests;
                // oversized ones are the caller's explicit trade.
                self.cpu_path(request, None, arrived, &tx);
                drop(permit);
            }
        }
        Ok(rx)
    }

    /// Convenience: submit and wait.
    pub fn sort_blocking(&self, request: SortRequest) -> Result<SortResponse, SortRequest> {
        let rx = self.submit(request)?;
        Ok(rx.recv().expect("service dropped response channel"))
    }

    fn cpu_path(
        &self,
        mut request: SortRequest,
        class: Option<usize>,
        arrived: Instant,
        tx: &Sender<SortResponse>,
    ) {
        self.fallback.sort(&mut request.keys, request.descending);
        self.stats.cpu_fallbacks.inc();
        let latency = arrived.elapsed();
        self.stats.note_latency(class, request.slo, latency);
        let _ = tx.send(SortResponse {
            id: request.id,
            keys: request.keys,
            path: ExecPath::Cpu,
            latency,
            batch_occupancy: 1,
        });
    }

    /// One shared worker: scan the home class first, then steal a ready
    /// batch from any other class's queue. The scheduler lock is held
    /// only while scanning/taking, never during execution.
    fn worker_loop(&self, worker: usize) {
        let classes = self.sorters.len();
        if classes == 0 {
            return;
        }
        let home = worker % classes;
        loop {
            let (class, batch, more_ready) = {
                let mut batchers = self.sched.batchers.lock().unwrap();
                loop {
                    let now = Instant::now();
                    // Home class first, then steal from peers in order.
                    let mut found = None;
                    for off in 0..classes {
                        let idx = (home + off) % classes;
                        if batchers[idx].ready(now) {
                            found = Some(idx);
                            break;
                        }
                    }
                    if found.is_none() && self.shutdown.load(Ordering::Acquire) {
                        // Drain: flush leftovers, ready or not.
                        found = (0..classes).find(|&i| !batchers[i].is_empty());
                        if found.is_none() {
                            return;
                        }
                    }
                    if let Some(idx) = found {
                        let batch = batchers[idx].take_batch();
                        // Hand remaining work to a sleeping peer before
                        // going off to execute. Non-empty (not just
                        // ready) on purpose: a woken peer recomputes the
                        // global min deadline, so a pending SLO/max-wait
                        // flush is watched while this worker is busy
                        // instead of waiting out a stale 50ms timeout.
                        let more = (0..classes).any(|i| !batchers[i].is_empty());
                        break (idx, batch, more);
                    }
                    let wait = batchers
                        .iter()
                        .filter_map(|b| b.next_deadline(now))
                        .min()
                        .unwrap_or(Duration::from_millis(50));
                    let (g, _timeout) = self
                        .sched
                        .wake
                        .wait_timeout(batchers, wait.max(Duration::from_micros(100)))
                        .unwrap();
                    batchers = g;
                }
            };
            if more_ready {
                self.sched.wake.notify_one();
            }
            if batch.items.is_empty() {
                continue;
            }
            if class != home {
                self.stats.stolen_batches.inc();
            }
            self.run_batch(class, batch);
        }
    }

    /// Assemble, execute and answer one dispatched batch.
    fn run_batch(&self, class: usize, batch: Batch) {
        let sorter = &self.sorters[class];
        let (batch_rows, n) = sorter.shape();

        // Assemble the (B, N) buffer writing each request directly
        // into its row (no staging copy); unused rows keep MAX
        // padding (cheapest: they sort to themselves).
        let mut rows: Vec<u32> = Vec::with_capacity(batch_rows * n);
        for item in &batch.items {
            rows.extend_from_slice(&item.request.keys);
            // Row padding: MAX sinks for ascending, 0 for descending
            // (reversed at reply time) — same contract as pad_row.
            let fill = if item.request.descending { 0 } else { u32::MAX };
            rows.resize(rows.len() + (n - item.request.keys.len()), fill);
        }
        rows.resize(batch_rows * n, u32::MAX);

        let occupancy = batch.items.len();
        match sorter.sort_rows(rows) {
            Ok(sorted) => {
                self.stats.device_batches.inc();
                self.stats.device_rows.add(occupancy as u64);
                if let Some(cs) = self.stats.classes.get(class) {
                    cs.batches.inc();
                    cs.rows.add(occupancy as u64);
                }
                for (i, item) in batch.items.into_iter().enumerate() {
                    let len = item.request.keys.len();
                    let row = &sorted[i * n..(i + 1) * n];
                    let keys = if item.request.descending {
                        // 0-pads sorted to the front; the request's
                        // keys are the tail — reverse just that slice.
                        row[n - len..].iter().rev().copied().collect()
                    } else {
                        row[..len].to_vec()
                    };
                    let latency = item.arrived.elapsed();
                    self.stats
                        .note_latency(Some(class), item.request.slo, latency);
                    let _ = item.reply.send(SortResponse {
                        id: item.request.id,
                        keys,
                        path: ExecPath::Device,
                        latency,
                        batch_occupancy: occupancy,
                    });
                    drop(item.permit);
                }
            }
            Err(err) => {
                // Device failure: degrade to the CPU path per item so
                // no request is ever dropped.
                eprintln!("device batch failed ({err:#}); CPU fallback");
                for item in batch.items {
                    self.cpu_path(item.request, Some(class), item.arrived, &item.reply);
                    drop(item.permit);
                }
            }
        }
    }

    /// Stop workers after draining queues.
    pub fn shutdown(&self) {
        self.shutdown.store(true, Ordering::Release);
        // Cycle the scheduler lock before notifying: the store cannot then
        // slip between a worker's shutdown check and its condvar wait
        // (classic lost-wakeup), because the check happens under the lock.
        drop(self.sched.batchers.lock().unwrap());
        self.sched.wake.notify_all();
        let mut workers = self.workers.lock().unwrap();
        for w in workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for Service {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sort::bitonic::bitonic_sort;

    /// CPU-backed mock with a given shape (tests run without artifacts).
    pub struct MockSorter {
        pub batch: usize,
        pub n: usize,
        pub calls: Counter,
    }

    impl BatchSorter for MockSorter {
        fn shape(&self) -> (usize, usize) {
            (self.batch, self.n)
        }
        fn sort_rows(&self, mut rows: Vec<u32>) -> crate::Result<Vec<u32>> {
            self.calls.inc();
            for r in rows.chunks_mut(self.n) {
                bitonic_sort(r);
            }
            Ok(rows)
        }
    }

    fn svc_with(classes: &[(usize, usize)], config: ServiceConfig) -> Arc<Service> {
        let sorters: Vec<Arc<dyn BatchSorter>> = classes
            .iter()
            .map(|&(batch, n)| {
                Arc::new(MockSorter {
                    batch,
                    n,
                    calls: Counter::new(),
                }) as Arc<dyn BatchSorter>
            })
            .collect();
        Service::new(sorters, config)
    }

    fn svc(classes: &[(usize, usize)]) -> Arc<Service> {
        svc_with(classes, ServiceConfig::default())
    }

    #[test]
    fn duplicate_row_sizes_collapse_to_largest_batch() {
        let s = svc(&[(1, 64), (8, 64), (4, 256)]);
        assert_eq!(s.router().classes().len(), 2);
        assert_eq!(s.router().classes()[0].batch, 8);
        // And it still serves requests correctly.
        let resp = s.sort_blocking(SortRequest::new(9, vec![3, 1, 2])).unwrap();
        assert_eq!(resp.keys, vec![1, 2, 3]);
    }

    #[test]
    fn sorts_single_request() {
        let s = svc(&[(4, 64)]);
        let resp = s
            .sort_blocking(SortRequest::new(1, vec![5, 3, 9, 1]))
            .unwrap();
        assert_eq!(resp.keys, vec![1, 3, 5, 9]);
        assert_eq!(resp.path, ExecPath::Device);
        assert_eq!(resp.id, 1);
    }

    #[test]
    fn descending_request() {
        let s = svc(&[(4, 64)]);
        let resp = s
            .sort_blocking(SortRequest {
                id: 2,
                keys: vec![5, 3, 9, 1],
                descending: true,
                slo: None,
            })
            .unwrap();
        assert_eq!(resp.keys, vec![9, 5, 3, 1]);
    }

    #[test]
    fn oversized_falls_back_to_cpu() {
        let s = svc(&[(4, 64)]);
        let keys: Vec<u32> = (0..1000).rev().collect();
        let resp = s.sort_blocking(SortRequest::new(3, keys)).unwrap();
        assert_eq!(resp.path, ExecPath::Cpu);
        assert!(resp.keys.windows(2).all(|w| w[0] <= w[1]));
        assert_eq!(resp.keys.len(), 1000);
    }

    #[test]
    fn empty_request_ok() {
        let s = svc(&[(4, 64)]);
        let resp = s.sort_blocking(SortRequest::new(4, vec![])).unwrap();
        assert!(resp.keys.is_empty());
    }

    #[test]
    fn batching_packs_concurrent_requests() {
        let s = svc(&[(8, 128)]);
        let rxs: Vec<_> = (0..8)
            .map(|i| {
                s.submit(SortRequest::new(i, vec![8 - i as u32, 1, 2]))
                    .unwrap()
            })
            .collect();
        let mut max_occ = 0;
        for rx in rxs {
            let resp = rx.recv().unwrap();
            assert_eq!(resp.keys.len(), 3);
            max_occ = max_occ.max(resp.batch_occupancy);
        }
        assert!(max_occ > 1, "no batching happened (occupancy {max_occ})");
    }

    #[test]
    fn shed_when_gate_full() {
        let sorters: Vec<Arc<dyn BatchSorter>> = vec![Arc::new(MockSorter {
            batch: 2,
            n: 64,
            calls: Counter::new(),
        })];
        let s = Service::new(
            sorters,
            ServiceConfig {
                max_in_flight: 1,
                batcher: BatcherConfig {
                    max_wait: Duration::from_secs(10), // hold the first one
                    max_rows: 2,
                    ..BatcherConfig::default()
                },
                ..ServiceConfig::default()
            },
        );
        let _rx = s.submit(SortRequest::new(1, vec![1])).unwrap();
        // Second submit must shed (capacity 1, first still queued).
        let second = s.submit(SortRequest::new(2, vec![2]));
        assert!(second.is_err());
        assert_eq!(s.stats().shed.get(), 1);
        // The shed is attributed to the class it was routed to.
        assert_eq!(s.stats().classes[0].shed.get(), 1);
        assert_eq!(s.stats().classes[0].admitted.get(), 1);
    }

    #[test]
    fn per_class_stats_attribute_traffic() {
        let s = svc(&[(4, 64), (4, 1024)]);
        assert_eq!(s.stats().classes.len(), 2);
        assert_eq!(s.stats().classes[0].n, 64);
        assert_eq!(s.stats().classes[1].n, 1024);
        s.sort_blocking(SortRequest::new(1, vec![2, 1])).unwrap();
        s.sort_blocking(SortRequest::new(2, (0..512u32).rev().collect()))
            .unwrap();
        let small = &s.stats().classes[0];
        let big = &s.stats().classes[1];
        assert_eq!(small.admitted.get(), 1);
        assert_eq!(big.admitted.get(), 1);
        assert_eq!(small.batches.get(), 1);
        assert_eq!(small.rows.get(), 1);
        assert_eq!(small.latency.count(), 1);
        assert_eq!(big.latency.count(), 1);
        // Oversized requests route nowhere: aggregate only.
        s.sort_blocking(SortRequest::new(3, (0..5000u32).collect()))
            .unwrap();
        assert_eq!(s.stats().cpu_fallbacks.get(), 1);
        assert_eq!(small.admitted.get() + big.admitted.get(), 2);
    }

    #[test]
    fn slo_misses_are_counted_per_class() {
        // A 3ms-per-batch backend cannot meet a 1ns SLO; the miss must
        // land in both the aggregate and the class counters.
        let s = Service::new(
            vec![Arc::new(SlowMock {
                batch: 1,
                n: 64,
                cost: Duration::from_millis(3),
            }) as Arc<dyn BatchSorter>],
            ServiceConfig::default(),
        );
        s.sort_blocking(SortRequest::new(1, vec![2, 1]).with_slo(Duration::from_nanos(1)))
            .unwrap();
        assert_eq!(s.stats().slo_misses.get(), 1);
        assert_eq!(s.stats().classes[0].slo_misses.get(), 1);
        // A generous SLO is not a miss.
        s.sort_blocking(SortRequest::new(2, vec![2, 1]).with_slo(Duration::from_secs(60)))
            .unwrap();
        assert_eq!(s.stats().slo_misses.get(), 1);
    }

    #[test]
    fn routes_to_smallest_class() {
        let s = svc(&[(4, 64), (4, 1024)]);
        let small = s.sort_blocking(SortRequest::new(1, vec![2, 1])).unwrap();
        assert_eq!(small.keys, vec![1, 2]);
        let big = s
            .sort_blocking(SortRequest::new(2, (0..512u32).rev().collect()))
            .unwrap();
        assert_eq!(big.keys.len(), 512);
        assert!(big.keys.windows(2).all(|w| w[0] <= w[1]));
    }

    /// Mock with a fixed per-batch execution cost (so batches overlap in
    /// time and stealing opportunities actually arise).
    struct SlowMock {
        batch: usize,
        n: usize,
        cost: Duration,
    }

    impl BatchSorter for SlowMock {
        fn shape(&self) -> (usize, usize) {
            (self.batch, self.n)
        }
        fn sort_rows(&self, mut rows: Vec<u32>) -> crate::Result<Vec<u32>> {
            std::thread::sleep(self.cost);
            for r in rows.chunks_mut(self.n) {
                bitonic_sort(r);
            }
            Ok(rows)
        }
    }

    #[test]
    fn idle_workers_steal_ready_batches_across_size_classes() {
        // Two classes, two workers, ALL traffic routed to class 0. With
        // per-class silos the class-1 worker would idle while class-0
        // batches queue behind a 3ms-per-batch backend; with the
        // multi-queue scheduler it must steal them — a mixed-size-class
        // deployment leaves no worker idle while another class has ready
        // batches.
        let s = Service::new(
            vec![
                Arc::new(SlowMock {
                    batch: 2,
                    n: 64,
                    cost: Duration::from_millis(3),
                }) as Arc<dyn BatchSorter>,
                Arc::new(SlowMock {
                    batch: 2,
                    n: 256,
                    cost: Duration::from_millis(3),
                }) as Arc<dyn BatchSorter>,
            ],
            ServiceConfig {
                threads: 2,
                batcher: BatcherConfig {
                    max_wait: Duration::from_micros(200),
                    max_rows: 2,
                    ..BatcherConfig::default()
                },
                ..ServiceConfig::default()
            },
        );
        let rxs: Vec<_> = (0..32)
            .map(|i| s.submit(SortRequest::new(i, vec![3, 1, 2])).unwrap())
            .collect();
        for rx in rxs {
            let resp = rx.recv().unwrap();
            assert_eq!(resp.keys, vec![1, 2, 3]);
        }
        assert!(
            s.stats().stolen_batches.get() > 0,
            "class-1's home worker never stole class-0 batches"
        );
    }

    #[test]
    fn threads_knob_scales_workers_beyond_class_count() {
        // One class, four workers: 16 one-row batches at 3ms each drain
        // ~4× faster than a single silo worker could.
        let s = Service::new(
            vec![Arc::new(SlowMock {
                batch: 1,
                n: 64,
                cost: Duration::from_millis(3),
            }) as Arc<dyn BatchSorter>],
            ServiceConfig {
                threads: 4,
                batcher: BatcherConfig {
                    max_wait: Duration::from_micros(100),
                    max_rows: 1,
                    ..BatcherConfig::default()
                },
                ..ServiceConfig::default()
            },
        );
        let t0 = Instant::now();
        let rxs: Vec<_> = (0..16)
            .map(|i| s.submit(SortRequest::new(i, vec![2, 1])).unwrap())
            .collect();
        for rx in rxs {
            rx.recv().unwrap();
        }
        // Serial would be ≥ 48ms (16×3ms); 4 workers ideal is ~12ms.
        // Assert comfortably below serial so a loaded CI runner cannot
        // flake the bound while a silo regression still trips it.
        assert!(
            t0.elapsed() < Duration::from_millis(36),
            "no cross-worker parallelism: {:?}",
            t0.elapsed()
        );
    }

    #[test]
    fn slo_request_flushes_partial_batch_early() {
        // max_wait would hold a lone request for 10s; its 20ms SLO budget
        // must flush the partial batch long before that.
        let s = svc_with(
            &[(8, 64)],
            ServiceConfig {
                batcher: BatcherConfig {
                    max_wait: Duration::from_secs(10),
                    max_rows: 8,
                    slo_margin: Duration::from_millis(1),
                },
                ..ServiceConfig::default()
            },
        );
        let t0 = Instant::now();
        let resp = s
            .sort_blocking(SortRequest::new(1, vec![2, 1]).with_slo(Duration::from_millis(20)))
            .unwrap();
        assert_eq!(resp.keys, vec![1, 2]);
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "SLO flush never fired: {:?}",
            t0.elapsed()
        );
    }

    #[test]
    fn many_concurrent_clients() {
        let s = svc(&[(8, 256)]);
        let mut gen = crate::workload::Generator::new(99);
        let inputs: Vec<Vec<u32>> = (0..100)
            .map(|i| gen.u32s(1 + (i * 7) % 200, crate::workload::Distribution::Uniform))
            .collect();
        std::thread::scope(|scope| {
            for (i, input) in inputs.iter().enumerate() {
                let s = &s;
                scope.spawn(move || {
                    let resp = s
                        .sort_blocking(SortRequest::new(i as u64, input.clone()))
                        .unwrap();
                    let mut want = input.clone();
                    want.sort_unstable();
                    assert_eq!(resp.keys, want, "request {i}");
                });
            }
        });
        assert_eq!(s.stats().admitted.get(), 100);
    }
}
