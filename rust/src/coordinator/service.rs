//! The service leader: ties router, batchers, admission gate and worker
//! threads together around a [`BatchSorter`] backend per size class.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use super::backpressure::AdmissionGate;
use super::batcher::{Batcher, BatcherConfig, Pending};
use super::request::{ExecPath, SortRequest, SortResponse};
use super::router::{Router, SizeClass};
use crate::util::metrics::{Counter, Histogram};

/// A backend that sorts a full `(batch, n)` row-major buffer ascending.
///
/// Implemented by [`RegistrySorter`] (PJRT artifacts) and by CPU mocks in
/// the test-suite; the service logic is backend-agnostic.
pub trait BatchSorter: Send + Sync {
    /// `(batch_rows, row_len)` of this backend.
    fn shape(&self) -> (usize, usize);
    /// Sort each of the `batch` rows of `rows` ascending. Takes the
    /// buffer by value: the device path ships it across the host-thread
    /// channel anyway, and by-value avoids a defensive copy per batch
    /// (§Perf L3 iteration 1).
    fn sort_rows(&self, rows: Vec<u32>) -> crate::Result<Vec<u32>>;
}

/// [`BatchSorter`] backed by a compiled PJRT artifact, executed via the
/// device-host thread (PJRT objects are `!Send`; see `runtime::host`).
pub struct RegistrySorter {
    handle: crate::runtime::DeviceHandle,
    key: crate::runtime::Key,
    batch: usize,
    n: usize,
}

impl RegistrySorter {
    /// Wrap an (ascending, u32) artifact behind the device handle.
    pub fn new(
        handle: crate::runtime::DeviceHandle,
        meta: &crate::runtime::ArtifactMeta,
    ) -> Self {
        Self {
            handle,
            key: crate::runtime::Key::of(meta),
            batch: meta.batch,
            n: meta.n,
        }
    }
}

impl BatchSorter for RegistrySorter {
    fn shape(&self) -> (usize, usize) {
        (self.batch, self.n)
    }
    fn sort_rows(&self, rows: Vec<u32>) -> crate::Result<Vec<u32>> {
        self.handle.sort_u32(self.key, rows)
    }
}

/// CPU fallback for requests larger than every artifact (or when no
/// artifacts are available): our from-scratch quicksort.
pub struct CpuFallbackSorter;

impl CpuFallbackSorter {
    /// Sort one request's keys on the CPU.
    pub fn sort(&self, keys: &mut [u32], descending: bool) {
        crate::sort::quicksort(keys);
        if descending {
            keys.reverse();
        }
    }
}

/// Service configuration.
#[derive(Clone, Copy, Debug)]
pub struct ServiceConfig {
    /// Batching policy applied to every size class.
    pub batcher: BatcherConfig,
    /// Admission bound (in-flight requests).
    pub max_in_flight: usize,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self {
            batcher: BatcherConfig::default(),
            max_in_flight: 1024,
        }
    }
}

/// Aggregate service statistics.
#[derive(Debug, Default)]
pub struct ServiceStats {
    /// Requests accepted.
    pub admitted: Counter,
    /// Requests rejected by the admission gate.
    pub shed: Counter,
    /// Device batches dispatched.
    pub device_batches: Counter,
    /// Rows occupied across device batches (occupancy = rows/batches·B).
    pub device_rows: Counter,
    /// Requests served by the CPU fallback.
    pub cpu_fallbacks: Counter,
    /// End-to-end latency distribution.
    pub latency: Histogram,
}

struct ClassState {
    batcher: Mutex<Batcher>,
    wake: Condvar,
}

/// The sort service. `submit` never blocks on sorting; responses arrive on
/// per-request channels.
pub struct Service {
    router: Router,
    classes: Vec<Arc<ClassState>>,
    sorters: Vec<Arc<dyn BatchSorter>>,
    fallback: CpuFallbackSorter,
    gate: AdmissionGate,
    stats: Arc<ServiceStats>,
    shutdown: Arc<AtomicBool>,
    workers: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl Service {
    /// Build a service over one backend per size class. Shapes are taken
    /// from the backends; classes with duplicate `n` must not occur.
    pub fn new(sorters: Vec<Arc<dyn BatchSorter>>, config: ServiceConfig) -> Arc<Self> {
        let mut shaped: Vec<(SizeClass, Arc<dyn BatchSorter>)> = sorters
            .into_iter()
            .map(|s| {
                let (batch, n) = s.shape();
                (SizeClass { n, batch }, s)
            })
            .collect();
        // Duplicate row sizes (e.g. batch-1 and batch-8 artifacts for the
        // same n) collapse to the largest batch — matching Router::new.
        // Sort batch-descending within n so dedup keeps the big batch.
        shaped.sort_by_key(|(c, _)| (c.n, std::cmp::Reverse(c.batch)));
        shaped.dedup_by_key(|(c, _)| c.n);
        let router = Router::new(shaped.iter().map(|(c, _)| *c).collect());
        assert_eq!(
            router.classes().len(),
            shaped.len(),
            "router/class mismatch"
        );
        let classes: Vec<Arc<ClassState>> = shaped
            .iter()
            .map(|(c, _)| {
                Arc::new(ClassState {
                    batcher: Mutex::new(Batcher::new(BatcherConfig {
                        max_rows: c.batch,
                        ..config.batcher
                    })),
                    wake: Condvar::new(),
                })
            })
            .collect();
        let service = Arc::new(Self {
            router,
            classes,
            sorters: shaped.into_iter().map(|(_, s)| s).collect(),
            fallback: CpuFallbackSorter,
            gate: AdmissionGate::new(config.max_in_flight),
            stats: Arc::new(ServiceStats::default()),
            shutdown: Arc::new(AtomicBool::new(false)),
            workers: Mutex::new(Vec::new()),
        });
        // One worker per size class.
        let mut workers = service.workers.lock().unwrap();
        for idx in 0..service.classes.len() {
            let svc = Arc::clone(&service);
            workers.push(
                std::thread::Builder::new()
                    .name(format!("sort-class-{idx}"))
                    .spawn(move || svc.worker_loop(idx))
                    .expect("spawn class worker"),
            );
        }
        drop(workers);
        service
    }

    /// Service statistics handle.
    pub fn stats(&self) -> &ServiceStats {
        &self.stats
    }

    /// The router (for introspection / tests).
    pub fn router(&self) -> &Router {
        &self.router
    }

    /// Submit a request. Returns the response channel, or `Err` when shed
    /// by admission control.
    pub fn submit(&self, request: SortRequest) -> Result<Receiver<SortResponse>, SortRequest> {
        let Some(permit) = self.gate.try_acquire() else {
            self.stats.shed.inc();
            return Err(request);
        };
        self.stats.admitted.inc();
        let (tx, rx) = std::sync::mpsc::channel();
        let arrived = Instant::now();
        match self.router.route(request.keys.len()) {
            Some(class) => {
                let state = &self.classes[class];
                let mut batcher = state.batcher.lock().unwrap();
                batcher.push(Pending {
                    request,
                    arrived,
                    reply: tx,
                    permit: Some(permit),
                });
                drop(batcher);
                state.wake.notify_one();
            }
            None => {
                // Oversized (or empty) request: CPU fallback, run inline —
                // submit() is documented to be cheap for routed requests;
                // oversized ones are the caller's explicit trade.
                self.cpu_path(request, arrived, &tx);
                drop(permit);
            }
        }
        Ok(rx)
    }

    /// Convenience: submit and wait.
    pub fn sort_blocking(&self, request: SortRequest) -> Result<SortResponse, SortRequest> {
        let rx = self.submit(request)?;
        Ok(rx.recv().expect("service dropped response channel"))
    }

    fn cpu_path(&self, mut request: SortRequest, arrived: Instant, tx: &Sender<SortResponse>) {
        self.fallback.sort(&mut request.keys, request.descending);
        self.stats.cpu_fallbacks.inc();
        let latency = arrived.elapsed();
        self.stats.latency.record(latency);
        let _ = tx.send(SortResponse {
            id: request.id,
            keys: request.keys,
            path: ExecPath::Cpu,
            latency,
            batch_occupancy: 1,
        });
    }

    fn worker_loop(&self, class: usize) {
        let state = Arc::clone(&self.classes[class]);
        let sorter = Arc::clone(&self.sorters[class]);
        let (batch_rows, n) = sorter.shape();
        loop {
            let batch = {
                let mut batcher = state.batcher.lock().unwrap();
                loop {
                    let now = Instant::now();
                    if batcher.ready(now) {
                        break batcher.take_batch();
                    }
                    if self.shutdown.load(Ordering::Acquire) {
                        if batcher.is_empty() {
                            return;
                        }
                        break batcher.take_batch();
                    }
                    let wait = batcher
                        .next_deadline(now)
                        .unwrap_or(Duration::from_millis(50));
                    let (g, _timeout) = state
                        .wake
                        .wait_timeout(batcher, wait.max(Duration::from_micros(100)))
                        .unwrap();
                    batcher = g;
                }
            };
            if batch.items.is_empty() {
                continue;
            }

            // Assemble the (B, N) buffer writing each request directly
            // into its row (no staging copy); unused rows keep MAX
            // padding (cheapest: they sort to themselves).
            let mut rows: Vec<u32> = Vec::with_capacity(batch_rows * n);
            for item in &batch.items {
                rows.extend_from_slice(&item.request.keys);
                // Row padding: MAX sinks for ascending, 0 for descending
                // (reversed at reply time) — same contract as pad_row.
                let fill = if item.request.descending { 0 } else { u32::MAX };
                rows.resize(rows.len() + (n - item.request.keys.len()), fill);
            }
            rows.resize(batch_rows * n, u32::MAX);

            let occupancy = batch.items.len();
            match sorter.sort_rows(rows) {
                Ok(sorted) => {
                    self.stats.device_batches.inc();
                    self.stats.device_rows.add(occupancy as u64);
                    for (i, item) in batch.items.into_iter().enumerate() {
                        let len = item.request.keys.len();
                        let row = &sorted[i * n..(i + 1) * n];
                        let keys = if item.request.descending {
                            // 0-pads sorted to the front; the request's
                            // keys are the tail — reverse just that slice.
                            row[n - len..].iter().rev().copied().collect()
                        } else {
                            row[..len].to_vec()
                        };
                        let latency = item.arrived.elapsed();
                        self.stats.latency.record(latency);
                        let _ = item.reply.send(SortResponse {
                            id: item.request.id,
                            keys,
                            path: ExecPath::Device,
                            latency,
                            batch_occupancy: occupancy,
                        });
                        drop(item.permit);
                    }
                }
                Err(err) => {
                    // Device failure: degrade to the CPU path per item so
                    // no request is ever dropped.
                    eprintln!("device batch failed ({err:#}); CPU fallback");
                    for item in batch.items {
                        self.cpu_path(item.request, item.arrived, &item.reply);
                        drop(item.permit);
                    }
                }
            }
        }
    }

    /// Stop workers after draining queues.
    pub fn shutdown(&self) {
        self.shutdown.store(true, Ordering::Release);
        for c in &self.classes {
            c.wake.notify_all();
        }
        let mut workers = self.workers.lock().unwrap();
        for w in workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for Service {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sort::bitonic::bitonic_sort;

    /// CPU-backed mock with a given shape (tests run without artifacts).
    pub struct MockSorter {
        pub batch: usize,
        pub n: usize,
        pub calls: Counter,
    }

    impl BatchSorter for MockSorter {
        fn shape(&self) -> (usize, usize) {
            (self.batch, self.n)
        }
        fn sort_rows(&self, mut rows: Vec<u32>) -> crate::Result<Vec<u32>> {
            self.calls.inc();
            for r in rows.chunks_mut(self.n) {
                bitonic_sort(r);
            }
            Ok(rows)
        }
    }

    fn svc(classes: &[(usize, usize)]) -> Arc<Service> {
        let sorters: Vec<Arc<dyn BatchSorter>> = classes
            .iter()
            .map(|&(batch, n)| {
                Arc::new(MockSorter {
                    batch,
                    n,
                    calls: Counter::new(),
                }) as Arc<dyn BatchSorter>
            })
            .collect();
        Service::new(sorters, ServiceConfig::default())
    }

    #[test]
    fn duplicate_row_sizes_collapse_to_largest_batch() {
        let s = svc(&[(1, 64), (8, 64), (4, 256)]);
        assert_eq!(s.router().classes().len(), 2);
        assert_eq!(s.router().classes()[0].batch, 8);
        // And it still serves requests correctly.
        let resp = s.sort_blocking(SortRequest::new(9, vec![3, 1, 2])).unwrap();
        assert_eq!(resp.keys, vec![1, 2, 3]);
    }

    #[test]
    fn sorts_single_request() {
        let s = svc(&[(4, 64)]);
        let resp = s
            .sort_blocking(SortRequest::new(1, vec![5, 3, 9, 1]))
            .unwrap();
        assert_eq!(resp.keys, vec![1, 3, 5, 9]);
        assert_eq!(resp.path, ExecPath::Device);
        assert_eq!(resp.id, 1);
    }

    #[test]
    fn descending_request() {
        let s = svc(&[(4, 64)]);
        let resp = s
            .sort_blocking(SortRequest {
                id: 2,
                keys: vec![5, 3, 9, 1],
                descending: true,
            })
            .unwrap();
        assert_eq!(resp.keys, vec![9, 5, 3, 1]);
    }

    #[test]
    fn oversized_falls_back_to_cpu() {
        let s = svc(&[(4, 64)]);
        let keys: Vec<u32> = (0..1000).rev().collect();
        let resp = s.sort_blocking(SortRequest::new(3, keys)).unwrap();
        assert_eq!(resp.path, ExecPath::Cpu);
        assert!(resp.keys.windows(2).all(|w| w[0] <= w[1]));
        assert_eq!(resp.keys.len(), 1000);
    }

    #[test]
    fn empty_request_ok() {
        let s = svc(&[(4, 64)]);
        let resp = s.sort_blocking(SortRequest::new(4, vec![])).unwrap();
        assert!(resp.keys.is_empty());
    }

    #[test]
    fn batching_packs_concurrent_requests() {
        let s = svc(&[(8, 128)]);
        let rxs: Vec<_> = (0..8)
            .map(|i| {
                s.submit(SortRequest::new(i, vec![8 - i as u32, 1, 2]))
                    .unwrap()
            })
            .collect();
        let mut max_occ = 0;
        for rx in rxs {
            let resp = rx.recv().unwrap();
            assert_eq!(resp.keys.len(), 3);
            max_occ = max_occ.max(resp.batch_occupancy);
        }
        assert!(max_occ > 1, "no batching happened (occupancy {max_occ})");
    }

    #[test]
    fn shed_when_gate_full() {
        let sorters: Vec<Arc<dyn BatchSorter>> = vec![Arc::new(MockSorter {
            batch: 2,
            n: 64,
            calls: Counter::new(),
        })];
        let s = Service::new(
            sorters,
            ServiceConfig {
                max_in_flight: 1,
                batcher: BatcherConfig {
                    max_wait: Duration::from_secs(10), // hold the first one
                    max_rows: 2,
                },
            },
        );
        let _rx = s.submit(SortRequest::new(1, vec![1])).unwrap();
        // Second submit must shed (capacity 1, first still queued).
        let second = s.submit(SortRequest::new(2, vec![2]));
        assert!(second.is_err());
        assert_eq!(s.stats().shed.get(), 1);
    }

    #[test]
    fn routes_to_smallest_class() {
        let s = svc(&[(4, 64), (4, 1024)]);
        let small = s.sort_blocking(SortRequest::new(1, vec![2, 1])).unwrap();
        assert_eq!(small.keys, vec![1, 2]);
        let big = s
            .sort_blocking(SortRequest::new(2, (0..512u32).rev().collect()))
            .unwrap();
        assert_eq!(big.keys.len(), 512);
        assert!(big.keys.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn many_concurrent_clients() {
        let s = svc(&[(8, 256)]);
        let mut gen = crate::workload::Generator::new(99);
        let inputs: Vec<Vec<u32>> = (0..100)
            .map(|i| gen.u32s(1 + (i * 7) % 200, crate::workload::Distribution::Uniform))
            .collect();
        std::thread::scope(|scope| {
            for (i, input) in inputs.iter().enumerate() {
                let s = &s;
                scope.spawn(move || {
                    let resp = s
                        .sort_blocking(SortRequest::new(i as u64, input.clone()))
                        .unwrap();
                    let mut want = input.clone();
                    want.sort_unstable();
                    assert_eq!(resp.keys, want, "request {i}");
                });
            }
        });
        assert_eq!(s.stats().admitted.get(), 100);
    }
}
