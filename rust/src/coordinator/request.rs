//! Request/response types of the sort service.

/// A client sort request. Keys are u32 (the paper's workload); arbitrary
/// length — the router pads to the artifact's power-of-two row size.
#[derive(Clone, Debug)]
pub struct SortRequest {
    /// Caller-chosen id, echoed in the response.
    pub id: u64,
    /// The keys to sort.
    pub keys: Vec<u32>,
    /// Sort direction.
    pub descending: bool,
    /// Optional end-to-end latency budget (SLO). The batcher flushes a
    /// partial batch early rather than letting this expire in queue;
    /// `None` ⇒ only the class's max-wait/max-rows policy applies.
    pub slo: Option<std::time::Duration>,
}

impl SortRequest {
    /// Ascending request with no SLO budget.
    pub fn new(id: u64, keys: Vec<u32>) -> Self {
        Self {
            id,
            keys,
            descending: false,
            slo: None,
        }
    }

    /// Attach an end-to-end latency budget.
    pub fn with_slo(mut self, slo: std::time::Duration) -> Self {
        self.slo = Some(slo);
        self
    }
}

/// Service response.
#[derive(Clone, Debug)]
pub struct SortResponse {
    /// Echo of the request id.
    pub id: u64,
    /// The sorted keys (same length as the request).
    pub keys: Vec<u32>,
    /// Which execution path served it.
    pub path: ExecPath,
    /// Queue wait + execution wall time.
    pub latency: std::time::Duration,
    /// Rows in the device batch this request shared (1 for CPU path).
    pub batch_occupancy: usize,
}

/// Which backend served a request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecPath {
    /// PJRT artifact (the accelerator path).
    Device,
    /// CPU fallback (no artifact fits, or fallback forced).
    Cpu,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_constructor_defaults_ascending() {
        let r = SortRequest::new(7, vec![3, 1]);
        assert_eq!(r.id, 7);
        assert!(!r.descending);
        assert!(r.slo.is_none());
        let r = r.with_slo(std::time::Duration::from_millis(5));
        assert_eq!(r.slo, Some(std::time::Duration::from_millis(5)));
    }
}
