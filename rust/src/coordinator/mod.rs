//! Layer-3 coordinator: sort-as-a-service.
//!
//! The paper's contribution is a *kernel* technique, so L3 is the serving
//! scaffold that turns the compiled sort artifacts into a deployable
//! service (the vLLM-router shape adapted to sorting):
//!
//! ```text
//!                    ┌────────────┐   per-class queues   ┌──────────┐
//!  submit(keys) ───> │   Router   │ ───────────────────> │ Batcher  │
//!                    │ pad→2^k,   │                      │ SLO/wait/ │
//!                    │ pick class │                      │ capacity │
//!                    └────────────┘                      └────┬─────┘
//!        bounded admission (Backpressure)                    │ (B,N) batch
//!                                                  ┌─────────▼────────┐
//!  response channel <──────────────────────────────│ Worker pool      │──> PJRT
//!                                                  │ (work stealing)  │  executor
//!                                                  └──────────────────┘
//! ```
//!
//! The queues are per size class but the workers are not: each worker
//! scans its *home* class first and steals ready batches from any other
//! class, so no worker idles while dispatchable work exists anywhere
//! (`ServiceConfig::threads` sizes the pool). Batchers flush on capacity,
//! max-wait, or when a pending request's SLO budget is about to expire
//! (`SortRequest::slo` + `BatcherConfig::slo_margin`).
//!
//! Off-process callers reach `submit` through the TCP front-end in
//! [`net`] (length-prefixed binary frames over `std::net`, served by
//! `bitonic-tpu serve-tcp`, measured by `bitonic-tpu loadgen`).
//!
//! Invariants (property-tested in `rust/tests/coordinator_props.rs`):
//! every admitted request is answered exactly once; the answer is the
//! sorted multiset of its input; a batch never mixes size classes; queue
//! depth never exceeds the configured bound; shedding happens only when
//! the bound is hit.

pub mod backpressure;
pub mod batcher;
pub mod net;
pub mod request;
pub mod router;
pub mod service;

pub use backpressure::AdmissionGate;
pub use batcher::{Batch, Batcher, BatcherConfig};
pub use net::{NetClient, NetServer, NetServerConfig, SortReply};
pub use request::{SortRequest, SortResponse};
pub use router::{Router, SizeClass};
pub use service::{
    BatchSorter, ClassStats, CpuFallbackSorter, RegistrySorter, Service, ServiceConfig,
    ServiceStats,
};
