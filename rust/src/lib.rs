//! # bitonic-tpu
//!
//! A three-layer (rust + JAX + Pallas, AOT via PJRT) reproduction of
//! *"The implementation and optimization of Bitonic sort algorithm based
//! on CUDA"* (Qi Mu, Liqing Cui, Yufei Song; CS.DC 2015).
//!
//! The crate is organised as the paper's system plus every substrate it
//! depends on (see `DESIGN.md` for the full inventory):
//!
//! * [`sort`] — from-scratch CPU sorting substrates: the paper's two CPU
//!   baselines (quick sort, sequential bitonic sort), the multicore
//!   bitonic sort the paper lists as future work, auxiliary baselines
//!   (radix / heap / merge / odd-even), and the bitonic *network schedule*
//!   generator shared with the simulator and (conceptually) with the
//!   Pallas kernels.
//! * [`sim`] — a cost-model simulator of the paper's Kepler K10 GPU:
//!   launch counts, global-memory passes and shared-memory traffic are
//!   derived from the exact per-variant step schedule; used to regenerate
//!   Table 1's GPU columns in *shape* (we have no CUDA hardware).
//! * [`runtime`] — the PJRT bridge: loads `artifacts/*.hlo.txt`
//!   (AOT-lowered by `python/compile/aot.py`, Pallas kernels in interpret
//!   mode), compiles them once on the CPU PJRT client, and executes them
//!   on the request path. Python never runs at request time.
//! * [`coordinator`] — the L3 sort-as-a-service layer: request router
//!   with pad-to-power-of-two size classes, deadline/capacity dynamic
//!   batcher that packs requests into the artifacts' `(B, N)` row-sorted
//!   executions, bounded queues with shedding, and a worker pool.
//! * [`workload`] — PRNGs and input distributions for experiments.
//! * [`bench`] — the benchmark subsystem: measurement harness
//!   (criterion stand-in), the survey-style scenario matrix
//!   ([`bench::matrix`]), the unified machine-readable trajectory every
//!   bench appends to (`BENCH_trajectory.json`, [`bench::record`]), and
//!   the `RESULTS.md` generator ([`bench::report`]).
//! * [`util`] — error handling ([`util::error`]), CLI parsing, JSON
//!   builder + parser ([`util::json`]), thread pool, metrics,
//!   property-testing and table formatting substrates (their crates.io
//!   equivalents are unavailable offline).
//! * [`analysis`] — the static plan verifier (`bitonic-tpu
//!   verify-plans`): proves every compiled launch program sorts (0–1
//!   principle), proves parallel schedules write-disjoint, and audits
//!   the artifact manifest — all before anything executes. See README
//!   "Static guarantees".
//!
//! ## Where the numbers live
//!
//! Performance claims in this repo are backed by the bench trajectory:
//! `bitonic-tpu bench` (or any `cargo bench` binary) appends
//! schema-validated records to `BENCH_trajectory.json`, and `bitonic-tpu
//! report` regenerates `RESULTS.md` from it deterministically — see
//! README "Benchmarks & results".

// Public API is the reproduction's documentation of record; undocumented
// items are a defect the build should flag.
#![warn(missing_docs)]
// Every unsafe operation must sit in its own `unsafe {}` block with a
// SAFETY argument, even inside `unsafe fn` — the disjointness checker
// (`analysis::disjoint`) proves those arguments; the blocks must stay
// visible for the proofs to be auditable.
#![deny(unsafe_op_in_unsafe_fn)]

pub mod analysis;
pub mod bench;
pub mod coordinator;
pub mod runtime;
pub mod sim;
pub mod sort;
pub mod util;
pub mod workload;

/// Crate-wide result type (see [`util::error`] for the error subsystem).
pub type Result<T, E = util::error::Error> = std::result::Result<T, E>;
