//! Plain-text table rendering for benchmark reports (EXPERIMENTS.md and
//! the Table-1 regeneration output).

/// Column alignment.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Align {
    /// Left-aligned (labels).
    Left,
    /// Right-aligned (numbers).
    Right,
}

/// A simple text table builder.
#[derive(Clone, Debug)]
pub struct Table {
    headers: Vec<String>,
    aligns: Vec<Align>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// New table with the given headers; first column left-aligned, the
    /// rest right-aligned (the usual benchmark layout).
    pub fn new<S: Into<String>>(headers: Vec<S>) -> Self {
        let headers: Vec<String> = headers.into_iter().map(Into::into).collect();
        let aligns = headers
            .iter()
            .enumerate()
            .map(|(i, _)| if i == 0 { Align::Left } else { Align::Right })
            .collect();
        Self {
            headers,
            aligns,
            rows: Vec::new(),
        }
    }

    /// Override column alignments.
    pub fn aligns(mut self, aligns: Vec<Align>) -> Self {
        assert_eq!(aligns.len(), self.headers.len());
        self.aligns = aligns;
        self
    }

    /// Append a row (must match header arity).
    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) -> &mut Self {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(
            cells.len(),
            self.headers.len(),
            "row arity {} != header arity {}",
            cells.len(),
            self.headers.len()
        );
        self.rows.push(cells);
        self
    }

    /// Number of data rows.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// True if no data rows.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.headers.iter().map(String::len).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        w
    }

    /// Render as an aligned plain-text table.
    pub fn render(&self) -> String {
        let w = self.widths();
        let mut out = String::new();
        let fmt_row = |cells: &[String], w: &[usize], aligns: &[Align]| -> String {
            let mut line = String::new();
            for (i, c) in cells.iter().enumerate() {
                if i > 0 {
                    line.push_str("  ");
                }
                match aligns[i] {
                    Align::Left => line.push_str(&format!("{:<width$}", c, width = w[i])),
                    Align::Right => line.push_str(&format!("{:>width$}", c, width = w[i])),
                }
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.headers, &w, &self.aligns));
        out.push('\n');
        out.push_str(&"-".repeat(w.iter().sum::<usize>() + 2 * (w.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &w, &self.aligns));
            out.push('\n');
        }
        out
    }

    /// Render as a GitHub-flavoured markdown table (for EXPERIMENTS.md).
    pub fn render_markdown(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("| {} |\n", self.headers.join(" | ")));
        out.push_str(&format!(
            "|{}|\n",
            self.aligns
                .iter()
                .map(|a| match a {
                    Align::Left => "---",
                    Align::Right => "---:",
                })
                .collect::<Vec<_>>()
                .join("|")
        ));
        for row in &self.rows {
            out.push_str(&format!("| {} |\n", row.join(" | ")));
        }
        out
    }
}

/// Format a millisecond value the way the paper's Table 1 does.
pub fn fmt_ms(ms: f64) -> String {
    if ms >= 100.0 {
        format!("{ms:.2}")
    } else if ms >= 1.0 {
        format!("{ms:.2}")
    } else {
        format!("{ms:.3}")
    }
}

/// Human-readable power-of-two size (the paper's "128K", "1M", … labels).
pub fn fmt_size(n: usize) -> String {
    if n >= 1 << 20 && n % (1 << 20) == 0 {
        format!("{}M", n >> 20)
    } else if n >= 1 << 10 && n % (1 << 10) == 0 {
        format!("{}K", n >> 10)
    } else {
        format!("{n}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(vec!["name", "value"]);
        t.row(vec!["a", "1"]);
        t.row(vec!["long-name", "123456"]);
        let s = t.render();
        assert!(s.contains("name"));
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        // All data lines same width as header line (trailing trim aside).
        assert!(lines[3].ends_with("123456"));
    }

    #[test]
    fn markdown_shape() {
        let mut t = Table::new(vec!["a", "b"]);
        t.row(vec!["x", "1"]);
        let md = t.render_markdown();
        assert!(md.starts_with("| a | b |\n|---|---:|\n"));
        assert!(md.contains("| x | 1 |"));
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        let mut t = Table::new(vec!["a", "b"]);
        t.row(vec!["only-one"]);
    }

    #[test]
    fn size_labels_match_paper() {
        assert_eq!(fmt_size(128 << 10), "128K");
        assert_eq!(fmt_size(256 << 10), "256K");
        assert_eq!(fmt_size(1 << 20), "1M");
        assert_eq!(fmt_size(256 << 20), "256M");
        assert_eq!(fmt_size(1000), "1000");
    }

    #[test]
    fn ms_formatting() {
        assert_eq!(fmt_ms(0.36), "0.360");
        assert_eq!(fmt_ms(30.0), "30.00");
        assert_eq!(fmt_ms(1727.23), "1727.23");
    }
}
