//! Minimal JSON document builder **and parser** (serde is unavailable
//! offline): enough to emit and reload machine-readable bench reports
//! like `BENCH_trajectory.json` — insertion-ordered objects, pretty
//! printing, correct string escaping, and a strict recursive-descent
//! reader ([`Json::parse`]).
//!
//! The parser exists because the bench trajectory is read back by this
//! crate itself: `bitonic-tpu report` regenerates `RESULTS.md` from the
//! JSON the benches append (see [`crate::bench::record`]), and every
//! bench run appends to the existing file rather than clobbering it. It
//! is strict (no trailing commas or garbage, control characters must be
//! escaped, depth-limited) so a hand-edited trajectory fails loudly at
//! load instead of producing a quietly wrong report.
//!
//! `render` → `parse` round-trips every value except the float forms
//! that [`Json::render`] normalises on output (non-finite numbers become
//! `null`, integral floats print without a decimal point).

/// A JSON value. Objects keep insertion order so reports diff cleanly.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null` (also what non-finite floats become).
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any finite number.
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object, insertion-ordered.
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Empty object.
    pub fn obj() -> Self {
        Json::Obj(Vec::new())
    }

    /// Empty array.
    pub fn arr() -> Self {
        Json::Arr(Vec::new())
    }

    /// Set `key` on an object (replacing an existing key in place).
    /// Panics on non-objects — report-building is programmer-controlled.
    pub fn set(&mut self, key: &str, value: impl Into<Json>) -> &mut Self {
        match self {
            Json::Obj(fields) => {
                let value = value.into();
                if let Some(f) = fields.iter_mut().find(|(k, _)| k == key) {
                    f.1 = value;
                } else {
                    fields.push((key.to_string(), value));
                }
            }
            other => panic!("Json::set on non-object {other:?}"),
        }
        self
    }

    /// Append to an array. Panics on non-arrays.
    pub fn push(&mut self, value: impl Into<Json>) -> &mut Self {
        match self {
            Json::Arr(items) => items.push(value.into()),
            other => panic!("Json::push on non-array {other:?}"),
        }
        self
    }

    /// Field of an object (first match), `None` on non-objects too.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// String payload, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s.as_str()),
            _ => None,
        }
    }

    /// Numeric payload, if this is a (finite) number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// Numeric payload as a non-negative integer: the number must be
    /// integral and fit `usize` (sizes, batches, counts).
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(x) if *x >= 0.0 && *x == x.trunc() && *x < 9e15 => Some(*x as usize),
            _ => None,
        }
    }

    /// Boolean payload, if this is a bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Array items, if this is an array.
    pub fn items(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(items) => Some(items.as_slice()),
            _ => None,
        }
    }

    /// Object fields in insertion order, if this is an object.
    pub fn fields(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(fields) => Some(fields.as_slice()),
            _ => None,
        }
    }

    /// Parse a complete JSON document (strict: exactly one value, no
    /// trailing garbage, nesting depth ≤ 128).
    pub fn parse(text: &str) -> crate::Result<Json> {
        let mut p = Reader {
            bytes: text.as_bytes(),
            pos: 0,
            depth: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        crate::ensure!(
            p.pos == p.bytes.len(),
            "JSON: trailing data at byte {} of {}",
            p.pos,
            p.bytes.len()
        );
        Ok(v)
    }

    /// Pretty-print with two-space indentation and a trailing newline.
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if !x.is_finite() {
                    out.push_str("null");
                } else if *x == x.trunc() && x.abs() < 9e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('\n');
                    indent(out, depth + 1);
                    item.write(out, depth + 1);
                }
                out.push('\n');
                indent(out, depth);
                out.push(']');
            }
            Json::Obj(fields) => {
                if fields.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('\n');
                    indent(out, depth + 1);
                    write_escaped(out, k);
                    out.push_str(": ");
                    v.write(out, depth + 1);
                }
                out.push('\n');
                indent(out, depth);
                out.push('}');
            }
        }
    }
}

/// Strict recursive-descent JSON reader over the input bytes. The input
/// is a `&str`, so the bytes are valid UTF-8 throughout; the reader only
/// ever stops on ASCII structural characters, which keeps `pos` on char
/// boundaries.
struct Reader<'a> {
    bytes: &'a [u8],
    pos: usize,
    depth: usize,
}

/// Containers deeper than this are rejected (keeps a hostile input from
/// overflowing the parse stack; real trajectories nest ~4 levels).
const MAX_DEPTH: usize = 128;

impl Reader<'_> {
    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn next_byte(&mut self) -> crate::Result<u8> {
        let b = self
            .peek()
            .ok_or_else(|| crate::err!("JSON: unexpected end of input at byte {}", self.pos))?;
        self.pos += 1;
        Ok(b)
    }

    fn expect(&mut self, want: u8) -> crate::Result<()> {
        let got = self.next_byte()?;
        crate::ensure!(
            got == want,
            "JSON: expected {:?} at byte {}, got {:?}",
            want as char,
            self.pos - 1,
            got as char
        );
        Ok(())
    }

    /// Consume the exact ASCII keyword `kw` (after its first byte has
    /// been peeked by the caller).
    fn literal(&mut self, kw: &str, value: Json) -> crate::Result<Json> {
        if self.bytes[self.pos..].starts_with(kw.as_bytes()) {
            self.pos += kw.len();
            Ok(value)
        } else {
            crate::bail!("JSON: bad literal at byte {} (expected {kw:?})", self.pos)
        }
    }

    fn value(&mut self) -> crate::Result<Json> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(b'-') | Some(b'0'..=b'9') => self.number(),
            Some(other) => crate::bail!(
                "JSON: unexpected byte {:?} at {}",
                other as char,
                self.pos
            ),
            None => crate::bail!("JSON: unexpected end of input at byte {}", self.pos),
        }
    }

    fn eat_digits(&mut self) -> usize {
        let start = self.pos;
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        self.pos - start
    }

    fn number(&mut self) -> crate::Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        let int_start = self.pos;
        crate::ensure!(self.eat_digits() > 0, "JSON: bad number at byte {start}");
        // RFC 8259: no leading zeros ("0123" is not a number) — stdlib
        // readers of the trajectory would reject what we accepted.
        crate::ensure!(
            self.bytes[int_start] != b'0' || self.pos == int_start + 1,
            "JSON: leading zero in number at byte {start}"
        );
        if self.peek() == Some(b'.') {
            self.pos += 1;
            crate::ensure!(
                self.eat_digits() > 0,
                "JSON: digits must follow '.' at byte {}",
                self.pos
            );
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            crate::ensure!(
                self.eat_digits() > 0,
                "JSON: digits must follow exponent at byte {}",
                self.pos
            );
        }
        // The scanned slice matches the JSON number grammar, so it is
        // ASCII and f64::from_str accepts it; only overflow can fail us.
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        let x: f64 = s
            .parse()
            .map_err(|e| crate::err!("JSON: number {s:?} at byte {start}: {e}"))?;
        crate::ensure!(x.is_finite(), "JSON: number {s:?} overflows f64");
        Ok(Json::Num(x))
    }

    fn hex4(&mut self) -> crate::Result<u32> {
        crate::ensure!(
            self.pos + 4 <= self.bytes.len(),
            "JSON: truncated \\u escape at byte {}",
            self.pos
        );
        let s = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
            .map_err(|_| crate::err!("JSON: non-ASCII \\u escape at byte {}", self.pos))?;
        let v = u32::from_str_radix(s, 16)
            .map_err(|_| crate::err!("JSON: bad \\u escape {s:?} at byte {}", self.pos))?;
        self.pos += 4;
        Ok(v)
    }

    fn string(&mut self) -> crate::Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let b = self.next_byte()?;
            match b {
                b'"' => return Ok(out),
                b'\\' => match self.next_byte()? {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'b' => out.push('\u{0008}'),
                    b'f' => out.push('\u{000C}'),
                    b'n' => out.push('\n'),
                    b'r' => out.push('\r'),
                    b't' => out.push('\t'),
                    b'u' => {
                        let hi = self.hex4()?;
                        let cp = if (0xD800..0xDC00).contains(&hi) {
                            // High surrogate: a low surrogate escape must
                            // follow; combine into one code point.
                            self.expect(b'\\')?;
                            self.expect(b'u')?;
                            let lo = self.hex4()?;
                            crate::ensure!(
                                (0xDC00..0xE000).contains(&lo),
                                "JSON: unpaired surrogate \\u{hi:04x} at byte {}",
                                self.pos
                            );
                            0x1_0000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                        } else {
                            hi
                        };
                        out.push(char::from_u32(cp).ok_or_else(|| {
                            crate::err!("JSON: invalid code point \\u{cp:04x}")
                        })?);
                    }
                    other => crate::bail!(
                        "JSON: bad escape \\{} at byte {}",
                        other as char,
                        self.pos - 1
                    ),
                },
                c if c < 0x20 => crate::bail!(
                    "JSON: unescaped control character 0x{c:02x} at byte {}",
                    self.pos - 1
                ),
                c if c < 0x80 => out.push(c as char),
                c => {
                    // Multi-byte UTF-8: the input is a valid &str, so the
                    // full sequence is present — copy it through.
                    let len = if c >= 0xF0 {
                        4
                    } else if c >= 0xE0 {
                        3
                    } else {
                        2
                    };
                    let start = self.pos - 1;
                    crate::ensure!(
                        start + len <= self.bytes.len(),
                        "JSON: truncated UTF-8 at byte {start}"
                    );
                    self.pos = start + len;
                    out.push_str(std::str::from_utf8(&self.bytes[start..start + len]).unwrap());
                }
            }
        }
    }

    fn array(&mut self) -> crate::Result<Json> {
        self.depth += 1;
        crate::ensure!(self.depth <= MAX_DEPTH, "JSON: nesting deeper than {MAX_DEPTH}");
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.next_byte()? {
                b',' => continue,
                b']' => break,
                other => crate::bail!(
                    "JSON: expected ',' or ']' at byte {}, got {:?}",
                    self.pos - 1,
                    other as char
                ),
            }
        }
        self.depth -= 1;
        Ok(Json::Arr(items))
    }

    fn object(&mut self) -> crate::Result<Json> {
        self.depth += 1;
        crate::ensure!(self.depth <= MAX_DEPTH, "JSON: nesting deeper than {MAX_DEPTH}");
        self.expect(b'{')?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(Json::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let value = self.value()?;
            fields.push((key, value));
            self.skip_ws();
            match self.next_byte()? {
                b',' => continue,
                b'}' => break,
                other => crate::bail!(
                    "JSON: expected ',' or '}}' at byte {}, got {:?}",
                    self.pos - 1,
                    other as char
                ),
            }
        }
        self.depth -= 1;
        Ok(Json::Obj(fields))
    }
}

fn indent(out: &mut String, depth: usize) {
    for _ in 0..depth {
        out.push_str("  ");
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}
impl From<f64> for Json {
    fn from(x: f64) -> Self {
        if x.is_finite() {
            Json::Num(x)
        } else {
            Json::Null
        }
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Self {
        Json::Num(x as f64)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Self {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Self {
        Json::Num(x as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Self {
        Json::Str(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_nested_document() {
        let mut doc = Json::obj();
        doc.set("name", "ablation").set("passes", 11usize).set("ok", true);
        let mut rows = Json::arr();
        let mut row = Json::obj();
        row.set("variant", "optimized").set("rows_per_sec", 1234.5f64);
        rows.push(row);
        doc.set("rows", rows);
        let s = doc.render();
        assert!(s.contains("\"name\": \"ablation\""), "{s}");
        assert!(s.contains("\"passes\": 11"), "{s}");
        assert!(s.contains("\"rows_per_sec\": 1234.5"), "{s}");
        assert!(s.ends_with("}\n"), "{s}");
        // Balanced braces/brackets (cheap well-formedness check).
        assert_eq!(s.matches('{').count(), s.matches('}').count());
        assert_eq!(s.matches('[').count(), s.matches(']').count());
    }

    #[test]
    fn integers_render_without_decimals() {
        assert_eq!(Json::from(42usize).render(), "42\n");
        assert_eq!(Json::from(1e6).render(), "1000000\n");
        assert_eq!(Json::from(1.25).render(), "1.25\n");
    }

    #[test]
    fn non_finite_numbers_become_null() {
        assert_eq!(Json::from(f64::NAN).render(), "null\n");
        assert_eq!(Json::from(f64::INFINITY).render(), "null\n");
    }

    #[test]
    fn strings_escape_specials() {
        let s = Json::from("a\"b\\c\nd\te\u{1}").render();
        assert_eq!(s, "\"a\\\"b\\\\c\\nd\\te\\u0001\"\n");
    }

    #[test]
    fn set_replaces_existing_key_in_place() {
        let mut o = Json::obj();
        o.set("k", 1usize).set("j", 2usize).set("k", 3usize);
        match &o {
            Json::Obj(fields) => {
                assert_eq!(fields.len(), 2);
                assert_eq!(fields[0], ("k".to_string(), Json::Num(3.0)));
            }
            _ => unreachable!(),
        }
    }

    #[test]
    fn empty_collections_render_compact() {
        assert_eq!(Json::obj().render(), "{}\n");
        assert_eq!(Json::arr().render(), "[]\n");
    }

    // --- parser ----------------------------------------------------------

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.25e2").unwrap(), Json::Num(-125.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
        assert_eq!(Json::parse("  7  ").unwrap(), Json::Num(7.0));
    }

    #[test]
    fn parse_nested_document_preserves_order() {
        let doc = Json::parse(
            r#"{"b": [1, 2, {"x": null}], "a": {"k": "v"}, "n": -0.5}"#,
        )
        .unwrap();
        let fields = doc.fields().unwrap();
        assert_eq!(fields[0].0, "b");
        assert_eq!(fields[1].0, "a");
        assert_eq!(doc.get("n"), Some(&Json::Num(-0.5)));
        assert_eq!(doc.get("b").unwrap().items().unwrap().len(), 3);
        assert_eq!(
            doc.get("a").unwrap().get("k").and_then(Json::as_str),
            Some("v")
        );
    }

    #[test]
    fn parse_string_escapes() {
        let s = Json::parse(r#""a\"b\\c\nd\teA☃""#).unwrap();
        assert_eq!(s.as_str(), Some("a\"b\\c\nd\teA☃"));
        // \uXXXX escapes, BMP and (via surrogate pair) astral.
        let s = Json::parse(r#""\u0041\u2603""#).unwrap();
        assert_eq!(s.as_str(), Some("A☃"));
        let s = Json::parse(r#""\ud83d\ude00""#).unwrap();
        assert_eq!(s.as_str(), Some("😀"));
        // Raw astral chars pass through unescaped too.
        let s = Json::parse("\"😀\"").unwrap();
        assert_eq!(s.as_str(), Some("😀"));
        // Raw (unescaped) multi-byte UTF-8 passes through.
        let s = Json::parse("\"héllo ☃\"").unwrap();
        assert_eq!(s.as_str(), Some("héllo ☃"));
    }

    #[test]
    fn render_parse_roundtrip() {
        let mut doc = Json::obj();
        doc.set("name", "trajectory \"v1\"\n")
            .set("count", 3usize)
            .set("ratio", 1.5)
            .set("ok", true)
            .set("missing", Json::Null);
        let mut arr = Json::arr();
        arr.push(1u64).push("two").push(Json::obj());
        doc.set("items", arr);
        let text = doc.render();
        assert_eq!(Json::parse(&text).unwrap(), doc);
    }

    #[test]
    fn parse_rejects_malformed() {
        for bad in [
            "",
            "{",
            "[1, 2",
            "{\"a\": }",
            "{\"a\" 1}",
            "[1,, 2]",
            "nul",
            "truex",
            "1 2",
            "{\"a\": 1} garbage",
            "\"unterminated",
            "\"bad \\q escape\"",
            "\"ctrl \u{1} char\"",
            "\"\\ud83d alone\"",
            "'single'",
            "- 1",
            "1.",
            ".5",
            "1e",
            "1e999",
            "0123",
            "-012",
            "[1] ]",
        ] {
            assert!(Json::parse(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn parse_depth_limited() {
        let deep = "[".repeat(200) + &"]".repeat(200);
        assert!(Json::parse(&deep).is_err());
        let ok = "[".repeat(100) + &"]".repeat(100);
        assert!(Json::parse(&ok).is_ok());
    }

    #[test]
    fn accessors_type_check() {
        let doc = Json::parse(r#"{"s": "x", "n": 3, "f": 1.5, "b": false, "a": [1]}"#).unwrap();
        assert_eq!(doc.get("s").and_then(Json::as_str), Some("x"));
        assert_eq!(doc.get("n").and_then(Json::as_usize), Some(3));
        assert_eq!(doc.get("f").and_then(Json::as_usize), None);
        assert_eq!(doc.get("f").and_then(Json::as_f64), Some(1.5));
        assert_eq!(doc.get("b").and_then(Json::as_bool), Some(false));
        assert_eq!(doc.get("a").and_then(Json::items).map(<[Json]>::len), Some(1));
        assert_eq!(doc.get("nope"), None);
        assert_eq!(Json::Null.get("s"), None);
        assert_eq!(Json::Num(-1.0).as_usize(), None);
    }
}
