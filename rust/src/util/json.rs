//! Minimal JSON document builder (serde is unavailable offline): just
//! enough to emit machine-readable bench/tuning reports like
//! `BENCH_ablation.json` — insertion-ordered objects, pretty printing,
//! correct string escaping, nothing else. There is deliberately no
//! parser; the reports are write-only from this crate's point of view
//! (future PRs diff them as text or load them with real tooling).

/// A JSON value. Objects keep insertion order so reports diff cleanly.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null` (also what non-finite floats become).
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any finite number.
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object, insertion-ordered.
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Empty object.
    pub fn obj() -> Self {
        Json::Obj(Vec::new())
    }

    /// Empty array.
    pub fn arr() -> Self {
        Json::Arr(Vec::new())
    }

    /// Set `key` on an object (replacing an existing key in place).
    /// Panics on non-objects — report-building is programmer-controlled.
    pub fn set(&mut self, key: &str, value: impl Into<Json>) -> &mut Self {
        match self {
            Json::Obj(fields) => {
                let value = value.into();
                if let Some(f) = fields.iter_mut().find(|(k, _)| k == key) {
                    f.1 = value;
                } else {
                    fields.push((key.to_string(), value));
                }
            }
            other => panic!("Json::set on non-object {other:?}"),
        }
        self
    }

    /// Append to an array. Panics on non-arrays.
    pub fn push(&mut self, value: impl Into<Json>) -> &mut Self {
        match self {
            Json::Arr(items) => items.push(value.into()),
            other => panic!("Json::push on non-array {other:?}"),
        }
        self
    }

    /// Pretty-print with two-space indentation and a trailing newline.
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if !x.is_finite() {
                    out.push_str("null");
                } else if *x == x.trunc() && x.abs() < 9e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('\n');
                    indent(out, depth + 1);
                    item.write(out, depth + 1);
                }
                out.push('\n');
                indent(out, depth);
                out.push(']');
            }
            Json::Obj(fields) => {
                if fields.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('\n');
                    indent(out, depth + 1);
                    write_escaped(out, k);
                    out.push_str(": ");
                    v.write(out, depth + 1);
                }
                out.push('\n');
                indent(out, depth);
                out.push('}');
            }
        }
    }
}

fn indent(out: &mut String, depth: usize) {
    for _ in 0..depth {
        out.push_str("  ");
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}
impl From<f64> for Json {
    fn from(x: f64) -> Self {
        if x.is_finite() {
            Json::Num(x)
        } else {
            Json::Null
        }
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Self {
        Json::Num(x as f64)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Self {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Self {
        Json::Num(x as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Self {
        Json::Str(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_nested_document() {
        let mut doc = Json::obj();
        doc.set("name", "ablation").set("passes", 11usize).set("ok", true);
        let mut rows = Json::arr();
        let mut row = Json::obj();
        row.set("variant", "optimized").set("rows_per_sec", 1234.5f64);
        rows.push(row);
        doc.set("rows", rows);
        let s = doc.render();
        assert!(s.contains("\"name\": \"ablation\""), "{s}");
        assert!(s.contains("\"passes\": 11"), "{s}");
        assert!(s.contains("\"rows_per_sec\": 1234.5"), "{s}");
        assert!(s.ends_with("}\n"), "{s}");
        // Balanced braces/brackets (cheap well-formedness check).
        assert_eq!(s.matches('{').count(), s.matches('}').count());
        assert_eq!(s.matches('[').count(), s.matches(']').count());
    }

    #[test]
    fn integers_render_without_decimals() {
        assert_eq!(Json::from(42usize).render(), "42\n");
        assert_eq!(Json::from(1e6).render(), "1000000\n");
        assert_eq!(Json::from(1.25).render(), "1.25\n");
    }

    #[test]
    fn non_finite_numbers_become_null() {
        assert_eq!(Json::from(f64::NAN).render(), "null\n");
        assert_eq!(Json::from(f64::INFINITY).render(), "null\n");
    }

    #[test]
    fn strings_escape_specials() {
        let s = Json::from("a\"b\\c\nd\te\u{1}").render();
        assert_eq!(s, "\"a\\\"b\\\\c\\nd\\te\\u0001\"\n");
    }

    #[test]
    fn set_replaces_existing_key_in_place() {
        let mut o = Json::obj();
        o.set("k", 1usize).set("j", 2usize).set("k", 3usize);
        match &o {
            Json::Obj(fields) => {
                assert_eq!(fields.len(), 2);
                assert_eq!(fields[0], ("k".to_string(), Json::Num(3.0)));
            }
            _ => unreachable!(),
        }
    }

    #[test]
    fn empty_collections_render_compact() {
        assert_eq!(Json::obj().render(), "{}\n");
        assert_eq!(Json::arr().render(), "[]\n");
    }
}
