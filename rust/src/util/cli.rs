//! Minimal command-line argument parser (clap is unavailable offline).
//!
//! Supports subcommands, `--flag`, `--key value` / `--key=value`, and
//! positional arguments, with typed accessors and generated usage text —
//! enough for the `bitonic-tpu` binary and the bench/example drivers.

use std::collections::BTreeMap;

/// Declarative description of one option (for usage text and validation).
#[derive(Clone, Debug)]
pub struct OptSpec {
    /// Long name without the leading `--`.
    pub name: &'static str,
    /// Help text.
    pub help: &'static str,
    /// `true` if the option is a boolean flag (no value).
    pub is_flag: bool,
    /// Default value rendered in help (None = required or flag).
    pub default: Option<&'static str>,
}

/// Parsed arguments.
#[derive(Clone, Debug, Default)]
pub struct Args {
    /// The subcommand, if the grammar has one.
    pub command: Option<String>,
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    positionals: Vec<String>,
}

impl Args {
    /// String option value.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(String::as_str)
    }

    /// String option with default.
    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    /// Typed option value (parse error is reported with the key name).
    pub fn get_parsed<T: std::str::FromStr>(&self, key: &str) -> crate::Result<Option<T>>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None => Ok(None),
            Some(s) => s
                .parse::<T>()
                .map(Some)
                .map_err(|e| crate::err!("--{key} {s:?}: {e}")),
        }
    }

    /// Typed option with default.
    pub fn parsed_or<T: std::str::FromStr>(&self, key: &str, default: T) -> crate::Result<T>
    where
        T::Err: std::fmt::Display,
    {
        Ok(self.get_parsed(key)?.unwrap_or(default))
    }

    /// Was the boolean flag given?
    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }

    /// Positional arguments in order.
    pub fn positionals(&self) -> &[String] {
        &self.positionals
    }
}

/// Command-line grammar: optional subcommand list plus option specs.
#[derive(Clone, Debug, Default)]
pub struct Parser {
    /// Binary name for usage text.
    pub program: &'static str,
    /// One-line description.
    pub about: &'static str,
    /// Known subcommands (empty = no subcommand level).
    pub commands: Vec<(&'static str, &'static str)>,
    /// Known options.
    pub opts: Vec<OptSpec>,
}

impl Parser {
    /// New grammar.
    pub fn new(program: &'static str, about: &'static str) -> Self {
        Self {
            program,
            about,
            ..Default::default()
        }
    }

    /// Add a subcommand.
    pub fn command(mut self, name: &'static str, help: &'static str) -> Self {
        self.commands.push((name, help));
        self
    }

    /// Add a `--key value` option.
    pub fn opt(mut self, name: &'static str, help: &'static str, default: Option<&'static str>) -> Self {
        self.opts.push(OptSpec {
            name,
            help,
            is_flag: false,
            default,
        });
        self
    }

    /// Add a boolean `--flag`.
    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec {
            name,
            help,
            is_flag: true,
            default: None,
        });
        self
    }

    /// Usage text.
    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nUSAGE:\n  {}", self.program, self.about, self.program);
        if !self.commands.is_empty() {
            s.push_str(" <COMMAND>");
        }
        s.push_str(" [OPTIONS]\n");
        if !self.commands.is_empty() {
            s.push_str("\nCOMMANDS:\n");
            for (name, help) in &self.commands {
                s.push_str(&format!("  {name:<14} {help}\n"));
            }
        }
        if !self.opts.is_empty() {
            s.push_str("\nOPTIONS:\n");
            for o in &self.opts {
                let left = if o.is_flag {
                    format!("--{}", o.name)
                } else {
                    format!("--{} <v>", o.name)
                };
                let dflt = o
                    .default
                    .map(|d| format!(" [default: {d}]"))
                    .unwrap_or_default();
                s.push_str(&format!("  {left:<20} {}{dflt}\n", o.help));
            }
        }
        s
    }

    /// Parse a raw argument vector (without argv[0]).
    pub fn parse(&self, argv: &[String]) -> crate::Result<Args> {
        let mut args = Args::default();
        let mut it = argv.iter().peekable();

        if !self.commands.is_empty() {
            match it.peek() {
                Some(first) if !first.starts_with('-') => {
                    let name = it.next().unwrap();
                    if !self.commands.iter().any(|(c, _)| c == name) {
                        crate::bail!("unknown command {name:?}\n\n{}", self.usage());
                    }
                    args.command = Some(name.clone());
                }
                _ => {}
            }
        }

        while let Some(tok) = it.next() {
            if tok == "--help" || tok == "-h" {
                crate::bail!("{}", self.usage());
            }
            if let Some(body) = tok.strip_prefix("--") {
                let (key, inline_val) = match body.split_once('=') {
                    Some((k, v)) => (k, Some(v.to_string())),
                    None => (body, None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.name == key)
                    .ok_or_else(|| crate::err!("unknown option --{key}\n\n{}", self.usage()))?;
                if spec.is_flag {
                    if inline_val.is_some() {
                        crate::bail!("flag --{key} takes no value");
                    }
                    args.flags.push(key.to_string());
                } else {
                    let val = match inline_val {
                        Some(v) => v,
                        None => it
                            .next()
                            .ok_or_else(|| crate::err!("option --{key} needs a value"))?
                            .clone(),
                    };
                    args.values.insert(key.to_string(), val);
                }
            } else {
                args.positionals.push(tok.clone());
            }
        }

        // Apply defaults.
        for o in &self.opts {
            if let Some(d) = o.default {
                args.values.entry(o.name.to_string()).or_insert_with(|| d.to_string());
            }
        }
        Ok(args)
    }

    /// Parse `std::env::args()`.
    pub fn parse_env(&self) -> crate::Result<Args> {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        self.parse(&argv)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn grammar() -> Parser {
        Parser::new("prog", "test program")
            .command("run", "run it")
            .command("bench", "bench it")
            .opt("size", "array size", Some("1024"))
            .opt("name", "a name", None)
            .flag("verbose", "more output")
    }

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_and_options() {
        let a = grammar().parse(&sv(&["run", "--size", "64", "--verbose"])).unwrap();
        assert_eq!(a.command.as_deref(), Some("run"));
        assert_eq!(a.get("size"), Some("64"));
        assert!(a.flag("verbose"));
        assert!(!a.flag("other"));
    }

    #[test]
    fn equals_syntax() {
        let a = grammar().parse(&sv(&["--size=128"])).unwrap();
        assert_eq!(a.get("size"), Some("128"));
    }

    #[test]
    fn defaults_applied() {
        let a = grammar().parse(&sv(&[])).unwrap();
        assert_eq!(a.get("size"), Some("1024"));
        assert_eq!(a.get("name"), None);
    }

    #[test]
    fn typed_accessors() {
        let a = grammar().parse(&sv(&["--size", "4096"])).unwrap();
        assert_eq!(a.parsed_or::<usize>("size", 0).unwrap(), 4096);
        let a = grammar().parse(&sv(&["--size", "nope"])).unwrap();
        assert!(a.get_parsed::<usize>("size").is_err());
    }

    #[test]
    fn unknown_option_rejected() {
        assert!(grammar().parse(&sv(&["--bogus", "1"])).is_err());
    }

    #[test]
    fn unknown_command_rejected() {
        assert!(grammar().parse(&sv(&["fly"])).is_err());
    }

    #[test]
    fn positionals_collected() {
        let a = grammar().parse(&sv(&["run", "a.txt", "b.txt"])).unwrap();
        assert_eq!(a.positionals(), &["a.txt".to_string(), "b.txt".to_string()]);
    }

    #[test]
    fn flag_with_value_rejected() {
        assert!(grammar().parse(&sv(&["--verbose=yes"])).is_err());
    }

    #[test]
    fn missing_value_rejected() {
        assert!(grammar().parse(&sv(&["--name"])).is_err());
    }

    #[test]
    fn usage_mentions_everything() {
        let u = grammar().usage();
        for needle in ["run", "bench", "--size", "--verbose", "default: 1024"] {
            assert!(u.contains(needle), "usage missing {needle}: {u}");
        }
    }
}
