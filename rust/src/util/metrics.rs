//! Lightweight metrics: monotonic timers, counters, and streaming
//! histograms with percentile queries (the offline stand-in for the
//! `metrics`/`hdrhistogram` crates).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

/// Wall-clock stopwatch.
#[derive(Debug)]
pub struct Stopwatch(Instant);

impl Stopwatch {
    /// Start now.
    pub fn start() -> Self {
        Self(Instant::now())
    }

    /// Elapsed since start.
    pub fn elapsed(&self) -> Duration {
        self.0.elapsed()
    }

    /// Elapsed milliseconds as f64 (the unit Table 1 uses).
    pub fn ms(&self) -> f64 {
        self.0.elapsed().as_secs_f64() * 1e3
    }
}

/// Thread-safe monotonically increasing counter.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    /// Zeroed counter.
    pub const fn new() -> Self {
        Self(AtomicU64::new(0))
    }

    /// Add `n`.
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    /// Increment by one.
    pub fn inc(&self) {
        self.add(1);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Log-bucketed latency histogram: ~4% relative resolution over
/// nanoseconds → hours, constant memory, lock-free recording.
///
/// Buckets: 64 octaves × 16 sub-buckets (linear within an octave).
#[derive(Debug)]
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_ns: AtomicU64,
    max_ns: AtomicU64,
}

const SUB: usize = 16;
const SUB_BITS: u32 = 4;

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    /// Empty histogram.
    pub fn new() -> Self {
        Self {
            buckets: (0..64 * SUB).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_ns: AtomicU64::new(0),
            max_ns: AtomicU64::new(0),
        }
    }

    fn index(ns: u64) -> usize {
        if ns < SUB as u64 {
            return ns as usize;
        }
        let octave = 63 - ns.leading_zeros() as usize;
        let shift = octave as u32 - SUB_BITS;
        let sub = ((ns >> shift) & (SUB as u64 - 1)) as usize;
        ((octave - SUB_BITS as usize + 1) << SUB_BITS) | sub
    }

    /// Lower bound of bucket `idx` in nanoseconds.
    fn lower_bound(idx: usize) -> u64 {
        let octave = idx >> SUB_BITS;
        let sub = (idx & (SUB - 1)) as u64;
        if octave == 0 {
            return sub;
        }
        let shift = octave as u32 - 1;
        (SUB as u64 + sub) << shift
    }

    /// Record one duration.
    pub fn record(&self, d: Duration) {
        let ns = d.as_nanos().min(u64::MAX as u128) as u64;
        self.record_ns(ns);
    }

    /// Record a raw nanosecond value.
    pub fn record_ns(&self, ns: u64) {
        let idx = Self::index(ns).min(self.buckets.len() - 1);
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add(ns, Ordering::Relaxed);
        self.max_ns.fetch_max(ns, Ordering::Relaxed);
    }

    /// Number of recorded samples.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Mean in nanoseconds (0 if empty).
    pub fn mean_ns(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.sum_ns.load(Ordering::Relaxed) as f64 / c as f64
        }
    }

    /// Maximum recorded value in nanoseconds.
    pub fn max_ns(&self) -> u64 {
        self.max_ns.load(Ordering::Relaxed)
    }

    /// Approximate quantile (`q` in [0,1]) in nanoseconds.
    pub fn quantile_ns(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = ((q.clamp(0.0, 1.0) * total as f64).ceil() as u64).max(1);
        let mut seen = 0;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return Self::lower_bound(i);
            }
        }
        self.max_ns()
    }

    /// Render a one-line summary (count / mean / p50 / p99 / max, ms).
    pub fn summary(&self) -> String {
        format!(
            "n={} mean={:.3}ms p50={:.3}ms p99={:.3}ms max={:.3}ms",
            self.count(),
            self.mean_ns() / 1e6,
            self.quantile_ns(0.5) as f64 / 1e6,
            self.quantile_ns(0.99) as f64 / 1e6,
            self.max_ns() as f64 / 1e6,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_basics() {
        let c = Counter::new();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
    }

    #[test]
    fn histogram_index_monotone() {
        let mut last = 0;
        for ns in [0u64, 1, 15, 16, 17, 100, 1_000, 10_000, 1 << 20, 1 << 40] {
            let idx = Histogram::index(ns);
            assert!(idx >= last, "index not monotone at {ns}");
            last = idx;
        }
    }

    #[test]
    fn histogram_bounds_bracket_value() {
        for ns in [1u64, 7, 16, 100, 999, 123_456, 1 << 30] {
            let idx = Histogram::index(ns);
            let lo = Histogram::lower_bound(idx);
            let hi = Histogram::lower_bound(idx + 1);
            assert!(lo <= ns && ns < hi, "{ns}: [{lo},{hi})");
        }
    }

    #[test]
    fn quantiles_roughly_correct() {
        let h = Histogram::new();
        for i in 1..=10_000u64 {
            h.record_ns(i * 1000); // 1µs … 10ms uniformly
        }
        let p50 = h.quantile_ns(0.5) as f64;
        let p99 = h.quantile_ns(0.99) as f64;
        assert!((4.0e6..6.5e6).contains(&p50), "p50={p50}");
        assert!((9.0e6..10.5e6).contains(&p99), "p99={p99}");
        assert_eq!(h.count(), 10_000);
        assert!(h.max_ns() >= 9_990_000);
    }

    #[test]
    fn empty_histogram_safe() {
        let h = Histogram::new();
        assert_eq!(h.quantile_ns(0.5), 0);
        assert_eq!(h.mean_ns(), 0.0);
        assert!(h.summary().contains("n=0"));
    }

    #[test]
    fn concurrent_recording() {
        let h = std::sync::Arc::new(Histogram::new());
        std::thread::scope(|s| {
            for _ in 0..8 {
                let h = std::sync::Arc::clone(&h);
                s.spawn(move || {
                    for i in 0..1000 {
                        h.record_ns(1000 + i);
                    }
                });
            }
        });
        assert_eq!(h.count(), 8000);
    }
}
