//! Fixed-size worker thread pool (tokio is unavailable offline; the
//! service is CPU/FFI-bound, so OS threads are the honest model anyway).
//!
//! Jobs are `FnOnce() + Send` closures delivered over a bounded channel —
//! the bound is the first backpressure stage of the coordinator (see
//! `coordinator::backpressure` for the policy layer on top).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Bounded MPMC job queue. `push` blocks when full, `pop` blocks when
/// empty; `close` wakes everyone and drains.
struct Queue {
    inner: Mutex<QueueInner>,
    not_empty: Condvar,
    not_full: Condvar,
    capacity: usize,
}

struct QueueInner {
    jobs: VecDeque<Job>,
    closed: bool,
}

impl Queue {
    fn new(capacity: usize) -> Self {
        Self {
            inner: Mutex::new(QueueInner {
                jobs: VecDeque::new(),
                closed: false,
            }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            capacity,
        }
    }

    /// Blocking push. Returns `false` if the queue is closed.
    fn push(&self, job: Job) -> bool {
        let mut g = self.inner.lock().unwrap();
        while g.jobs.len() >= self.capacity && !g.closed {
            g = self.not_full.wait(g).unwrap();
        }
        if g.closed {
            return false;
        }
        g.jobs.push_back(job);
        drop(g);
        self.not_empty.notify_one();
        true
    }

    /// Non-blocking push. `Err` returns the job when full or closed.
    fn try_push(&self, job: Job) -> Result<(), Job> {
        let mut g = self.inner.lock().unwrap();
        if g.closed || g.jobs.len() >= self.capacity {
            return Err(job);
        }
        g.jobs.push_back(job);
        drop(g);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Blocking pop; `None` when closed *and* drained.
    fn pop(&self) -> Option<Job> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(job) = g.jobs.pop_front() {
                drop(g);
                self.not_full.notify_one();
                return Some(job);
            }
            if g.closed {
                return None;
            }
            g = self.not_empty.wait(g).unwrap();
        }
    }

    fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    fn len(&self) -> usize {
        self.inner.lock().unwrap().jobs.len()
    }
}

/// Fixed-size thread pool.
pub struct ThreadPool {
    queue: Arc<Queue>,
    workers: Vec<JoinHandle<()>>,
    in_flight: Arc<AtomicUsize>,
}

impl ThreadPool {
    /// Spawn `threads` workers with a job queue bounded at `queue_cap`.
    pub fn new(threads: usize, queue_cap: usize) -> Self {
        assert!(threads > 0 && queue_cap > 0);
        let queue = Arc::new(Queue::new(queue_cap));
        let in_flight = Arc::new(AtomicUsize::new(0));
        let workers = (0..threads)
            .map(|i| {
                let queue = Arc::clone(&queue);
                let in_flight = Arc::clone(&in_flight);
                std::thread::Builder::new()
                    .name(format!("pool-worker-{i}"))
                    .spawn(move || {
                        while let Some(job) = queue.pop() {
                            job();
                            in_flight.fetch_sub(1, Ordering::Release);
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        Self {
            queue,
            workers,
            in_flight,
        }
    }

    /// Pool sized to the machine (one worker per core, queue 2× workers).
    pub fn with_default_size() -> Self {
        let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        Self::new(n, 2 * n)
    }

    /// Blocking submit. Returns `false` if the pool is shut down.
    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) -> bool {
        self.in_flight.fetch_add(1, Ordering::Acquire);
        let ok = self.queue.push(Box::new(f));
        if !ok {
            self.in_flight.fetch_sub(1, Ordering::Release);
        }
        ok
    }

    /// Non-blocking submit; `false` when the queue is full (caller sheds).
    pub fn try_submit<F: FnOnce() + Send + 'static>(&self, f: F) -> bool {
        self.in_flight.fetch_add(1, Ordering::Acquire);
        let ok = self.queue.try_push(Box::new(f)).is_ok();
        if !ok {
            self.in_flight.fetch_sub(1, Ordering::Release);
        }
        ok
    }

    /// Jobs queued but not yet started.
    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    /// Jobs submitted and not yet finished (queued + running).
    pub fn in_flight(&self) -> usize {
        self.in_flight.load(Ordering::Acquire)
    }

    /// Busy-wait (with parking) until all submitted jobs finish.
    pub fn wait_idle(&self) {
        while self.in_flight() > 0 {
            std::thread::yield_now();
        }
    }

    /// Number of worker threads.
    pub fn threads(&self) -> usize {
        self.workers.len()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.queue.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4, 16);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            assert!(pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            }));
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn try_submit_sheds_when_full() {
        let pool = ThreadPool::new(1, 1);
        let gate = Arc::new((Mutex::new(false), Condvar::new()));
        // Occupy the single worker.
        let g2 = Arc::clone(&gate);
        pool.submit(move || {
            let (m, cv) = &*g2;
            let mut open = m.lock().unwrap();
            while !*open {
                open = cv.wait(open).unwrap();
            }
        });
        // Wait until the blocker has been picked up by the worker so the
        // queue slot is truly free for exactly one more job.
        while pool.queued() > 0 {
            std::thread::yield_now();
        }
        // Fill the queue slot…
        assert!(pool.try_submit(|| {}));
        // …then shedding must kick in.
        let mut shed = 0;
        for _ in 0..10 {
            if !pool.try_submit(|| {}) {
                shed += 1;
            }
        }
        assert!(shed >= 9, "expected sheds, got {shed}");
        let (m, cv) = &*gate;
        *m.lock().unwrap() = true;
        cv.notify_all();
        pool.wait_idle();
    }

    #[test]
    fn drop_drains_queue() {
        let counter = Arc::new(AtomicU64::new(0));
        {
            let pool = ThreadPool::new(2, 64);
            for _ in 0..50 {
                let c = Arc::clone(&counter);
                pool.submit(move || {
                    std::thread::sleep(std::time::Duration::from_micros(100));
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
            // Drop happens here: close + join must still run queued jobs.
        }
        assert_eq!(counter.load(Ordering::SeqCst), 50);
    }

    #[test]
    fn in_flight_tracking() {
        let pool = ThreadPool::new(2, 8);
        assert_eq!(pool.in_flight(), 0);
        pool.submit(|| std::thread::sleep(std::time::Duration::from_millis(5)));
        assert!(pool.in_flight() >= 1 || pool.queued() == 0);
        pool.wait_idle();
        assert_eq!(pool.in_flight(), 0);
    }

    #[test]
    fn parallelism_actually_happens() {
        let pool = ThreadPool::new(4, 16);
        let t0 = std::time::Instant::now();
        for _ in 0..4 {
            pool.submit(|| std::thread::sleep(std::time::Duration::from_millis(50)));
        }
        pool.wait_idle();
        // 4×50 ms serial would be 200 ms; parallel should be well under.
        assert!(t0.elapsed().as_millis() < 150, "no parallelism: {:?}", t0.elapsed());
    }
}
