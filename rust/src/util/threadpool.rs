//! Fixed-size worker thread pool (tokio is unavailable offline; the
//! service is CPU/FFI-bound, so OS threads are the honest model anyway).
//!
//! Jobs are `FnOnce() + Send` closures delivered over a bounded channel —
//! the bound is the first backpressure stage of the coordinator (see
//! `coordinator::backpressure` for the policy layer on top).
//!
//! Two dispatch styles:
//!
//! * [`ThreadPool::submit`] / [`ThreadPool::try_submit`] — fire-and-forget
//!   `'static` jobs (the service's request path).
//! * [`ThreadPool::run_scoped`] — a batch of jobs that may **borrow the
//!   caller's stack** (the executor's row-parallel path: tasks hold
//!   `&mut` row chunks of one `(B, N)` buffer). The call blocks until
//!   every task finished, which is what makes the borrows sound — the
//!   same discipline as `std::thread::scope`, enforced by the wait.
//!
//! Panics never poison the pool: a panicking job is caught on the worker,
//! the worker keeps serving, and `run_scoped` reports the panic count to
//! its caller instead of deadlocking the batch.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A pool task that may borrow from the submitting stack frame; only
/// [`ThreadPool::run_scoped`] accepts these (it blocks until completion,
/// which is what keeps the borrows alive long enough).
pub type ScopedJob<'env> = Box<dyn FnOnce() + Send + 'env>;

/// Bounded MPMC job queue. `push` blocks when full, `pop` blocks when
/// empty; `close` wakes everyone and drains.
struct Queue {
    inner: Mutex<QueueInner>,
    not_empty: Condvar,
    not_full: Condvar,
    capacity: usize,
}

struct QueueInner {
    jobs: VecDeque<Job>,
    closed: bool,
}

impl Queue {
    fn new(capacity: usize) -> Self {
        Self {
            inner: Mutex::new(QueueInner {
                jobs: VecDeque::new(),
                closed: false,
            }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            capacity,
        }
    }

    /// Blocking push. `Err` returns the job when the queue is closed (so
    /// the caller can still run it inline).
    fn push(&self, job: Job) -> Result<(), Job> {
        let mut g = self.inner.lock().unwrap();
        while g.jobs.len() >= self.capacity && !g.closed {
            g = self.not_full.wait(g).unwrap();
        }
        if g.closed {
            return Err(job);
        }
        g.jobs.push_back(job);
        drop(g);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Non-blocking push. `Err` returns the job when full or closed.
    fn try_push(&self, job: Job) -> Result<(), Job> {
        let mut g = self.inner.lock().unwrap();
        if g.closed || g.jobs.len() >= self.capacity {
            return Err(job);
        }
        g.jobs.push_back(job);
        drop(g);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Blocking pop; `None` when closed *and* drained.
    fn pop(&self) -> Option<Job> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(job) = g.jobs.pop_front() {
                drop(g);
                self.not_full.notify_one();
                return Some(job);
            }
            if g.closed {
                return None;
            }
            g = self.not_empty.wait(g).unwrap();
        }
    }

    fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    fn len(&self) -> usize {
        self.inner.lock().unwrap().jobs.len()
    }
}

/// Fixed-size thread pool.
pub struct ThreadPool {
    queue: Arc<Queue>,
    workers: Vec<JoinHandle<()>>,
    in_flight: Arc<AtomicUsize>,
}

impl ThreadPool {
    /// Spawn `threads` workers with a job queue bounded at `queue_cap`.
    pub fn new(threads: usize, queue_cap: usize) -> Self {
        assert!(threads > 0 && queue_cap > 0);
        let queue = Arc::new(Queue::new(queue_cap));
        let in_flight = Arc::new(AtomicUsize::new(0));
        let workers = (0..threads)
            .map(|i| {
                let queue = Arc::clone(&queue);
                let in_flight = Arc::clone(&in_flight);
                std::thread::Builder::new()
                    .name(format!("pool-worker-{i}"))
                    .spawn(move || {
                        while let Some(job) = queue.pop() {
                            // Contain panics: the worker must survive a
                            // panicking job and the in-flight count must
                            // stay balanced, or wait_idle() deadlocks.
                            let _ = std::panic::catch_unwind(
                                std::panic::AssertUnwindSafe(job),
                            );
                            in_flight.fetch_sub(1, Ordering::Release);
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        Self {
            queue,
            workers,
            in_flight,
        }
    }

    /// Pool sized to the machine (one worker per core, queue 2× workers).
    pub fn with_default_size() -> Self {
        let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        Self::new(n, 2 * n)
    }

    /// Blocking submit. Returns `false` if the pool is shut down.
    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) -> bool {
        self.dispatch(Box::new(f)).is_ok()
    }

    /// Blocking boxed submit; `Err` hands the job back when the pool is
    /// shut down so the caller can degrade to inline execution.
    fn dispatch(&self, job: Job) -> Result<(), Job> {
        self.in_flight.fetch_add(1, Ordering::Acquire);
        match self.queue.push(job) {
            Ok(()) => Ok(()),
            Err(job) => {
                self.in_flight.fetch_sub(1, Ordering::Release);
                Err(job)
            }
        }
    }

    /// Execute `tasks` on the pool and block until every one finished.
    ///
    /// Unlike [`submit`](Self::submit), tasks may borrow from the caller's
    /// stack (e.g. disjoint `&mut` chunks of one buffer): this call does
    /// not return before all tasks have run, so no borrow can outlive its
    /// referent. If the pool is already shut down, tasks run inline on the
    /// calling thread — the batch still completes.
    ///
    /// Panicking tasks are contained: the panic is caught on the worker,
    /// sibling tasks still run, the pool stays usable, and the number of
    /// panicked tasks comes back as `Err` so the caller can fail its batch
    /// cleanly instead of deadlocking.
    pub fn run_scoped<'env>(&self, tasks: Vec<ScopedJob<'env>>) -> Result<(), usize> {
        if tasks.is_empty() {
            return Ok(());
        }
        struct ScopeSync {
            remaining: Mutex<usize>,
            done: Condvar,
            panicked: AtomicUsize,
        }
        let sync = Arc::new(ScopeSync {
            remaining: Mutex::new(tasks.len()),
            done: Condvar::new(),
            panicked: AtomicUsize::new(0),
        });
        for task in tasks {
            // SAFETY: extending 'env to 'static is sound because this
            // function blocks on `sync` until the wrapper below has run
            // the task (or runs it inline) — the task can never be alive
            // after 'env ends. The callers that exploit this to hand out
            // `&mut` row chunks rely on those chunks being disjoint,
            // which the static checker proves for the executor's tile
            // dispatch (`analysis::disjoint::check_tile_dispatch`) and
            // for the parallel merge's bucket partition
            // (`analysis::disjoint::check_bucket_plan`, replaying the
            // same `sort::pmerge::plan_partition` geometry the dispatch
            // uses); see `rust/tests/analysis_mutations.rs`.
            let task: ScopedJob<'static> = unsafe {
                std::mem::transmute::<ScopedJob<'env>, ScopedJob<'static>>(task)
            };
            let sync2 = Arc::clone(&sync);
            let job: Job = Box::new(move || {
                if std::panic::catch_unwind(std::panic::AssertUnwindSafe(task)).is_err() {
                    sync2.panicked.fetch_add(1, Ordering::Relaxed);
                }
                let mut left = sync2.remaining.lock().unwrap();
                *left -= 1;
                if *left == 0 {
                    sync2.done.notify_all();
                }
            });
            if let Err(job) = self.dispatch(job) {
                // Pool shut down between batches: run on the caller.
                job();
            }
        }
        let mut left = sync.remaining.lock().unwrap();
        while *left > 0 {
            left = sync.done.wait(left).unwrap();
        }
        // Shadow of the soundness condition the SAFETY comment above
        // rests on: no task wrapper can still be running once the wait
        // releases, so the 'env-extended closures are all dead here.
        debug_assert_eq!(*left, 0, "run_scoped returned with tasks still in flight");
        drop(left);
        match sync.panicked.load(Ordering::Acquire) {
            0 => Ok(()),
            n => Err(n),
        }
    }

    /// Non-blocking submit; `false` when the queue is full (caller sheds).
    pub fn try_submit<F: FnOnce() + Send + 'static>(&self, f: F) -> bool {
        self.in_flight.fetch_add(1, Ordering::Acquire);
        let ok = self.queue.try_push(Box::new(f)).is_ok();
        if !ok {
            self.in_flight.fetch_sub(1, Ordering::Release);
        }
        ok
    }

    /// Jobs queued but not yet started.
    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    /// Jobs submitted and not yet finished (queued + running).
    pub fn in_flight(&self) -> usize {
        self.in_flight.load(Ordering::Acquire)
    }

    /// Busy-wait (with parking) until all submitted jobs finish.
    pub fn wait_idle(&self) {
        while self.in_flight() > 0 {
            std::thread::yield_now();
        }
    }

    /// Number of worker threads.
    pub fn threads(&self) -> usize {
        self.workers.len()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.queue.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4, 16);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            assert!(pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            }));
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn try_submit_sheds_when_full() {
        let pool = ThreadPool::new(1, 1);
        let gate = Arc::new((Mutex::new(false), Condvar::new()));
        // Occupy the single worker.
        let g2 = Arc::clone(&gate);
        pool.submit(move || {
            let (m, cv) = &*g2;
            let mut open = m.lock().unwrap();
            while !*open {
                open = cv.wait(open).unwrap();
            }
        });
        // Wait until the blocker has been picked up by the worker so the
        // queue slot is truly free for exactly one more job.
        while pool.queued() > 0 {
            std::thread::yield_now();
        }
        // Fill the queue slot…
        assert!(pool.try_submit(|| {}));
        // …then shedding must kick in.
        let mut shed = 0;
        for _ in 0..10 {
            if !pool.try_submit(|| {}) {
                shed += 1;
            }
        }
        assert!(shed >= 9, "expected sheds, got {shed}");
        let (m, cv) = &*gate;
        *m.lock().unwrap() = true;
        cv.notify_all();
        pool.wait_idle();
    }

    #[test]
    fn drop_drains_queue() {
        let counter = Arc::new(AtomicU64::new(0));
        {
            let pool = ThreadPool::new(2, 64);
            for _ in 0..50 {
                let c = Arc::clone(&counter);
                pool.submit(move || {
                    std::thread::sleep(std::time::Duration::from_micros(100));
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
            // Drop happens here: close + join must still run queued jobs.
        }
        assert_eq!(counter.load(Ordering::SeqCst), 50);
    }

    #[test]
    fn in_flight_tracking() {
        let pool = ThreadPool::new(2, 8);
        assert_eq!(pool.in_flight(), 0);
        pool.submit(|| std::thread::sleep(std::time::Duration::from_millis(5)));
        assert!(pool.in_flight() >= 1 || pool.queued() == 0);
        pool.wait_idle();
        assert_eq!(pool.in_flight(), 0);
    }

    #[test]
    fn scoped_tasks_borrow_the_stack() {
        let pool = ThreadPool::new(4, 16);
        let mut data = vec![0u32; 64];
        let tasks: Vec<ScopedJob> = data
            .chunks_mut(16)
            .map(|chunk| {
                Box::new(move || {
                    for x in chunk.iter_mut() {
                        *x += 1;
                    }
                }) as ScopedJob
            })
            .collect();
        pool.run_scoped(tasks).unwrap();
        assert!(data.iter().all(|&x| x == 1));
    }

    #[test]
    fn scoped_panic_fails_batch_cleanly_without_deadlock() {
        let pool = ThreadPool::new(2, 8);
        let counter = Arc::new(AtomicU64::new(0));
        let mut tasks: Vec<ScopedJob> = Vec::new();
        for i in 0..8u64 {
            let c = Arc::clone(&counter);
            tasks.push(Box::new(move || {
                if i % 4 == 0 {
                    panic!("injected row-task failure");
                }
                c.fetch_add(1, Ordering::SeqCst);
            }));
        }
        // The batch fails (2 of 8 tasks panic) but run_scoped returns —
        // no deadlocked latch, no dead workers.
        assert_eq!(pool.run_scoped(tasks), Err(2));
        assert_eq!(counter.load(Ordering::SeqCst), 6);
        // And the pool is still fully usable afterwards.
        let c = Arc::clone(&counter);
        assert!(pool.submit(move || {
            c.fetch_add(10, Ordering::SeqCst);
        }));
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 16);
    }

    #[test]
    fn worker_survives_panicking_direct_job() {
        let pool = ThreadPool::new(1, 4);
        pool.submit(|| panic!("die"));
        pool.wait_idle();
        let done = Arc::new(AtomicU64::new(0));
        let d = Arc::clone(&done);
        pool.submit(move || {
            d.fetch_add(1, Ordering::SeqCst);
        });
        pool.wait_idle();
        assert_eq!(done.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn scoped_on_shut_down_pool_runs_inline() {
        let pool = ThreadPool::new(1, 1);
        pool.queue.close();
        let mut hits = 0u32;
        let tasks: Vec<ScopedJob> = vec![Box::new(|| hits += 1) as ScopedJob];
        // hits is borrowed mutably by the task; run_scoped's blocking
        // semantics make this legal even though execution is inline here.
        pool.run_scoped(tasks).unwrap();
        assert_eq!(hits, 1);
    }

    #[test]
    fn parallelism_actually_happens() {
        let pool = ThreadPool::new(4, 16);
        let t0 = std::time::Instant::now();
        for _ in 0..4 {
            pool.submit(|| std::thread::sleep(std::time::Duration::from_millis(50)));
        }
        pool.wait_idle();
        // 4×50 ms serial would be 200 ms; parallel should be well under.
        assert!(t0.elapsed().as_millis() < 150, "no parallelism: {:?}", t0.elapsed());
    }
}
