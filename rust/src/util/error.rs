//! In-crate error subsystem — the offline stand-in for the `anyhow` crate.
//!
//! The seed design used `anyhow` for its ergonomic dynamic errors, but the
//! build must work with zero external dependencies, so this module
//! re-implements exactly the API surface the crate uses:
//!
//! * [`Error`] — a dynamic error value: either a plain message, or a
//!   wrapped `std::error::Error`, plus any number of context layers
//!   (`anyhow::Error` analogue).
//! * [`Result`] — `Result<T, Error>` alias (`anyhow::Result` analogue);
//!   re-exported at the crate root as `crate::Result`.
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option` (`anyhow::Context` analogue).
//! * `err!` / `bail!` / `ensure!` — macros at the crate root
//!   (`anyhow::anyhow!` / `bail!` / `ensure!` analogues).
//!
//! Display behaviour matches what the call sites rely on: `{}` prints the
//! outermost message only; the alternate form `{:#}` prints the whole
//! chain outermost→innermost joined by `": "`, so tests can assert on
//! context text added deep in the stack.

use std::fmt;

/// Crate-wide result type (also exported as `crate::Result`).
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A dynamic error: a root cause plus zero or more context layers.
pub struct Error {
    /// Context messages, innermost first (push order).
    context: Vec<String>,
    /// The root cause.
    root: Box<dyn std::error::Error + Send + Sync + 'static>,
}

/// Root cause for errors built from a plain message (`err!`, `bail!`).
#[derive(Debug)]
struct MessageError(String);

impl fmt::Display for MessageError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for MessageError {}

impl Error {
    /// Error from a plain message.
    pub fn msg(message: impl fmt::Display) -> Self {
        Self {
            context: Vec::new(),
            root: Box::new(MessageError(message.to_string())),
        }
    }

    /// Wrap this error in one more layer of context (outermost).
    pub fn context(mut self, message: impl fmt::Display) -> Self {
        self.context.push(message.to_string());
        self
    }

    /// The whole message chain, outermost first: context layers in
    /// reverse push order, then the root cause, then the root's own
    /// `std::error::Error::source` chain.
    pub fn chain(&self) -> Vec<String> {
        let mut out: Vec<String> = self.context.iter().rev().cloned().collect();
        out.push(self.root.to_string());
        let mut source = self.root.source();
        while let Some(s) = source {
            out.push(s.to_string());
            source = s.source();
        }
        out
    }

    /// The root cause (innermost error).
    pub fn root_cause(&self) -> &(dyn std::error::Error + Send + Sync + 'static) {
        self.root.as_ref()
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` — the full chain, anyhow-style.
            return f.write_str(&self.chain().join(": "));
        }
        match self.context.last() {
            Some(outer) => f.write_str(outer),
            None => write!(f, "{}", self.root),
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `main() -> Result<()>` prints errors via Debug: outermost
        // message first, then the cause chain.
        let chain = self.chain();
        f.write_str(&chain[0])?;
        if chain.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            for cause in &chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// Any std error converts into `Error` (this is what makes `?` work on
// io/parse/channel errors). `Error` deliberately does NOT implement
// `std::error::Error` itself — exactly like `anyhow::Error` — so this
// blanket impl does not collide with the identity `From<Error> for Error`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Self {
            context: Vec::new(),
            root: Box::new(e),
        }
    }
}

/// `.context(..)` / `.with_context(..)` on `Result` and `Option`.
pub trait Context<T> {
    /// Attach a context message, converting the error to [`Error`]
    /// (on `Option`, `None` becomes an error with this message).
    fn context<C: fmt::Display>(self, message: C) -> Result<T>;
    /// Like [`Context::context`], but the message is built lazily.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, message: C) -> Result<T> {
        self.map_err(|e| Error::from(e).context(message))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, message: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(message))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Build an [`Error`] from a format string (the `anyhow::anyhow!`
/// analogue). Exported at the crate root: `crate::err!(..)`.
#[macro_export]
macro_rules! err {
    ($($arg:tt)*) => {
        $crate::util::error::Error::msg(::std::format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::err!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_missing() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "no such file")
    }

    #[test]
    fn message_error_displays() {
        let e = Error::msg("boom");
        assert_eq!(format!("{e}"), "boom");
        assert_eq!(format!("{e:#}"), "boom");
    }

    #[test]
    fn err_macro_formats() {
        let n = 7;
        let e: Error = crate::err!("bad value {n} ({})", "ctx");
        assert_eq!(format!("{e}"), "bad value 7 (ctx)");
    }

    #[test]
    fn context_layers_chain() {
        let e = Error::from(io_missing())
            .context("reading manifest")
            .context("opening artifacts");
        // `{}` = outermost only.
        assert_eq!(format!("{e}"), "opening artifacts");
        // `{:#}` = whole chain, outermost first.
        assert_eq!(
            format!("{e:#}"),
            "opening artifacts: reading manifest: no such file"
        );
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_missing());
        let e = r.context("loading").unwrap_err();
        assert_eq!(format!("{e:#}"), "loading: no such file");

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", "column")).unwrap_err();
        assert_eq!(format!("{e}"), "missing column");

        assert_eq!(Some(5u32).context("unused").unwrap(), 5);
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<u32> {
            Ok(s.parse::<u32>()?)
        }
        assert_eq!(parse("42").unwrap(), 42);
        let e = parse("nope").unwrap_err();
        assert!(format!("{e}").contains("invalid digit"), "{e}");
    }

    #[test]
    fn bail_and_ensure() {
        fn f(x: u32) -> Result<u32> {
            crate::ensure!(x < 10, "x too big: {x}");
            if x == 3 {
                crate::bail!("three is right out");
            }
            Ok(x)
        }
        assert_eq!(f(5).unwrap(), 5);
        assert_eq!(format!("{}", f(12).unwrap_err()), "x too big: 12");
        assert_eq!(format!("{}", f(3).unwrap_err()), "three is right out");
    }

    #[test]
    fn debug_prints_cause_chain() {
        let e = Error::from(io_missing()).context("reading manifest.tsv");
        let dbg = format!("{e:?}");
        assert!(dbg.starts_with("reading manifest.tsv"), "{dbg}");
        assert!(dbg.contains("Caused by:"), "{dbg}");
        assert!(dbg.contains("no such file"), "{dbg}");
    }

    #[test]
    fn root_cause_exposed() {
        let e = Error::from(io_missing()).context("outer");
        assert_eq!(e.root_cause().to_string(), "no such file");
    }
}
