//! Self-contained utility substrates.
//!
//! The build is fully offline with zero external dependencies, so the
//! pieces a project would normally pull from crates.io — error handling,
//! CLI parsing, a thread pool, metrics, property testing, table
//! formatting — are implemented here from scratch. See DESIGN.md §3.

pub mod cli;
pub mod error;
pub mod json;
pub mod metrics;
pub mod prop;
pub mod table;
pub mod threadpool;
