//! Property-based testing mini-framework (proptest is unavailable
//! offline).
//!
//! A property is a function from a generated input to `Result<(), String>`.
//! The runner executes it over many seeded random cases; on failure it
//! *shrinks* the input via the strategy's `shrink` candidates and reports
//! the minimal failing case together with the seed needed to replay it.
//!
//! Used by the coordinator invariants (routing, batching, response
//! integrity — DESIGN.md §6.5) and the sort substrates.

use crate::workload::rng::Pcg32;

/// Generates values of `T` and proposes smaller variants on failure.
pub trait Strategy {
    /// Generated type.
    type Value: Clone + std::fmt::Debug;
    /// Sample one value.
    fn sample(&self, rng: &mut Pcg32) -> Self::Value;
    /// Candidate simplifications of `v`, in decreasing aggressiveness.
    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let _ = v;
        Vec::new()
    }
}

/// Runner configuration.
#[derive(Clone, Copy, Debug)]
pub struct Config {
    /// Number of random cases.
    pub cases: u32,
    /// Base seed (change to explore a different corner).
    pub seed: u64,
    /// Maximum shrink iterations.
    pub max_shrink: u32,
}

impl Default for Config {
    fn default() -> Self {
        Self {
            cases: 64,
            seed: 0xDEFA_17,
            max_shrink: 500,
        }
    }
}

/// Run `prop` over `cases` random samples of `strategy`; panic with the
/// minimal counterexample on failure.
pub fn check<S, F>(strategy: &S, prop: F)
where
    S: Strategy,
    F: Fn(&S::Value) -> Result<(), String>,
{
    check_with(Config::default(), strategy, prop)
}

/// [`check`] with explicit configuration.
pub fn check_with<S, F>(config: Config, strategy: &S, prop: F)
where
    S: Strategy,
    F: Fn(&S::Value) -> Result<(), String>,
{
    for case in 0..config.cases {
        let mut rng = Pcg32::new(config.seed, case as u64);
        let value = strategy.sample(&mut rng);
        if let Err(msg) = prop(&value) {
            // Shrink.
            let mut best = value;
            let mut best_msg = msg;
            let mut budget = config.max_shrink;
            'outer: loop {
                for cand in strategy.shrink(&best) {
                    if budget == 0 {
                        break 'outer;
                    }
                    budget -= 1;
                    if let Err(m) = prop(&cand) {
                        best = cand;
                        best_msg = m;
                        continue 'outer;
                    }
                }
                break;
            }
            panic!(
                "property failed (seed={:#x}, case={case}):\n  input: {:?}\n  error: {}",
                config.seed, best, best_msg
            );
        }
    }
}

// ---------------------------------------------------------------------
// Standard strategies
// ---------------------------------------------------------------------

/// Uniform `u32` in `[lo, hi]`.
pub struct U32Range(pub u32, pub u32);

impl Strategy for U32Range {
    type Value = u32;
    fn sample(&self, rng: &mut Pcg32) -> u32 {
        self.0 + rng.next_below(self.1 - self.0 + 1)
    }
    fn shrink(&self, v: &u32) -> Vec<u32> {
        // Binary descent towards the lower bound: lo, then candidates that
        // halve the remaining distance, then v-1 — finds a boundary value
        // in O(log range) property evaluations.
        let mut out = Vec::new();
        if *v > self.0 {
            out.push(self.0);
            let mut dist = (v - self.0) / 2;
            while dist > 0 {
                out.push(v - dist);
                dist /= 2;
            }
            out.push(v - 1);
        }
        out.dedup();
        out
    }
}

/// `Vec<u32>` with length in `[0, max_len]`, elements in `[0, max_val]`.
pub struct VecU32 {
    /// Maximum length.
    pub max_len: usize,
    /// Maximum element value.
    pub max_val: u32,
}

impl Strategy for VecU32 {
    type Value = Vec<u32>;
    fn sample(&self, rng: &mut Pcg32) -> Vec<u32> {
        let len = rng.next_below(self.max_len as u32 + 1) as usize;
        (0..len)
            .map(|_| {
                if self.max_val == u32::MAX {
                    rng.next_u32()
                } else {
                    rng.next_below(self.max_val + 1)
                }
            })
            .collect()
    }
    fn shrink(&self, v: &Vec<u32>) -> Vec<Vec<u32>> {
        let mut out = Vec::new();
        if v.is_empty() {
            return out;
        }
        // Halves.
        out.push(v[..v.len() / 2].to_vec());
        out.push(v[v.len() / 2..].to_vec());
        // Drop one element.
        if v.len() <= 8 {
            for i in 0..v.len() {
                let mut w = v.clone();
                w.remove(i);
                out.push(w);
            }
        } else {
            let mut w = v.clone();
            w.pop();
            out.push(w);
        }
        // Zero an element.
        if let Some(pos) = v.iter().position(|&x| x != 0) {
            let mut w = v.clone();
            w[pos] = 0;
            out.push(w);
        }
        out
    }
}

/// Power-of-two `usize` in `[2^lo_log2, 2^hi_log2]` — the shape every
/// bitonic entry point requires.
pub struct Pow2(pub u32, pub u32);

impl Strategy for Pow2 {
    type Value = usize;
    fn sample(&self, rng: &mut Pcg32) -> usize {
        1usize << (self.0 + rng.next_below(self.1 - self.0 + 1))
    }
    fn shrink(&self, v: &usize) -> Vec<usize> {
        if *v > (1usize << self.0) {
            vec![v / 2, 1usize << self.0]
        } else {
            Vec::new()
        }
    }
}

/// Pair of independent strategies.
pub struct Zip<A, B>(pub A, pub B);

impl<A: Strategy, B: Strategy> Strategy for Zip<A, B> {
    type Value = (A::Value, B::Value);
    fn sample(&self, rng: &mut Pcg32) -> Self::Value {
        (self.0.sample(rng), self.1.sample(rng))
    }
    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let mut out: Vec<Self::Value> = self
            .0
            .shrink(&v.0)
            .into_iter()
            .map(|a| (a, v.1.clone()))
            .collect();
        out.extend(self.1.shrink(&v.1).into_iter().map(|b| (v.0.clone(), b)));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(&U32Range(0, 100), |&v| {
            if v <= 100 {
                Ok(())
            } else {
                Err(format!("{v} out of range"))
            }
        });
    }

    #[test]
    fn failing_property_shrinks_to_minimum() {
        let result = std::panic::catch_unwind(|| {
            check(&U32Range(0, 1000), |&v| {
                if v < 500 {
                    Ok(())
                } else {
                    Err("too big".into())
                }
            });
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        // Shrinker must walk down to the boundary value 500.
        assert!(msg.contains("input: 500"), "unshrunk: {msg}");
    }

    #[test]
    fn vec_strategy_respects_bounds() {
        let s = VecU32 {
            max_len: 10,
            max_val: 5,
        };
        let mut rng = Pcg32::new(1, 1);
        for _ in 0..100 {
            let v = s.sample(&mut rng);
            assert!(v.len() <= 10);
            assert!(v.iter().all(|&x| x <= 5));
        }
    }

    #[test]
    fn vec_shrink_reduces() {
        let s = VecU32 {
            max_len: 100,
            max_val: u32::MAX,
        };
        let v: Vec<u32> = (1..=20).collect();
        for w in s.shrink(&v) {
            assert!(w.len() < v.len() || w.iter().sum::<u32>() < v.iter().sum::<u32>());
        }
    }

    #[test]
    fn pow2_strategy_powers_only() {
        let s = Pow2(1, 12);
        let mut rng = Pcg32::new(2, 0);
        for _ in 0..100 {
            let v = s.sample(&mut rng);
            assert!(v.is_power_of_two() && (2..=4096).contains(&v));
        }
    }

    #[test]
    fn deterministic_given_seed() {
        // Same config must generate the same cases: a property that
        // records inputs sees identical sequences across two runs.
        use std::cell::RefCell;
        let record = |store: &RefCell<Vec<u32>>| {
            let cfg = Config {
                cases: 10,
                seed: 42,
                max_shrink: 0,
            };
            check_with(cfg, &U32Range(0, 1_000_000), |&v| {
                store.borrow_mut().push(v);
                Ok(())
            });
        };
        let a = RefCell::new(Vec::new());
        let b = RefCell::new(Vec::new());
        record(&a);
        record(&b);
        assert_eq!(*a.borrow(), *b.borrow());
    }
}
