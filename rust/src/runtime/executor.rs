//! A loaded sort artifact plus typed marshalling, executed natively.
//!
//! The original design compiled `artifacts/*.hlo.txt` with the `xla`
//! crate's PJRT CPU client. That crate is not vendored in this offline
//! environment, so the executor is a deterministic **native-CPU
//! fallback**: "compilation" loads and validates the artifact's HLO text
//! (shape and module sanity — catching manifest/file drift at load time,
//! exactly where PJRT compilation would fail), and execution walks the
//! same abstract bitonic network the Pallas kernels implement
//! ([`crate::sort::network`]), row by row over the `(batch, n)` buffer.
//!
//! The executor therefore honours the full artifact contract the
//! integration tests pin down — ascending/descending, u32/i32/f32, sort
//! and merge kinds, MAX-padding semantics — and is bit-exact with the CPU
//! substrates. Swapping a real PJRT backend in later is a change local to
//! this type: same constructor, same `sort_*` entry points.

use std::path::Path;

use crate::sort::bitonic::{bitonic_sort, compare_exchange_step};
use crate::sort::SortKey;
use crate::util::error::Context;

use super::artifact::{ArtifactKind, ArtifactMeta, Dtype};

/// One loaded sort/merge artifact, ready to execute.
pub struct SortExecutor {
    /// The artifact this executor was built from.
    pub meta: ArtifactMeta,
    /// Size of the loaded HLO text in bytes (artifact was really read).
    pub hlo_bytes: usize,
}

impl SortExecutor {
    /// Load and validate `hlo_text_path` for `meta`. The HLO text must
    /// exist, look like an HLO module, and declare the `(batch, n)` shape
    /// the manifest promises.
    pub fn compile(meta: ArtifactMeta, hlo_text_path: &Path) -> crate::Result<Self> {
        crate::ensure!(
            meta.n.is_power_of_two() && meta.batch >= 1,
            "artifact {} has a malformed shape ({}x{})",
            meta.name,
            meta.batch,
            meta.n
        );
        let text = std::fs::read_to_string(hlo_text_path)
            .with_context(|| format!("reading {hlo_text_path:?} — generate artifacts with `python -m compile.aot` (see README)"))?;
        crate::ensure!(
            text.contains("HloModule"),
            "{hlo_text_path:?} does not look like HLO text"
        );
        let shape = format!("[{},{}]", meta.batch, meta.n);
        crate::ensure!(
            text.contains(&shape),
            "artifact {} HLO text does not declare shape {shape} — manifest/file mismatch",
            meta.name
        );
        Ok(Self {
            meta,
            hlo_bytes: text.len(),
        })
    }

    /// Sort a full `(batch, n)` buffer of u32 keys, row-major, in place.
    /// Returns the sorted rows in the same layout. This is the hot path:
    /// the buffer is taken by value (the host thread already owns it) so
    /// no defensive copy happens per batch.
    pub fn sort_u32(&self, rows: Vec<u32>) -> crate::Result<Vec<u32>> {
        crate::ensure!(
            self.meta.dtype == Dtype::U32,
            "artifact {} holds {:?} keys",
            self.meta.name,
            self.meta.dtype
        );
        self.execute(rows)
    }

    /// Sort `(batch, n)` i32 keys.
    pub fn sort_i32(&self, rows: Vec<i32>) -> crate::Result<Vec<i32>> {
        crate::ensure!(self.meta.dtype == Dtype::I32, "dtype mismatch");
        self.execute(rows)
    }

    /// Sort `(batch, n)` f32 keys (finite values only — NaN ordering is
    /// not defined for the min/max network; see DESIGN.md §6).
    pub fn sort_f32(&self, rows: Vec<f32>) -> crate::Result<Vec<f32>> {
        crate::ensure!(self.meta.dtype == Dtype::F32, "dtype mismatch");
        self.execute(rows)
    }

    fn execute<T: SortKey>(&self, mut rows: Vec<T>) -> crate::Result<Vec<T>> {
        let (b, n) = (self.meta.batch, self.meta.n);
        crate::ensure!(
            rows.len() == b * n,
            "artifact {} wants {}x{} ({} bytes), got {} bytes",
            self.meta.name,
            b,
            n,
            b * n * self.meta.dtype.size(),
            rows.len() * self.meta.dtype.size()
        );
        for row in rows.chunks_mut(n) {
            match self.meta.kind {
                // The full network — the same `sort::bitonic` walk the CPU
                // baseline uses, keeping the two paths bit-exact by
                // construction.
                ArtifactKind::Sort => bitonic_sort(row),
                ArtifactKind::Merge => merge_row(row),
            }
            if self.meta.descending {
                row.reverse();
            }
        }
        Ok(rows)
    }
}

/// Merge one row whose two halves are each sorted ascending (the merge
/// artifact contract): reverse the second half to form a bitonic
/// sequence, then run the final merge phase (`log2(n)` steps — the
/// paper §3 primitive, not a full re-sort).
fn merge_row<T: SortKey>(row: &mut [T]) {
    let n = row.len();
    if n < 2 {
        return;
    }
    debug_assert!(n.is_power_of_two(), "artifact rows are powers of two");
    row[n / 2..].reverse();
    let mut stride = n / 2;
    while stride >= 1 {
        // phase_len = n ⇒ every pair compares ascending (i & n == 0).
        compare_exchange_step(row, n, stride);
        stride /= 2;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sort::network::Variant;
    use crate::workload::{Distribution, Generator};

    fn meta(kind: ArtifactKind, batch: usize, n: usize, dtype: Dtype, desc: bool) -> ArtifactMeta {
        ArtifactMeta {
            name: "test".into(),
            kind,
            variant: Variant::Optimized,
            batch,
            n,
            dtype,
            descending: desc,
            block: 256,
            grid_cells: 4,
            file: "test.hlo.txt".into(),
        }
    }

    fn executor(kind: ArtifactKind, batch: usize, n: usize, dtype: Dtype, desc: bool) -> SortExecutor {
        SortExecutor {
            meta: meta(kind, batch, n, dtype, desc),
            hlo_bytes: 0,
        }
    }

    #[test]
    fn merge_row_merges_sorted_halves() {
        let mut gen = Generator::new(2);
        for logn in 1..=12 {
            let n = 1usize << logn;
            let mut v = gen.u32s(n, Distribution::Uniform);
            v[..n / 2].sort_unstable();
            v[n / 2..].sort_unstable();
            let mut want = v.clone();
            want.sort_unstable();
            merge_row(&mut v);
            assert_eq!(v, want, "n=2^{logn}");
        }
    }

    #[test]
    fn executes_batch_rows_independently() {
        let exe = executor(ArtifactKind::Sort, 3, 8, Dtype::U32, false);
        let rows = vec![
            7, 6, 5, 4, 3, 2, 1, 0, // row 0
            0, 2, 1, 3, 5, 4, 7, 6, // row 1
            9, 9, 9, 9, 0, 0, 0, 0, // row 2
        ];
        let out = exe.sort_u32(rows).unwrap();
        assert_eq!(&out[0..8], &[0, 1, 2, 3, 4, 5, 6, 7]);
        assert_eq!(&out[8..16], &[0, 1, 2, 3, 4, 5, 6, 7]);
        assert_eq!(&out[16..24], &[0, 0, 0, 0, 9, 9, 9, 9]);
    }

    #[test]
    fn descending_reverses_rows() {
        let exe = executor(ArtifactKind::Sort, 1, 8, Dtype::U32, true);
        let out = exe.sort_u32(vec![3, 1, 4, 1, 5, 9, 2, 6]).unwrap();
        assert_eq!(out, vec![9, 6, 5, 4, 3, 2, 1, 1]);
    }

    #[test]
    fn wrong_size_mentions_bytes() {
        let exe = executor(ArtifactKind::Sort, 2, 8, Dtype::U32, false);
        let err = exe.sort_u32(vec![1, 2, 3]).unwrap_err();
        assert!(format!("{err:#}").contains("bytes"), "{err:#}");
    }

    #[test]
    fn dtype_mismatch_rejected() {
        let exe = executor(ArtifactKind::Sort, 1, 4, Dtype::F32, false);
        assert!(exe.sort_u32(vec![1, 2, 3, 4]).is_err());
        assert!(exe.sort_i32(vec![1, 2, 3, 4]).is_err());
        assert!(exe.sort_f32(vec![1.0, 0.5, 2.0, -1.0]).is_ok());
    }

    #[test]
    fn compile_validates_hlo_text() {
        let dir = std::env::temp_dir().join("bitonic-tpu-executor-tests");
        std::fs::create_dir_all(&dir).unwrap();

        // Missing file errors with the regeneration hint.
        let missing = SortExecutor::compile(
            meta(ArtifactKind::Sort, 2, 8, Dtype::U32, false),
            &dir.join("nope.hlo.txt"),
        );
        assert!(format!("{:#}", missing.unwrap_err()).contains("compile.aot"));

        // Garbage content rejected.
        let garbage = dir.join("garbage.hlo.txt");
        std::fs::write(&garbage, "not hlo at all").unwrap();
        assert!(SortExecutor::compile(
            meta(ArtifactKind::Sort, 2, 8, Dtype::U32, false),
            &garbage
        )
        .is_err());

        // Shape mismatch rejected; matching shape accepted.
        let good = dir.join("good.hlo.txt");
        std::fs::write(&good, "HloModule test\nENTRY main { u32[2,8] parameter(0) }\n").unwrap();
        assert!(SortExecutor::compile(
            meta(ArtifactKind::Sort, 4, 8, Dtype::U32, false),
            &good
        )
        .is_err());
        let exe =
            SortExecutor::compile(meta(ArtifactKind::Sort, 2, 8, Dtype::U32, false), &good)
                .unwrap();
        assert!(exe.hlo_bytes > 0);
    }

    #[test]
    fn merge_artifact_end_to_end() {
        let exe = executor(ArtifactKind::Merge, 2, 8, Dtype::U32, false);
        let rows = vec![
            1, 3, 5, 7, 0, 2, 4, 6, // two sorted halves
            0, 0, 1, 1, 0, 1, 2, 3, // duplicates across halves
        ];
        let out = exe.sort_u32(rows).unwrap();
        assert_eq!(&out[0..8], &[0, 1, 2, 3, 4, 5, 6, 7]);
        assert_eq!(&out[8..16], &[0, 0, 0, 1, 1, 1, 2, 3]);
    }
}
