//! A loaded sort artifact plus typed marshalling, executed natively with
//! a **plan/execute split**.
//!
//! The original design compiled `artifacts/*.hlo.txt` with the `xla`
//! crate's PJRT CPU client. That crate is not vendored in this offline
//! environment, so the executor is a deterministic **native-CPU
//! fallback** organised the way a real PJRT backend is:
//!
//! * **Plan (compile time).** [`SortExecutor::compile`] loads and
//!   validates the artifact's HLO text (dtype+shape token and module
//!   sanity — catching manifest/file drift at load time, exactly where
//!   PJRT compilation would fail) and precomputes the full network
//!   schedule — the `(phase_len, stride)` step list from
//!   [`crate::sort::network`] — into an [`ExecutionPlan`]. This happens
//!   once per artifact, cached by the registry.
//! * **Execute (request time).** The `sort_*` entry points are a pure
//!   walk over the plan: no schedule re-derivation per row per call.
//!   When the executor holds a shared [`ThreadPool`] (threaded through
//!   [`crate::runtime::Registry`] from the device-host config), the
//!   `(B, N)` buffer is partitioned into row-chunk tasks dispatched via
//!   [`ThreadPool::run_scoped`], so rows sort in parallel — the CPU
//!   analogue of the paper's "keep every lane busy" objective. A
//!   panicking row task fails the batch with an error instead of
//!   poisoning the pool.
//!
//! The executor honours the full artifact contract the integration tests
//! pin down — ascending/descending, u32/i32/f32, sort and merge kinds,
//! MAX-padding semantics — and is bit-exact with the CPU substrates (and
//! with its own serial path; property-tested below). Swapping a real
//! PJRT backend in later replaces the plan walk, not the module
//! boundary: same constructor, same `sort_*` entry points.

use std::path::Path;
use std::sync::Arc;

use crate::sort::bitonic::compare_exchange_step;
use crate::sort::network::{Network, Phase, Step};
use crate::sort::SortKey;
use crate::util::error::Context;
use crate::util::threadpool::{ScopedJob, ThreadPool};

use super::artifact::{ArtifactKind, ArtifactMeta, Dtype};

/// The precompiled execution schedule of one artifact: the exact
/// compare-exchange step list the bitonic network prescribes, plus the
/// pre/post row transforms the artifact kind and direction require.
/// Plain data, `Sync` — shared read-only by every row task. This is the
/// seam a future PJRT backend replaces: planning stays, the walk becomes
/// a device dispatch.
#[derive(Clone, Debug)]
pub struct ExecutionPlan {
    /// Row length `n` the plan was built for.
    n: usize,
    /// Reverse the row's second half before the steps (merge artifacts:
    /// two ascending halves form a bitonic sequence).
    reverse_tail: bool,
    /// `(phase_len, stride)` steps, execution order.
    steps: Vec<Step>,
    /// Reverse the whole row after the steps (descending artifacts).
    reverse_output: bool,
}

impl ExecutionPlan {
    /// Precompute the schedule for an artifact shape. For `Sort` this is
    /// the full network; for `Merge` only the final merge phase
    /// (`log2(n)` steps — the paper §3 primitive, not a full re-sort).
    pub fn new(kind: ArtifactKind, n: usize, descending: bool) -> Self {
        assert!(
            n.is_power_of_two(),
            "execution plans require a power-of-two row length, got {n}"
        );
        let (reverse_tail, steps) = if n < 2 {
            (false, Vec::new())
        } else {
            match kind {
                ArtifactKind::Sort => (false, Network::new(n).step_schedule()),
                // phase_len = n ⇒ every pair compares ascending
                // (i & n == 0 for all i < n).
                ArtifactKind::Merge => (true, Phase { len: n }.steps().collect()),
            }
        };
        Self {
            n,
            reverse_tail,
            steps,
            reverse_output: descending,
        }
    }

    /// Row length the plan covers.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Number of compare-exchange steps the plan walks per row.
    pub fn step_count(&self) -> usize {
        self.steps.len()
    }

    /// Execute the plan over one row of length [`Self::n`].
    pub fn run_row<T: SortKey>(&self, row: &mut [T]) {
        debug_assert_eq!(row.len(), self.n);
        if self.reverse_tail && self.n >= 2 {
            row[self.n / 2..].reverse();
        }
        for s in &self.steps {
            compare_exchange_step(row, s.phase_len, s.stride);
        }
        if self.reverse_output {
            row.reverse();
        }
    }
}

/// One loaded sort/merge artifact, ready to execute.
pub struct SortExecutor {
    /// The artifact this executor was built from.
    pub meta: ArtifactMeta,
    /// Size of the loaded HLO text in bytes (artifact was really read).
    pub hlo_bytes: usize,
    /// The precomputed schedule (plan layer).
    plan: ExecutionPlan,
    /// Shared row-parallel pool; `None` ⇒ serial execution.
    pool: Option<Arc<ThreadPool>>,
}

impl SortExecutor {
    /// Load and validate `hlo_text_path` for `meta`, serial execution.
    /// The HLO text must exist, look like an HLO module, and declare the
    /// dtype + `(batch, n)` shape the manifest promises.
    pub fn compile(meta: ArtifactMeta, hlo_text_path: &Path) -> crate::Result<Self> {
        Self::compile_with_pool(meta, hlo_text_path, None)
    }

    /// [`compile`](Self::compile) with a shared execution pool: rows of
    /// each `(B, N)` batch are sorted in parallel on `pool`.
    pub fn compile_with_pool(
        meta: ArtifactMeta,
        hlo_text_path: &Path,
        pool: Option<Arc<ThreadPool>>,
    ) -> crate::Result<Self> {
        crate::ensure!(
            meta.n.is_power_of_two() && meta.batch >= 1,
            "artifact {} has a malformed shape ({}x{})",
            meta.name,
            meta.batch,
            meta.n
        );
        let text = std::fs::read_to_string(hlo_text_path)
            .with_context(|| format!("reading {hlo_text_path:?} — generate artifacts with `python -m compile.aot` (see README)"))?;
        crate::ensure!(
            text.contains("HloModule"),
            "{hlo_text_path:?} does not look like HLO text"
        );
        // Validate the dtype token together with the shape (`u32[2,8]`,
        // not just `[2,8]`): a manifest dtype/file mismatch must fail at
        // load time, like a real PJRT compile would.
        let shape = format!("{}[{},{}]", meta.dtype.hlo_token(), meta.batch, meta.n);
        crate::ensure!(
            text.contains(&shape),
            "artifact {} HLO text does not declare {shape} — manifest dtype/shape vs file mismatch",
            meta.name
        );
        let plan = ExecutionPlan::new(meta.kind, meta.n, meta.descending);
        Ok(Self {
            meta,
            hlo_bytes: text.len(),
            plan,
            pool,
        })
    }

    /// The precomputed schedule this executor walks.
    pub fn plan(&self) -> &ExecutionPlan {
        &self.plan
    }

    /// Worker threads available for row-parallel execution (1 ⇒ serial).
    pub fn threads(&self) -> usize {
        self.pool.as_ref().map_or(1, |p| p.threads())
    }

    /// Sort a full `(batch, n)` buffer of u32 keys, row-major, in place.
    /// Returns the sorted rows in the same layout. This is the hot path:
    /// the buffer is taken by value (the host thread already owns it) so
    /// no defensive copy happens per batch.
    pub fn sort_u32(&self, rows: Vec<u32>) -> crate::Result<Vec<u32>> {
        crate::ensure!(
            self.meta.dtype == Dtype::U32,
            "artifact {} holds {:?} keys",
            self.meta.name,
            self.meta.dtype
        );
        self.execute(rows)
    }

    /// Sort `(batch, n)` i32 keys.
    pub fn sort_i32(&self, rows: Vec<i32>) -> crate::Result<Vec<i32>> {
        crate::ensure!(self.meta.dtype == Dtype::I32, "dtype mismatch");
        self.execute(rows)
    }

    /// Sort `(batch, n)` f32 keys (finite values only — NaN ordering is
    /// not defined for the min/max network; see DESIGN.md §6).
    pub fn sort_f32(&self, rows: Vec<f32>) -> crate::Result<Vec<f32>> {
        crate::ensure!(self.meta.dtype == Dtype::F32, "dtype mismatch");
        self.execute(rows)
    }

    fn execute<T: SortKey>(&self, mut rows: Vec<T>) -> crate::Result<Vec<T>> {
        let (b, n) = (self.meta.batch, self.meta.n);
        crate::ensure!(
            rows.len() == b * n,
            "artifact {} wants {}x{} ({} bytes), got {} bytes",
            self.meta.name,
            b,
            n,
            b * n * self.meta.dtype.size(),
            rows.len() * self.meta.dtype.size()
        );
        match &self.pool {
            // Row-parallel path: worth the dispatch only when several
            // rows can overlap and each carries real work.
            Some(pool) if pool.threads() > 1 && b > 1 && n >= 64 => {
                // Oversubscribe 2× so uneven worker speeds load-balance.
                let chunks = (pool.threads() * 2).min(b);
                let rows_per_task = (b + chunks - 1) / chunks;
                let plan = &self.plan;
                let tasks: Vec<ScopedJob> = rows
                    .chunks_mut(rows_per_task * n)
                    .map(|chunk| {
                        Box::new(move || {
                            for row in chunk.chunks_mut(n) {
                                plan.run_row(row);
                            }
                        }) as ScopedJob
                    })
                    .collect();
                pool.run_scoped(tasks).map_err(|panicked| {
                    crate::err!(
                        "artifact {}: {panicked} row task(s) panicked during parallel execute",
                        self.meta.name
                    )
                })?;
            }
            _ => {
                for row in rows.chunks_mut(n) {
                    self.plan.run_row(row);
                }
            }
        }
        Ok(rows)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sort::network::Variant;
    use crate::util::prop::{check_with, Config, Strategy};
    use crate::workload::rng::Pcg32;
    use crate::workload::{Distribution, Generator};

    fn meta(kind: ArtifactKind, batch: usize, n: usize, dtype: Dtype, desc: bool) -> ArtifactMeta {
        ArtifactMeta {
            name: "test".into(),
            kind,
            variant: Variant::Optimized,
            batch,
            n,
            dtype,
            descending: desc,
            block: 256,
            grid_cells: 4,
            file: "test.hlo.txt".into(),
        }
    }

    fn executor_with_pool(
        kind: ArtifactKind,
        batch: usize,
        n: usize,
        dtype: Dtype,
        desc: bool,
        pool: Option<Arc<ThreadPool>>,
    ) -> SortExecutor {
        SortExecutor {
            meta: meta(kind, batch, n, dtype, desc),
            hlo_bytes: 0,
            plan: ExecutionPlan::new(kind, n, desc),
            pool,
        }
    }

    fn executor(kind: ArtifactKind, batch: usize, n: usize, dtype: Dtype, desc: bool) -> SortExecutor {
        executor_with_pool(kind, batch, n, dtype, desc, None)
    }

    #[test]
    fn merge_plan_merges_sorted_halves() {
        let mut gen = Generator::new(2);
        for logn in 1..=12 {
            let n = 1usize << logn;
            let plan = ExecutionPlan::new(ArtifactKind::Merge, n, false);
            let mut v = gen.u32s(n, Distribution::Uniform);
            v[..n / 2].sort_unstable();
            v[n / 2..].sort_unstable();
            let mut want = v.clone();
            want.sort_unstable();
            plan.run_row(&mut v);
            assert_eq!(v, want, "n=2^{logn}");
        }
    }

    #[test]
    fn plan_precomputes_full_network_for_sort() {
        let plan = ExecutionPlan::new(ArtifactKind::Sort, 1 << 10, false);
        assert_eq!(plan.step_count(), Network::new(1 << 10).step_count());
        assert_eq!(plan.n(), 1 << 10);
        // Merge plans walk only the final phase: log2(n) steps.
        let merge = ExecutionPlan::new(ArtifactKind::Merge, 1 << 10, false);
        assert_eq!(merge.step_count(), 10);
    }

    #[test]
    fn executes_batch_rows_independently() {
        let exe = executor(ArtifactKind::Sort, 3, 8, Dtype::U32, false);
        let rows = vec![
            7, 6, 5, 4, 3, 2, 1, 0, // row 0
            0, 2, 1, 3, 5, 4, 7, 6, // row 1
            9, 9, 9, 9, 0, 0, 0, 0, // row 2
        ];
        let out = exe.sort_u32(rows).unwrap();
        assert_eq!(&out[0..8], &[0, 1, 2, 3, 4, 5, 6, 7]);
        assert_eq!(&out[8..16], &[0, 1, 2, 3, 4, 5, 6, 7]);
        assert_eq!(&out[16..24], &[0, 0, 0, 0, 9, 9, 9, 9]);
    }

    #[test]
    fn descending_reverses_rows() {
        let exe = executor(ArtifactKind::Sort, 1, 8, Dtype::U32, true);
        let out = exe.sort_u32(vec![3, 1, 4, 1, 5, 9, 2, 6]).unwrap();
        assert_eq!(out, vec![9, 6, 5, 4, 3, 2, 1, 1]);
    }

    #[test]
    fn wrong_size_mentions_bytes() {
        let exe = executor(ArtifactKind::Sort, 2, 8, Dtype::U32, false);
        let err = exe.sort_u32(vec![1, 2, 3]).unwrap_err();
        assert!(format!("{err:#}").contains("bytes"), "{err:#}");
    }

    #[test]
    fn dtype_mismatch_rejected() {
        let exe = executor(ArtifactKind::Sort, 1, 4, Dtype::F32, false);
        assert!(exe.sort_u32(vec![1, 2, 3, 4]).is_err());
        assert!(exe.sort_i32(vec![1, 2, 3, 4]).is_err());
        assert!(exe.sort_f32(vec![1.0, 0.5, 2.0, -1.0]).is_ok());
    }

    #[test]
    fn compile_validates_hlo_text() {
        let dir = std::env::temp_dir().join("bitonic-tpu-executor-tests");
        std::fs::create_dir_all(&dir).unwrap();

        // Missing file errors with the regeneration hint.
        let missing = SortExecutor::compile(
            meta(ArtifactKind::Sort, 2, 8, Dtype::U32, false),
            &dir.join("nope.hlo.txt"),
        );
        assert!(format!("{:#}", missing.unwrap_err()).contains("compile.aot"));

        // Garbage content rejected.
        let garbage = dir.join("garbage.hlo.txt");
        std::fs::write(&garbage, "not hlo at all").unwrap();
        assert!(SortExecutor::compile(
            meta(ArtifactKind::Sort, 2, 8, Dtype::U32, false),
            &garbage
        )
        .is_err());

        // Shape mismatch rejected; matching dtype+shape accepted.
        let good = dir.join("good.hlo.txt");
        std::fs::write(&good, "HloModule test\nENTRY main { u32[2,8] parameter(0) }\n").unwrap();
        assert!(SortExecutor::compile(
            meta(ArtifactKind::Sort, 4, 8, Dtype::U32, false),
            &good
        )
        .is_err());
        // Dtype mismatch at the same shape also rejected: the manifest
        // claims f32 but the HLO declares u32[2,8].
        let dtype_drift = SortExecutor::compile(
            meta(ArtifactKind::Sort, 2, 8, Dtype::F32, false),
            &good,
        );
        assert!(
            format!("{:#}", dtype_drift.unwrap_err()).contains("f32[2,8]"),
            "dtype drift must name the expected token"
        );
        let exe =
            SortExecutor::compile(meta(ArtifactKind::Sort, 2, 8, Dtype::U32, false), &good)
                .unwrap();
        assert!(exe.hlo_bytes > 0);
        assert_eq!(exe.threads(), 1);
        assert_eq!(exe.plan().step_count(), Network::new(8).step_count());
    }

    #[test]
    fn merge_artifact_end_to_end() {
        let exe = executor(ArtifactKind::Merge, 2, 8, Dtype::U32, false);
        let rows = vec![
            1, 3, 5, 7, 0, 2, 4, 6, // two sorted halves
            0, 0, 1, 1, 0, 1, 2, 3, // duplicates across halves
        ];
        let out = exe.sort_u32(rows).unwrap();
        assert_eq!(&out[0..8], &[0, 1, 2, 3, 4, 5, 6, 7]);
        assert_eq!(&out[8..16], &[0, 0, 0, 1, 1, 1, 2, 3]);
    }

    #[test]
    fn pooled_execution_sorts_large_batches() {
        let pool = Arc::new(ThreadPool::new(4, 16));
        let exe = executor_with_pool(ArtifactKind::Sort, 16, 256, Dtype::U32, false, Some(pool));
        assert_eq!(exe.threads(), 4);
        let mut gen = Generator::new(0xB00);
        let rows = gen.u32s(16 * 256, Distribution::Uniform);
        let out = exe.sort_u32(rows.clone()).unwrap();
        for r in 0..16 {
            let mut want = rows[r * 256..(r + 1) * 256].to_vec();
            want.sort_unstable();
            assert_eq!(&out[r * 256..(r + 1) * 256], &want[..], "row {r}");
        }
    }

    /// One random executor configuration for the bit-exactness property.
    #[derive(Clone, Debug)]
    struct Case {
        kind: ArtifactKind,
        dtype: Dtype,
        descending: bool,
        batch: usize,
        n: usize,
        seed: u64,
    }

    struct CaseStrategy;
    impl Strategy for CaseStrategy {
        type Value = Case;
        fn sample(&self, rng: &mut Pcg32) -> Case {
            Case {
                kind: if rng.next_below(2) == 0 {
                    ArtifactKind::Sort
                } else {
                    ArtifactKind::Merge
                },
                dtype: match rng.next_below(3) {
                    0 => Dtype::U32,
                    1 => Dtype::I32,
                    _ => Dtype::F32,
                },
                descending: rng.next_below(2) == 1,
                batch: 1 + rng.next_below(8) as usize,
                n: 1usize << (1 + rng.next_below(8)), // 2..=256
                seed: rng.next_u32() as u64,
            }
        }
        fn shrink(&self, v: &Case) -> Vec<Case> {
            let mut out = Vec::new();
            if v.batch > 1 {
                out.push(Case { batch: v.batch / 2, ..v.clone() });
            }
            if v.n > 2 {
                out.push(Case { n: v.n / 2, ..v.clone() });
            }
            out
        }
    }

    /// Run the same input through a serial and a pooled executor of the
    /// same configuration; outputs must agree bit-for-bit.
    fn assert_bit_exact<T>(case: &Case, pool: &Arc<ThreadPool>, mut rows: Vec<T>) -> Result<(), String>
    where
        T: SortKey + PartialEq + std::fmt::Debug,
    {
        if case.kind == ArtifactKind::Merge {
            // Merge contract: each row's two halves arrive sorted asc.
            for row in rows.chunks_mut(case.n) {
                let half = case.n / 2;
                crate::sort::bitonic::bitonic_sort(&mut row[..half]);
                crate::sort::bitonic::bitonic_sort(&mut row[half..]);
            }
        }
        let serial = executor_with_pool(case.kind, case.batch, case.n, case.dtype, case.descending, None);
        let pooled = executor_with_pool(
            case.kind,
            case.batch,
            case.n,
            case.dtype,
            case.descending,
            Some(Arc::clone(pool)),
        );
        let a = serial.execute(rows.clone()).map_err(|e| format!("{e:#}"))?;
        let b = pooled.execute(rows).map_err(|e| format!("{e:#}"))?;
        if a != b {
            return Err("parallel output diverged from serial".into());
        }
        Ok(())
    }

    #[test]
    fn pooled_bit_exact_with_serial_across_dtypes_kinds_directions() {
        let pool = Arc::new(ThreadPool::new(4, 32));
        check_with(
            Config {
                cases: 48,
                ..Config::default()
            },
            &CaseStrategy,
            |case| {
                let mut gen = Generator::new(case.seed);
                let count = case.batch * case.n;
                match case.dtype {
                    Dtype::U32 => {
                        assert_bit_exact(case, &pool, gen.u32s(count, Distribution::DupHeavy))
                    }
                    Dtype::I32 => {
                        let rows: Vec<i32> = gen
                            .u32s(count, Distribution::Uniform)
                            .into_iter()
                            .map(|x| x as i32)
                            .collect();
                        assert_bit_exact(case, &pool, rows)
                    }
                    Dtype::F32 => {
                        // Finite floats only (generator contract).
                        assert_bit_exact(case, &pool, gen.f32s(count, Distribution::Uniform))
                    }
                }
            },
        );
    }
}
